//! Virtual-clock invariants at workload level: multi-stream overlap
//! wins exactly where the paper says it should, the halo-overhead
//! analysis predicts the lavaMD negative case, and a corpus-style sweep
//! runs with no real-time sleeping and bit-identical timelines.
//!
//! Every assertion here is exact (integer nanoseconds / byte counts),
//! not tolerance-based — that is the point of `TimeMode::Virtual`.

use hetstream::device::{DeviceProfile, TimeMode};
use hetstream::hstreams::{Context, ContextBuilder};
use hetstream::partition::halo_overhead_ratio;
use hetstream::workloads::{Benchmark, LavaMd, Mode, Nn};

fn virtual_ctx(artifacts: &[&str]) -> Context {
    ContextBuilder::new()
        .profile(DeviceProfile::mic31sp())
        .only_artifacts(artifacts.to_vec())
        .time_mode(TimeMode::Virtual)
        .build()
        .expect("context")
}

#[test]
fn nn_multi_stream_beats_single_stream_exactly() {
    // Embarrassingly Independent via partition::independent: the
    // streamed port's virtual makespan must strictly beat the
    // serialized pipeline — an exact u64 comparison, no tolerances.
    let b = Nn::new(1);
    let ctx = virtual_ctx(&["nn_dist"]);
    let single = b.run(&ctx, Mode::Streamed(1)).expect("1-stream run");
    let multi = b.run(&ctx, Mode::Streamed(4)).expect("4-stream run");
    assert!(single.validated && multi.validated);
    let (s, m) = (single.wall.as_nanos(), multi.wall.as_nanos());
    assert!(m < s, "4-stream virtual makespan {m} must beat 1-stream {s}");
}

#[test]
fn virtual_makespan_is_deterministic_across_runs_and_contexts() {
    let b = Nn::new(1);
    let runs: Vec<u128> = (0..2)
        .map(|_| {
            let ctx = virtual_ctx(&["nn_dist"]);
            let base = b.run(&ctx, Mode::Baseline).expect("baseline");
            let strm = b.run(&ctx, Mode::Streamed(4)).expect("streamed");
            assert!(base.validated && strm.validated);
            base.wall.as_nanos() * 1_000_000_007 + strm.wall.as_nanos()
        })
        .collect();
    assert_eq!(runs[0], runs[1], "identical programs must yield identical timelines");
}

#[test]
fn halo_overhead_predicts_the_lavamd_negative_case() {
    // §5: lavaMD's halo (2*111) is comparable to its task (256), so the
    // streamed port ships ~1.9x the bytes.  Byte counts are exact.
    let b = LavaMd::new(1);
    let ctx = virtual_ctx(&["lavamd_box"]);
    let base = b.run(&ctx, Mode::Baseline).expect("baseline");
    let strm = b.run(&ctx, Mode::Streamed(4)).expect("streamed");
    assert!(base.validated && strm.validated);

    let chunks = 64; // LavaMd::new(1)
    let (chunk, halo) = (256usize, 111usize);
    assert_eq!(base.h2d_bytes, ((chunks * chunk + 2 * halo) * 4) as u64, "bulk = padded array");
    assert_eq!(
        strm.h2d_bytes,
        (chunks * (chunk + 2 * halo) * 4) as u64,
        "streamed = every task ships its halo window"
    );
    assert!(halo_overhead_ratio(chunk, halo) > 0.85, "halo ≈ task size");

    // The redundant bytes + per-task DMA latency must erode lavaMD's
    // streaming gain below nn's (the paper's contrast: ~85% vs a loss).
    let nn = Nn::new(1);
    let nn_ctx = virtual_ctx(&["nn_dist"]);
    let nn_base = nn.run(&nn_ctx, Mode::Baseline).expect("nn baseline");
    let nn_strm = nn.run(&nn_ctx, Mode::Streamed(4)).expect("nn streamed");
    let gain = |b: u128, s: u128| b as f64 / s.max(1) as f64;
    assert!(
        gain(nn_base.wall.as_nanos(), nn_strm.wall.as_nanos())
            > gain(base.wall.as_nanos(), strm.wall.as_nanos()),
        "nn's streaming gain must exceed lavaMD's (halo overhead predicts the loss)"
    );
}

#[test]
fn virtual_sweep_sleeps_through_nothing() {
    // On a deliberately glacial profile the modeled makespan is
    // minutes; the run must still finish in interactive time (wall ≪
    // modeled).  Margins are huge on both sides so debug builds and
    // loaded CI machines cannot flake this: wall-clock pacing would
    // need > 8 minutes, the real interpreter work is well under 60 s.
    let glacial = DeviceProfile {
        name: "glacial-sim".into(), // -sim: used as-is, no dilation
        h2d_gbps: 1e-3,             // 128 KiB chunk upload ≈ 130 ms modeled
        d2h_gbps: 1e-3,
        latency_us: 0.0,
        alloc_us_per_mb: 0.0,
        gflops: 1e-5, // 650k-FLOP chunk kernel ≈ 65 s modeled
        launch_us: 0.0,
        duplex: true,
    };
    let ctx = ContextBuilder::new()
        .profile(glacial)
        .only_artifacts(["nn_dist"])
        .time_mode(TimeMode::Virtual)
        .build()
        .expect("context");
    let b = Nn::new(1); // 8 chunks => >= 8 * 65 s of modeled kernel time
    let t0 = std::time::Instant::now();
    let r = b.run(&ctx, Mode::Streamed(4)).expect("run");
    let real = t0.elapsed();
    assert!(r.validated);
    assert!(
        r.wall > std::time::Duration::from_secs(8 * 60),
        "modeled makespan should be minutes, got {:?}",
        r.wall
    );
    assert!(
        real < std::time::Duration::from_secs(60),
        "virtual run must not sleep through modeled time (took {real:?})"
    );
}
