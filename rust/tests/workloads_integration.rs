//! Workload integration: all 13 streamed benchmarks + both Reduction
//! variants run end-to-end (bulk and multi-stream) on an un-paced
//! device and validate against their host oracles.
//!
//! Pacing is irrelevant to correctness, so these use the `instant`
//! profile to keep the suite fast; the paced timing behaviour is
//! covered by benches and `analysis_integration`.

use hetstream::device::DeviceProfile;
use hetstream::hstreams::{Context, ContextBuilder};
use hetstream::workloads::{fig9_benchmarks, Benchmark, Mode};

fn ctx_for(b: &dyn Benchmark) -> Context {
    ContextBuilder::new()
        .profile(DeviceProfile::instant())
        .only_artifacts(b.artifacts().into_iter().map(String::from).collect::<Vec<_>>())
        .build()
        .expect("context")
}

fn check(b: &dyn Benchmark) {
    let ctx = ctx_for(b);
    let base = b.run(&ctx, Mode::Baseline).expect("baseline run");
    assert!(base.validated, "{}: baseline failed validation", b.name());
    for streams in [1, 3, 4] {
        let s = b.run(&ctx, Mode::Streamed(streams)).expect("streamed run");
        assert!(s.validated, "{}: {streams}-stream failed validation", b.name());
        assert!(
            s.h2d_bytes >= base.h2d_bytes,
            "{}: streamed H2D can only add (halo) bytes",
            b.name()
        );
    }
}

// One test per benchmark so failures localize.

#[test]
fn nn_validates() {
    check(&hetstream::workloads::Nn::new(1));
}

#[test]
fn fwt_validates() {
    check(&hetstream::workloads::Fwt::new(1));
}

#[test]
fn cfft2d_validates() {
    check(&hetstream::workloads::ConvFft2d::new(1));
}

#[test]
fn nw_validates() {
    check(&hetstream::workloads::NeedlemanWunsch::new(1));
}

#[test]
fn lavamd_validates() {
    check(&hetstream::workloads::LavaMd::new(1));
}

#[test]
fn convsep_validates() {
    check(&hetstream::workloads::ConvSep::new(1));
}

#[test]
fn transpose_validates() {
    check(&hetstream::workloads::Transpose::new(1));
}

#[test]
fn prefix_sum_validates() {
    check(&hetstream::workloads::PrefixSum::new(1));
}

#[test]
fn histogram_validates() {
    check(&hetstream::workloads::Histogram::new(1));
}

#[test]
fn matmul_validates() {
    check(&hetstream::workloads::MatMul::new(1));
}

#[test]
fn vecadd_validates() {
    check(&hetstream::workloads::VectorAdd::new(1));
}

#[test]
fn blackscholes_validates() {
    check(&hetstream::workloads::BlackScholes::new(1));
}

#[test]
fn stencil_validates() {
    check(&hetstream::workloads::Stencil::new(1));
}

#[test]
fn reduction_variants_validate() {
    check(&hetstream::workloads::ReductionV1::new(1));
    check(&hetstream::workloads::ReductionV2::new(1));
}

#[test]
fn fig9_registry_is_the_papers_thirteen() {
    let benches = fig9_benchmarks(1);
    assert_eq!(benches.len(), 13);
    let names: Vec<&str> = benches.iter().map(|b| b.name()).collect();
    for expect in ["nn", "FastWalshTransform", "ConvolutionFFT2D", "nw", "lavaMD"] {
        assert!(names.contains(&expect), "missing {expect}");
    }
}

#[test]
fn halo_benchmarks_ship_redundant_bytes() {
    // The False Dependent ports must transfer more than the bulk port
    // (Fig. 7's redundant boundary transfer) — and lavaMD's ratio must
    // be close to the paper's ~1.9x.
    let b = hetstream::workloads::LavaMd::new(1);
    let ctx = ctx_for(&b);
    let base = b.run(&ctx, Mode::Baseline).unwrap();
    let strm = b.run(&ctx, Mode::Streamed(4)).unwrap();
    let ratio = strm.h2d_bytes as f64 / base.h2d_bytes as f64;
    assert!(ratio > 1.5 && ratio < 2.0, "lavaMD halo ratio {ratio}");

    let b = hetstream::workloads::Stencil::new(1);
    let ctx = ctx_for(&b);
    let base = b.run(&ctx, Mode::Baseline).unwrap();
    let strm = b.run(&ctx, Mode::Streamed(4)).unwrap();
    let ratio = strm.h2d_bytes as f64 / base.h2d_bytes as f64;
    assert!(ratio > 1.0 && ratio < 1.1, "stencil halo ratio {ratio} should be tiny");
}

#[test]
fn nw_scales_to_larger_grids() {
    // True Dependent wavefront at 2x grid still equals the DP oracle.
    let b = hetstream::workloads::NeedlemanWunsch::new(2);
    let ctx = ctx_for(&b);
    let r = b.run(&ctx, Mode::Streamed(6)).expect("run");
    assert!(r.validated);
    assert_eq!(r.tasks, 16 * 16);
}

#[test]
fn dct8x8_validates() {
    check(&hetstream::workloads::Dct8x8::new(1));
}

#[test]
fn dotproduct_validates() {
    check(&hetstream::workloads::DotProduct::new(1));
}

#[test]
fn hotspot_iterative_validates() {
    // The Iterative control: correctness of the device ping-pong chain
    // against the iterated host oracle, in both modes.
    check(&hetstream::workloads::Hotspot::new(1));
}

#[test]
fn hotspot_dependency_chain_is_ordered() {
    // Each step's kernel must retire after its predecessor (RAW chain).
    use hetstream::workloads::Hotspot;
    let b = Hotspot::new(1);
    let ctx = ctx_for(&b);
    let r = b.run(&ctx, Mode::Streamed(8)).expect("run");
    assert!(r.validated);
    assert_eq!(r.tasks, b.steps());
}
