//! Property-based invariants (seeded sweeps via `util::prop`):
//! partition coverage, wavefront topology, arena safety, CDF
//! monotonicity, JSON round-trips.

use std::collections::HashSet;

use hetstream::analysis::cdf_points;
use hetstream::device::DeviceArena;
use hetstream::partition::{chunk_ranges, diagonals, halo_chunks, tile_coords};
use hetstream::util::json::{escape, Json};
use hetstream::util::prop::{check, Rng};

#[test]
fn prop_chunk_ranges_exactly_cover() {
    check(200, |rng: &mut Rng| {
        let total = rng.range(0, 10_000);
        let chunks = rng.range(1, 64);
        let rs = chunk_ranges(total, chunks);
        assert_eq!(rs.len(), chunks);
        let mut pos = 0;
        for (i, r) in rs.iter().enumerate() {
            assert_eq!(r.index, i);
            assert_eq!(r.start, pos, "contiguous");
            pos += r.len;
        }
        assert_eq!(pos, total, "exact cover");
        let min = rs.iter().map(|r| r.len).min().unwrap();
        let max = rs.iter().map(|r| r.len).max().unwrap();
        assert!(max - min <= 1, "balanced");
    });
}

#[test]
fn prop_halo_windows_contain_owned_range() {
    check(200, |rng: &mut Rng| {
        let total = rng.range(64, 50_000);
        let chunks = rng.range(1, 32);
        let halo = rng.range(0, 512);
        let hs = halo_chunks(total, chunks, halo);
        assert_eq!(hs.iter().map(|h| h.len).sum::<usize>(), total);
        for h in &hs {
            // In padded coordinates the owned range [start+halo, ..] sits
            // strictly inside the transferred window.
            assert_eq!(h.xfer_start, h.start);
            assert_eq!(h.xfer_len, h.len + 2 * halo);
        }
    });
}

#[test]
fn prop_wavefront_is_a_topological_order() {
    check(60, |rng: &mut Rng| {
        let rows = rng.range(1, 12);
        let cols = rng.range(1, 12);
        let order = tile_coords(rows, cols);
        assert_eq!(order.len(), rows * cols);
        let mut pos = vec![vec![0usize; cols]; rows];
        let uniq: HashSet<_> = order.iter().collect();
        assert_eq!(uniq.len(), order.len(), "no duplicates");
        for (i, c) in order.iter().enumerate() {
            pos[c.bi][c.bj] = i;
        }
        for c in &order {
            if c.bi > 0 {
                assert!(pos[c.bi - 1][c.bj] < pos[c.bi][c.bj]);
            }
            if c.bj > 0 {
                assert!(pos[c.bi][c.bj - 1] < pos[c.bi][c.bj]);
            }
            if c.bi > 0 && c.bj > 0 {
                assert!(pos[c.bi - 1][c.bj - 1] < pos[c.bi][c.bj]);
            }
        }
        // Diagonal widths: grow by 1, plateau, shrink by 1.
        let ds = diagonals(rows, cols);
        let widths: Vec<usize> = ds.iter().map(|d| d.tiles.len()).collect();
        for w in widths.windows(2) {
            let delta = w[1] as isize - w[0] as isize;
            assert!((-1..=1).contains(&delta), "widths change by at most 1: {widths:?}");
        }
        assert_eq!(widths.iter().sum::<usize>(), rows * cols);
    });
}

#[test]
fn prop_arena_never_leaks_or_overlaps() {
    check(50, |rng: &mut Rng| {
        let cap = 1 << 20;
        let mut arena = DeviceArena::new(cap);
        let mut live: Vec<(hetstream::device::BufId, usize, u8)> = Vec::new();
        for step in 0..rng.range(10, 120) {
            if live.is_empty() || rng.below(2) == 0 {
                let len = rng.range(1, 32_768);
                if let Ok(id) = arena.alloc(len) {
                    let tag = (step % 251) as u8;
                    arena
                        .write(hetstream::device::DevRegion::whole(id, len), &vec![tag; len])
                        .unwrap();
                    live.push((id, len, tag));
                }
            } else {
                let idx = rng.below(live.len() as u64) as usize;
                let (id, len, tag) = live.swap_remove(idx);
                // Buffer still holds exactly its own bytes (no overlap
                // with any other allocation).
                let back = arena.read(hetstream::device::DevRegion::whole(id, len)).unwrap();
                assert!(back.iter().all(|&b| b == tag), "buffer integrity");
                arena.free(id).unwrap();
            }
            assert!(arena.used() <= cap, "capacity respected");
        }
        for (id, len, tag) in live {
            let back = arena.read(hetstream::device::DevRegion::whole(id, len)).unwrap();
            assert!(back.iter().all(|&b| b == tag));
            arena.free(id).unwrap();
        }
        assert_eq!(arena.used(), 0, "all memory returned");
        assert_eq!(arena.live_buffers(), 0);
    });
}

#[test]
fn prop_cdf_is_monotone_and_normalized() {
    check(100, |rng: &mut Rng| {
        let n = rng.range(1, 500);
        let vals: Vec<f64> = (0..n).map(|_| rng.unit_f64() * 2.0 - 0.5).collect();
        let pts = cdf_points(&vals);
        assert_eq!(pts.len(), n);
        assert!((pts.last().unwrap().fraction - 1.0).abs() < 1e-12);
        for w in pts.windows(2) {
            assert!(w[0].value <= w[1].value);
            assert!(w[0].fraction < w[1].fraction + 1e-12);
        }
    });
}

#[test]
fn prop_json_string_roundtrip() {
    check(200, |rng: &mut Rng| {
        let len = rng.range(0, 60);
        let s: String = (0..len)
            .map(|_| {
                let c = rng.below(128) as u8;
                if c.is_ascii_graphic() || c == b' ' {
                    c as char
                } else {
                    match c % 5 {
                        0 => '"',
                        1 => '\\',
                        2 => '\n',
                        3 => '\t',
                        _ => 'é',
                    }
                }
            })
            .collect();
        let doc = format!("{{\"k\": \"{}\"}}", escape(&s));
        let parsed = Json::parse(&doc).unwrap();
        assert_eq!(parsed.get("k").unwrap().as_str(), Some(s.as_str()));
    });
}

#[test]
fn prop_json_numbers_roundtrip() {
    check(200, |rng: &mut Rng| {
        let v = (rng.unit_f64() - 0.5) * 1e9;
        let doc = format!("{{\"n\": {v}}}");
        let parsed = Json::parse(&doc).unwrap();
        let got = parsed.get("n").unwrap().as_f64().unwrap();
        assert!((got - v).abs() <= 1e-6 * v.abs().max(1.0));
    });
}

// --- virtual-clock scheduler properties ---------------------------------

mod sched {
    use std::sync::Arc;

    use hetstream::device::{DevRegion, DeviceProfile, HostSrc, TimeMode};
    use hetstream::hstreams::{host_dst, Context, ContextBuilder, Event};
    use hetstream::util::prop::Rng;

    const BURNER_BYTES: usize = 65536 * 4;

    /// One op of a randomly generated multi-stream program.
    #[derive(Debug, Clone)]
    pub enum Op {
        H2d { stream: usize, len: usize },
        D2h { stream: usize, len: usize },
        Kex { stream: usize, flops: u64 },
        /// Make `stream`'s next op wait on issued op `dep`.
        Wait { stream: usize, dep: usize },
    }

    /// Generate a program: `streams` in-order pipelines with random
    /// transfers, kernels and cross-stream waits on *earlier* ops.
    pub fn gen_program(rng: &mut Rng, streams: usize) -> Vec<Op> {
        let n_ops = rng.range(6, 28);
        let mut ops = Vec::with_capacity(n_ops);
        let mut issued = 0usize;
        for _ in 0..n_ops {
            let stream = rng.range(0, streams - 1);
            match rng.below(8) {
                0 | 1 | 2 => {
                    ops.push(Op::H2d { stream, len: rng.range(64, 65536) });
                    issued += 1;
                }
                3 | 4 => {
                    ops.push(Op::D2h { stream, len: rng.range(64, 65536) });
                    issued += 1;
                }
                5 | 6 => {
                    ops.push(Op::Kex { stream, flops: rng.range(1_000, 400_000) as u64 });
                    issued += 1;
                }
                _ => {
                    if issued > 0 {
                        ops.push(Op::Wait { stream, dep: rng.range(0, issued - 1) });
                    }
                }
            }
        }
        ops
    }

    /// Fresh virtual-mode context on a small paced profile (`-sim`
    /// suffix: used as-is, no auto-dilation).
    pub fn virtual_ctx(workers: usize) -> Context {
        ContextBuilder::new()
            .profile(DeviceProfile {
                name: "prop-sim".into(),
                h2d_gbps: 0.5,
                d2h_gbps: 0.4,
                latency_us: 10.0,
                alloc_us_per_mb: 50.0,
                gflops: 1.0,
                launch_us: 5.0,
                duplex: true,
            })
            .only_artifacts(["burner_8"])
            .compute_workers(workers)
            .time_mode(TimeMode::Virtual)
            .build()
            .expect("context")
    }

    /// Execute the program; returns per issued op: (stream, explicit
    /// cross-stream dep indices, completion event).
    pub fn run_program(
        ctx: &Context,
        streams: usize,
        ops: &[Op],
    ) -> Vec<(usize, Vec<usize>, Event)> {
        let payload = Arc::new(vec![0x4du8; 65536]);
        let xfer = DevRegion::whole(ctx.alloc(65536).unwrap(), 65536);
        let kin = DevRegion::whole(ctx.alloc(BURNER_BYTES).unwrap(), BURNER_BYTES);
        let kout = DevRegion::whole(ctx.alloc(BURNER_BYTES).unwrap(), BURNER_BYTES);

        let mut ss: Vec<_> = (0..streams).map(|_| ctx.stream()).collect();
        let mut issued: Vec<(usize, Vec<usize>, Event)> = Vec::new();
        let mut pending: Vec<Vec<usize>> = vec![Vec::new(); streams];
        for op in ops {
            match op {
                Op::H2d { stream, len } => {
                    let region = DevRegion { buf: xfer.buf, off: 0, len: *len };
                    let src = HostSrc { data: payload.clone(), off: 0, len: *len };
                    let e = ss[*stream].h2d(src, region);
                    issued.push((*stream, std::mem::take(&mut pending[*stream]), e));
                }
                Op::D2h { stream, len } => {
                    let region = DevRegion { buf: xfer.buf, off: 0, len: *len };
                    let e = ss[*stream].d2h(region, host_dst(*len));
                    issued.push((*stream, std::mem::take(&mut pending[*stream]), e));
                }
                Op::Kex { stream, flops } => {
                    let e = ss[*stream].kex_with(
                        "burner_8",
                        vec![kin],
                        vec![kout],
                        Some(*flops),
                        1,
                    );
                    issued.push((*stream, std::mem::take(&mut pending[*stream]), e));
                }
                Op::Wait { stream, dep } => {
                    ss[*stream].wait_event(issued[*dep].2.clone());
                    pending[*stream].push(*dep);
                }
            }
        }
        for s in &ss {
            s.sync();
        }
        issued
    }
}

#[test]
fn prop_virtual_stream_order_is_fifo() {
    use hetstream::util::prop::{check, Rng};
    check(25, |rng: &mut Rng| {
        let streams = rng.range(1, 3);
        let prog = sched::gen_program(rng, streams);
        let ctx = sched::virtual_ctx(2);
        let issued = sched::run_program(&ctx, streams, &prog);
        // Per-stream FIFO: each op starts no earlier than its stream
        // predecessor retires (in-order pipeline semantics), exactly.
        let mut last_end = vec![None; streams];
        for (stream, _, e) in &issued {
            let s = e.sample().expect("synced");
            if let Some(end) = last_end[*stream] {
                assert!(s.start >= end, "stream {stream} op started before predecessor retired");
            }
            assert!(s.end >= s.start);
            last_end[*stream] = Some(s.end);
        }
    });
}

#[test]
fn prop_no_op_fires_before_its_deps() {
    use hetstream::util::prop::{check, Rng};
    check(25, |rng: &mut Rng| {
        let streams = rng.range(2, 3);
        let prog = sched::gen_program(rng, streams);
        let ctx = sched::virtual_ctx(1);
        let issued = sched::run_program(&ctx, streams, &prog);
        for (_, deps, e) in &issued {
            let s = e.sample().expect("synced");
            for &d in deps {
                let dep = issued[d].2.sample().expect("dep synced");
                assert!(
                    s.start >= dep.end,
                    "op started at {:?} before its cross-stream dep retired at {:?}",
                    s.start,
                    dep.end
                );
            }
        }
    });
}

#[test]
fn prop_virtual_timeline_identical_across_runs() {
    use hetstream::util::prop::{check, Rng};
    // Two fresh contexts (2 kernel workers — the racy case the clock's
    // admission gate makes deterministic) replay one seeded program;
    // every op's (start, end) must match bit-for-bit.
    check(12, |rng: &mut Rng| {
        let streams = rng.range(1, 3);
        let prog = sched::gen_program(rng, streams);
        let timeline = |ctx: &hetstream::hstreams::Context| -> Vec<(u64, u64)> {
            sched::run_program(ctx, streams, &prog)
                .iter()
                .map(|(_, _, e)| {
                    let s = e.sample().unwrap();
                    (s.start.as_nanos(), s.end.as_nanos())
                })
                .collect()
        };
        let a = timeline(&sched::virtual_ctx(2));
        let b = timeline(&sched::virtual_ctx(2));
        assert_eq!(a, b, "virtual timeline must be reproducible");
    });
}

#[test]
fn prop_wavefront_plan_deps_are_exactly_the_grid_neighbours() {
    // The NW lowering must emit, for every tile kernel, explicit RAW
    // deps on precisely its north / west / northwest neighbour kernels
    // — and list ops in a topological order (deps point backwards).
    use hetstream::plan::{PlanOpKind, Slot};
    check(10, |rng: &mut Rng| {
        let g = rng.range(1, 5);
        let plan = hetstream::workloads::NeedlemanWunsch::with_grid(g).lower();
        plan.validate().expect("lowered plan is well-formed");

        // Kex ops appear in wavefront order: zip them with tile coords.
        let kex_ids: Vec<usize> = plan
            .ops
            .iter()
            .enumerate()
            .filter(|(_, op)| matches!(op.kind, PlanOpKind::Kex { .. }))
            .map(|(i, _)| i)
            .collect();
        let coords = tile_coords(g, g);
        assert_eq!(kex_ids.len(), coords.len(), "one kernel per tile");

        let mut kex_of = vec![vec![usize::MAX; g]; g];
        for (id, c) in kex_ids.iter().zip(&coords) {
            kex_of[c.bi][c.bj] = *id;
        }
        for (id, c) in kex_ids.iter().zip(&coords) {
            let mut want = Vec::new();
            if c.bi > 0 {
                want.push(kex_of[c.bi - 1][c.bj]);
            }
            if c.bj > 0 {
                want.push(kex_of[c.bi][c.bj - 1]);
            }
            if c.bi > 0 && c.bj > 0 {
                want.push(kex_of[c.bi - 1][c.bj - 1]);
            }
            let mut got = plan.ops[*id].deps.clone();
            got.sort_unstable();
            want.sort_unstable();
            assert_eq!(got, want, "tile ({}, {}) deps", c.bi, c.bj);
            assert!(got.iter().all(|&d| d < *id), "deps must point backwards");
            // Diagonal-aware placement: the tile's lane is its slot
            // within the anti-diagonal.
            match plan.ops[*id].slot {
                Slot::Task(lane) => {
                    let d = c.bi + c.bj;
                    let slot_in_diag = c.bi - d.saturating_sub(g - 1);
                    assert_eq!(lane, slot_in_diag, "tile ({}, {}) lane", c.bi, c.bj);
                }
                Slot::Broadcast => panic!("tile kernels must not be broadcast"),
            }
        }
    });
}

#[test]
fn prop_halo_overhead_ratio_predicts_cases() {
    use hetstream::partition::halo_overhead_ratio;
    check(100, |rng: &mut Rng| {
        let chunk = rng.range(1, 1 << 20);
        let halo = rng.range(0, 1 << 12);
        let r = halo_overhead_ratio(chunk, halo);
        assert!(r >= 0.0);
        assert!((r - 2.0 * halo as f64 / chunk as f64).abs() < 1e-12);
    });
}
