//! Load-harness integration tests (ISSUE acceptance): under an
//! open-loop flood with cost-based admission on, the flooding tenant
//! is shed (nonzero shed count) while a well-behaved tenant keeps
//! completing with a bounded latency tail; the emitted bench JSON
//! parses and carries the versioned schema; and a client that panics
//! after submitting cannot take the service down for anyone else.

use std::sync::Arc;

use hetstream::device::{DeviceProfile, TimeMode};
use hetstream::experiments::{demo_roster, run_bench, BenchOpts};
use hetstream::metrics::{bench_json, BENCH_SCHEMA};
use hetstream::service::{
    AdmissionConfig, AnalyticPolicy, ExecBackend, Request, ServiceConfig, StreamService,
    TunePolicy,
};
use hetstream::util::json::Json;

fn base_opts() -> BenchOpts {
    BenchOpts {
        tenants: 2,
        rate: 5.0,
        secs: 2.0,
        open_loop: false,
        lanes: 4,
        flood: None,
        admission: None,
        profile: DeviceProfile::mic31sp(),
        time_mode: TimeMode::Virtual,
        backend: ExecBackend::Sim,
    }
}

/// The summed modeled cost of one full roster cycle, under the same
/// dilated profile the service lanes model — the unit the admission
/// bucket is sized in, so the tests hold for any profile or per-app
/// cost spread (workers cycle the roster, so k requests cost at most
/// ceil(k / 8) cycles).
fn roster_cycle_est_ms() -> f64 {
    let profile = DeviceProfile::mic31sp().simulation();
    let est: f64 =
        demo_roster(8).iter().map(|c| AnalyticPolicy.choose(c, &profile).est_ms).sum();
    assert!(est.is_finite() && est > 0.0, "roster costs must be modeled: {est}");
    est
}

#[test]
fn open_loop_flood_is_shed_while_well_behaved_tenant_stays_bounded() {
    // Budget in units of the roster's own modeled cost: the
    // well-behaved tenant's ~10 requests span at most two roster
    // cycles, so a 2.5-cycle burst admits all of them; the flooder's
    // ~200 requests (20x rate) demand ~25 cycles and must overrun.
    // Refill is negligible so the bound is the burst, deterministically.
    let cycle = roster_cycle_est_ms();
    let opts = BenchOpts {
        open_loop: true,
        flood: Some((0, 20.0)),
        admission: Some(AdmissionConfig {
            refill_ms_per_sec: cycle * 1e-3,
            burst_ms: cycle * 2.5,
        }),
        ..base_opts()
    };
    let report = run_bench(&opts, Arc::new(AnalyticPolicy)).expect("bench runs");

    assert!(report.completed > 0, "the bench must complete work");
    let flooder = &report.per_tenant[0];
    let good = &report.per_tenant[1];
    assert_eq!(flooder.tenant, "tenant-0");
    assert!(
        flooder.shed > 0,
        "a 20x open-loop flood must overrun its token bucket (shed = {})",
        flooder.shed
    );
    assert_eq!(good.shed, 0, "the well-behaved tenant fits its budget");
    assert!(good.completed > 0, "the well-behaved tenant keeps completing under the flood");
    assert_eq!(good.errors, 0);
    // Bounded tail: sheds happen at submit (never queued), so the
    // admitted work drains across 4 lanes well inside this generous
    // wall-clock bound.
    assert!(
        good.p99_ms.is_finite() && good.p99_ms < 2_000.0,
        "well-behaved p99 must stay bounded under the flood, got {} ms",
        good.p99_ms
    );

    // The emitted artifact parses with the crate's own JSON parser and
    // carries the versioned schema + the full series.
    let doc = Json::parse(&bench_json(&report)).expect("bench JSON parses");
    assert_eq!(doc.get("schema").and_then(Json::as_str), Some(BENCH_SCHEMA));
    let ticks = doc.get("ticks").and_then(Json::as_arr).expect("ticks array");
    assert_eq!(ticks.len(), report.ticks.len());
    let tenants = doc.get("per_tenant").and_then(Json::as_arr).expect("per_tenant array");
    assert_eq!(tenants.len(), 2);
    assert_eq!(tenants[0].get("shed").and_then(Json::as_u64), Some(flooder.shed));
    let totals = doc.get("totals").expect("totals");
    assert_eq!(totals.get("completed").and_then(Json::as_u64), Some(report.completed));
    assert_eq!(totals.get("rejected").and_then(Json::as_u64), Some(report.rejected));
}

#[test]
fn closed_loop_bench_without_admission_completes_everything() {
    let opts = BenchOpts { rate: 3.0, secs: 1.0, ..base_opts() };
    let report = run_bench(&opts, Arc::new(AnalyticPolicy)).expect("bench runs");
    // ceil(rate * secs) submissions per tenant, nothing shed.
    let expected = (opts.rate * opts.secs).ceil() as u64 * opts.tenants as u64;
    assert_eq!(report.completed, expected, "admission off must complete every submission");
    assert_eq!(report.rejected, 0);
    assert_eq!(report.errors, 0);
    // The tick series partitions the totals.
    let tick_sum: u64 = report.ticks.iter().map(|t| t.completed).sum();
    assert_eq!(tick_sum, report.completed);
    assert!(report.lat_p99_ms.is_finite() && report.lat_p99_ms >= report.lat_p50_ms);
    assert!(report.modeled_total_ms > 0.0);
}

#[test]
fn panicking_client_does_not_wedge_the_service_for_others() {
    // A client thread that submits and then panics (dropping its
    // ticket mid-flight) must not poison anything another tenant can
    // observe: submissions after the crash still serve, and shutdown
    // still drains — the integration twin of the service's internal
    // poisoned-lock unit test.
    let c = demo_roster(1).into_iter().next().expect("roster");
    let service = StreamService::start(
        ServiceConfig {
            lanes: 2,
            runs: 1,
            profile: DeviceProfile::mic31sp(),
            time_mode: TimeMode::Virtual,
            backend: ExecBackend::Sim,
            artifacts: Some(vec![hetstream::plan::CORPUS_BURNER.into()]),
            admission: Some(AdmissionConfig::default()),
        },
        Arc::new(AnalyticPolicy),
    )
    .expect("service starts");
    std::thread::scope(|s| {
        let handle = s.spawn(|| {
            let _ticket =
                service.submit("crasher", Request::Corpus(c.clone())).expect("admitted");
            panic!("client crashes with a ticket in flight");
        });
        assert!(handle.join().is_err(), "the client must have panicked");
    });
    let report = service
        .submit("survivor", Request::Corpus(c))
        .expect("service still admits after a client crash")
        .wait()
        .expect("service still serves");
    assert!(report.ok(), "{:?}", report.error);
    let stats = service.shutdown();
    assert_eq!(stats.errors(), 0);
    assert!(stats.jobs() >= 1, "the survivor's job must have run");
}
