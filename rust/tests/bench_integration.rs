//! Load-harness integration tests (ISSUE acceptance): under an
//! open-loop flood with cost-based admission on, the flooding tenant
//! is shed (nonzero shed count) while a well-behaved tenant keeps
//! completing with a bounded latency tail; the emitted bench JSON
//! parses and carries the versioned schema; and a client that panics
//! after submitting cannot take the service down for anyone else.

use std::sync::Arc;

use hetstream::corpus::BenchConfig;
use hetstream::device::{DeviceProfile, TimeMode};
use hetstream::experiments::{demo_roster, run_bench, BenchOpts};
use hetstream::metrics::{bench_json, BENCH_SCHEMA};
use hetstream::service::{
    AdaptiveConfig, AdmissionConfig, AnalyticPolicy, ExecBackend, Request, ServiceConfig,
    StreamService, TunePolicy,
};
use hetstream::util::json::Json;

fn base_opts() -> BenchOpts {
    BenchOpts {
        tenants: 2,
        rate: 5.0,
        secs: 2.0,
        open_loop: false,
        lanes: 4,
        flood: None,
        admission: None,
        profile: DeviceProfile::mic31sp(),
        time_mode: TimeMode::Virtual,
        backend: ExecBackend::Sim,
        adaptive: None,
    }
}

/// The summed modeled cost of one full roster cycle, under the same
/// dilated profile the service lanes model — the unit the admission
/// bucket is sized in, so the tests hold for any profile or per-app
/// cost spread (workers cycle the roster, so k requests cost at most
/// ceil(k / 8) cycles).
fn roster_cycle_est_ms() -> f64 {
    let profile = DeviceProfile::mic31sp().simulation();
    let est: f64 =
        demo_roster(8).iter().map(|c| AnalyticPolicy.choose(c, &profile).est_ms).sum();
    assert!(est.is_finite() && est > 0.0, "roster costs must be modeled: {est}");
    est
}

#[test]
fn open_loop_flood_is_shed_while_well_behaved_tenant_stays_bounded() {
    // Budget in units of the roster's own modeled cost: the
    // well-behaved tenant's ~10 requests span at most two roster
    // cycles, so a 2.5-cycle burst admits all of them; the flooder's
    // ~200 requests (20x rate) demand ~25 cycles and must overrun.
    // Refill is negligible so the bound is the burst, deterministically.
    let cycle = roster_cycle_est_ms();
    let opts = BenchOpts {
        open_loop: true,
        flood: Some((0, 20.0)),
        admission: Some(AdmissionConfig {
            refill_ms_per_sec: cycle * 1e-3,
            burst_ms: cycle * 2.5,
        }),
        ..base_opts()
    };
    let report = run_bench(&opts, Arc::new(AnalyticPolicy)).expect("bench runs");

    assert!(report.completed > 0, "the bench must complete work");
    let flooder = &report.per_tenant[0];
    let good = &report.per_tenant[1];
    assert_eq!(flooder.tenant, "tenant-0");
    assert!(
        flooder.shed > 0,
        "a 20x open-loop flood must overrun its token bucket (shed = {})",
        flooder.shed
    );
    assert_eq!(good.shed, 0, "the well-behaved tenant fits its budget");
    assert!(good.completed > 0, "the well-behaved tenant keeps completing under the flood");
    assert_eq!(good.errors, 0);
    // Bounded tail: sheds happen at submit (never queued), so the
    // admitted work drains across 4 lanes well inside this generous
    // wall-clock bound.
    assert!(
        good.p99_ms.is_finite() && good.p99_ms < 2_000.0,
        "well-behaved p99 must stay bounded under the flood, got {} ms",
        good.p99_ms
    );

    // The emitted artifact parses with the crate's own JSON parser and
    // carries the versioned schema + the full series.
    let doc = Json::parse(&bench_json(&report)).expect("bench JSON parses");
    assert_eq!(doc.get("schema").and_then(Json::as_str), Some(BENCH_SCHEMA));
    let ticks = doc.get("ticks").and_then(Json::as_arr).expect("ticks array");
    assert_eq!(ticks.len(), report.ticks.len());
    let tenants = doc.get("per_tenant").and_then(Json::as_arr).expect("per_tenant array");
    assert_eq!(tenants.len(), 2);
    assert_eq!(tenants[0].get("shed").and_then(Json::as_u64), Some(flooder.shed));
    let totals = doc.get("totals").expect("totals");
    assert_eq!(totals.get("completed").and_then(Json::as_u64), Some(report.completed));
    assert_eq!(totals.get("rejected").and_then(Json::as_u64), Some(report.rejected));
}

#[test]
fn closed_loop_bench_without_admission_completes_everything() {
    let opts = BenchOpts { rate: 3.0, secs: 1.0, ..base_opts() };
    let report = run_bench(&opts, Arc::new(AnalyticPolicy)).expect("bench runs");
    // ceil(rate * secs) submissions per tenant, nothing shed.
    let expected = (opts.rate * opts.secs).ceil() as u64 * opts.tenants as u64;
    assert_eq!(report.completed, expected, "admission off must complete every submission");
    assert_eq!(report.rejected, 0);
    assert_eq!(report.errors, 0);
    // The tick series partitions the totals.
    let tick_sum: u64 = report.ticks.iter().map(|t| t.completed).sum();
    assert_eq!(tick_sum, report.completed);
    assert!(report.lat_p99_ms.is_finite() && report.lat_p99_ms >= report.lat_p50_ms);
    assert!(report.modeled_total_ms > 0.0);
}

/// An adaptive config aggressive enough to exercise every actuator in
/// a short test: batching always on, a single starting lane so the
/// queue backs up, elasticity tripping on any backlog.
fn aggressive_adaptive(max_lanes: usize) -> AdaptiveConfig {
    AdaptiveConfig {
        dwell_ms: 0,
        batch_on_rps: 0.0,
        batch_off_rps: 0.0,
        max_batch: 8,
        min_lanes: 1,
        max_lanes,
        grow_depth: 1,
        ..Default::default()
    }
}

/// ISSUE acceptance: the adaptive runtime must change *when* work runs
/// (coalesced batches, elastic lanes) but never *what* it computes —
/// every ticket's bytes must be identical to a non-adaptive fixed-lane
/// run of the same submissions, on both backends.
fn adaptive_run_is_bitwise_exact_on(backend: ExecBackend) {
    let roster = demo_roster(4);
    let submissions: Vec<BenchConfig> =
        (0..48).map(|i| roster[i % roster.len()].clone()).collect();
    let run = |lanes: usize, adaptive: Option<AdaptiveConfig>| {
        let service = StreamService::start(
            ServiceConfig {
                lanes,
                runs: 1,
                profile: DeviceProfile::mic31sp(),
                time_mode: TimeMode::Virtual,
                backend,
                artifacts: Some(vec![hetstream::plan::CORPUS_BURNER.into()]),
                admission: None,
                adaptive,
            },
            Arc::new(AnalyticPolicy),
        )
        .expect("service starts");
        let tickets: Vec<_> = submissions
            .iter()
            .enumerate()
            .map(|(i, c)| {
                service
                    .submit(&format!("tenant-{}", i % 3), Request::Corpus(c.clone()))
                    .expect("admitted")
            })
            .collect();
        let reports: Vec<_> =
            tickets.into_iter().map(|t| t.wait().expect("report")).collect();
        (reports, service.shutdown())
    };

    let (want, _) = run(2, None);
    let (got, stats) = run(1, Some(aggressive_adaptive(3)));

    assert_eq!(got.len(), want.len());
    for (i, (g, w)) in got.iter().zip(&want).enumerate() {
        assert!(g.ok(), "submission {i}: {:?}", g.error);
        assert_eq!(
            g.outputs, w.outputs,
            "submission {i} ({}): adaptive outputs must equal the fixed-lane run bitwise",
            g.name
        );
        if backend == ExecBackend::Sim {
            // Virtual-clock physics are batching-invariant: each ticket
            // keeps the modeled makespan of its own unbatched run.
            assert_eq!(g.modeled_ms, w.modeled_ms, "submission {i} ({})", g.name);
        }
    }
    let a = stats.adaptive.expect("adaptive stats present");
    assert!(a.batches > 0, "a 48-deep same-key backlog must coalesce (batches = 0)");
    assert!(a.lane_grows >= 1, "sustained backlog must grow the fleet at least once");
    assert!(a.peak_lanes >= 2 && a.peak_lanes <= 3, "peak {} within 1..=3", a.peak_lanes);
    assert_eq!(stats.jobs(), submissions.len(), "every ticket accounted");
}

#[test]
fn adaptive_run_is_bitwise_exact_on_sim() {
    adaptive_run_is_bitwise_exact_on(ExecBackend::Sim);
}

#[test]
fn adaptive_run_is_bitwise_exact_on_native() {
    adaptive_run_is_bitwise_exact_on(ExecBackend::Native);
}

#[test]
fn adaptive_flood_batches_and_keeps_the_good_tenant_bounded() {
    // The flood acceptance run with the controller on: same budget
    // shape as the non-adaptive flood test, but one starting lane and
    // batching forced on, so the flooder's admitted burst backs up and
    // coalesces.  The well-behaved tenant must still be shed-free with
    // a bounded tail, and the v3 artifact must carry the adaptive
    // series.
    let cycle = roster_cycle_est_ms();
    let opts = BenchOpts {
        open_loop: true,
        lanes: 1,
        flood: Some((0, 20.0)),
        admission: Some(AdmissionConfig {
            refill_ms_per_sec: cycle * 1e-3,
            burst_ms: cycle * 2.5,
        }),
        adaptive: Some(aggressive_adaptive(4)),
        ..base_opts()
    };
    let report = run_bench(&opts, Arc::new(AnalyticPolicy)).expect("bench runs");

    assert!(report.completed > 0);
    assert!(report.adaptive);
    assert_eq!(report.max_lanes, 4);
    assert!(
        report.batches > 0,
        "the flooder's admitted burst must coalesce through one starting lane"
    );
    assert!(report.batched_jobs >= 2 * report.batches, "a batch covers at least two jobs");
    let flooder = &report.per_tenant[0];
    let good = &report.per_tenant[1];
    assert!(flooder.shed > 0, "the 20x flood still overruns its bucket");
    assert_eq!(good.shed, 0, "the well-behaved tenant fits its budget");
    assert!(good.completed > 0);
    assert!(
        good.p99_ms.is_finite() && good.p99_ms < 2_000.0,
        "well-behaved p99 must stay bounded under the adaptive flood, got {} ms",
        good.p99_ms
    );

    // v3 artifact: config + totals carry the adaptive block, every
    // tick carries mode/lanes/batches.
    let doc = Json::parse(&bench_json(&report)).expect("bench JSON parses");
    assert_eq!(doc.get("schema").and_then(Json::as_str), Some(BENCH_SCHEMA));
    let cfg = doc.get("config").expect("config");
    assert_eq!(cfg.get("adaptive").and_then(Json::as_bool), Some(true));
    assert_eq!(cfg.get("max_lanes").and_then(Json::as_u64), Some(4));
    let adaptive = doc.get("totals").and_then(|t| t.get("adaptive")).expect("totals.adaptive");
    assert_eq!(adaptive.get("batches").and_then(Json::as_u64), Some(report.batches));
    assert_eq!(adaptive.get("peak_lanes").and_then(Json::as_u64), Some(report.peak_lanes));
    for tick in doc.get("ticks").and_then(Json::as_arr).expect("ticks") {
        let mode = tick.get("mode").and_then(Json::as_str).expect("tick mode");
        assert!(mode == "park" || mode == "spin", "unknown mode `{mode}`");
        let lanes = tick.get("lanes").and_then(Json::as_u64).expect("tick lanes");
        assert!((1..=4).contains(&lanes), "tick lanes {lanes} outside 1..=4");
        assert!(tick.get("batches").and_then(Json::as_u64).is_some());
    }
}

#[test]
fn panicking_client_does_not_wedge_the_service_for_others() {
    // A client thread that submits and then panics (dropping its
    // ticket mid-flight) must not poison anything another tenant can
    // observe: submissions after the crash still serve, and shutdown
    // still drains — the integration twin of the service's internal
    // poisoned-lock unit test.
    let c = demo_roster(1).into_iter().next().expect("roster");
    let service = StreamService::start(
        ServiceConfig {
            lanes: 2,
            runs: 1,
            profile: DeviceProfile::mic31sp(),
            time_mode: TimeMode::Virtual,
            backend: ExecBackend::Sim,
            artifacts: Some(vec![hetstream::plan::CORPUS_BURNER.into()]),
            admission: Some(AdmissionConfig::default()),
            adaptive: None,
        },
        Arc::new(AnalyticPolicy),
    )
    .expect("service starts");
    std::thread::scope(|s| {
        let handle = s.spawn(|| {
            let _ticket =
                service.submit("crasher", Request::Corpus(c.clone())).expect("admitted");
            panic!("client crashes with a ticket in flight");
        });
        assert!(handle.join().is_err(), "the client must have panicked");
    });
    let report = service
        .submit("survivor", Request::Corpus(c))
        .expect("service still admits after a client crash")
        .wait()
        .expect("service still serves");
    assert!(report.ok(), "{:?}", report.error);
    let stats = service.shutdown();
    assert_eq!(stats.errors(), 0);
    assert!(stats.jobs() >= 1, "the survivor's job must have run");
}
