//! Service-layer and backend-equivalence integration tests.
//!
//! The two acceptance properties of the backend-agnostic execution
//! API:
//!
//! 1. **Concurrency changes nothing observable** — N submissions
//!    racing through the multi-tenant `StreamService` produce, per
//!    submission, bitwise-identical outputs *and* identical modeled
//!    makespans to the same plans run serially on a private engine
//!    (quiesced lanes make the simulated physics order-independent).
//! 2. **Backends agree bitwise** — the engine-backed `SimBackend` and
//!    the host thread-pool `NativeBackend` assemble byte-identical
//!    outputs for every corpus plan shape (independent fan-out, halo,
//!    wavefront, iterative chain, sync), at any pool width.

use std::sync::Arc;

use hetstream::corpus::{all_configs, BenchConfig};
use hetstream::device::DeviceProfile;
use hetstream::hstreams::{Context, ContextBuilder};
use hetstream::plan::{
    lower_corpus_bulk, lower_corpus_streamed, lower_corpus_streamed_at, outputs_match, Backend,
    Granularity, NativeBackend, RunConfig, SimBackend, CORPUS_BURNER,
};
use hetstream::service::{
    AnalyticPolicy, ExecBackend, Request, ServiceConfig, StreamService, TunePolicy,
};

fn instant_ctx() -> Context {
    ContextBuilder::new()
        .profile(DeviceProfile::instant())
        .only_artifacts(vec![CORPUS_BURNER])
        .build()
        .expect("context")
}

/// Service over the paper's MIC profile: the virtual clock never
/// sleeps, so a real profile costs nothing and makes the modeled-time
/// equality assertions non-trivial (instant would compare zeros).
fn service_config(lanes: usize) -> ServiceConfig {
    ServiceConfig {
        lanes,
        runs: 1,
        profile: DeviceProfile::mic31sp(),
        time_mode: hetstream::device::TimeMode::Virtual,
        backend: ExecBackend::Sim,
        artifacts: Some(vec![CORPUS_BURNER.into()]),
        // These tests exercise execution equivalence, not load
        // shedding — admit everything.
        admission: None,
        adaptive: None,
    }
}

/// Host cores, the widest pool the equivalence sweeps exercise.
fn ncores() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4)
}

/// The serial twin of [`service_config`]'s lanes: same profile
/// (builder-dilated the same way), same artifact subset.
fn mic_ctx() -> Context {
    ContextBuilder::new().only_artifacts(vec![CORPUS_BURNER]).build().expect("context")
}

/// A corpus sample guaranteed to span every Table-2 category, plus a
/// stratified slice for breadth.
fn category_spanning_sample() -> Vec<BenchConfig> {
    use hetstream::analysis::Category;
    let configs = all_configs();
    let mut sample: Vec<BenchConfig> = Vec::new();
    for cat in [
        Category::Sync,
        Category::Iterative,
        Category::Independent,
        Category::FalseDependent,
        Category::TrueDependent,
    ] {
        let c = configs.iter().find(|c| c.category() == cat).expect("category in corpus");
        sample.push(c.clone());
    }
    sample.extend(configs.iter().step_by(41).cloned());
    sample
}

#[test]
fn concurrent_service_submissions_match_serial_bitwise() {
    let sample: Vec<BenchConfig> = all_configs().into_iter().step_by(29).collect();
    assert!(sample.len() >= 6);

    // Serial twin: a private engine, one submission at a time, the
    // same analytic policy the service will consult.
    let ctx = mic_ctx();
    let backend = SimBackend::new(&ctx);
    let serial: Vec<(f64, Vec<Vec<u8>>)> = sample
        .iter()
        .map(|c| {
            let choice = AnalyticPolicy.choose(c, ctx.profile());
            let plan = lower_corpus_streamed_at(c, CORPUS_BURNER, Granularity::new(choice.gran));
            let run = backend.run(&plan, RunConfig::streams(choice.streams)).expect("serial run");
            (run.wall.as_secs_f64() * 1e3, run.outputs)
        })
        .collect();

    // Concurrent: three client threads race their slices into a
    // 3-lane service.
    let service = StreamService::start(service_config(3), Arc::new(AnalyticPolicy))
        .expect("service starts");
    let reports: Vec<(usize, hetstream::service::SubmissionReport)> = std::thread::scope(|s| {
        let service = &service;
        let sample = &sample;
        let handles: Vec<_> = (0..3)
            .map(|client| {
                s.spawn(move || {
                    let mut got = Vec::new();
                    for (i, c) in sample.iter().enumerate().skip(client).step_by(3) {
                        let ticket = service
                            .submit(&format!("client-{client}"), Request::Corpus(c.clone()))
                            .expect("admitted");
                        got.push((i, ticket.wait().expect("report")));
                    }
                    got
                })
            })
            .collect();
        handles.into_iter().flat_map(|h| h.join().expect("client thread")).collect()
    });
    let stats = service.shutdown();

    assert_eq!(reports.len(), sample.len());
    assert_eq!(stats.jobs(), sample.len());
    assert_eq!(stats.errors(), 0);
    for (i, r) in &reports {
        assert!(r.ok(), "{}: {:?}", r.name, r.error);
        let (serial_ms, serial_outputs) = &serial[*i];
        assert_eq!(
            &r.outputs, serial_outputs,
            "{}: concurrent outputs must equal the serial twin bitwise",
            r.name
        );
        assert_eq!(
            r.modeled_ms, *serial_ms,
            "{}: quiesced lanes must reproduce the serial modeled makespan",
            r.name
        );
    }
}

#[test]
fn adaptive_run_accounts_for_every_submission() {
    // The ServiceStats drift oracle across a mixed adaptive run: with
    // batching forced on, lanes growing and retiring, and a tight
    // token bucket shedding part of the offered load, every submission
    // attempt must land in exactly one bucket — completed or shed —
    // and none may error.  A fan-out bug (a coalesced ticket counted
    // twice or dropped) or a retirement bug (a lane exiting with a
    // claimed job) shows up here as drift.
    let mut cfg = service_config(1);
    cfg.admission = Some(hetstream::service::AdmissionConfig {
        refill_ms_per_sec: 40.0,
        burst_ms: 80.0,
    });
    cfg.adaptive = Some(hetstream::service::AdaptiveConfig {
        dwell_ms: 0,
        batch_on_rps: 0.0,
        batch_off_rps: 0.0,
        max_batch: 8,
        min_lanes: 1,
        max_lanes: 3,
        grow_depth: 1,
        ..Default::default()
    });
    let service = StreamService::start(cfg, Arc::new(AnalyticPolicy)).expect("service");

    let sample: Vec<BenchConfig> = all_configs().into_iter().step_by(47).take(4).collect();
    let attempts = 36u64;
    let mut tickets = Vec::new();
    let mut shed = 0u64;
    for i in 0..attempts {
        let tenant = format!("tenant-{}", i % 3);
        match service.submit(&tenant, Request::Corpus(sample[i as usize % sample.len()].clone()))
        {
            Ok(t) => tickets.push(t),
            Err(hetstream::Error::Admission { .. }) => shed += 1,
            Err(e) => panic!("unexpected submit error: {e}"),
        }
    }
    let reports: Vec<_> = tickets.into_iter().map(|t| t.wait().expect("report")).collect();
    let stats = service.shutdown();

    assert!(shed > 0, "a 40 ms/s budget must shed part of a 36-deep burst");
    assert_eq!(stats.errors(), 0);
    assert_eq!(reports.iter().filter(|r| r.ok()).count(), reports.len());
    assert_eq!(
        stats.jobs() as u64 + stats.shed_total(),
        attempts,
        "completed ({}) + shed ({}) must equal submissions ({attempts}) — no drift",
        stats.jobs(),
        stats.shed_total(),
    );
    assert_eq!(stats.shed_total(), shed, "service-side shed count matches the client's");
    let a = stats.adaptive.expect("adaptive stats present when the controller is on");
    assert!(a.peak_lanes >= 1 && a.peak_lanes <= 3, "peak {} within cap", a.peak_lanes);
    // Lane lifecycle books balance: whatever grew beyond the initial
    // single lane either retired during the run or was still live at
    // shutdown — never negative, never past the cap.
    let live_at_end = 1 + a.lane_grows as i64 - a.lane_retires as i64;
    assert!(
        (1..=3).contains(&live_at_end),
        "grows {} / retires {} leave {live_at_end} live lanes",
        a.lane_grows,
        a.lane_retires,
    );
}

#[test]
fn service_plan_cache_hits_on_repeat_submissions() {
    let c = all_configs().into_iter().next().expect("corpus");
    let service =
        StreamService::start(service_config(2), Arc::new(AnalyticPolicy)).expect("service");
    let tickets: Vec<_> = (0..3)
        .map(|_| service.submit("tenant", Request::Corpus(c.clone())).expect("admitted"))
        .collect();
    let reports: Vec<_> = tickets.into_iter().map(|t| t.wait().expect("report")).collect();
    let stats = service.shutdown();

    assert_eq!(stats.cache_misses, 1, "one lowering for three identical submissions");
    assert_eq!(stats.cache_hits, 2);
    assert_eq!(reports.iter().filter(|r| r.cache_hit).count(), 2);
    for r in &reports[1..] {
        assert_eq!(r.outputs, reports[0].outputs, "cached plan reproduces the same bytes");
        assert_eq!(r.modeled_ms, reports[0].modeled_ms);
    }
}

#[test]
fn pre_lowered_plan_submissions_bypass_policy_and_cache() {
    let c = all_configs().into_iter().next().expect("corpus");
    let plan = Arc::new(lower_corpus_streamed(&c, CORPUS_BURNER));
    let ctx = instant_ctx();
    let want = SimBackend::new(&ctx).run(&plan, RunConfig::streams(2)).expect("reference");

    let service =
        StreamService::start(service_config(1), Arc::new(AnalyticPolicy)).expect("service");
    let report = service
        .submit("tenant", Request::Plan { plan: plan.clone(), streams: 2 })
        .expect("admitted")
        .wait()
        .expect("report");
    let stats = service.shutdown();
    assert!(report.ok());
    assert!(!report.cache_hit && report.gran.is_none());
    assert_eq!(report.outputs, want.outputs);
    assert_eq!(stats.cache_hits + stats.cache_misses, 0, "plan submissions skip the cache");
}

#[test]
fn service_refuses_plans_outside_its_artifact_subset() {
    // A plan launching an artifact the lanes never compiled must come
    // back as a clean error report: the engine's kex worker would
    // panic on it and never complete its event, hanging the lane, the
    // ticket, and shutdown.
    let mut p = hetstream::plan::StreamPlan::new("foreign-artifact");
    let n = 65536 * 4;
    let b = p.buf(n);
    let r = hetstream::plan::PlanRegion::whole(b, n);
    p.kex(hetstream::plan::Slot::Task(0), "vector_add", vec![r, r], vec![r], Some(1), 1, vec![]);

    let service =
        StreamService::start(service_config(1), Arc::new(AnalyticPolicy)).expect("service");
    let report = service
        .submit("tenant", Request::Plan { plan: Arc::new(p), streams: 2 })
        .expect("admitted")
        .wait()
        .expect("report, not a hang");
    let stats = service.shutdown();
    let err = report.error.expect("foreign artifact must be refused");
    assert!(err.contains("vector_add"), "{err}");
    assert_eq!(stats.errors(), 1);
}

#[test]
fn dropped_service_releases_its_lanes() {
    // Dropping without shutdown() must still stop the lane threads —
    // the Drop impl closes the queue and wakes them.  If it didn't,
    // this test would leak parked threads (and under a test harness
    // that joins on exit, hang).
    let service =
        StreamService::start(service_config(2), Arc::new(AnalyticPolicy)).expect("service");
    let c = all_configs().into_iter().next().expect("corpus");
    let ticket = service.submit("tenant", Request::Corpus(c)).expect("admitted");
    drop(service);
    // The in-flight job still completes (lanes drain the queue before
    // exiting), so the ticket resolves rather than erroring.
    let report = ticket.wait().expect("queued job drains on drop");
    assert!(report.ok(), "{:?}", report.error);
}

#[test]
fn sim_and_native_backends_assemble_identical_bytes() {
    // The tentpole oracle over a category-spanning corpus sample: both
    // Backend implementations must produce bitwise-identical outputs
    // (and agree with the bulk reference) for every plan shape.
    let ctx = instant_ctx();
    let sim = SimBackend::new(&ctx);
    let native = NativeBackend::new();
    for c in category_spanning_sample() {
        let bulk = lower_corpus_bulk(&c, CORPUS_BURNER);
        let reference = sim.run(&bulk, RunConfig::streams(1)).expect("bulk reference");
        let plan = lower_corpus_streamed(&c, CORPUS_BURNER);
        let sim_run = sim.run(&plan, RunConfig::streams(4)).expect("sim run");
        assert!(
            outputs_match(&reference, &sim_run),
            "{}/{}: sim diverges from bulk",
            c.app,
            c.config
        );
        // Widths 1 (serial), 4 (the demo default), and every host
        // core (the widest the ready-queue scheduler will see).
        for pool in [1usize, 4, ncores()] {
            let native_run = native.run(&plan, RunConfig::streams(pool)).expect("native run");
            assert!(
                outputs_match(&sim_run, &native_run),
                "{}/{}: native diverges from sim at pool width {pool}",
                c.app,
                c.config
            );
            assert_eq!(native_run.h2d_bytes, sim_run.h2d_bytes, "{}", c.app);
            assert_eq!(native_run.d2h_bytes, sim_run.d2h_bytes, "{}", c.app);
            assert_eq!(native_run.tasks, sim_run.tasks, "{}", c.app);
        }
    }
}

#[test]
fn arena_reuse_across_corpus_apps_matches_fresh_backends_bitwise() {
    // The arena-reuse regression oracle: run two different corpus apps
    // back-to-back (then the first again) on ONE NativeBackend — the
    // later runs check out the earlier runs' pooled, dirty storage —
    // and demand bitwise equality with fresh-backend runs of the same
    // plans.  A must-zero span the layout analysis missed would
    // surface here as stale bytes in a zero-source buffer.
    let sample = category_spanning_sample();
    let (a, b) = (&sample[0], &sample[2]);
    let plan_a = lower_corpus_streamed(a, CORPUS_BURNER);
    let plan_b = lower_corpus_streamed(b, CORPUS_BURNER);
    for pool in [1usize, 4, ncores()] {
        let fresh_a =
            NativeBackend::new().run(&plan_a, RunConfig::streams(pool)).expect("fresh a");
        let fresh_b =
            NativeBackend::new().run(&plan_b, RunConfig::streams(pool)).expect("fresh b");
        let shared = NativeBackend::new();
        let runs = [
            ("first", &plan_a, &fresh_a),
            ("reused across apps", &plan_b, &fresh_b),
            ("reused again", &plan_a, &fresh_a),
        ];
        for (label, plan, want) in runs {
            let got = shared.run(plan, RunConfig::streams(pool)).expect(label);
            assert!(
                outputs_match(want, &got),
                "{} vs {}: {label} run diverges from a fresh backend at pool width {pool}",
                a.app,
                b.app
            );
        }
    }
}

#[test]
fn native_backend_surfaces_kernel_errors_cleanly() {
    // An artifact the manifest does not know passes structural
    // validation (no signature to check against) but must fail the run
    // with a clean error — not hang the pool.
    let mut p = hetstream::plan::StreamPlan::new("unknown-artifact");
    let b = p.buf(64);
    p.kex(
        hetstream::plan::Slot::Task(0),
        "no_such_kernel",
        vec![hetstream::plan::PlanRegion::whole(b, 64)],
        vec![hetstream::plan::PlanRegion::whole(b, 64)],
        Some(1),
        1,
        vec![],
    );
    let handle = NativeBackend::new()
        .submit(&p, RunConfig::streams(2))
        .expect("structurally valid plan submits");
    let err = handle.wait().expect_err("unknown kernel must fail the run");
    assert!(err.to_string().contains("no_such_kernel"), "{err}");
}
