#![cfg(loom)]
//! Loom model check of the native backend's readiness protocol
//! (`plan/backend.rs::Scheduler`, DESIGN.md §Verification).
//!
//! The protocol under test, replicated structurally from the real
//! scheduler (same atomics, same orderings, same lock discipline):
//!
//! - retiring an op decrements each successor's indegree with
//!   `fetch_sub(1, AcqRel)`; the worker that sees the count hit zero
//!   owns the successor (chain-follow on the same lane, else one short
//!   push under the queue mutex + condvar notify),
//! - the last retirement (`remaining.fetch_sub(1, AcqRel) == 1`) flips
//!   `done` **while holding the queue mutex** before `notify_all`, so a
//!   worker between its empty-queue check and its park cannot miss the
//!   wakeup,
//! - `next()` parks on the condvar and re-checks `done` (Acquire) on
//!   every wakeup.
//!
//! Loom exhaustively interleaves 2 workers over a diamond DAG
//! (A → {B, C} → D) and fails the model if any schedule lets an op run
//! twice, lets `join` hang, or lets D read B/C's bytes without a
//! happens-before edge (the `UnsafeCell` accesses are checked
//! dynamically — exactly the release-sequence argument the real
//! scheduler's `// SAFETY:` comments make for the shared arena).
//!
//! This test only exists under `--cfg loom` (see Cargo.toml for the
//! run recipe); the container build compiles it to an empty crate.

use loom::cell::UnsafeCell;
use loom::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use loom::sync::{Arc, Condvar, Mutex};
use loom::thread;

/// Diamond: A=0 feeds B=1 and C=2; D=3 joins both.
const CHILDREN: [&[usize]; 4] = [&[1, 2], &[3], &[3], &[]];
const INDEG: [usize; 4] = [0, 1, 1, 2];
/// B shares A and D's lane (exercises chain-follow); C is off-lane
/// (exercises the spill-push + condvar path).
const LANE: [usize; 4] = [0, 0, 1, 0];

/// One op's output byte store — the model's stand-in for the shared
/// arena slices the real workers write through `SharedBytes`.
struct Slot(UnsafeCell<u64>);

// SAFETY: loom's `UnsafeCell` dynamically checks every access during
// model exploration — two unordered accesses (one a write) fail the
// model.  Declaring `Sync` hands the data-race proof obligation to the
// protocol under test, which is the point of the model.
unsafe impl Sync for Slot {}

struct Sched {
    indeg: Vec<AtomicUsize>,
    remaining: AtomicUsize,
    /// Ready op indices (the real scheduler's `BinaryHeap<Reverse<u64>>`;
    /// a scan-min Vec keeps the model small — same lock discipline).
    queue: Mutex<Vec<usize>>,
    cv: Condvar,
    done: AtomicBool,
}

impl Sched {
    fn new() -> Self {
        Self {
            indeg: INDEG.iter().map(|&d| AtomicUsize::new(d)).collect(),
            remaining: AtomicUsize::new(4),
            queue: Mutex::new(vec![0]), // A is born ready
            cv: Condvar::new(),
            done: AtomicBool::new(false),
        }
    }

    /// `Scheduler::next`: pop the best ready op, parking while empty.
    fn next(&self) -> Option<usize> {
        let mut q = self.queue.lock().unwrap();
        loop {
            if self.done.load(Ordering::Acquire) {
                return None;
            }
            if !q.is_empty() {
                let mut at = 0;
                for j in 1..q.len() {
                    if (LANE[q[j]], q[j]) < (LANE[q[at]], q[at]) {
                        at = j;
                    }
                }
                return Some(q.swap_remove(at));
            }
            q = self.cv.wait(q).unwrap();
        }
    }

    /// `Scheduler::push`: publish newly-ready off-lane ops.
    fn push(&self, ready: &[usize]) {
        if ready.is_empty() {
            return;
        }
        let mut q = self.queue.lock().unwrap();
        q.extend_from_slice(ready);
        drop(q);
        if ready.len() == 1 {
            self.cv.notify_one();
        } else {
            self.cv.notify_all();
        }
    }

    /// `Scheduler::finish`: flip `done` under the queue mutex, then
    /// wake everyone — the check-then-park race closure under test.
    fn finish(&self) {
        let _q = self.queue.lock().unwrap();
        self.done.store(true, Ordering::Release);
        self.cv.notify_all();
    }
}

/// The real worker loop: chain-follow same-lane successors, spill the
/// rest, retire through `remaining`, finish on the last op.
fn worker(s: &Arc<Sched>, data: &Arc<Vec<Slot>>, hits: &Arc<Vec<AtomicUsize>>) {
    let mut next: Option<usize> = None;
    loop {
        let i = match next.take() {
            Some(i) => i,
            None => match s.next() {
                Some(i) => i,
                None => return,
            },
        };
        // "Execute" op i: read every predecessor's slot, write ours.
        // The protocol must make the predecessor writes visible — loom
        // fails the model here if the AcqRel release sequence on the
        // indegrees (plus the queue-mutex hand-off) is not enough.
        let val: u64 = match i {
            // SAFETY (all arms): the indegree protocol orders every
            // predecessor's `with_mut` before this access, and no
            // other op touches slot `i` — loom verifies both claims
            // on every explored schedule.
            0 => 1,
            1 | 2 => data[0].0.with(|p| unsafe { *p }) + i as u64,
            3 => {
                data[1].0.with(|p| unsafe { *p }) + data[2].0.with(|p| unsafe { *p })
            }
            _ => unreachable!(),
        };
        // SAFETY: as above — op `i` is the sole writer of slot `i`,
        // and all of its readers are ordered after it by the protocol.
        data[i].0.with_mut(|p| unsafe { *p = val });
        hits[i].fetch_add(1, Ordering::Relaxed);

        // Retire: the last decrement of each successor's indegree owns
        // it (release sequence — AcqRel on both sides).
        let mut spill: Vec<usize> = Vec::new();
        for &c in CHILDREN[i] {
            if s.indeg[c].fetch_sub(1, Ordering::AcqRel) == 1 {
                if next.is_none() && LANE[c] == LANE[i] {
                    next = Some(c);
                } else {
                    spill.push(c);
                }
            }
        }
        s.push(&spill);
        if s.remaining.fetch_sub(1, Ordering::AcqRel) == 1 {
            s.finish();
            return;
        }
    }
}

#[test]
fn diamond_readiness_protocol_is_race_free() {
    loom::model(|| {
        let sched = Arc::new(Sched::new());
        let data: Arc<Vec<Slot>> = Arc::new((0..4).map(|_| Slot(UnsafeCell::new(0))).collect());
        let hits: Arc<Vec<AtomicUsize>> =
            Arc::new((0..4).map(|_| AtomicUsize::new(0)).collect());

        let workers: Vec<_> = (0..2)
            .map(|_| {
                let (s, d, h) = (sched.clone(), data.clone(), hits.clone());
                thread::spawn(move || worker(&s, &d, &h))
            })
            .collect();
        for w in workers {
            w.join().expect("worker completes — no hang, no panic");
        }

        // Every op ran exactly once on every explored schedule.
        for (i, h) in hits.iter().enumerate() {
            assert_eq!(h.load(Ordering::Relaxed), 1, "op {i} must execute exactly once");
        }
        // And the join op observed both branches' writes:
        // A=1, B=A+1=2, C=A+2=3, D=B+C=5.
        // SAFETY: both workers are joined — this is the only live
        // access.
        let d = data[3].0.with(|p| unsafe { *p });
        assert_eq!(d, 5, "D must observe B and C's writes");
    });
}
