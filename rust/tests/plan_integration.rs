//! Plan-lowering integration: the engine-backed `plan::SimBackend`
//! must reproduce the bulk-lowered outputs **bit-for-bit** at every
//! stream count, for all three partition shapes (independent, halo,
//! wavefront) — every task runs the same kernels over the same bytes,
//! so even float kernels admit exact equality.  Also: the descriptor
//! corpus executes through plans with streamed-vs-1-stream validation.

use std::sync::Arc;

use hetstream::device::DeviceProfile;
use hetstream::hstreams::{Context, ContextBuilder};
use hetstream::plan::{
    lower_corpus_bulk, lower_corpus_streamed, lower_corpus_streamed_at, outputs_match, Backend,
    Granularity, HostSlice, PlanRegion, RunConfig, SimBackend, Slot, StreamPlan, CORPUS_BURNER,
};
use hetstream::runtime::bytes;
use hetstream::util::prop::{check, Rng};
use hetstream::workloads::{
    gen_f32, gen_i32, GenericWorkload, Hotspot, Mode, NeedlemanWunsch, Windows,
};

fn instant_ctx(artifacts: &[&str]) -> Context {
    ContextBuilder::new()
        .profile(DeviceProfile::instant())
        .only_artifacts(artifacts.to_vec())
        .build()
        .expect("context")
}

/// Histogram-shaped independent workload (integer kernel).
fn independent_wl(chunks: usize, seed: u64) -> GenericWorkload {
    let x = gen_i32(chunks * 16384, 256, seed);
    GenericWorkload {
        name: "prop-histogram",
        artifact: "histogram",
        streamed_inputs: vec![Windows::disjoint(Arc::new(bytes::from_i32(&x)), chunks)],
        shared_inputs: vec![],
        output_chunk_bytes: vec![256 * 4],
        flops_per_chunk: None,
    }
}

/// Stencil-shaped halo workload (one row of halo per side).
fn halo_wl(chunks: usize, seed: u64) -> GenericWorkload {
    let (rows, cols) = (chunks * 128, 512);
    let field = gen_f32(rows * cols, seed);
    let mut padded = vec![0.0f32; (rows + 2) * cols];
    padded[cols..(rows + 1) * cols].copy_from_slice(&field);
    GenericWorkload {
        name: "prop-stencil",
        artifact: "stencil2d",
        streamed_inputs: vec![Windows::halo(Arc::new(bytes::from_f32(&padded)), chunks, cols * 4)],
        shared_inputs: vec![],
        output_chunk_bytes: vec![128 * cols * 4],
        flops_per_chunk: Some(7_100_000),
    }
}

#[test]
fn prop_independent_streamed_equals_baseline_bitwise() {
    let ctx = instant_ctx(&["histogram"]);
    check(6, |rng: &mut Rng| {
        let wl = independent_wl(rng.range(2, 5), rng.next_u64());
        let (_, base, base_bytes) = wl.execute(&ctx, Mode::Baseline).expect("baseline");
        let streams = rng.range(1, 5);
        let (_, strm, strm_bytes) = wl.execute(&ctx, Mode::Streamed(streams)).expect("streamed");
        assert_eq!(base, strm, "independent outputs must match bit-for-bit");
        assert_eq!(base_bytes, strm_bytes, "disjoint windows ship no extra bytes");
    });
}

#[test]
fn prop_halo_streamed_equals_baseline_bitwise() {
    let ctx = instant_ctx(&["stencil2d"]);
    check(5, |rng: &mut Rng| {
        let wl = halo_wl(rng.range(2, 4), rng.next_u64());
        let (_, base, base_bytes) = wl.execute(&ctx, Mode::Baseline).expect("baseline");
        let streams = rng.range(1, 5);
        let (_, strm, strm_bytes) = wl.execute(&ctx, Mode::Streamed(streams)).expect("streamed");
        assert_eq!(base, strm, "halo outputs must match bit-for-bit");
        assert!(strm_bytes > base_bytes, "halo windows must ship redundant bytes");
    });
}

#[test]
fn prop_wavefront_streamed_equals_single_stream_bitwise() {
    let ctx = instant_ctx(&["nw_tile"]);
    check(4, |rng: &mut Rng| {
        let nw = NeedlemanWunsch::with_grid(rng.range(2, 4));
        let plan = nw.lower();
        plan.validate().expect("well-formed wavefront plan");
        let exec = SimBackend::new(&ctx);
        let reference = exec.run(&plan, RunConfig::streams(1)).expect("1-stream run");
        let n = rng.range(2, 6);
        let multi = exec.run(&plan, RunConfig::streams(n)).expect("n-stream run");
        assert!(
            outputs_match(&reference, &multi),
            "wavefront outputs diverged at {n} streams"
        );
        assert_eq!(reference.h2d_bytes, multi.h2d_bytes);
    });
}

#[test]
fn broadcast_inputs_upload_once_whatever_the_stream_count() {
    // Shared (broadcast) payloads must be transferred exactly once; the
    // executor fan-out replaces per-task re-uploads.
    let ctx = instant_ctx(&["nn_dist"]);
    let records = gen_f32(4 * 16384 * 2, 0xA11CE);
    let target = [0.25f32, -0.5f32];
    let wl = GenericWorkload {
        name: "prop-nn",
        artifact: "nn_dist",
        streamed_inputs: vec![Windows::disjoint(Arc::new(bytes::from_f32(&records)), 4)],
        shared_inputs: vec![Arc::new(bytes::from_f32(&target))],
        output_chunk_bytes: vec![16384 * 4],
        flops_per_chunk: Some(650_000),
    };
    let payload_bytes = (4 * 16384 * 2 * 4) as u64;
    let shared_bytes = 8u64;
    for mode in [Mode::Baseline, Mode::Streamed(1), Mode::Streamed(4)] {
        let (_, _, h2d) = wl.execute(&ctx, mode).expect("run");
        assert_eq!(h2d, payload_bytes + shared_bytes, "{mode:?}");
    }
}

#[test]
fn corpus_descriptors_execute_through_plans_with_validation() {
    // A stratified slice of the 223 descriptors (the full-corpus sweep
    // runs in CI via `repro sweep --corpus`): lower, execute the ladder,
    // and demand bit-identical outputs vs the 1-stream reference.
    let ctx = instant_ctx(&[CORPUS_BURNER]);
    let exec = SimBackend::new(&ctx);
    let sample: Vec<_> = hetstream::corpus::all_configs().into_iter().step_by(31).collect();
    assert!(sample.len() >= 7);
    for cfg in sample {
        let plan = lower_corpus_streamed(&cfg, CORPUS_BURNER);
        plan.validate().unwrap_or_else(|e| panic!("{}/{}: {e}", cfg.app, cfg.config));
        let reference = exec.run(&plan, RunConfig::streams(1)).expect("1-stream run");
        for n in [2, 4] {
            let r = exec.run(&plan, RunConfig::streams(n)).expect("n-stream run");
            assert!(
                outputs_match(&reference, &r),
                "{}/{} diverged at {n} streams",
                cfg.app,
                cfg.config
            );
        }
    }
}

#[test]
fn prop_corpus_relowering_is_granularity_invariant() {
    // The tentpole oracle: re-lowering any descriptor at any two
    // granularities and any stream count assembles outputs bitwise
    // equal to the *bulk* lowering — the knob moves when bytes travel,
    // never what the result holds.
    let ctx = instant_ctx(&[CORPUS_BURNER]);
    let exec = SimBackend::new(&ctx);
    let cfgs = hetstream::corpus::all_configs();
    check(10, |rng: &mut Rng| {
        let cfg = &cfgs[rng.below(cfgs.len() as u64) as usize];
        let bulk = lower_corpus_bulk(cfg, CORPUS_BURNER);
        let reference = exec.run(&bulk, RunConfig::streams(1)).expect("bulk run");
        let n = rng.range(1, 8);
        for _ in 0..2 {
            let g = rng.range(1, 16);
            let plan = lower_corpus_streamed_at(cfg, CORPUS_BURNER, Granularity::new(g));
            plan.validate()
                .unwrap_or_else(|e| panic!("{}/{} gran {g}: {e}", cfg.app, cfg.config));
            let r = exec.run(&plan, RunConfig::streams(n)).expect("streamed run");
            assert!(
                outputs_match(&reference, &r),
                "{}/{} diverged from bulk at granularity {g} x {n} streams",
                cfg.app,
                cfg.config
            );
        }
    });
}

#[test]
fn generic_workload_rechunk_is_bitwise_stable() {
    // The GenericWorkload granularity knob: a per-element map kernel
    // re-chunked at any dividing task count reproduces the baseline
    // outputs bitwise at any stream count.
    let ctx = instant_ctx(&["vector_add"]);
    let chunk = 65536usize;
    let a = gen_f32(8 * chunk, 0x11);
    let b = gen_f32(8 * chunk, 0x22);
    let wl = GenericWorkload {
        name: "prop-vecadd",
        artifact: "vector_add",
        streamed_inputs: vec![
            Windows::disjoint(Arc::new(bytes::from_f32(&a)), 8),
            Windows::disjoint(Arc::new(bytes::from_f32(&b)), 8),
        ],
        shared_inputs: vec![],
        output_chunk_bytes: vec![chunk * 4],
        flops_per_chunk: None,
    };
    let (_, base, _) = wl.execute(&ctx, Mode::Baseline).expect("baseline");
    for k in [1usize, 2, 4, 16] {
        let re = wl.with_chunks(k).expect("dividing chunk count");
        assert_eq!(re.chunks(), k);
        for n in [1usize, 3] {
            let (_, got, _) = re.execute(&ctx, Mode::Streamed(n)).expect("rechunked run");
            assert_eq!(base, got, "vecadd diverged at {k} chunks x {n} streams");
        }
    }
    // Non-dividing counts refuse rather than silently skew windows.
    assert!(wl.with_chunks(7).is_none());
}

#[test]
fn hotspot_upload_granularity_is_bitwise_stable() {
    let ctx = instant_ctx(&["hotspot_step"]);
    let hs = Hotspot::new(1);
    let temp0 = gen_f32(hetstream::workloads::hotspot::N * hetstream::workloads::hotspot::N, 3);
    let power = gen_f32(hetstream::workloads::hotspot::N * hetstream::workloads::hotspot::N, 4);
    let exec = SimBackend::new(&ctx);
    let reference = exec.run(&hs.lower(&temp0, &power), RunConfig::streams(1)).expect("reference");
    for g in [2usize, 5, 16] {
        let plan = hs.lower_at(&temp0, &power, Granularity::new(g));
        plan.validate().expect("chunked-upload plan");
        for n in [1usize, 2] {
            let r = exec.run(&plan, RunConfig::streams(n)).expect("run");
            assert!(outputs_match(&reference, &r), "hotspot diverged at gran {g} x {n} streams");
        }
    }
}

#[test]
fn executor_rejects_late_broadcast() {
    // Regression for the broadcast fan-out ordering: the executor only
    // snapshots broadcast events for streams that have not started, so
    // a `Slot::Broadcast` op after any task op must be a structural
    // error, not a silently dropped RAW edge.
    let ctx = instant_ctx(&["histogram"]);
    let mut p = StreamPlan::new("late-broadcast");
    let b = p.buf(16);
    let src = Arc::new(vec![7u8; 16]);
    p.h2d(Slot::Task(0), HostSlice::whole(src.clone()), PlanRegion::whole(b, 16), vec![]);
    p.h2d(Slot::Broadcast, HostSlice::whole(src), PlanRegion::whole(b, 16), vec![]);
    let err = SimBackend::new(&ctx)
        .run(&p, RunConfig::streams(4))
        .expect_err("late broadcast must be rejected");
    assert!(err.to_string().contains("broadcast"), "unexpected error: {err}");
}

#[test]
fn executor_frees_every_device_buffer() {
    let ctx = instant_ctx(&["histogram"]);
    let wl = independent_wl(3, 7);
    let before = ctx.device_mem_used();
    wl.execute(&ctx, Mode::Streamed(3)).expect("run");
    assert_eq!(ctx.device_mem_used(), before, "plan buffers must be released");
}
