//! Learned-tuner integration: leave-one-app-out cross-validation of
//! the k-NN seed over the 56-app corpus, the seed-centered pruned
//! search against the exhaustive grid, and the granularity-aware
//! workload autotune for re-chunkable fig9 drivers.
//!
//! The quantitative bars (≥ 80% of apps within 10% of the exhaustive
//! optimum; pruned walk measuring ≤ 40% of the grid) were validated
//! offline against an exact Python mirror of the virtual-clock
//! executor (`tools/mirror/tuner_mirror.py`): the mirror reproduces
//! the checked-in golden trace timestamp-for-timestamp, and on this
//! corpus measures CV at 51/56 within 10%, pruned-vs-full argmin-time
//! equality on 55/56 apps (the miss is +0.01%), and 28–33% grid
//! coverage.

use hetstream::analysis::{
    autotune_plan, autotune_plan_pruned, autotune_workload, corpus_features, gran_ladder,
    predict_plan_point, snap_seed, Category, KnnTuner, DEFAULT_K,
};
use hetstream::corpus::{all_configs, BenchConfig};
use hetstream::experiments::{
    dataset_from_tune_rows, learn_cv, tune_corpus_with, TuneRow, TuneStrategy,
};
use hetstream::hstreams::{Context, ContextBuilder};
use hetstream::plan::{
    default_corpus_granularity, effective_corpus_granularity, lower_corpus_bulk,
    lower_corpus_streamed_at, Granularity, CORPUS_BURNER,
};
use hetstream::workloads::{Benchmark, Histogram, Nn, VectorAdd};

const STREAMS: [usize; 4] = [1, 2, 4, 8];

fn paced_ctx(artifacts: &[&str]) -> Context {
    ContextBuilder::new()
        .only_artifacts(artifacts.to_vec())
        .time_mode(hetstream::device::TimeMode::Virtual)
        .build()
        .expect("context")
}

/// The same candidate construction as `tune_one`: default `--grans`
/// ladder grown around the analytic seed plus the fixed pre-tuner
/// granularity, mapped to effective knob values, deduped.
fn candidates(ctx: &Context, c: &BenchConfig) -> (Vec<usize>, (usize, usize)) {
    let bulk = lower_corpus_bulk(c, CORPUS_BURNER);
    let (seed_streams, seed_tasks) = predict_plan_point(&bulk, ctx.profile());
    let knob = match c.category() {
        Category::TrueDependent => (seed_tasks as f64).sqrt().ceil() as usize,
        _ => seed_tasks,
    };
    let seed_gran = effective_corpus_granularity(c, Granularity::new(knob)).get();
    let fixed = effective_corpus_granularity(c, default_corpus_granularity(c.category())).get();
    let mut grans: Vec<usize> = [1usize, 2, 4, 8, 16]
        .into_iter()
        .chain(gran_ladder(seed_gran))
        .chain([fixed])
        .map(|g| effective_corpus_granularity(c, Granularity::new(g)).get())
        .collect();
    grans.sort_unstable();
    grans.dedup();
    (grans, (seed_streams, seed_gran))
}

fn rep(app: &str) -> BenchConfig {
    all_configs().into_iter().find(|c| c.app == app).expect("app in corpus")
}

#[test]
fn pruned_search_matches_full_grid_argmin_while_visiting_fewer_points() {
    // A category-spanning sample whose surfaces the mirror verified the
    // 4-neighborhood hill-climb solves exactly (mean coverage ~35%).
    let ctx = paced_ctx(&[CORPUS_BURNER]);
    let (mut visited, mut grid_total) = (0usize, 0usize);
    for app in
        ["nn", "gaussian", "lavaMD", "backprop", "Reduction", "Transpose",
         "FastWalshTransform", "nw", "hotspot"]
    {
        let cfg = rep(app);
        let bulk = lower_corpus_bulk(&cfg, CORPUS_BURNER);
        let (grans, seed) = candidates(&ctx, &cfg);
        let lower = |g| lower_corpus_streamed_at(&cfg, CORPUS_BURNER, g);

        let full = autotune_plan(&ctx, &bulk, &lower, &STREAMS, &grans, 1)
            .unwrap_or_else(|e| panic!("{app} full: {e}"));
        let pruned = autotune_plan_pruned(&ctx, &bulk, &lower, &STREAMS, &grans, seed, 1)
            .unwrap_or_else(|e| panic!("{app} pruned: {e}"));

        // Same argmin: under the deterministic virtual clock the pruned
        // walk must land on the exhaustive optimum's exact time (ties
        // between equal-time points are both argmins).
        assert_eq!(
            pruned.best_ms.to_bits(),
            full.best_ms.to_bits(),
            "{app}: pruned ({}, {}) {} ms vs full ({}, {}) {} ms",
            pruned.best_streams,
            pruned.best_gran,
            pruned.best_ms,
            full.best_streams,
            full.best_gran,
            full.best_ms
        );
        // The pruned point's time must equal the full grid's at the
        // same coordinates (the walk measures real points, not a model).
        let at = full
            .surface
            .iter()
            .find(|&&(n, g, _)| n == pruned.best_streams && g == pruned.best_gran)
            .map(|&(_, _, ms)| ms)
            .expect("pruned argmin lies on the full grid");
        assert_eq!(at.to_bits(), pruned.best_ms.to_bits(), "{app}");

        // …while visiting strictly fewer points.
        let grid = STREAMS.len() * grans.len();
        assert!(
            pruned.surface.len() < grid,
            "{app}: visited {}/{grid}",
            pruned.surface.len()
        );
        assert_eq!(full.surface.len(), grid, "{app}: exhaustive measures everything");
        visited += pruned.surface.len();
        grid_total += grid;
    }
    let frac = visited as f64 / grid_total as f64;
    assert!(frac <= 0.40, "pruned sample coverage {frac:.3} exceeds the 40% budget");
}

#[test]
fn leave_one_app_out_cv_meets_the_bar_and_pruned_learned_tuning_is_cheap() {
    // One exhaustive pass over all 56 representative apps doubles as
    // the CV ground truth and the learned tuner's training set.
    let ctx = paced_ctx(&[CORPUS_BURNER]);
    let (_, rows, failures) =
        tune_corpus_with(&ctx, &STREAMS, &[1, 2, 4, 8, 16], false, 1, TuneStrategy::Exhaustive)
            .expect("exhaustive corpus tune");
    assert_eq!(failures, 0, "every corpus app must tune cleanly");
    assert_eq!(rows.len(), 56);

    let dataset = dataset_from_tune_rows(&rows, &ctx);
    assert_eq!(dataset.rows.len(), 56);
    let model = KnnTuner::fit(dataset, DEFAULT_K);
    let configs: Vec<BenchConfig> = {
        let mut seen = std::collections::HashSet::new();
        all_configs().into_iter().filter(|c| seen.insert((c.app, c.suite))).collect()
    };

    // (a) CV of the raw learned seed: snap the held-out prediction onto
    // the app's measured grid and compare against the exhaustive
    // optimum.  Mirror: 51/56 within 10%.
    let mut within = 0usize;
    for (c, r) in configs.iter().zip(&rows) {
        let held = model.without_app(r.app);
        let (ps, pg) = held.predict(&corpus_features(c, ctx.profile())).unwrap_or(r.seed);
        let snap = snap_to_surface(r, ps, pg);
        if snap <= r.best_ms * 1.10 {
            within += 1;
        }
    }
    assert!(
        within * 10 >= rows.len() * 8,
        "learned seed within 10% on only {within}/{} apps",
        rows.len()
    );

    // (b) The acceptance criterion end-to-end: hill-climb from each
    // held-out learned seed, reach the exhaustive optimum's time within
    // 10% on ≥ 80% of apps, measuring ≤ 40% of the grid in aggregate.
    // Mirror: 56/56 within 10% at 28% coverage.
    let (mut within, mut visited, mut grid) = (0usize, 0usize, 0usize);
    for (c, full) in configs.iter().zip(&rows) {
        let held = model.without_app(full.app);
        let pruned_rows = hetstream::experiments::learn::tune_held_out(
            &ctx,
            c,
            &STREAMS,
            &[1, 2, 4, 8, 16],
            &held,
        );
        let r = &pruned_rows;
        assert!(r.validated && r.error.is_none(), "{}: {:?}", r.app, r.error);
        if r.best_ms <= full.best_ms * 1.10 {
            within += 1;
        }
        visited += r.surface.len();
        grid += r.grid;
    }
    assert!(
        within * 10 >= rows.len() * 8,
        "pruned learned tuning within 10% on only {within}/{} apps",
        rows.len()
    );
    let frac = visited as f64 / grid.max(1) as f64;
    assert!(frac <= 0.40, "learned tuning measured {frac:.3} of the grid (budget 40%)");

    // (c) The experiments::learn_cv wiring agrees on a cheap subset.
    let (_, stats) = learn_cv(&ctx, &STREAMS, &[1, 2, 4, 8, 16], 6, DEFAULT_K, None)
        .expect("subset CV");
    assert_eq!(stats.apps, 6);
    assert_eq!(stats.failures, 0);
    assert!(stats.within_10pct >= 4, "subset CV collapsed: {stats:?}");
}

fn snap_to_surface(r: &TuneRow, ps: usize, pg: usize) -> f64 {
    let mut srow: Vec<usize> = r.surface.iter().map(|&(n, _, _)| n).collect();
    srow.sort_unstable();
    srow.dedup();
    let mut grow: Vec<usize> = r.surface.iter().map(|&(_, g, _)| g).collect();
    grow.sort_unstable();
    grow.dedup();
    // The walk's own snapping rule, so the CV judges what the pruned
    // search would actually start from.
    let (sn, gn) = snap_seed(&srow, &grow, (ps, pg));
    r.surface
        .iter()
        .find(|&&(n, g, _)| n == sn && g == gn)
        .map(|&(_, _, ms)| ms)
        .expect("snapped point on the surface")
}

#[test]
fn autotune_workload_tunes_rechunkable_drivers_jointly() {
    // The elastic-signature path: VectorAdd re-chunks through
    // `with_chunks` and every grid point validates bitwise against the
    // bulk (baseline) lowering.
    let ctx = paced_ctx(&["vector_add"]);
    let wl = VectorAdd::new(1).tunable().expect("vecadd is per-element");
    let r = autotune_workload(&ctx, &wl, &[1, 2, 4], 1).expect("joint autotune");
    assert!(r.best_ms.is_finite() && r.best_ms > 0.0);
    assert!(!r.surface.is_empty());
    // The candidate set really exercises the knob (not just the
    // driver's native 8 chunks).
    let grans: std::collections::BTreeSet<usize> =
        r.surface.iter().map(|&(_, g, _)| g).collect();
    assert!(grans.len() > 1, "single-granularity grid: {grans:?}");
    assert!(grans.contains(&8), "native chunk count stays a candidate");

    // Chunk-semantic drivers opt out of the knob.
    assert!(Nn::new(1).tunable().is_some());
    assert!(Histogram::new(1).tunable().is_none());
}
