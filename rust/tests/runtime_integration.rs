//! Runtime integration: every AOT artifact loads, compiles and executes
//! through the PJRT CPU client, with numerics spot-checked against
//! in-test oracles.  Requires `make artifacts` first.

use hetstream::runtime::{bytes, ArtifactStore, Manifest};

fn store(names: &[&str]) -> ArtifactStore {
    ArtifactStore::load_subset(&hetstream::artifacts_dir(), names).expect("load artifacts")
}

#[test]
fn manifest_loads_and_covers_all_artifacts() {
    let m = Manifest::load(&hetstream::artifacts_dir()).expect("manifest");
    assert!(m.artifacts.len() >= 18, "expected the full artifact set");
    for a in &m.artifacts {
        assert!(!a.inputs.is_empty(), "{} has inputs", a.name);
        assert!(!a.outputs.is_empty(), "{} has outputs", a.name);
        assert!(a.flops_per_call > 0, "{} has a FLOP estimate", a.name);
    }
}

#[test]
fn every_artifact_executes_with_correct_output_arity() {
    let m = Manifest::load(&hetstream::artifacts_dir()).expect("manifest");
    let s = ArtifactStore::load(&hetstream::artifacts_dir()).expect("load all");
    for a in &m.artifacts {
        let inputs: Vec<Vec<u8>> = a.inputs.iter().map(|io| vec![0u8; io.bytes()]).collect();
        let refs: Vec<&[u8]> = inputs.iter().map(|v| v.as_slice()).collect();
        let outs = s.execute_bytes(&a.name, &refs).unwrap_or_else(|e| panic!("{}: {e}", a.name));
        assert_eq!(outs.len(), a.outputs.len(), "{} output arity", a.name);
        for (out, spec) in outs.iter().zip(&a.outputs) {
            assert_eq!(out.len(), spec.bytes(), "{} output size", a.name);
        }
    }
}

#[test]
fn vector_add_numerics() {
    let s = store(&["vector_add"]);
    let n = 65536;
    let a: Vec<f32> = (0..n).map(|i| i as f32 * 0.5).collect();
    let b: Vec<f32> = (0..n).map(|i| 1.0 - i as f32).collect();
    let out = s
        .execute_bytes("vector_add", &[&bytes::from_f32(&a), &bytes::from_f32(&b)])
        .expect("execute");
    let c = bytes::to_f32(&out[0]);
    for i in (0..n).step_by(1111) {
        assert_eq!(c[i], a[i] + b[i]);
    }
}

#[test]
fn matmul_identity_numerics() {
    let s = store(&["matmul"]);
    // a @ I (embedded in a 256x256 b with the top-left 256x256 identity).
    let a: Vec<f32> = (0..128 * 256).map(|i| (i % 97) as f32 * 0.25).collect();
    let mut b = vec![0.0f32; 256 * 256];
    for i in 0..256 {
        b[i * 256 + i] = 1.0;
    }
    let out = s
        .execute_bytes("matmul", &[&bytes::from_f32(&a), &bytes::from_f32(&b)])
        .expect("execute");
    let c = bytes::to_f32(&out[0]);
    assert_eq!(c.len(), 128 * 256);
    for i in (0..c.len()).step_by(997) {
        assert!((c[i] - a[i]).abs() < 1e-4, "identity matmul at {i}");
    }
}

#[test]
fn reduction_variants_agree() {
    let s = store(&["reduction_v1", "reduction_v2"]);
    let x: Vec<f32> = (0..65536).map(|i| ((i * 37) % 101) as f32 * 0.01 - 0.5).collect();
    let xb = bytes::from_f32(&x);
    let v1 = bytes::to_f32(&s.execute_bytes("reduction_v1", &[&xb]).unwrap()[0]);
    let v2 = bytes::to_f32(&s.execute_bytes("reduction_v2", &[&xb]).unwrap()[0]);
    assert_eq!(v1.len(), 1);
    assert_eq!(v2.len(), 256);
    let v2sum: f32 = v2.iter().sum();
    assert!((v1[0] - v2sum).abs() < 0.5, "v1 {} vs v2 {}", v1[0], v2sum);
}

#[test]
fn prefix_sum_total_matches_scan() {
    let s = store(&["prefix_sum"]);
    let x: Vec<f32> = (0..16384).map(|i| ((i % 13) as f32) - 6.0).collect();
    let outs = s.execute_bytes("prefix_sum", &[&bytes::from_f32(&x)]).unwrap();
    let scan = bytes::to_f32(&outs[0]);
    let tot = bytes::to_f32(&outs[1]);
    assert!((scan[16383] - tot[0]).abs() < 1e-2);
    // spot-check against a host prefix
    let want: f32 = x[..1000].iter().sum();
    assert!((scan[999] - want).abs() < 1e-2);
}

#[test]
fn histogram_counts_conserved() {
    let s = store(&["histogram"]);
    let x: Vec<i32> = (0..16384).map(|i| (i * 7 % 256) as i32).collect();
    let outs = s.execute_bytes("histogram", &[&bytes::from_i32(&x)]).unwrap();
    let h = bytes::to_i32(&outs[0]);
    assert_eq!(h.len(), 256);
    assert_eq!(h.iter().map(|&c| c as i64).sum::<i64>(), 16384);
}

#[test]
fn wrong_input_count_rejected() {
    let s = store(&["vector_add"]);
    let a = vec![0u8; 65536 * 4];
    let err = s.execute_bytes("vector_add", &[&a]).unwrap_err();
    assert!(err.to_string().contains("signature"), "{err}");
}

#[test]
fn wrong_input_size_rejected() {
    let s = store(&["vector_add"]);
    let a = vec![0u8; 16];
    let err = s.execute_bytes("vector_add", &[&a, &a]).unwrap_err();
    assert!(err.to_string().contains("signature"), "{err}");
}

#[test]
fn unknown_artifact_rejected() {
    let s = store(&["vector_add"]);
    let a = vec![0u8; 4];
    assert!(s.execute_bytes("definitely_not_a_kernel", &[&a]).is_err());
}
