//! Spec front-end integration (DESIGN.md §Spec): the committed
//! `specs/*.json` files are the acceptance fixtures for the declarative
//! lowering path.
//!
//! Three proofs:
//! 1. **Round-trip identity** — every committed file is byte-identical
//!    to its own canonical re-serialization, so the content-hash cache
//!    key is stable and the files document the one true format.
//! 2. **Clean rejection** — a table of malformed documents must each
//!    fail with `Error::Spec` (never a panic, hang, or silent default).
//! 3. **Legacy equivalence** — the vectoradd / hotspot / nw specs
//!    reproduce their hand-written drivers' output bytes exactly,
//!    across a stream ladder × granularity grid on both the Sim and
//!    Native backends; the two novel specs (3-stage mixed pipeline,
//!    asymmetric-halo stencil) verify hazard-clean and pass the
//!    streamed-vs-bulk re-chunking oracle.

use hetstream::device::DeviceProfile;
use hetstream::experiments::{run_spec, verify_spec, RunSpecOpts};
use hetstream::hstreams::{Context, ContextBuilder};
use hetstream::plan::{Backend, NativeBackend, RunConfig, SimBackend};
use hetstream::spec::WorkloadSpec;
use hetstream::workloads::hotspot::N as HOTSPOT_N;
use hetstream::workloads::{gen_f32, Benchmark, Hotspot, Mode, NeedlemanWunsch, VectorAdd};

fn load(name: &str) -> (String, WorkloadSpec) {
    let path = format!("{}/../specs/{name}", env!("CARGO_MANIFEST_DIR"));
    let text = std::fs::read_to_string(&path).expect("committed spec readable");
    let spec = WorkloadSpec::from_json(&text).expect("committed spec parses");
    spec.validate().expect("committed spec validates");
    (text, spec)
}

fn instant_ctx(artifacts: &[&str]) -> Context {
    ContextBuilder::new()
        .profile(DeviceProfile::instant())
        .only_artifacts(artifacts.to_vec())
        .build()
        .expect("context")
}

const COMMITTED: &[&str] =
    &["vectoradd.json", "hotspot.json", "nw.json", "pipeline3.json", "stencil_asym.json"];

#[test]
fn committed_specs_round_trip_byte_identically() {
    for name in COMMITTED {
        let (text, spec) = load(name);
        assert_eq!(
            spec.to_json(),
            text,
            "{name}: the committed file must be the canonical serialization \
             (re-run to_json and commit its output)"
        );
        // And the canonical form is a fixpoint, so the cache key is too.
        let reparsed = WorkloadSpec::from_json(&spec.to_json()).expect("canonical form parses");
        assert_eq!(reparsed.content_hash(), spec.content_hash(), "{name}: unstable content hash");
    }
}

#[test]
fn malformed_specs_reject_with_a_clean_spec_error() {
    let valid = r#"{
        "schema": "hetstream-spec-v1",
        "name": "ok",
        "category": "independent",
        "mode": "windows",
        "output_bytes": 4096,
        "buffers": [{"name": "a", "bytes": 4096, "init": {"kind": "f32_rand", "seed": 1}}],
        "stages": [{"kernel": "burner_8", "inputs": ["a"]}]
    }"#;
    WorkloadSpec::from_json(valid).and_then(|s| s.validate()).expect("baseline must be valid");

    // (what is broken, the document) — every row must be Error::Spec.
    let table: &[(&str, String)] = &[
        ("unparsable json", "{".into()),
        ("wrong schema", valid.replace("hetstream-spec-v1", "hetstream-spec-v0")),
        ("empty name", valid.replace("\"ok\"", "\"\"")),
        ("unknown category", valid.replace("independent", "embarrassing")),
        ("unknown mode", valid.replace("windows", "ribbons")),
        ("missing output_bytes", valid.replace("\"output_bytes\": 4096,", "")),
        ("zero-byte buffer", valid.replace("\"bytes\": 4096", "\"bytes\": 0")),
        ("unknown init kind", valid.replace("f32_rand", "f16_rand")),
        ("unknown kernel", valid.replace("burner_8", "no_such_kernel")),
        ("undeclared stage-0 input", valid.replace("[\"a\"]", "[\"z\"]")),
        (
            "output/input size mismatch",
            valid.replace("\"output_bytes\": 4096", "\"output_bytes\": 8192"),
        ),
        ("misaligned buffer", valid.replace("4096", "4095")),
        ("zero granularity", valid.replace("\"mode\"", "\"granularity\": 0, \"mode\"")),
        (
            "halo without false_dependent",
            valid.replace("\"mode\"", "\"halo\": {\"lo\": 0.5}, \"mode\""),
        ),
        ("negative halo", valid.replace("\"mode\"", "\"halo\": {\"lo\": -1}, \"mode\"")),
    ];
    for (what, doc) in table {
        let got = WorkloadSpec::from_json(doc).and_then(|s| s.validate());
        assert!(
            matches!(got, Err(hetstream::Error::Spec(_))),
            "{what}: expected Error::Spec, got {got:?}"
        );
    }
}

/// Run `spec` over backends × streams × granularities and demand every
/// run assembles exactly `reference` — and passes its own bulk oracle.
fn assert_grid_matches(spec: &WorkloadSpec, ctx: &Context, grans: &[usize], reference: &[Vec<u8>]) {
    let sim = SimBackend::new(ctx);
    let native = NativeBackend::new();
    let backends: [&dyn Backend; 2] = [&sim, &native];
    for backend in backends {
        for &streams in &[1usize, 2, 4] {
            for &gran in grans {
                let opts = RunSpecOpts { streams, gran: Some(gran), verify: true };
                let out = run_spec(spec, backend, &opts).expect("spec run");
                let at = format!(
                    "{} on {} at {streams} stream(s) x gran {gran}",
                    spec.name,
                    backend.name()
                );
                assert_eq!(out.bulk_match, Some(true), "{at}: bulk oracle");
                assert_eq!(out.outputs, reference, "{at}: legacy bytes");
            }
        }
    }
}

#[test]
fn vectoradd_spec_is_bitwise_identical_to_the_legacy_driver() {
    let (_, spec) = load("vectoradd.json");
    let ctx = instant_ctx(&["vector_add"]);
    // Legacy reference: the hand-written driver's own tunable workload
    // through the historical GenericWorkload execution path.
    let wl = VectorAdd::new(1).tunable().expect("VectorAdd is re-chunkable");
    let (_, reference, _) = wl.execute(&ctx, Mode::Streamed(4)).expect("legacy run");
    assert_grid_matches(&spec, &ctx, &[1, 4, 8], &reference);
}

#[test]
fn hotspot_spec_is_bitwise_identical_to_the_legacy_driver() {
    let (_, spec) = load("hotspot.json");
    let ctx = instant_ctx(&["hotspot_step"]);
    let temp0 = gen_f32(HOTSPOT_N * HOTSPOT_N, 221);
    let power = gen_f32(HOTSPOT_N * HOTSPOT_N, 222);
    let plan = Hotspot::new(1).lower(&temp0, &power);
    let reference =
        SimBackend::new(&ctx).run(&plan, RunConfig::streams(2)).expect("legacy run").outputs;
    assert_grid_matches(&spec, &ctx, &[1, 2, 4], &reference);
}

#[test]
fn nw_spec_is_bitwise_identical_to_the_legacy_driver() {
    let (_, spec) = load("nw.json");
    let ctx = instant_ctx(&["nw_tile"]);
    let plan = NeedlemanWunsch::new(1).lower();
    let reference =
        SimBackend::new(&ctx).run(&plan, RunConfig::streams(4)).expect("legacy run").outputs;
    // Tiles-mode granularity is pinned by the matrix: every request
    // clamps to the 8x8 grid, so the "ladder" proves the clamp too.
    assert_grid_matches(&spec, &ctx, &[1, 8, 16], &reference);
}

#[test]
fn novel_specs_verify_clean_and_pass_the_bulk_oracle() {
    let apps: &[(&str, &[&str])] = &[
        ("pipeline3.json", &["vector_add", "fwt", "burner_8"]),
        ("stencil_asym.json", &["burner_64"]),
    ];
    for (file, artifacts) in apps {
        let (_, spec) = load(file);
        // Static: hazard-clean (tiling findings included) at the bulk
        // point and across the streamed ladder.
        let (_, rows, failed) = verify_spec(&spec);
        assert_eq!(failed, 0, "{file}: {:?}", rows.iter().filter(|r| !r.ok).collect::<Vec<_>>());
        // Dynamic: streamed output equals the bulk lowering bitwise on
        // both backends (the §4 re-chunking oracle).
        let ctx = instant_ctx(artifacts);
        let sim = SimBackend::new(&ctx);
        let native = NativeBackend::new();
        let backends: [&dyn Backend; 2] = [&sim, &native];
        for backend in backends {
            for streams in [1usize, 4] {
                let opts = RunSpecOpts { streams, gran: None, verify: true };
                let out = run_spec(&spec, backend, &opts).expect("novel spec run");
                assert_eq!(
                    out.bulk_match,
                    Some(true),
                    "{file} on {} at {streams} stream(s)",
                    backend.name()
                );
                assert!(out.report.is_clean());
            }
        }
    }
}
