//! hstreams semantics on the simulated device: in-order streams,
//! cross-stream events, real overlap, data integrity under concurrency.

use std::sync::Arc;

use hetstream::device::{DeviceProfile, DevRegion, HostDst, HostSrc};
use hetstream::hstreams::{host_dst, ContextBuilder};
use hetstream::runtime::bytes;

fn instant_ctx() -> hetstream::hstreams::Context {
    ContextBuilder::new()
        .profile(DeviceProfile::instant())
        .only_artifacts(["vector_add"])
        .build()
        .expect("context")
}

#[test]
fn h2d_d2h_roundtrip() {
    let ctx = instant_ctx();
    let payload: Vec<f32> = (0..1024).map(|i| i as f32).collect();
    let dev = DevRegion::whole(ctx.alloc(4096).unwrap(), 4096);
    let dst = host_dst(4096);

    let mut s = ctx.stream();
    s.h2d(HostSrc::whole(Arc::new(bytes::from_f32(&payload))), dev);
    s.d2h(dev, dst.clone());
    s.sync();

    assert_eq!(bytes::to_f32(&dst.data.lock().unwrap()), payload);
}

#[test]
fn kex_reads_and_writes_device_regions() {
    let ctx = instant_ctx();
    let n = 65536;
    let a: Vec<f32> = (0..n).map(|i| i as f32).collect();
    let b: Vec<f32> = vec![2.5; n];
    let da = DevRegion::whole(ctx.alloc(n * 4).unwrap(), n * 4);
    let db = DevRegion::whole(ctx.alloc(n * 4).unwrap(), n * 4);
    let dc = DevRegion::whole(ctx.alloc(n * 4).unwrap(), n * 4);
    let dst = host_dst(n * 4);

    let mut s = ctx.stream();
    s.h2d(HostSrc::whole(Arc::new(bytes::from_f32(&a))), da);
    s.h2d(HostSrc::whole(Arc::new(bytes::from_f32(&b))), db);
    s.kex("vector_add", vec![da, db], vec![dc]);
    s.d2h(dc, dst.clone());
    s.sync();

    let c = bytes::to_f32(&dst.data.lock().unwrap());
    for i in (0..n).step_by(4096) {
        assert_eq!(c[i], a[i] + 2.5);
    }
}

#[test]
fn stream_ops_retire_in_order() {
    let ctx = instant_ctx();
    let dev = DevRegion::whole(ctx.alloc(4).unwrap(), 4);
    let mut s = ctx.stream();
    let mut events = Vec::new();
    for v in 0..50i32 {
        let e = s.h2d(HostSrc::whole(Arc::new(bytes::from_i32(&[v]))), dev);
        events.push(e);
    }
    s.sync();
    // Samples must be monotone: op k ends no later than op k+1 ends.
    for w in events.windows(2) {
        let a = w[0].sample().unwrap();
        let b = w[1].sample().unwrap();
        assert!(a.end <= b.end, "in-order retirement violated");
    }
    // Last write wins.
    assert_eq!(bytes::to_i32(&ctx.debug_read(dev).unwrap()), vec![49]);
}

#[test]
fn cross_stream_wait_event_orders_work() {
    let ctx = instant_ctx();
    let dev = DevRegion::whole(ctx.alloc(4).unwrap(), 4);

    let mut s1 = ctx.stream();
    let mut s2 = ctx.stream();
    // s1 writes 7; s2 waits on that event, then overwrites with 9.
    let e1 = s1.h2d(HostSrc::whole(Arc::new(bytes::from_i32(&[7]))), dev);
    s2.wait_event(e1.clone());
    let e2 = s2.h2d(HostSrc::whole(Arc::new(bytes::from_i32(&[9]))), dev);
    e2.wait();
    assert!(e1.is_done(), "dependency retired first");
    assert_eq!(bytes::to_i32(&ctx.debug_read(dev).unwrap()), vec![9]);
}

#[test]
fn transfers_overlap_compute_on_paced_device() {
    // Two streams on a paced profile: stream B's H2D must start before
    // stream A's KEX finishes — the paper's overlap, observed directly
    // from the event samples.  Under the virtual clock the modeled
    // milliseconds cost no real time and the assertion is exact instead
    // of OS-scheduler dependent.
    let mut profile = DeviceProfile::instant();
    profile.name = "paced-test-sim".into(); // opt out of auto-dilation
    profile.h2d_gbps = 0.05; // 256KiB ≈ 5 ms
    profile.gflops = 1e-3; // 10k flops ≈ 10 ms
    let ctx = ContextBuilder::new()
        .profile(profile)
        .only_artifacts(["vector_add"])
        .time_mode(hetstream::device::TimeMode::Virtual)
        .build()
        .expect("context");

    let n = 65536;
    let payload = Arc::new(bytes::from_f32(&vec![1.0f32; n]));
    let da = DevRegion::whole(ctx.alloc(n * 4).unwrap(), n * 4);
    let db = DevRegion::whole(ctx.alloc(n * 4).unwrap(), n * 4);
    let dc = DevRegion::whole(ctx.alloc(n * 4).unwrap(), n * 4);
    let dx = DevRegion::whole(ctx.alloc(n * 4).unwrap(), n * 4);

    let mut s1 = ctx.stream();
    let mut s2 = ctx.stream();
    // Pre-stage s1 inputs (untimed-ish, still paced but sequential).
    s1.h2d(HostSrc::whole(payload.clone()), da);
    s1.h2d(HostSrc::whole(payload.clone()), db);
    s1.sync();

    let kex = s1.kex_with("vector_add", vec![da, db], vec![dc], Some(10_000), 1);
    let xfer = s2.h2d(HostSrc::whole(payload.clone()), dx);
    let k = kex.wait();
    let x = xfer.wait();
    assert!(
        x.start < k.end,
        "H2D on stream 2 must overlap KEX on stream 1 (x.start {:?} k.end {:?})",
        x.start,
        k.end
    );
}

#[test]
fn arena_exhaustion_is_an_error_not_a_panic() {
    let ctx = ContextBuilder::new()
        .profile(DeviceProfile::instant())
        .only_artifacts(["vector_add"])
        .device_mem(1 << 20)
        .build()
        .expect("context");
    assert!(ctx.alloc(2 << 20).is_err());
    let ok = ctx.alloc(1 << 19).unwrap();
    ctx.free(ok).unwrap();
}

#[test]
fn device_mem_accounting() {
    let ctx = instant_ctx();
    let before = ctx.device_mem_used();
    let id = ctx.alloc(12345).unwrap();
    assert_eq!(ctx.device_mem_used(), before + 12345);
    ctx.free(id).unwrap();
    assert_eq!(ctx.device_mem_used(), before);
}

#[test]
fn d2h_into_offset_destination() {
    let ctx = instant_ctx();
    let dev = DevRegion::whole(ctx.alloc(8).unwrap(), 8);
    let dst = host_dst(24);
    let mut s = ctx.stream();
    s.h2d(HostSrc::whole(Arc::new(bytes::from_i32(&[5, 6]))), dev);
    s.d2h(dev, HostDst { data: dst.data.clone(), off: 8 });
    s.sync();
    let out = bytes::to_i32(&dst.data.lock().unwrap());
    assert_eq!(out, vec![0, 0, 5, 6, 0, 0]);
}

#[test]
fn multiple_compute_workers_stay_correct() {
    // hStreams-style core partitioning: two kernel queues (each with its
    // own PJRT client) executing interleaved work must still produce
    // exact results.
    let ctx = ContextBuilder::new()
        .profile(DeviceProfile::instant())
        .only_artifacts(["vector_add"])
        .compute_workers(2)
        .build()
        .expect("context");
    let n = 65536;
    let a: Vec<f32> = (0..n).map(|i| i as f32).collect();
    let b: Vec<f32> = (0..n).map(|i| (n - i) as f32).collect();
    let mut streams: Vec<_> = (0..4).map(|_| ctx.stream()).collect();
    let mut dsts = Vec::new();
    let mut bufs = Vec::new();
    for (t, s) in streams.iter_mut().enumerate() {
        let da = DevRegion::whole(ctx.alloc(n * 4).unwrap(), n * 4);
        let db = DevRegion::whole(ctx.alloc(n * 4).unwrap(), n * 4);
        let dc = DevRegion::whole(ctx.alloc(n * 4).unwrap(), n * 4);
        let at: Vec<f32> = a.iter().map(|v| v + t as f32).collect();
        s.h2d(HostSrc::whole(Arc::new(bytes::from_f32(&at))), da);
        s.h2d(HostSrc::whole(Arc::new(bytes::from_f32(&b))), db);
        s.kex("vector_add", vec![da, db], vec![dc]);
        let dst = host_dst(n * 4);
        s.d2h(dc, dst.clone());
        dsts.push(dst);
        bufs.push((da, db, dc));
    }
    for s in &streams {
        s.sync();
    }
    for (t, dst) in dsts.iter().enumerate() {
        let c = bytes::to_f32(&dst.data.lock().unwrap());
        for i in (0..n).step_by(7919) {
            assert_eq!(c[i], a[i] + t as f32 + b[i], "task {t} elem {i}");
        }
    }
}
