//! The hazard verifier against the real corpus — the positive proof
//! (all 224 (app × granularity) lowerings are clean) and the
//! hazard-injection negative controls of DESIGN.md §Verification:
//! mutate a provably-clean plan in one targeted way and assert the
//! verifier rejects it with the *right* structured hazard (kind, op
//! pair, byte interval) — a verifier that accepts everything would
//! pass the corpus sweep trivially.

use std::sync::Arc;

use hetstream::experiments::{verify_corpus, verify_rows_json};
use hetstream::plan::verify::{verify_plan_with_layout, HazardKind};
use hetstream::plan::{
    ensure_sound, lower_corpus_streamed_at, mirror_check_granularities, verify_plan, Granularity,
    HostSlice, PlanOpKind, PlanRegion, Slot, StreamPlan, CORPUS_BURNER,
};
use hetstream::runtime::ArenaLayout;

// ---------------------------------------------------------------------
// Positive proof: the whole verification corpus is hazard-free.
// ---------------------------------------------------------------------

#[test]
fn all_224_corpus_lowerings_verify_clean() {
    let (_, rows, failed) = verify_corpus(true);
    assert_eq!(rows.len(), 224, "56 representative apps x 4 granularities");
    assert_eq!(
        failed,
        0,
        "hazardous corpus lowerings: {:?}",
        rows.iter()
            .filter(|r| !r.ok)
            .map(|r| (r.app, r.gran, r.report.summary()))
            .collect::<Vec<_>>()
    );
    // The proof must not be vacuous: the sweep as a whole discharges
    // real ordered-conflict obligations and the JSON verdicts parse.
    let conflicts: usize = rows.iter().map(|r| r.report.conflicts).sum();
    assert!(conflicts > 1000, "only {conflicts} conflict pairs discharged corpus-wide");
    let v = hetstream::util::json::Json::parse(&verify_rows_json(&rows)).expect("valid JSON");
    assert_eq!(v.get("failed").and_then(|n| n.as_usize()), Some(0));
    assert_eq!(
        v.get("rows").and_then(|r| r.as_arr()).map(|a| a.len()),
        Some(224)
    );
}

// ---------------------------------------------------------------------
// Negative controls: injected hazards must be caught, by kind.
// ---------------------------------------------------------------------

/// A clean two-lane pipeline to mutate: per lane, H2D into a private
/// buffer, KEX into a private result buffer, D2H into the lane's half
/// of one shared host output.  Returns (plan, per-lane op indices).
fn clean_two_lane_plan() -> (StreamPlan, Vec<[usize; 3]>) {
    let n = 256usize;
    let payload = Arc::new(vec![7u8; n]);
    let mut p = StreamPlan::new("verify-mutant-base");
    let out = p.output(2 * n);
    let mut lanes = Vec::new();
    for lane in 0..2usize {
        let inb = p.buf(n);
        let resb = p.buf(n);
        let h = p.h2d(
            Slot::Task(lane),
            HostSlice::whole(payload.clone()),
            PlanRegion::whole(inb, n),
            vec![],
        );
        let k = p.kex(
            Slot::Task(lane),
            "burner_64",
            vec![PlanRegion::whole(inb, n)],
            vec![PlanRegion::whole(resb, n)],
            Some(1 << 16),
            1,
            vec![h],
        );
        let d = p.d2h(Slot::Task(lane), PlanRegion::whole(resb, n), out, lane * n, vec![k]);
        lanes.push([h, k, d]);
    }
    assert!(verify_plan(&p).is_clean(), "mutation base must start clean");
    (p, lanes)
}

#[test]
fn dropping_a_dep_edge_is_an_unordered_race() {
    let (mut p, lanes) = clean_two_lane_plan();
    // Re-home lane 1's KEX onto lane 0's slot *after* lane 0's D2H was
    // submitted, and cut its explicit edge: its read of the input
    // buffer is now ordered only by lane-0 program order — but move it
    // to a fresh slot and the edge to its own H2D is gone entirely.
    let [h1, k1, _] = lanes[1];
    p.ops[k1].deps.clear();
    p.ops[k1].slot = Slot::Task(7); // no shared program order with h1
    let report = verify_plan(&p);
    assert!(!report.is_sound());
    let h = report
        .hazards
        .iter()
        .find(|h| h.kind == HazardKind::UnorderedRace)
        .expect("dropped dep edge must surface as an unordered race");
    // The structured report names the op pair, the byte interval, and
    // the exact missing edge.
    assert_eq!(h.ops, (Some(h1), Some(k1)));
    assert_eq!((h.lo, h.hi), (0, 256));
    assert_eq!(h.missing_edge, Some((h1, k1)));
    let err = ensure_sound(&p).expect_err("submit gate must refuse the mutant");
    let msg = err.to_string();
    assert!(msg.contains("unordered-race"), "gate names the hazard kind: {msg}");
    assert!(msg.contains(&format!("op {h1}")), "gate names the op pair: {msg}");
}

#[test]
fn overlapping_d2h_windows_are_reported_with_the_interval() {
    let (mut p, lanes) = clean_two_lane_plan();
    // Slide lane 1's D2H window back so its first 64 bytes land on
    // lane 0's half of the output: an unordered cross-lane double
    // write (race) that also breaks exact tiling (gap + overlap).
    let [_, _, d1] = lanes[1];
    let d0 = lanes[0][2];
    if let PlanOpKind::D2h { off, .. } = &mut p.ops[d1].kind {
        *off -= 64;
    } else {
        unreachable!("lane op table");
    }
    let report = verify_plan(&p);
    assert!(!report.is_sound(), "cross-lane double write is fatal");
    let race = report
        .hazards
        .iter()
        .find(|h| h.kind == HazardKind::UnorderedRace)
        .expect("overlapping windows from unordered lanes race");
    assert_eq!(race.ops, (Some(d0), Some(d1)));
    assert_eq!((race.lo, race.hi), (192, 256), "exactly the 64 contested bytes");
    // And the tiling walk still reports the strictness hazards: the
    // doubly-written interval and the now-uncovered tail.
    assert!(report.hazards.iter().any(|h| h.kind == HazardKind::OutputOverlap
        && (h.lo, h.hi) == (192, 256)));
    assert!(report
        .hazards
        .iter()
        .any(|h| h.kind == HazardKind::OutputGap && (h.lo, h.hi) == (448, 512)));
}

#[test]
fn shrinking_a_must_zero_span_is_an_uncovered_read() {
    // A plan that legitimately reads bytes nothing wrote: H2D fills
    // only the first half of the KEX input buffer.  `ArenaLayout::of`
    // must-zeroes the second half, so the honest layout is clean.
    let n = 128usize;
    let payload = Arc::new(vec![3u8; n / 2]);
    let mut p = StreamPlan::new("verify-zero-mutant");
    let out = p.output(n);
    let inb = p.buf(n);
    let resb = p.buf(n);
    let h = p.h2d(
        Slot::Task(0),
        HostSlice::whole(payload),
        PlanRegion { buf: inb, off: 0, len: n / 2 },
        vec![],
    );
    let k = p.kex(
        Slot::Task(0),
        "burner_64",
        vec![PlanRegion::whole(inb, n)],
        vec![PlanRegion::whole(resb, n)],
        Some(1 << 12),
        1,
        vec![h],
    );
    p.d2h(Slot::Task(0), PlanRegion::whole(resb, n), out, 0, vec![k]);

    let honest = ArenaLayout::of(&p);
    assert!(verify_plan_with_layout(&p, &honest).is_clean());

    // Shrink the span by one byte: a reused arena could now leak one
    // stale byte into the KEX read.
    let mut spans = honest.zero_spans().to_vec();
    let (s, e) = spans.pop().expect("the half-filled buffer must need a zero span");
    spans.push((s, e - 1));
    let report = verify_plan_with_layout(&p, &honest.clone().with_zero_spans(spans));
    assert!(!report.is_sound());
    let hz = report
        .hazards
        .iter()
        .find(|h| h.kind == HazardKind::UncoveredRead)
        .expect("shrunk zero span must surface as an uncovered read");
    assert_eq!(hz.ops.0, Some(k), "the reading op is named");
    assert_eq!((hz.lo, hz.hi), (n / 2, n), "the whole unwritten read interval is named");
}

#[test]
fn reordering_a_broadcast_after_its_consumer_is_late() {
    // Clean broadcast-prologue plan: shared H2D on Broadcast, then one
    // consumer KEX + D2H per lane.
    let n = 64usize;
    let payload = Arc::new(vec![9u8; n]);
    let mut p = StreamPlan::new("verify-late-broadcast");
    let out = p.output(n);
    let shared = p.buf(n);
    let resb = p.buf(n);
    let b = p.h2d(
        Slot::Broadcast,
        HostSlice::whole(payload.clone()),
        PlanRegion::whole(shared, n),
        vec![],
    );
    let k = p.kex(
        Slot::Task(0),
        "burner_64",
        vec![PlanRegion::whole(shared, n)],
        vec![PlanRegion::whole(resb, n)],
        Some(1 << 10),
        1,
        vec![b],
    );
    p.d2h(Slot::Task(0), PlanRegion::whole(resb, n), out, 0, vec![k]);
    assert!(verify_plan(&p).is_clean());

    // Swap the broadcast after its consumer, remapping dep indices to
    // keep edges strictly backwards (isolating the *placement* hazard
    // from InvalidDep): consumer first with no deps, broadcast second.
    let mut m = StreamPlan::new("verify-late-broadcast-mutant");
    m.outputs = p.outputs.clone();
    m.bufs = p.bufs.clone();
    let mk = m.kex(
        Slot::Task(0),
        "burner_64",
        vec![PlanRegion::whole(shared, n)],
        vec![PlanRegion::whole(resb, n)],
        Some(1 << 10),
        1,
        vec![],
    );
    let mb = m.h2d(
        Slot::Broadcast,
        HostSlice::whole(payload),
        PlanRegion::whole(shared, n),
        vec![],
    );
    m.d2h(Slot::Task(0), PlanRegion::whole(resb, n), out, 0, vec![mk]);
    let report = verify_plan(&m);
    assert!(!report.is_sound());
    let hz = report
        .hazards
        .iter()
        .find(|h| h.kind == HazardKind::LateBroadcast)
        .expect("broadcast after a Task op must be flagged late");
    assert_eq!(hz.ops.0, Some(mb), "the late broadcast op is named");
    // The misplacement also leaves the consumer's read unordered
    // against the broadcast write — both findings, not just one.
    assert!(report
        .hazards
        .iter()
        .any(|h| h.kind == HazardKind::UnorderedRace && h.ops == (Some(mk), Some(mb))));
}

// ---------------------------------------------------------------------
// The corpus mutants: injected hazards on *real* lowerings.
// ---------------------------------------------------------------------

#[test]
fn corpus_lowering_with_shifted_d2h_window_is_rejected() {
    // Take a real independent-category lowering and slide one D2H
    // window: the verifier must catch the injected hazard on the same
    // plans the positive sweep proves clean.
    let cfgs = hetstream::corpus::all_configs();
    let c = cfgs
        .iter()
        .find(|c| {
            matches!(
                c.category(),
                hetstream::analysis::Category::Independent
                    | hetstream::analysis::Category::FalseDependent
            )
        })
        .expect("corpus has independent apps");
    for g in mirror_check_granularities(c.category()) {
        let mut plan = lower_corpus_streamed_at(c, CORPUS_BURNER, g);
        assert!(verify_plan(&plan).is_clean(), "{}/{} starts clean", c.app, g.get());
        let Some((idx, width)) = plan.ops.iter().enumerate().find_map(|(i, op)| match &op.kind {
            PlanOpKind::D2h { off, src, .. } if *off > 0 => Some((i, src.len.min(*off))),
            _ => None,
        }) else {
            // Granularity 1 lowers to a single D2H window at offset 0;
            // there is nothing to collide with.
            assert_eq!(g.get(), 1, "multi-window lowerings must expose a shiftable D2H");
            continue;
        };
        if let PlanOpKind::D2h { off, .. } = &mut plan.ops[idx].kind {
            *off -= width.max(1);
        }
        let report = verify_plan(&plan);
        assert!(
            !report.is_clean(),
            "{}/{} gran {}: shifted D2H window must not verify",
            c.app,
            c.config,
            g.get()
        );
        assert!(
            report
                .hazards
                .iter()
                .any(|h| matches!(
                    h.kind,
                    HazardKind::UnorderedRace | HazardKind::OutputOverlap | HazardKind::OutputGap
                )),
            "the injected window collision is reported as a race or tiling hazard"
        );
    }
}

#[test]
fn corpus_granularity_ladder_matches_the_mirror_population() {
    // The cross-check contract: both sides enumerate (1, default, 7,
    // 16) per app, pre-clamp, duplicates kept.
    let g = mirror_check_granularities(hetstream::analysis::Category::Sync);
    assert_eq!(
        g.iter().map(|g| g.get()).collect::<Vec<_>>(),
        vec![1, 1, 7, 16],
        "SYNC default granularity duplicates 1 — kept, to count like the mirror"
    );
    let g = mirror_check_granularities(hetstream::analysis::Category::Independent);
    assert_eq!(g.iter().map(|g| g.get()).collect::<Vec<_>>(), vec![1, 8, 7, 16]);
    let _ = Granularity::new(0); // clamps, never panics
}
