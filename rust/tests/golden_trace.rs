//! Golden-trace regression: the virtual event timeline of a fig1-style
//! two-stream pipelined offload, serialized to JSON and compared
//! byte-for-byte against a checked-in golden file.
//!
//! The scenario uses a round-number profile (1 GB/s, 1 GFLOP/s, zero
//! latencies) so every modeled duration is an exact integer nanosecond
//! count and the whole timeline is hand-checkable:
//!
//! - each 256 KiB transfer takes exactly 262144 ns,
//! - each kernel (1e6-FLOP override) takes exactly 1000000 ns,
//! - chunk task = h2d a, h2d b, kex vector_add, d2h — 4 chunks
//!   round-robined over 2 streams, so chunk i+1's uploads overlap
//!   chunk i's kernel exactly as in the paper's Fig. 5.
//!
//! Regenerate after an intentional model change with:
//! `UPDATE_GOLDEN=1 cargo test --test golden_trace`.

use std::sync::Arc;

use hetstream::device::{DevRegion, DeviceProfile, HostSrc, TimeMode};
use hetstream::hstreams::{host_dst, ContextBuilder};
use hetstream::runtime::bytes;

const N: usize = 65536; // vector_add artifact elements
const CHUNKS: usize = 4;
const STREAMS: usize = 2;

fn golden_profile() -> DeviceProfile {
    DeviceProfile {
        // `-sim` suffix opts out of auto-dilation: used as-is.
        name: "golden-sim".into(),
        h2d_gbps: 1.0,
        d2h_gbps: 1.0,
        latency_us: 0.0,
        alloc_us_per_mb: 0.0,
        gflops: 1.0,
        launch_us: 0.0,
        duplex: true,
    }
}

/// Run the pipeline once on a fresh context; returns (trace JSON,
/// makespan ns, outputs valid).
fn run_pipeline() -> (String, u64, bool) {
    let ctx = ContextBuilder::new()
        .profile(golden_profile())
        .only_artifacts(["vector_add"])
        .time_mode(TimeMode::Virtual)
        .record_trace(true)
        .build()
        .expect("context");

    let a: Vec<f32> = (0..N).map(|i| i as f32 * 0.25).collect();
    let b: Vec<f32> = (0..N).map(|i| (N - i) as f32).collect();
    let pa = Arc::new(bytes::from_f32(&a));
    let pb = Arc::new(bytes::from_f32(&b));

    let mut streams: Vec<_> = (0..STREAMS).map(|_| ctx.stream()).collect();
    let mut dsts = Vec::new();
    for c in 0..CHUNKS {
        let s = &mut streams[c % STREAMS];
        let da = DevRegion::whole(ctx.alloc(N * 4).unwrap(), N * 4);
        let db = DevRegion::whole(ctx.alloc(N * 4).unwrap(), N * 4);
        let dc = DevRegion::whole(ctx.alloc(N * 4).unwrap(), N * 4);
        s.h2d(HostSrc::whole(pa.clone()), da);
        s.h2d(HostSrc::whole(pb.clone()), db);
        s.kex_with("vector_add", vec![da, db], vec![dc], Some(1_000_000), 1);
        let dst = host_dst(N * 4);
        s.d2h(dc, dst.clone());
        dsts.push(dst);
    }
    for s in &streams {
        s.sync();
    }

    let makespan = hetstream::hstreams::makespan(streams.iter().flat_map(|s| s.events()));
    let valid = dsts.iter().all(|d| {
        let got = bytes::to_f32(&d.data.lock().unwrap());
        got.iter().enumerate().all(|(i, &v)| v == a[i] + b[i])
    });
    (ctx.trace_json(), makespan.as_nanos() as u64, valid)
}

fn golden_path() -> std::path::PathBuf {
    std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("tests/golden/fig1_pipeline_trace.json")
}

#[test]
fn virtual_timeline_matches_checked_in_golden() {
    let (json, makespan_ns, valid) = run_pipeline();
    assert!(valid, "pipeline outputs must equal a + b");
    // Hand-computed end of the last D2H (see module docs).
    assert_eq!(makespan_ns, 4_786_432, "modeled makespan");

    if std::env::var("UPDATE_GOLDEN").is_ok() {
        std::fs::write(golden_path(), &json).expect("write golden");
        return;
    }
    let golden = std::fs::read_to_string(golden_path()).expect(
        "golden file missing — run with UPDATE_GOLDEN=1 to create it",
    );
    assert_eq!(
        json, golden,
        "virtual trace diverged from golden (UPDATE_GOLDEN=1 regenerates after an \
         intentional model change)"
    );
}

#[test]
fn virtual_timeline_is_byte_identical_across_runs() {
    let (a, ns_a, _) = run_pipeline();
    let (b, ns_b, _) = run_pipeline();
    assert_eq!(ns_a, ns_b);
    assert_eq!(a, b, "two identical simulations must serialize identically");
}

#[test]
fn overlap_is_visible_in_the_trace() {
    // Chunk 1's H2D (seq 4, stream 1) must start strictly before chunk
    // 0's KEX (seq 2, stream 0) ends — the paper's overlap, read off
    // the recorded timeline rather than asserted via sleeps.
    let (json, _, _) = run_pipeline();
    let parsed = hetstream::util::json::Json::parse(&json).expect("trace parses");
    let events = parsed.get("events").and_then(|e| e.as_arr()).expect("events");
    let field = |i: usize, k: &str| -> u64 {
        events[i].get(k).and_then(|v| v.as_u64()).unwrap_or_else(|| panic!("{k} of event {i}"))
    };
    assert_eq!(events.len(), (CHUNKS) * 4);
    let kex0_end = field(2, "end_ns");
    let h2d1_start = field(4, "start_ns");
    assert!(
        h2d1_start < kex0_end,
        "stream 1 upload ({h2d1_start}) must overlap stream 0 kernel (ends {kex0_end})"
    );
}
