//! Analysis integration: the stage-by-stage engine measurement agrees
//! with the analytic device model, and the experiment tables carry the
//! paper's key shapes.

use hetstream::analysis::{decide, fraction_at_or_below, Decision};
use hetstream::corpus::{all_configs, configs_for};
use hetstream::device::DeviceProfile;
use hetstream::experiments::{analytic_stage_times, fig4, offload_spec, table2};
use hetstream::hstreams::ContextBuilder;

#[test]
fn engine_stage_times_match_the_device_model() {
    // A transfer-heavy and a compute-heavy spec, measured through the
    // engines (5-run medians), must land near the dilated model.
    let ctx = ContextBuilder::new().only_artifacts(["burner_64"]).build().expect("context");
    let p = ctx.profile().clone();

    // FLOP budgets sit well above the burner's real execution floor
    // (~1.3 ms/call) so the modeled pacing governs (max(real, modeled)).
    for (h2d, flops) in [(1 << 20, 10_000_000u64), (1 << 16, 40_000_000u64)] {
        let spec = hetstream::analysis::OffloadSpec {
            name: "model-check".into(),
            h2d: vec![h2d],
            kex: vec![hetstream::analysis::KexCall {
                artifact: "burner_64".into(),
                flops,
                repeats: 1,
            }],
            d2h: vec![h2d / 2],
        };
        let st = hetstream::analysis::measure_stages(&ctx, &spec, 5);
        let want_h2d = p.transfer_time(h2d, true) + p.alloc_time(h2d);
        let want_kex = p.kex_time(flops);
        let h2d_err =
            (st.h2d.as_secs_f64() - want_h2d.as_secs_f64()).abs() / want_h2d.as_secs_f64();
        let kex_err =
            (st.kex.as_secs_f64() - want_kex.as_secs_f64()).abs() / want_kex.as_secs_f64();
        assert!(h2d_err < 0.25, "h2d {:?} vs model {:?}", st.h2d, want_h2d);
        assert!(kex_err < 0.35, "kex {:?} vs model {:?}", st.kex, want_kex);
    }
}

#[test]
fn engine_r_matches_analytic_r_on_corpus_sample() {
    let ctx = ContextBuilder::new().only_artifacts(["burner_64"]).build().expect("context");
    let paper = DeviceProfile::mic31sp();
    // A few configs spanning the R spectrum.
    let sample: Vec<_> = all_configs().into_iter().step_by(47).take(5).collect();
    for cfg in sample {
        let st = hetstream::analysis::measure_stages(&ctx, &offload_spec(&cfg), 5);
        let model = analytic_stage_times(&cfg, &paper);
        // Virtual-clock engine times are exact, so the only divergence
        // left is structural: dilated fixed latencies (the engine pays
        // 16x the paper's per-op latency/launch while bytes/FLOPs scale
        // down 16x) and the iteration/FLOP caps on heavily iterative
        // apps.  Both sides are closed-form (engine = the modeled
        // durations themselves under TimeMode::Virtual), and evaluating
        // them over this sample gives a worst case of ~0.032 (MatVecMul
        // n=4, latency-dominated); 0.06 leaves ~2x margin without
        // masking a model regression the old 0.22 bound would have let
        // slip.
        let err = (st.r_h2d() - model.r_h2d()).abs();
        assert!(
            err < 0.06,
            "{}/{}: engine R {:.3} vs analytic {:.3}",
            cfg.app,
            cfg.config,
            st.r_h2d(),
            model.r_h2d()
        );
    }
}

#[test]
fn fig1_headline_shape_holds() {
    // Paper: >50% of configs have R_H2D <= 0.1; D2H fraction even larger.
    let p = DeviceProfile::mic31sp();
    let rs: Vec<f64> = all_configs().iter().map(|c| analytic_stage_times(c, &p).r_h2d()).collect();
    let ds: Vec<f64> = all_configs().iter().map(|c| analytic_stage_times(c, &p).r_d2h()).collect();
    let h = fraction_at_or_below(&rs, 0.1);
    let d = fraction_at_or_below(&ds, 0.1);
    assert!(h > 0.5, "CDF(R_H2D<=0.1) = {h}");
    assert!(d > h, "D2H fraction ({d}) should exceed H2D ({h})");
    assert!(d > 0.6, "CDF(R_D2H<=0.1) = {d}");
}

#[test]
fn fig2_dataset_shape_holds() {
    let p = DeviceProfile::mic31sp();
    let lbm = configs_for("lbm");
    let short = analytic_stage_times(&lbm[0], &p).r_h2d();
    let long = analytic_stage_times(&lbm[1], &p).r_h2d();
    assert!(short > 3.0 * long, "lbm: R(short) {short} >> R(long) {long}");

    let fdtd = configs_for("FDTD3d");
    let rs: Vec<f64> = fdtd.iter().map(|c| analytic_stage_times(c, &p).r_h2d()).collect();
    for w in rs.windows(2) {
        assert!(w[0] > w[1], "FDTD3d R must fall as timesteps rise: {rs:?}");
    }
}

#[test]
fn fig3_variant_shape_holds() {
    let p = DeviceProfile::mic31sp();
    let v1 = configs_for("Reduction");
    let v2 = configs_for("Reduction-2");
    for (a, b) in v1.iter().zip(&v2) {
        let r1 = analytic_stage_times(a, &p).r_d2h();
        let r2 = analytic_stage_times(b, &p).r_d2h();
        assert!(r2 > r1, "v2 must ship more back: {r1} vs {r2}");
    }
}

#[test]
fn fig4_platform_shape_holds() {
    let mic = DeviceProfile::mic31sp();
    let k80 = DeviceProfile::k80();
    let mut mic_kex = 0.0;
    let mut k80_kex = 0.0;
    let cfgs = configs_for("nn");
    for c in &cfgs {
        mic_kex += analytic_stage_times(c, &mic).r_kex();
        k80_kex += analytic_stage_times(c, &k80).r_kex();
    }
    mic_kex /= cfgs.len() as f64;
    k80_kex /= cfgs.len() as f64;
    // Paper: ~33% on MIC vs ~2% on the GPU.
    assert!((0.2..0.5).contains(&mic_kex), "MIC KEX fraction {mic_kex}");
    assert!(k80_kex < 0.1, "K80 KEX fraction {k80_kex}");
    assert!(mic_kex > 4.0 * k80_kex);
    // And the table renders.
    assert!(fig4().markdown().contains("MEAN"));
}

#[test]
fn decision_rule_flags_both_extremes() {
    let p = DeviceProfile::mic31sp();
    let mut low = 0;
    let mut high = 0;
    for c in all_configs() {
        match decide(analytic_stage_times(&c, &p).r_h2d()) {
            Decision::NotWorthLowR => low += 1,
            Decision::NotWorthHighR => high += 1,
            Decision::Worthwhile => {}
        }
    }
    assert!(low > 100, "most corpus configs are not worth streaming (paper: >50%)");
    assert!(high > 0, "some configs are transfer-bound beyond help");
}

#[test]
fn table2_lists_every_suite_and_exemplar() {
    let md = table2().markdown();
    for s in ["Rodinia", "Parboil", "NVIDIA SDK", "AMD SDK"] {
        assert!(md.contains(s), "missing suite {s}");
    }
    assert!(md.contains("nn"));
    assert!(md.contains("FastWalshTransform"));
}
