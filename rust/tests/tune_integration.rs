//! Joint (streams × granularity) autotuner integration: the measured
//! grid search finds per-app optima, validates every grid point
//! bitwise against the bulk lowering, and the tuning paths fail soft
//! (errors, never panics) on degenerate ladders.

use hetstream::analysis::{autotune_plan, autotune_streams, gran_ladder, predict_plan_point};
use hetstream::corpus::configs_for;
use hetstream::hstreams::{Context, ContextBuilder};
use hetstream::plan::{
    default_corpus_granularity, effective_corpus_granularity, lower_corpus_bulk,
    lower_corpus_streamed_at, Granularity, CORPUS_BURNER,
};
use hetstream::workloads::VectorAdd;

/// Default (mic31sp-sim) virtual-clock context — modeled pacing, so
/// the tuning surface has real shape, but no sleeping.
fn paced_ctx(artifacts: &[&str]) -> Context {
    ContextBuilder::new()
        .only_artifacts(artifacts.to_vec())
        .time_mode(hetstream::device::TimeMode::Virtual)
        .build()
        .expect("context")
}

#[test]
fn autotune_plan_beats_fixed_granularity_somewhere_on_the_corpus() {
    // A category-spanning sample: independent (nn), compute-dominated
    // wavefront (gaussian), halo-inflated (lavaMD), sync control
    // (backprop), big-transfer scalar-output independent (Reduction).
    let ctx = paced_ctx(&[CORPUS_BURNER]);
    let streams = [1usize, 2, 4, 8];
    let mut strict_wins = 0usize;

    for app in ["nn", "gaussian", "lavaMD", "backprop", "Reduction"] {
        let cfg = configs_for(app).into_iter().next().expect("app in corpus");
        let bulk = lower_corpus_bulk(&cfg, CORPUS_BURNER);
        // Map candidates to the lowering's effective knob values and
        // dedupe, as autotune_plan's contract requires (tune_corpus
        // does the same) — no aliased grid points.
        let fixed =
            effective_corpus_granularity(&cfg, default_corpus_granularity(cfg.category())).get();
        let mut grans: Vec<usize> = [1usize, 2, 4, 8, 16]
            .into_iter()
            .chain([fixed])
            .map(|g| effective_corpus_granularity(&cfg, Granularity::new(g)).get())
            .collect();
        grans.sort_unstable();
        grans.dedup();

        let r = autotune_plan(
            &ctx,
            &bulk,
            &|g| lower_corpus_streamed_at(&cfg, CORPUS_BURNER, g),
            &streams,
            &grans,
            1,
        )
        .unwrap_or_else(|e| panic!("{app}: {e}"));

        assert_eq!(r.surface.len(), streams.len() * grans.len(), "{app}: full grid measured");
        assert!(r.best_ms.is_finite() && r.best_ms > 0.0, "{app}");
        assert!(streams.contains(&r.best_streams) && grans.contains(&r.best_gran), "{app}");

        // The argmin over the whole grid can never lose to the fixed
        // pre-tuner granularity column…
        let fixed_ms = r
            .surface
            .iter()
            .filter(|&&(_, g, _)| g == fixed)
            .map(|&(_, _, ms)| ms)
            .min_by(f64::total_cmp)
            .expect("fixed granularity is in the grid");
        assert!(r.best_ms <= fixed_ms, "{app}: argmin {} > fixed {}", r.best_ms, fixed_ms);
        if r.best_ms < fixed_ms {
            strict_wins += 1;
        }
    }
    // …and the knob must actually pay somewhere: at least one app's
    // tuned makespan strictly beats its fixed-granularity best.
    assert!(strict_wins >= 1, "granularity tuning never beat the fixed setting");
}

#[test]
fn analytic_seed_is_sane_on_corpus_plans() {
    let ctx = paced_ctx(&[CORPUS_BURNER]);
    for app in ["nn", "gaussian", "hotspot"] {
        let cfg = configs_for(app).into_iter().next().expect("app in corpus");
        let bulk = lower_corpus_bulk(&cfg, CORPUS_BURNER);
        let (s, g) = predict_plan_point(&bulk, ctx.profile());
        assert!((2..=8).contains(&s), "{app}: streams seed {s}");
        assert!((1..=64).contains(&g), "{app}: granularity seed {g}");
        assert!(g >= s, "{app}: at least one task per stream");
        assert!(gran_ladder(g).contains(&g));
    }
}

#[test]
fn autotune_plan_errors_on_empty_grid() {
    let ctx = paced_ctx(&[CORPUS_BURNER]);
    let cfg = configs_for("nn").into_iter().next().expect("nn in corpus");
    let bulk = lower_corpus_bulk(&cfg, CORPUS_BURNER);
    let lower = |g| lower_corpus_streamed_at(&cfg, CORPUS_BURNER, g);
    assert!(autotune_plan(&ctx, &bulk, &lower, &[], &[1, 2], 1).is_err());
    assert!(autotune_plan(&ctx, &bulk, &lower, &[1, 2], &[], 1).is_err());
}

#[test]
fn autotune_streams_errors_on_empty_ladder() {
    let ctx = paced_ctx(&["vector_add"]);
    let bench = VectorAdd::new(1);
    let err = autotune_streams(&ctx, &bench, &[], 3).expect_err("empty ladder must error");
    assert!(err.to_string().contains("empty"), "unexpected error: {err}");
}
