//! End-to-end driver (DESIGN.md E1/E5): the paper's full decision flow
//! over the 56-benchmark / 223-configuration Table-1 corpus — measure R
//! stage-by-stage through the simulated platform for a stratified engine
//! sample, sweep the rest analytically, categorize every benchmark
//! (Table 2), and apply the §6 streaming-necessity rule.
//!
//! ```sh
//! cargo run --release --example corpus_survey -- [engine-sample-size]
//! ```

use hetstream::analysis::{decide, fraction_at_or_below, Decision};
use hetstream::corpus::all_configs;
use hetstream::device::DeviceProfile;
use hetstream::experiments::{analytic_stage_times, fig1_engine, table2};
use hetstream::hstreams::ContextBuilder;

fn main() -> Result<(), Box<dyn std::error::Error>> {
    let sample: usize = std::env::args().nth(1).and_then(|s| s.parse().ok()).unwrap_or(20);
    let profile = DeviceProfile::mic31sp();

    // --- Analytic sweep of all 223 configs (paper-scale profile) ---
    let configs = all_configs();
    let mut r_h2d = Vec::new();
    let mut r_d2h = Vec::new();
    let mut worthwhile = 0usize;
    for c in &configs {
        let st = analytic_stage_times(c, &profile);
        if decide(st.r_h2d()) == Decision::Worthwhile {
            worthwhile += 1;
        }
        r_h2d.push(st.r_h2d());
        r_d2h.push(st.r_d2h());
    }
    println!("=== Fig. 1 statistical view ({} configs) ===", configs.len());
    for x in [0.1, 0.3, 0.5, 0.9] {
        println!(
            "  CDF at R = {x:.1}:  H2D {:5.1}%   D2H {:5.1}%",
            100.0 * fraction_at_or_below(&r_h2d, x),
            100.0 * fraction_at_or_below(&r_d2h, x),
        );
    }
    println!(
        "  paper: >50% of configs at R_H2D <= 0.1 -> here {:.1}%",
        100.0 * fraction_at_or_below(&r_h2d, 0.1)
    );
    println!("  streaming worthwhile (0.1 < R < 0.9): {worthwhile}/{} configs", configs.len());

    // --- Engine validation sample: same protocol through the real DMA +
    //     compute engines (the paper's 11-run medians) ---
    println!("\n=== engine validation sample ({sample} configs, 11-run medians) ===");
    let ctx = ContextBuilder::new().only_artifacts(["burner_64"]).build()?;
    let (table, rows) = fig1_engine(&ctx, 11, Some(sample));
    println!("{}", table.markdown());
    let eng_h2d: Vec<f64> = rows.iter().map(|r| r.r_h2d).collect();
    println!(
        "engine-measured CDF at R_H2D = 0.1: {:.1}%",
        100.0 * fraction_at_or_below(&eng_h2d, 0.1)
    );

    // --- Table 2 ---
    println!("\n{}", table2().markdown());
    Ok(())
}
