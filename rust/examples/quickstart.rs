//! Quickstart: the whole stack in ~40 lines.
//!
//! Builds a simulated CPU+coprocessor platform, runs `VectorAdd` as a
//! classic bulk offload and as a 4-stream pipelined port, validates the
//! results against a host oracle, and prints the streaming gain.
//!
//! ```sh
//! make artifacts && cargo run --release --example quickstart
//! ```

use hetstream::device::DeviceProfile;
use hetstream::hstreams::ContextBuilder;
use hetstream::workloads::{Benchmark, Mode, VectorAdd};

fn main() -> Result<(), Box<dyn std::error::Error>> {
    // The paper's platform: Xeon host + Xeon Phi 31SP over PCIe,
    // time-dilated for the simulator (all ratios preserved).
    let ctx = ContextBuilder::new()
        .profile(DeviceProfile::mic31sp())
        .only_artifacts(["vector_add"])
        .build()?;

    let bench = VectorAdd::new(1);

    // Warm up the PJRT executable, then measure both modes.
    bench.run(&ctx, Mode::Baseline)?;
    let base = bench.run(&ctx, Mode::Baseline)?;
    let streamed = bench.run(&ctx, Mode::Streamed(4))?;

    assert!(base.validated && streamed.validated, "outputs must match the host oracle");

    let gain = (base.wall.as_secs_f64() / streamed.wall.as_secs_f64() - 1.0) * 100.0;
    println!("device profile : {}", ctx.profile().name);
    println!("tasks          : {}", base.tasks);
    println!("bulk offload   : {:7.2} ms", base.wall.as_secs_f64() * 1e3);
    println!("4 streams      : {:7.2} ms", streamed.wall.as_secs_f64() * 1e3);
    println!("improvement    : {gain:+.1}%  (paper range: 8%..90%)");
    Ok(())
}
