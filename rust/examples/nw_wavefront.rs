//! The paper's Fig. 8 walk-through: Needleman–Wunsch as a wavefront of
//! dependent tiles — diagonals execute in order, tiles on one diagonal
//! ride different streams, and the per-diagonal stream count varies
//! exactly as the paper describes.
//!
//! ```sh
//! cargo run --release --example nw_wavefront -- [streams] [scale]
//! ```

use hetstream::hstreams::ContextBuilder;
use hetstream::partition::diagonals;
use hetstream::workloads::{Benchmark, Mode, NeedlemanWunsch};

fn main() -> Result<(), Box<dyn std::error::Error>> {
    let args: Vec<String> = std::env::args().collect();
    let n_streams: usize = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(4);
    let scale: usize = args.get(2).and_then(|s| s.parse().ok()).unwrap_or(1);

    let ctx = ContextBuilder::new().only_artifacts(["nw_tile"]).build()?;
    let bench = NeedlemanWunsch::new(scale);
    let grid = bench.matrix_size() / 32;

    println!(
        "aligning two {}-element sequences: {}x{} tiles of 32x32",
        bench.matrix_size(),
        grid,
        grid
    );
    println!("wavefront schedule (tiles per diagonal = concurrent tasks):");
    let widths: Vec<String> =
        diagonals(grid, grid).iter().map(|d| d.tiles.len().to_string()).collect();
    println!("  {}", widths.join(" "));

    // Bulk offload vs wavefront-streamed; the driver validates against
    // the full whole-matrix DP oracle.
    bench.run(&ctx, Mode::Baseline)?; // warmup
    let base = bench.run(&ctx, Mode::Baseline)?;
    let streamed = bench.run(&ctx, Mode::Streamed(n_streams))?;
    assert!(base.validated && streamed.validated, "tile wavefront must equal whole-matrix DP");

    println!("single stream : {:7.2} ms", base.wall.as_secs_f64() * 1e3);
    println!(
        "{n_streams} streams     : {:7.2} ms  ({:+.1}% — paper: ~52% for nw)",
        streamed.wall.as_secs_f64() * 1e3,
        (base.wall.as_secs_f64() / streamed.wall.as_secs_f64() - 1.0) * 100.0
    );
    Ok(())
}
