//! The paper's Fig. 6 walk-through: streaming Rodinia `nn` by hand with
//! the raw hstreams API (no workload driver) — partition the record set,
//! spawn streams, overlap H2D with KEX, select the k nearest on the host.
//!
//! ```sh
//! cargo run --release --example nn_streaming -- [streams] [chunks]
//! ```

use std::sync::Arc;

use hetstream::device::{DevRegion, HostDst, HostSrc};
use hetstream::hstreams::ContextBuilder;
use hetstream::partition::chunk_ranges;
use hetstream::runtime::bytes;
use hetstream::workloads::gen_f32;

const CHUNK: usize = 16384; // records per task (the nn_dist artifact shape)

fn main() -> Result<(), Box<dyn std::error::Error>> {
    let args: Vec<String> = std::env::args().collect();
    let n_streams: usize = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(4);
    let chunks: usize = args.get(2).and_then(|s| s.parse().ok()).unwrap_or(16);
    let k = 8;

    let ctx = ContextBuilder::new().only_artifacts(["nn_dist"]).build()?;

    // Host data: (lat, lng) records + the query target.
    let total = chunks * CHUNK;
    let records = gen_f32(total * 2, 0xA11CE);
    let host = Arc::new(bytes::from_f32(&records));
    let target = [0.25f32, -0.5];

    // Device buffers: target broadcast + one in/out pair per task.
    let tgt = DevRegion::whole(ctx.alloc(8)?, 8);
    let tasks: Vec<(DevRegion, DevRegion)> = (0..chunks)
        .map(|_| {
            Ok::<_, hetstream::Error>((
                DevRegion::whole(ctx.alloc(CHUNK * 8)?, CHUNK * 8),
                DevRegion::whole(ctx.alloc(CHUNK * 4)?, CHUNK * 4),
            ))
        })
        .collect::<Result<_, _>>()?;
    let dst = hetstream::hstreams::host_dst(total * 4);

    let mut streams: Vec<_> = (0..n_streams).map(|_| ctx.stream()).collect();

    // Broadcast the target on stream 0; others wait for it.
    let tgt_done = streams[0].h2d(HostSrc::whole(Arc::new(bytes::from_f32(&target))), tgt);
    for s in streams.iter_mut().skip(1) {
        s.wait_event(tgt_done.clone());
    }

    // Fig. 6: independent chunks round-robin over the streams; the DMA of
    // chunk i+1 overlaps the distance kernel of chunk i.
    for r in chunk_ranges(total, chunks) {
        let s = &mut streams[r.index % n_streams];
        let (rec_buf, dist_buf) = tasks[r.index];
        s.h2d(HostSrc { data: host.clone(), off: r.start * 8, len: r.len * 8 }, rec_buf);
        s.kex_with("nn_dist", vec![rec_buf, tgt], vec![dist_buf], Some(650_000), 1);
        s.d2h(dist_buf, HostDst { data: dst.data.clone(), off: r.start * 4 });
    }
    for s in &streams {
        s.sync();
    }
    // Modeled pipeline makespan (virtual timeline under the default
    // TimeMode::Virtual; measured span under wallclock mode).
    let wall = hetstream::hstreams::makespan(streams.iter().flat_map(|s| s.events()));

    // Host-side k-NN selection over the streamed distances.
    let dists = bytes::to_f32(&dst.data.lock().unwrap());
    let mut idx: Vec<usize> = (0..dists.len()).collect();
    idx.sort_by(|&a, &b| dists[a].partial_cmp(&dists[b]).unwrap());

    println!(
        "streamed {total} records over {n_streams} streams in {:.2} ms",
        wall.as_secs_f64() * 1e3
    );
    println!("{k} nearest neighbors to ({}, {}):", target[0], target[1]);
    for &i in idx.iter().take(k) {
        println!(
            "  record {i:7}  (lat {:+.4}, lng {:+.4})  dist {:.5}",
            records[2 * i],
            records[2 * i + 1],
            dists[i]
        );
    }
    Ok(())
}
