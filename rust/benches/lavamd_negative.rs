//! Bench E7 / §5: the lavaMD negative case — halo ≈ task size, so the
//! streamed port transfers ~1.9x the bytes and loses to the bulk
//! offload (paper: 0.3476 + 0.3380 s single vs 0.7242 s streamed).
//!
//! `cargo bench --bench lavamd_negative`

use hetstream::experiments::lavamd_negative;
use hetstream::hstreams::ContextBuilder;

fn main() {
    let ctx = ContextBuilder::new().only_artifacts(["lavamd_box"]).build().expect("context");
    let table = lavamd_negative(&ctx, 1, 4, 5).expect("lavamd");
    println!("{}", table.markdown());
    println!("KEY SHAPE — paper: streamed lavaMD is *slower* than the bulk offload");
}
