//! Bench E6 / Fig. 9: single vs multi-stream wall-clock for the 13
//! streamed benchmarks (the paper's headline result: 8%–90% improvement,
//! lavaMD negative).
//!
//! `cargo bench --bench fig9_streams`
//! Env: FIG9_SCALE (default 1), FIG9_STREAMS (4), FIG9_RUNS (5).

use hetstream::experiments::fig9;
use hetstream::hstreams::ContextBuilder;

fn main() {
    let scale = std::env::var("FIG9_SCALE").ok().and_then(|s| s.parse().ok()).unwrap_or(1);
    let streams = std::env::var("FIG9_STREAMS").ok().and_then(|s| s.parse().ok()).unwrap_or(4);
    let runs = std::env::var("FIG9_RUNS").ok().and_then(|s| s.parse().ok()).unwrap_or(5);

    let ctx = ContextBuilder::new().build().expect("context");
    let t0 = std::time::Instant::now();
    let (table, rows) = fig9(&ctx, scale, streams, runs).expect("fig9");
    println!("{}", table.markdown());
    assert!(rows.iter().all(|r| r.validated), "all benchmarks must validate");

    let positive = rows.iter().filter(|r| r.improvement_pct > 5.0).count();
    let lavamd = rows.iter().find(|r| r.name == "lavaMD").unwrap();
    println!(
        "suite in {:.1} s — {} of {} benchmarks gain >5%;",
        t0.elapsed().as_secs_f64(),
        positive,
        rows.len()
    );
    println!(
        "KEY SHAPE — paper: gains 8..90%, nn highest among independents, lavaMD negative \
         (here {:+.1}%, h2d ratio {:.2}x vs paper ~1.9x)",
        lavamd.improvement_pct,
        lavamd.h2d_streamed as f64 / lavamd.h2d_baseline as f64
    );
}
