//! Ablation A1: streaming gain vs number of streams (nn, fwt, nw).
//!
//! The paper defers "how many streams" to future work; this ablation
//! shows the saturation: gains flatten once the busiest engine lane is
//! fully hidden (usually 2–4 streams on a single-DMA-lane platform).
//!
//! `cargo bench --bench ablation_nstreams`

use hetstream::experiments::fig9::measure_one;
use hetstream::hstreams::ContextBuilder;
use hetstream::metrics::Table;
use hetstream::workloads::{Benchmark, Fwt, NeedlemanWunsch, Nn};

fn main() {
    let ctx = ContextBuilder::new()
        .only_artifacts(["nn_dist", "fwt", "nw_tile"])
        .build()
        .expect("context");

    let mut t = Table::new(
        "A1 — improvement vs stream count",
        &["benchmark", "1 stream", "2", "4", "8", "16"],
    );
    let benches: Vec<Box<dyn Benchmark>> = vec![
        Box::new(Nn::new(1)),
        Box::new(Fwt::new(1)),
        Box::new(NeedlemanWunsch::new(1)),
    ];
    for b in &benches {
        let mut cells = vec![b.name().to_string()];
        for streams in [1usize, 2, 4, 8, 16] {
            let row = measure_one(&ctx, b.as_ref(), streams, 3).expect("measure");
            assert!(row.validated, "{} must validate", b.name());
            cells.push(format!("{:+.1}%", row.improvement_pct));
        }
        t.row(&cells);
    }
    println!("{}", t.markdown());
    println!("KEY SHAPE — gains saturate once the bottleneck lane is hidden; 1 stream ≈ baseline");
}
