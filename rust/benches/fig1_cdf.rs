//! Bench E1 / Fig. 1: CDF of R_H2D and R_D2H over the 223-config corpus.
//!
//! Regenerates the paper's headline statistic — "H2D takes less than 10%
//! of end-to-end time for more than 50% of configurations; ~70% for D2H"
//! — both analytically (all 223 configs) and through the DMA/compute
//! engines (stratified sample, 11-run medians).
//!
//! `cargo bench --bench fig1_cdf`

use hetstream::analysis::fraction_at_or_below;
use hetstream::device::DeviceProfile;
use hetstream::experiments::{fig1_analytic, fig1_engine};
use hetstream::hstreams::ContextBuilder;

fn main() {
    let profile = DeviceProfile::mic31sp();

    let t0 = std::time::Instant::now();
    let (table, rows) = fig1_analytic(&profile);
    println!("{}", table.markdown());
    let h2d: Vec<f64> = rows.iter().map(|r| r.r_h2d).collect();
    let d2h: Vec<f64> = rows.iter().map(|r| r.r_d2h).collect();
    println!(
        "analytic sweep: {} configs in {:.1} ms",
        rows.len(),
        t0.elapsed().as_secs_f64() * 1e3
    );
    println!(
        "KEY SHAPE — paper: CDF(0.1) > 50% (H2D), ~70% (D2H); measured: {:.1}% / {:.1}%\n",
        100.0 * fraction_at_or_below(&h2d, 0.1),
        100.0 * fraction_at_or_below(&d2h, 0.1),
    );

    // Engine path (the §3.3 protocol on the simulated platform).
    let sample = std::env::var("FIG1_SAMPLE").ok().and_then(|s| s.parse().ok()).unwrap_or(24);
    let runs = std::env::var("FIG1_RUNS").ok().and_then(|s| s.parse().ok()).unwrap_or(11);
    let ctx = ContextBuilder::new().only_artifacts(["burner_64"]).build().expect("context");
    let t0 = std::time::Instant::now();
    let (etable, erows) = fig1_engine(&ctx, runs, Some(sample));
    println!("{}", etable.markdown());
    println!(
        "engine sample: {} configs x {} runs in {:.1} s",
        erows.len(),
        runs,
        t0.elapsed().as_secs_f64()
    );
}
