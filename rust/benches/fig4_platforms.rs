//! Bench E4 / Fig. 4: platform divergence — nn's stage balance on the
//! MIC profile vs a K80-like profile.  Expected shape: KEX ≈ 33% on MIC
//! vs ≈ 2% on the GPU ("unnecessary to use multiple streams on GPU").
//!
//! `cargo bench --bench fig4_platforms`

use hetstream::analysis::decide;
use hetstream::corpus::configs_for;
use hetstream::device::DeviceProfile;
use hetstream::experiments::{analytic_stage_times, fig4};

fn main() {
    println!("{}", fig4().markdown());

    // The §3.4 decision rule on both platforms.
    let mic = DeviceProfile::mic31sp();
    let k80 = DeviceProfile::k80();
    for cfg in configs_for("nn") {
        let m = analytic_stage_times(&cfg, &mic);
        let k = analytic_stage_times(&cfg, &k80);
        println!(
            "nn {:9}  MIC: R={:.2} {:?}   K80: R={:.2} {:?}",
            cfg.config,
            m.r_h2d(),
            decide(m.r_h2d()),
            k.r_h2d(),
            decide(k.r_h2d()),
        );
    }
    println!("KEY SHAPE — paper: MIC KEX ~33% vs GPU ~2%; streaming unnecessary on the GPU");
}
