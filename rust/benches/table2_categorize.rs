//! Bench E5 / Table 2: regenerate the application categorization from
//! the corpus dependency facts, and report category counts.
//!
//! `cargo bench --bench table2_categorize`

use hetstream::analysis::Category;
use hetstream::corpus::apps;
use hetstream::experiments::table2;

fn main() {
    println!("{}", table2().markdown());

    let all = apps();
    let count = |c: Category| all.iter().filter(|(_, _, cat)| *cat == c).count();
    println!("56 benchmarks:");
    for c in [
        Category::Independent,
        Category::FalseDependent,
        Category::TrueDependent,
        Category::Sync,
        Category::Iterative,
    ] {
        println!("  {:16} {}", c.label(), count(c));
    }
    let streamable: usize = all.iter().filter(|(_, _, c)| c.streamable()).count();
    println!("  streamable       {streamable} / {}", all.len());
    println!(
        "KEY SHAPE — paper: two non-streamable patterns (SYNC, Iterative), three streamable \
         categories; exemplars nn/FWT/NW as Independent/False/True"
    );
}
