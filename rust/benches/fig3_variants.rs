//! Bench E3 / Fig. 3: R across code variants — Reduction v1 (full
//! device-side reduce, scalar D2H) vs v2 (partial sums to host).
//! Expected shape: v2 has the larger R_D2H at every size.
//!
//! `cargo bench --bench fig3_variants`

use hetstream::device::DeviceProfile;
use hetstream::experiments::fig3;
use hetstream::hstreams::ContextBuilder;
use hetstream::workloads::{Benchmark, Mode, ReductionV1, ReductionV2};

fn main() {
    let profile = DeviceProfile::mic31sp();
    println!("{}", fig3(None, &profile, 11).markdown());

    let ctx = ContextBuilder::new().only_artifacts(["burner_64"]).build().expect("context");
    println!("{}", fig3(Some(&ctx), &profile, 11).markdown());
    drop(ctx);

    // The variants also run end-to-end with their real kernels: both must
    // produce the same sum while moving very different D2H payloads.
    let ctx = ContextBuilder::new()
        .only_artifacts(["reduction_v1", "reduction_v2"])
        .build()
        .expect("context");
    for (name, b) in [
        ("v1", Box::new(ReductionV1::new(1)) as Box<dyn Benchmark>),
        ("v2", Box::new(ReductionV2::new(1)) as Box<dyn Benchmark>),
    ] {
        b.run(&ctx, Mode::Baseline).unwrap(); // warmup
        let r = b.run(&ctx, Mode::Baseline).unwrap();
        println!(
            "Reduction {name}: wall {:.2} ms, D2H {} B, validated {}",
            r.wall.as_secs_f64() * 1e3,
            r.d2h_bytes,
            r.validated
        );
    }
    println!("KEY SHAPE — paper: variant choice changes transfer requirements (v2 D2H >> v1)");
}
