//! L3 hot-path microbenchmarks (§Perf): per-op overheads of the
//! coordinator itself — these must stay far below the DMA pacing
//! quantum or the runtime, not the modeled device, becomes the
//! bottleneck.
//!
//! `cargo bench --bench hotpath_micro`

use std::sync::Arc;
use std::time::Instant;

use hetstream::device::{DeviceProfile, DevRegion, HostSrc};
use hetstream::hstreams::ContextBuilder;
use hetstream::runtime::{bytes, ArtifactStore};

fn bench<F: FnMut()>(name: &str, iters: usize, mut f: F) -> f64 {
    // warmup
    for _ in 0..iters.min(32) {
        f();
    }
    let t0 = Instant::now();
    for _ in 0..iters {
        f();
    }
    let ns = t0.elapsed().as_nanos() as f64 / iters as f64;
    println!("{name:42} {ns:12.0} ns/op");
    ns
}

fn main() {
    // --- arena ops ---
    let mut arena = hetstream::device::DeviceArena::new(1 << 28);
    bench("arena: alloc+free 64KiB", 10_000, || {
        let id = arena.alloc(65536).unwrap();
        arena.free(id).unwrap();
    });
    let id = arena.alloc(1 << 20).unwrap();
    let payload = vec![7u8; 65536];
    bench("arena: write 64KiB", 10_000, || {
        arena.write(DevRegion { buf: id, off: 0, len: 65536 }, &payload).unwrap();
    });
    bench("arena: read 64KiB", 10_000, || {
        let _ = arena.read(DevRegion { buf: id, off: 0, len: 65536 }).unwrap();
    });

    // --- byte conversions (driver-side marshalling) ---
    let v = vec![1.0f32; 65536];
    bench("bytes: from_f32 64Ki elems", 2_000, || {
        let _ = bytes::from_f32(&v);
    });
    let b = bytes::from_f32(&v);
    bench("bytes: to_f32 64Ki elems", 2_000, || {
        let _ = bytes::to_f32(&b);
    });

    // --- enqueue + event path on an instant (no pacing) device ---
    let ctx = ContextBuilder::new()
        .profile(DeviceProfile::instant())
        .only_artifacts(["vector_add"])
        .build()
        .expect("context");
    let dev = DevRegion::whole(ctx.alloc(65536 * 4).unwrap(), 65536 * 4);
    let host = Arc::new(bytes::from_f32(&v));
    let ns = bench("stream: h2d enqueue->retire 256KiB (instant)", 2_000, || {
        let mut s = ctx.stream();
        s.h2d(HostSrc::whole(host.clone()), dev);
        s.sync();
    });
    println!(
        "  -> h2d overhead vs mic31sp-sim DMA quantum (~1 ms): {:.2}%",
        ns / 1e7 * 100.0 // quantum ≈ 10^7 ns after dilation
    );

    let dev_b = DevRegion::whole(ctx.alloc(65536 * 4).unwrap(), 65536 * 4);
    let dev_o = DevRegion::whole(ctx.alloc(65536 * 4).unwrap(), 65536 * 4);
    bench("stream: kex enqueue->retire vector_add 64Ki", 200, || {
        let mut s = ctx.stream();
        s.kex("vector_add", vec![dev, dev_b], vec![dev_o]);
        s.sync();
    });

    // --- raw kernel-backend execute (the real KEX floor) ---
    let store = ArtifactStore::load_subset(&hetstream::artifacts_dir(), &["vector_add"]).unwrap();
    let raw = vec![0u8; 65536 * 4];
    let label = format!("{}: execute_bytes vector_add 64Ki", store.platform());
    bench(&label, 200, || {
        let _ = store.execute_bytes("vector_add", &[&raw, &raw]).unwrap();
    });

    // --- manifest parse (startup path) ---
    let text = std::fs::read_to_string(hetstream::artifacts_dir().join("manifest.json"))
        .unwrap_or_else(|_| hetstream::runtime::builtin_manifest_json().to_string());
    bench("manifest: parse", 2_000, || {
        let _ = hetstream::runtime::Manifest::parse(&text).unwrap();
    });
}
