//! Bench E2 / Fig. 2: R vs input dataset — lbm (short/long) and FDTD3d
//! (timestep count).  Expected shape: lbm-short transfer-heavy vs
//! lbm-long compute-heavy; FDTD3d's R falls as timesteps rise.
//!
//! `cargo bench --bench fig2_inputs`

use hetstream::device::DeviceProfile;
use hetstream::experiments::fig2;
use hetstream::hstreams::ContextBuilder;

fn main() {
    let profile = DeviceProfile::mic31sp();
    println!("{}", fig2(None, &profile, 11).markdown());

    // Engine confirmation (11-run medians through the simulator).
    let ctx = ContextBuilder::new().only_artifacts(["burner_64"]).build().expect("context");
    let t0 = std::time::Instant::now();
    println!("{}", fig2(Some(&ctx), &profile, 11).markdown());
    println!("engine pass in {:.1} s", t0.elapsed().as_secs_f64());
    println!("KEY SHAPE — paper: R(lbm short) >> R(lbm long); R(FDTD3d) decreases with steps");
}
