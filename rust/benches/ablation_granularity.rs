//! Ablation A2: streaming gain vs task granularity (nn).
//!
//! The paper's future work ("proper task granularity"): too few tasks
//! can't fill the pipeline; too many pay per-task DMA latency.  This
//! sweep exposes both ends.
//!
//! `cargo bench --bench ablation_granularity`

use hetstream::experiments::fig9::measure_one;
use hetstream::hstreams::ContextBuilder;
use hetstream::metrics::Table;
use hetstream::workloads::Nn;

fn main() {
    let ctx = ContextBuilder::new().only_artifacts(["nn_dist"]).build().expect("context");

    let mut t = Table::new(
        "A2 — nn: improvement vs task count (4 streams)",
        &["tasks (x8 chunks)", "baseline (ms)", "streamed (ms)", "improvement"],
    );
    // Nn::new(scale) gives 8*scale chunks of 16384 records each.
    for scale in [1usize, 2, 4, 8] {
        let b = Nn::new(scale);
        let row = measure_one(&ctx, &b, 4, 3).expect("measure");
        assert!(row.validated);
        t.row(&[
            format!("{}", 8 * scale),
            format!("{:.2}", row.baseline_ms),
            format!("{:.2}", row.streamed_ms),
            format!("{:+.1}%", row.improvement_pct),
        ]);
    }
    println!("{}", t.markdown());
    println!("KEY SHAPE — more tasks amortize pipeline fill/drain until DMA latency dominates");
}
