//! Ablation A3: how R (and hence streaming necessity) shifts with link
//! bandwidth — the "platform divergence" of Fig. 4 swept continuously.
//!
//! `cargo bench --bench ablation_bandwidth`

use hetstream::analysis::fraction_at_or_below;
use hetstream::corpus::all_configs;
use hetstream::device::DeviceProfile;
use hetstream::experiments::analytic_stage_times;
use hetstream::metrics::Table;

fn main() {
    let mut t = Table::new(
        "A3 — corpus CDF(R_H2D <= 0.1) vs PCIe bandwidth",
        &["link GB/s", "CDF(0.1)", "CDF(0.3)", "median R_H2D", "worthwhile (0.1<R<0.9)"],
    );
    for bw in [2.0, 4.0, 6.0, 12.0, 24.0] {
        let mut p = DeviceProfile::mic31sp();
        p.h2d_gbps = bw;
        p.d2h_gbps = bw * 1.08;
        let mut rs: Vec<f64> =
            all_configs().iter().map(|c| analytic_stage_times(c, &p).r_h2d()).collect();
        rs.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let worthwhile = rs.iter().filter(|&&r| (0.1..=0.9).contains(&r)).count();
        t.row(&[
            format!("{bw:.0}"),
            format!("{:.1}%", 100.0 * fraction_at_or_below(&rs, 0.1)),
            format!("{:.1}%", 100.0 * fraction_at_or_below(&rs, 0.3)),
            format!("{:.3}", rs[rs.len() / 2]),
            format!("{worthwhile}/223"),
        ]);
    }
    println!("{}", t.markdown());
    println!("KEY SHAPE — faster links shrink R: fewer codes are worth streaming (Fig. 4 logic)");
}
