//! Native-backend throughput bench (DESIGN.md §Native performance):
//! real host execution of the mixed-category serve roster through one
//! `NativeBackend` per pool width, so the arena pool, the atomic
//! ready-queue scheduler, and the vectorized simkern kernels are all
//! on the measured path.  Reports plans/s per width and the scaling
//! ratio against width 1.
//!
//! `cargo bench --bench native_backend`            full width sweep
//! `cargo bench --bench native_backend -- --smoke` CI: one pass, no
//!                                                 timing gate

use std::hint::black_box;
use std::time::Instant;

use hetstream::device::DeviceProfile;
use hetstream::experiments::demo_roster;
use hetstream::plan::{
    lower_corpus_streamed_at, Backend, Granularity, NativeBackend, RunConfig, CORPUS_BURNER,
};
use hetstream::service::{AnalyticPolicy, TunePolicy};

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke");
    let ncores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4);

    // The serve/bench roster, tuned by the same analytic policy the
    // service consults — every Table-2 plan shape is in the mix.
    let profile = DeviceProfile::mic31sp().simulation();
    let plans: Vec<_> = demo_roster(8)
        .iter()
        .map(|c| {
            let choice = AnalyticPolicy.choose(c, &profile);
            lower_corpus_streamed_at(c, CORPUS_BURNER, Granularity::new(choice.gran))
        })
        .collect();

    // Pool widths 1, 2, 4, ... up to every host core (smoke: just the
    // two endpoints — CI proves the harness runs, not the numbers).
    let mut widths = vec![1usize];
    let mut w = 2;
    while w < ncores {
        widths.push(w);
        w *= 2;
    }
    if ncores > 1 {
        widths.push(ncores);
    }
    if smoke {
        widths = vec![1, ncores];
    }
    widths.dedup();

    let passes = if smoke { 1 } else { 5 };
    println!(
        "native backend: {} roster plans x {passes} pass(es), {ncores} host core(s)",
        plans.len()
    );
    let mut base = f64::NAN;
    for &width in &widths {
        // One backend per width: the first (warmup) pass populates the
        // arena pool; every measured run reuses its storage.
        let backend = NativeBackend::new();
        for p in &plans {
            black_box(&backend.run(p, RunConfig::streams(width)).expect("warmup run").outputs);
        }
        let t0 = Instant::now();
        for _ in 0..passes {
            for p in &plans {
                let run = backend.run(p, RunConfig::streams(width)).expect("native run");
                black_box(&run.outputs);
            }
        }
        let secs = t0.elapsed().as_secs_f64();
        let total = (passes * plans.len()) as f64;
        let rate = total / secs;
        if width == 1 {
            base = rate;
        }
        println!(
            "pool width {width:3}: {rate:8.1} plans/s ({:6.2} ms/plan, {:.2}x vs width 1)",
            1e3 * secs / total,
            rate / base,
        );
    }
}
