//! Run configuration: JSON-loadable settings for the launcher
//! (`repro --config run.json`).  Parsed with the in-crate JSON parser —
//! the build is fully offline.

use crate::device::DeviceProfile;
use crate::util::json::Json;
use crate::{Error, Result};

/// Top-level run configuration.
#[derive(Debug, Clone)]
pub struct RunConfig {
    pub device: DeviceSection,
    pub measure: MeasureSection,
    pub streaming: StreamingSection,
    pub artifacts_dir: Option<String>,
}

/// Device profile selection + overrides.
#[derive(Debug, Clone)]
pub struct DeviceSection {
    /// Preset: mic31sp | k80 | instant | slow-link.
    pub preset: String,
    pub h2d_gbps: Option<f64>,
    pub d2h_gbps: Option<f64>,
    pub latency_us: Option<f64>,
    pub gflops: Option<f64>,
    pub compute_workers: usize,
    pub device_mem_mb: usize,
}

/// Measurement protocol (paper §3.3: 11 runs, median).
#[derive(Debug, Clone)]
pub struct MeasureSection {
    pub runs: usize,
    pub warmup: usize,
}

/// Streaming defaults.
#[derive(Debug, Clone)]
pub struct StreamingSection {
    pub streams: usize,
    pub chunks: usize,
}

impl Default for DeviceSection {
    fn default() -> Self {
        Self {
            preset: "mic31sp".into(),
            h2d_gbps: None,
            d2h_gbps: None,
            latency_us: None,
            gflops: None,
            compute_workers: 1,
            device_mem_mb: 2048,
        }
    }
}

impl Default for MeasureSection {
    fn default() -> Self {
        Self { runs: 11, warmup: 1 }
    }
}

impl Default for StreamingSection {
    fn default() -> Self {
        Self { streams: 4, chunks: 8 }
    }
}

impl Default for RunConfig {
    fn default() -> Self {
        Self {
            device: DeviceSection::default(),
            measure: MeasureSection::default(),
            streaming: StreamingSection::default(),
            artifacts_dir: None,
        }
    }
}

impl RunConfig {
    /// Load from a JSON file.
    pub fn load(path: &str) -> Result<Self> {
        let text = std::fs::read_to_string(path)?;
        Self::parse(&text)
    }

    /// Parse from JSON text.  Missing sections/fields keep defaults.
    pub fn parse(text: &str) -> Result<Self> {
        let j = Json::parse(text).map_err(|e| Error::Config(e.to_string()))?;
        let mut cfg = RunConfig::default();
        if let Some(d) = j.get("device") {
            if let Some(p) = d.get("preset").and_then(Json::as_str) {
                cfg.device.preset = p.to_string();
            }
            cfg.device.h2d_gbps = d.get("h2d_gbps").and_then(Json::as_f64);
            cfg.device.d2h_gbps = d.get("d2h_gbps").and_then(Json::as_f64);
            cfg.device.latency_us = d.get("latency_us").and_then(Json::as_f64);
            cfg.device.gflops = d.get("gflops").and_then(Json::as_f64);
            if let Some(w) = d.get("compute_workers").and_then(Json::as_usize) {
                cfg.device.compute_workers = w;
            }
            if let Some(m) = d.get("device_mem_mb").and_then(Json::as_usize) {
                cfg.device.device_mem_mb = m;
            }
        }
        if let Some(m) = j.get("measure") {
            if let Some(r) = m.get("runs").and_then(Json::as_usize) {
                cfg.measure.runs = r;
            }
            if let Some(w) = m.get("warmup").and_then(Json::as_usize) {
                cfg.measure.warmup = w;
            }
        }
        if let Some(s) = j.get("streaming") {
            if let Some(n) = s.get("streams").and_then(Json::as_usize) {
                cfg.streaming.streams = n;
            }
            if let Some(c) = s.get("chunks").and_then(Json::as_usize) {
                cfg.streaming.chunks = c;
            }
        }
        cfg.artifacts_dir = j.get("artifacts_dir").and_then(Json::as_str).map(String::from);
        Ok(cfg)
    }

    /// Resolve the device profile (preset + overrides).
    pub fn device_profile(&self) -> Result<DeviceProfile> {
        let mut p = DeviceProfile::preset(&self.device.preset).ok_or_else(|| {
            Error::Config(format!("unknown device preset `{}`", self.device.preset))
        })?;
        if let Some(v) = self.device.h2d_gbps {
            p.h2d_gbps = v;
        }
        if let Some(v) = self.device.d2h_gbps {
            p.d2h_gbps = v;
        }
        if let Some(v) = self.device.latency_us {
            p.latency_us = v;
        }
        if let Some(v) = self.device.gflops {
            p.gflops = v;
        }
        Ok(p)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_config_resolves() {
        let c = RunConfig::default();
        assert_eq!(c.measure.runs, 11, "paper protocol");
        assert_eq!(c.device_profile().unwrap().name, "mic31sp");
    }

    #[test]
    fn json_overrides() {
        let c = RunConfig::parse(
            r#"{"device": {"preset": "k80", "gflops": 123.0, "compute_workers": 2},
                "measure": {"runs": 5}}"#,
        )
        .unwrap();
        let p = c.device_profile().unwrap();
        assert_eq!(p.name, "k80");
        assert_eq!(p.gflops, 123.0);
        assert_eq!(c.measure.runs, 5);
        assert_eq!(c.device.compute_workers, 2);
        // untouched sections keep defaults
        assert_eq!(c.streaming.streams, 4);
    }

    #[test]
    fn unknown_preset_rejected() {
        let c = RunConfig::parse(r#"{"device": {"preset": "tpu-v9"}}"#).unwrap();
        assert!(c.device_profile().is_err());
    }
}
