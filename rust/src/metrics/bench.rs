//! The load-harness report: per-second time series + totals, emitted
//! as the `BENCH_<timestamp>.json` artifact (DESIGN.md §Bench).
//!
//! Every PR's service numbers land in one of these files, so the
//! schema is versioned ([`BENCH_SCHEMA`]) and validated both here (the
//! round-trip unit test below) and offline by
//! `tools/bench_schema.py` — a bench emitted by any commit must stay
//! comparable with every other commit's.

use crate::util::json::escape;
use crate::util::percentile;

/// Schema tag stamped into every bench JSON (bump on shape changes;
/// `tools/bench_schema.py` validates against it).  v2 added
/// `config.backend` (`"sim"` / `"native"`) — on native the latency
/// numbers are real host execution, so cross-commit comparisons must
/// never mix backends.  v3 added the adaptive runtime: per-tick
/// `mode`/`lanes`/`batches` series, `config.adaptive` +
/// `config.max_lanes`, and the `totals.adaptive` counter block.
pub const BENCH_SCHEMA: &str = "hetstream-bench-v3";

/// One reporter tick: everything that *completed or was shed* during
/// second `t_s` of the run, with latency statistics over the tick's
/// completions.
#[derive(Debug, Clone, Default)]
pub struct BenchTick {
    /// Tick index: events with completion time in `[t_s, t_s + 1)` s.
    pub t_s: u64,
    pub completed: u64,
    /// Admission sheds (over-budget / deadline-infeasible).
    pub rejected: u64,
    /// Submissions that resolved with an error report.
    pub errors: u64,
    /// Completions per second over this tick (= `completed`, ticks are
    /// one second wide).
    pub throughput_rps: f64,
    /// End-to-end latency stats over the tick's completions, ms
    /// (NaN when the tick completed nothing).
    pub lat_avg_ms: f64,
    pub lat_p50_ms: f64,
    pub lat_p99_ms: f64,
    /// Mean admission-queue wait over the tick's completions, ms.
    pub queue_avg_ms: f64,
    /// Lane wakeup mode in force during the tick (`"park"`/`"spin"`;
    /// always `"park"` when the adaptive runtime is off).
    pub mode: String,
    /// Lane target at the end of the tick (the fixed `--lanes` when
    /// adaptive is off).
    pub lanes: u64,
    /// Coalesced (multi-ticket) runs completed during the tick.
    pub batches: u64,
}

/// Per-tenant lifetime totals.
#[derive(Debug, Clone)]
pub struct TenantTotals {
    pub tenant: String,
    pub completed: u64,
    /// Admission sheds charged to this tenant.
    pub shed: u64,
    pub errors: u64,
    /// p99 end-to-end latency over the tenant's completions, ms.
    pub p99_ms: f64,
}

/// The whole bench outcome: configuration echo, per-tick series, and
/// aggregate totals.  [`bench_json`] is the canonical serialization.
#[derive(Debug, Clone)]
pub struct BenchReport {
    pub tenants: usize,
    /// Target per-tenant submission rate, req/s.
    pub rate: f64,
    /// Submission-window length, s.
    pub secs: f64,
    pub open_loop: bool,
    pub lanes: usize,
    /// Whether the adaptive runtime (`--adaptive`) drove this run.
    pub adaptive: bool,
    /// Lane-elasticity cap (`--max-lanes`; equals `lanes` when the
    /// adaptive runtime is off).
    pub max_lanes: usize,
    pub profile: String,
    pub time_mode: String,
    /// Lane execution backend label (`"sim"` / `"native"`).
    pub backend: String,
    pub ticks: Vec<BenchTick>,
    pub per_tenant: Vec<TenantTotals>,
    pub completed: u64,
    pub rejected: u64,
    pub errors: u64,
    /// Wall duration from first submission to last completion, s.
    pub duration_s: f64,
    pub throughput_rps: f64,
    pub lat_avg_ms: f64,
    pub lat_p50_ms: f64,
    pub lat_p99_ms: f64,
    pub queue_avg_ms: f64,
    /// Sum of modeled makespans across completions, ms — the modeled
    /// work the service actually executed.
    pub modeled_total_ms: f64,
    pub cache_hits: u64,
    pub cache_misses: u64,
    /// Coalesced (multi-ticket) backend runs over the whole run.
    pub batches: u64,
    /// Tickets those coalesced runs served.
    pub batched_jobs: u64,
    /// Lanes spawned beyond the initial fleet.
    pub lane_grows: u64,
    /// Lanes that quiesced and retired.
    pub lane_retires: u64,
    /// Wakeup-mode flips (park ↔ spin).
    pub wakeup_switches: u64,
    /// Largest live-lane count the service reached.
    pub peak_lanes: u64,
}

/// Latency aggregates of a completion sample (avg + nearest-rank
/// p50/p99 via [`percentile`]); all-NaN on an empty sample.
pub(crate) fn latency_stats(lat_ms: &[f64]) -> (f64, f64, f64) {
    let finite: Vec<f64> = lat_ms.iter().copied().filter(|v| v.is_finite()).collect();
    if finite.is_empty() {
        return (f64::NAN, f64::NAN, f64::NAN);
    }
    let avg = finite.iter().sum::<f64>() / finite.len() as f64;
    (avg, percentile(&finite, 50.0), percentile(&finite, 99.0))
}

/// Serialize a report as the versioned `BENCH_*.json` document.  JSON
/// has no NaN: unknown metrics (e.g. p99 of a tick that completed
/// nothing) serialize as `null`.
pub fn bench_json(r: &BenchReport) -> String {
    let num = |v: f64| if v.is_finite() { format!("{v:.6}") } else { "null".into() };
    let mut s = format!(
        "{{\"schema\":\"{}\",\"config\":{{\"tenants\":{},\"rate\":{},\"secs\":{},\
         \"open_loop\":{},\"lanes\":{},\"adaptive\":{},\"max_lanes\":{},\
         \"profile\":\"{}\",\"time_mode\":\"{}\",\"backend\":\"{}\"}},\
         \"totals\":{{\"completed\":{},\"rejected\":{},\"errors\":{},\"duration_s\":{},\
         \"throughput_rps\":{},\"latency_ms\":{{\"avg\":{},\"p50\":{},\"p99\":{}}},\
         \"queue_wait_avg_ms\":{},\"modeled_total_ms\":{},\
         \"cache\":{{\"hits\":{},\"misses\":{}}},\
         \"adaptive\":{{\"batches\":{},\"batched_jobs\":{},\"grows\":{},\"retires\":{},\
         \"wakeup_switches\":{},\"peak_lanes\":{}}}}},\"per_tenant\":[",
        BENCH_SCHEMA,
        r.tenants,
        num(r.rate),
        num(r.secs),
        r.open_loop,
        r.lanes,
        r.adaptive,
        r.max_lanes,
        escape(&r.profile),
        escape(&r.time_mode),
        escape(&r.backend),
        r.completed,
        r.rejected,
        r.errors,
        num(r.duration_s),
        num(r.throughput_rps),
        num(r.lat_avg_ms),
        num(r.lat_p50_ms),
        num(r.lat_p99_ms),
        num(r.queue_avg_ms),
        num(r.modeled_total_ms),
        r.cache_hits,
        r.cache_misses,
        r.batches,
        r.batched_jobs,
        r.lane_grows,
        r.lane_retires,
        r.wakeup_switches,
        r.peak_lanes,
    );
    for (i, t) in r.per_tenant.iter().enumerate() {
        if i > 0 {
            s.push(',');
        }
        s.push_str(&format!(
            "{{\"tenant\":\"{}\",\"completed\":{},\"shed\":{},\"errors\":{},\"p99_ms\":{}}}",
            escape(&t.tenant),
            t.completed,
            t.shed,
            t.errors,
            num(t.p99_ms),
        ));
    }
    s.push_str("],\"ticks\":[");
    for (i, t) in r.ticks.iter().enumerate() {
        if i > 0 {
            s.push(',');
        }
        s.push_str(&format!(
            "{{\"t_s\":{},\"completed\":{},\"rejected\":{},\"errors\":{},\
             \"throughput_rps\":{},\"lat_avg_ms\":{},\"lat_p50_ms\":{},\"lat_p99_ms\":{},\
             \"queue_avg_ms\":{},\"mode\":\"{}\",\"lanes\":{},\"batches\":{}}}",
            t.t_s,
            t.completed,
            t.rejected,
            t.errors,
            num(t.throughput_rps),
            num(t.lat_avg_ms),
            num(t.lat_p50_ms),
            num(t.lat_p99_ms),
            num(t.queue_avg_ms),
            escape(&t.mode),
            t.lanes,
            t.batches,
        ));
    }
    s.push_str("]}");
    s
}

/// The default artifact path: `BENCH_<unix-seconds>.json` in the
/// working directory — a fresh, sortable file per run so the perf
/// trajectory accumulates instead of overwriting itself.
pub fn default_bench_path() -> String {
    let secs = std::time::SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .map(|d| d.as_secs())
        .unwrap_or(0);
    format!("BENCH_{secs}.json")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json::Json;

    fn sample_report() -> BenchReport {
        BenchReport {
            tenants: 2,
            rate: 10.0,
            secs: 2.0,
            open_loop: true,
            lanes: 4,
            adaptive: true,
            max_lanes: 8,
            profile: "mic31sp-sim".into(),
            time_mode: "virtual".into(),
            backend: "sim".into(),
            ticks: vec![
                BenchTick {
                    t_s: 0,
                    completed: 3,
                    rejected: 1,
                    errors: 0,
                    throughput_rps: 3.0,
                    lat_avg_ms: 4.5,
                    lat_p50_ms: 4.0,
                    lat_p99_ms: 7.0,
                    queue_avg_ms: 0.5,
                    mode: "spin".into(),
                    lanes: 6,
                    batches: 2,
                },
                // A tick that completed nothing: NaN stats → null.
                BenchTick {
                    t_s: 1,
                    lat_avg_ms: f64::NAN,
                    mode: "park".into(),
                    lanes: 4,
                    ..Default::default()
                },
            ],
            per_tenant: vec![
                TenantTotals {
                    tenant: "t-0".into(),
                    completed: 3,
                    shed: 1,
                    errors: 0,
                    p99_ms: 7.0,
                },
                TenantTotals {
                    tenant: "t-1".into(),
                    completed: 0,
                    shed: 0,
                    errors: 0,
                    p99_ms: f64::NAN,
                },
            ],
            completed: 3,
            rejected: 1,
            errors: 0,
            duration_s: 2.0,
            throughput_rps: 1.5,
            lat_avg_ms: 4.5,
            lat_p50_ms: 4.0,
            lat_p99_ms: 7.0,
            queue_avg_ms: 0.5,
            modeled_total_ms: 42.0,
            cache_hits: 2,
            cache_misses: 1,
            batches: 2,
            batched_jobs: 5,
            lane_grows: 2,
            lane_retires: 1,
            wakeup_switches: 2,
            peak_lanes: 6,
        }
    }

    #[test]
    fn bench_json_round_trips_through_the_crate_parser() {
        let doc = Json::parse(&bench_json(&sample_report())).expect("valid JSON");
        assert_eq!(doc.get("schema").and_then(Json::as_str), Some(BENCH_SCHEMA));
        let cfg = doc.get("config").expect("config");
        assert_eq!(cfg.get("tenants").and_then(Json::as_usize), Some(2));
        assert_eq!(cfg.get("open_loop").and_then(Json::as_bool), Some(true));
        assert_eq!(cfg.get("backend").and_then(Json::as_str), Some("sim"));
        assert_eq!(cfg.get("adaptive").and_then(Json::as_bool), Some(true));
        assert_eq!(cfg.get("max_lanes").and_then(Json::as_usize), Some(8));
        let totals = doc.get("totals").expect("totals");
        assert_eq!(totals.get("completed").and_then(Json::as_u64), Some(3));
        let lat = totals.get("latency_ms").expect("latency");
        assert_eq!(lat.get("p99").and_then(Json::as_f64), Some(7.0));
        let adaptive = totals.get("adaptive").expect("adaptive totals");
        assert_eq!(adaptive.get("batches").and_then(Json::as_u64), Some(2));
        assert_eq!(adaptive.get("grows").and_then(Json::as_u64), Some(2));
        assert_eq!(adaptive.get("peak_lanes").and_then(Json::as_u64), Some(6));
        let ticks = doc.get("ticks").and_then(Json::as_arr).expect("ticks");
        assert_eq!(ticks.len(), 2);
        assert_eq!(ticks[0].get("t_s").and_then(Json::as_u64), Some(0));
        assert_eq!(ticks[0].get("mode").and_then(Json::as_str), Some("spin"));
        assert_eq!(ticks[0].get("lanes").and_then(Json::as_u64), Some(6));
        assert_eq!(ticks[0].get("batches").and_then(Json::as_u64), Some(2));
        assert_eq!(ticks[1].get("mode").and_then(Json::as_str), Some("park"));
        // The empty tick's NaN stats must be null, not a bare NaN token
        // (which would fail any standards JSON parser).
        assert!(matches!(ticks[1].get("lat_avg_ms"), Some(Json::Null)));
        let tenants = doc.get("per_tenant").and_then(Json::as_arr).expect("per_tenant");
        assert_eq!(tenants[0].get("shed").and_then(Json::as_u64), Some(1));
        assert!(matches!(tenants[1].get("p99_ms"), Some(Json::Null)));
    }

    #[test]
    fn latency_stats_guard_empty_samples() {
        let (avg, p50, p99) = latency_stats(&[]);
        assert!(avg.is_nan() && p50.is_nan() && p99.is_nan());
        let (avg, p50, p99) = latency_stats(&[2.0, 4.0, f64::NAN]);
        assert_eq!(avg, 3.0);
        assert_eq!(p50, 2.0);
        assert_eq!(p99, 4.0);
    }

    #[test]
    fn default_bench_path_is_timestamped_json() {
        let p = default_bench_path();
        assert!(p.starts_with("BENCH_") && p.ends_with(".json"), "{p}");
    }
}
