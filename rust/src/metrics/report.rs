//! Table emission: the benches print the same rows/series the paper's
//! tables and figures report, in markdown and CSV.

/// A simple column-ordered table.
#[derive(Debug, Clone, Default)]
pub struct Table {
    pub title: String,
    pub headers: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: impl Into<String>, headers: &[&str]) -> Self {
        Self {
            title: title.into(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row<S: ToString>(&mut self, cells: &[S]) {
        assert_eq!(cells.len(), self.headers.len(), "row arity mismatch");
        self.rows.push(cells.iter().map(|c| c.to_string()).collect());
    }

    pub fn markdown(&self) -> String {
        markdown_table(&self.title, &self.headers, &self.rows)
    }

    pub fn csv(&self) -> String {
        csv_table(&self.headers, &self.rows)
    }
}

/// Render a markdown table.
pub fn markdown_table(title: &str, headers: &[String], rows: &[Vec<String>]) -> String {
    let mut out = String::new();
    if !title.is_empty() {
        out.push_str(&format!("### {title}\n\n"));
    }
    out.push_str(&format!("| {} |\n", headers.join(" | ")));
    out.push_str(&format!("|{}\n", "---|".repeat(headers.len())));
    for row in rows {
        out.push_str(&format!("| {} |\n", row.join(" | ")));
    }
    out
}

/// Render a CSV table.
pub fn csv_table(headers: &[String], rows: &[Vec<String>]) -> String {
    let mut out = headers.join(",");
    out.push('\n');
    for row in rows {
        out.push_str(&row.join(","));
        out.push('\n');
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_renders() {
        let mut t = Table::new("demo", &["a", "b"]);
        t.row(&["1", "2"]);
        let md = t.markdown();
        assert!(md.contains("| a | b |"));
        assert!(md.contains("| 1 | 2 |"));
        let csv = t.csv();
        assert!(csv.starts_with("a,b\n"));
    }

    #[test]
    #[should_panic(expected = "arity")]
    fn row_arity_checked() {
        let mut t = Table::new("demo", &["a", "b"]);
        t.row(&["1"]);
    }
}
