//! Robust statistics helpers.

use std::time::Duration;

/// Median of a float sample (sorts in place).
pub fn median(xs: &mut [f64]) -> f64 {
    assert!(!xs.is_empty(), "median of empty sample");
    xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let n = xs.len();
    if n % 2 == 1 {
        xs[n / 2]
    } else {
        0.5 * (xs[n / 2 - 1] + xs[n / 2])
    }
}

/// Median of durations (sorts in place).
pub fn median_duration(xs: &mut [Duration]) -> Duration {
    assert!(!xs.is_empty());
    xs.sort();
    let n = xs.len();
    if n % 2 == 1 {
        xs[n / 2]
    } else {
        (xs[n / 2 - 1] + xs[n / 2]) / 2
    }
}

/// Empirical quantile (linear interpolation) of a sorted sample.
pub fn quantile(sorted: &[f64], q: f64) -> f64 {
    assert!(!sorted.is_empty());
    let q = q.clamp(0.0, 1.0);
    let pos = q * (sorted.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    if lo == hi {
        sorted[lo]
    } else {
        sorted[lo] + (sorted[hi] - sorted[lo]) * (pos - lo as f64)
    }
}

/// Summary statistics of a sample.
#[derive(Debug, Clone, Copy)]
pub struct Stats {
    pub n: usize,
    pub min: f64,
    pub median: f64,
    pub mean: f64,
    pub p95: f64,
    pub max: f64,
}

impl Stats {
    pub fn of(xs: &[f64]) -> Self {
        assert!(!xs.is_empty());
        let mut sorted = xs.to_vec();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let mean = sorted.iter().sum::<f64>() / sorted.len() as f64;
        Self {
            n: sorted.len(),
            min: sorted[0],
            median: quantile(&sorted, 0.5),
            mean,
            p95: quantile(&sorted, 0.95),
            max: sorted[sorted.len() - 1],
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn median_odd_even() {
        assert_eq!(median(&mut [3.0, 1.0, 2.0]), 2.0);
        assert_eq!(median(&mut [4.0, 1.0, 2.0, 3.0]), 2.5);
    }

    #[test]
    fn quantile_endpoints() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(quantile(&xs, 0.0), 1.0);
        assert_eq!(quantile(&xs, 1.0), 4.0);
        assert_eq!(quantile(&xs, 0.5), 2.5);
    }

    #[test]
    fn stats_of_sample() {
        let s = Stats::of(&[1.0, 2.0, 3.0, 4.0, 100.0]);
        assert_eq!(s.n, 5);
        assert_eq!(s.median, 3.0);
        assert_eq!(s.max, 100.0);
        assert!(s.mean > s.median, "outlier pulls the mean, not the median");
    }
}
