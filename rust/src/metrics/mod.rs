//! Timing, robust statistics (the paper's median-of-11 protocol),
//! report emission, timeline visualization ([`trace_svg`]), and the
//! load-harness report schema ([`bench`]).

mod bench;
mod report;
mod stats;
mod viz;

pub use bench::{
    bench_json, default_bench_path, BenchReport, BenchTick, TenantTotals, BENCH_SCHEMA,
};
pub(crate) use bench::latency_stats;
pub use report::{csv_table, markdown_table, Table};
pub use stats::{median, median_duration, quantile, Stats};
pub use viz::trace_svg;

use std::time::{Duration, Instant};

/// Wall-clock timer for a measured region.
pub struct Timer(Instant);

impl Timer {
    pub fn start() -> Self {
        Self(Instant::now())
    }

    pub fn elapsed(&self) -> Duration {
        self.0.elapsed()
    }
}

/// Run `f` `runs` times and return the median duration — the paper's
/// measurement protocol (§3.3: "we perform 11 runs and calculate the
/// median value").
pub fn median_of_runs<F: FnMut()>(runs: usize, mut f: F) -> Duration {
    let mut samples = Vec::with_capacity(runs);
    for _ in 0..runs {
        let t = Timer::start();
        f();
        samples.push(t.elapsed());
    }
    median_duration(&mut samples)
}
