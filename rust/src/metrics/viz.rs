//! Timeline visualization: render a recorded op trace
//! ([`crate::device::TraceEntry`]) as a per-lane SVG Gantt chart — the
//! PR-1 follow-up that turns the golden-trace JSON into something a
//! human can read.  `repro trace NAME --svg` uses this directly;
//! `tools/trace_viz.py` renders the same layout from a trace JSON file
//! offline.
//!
//! Layout: one row per modeled resource lane (`h2d`, `kex<N>`…,
//! `d2h`), time left-to-right with a µs/ms axis, one rectangle per
//! retired op colored by kind, with a `<title>` tooltip carrying the
//! op's stream / label / bytes / FLOPs / interval.  The output is a
//! deterministic standalone SVG string (stable ordering, no
//! randomness) so it can be golden-tested.

use crate::device::{OpKind, TraceEntry};

/// Chart geometry (pixels).
const CHART_W: f64 = 1000.0;
const MARGIN_L: f64 = 90.0;
const MARGIN_T: f64 = 40.0;
const ROW_H: f64 = 28.0;
const BAR_H: f64 = 18.0;
const AXIS_TICKS: usize = 6;

fn kind_color(kind: OpKind) -> &'static str {
    match kind {
        OpKind::H2d => "#4c78a8",
        OpKind::Kex => "#f58518",
        OpKind::D2h => "#54a24a",
    }
}

/// Minimal XML text escaping for labels and tooltips.
fn xml_escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '&' => out.push_str("&amp;"),
            '<' => out.push_str("&lt;"),
            '>' => out.push_str("&gt;"),
            '"' => out.push_str("&quot;"),
            _ => out.push(c),
        }
    }
    out
}

/// Lane display order: H2D DMA first, then the kernel queues in
/// numeric order (`kex2` before `kex10` — a lexicographic sort would
/// misplace queues once a context has ten or more workers), then the
/// D2H DMA, then anything else.
fn lane_rank(lane: &str) -> (u8, u64, String) {
    if lane == "h2d" {
        return (0, 0, String::new());
    }
    if lane == "d2h" {
        return (2, 0, String::new());
    }
    if let Some(n) = lane.strip_prefix("kex").and_then(|s| s.parse::<u64>().ok()) {
        return (1, n, String::new());
    }
    (3, 0, lane.to_string())
}

/// Render `entries` (any order; sorted internally) as a standalone
/// per-lane Gantt SVG.  An empty trace renders an explanatory stub
/// rather than erroring — "no events" is a fine thing to look at.
pub fn trace_svg(entries: &[TraceEntry]) -> String {
    let mut lanes: Vec<String> = Vec::new();
    for e in entries {
        if !lanes.iter().any(|l| *l == e.lane) {
            lanes.push(e.lane.clone());
        }
    }
    lanes.sort_by_key(|l| lane_rank(l));

    let t0 = entries.iter().map(|e| e.start.as_nanos()).min().unwrap_or(0);
    let t1 = entries.iter().map(|e| e.end.as_nanos()).max().unwrap_or(0);
    let span = (t1 - t0).max(1) as f64;
    let height = MARGIN_T + ROW_H * lanes.len().max(1) as f64 + 30.0;
    let width = MARGIN_L + CHART_W + 20.0;
    let x = |ns: u64| MARGIN_L + (ns - t0) as f64 / span * CHART_W;

    let mut s = String::new();
    s.push_str(&format!(
        "<svg xmlns=\"http://www.w3.org/2000/svg\" width=\"{width:.0}\" height=\"{height:.0}\" \
         viewBox=\"0 0 {width:.0} {height:.0}\" font-family=\"monospace\" font-size=\"11\">\n"
    ));
    s.push_str(&format!(
        "<text x=\"{MARGIN_L}\" y=\"16\" font-size=\"13\">hetstream timeline — {} events, \
         {:.3} ms</text>\n",
        entries.len(),
        (t1 - t0) as f64 / 1e6
    ));
    if entries.is_empty() {
        s.push_str("<text x=\"90\" y=\"60\">(no events recorded — was tracing enabled?)</text>\n");
        s.push_str("</svg>\n");
        return s;
    }

    // Axis ticks + gridlines (µs below 10 ms spans, ms above).
    let grid_bottom = MARGIN_T + ROW_H * lanes.len() as f64;
    for k in 0..=AXIS_TICKS {
        let ns = t0 + ((t1 - t0) as f64 * k as f64 / AXIS_TICKS as f64) as u64;
        let gx = x(ns);
        let label = if (t1 - t0) < 10_000_000 {
            format!("{:.1}µs", (ns - t0) as f64 / 1e3)
        } else {
            format!("{:.2}ms", (ns - t0) as f64 / 1e6)
        };
        s.push_str(&format!(
            "<line x1=\"{gx:.1}\" y1=\"{MARGIN_T}\" x2=\"{gx:.1}\" y2=\"{grid_bottom}\" \
             stroke=\"#ddd\"/>\n"
        ));
        s.push_str(&format!(
            "<text x=\"{gx:.1}\" y=\"{:.1}\" text-anchor=\"middle\" fill=\"#555\">{label}</text>\n",
            grid_bottom + 14.0
        ));
    }

    // Lane labels + op rectangles.
    for (row, lane) in lanes.iter().enumerate() {
        let y = MARGIN_T + ROW_H * row as f64;
        s.push_str(&format!(
            "<text x=\"{:.1}\" y=\"{:.1}\" text-anchor=\"end\" fill=\"#333\">{}</text>\n",
            MARGIN_L - 8.0,
            y + BAR_H - 4.0,
            xml_escape(lane)
        ));
        for e in entries.iter().filter(|e| e.lane == *lane) {
            let (x0, x1) = (x(e.start.as_nanos()), x(e.end.as_nanos()));
            let w = (x1 - x0).max(0.5);
            let tip = format!(
                "seq {} {} stream {}{}{}{} [{} .. {}] ns",
                e.seq,
                e.kind.label(),
                e.stream,
                if e.label.is_empty() { String::new() } else { format!(" {}", e.label) },
                if e.bytes > 0 { format!(" {} B", e.bytes) } else { String::new() },
                if e.flops > 0 { format!(" {} flop", e.flops) } else { String::new() },
                e.start.as_nanos(),
                e.end.as_nanos(),
            );
            s.push_str(&format!(
                "<rect x=\"{x0:.2}\" y=\"{y:.1}\" width=\"{w:.2}\" height=\"{BAR_H}\" \
                 fill=\"{}\" stroke=\"#333\" stroke-width=\"0.4\" opacity=\"0.9\">\
                 <title>{}</title></rect>\n",
                kind_color(e.kind),
                xml_escape(&tip)
            ));
        }
    }
    s.push_str("</svg>\n");
    s
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::SimTime;

    fn entry(seq: u64, kind: OpKind, lane: &str, start: u64, end: u64) -> TraceEntry {
        TraceEntry {
            seq,
            kind,
            lane: lane.into(),
            stream: seq % 2,
            label: if kind == OpKind::Kex { "vector_add".into() } else { String::new() },
            bytes: if kind == OpKind::Kex { 0 } else { 1024 },
            flops: if kind == OpKind::Kex { 1000 } else { 0 },
            start: SimTime::from_nanos(start),
            end: SimTime::from_nanos(end),
        }
    }

    #[test]
    fn svg_has_one_rect_per_event_and_ordered_lanes() {
        let entries = vec![
            entry(0, OpKind::H2d, "h2d", 0, 100),
            entry(1, OpKind::Kex, "kex0", 100, 300),
            entry(2, OpKind::D2h, "d2h", 300, 350),
            entry(3, OpKind::H2d, "h2d", 100, 200),
            entry(4, OpKind::Kex, "kex10", 100, 150),
            entry(5, OpKind::Kex, "kex2", 150, 250),
        ];
        let svg = trace_svg(&entries);
        assert!(svg.starts_with("<svg "), "standalone svg root");
        assert!(svg.trim_end().ends_with("</svg>"));
        assert_eq!(svg.matches("<rect ").count(), entries.len());
        // h2d row renders before the kernel queues (numerically
        // ordered: kex2 before kex10), and d2h last.
        let (h, k0, k2, k10, d) = (
            svg.find(">h2d</text>").expect("h2d lane label"),
            svg.find(">kex0</text>").expect("kex0 lane label"),
            svg.find(">kex2</text>").expect("kex2 lane label"),
            svg.find(">kex10</text>").expect("kex10 lane label"),
            svg.find(">d2h</text>").expect("d2h lane label"),
        );
        assert!(h < k0 && k0 < k2 && k2 < k10 && k10 < d, "lane order h2d < kex… < d2h");
        assert!(svg.contains("vector_add"), "kex tooltip carries the artifact");
    }

    #[test]
    fn empty_trace_renders_a_stub() {
        let svg = trace_svg(&[]);
        assert!(svg.contains("no events"));
        assert!(!svg.contains("<rect "));
    }

    #[test]
    fn labels_are_xml_escaped() {
        assert_eq!(xml_escape("a<b>&\"c\""), "a&lt;b&gt;&amp;&quot;c&quot;");
        let mut e = entry(0, OpKind::Kex, "kex0", 0, 10);
        e.label = "k<&>".into();
        let svg = trace_svg(&[e]);
        assert!(svg.contains("k&lt;&amp;&gt;"));
        assert!(!svg.contains("k<&>"));
    }
}
