//! `artifacts/manifest.json` schema — the contract between `aot.py` and
//! the Rust runtime.  Parsed with the in-crate JSON parser
//! ([`crate::util::json`]); no external dependencies.

use std::path::Path;

use crate::util::json::Json;
use crate::{Error, Result};

/// Element type of an artifact input/output.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DType {
    F32,
    I32,
}

impl DType {
    /// Size of one element in bytes.
    pub fn size(self) -> usize {
        4
    }

    fn from_name(s: &str) -> Option<Self> {
        match s {
            "f32" => Some(DType::F32),
            "i32" => Some(DType::I32),
            _ => None,
        }
    }
}

/// Shape + dtype of one input or output.
#[derive(Debug, Clone)]
pub struct IoSpec {
    pub shape: Vec<usize>,
    pub dtype: DType,
}

impl IoSpec {
    /// Number of elements.
    pub fn elements(&self) -> usize {
        self.shape.iter().product::<usize>().max(1)
    }

    /// Payload size in bytes.
    pub fn bytes(&self) -> usize {
        self.elements() * self.dtype.size()
    }

    fn from_json(j: &Json) -> Result<Self> {
        let shape = j
            .get("shape")
            .and_then(Json::as_arr)
            .ok_or_else(|| Error::Manifest("io spec missing shape".into()))?
            .iter()
            .map(|v| v.as_usize().ok_or_else(|| Error::Manifest("bad shape entry".into())))
            .collect::<Result<Vec<_>>>()?;
        let dtype = j
            .get("dtype")
            .and_then(Json::as_str)
            .and_then(DType::from_name)
            .ok_or_else(|| Error::Manifest("io spec missing/unknown dtype".into()))?;
        Ok(IoSpec { shape, dtype })
    }
}

/// One AOT-compiled kernel variant.
#[derive(Debug, Clone)]
pub struct ArtifactMeta {
    pub name: String,
    pub file: String,
    pub inputs: Vec<IoSpec>,
    pub outputs: Vec<IoSpec>,
    /// Analytic FLOP estimate for one call (drives KEX pacing).
    pub flops_per_call: u64,
}

/// The whole manifest.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub format: String,
    pub artifacts: Vec<ArtifactMeta>,
}

impl Manifest {
    /// Load and validate `manifest.json` from an artifacts directory.
    ///
    /// When the directory carries no manifest (no `make artifacts` run —
    /// e.g. a PJRT-less checkout driving the pure-Rust sim backend), the
    /// compiled-in [`builtin_manifest_json`] is used instead: the same
    /// shapes and FLOP estimates `aot.py` would emit.
    pub fn load(dir: &Path) -> Result<Self> {
        let path = dir.join("manifest.json");
        if !path.exists() {
            return Self::parse(builtin_manifest_json());
        }
        let text = std::fs::read_to_string(&path)
            .map_err(|e| Error::Manifest(format!("read {}: {e}", path.display())))?;
        let m = Self::parse(&text)?;
        for a in &m.artifacts {
            if !dir.join(&a.file).exists() {
                return Err(Error::Manifest(format!("missing artifact file {}", a.file)));
            }
        }
        Ok(m)
    }

    /// Parse manifest JSON text (no filesystem checks).
    pub fn parse(text: &str) -> Result<Self> {
        let j = Json::parse(text).map_err(|e| Error::Manifest(e.to_string()))?;
        let format = j
            .get("format")
            .and_then(Json::as_str)
            .ok_or_else(|| Error::Manifest("missing format".into()))?
            .to_string();
        if format != "hlo-text/v1" {
            return Err(Error::Manifest(format!(
                "unsupported manifest format `{format}` (want hlo-text/v1)"
            )));
        }
        let artifacts = j
            .get("artifacts")
            .and_then(Json::as_arr)
            .ok_or_else(|| Error::Manifest("missing artifacts array".into()))?
            .iter()
            .map(|a| {
                let name = a
                    .get("name")
                    .and_then(Json::as_str)
                    .ok_or_else(|| Error::Manifest("artifact missing name".into()))?
                    .to_string();
                let file = a
                    .get("file")
                    .and_then(Json::as_str)
                    .ok_or_else(|| Error::Manifest(format!("artifact {name} missing file")))?
                    .to_string();
                let io = |key: &str| -> Result<Vec<IoSpec>> {
                    a.get(key)
                        .and_then(Json::as_arr)
                        .ok_or_else(|| Error::Manifest(format!("artifact {name} missing {key}")))?
                        .iter()
                        .map(IoSpec::from_json)
                        .collect()
                };
                Ok(ArtifactMeta {
                    inputs: io("inputs")?,
                    outputs: io("outputs")?,
                    flops_per_call: a
                        .get("flops_per_call")
                        .and_then(Json::as_u64)
                        .unwrap_or(0),
                    name,
                    file,
                })
            })
            .collect::<Result<Vec<_>>>()?;
        Ok(Manifest { format, artifacts })
    }

    /// Look up an artifact by name.
    pub fn get(&self, name: &str) -> Option<&ArtifactMeta> {
        self.artifacts.iter().find(|a| a.name == name)
    }
}

/// The manifest `python/compile/aot.py` emits, compiled in: shapes and
/// per-call FLOP estimates for every artifact, mirroring `_spec_list()`.
/// Keeps the sim backend (and every test/bench) runnable without the
/// Python AOT step; `file` entries are never opened on the sim path.
pub fn builtin_manifest_json() -> &'static str {
    r#"{
  "format": "hlo-text/v1",
  "artifacts": [
    {"name": "nn_dist", "file": "nn_dist.hlo.txt",
     "inputs": [{"shape": [16384, 2], "dtype": "f32"}, {"shape": [2], "dtype": "f32"}],
     "outputs": [{"shape": [16384], "dtype": "f32"}], "flops_per_call": 98304},
    {"name": "vector_add", "file": "vector_add.hlo.txt",
     "inputs": [{"shape": [65536], "dtype": "f32"}, {"shape": [65536], "dtype": "f32"}],
     "outputs": [{"shape": [65536], "dtype": "f32"}], "flops_per_call": 65536},
    {"name": "transpose", "file": "transpose.hlo.txt",
     "inputs": [{"shape": [128, 1024], "dtype": "f32"}],
     "outputs": [{"shape": [1024, 128], "dtype": "f32"}], "flops_per_call": 131072},
    {"name": "matmul", "file": "matmul.hlo.txt",
     "inputs": [{"shape": [128, 256], "dtype": "f32"}, {"shape": [256, 256], "dtype": "f32"}],
     "outputs": [{"shape": [128, 256], "dtype": "f32"}], "flops_per_call": 16777216},
    {"name": "prefix_sum", "file": "prefix_sum.hlo.txt",
     "inputs": [{"shape": [16384], "dtype": "f32"}],
     "outputs": [{"shape": [16384], "dtype": "f32"}, {"shape": [1], "dtype": "f32"}],
     "flops_per_call": 16384},
    {"name": "histogram", "file": "histogram.hlo.txt",
     "inputs": [{"shape": [16384], "dtype": "i32"}],
     "outputs": [{"shape": [256], "dtype": "i32"}], "flops_per_call": 32768},
    {"name": "black_scholes", "file": "black_scholes.hlo.txt",
     "inputs": [{"shape": [16384], "dtype": "f32"}, {"shape": [16384], "dtype": "f32"},
                {"shape": [16384], "dtype": "f32"}],
     "outputs": [{"shape": [16384], "dtype": "f32"}, {"shape": [16384], "dtype": "f32"}],
     "flops_per_call": 983040},
    {"name": "dct8x8", "file": "dct8x8.hlo.txt",
     "inputs": [{"shape": [64, 512], "dtype": "f32"}, {"shape": [8, 8], "dtype": "f32"}],
     "outputs": [{"shape": [64, 512], "dtype": "f32"}], "flops_per_call": 1048576},
    {"name": "dot_product", "file": "dot_product.hlo.txt",
     "inputs": [{"shape": [65536], "dtype": "f32"}, {"shape": [65536], "dtype": "f32"}],
     "outputs": [{"shape": [1], "dtype": "f32"}], "flops_per_call": 131072},
    {"name": "hotspot_step", "file": "hotspot_step.hlo.txt",
     "inputs": [{"shape": [128, 128], "dtype": "f32"}, {"shape": [128, 128], "dtype": "f32"}],
     "outputs": [{"shape": [128, 128], "dtype": "f32"}], "flops_per_call": 131072},
    {"name": "fwt", "file": "fwt.hlo.txt",
     "inputs": [{"shape": [4096], "dtype": "f32"}],
     "outputs": [{"shape": [4096], "dtype": "f32"}], "flops_per_call": 98304},
    {"name": "conv_sep", "file": "conv_sep.hlo.txt",
     "inputs": [{"shape": [144, 256], "dtype": "f32"}, {"shape": [17], "dtype": "f32"},
                {"shape": [17], "dtype": "f32"}],
     "outputs": [{"shape": [128, 256], "dtype": "f32"}], "flops_per_call": 2228224},
    {"name": "stencil2d", "file": "stencil2d.hlo.txt",
     "inputs": [{"shape": [130, 512], "dtype": "f32"}],
     "outputs": [{"shape": [128, 512], "dtype": "f32"}], "flops_per_call": 393216},
    {"name": "lavamd_box", "file": "lavamd_box.hlo.txt",
     "inputs": [{"shape": [478], "dtype": "f32"}],
     "outputs": [{"shape": [256], "dtype": "f32"}], "flops_per_call": 285440},
    {"name": "cfft2d", "file": "cfft2d.hlo.txt",
     "inputs": [{"shape": [128, 128], "dtype": "f32"}, {"shape": [128, 128], "dtype": "f32"}],
     "outputs": [{"shape": [128, 128], "dtype": "f32"}], "flops_per_call": 3440640},
    {"name": "nw_tile", "file": "nw_tile.hlo.txt",
     "inputs": [{"shape": [32], "dtype": "i32"}, {"shape": [32], "dtype": "i32"},
                {"shape": [1], "dtype": "i32"}, {"shape": [32, 32], "dtype": "i32"}],
     "outputs": [{"shape": [32, 32], "dtype": "i32"}, {"shape": [32], "dtype": "i32"},
                 {"shape": [32], "dtype": "i32"}],
     "flops_per_call": 5120},
    {"name": "reduction_v1", "file": "reduction_v1.hlo.txt",
     "inputs": [{"shape": [65536], "dtype": "f32"}],
     "outputs": [{"shape": [1], "dtype": "f32"}], "flops_per_call": 65536},
    {"name": "reduction_v2", "file": "reduction_v2.hlo.txt",
     "inputs": [{"shape": [65536], "dtype": "f32"}],
     "outputs": [{"shape": [256], "dtype": "f32"}], "flops_per_call": 65536},
    {"name": "burner_8", "file": "burner_8.hlo.txt",
     "inputs": [{"shape": [65536], "dtype": "f32"}],
     "outputs": [{"shape": [65536], "dtype": "f32"}], "flops_per_call": 1048576},
    {"name": "burner_64", "file": "burner_64.hlo.txt",
     "inputs": [{"shape": [65536], "dtype": "f32"}],
     "outputs": [{"shape": [65536], "dtype": "f32"}], "flops_per_call": 8388608},
    {"name": "burner_512", "file": "burner_512.hlo.txt",
     "inputs": [{"shape": [65536], "dtype": "f32"}],
     "outputs": [{"shape": [65536], "dtype": "f32"}], "flops_per_call": 67108864}
  ]
}"#
}

#[cfg(test)]
mod tests {
    use super::*;

    const DOC: &str = r#"{
        "format": "hlo-text/v1",
        "artifacts": [{
            "name": "vector_add",
            "file": "vector_add.hlo.txt",
            "inputs": [{"shape": [65536], "dtype": "f32"}, {"shape": [65536], "dtype": "f32"}],
            "outputs": [{"shape": [65536], "dtype": "f32"}],
            "flops_per_call": 65536
        }]
    }"#;

    #[test]
    fn parses_manifest() {
        let m = Manifest::parse(DOC).unwrap();
        assert_eq!(m.artifacts.len(), 1);
        let a = m.get("vector_add").unwrap();
        assert_eq!(a.inputs.len(), 2);
        assert_eq!(a.inputs[0].bytes(), 65536 * 4);
        assert_eq!(a.outputs[0].elements(), 65536);
        assert_eq!(a.flops_per_call, 65536);
    }

    #[test]
    fn rejects_wrong_format() {
        let doc = DOC.replace("hlo-text/v1", "hlo-proto/v0");
        assert!(Manifest::parse(&doc).is_err());
    }

    #[test]
    fn scalar_shape_has_one_element() {
        let spec = IoSpec { shape: vec![], dtype: DType::F32 };
        assert_eq!(spec.elements(), 1);
        assert_eq!(spec.bytes(), 4);
    }

    #[test]
    fn builtin_manifest_parses_and_is_complete() {
        let m = Manifest::parse(builtin_manifest_json()).unwrap();
        assert!(m.artifacts.len() >= 18, "full artifact set, got {}", m.artifacts.len());
        for a in &m.artifacts {
            assert!(!a.inputs.is_empty(), "{} inputs", a.name);
            assert!(!a.outputs.is_empty(), "{} outputs", a.name);
            assert!(a.flops_per_call > 0, "{} flops", a.name);
        }
        // Spot-check a shape against the aot.py spec list.
        let nw = m.get("nw_tile").unwrap();
        assert_eq!(nw.inputs.len(), 4);
        assert_eq!(nw.outputs[0].shape, vec![32, 32]);
        assert_eq!(m.get("lavamd_box").unwrap().inputs[0].shape, vec![478]);
    }
}
