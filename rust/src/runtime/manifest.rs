//! `artifacts/manifest.json` schema — the contract between `aot.py` and
//! the Rust runtime.  Parsed with the in-crate JSON parser
//! ([`crate::util::json`]); no external dependencies.

use std::path::Path;

use crate::util::json::Json;
use crate::{Error, Result};

/// Element type of an artifact input/output.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DType {
    F32,
    I32,
}

impl DType {
    /// Size of one element in bytes.
    pub fn size(self) -> usize {
        4
    }

    fn from_name(s: &str) -> Option<Self> {
        match s {
            "f32" => Some(DType::F32),
            "i32" => Some(DType::I32),
            _ => None,
        }
    }
}

/// Shape + dtype of one input or output.
#[derive(Debug, Clone)]
pub struct IoSpec {
    pub shape: Vec<usize>,
    pub dtype: DType,
}

impl IoSpec {
    /// Number of elements.
    pub fn elements(&self) -> usize {
        self.shape.iter().product::<usize>().max(1)
    }

    /// Payload size in bytes.
    pub fn bytes(&self) -> usize {
        self.elements() * self.dtype.size()
    }

    fn from_json(j: &Json) -> Result<Self> {
        let shape = j
            .get("shape")
            .and_then(Json::as_arr)
            .ok_or_else(|| Error::Manifest("io spec missing shape".into()))?
            .iter()
            .map(|v| v.as_usize().ok_or_else(|| Error::Manifest("bad shape entry".into())))
            .collect::<Result<Vec<_>>>()?;
        let dtype = j
            .get("dtype")
            .and_then(Json::as_str)
            .and_then(DType::from_name)
            .ok_or_else(|| Error::Manifest("io spec missing/unknown dtype".into()))?;
        Ok(IoSpec { shape, dtype })
    }
}

/// One AOT-compiled kernel variant.
#[derive(Debug, Clone)]
pub struct ArtifactMeta {
    pub name: String,
    pub file: String,
    pub inputs: Vec<IoSpec>,
    pub outputs: Vec<IoSpec>,
    /// Analytic FLOP estimate for one call (drives KEX pacing).
    pub flops_per_call: u64,
}

/// The whole manifest.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub format: String,
    pub artifacts: Vec<ArtifactMeta>,
}

impl Manifest {
    /// Load and validate `manifest.json` from an artifacts directory.
    pub fn load(dir: &Path) -> Result<Self> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .map_err(|e| Error::Manifest(format!("read {}: {e}", path.display())))?;
        let m = Self::parse(&text)?;
        for a in &m.artifacts {
            if !dir.join(&a.file).exists() {
                return Err(Error::Manifest(format!("missing artifact file {}", a.file)));
            }
        }
        Ok(m)
    }

    /// Parse manifest JSON text (no filesystem checks).
    pub fn parse(text: &str) -> Result<Self> {
        let j = Json::parse(text).map_err(|e| Error::Manifest(e.to_string()))?;
        let format = j
            .get("format")
            .and_then(Json::as_str)
            .ok_or_else(|| Error::Manifest("missing format".into()))?
            .to_string();
        if format != "hlo-text/v1" {
            return Err(Error::Manifest(format!(
                "unsupported manifest format `{format}` (want hlo-text/v1)"
            )));
        }
        let artifacts = j
            .get("artifacts")
            .and_then(Json::as_arr)
            .ok_or_else(|| Error::Manifest("missing artifacts array".into()))?
            .iter()
            .map(|a| {
                let name = a
                    .get("name")
                    .and_then(Json::as_str)
                    .ok_or_else(|| Error::Manifest("artifact missing name".into()))?
                    .to_string();
                let file = a
                    .get("file")
                    .and_then(Json::as_str)
                    .ok_or_else(|| Error::Manifest(format!("artifact {name} missing file")))?
                    .to_string();
                let io = |key: &str| -> Result<Vec<IoSpec>> {
                    a.get(key)
                        .and_then(Json::as_arr)
                        .ok_or_else(|| Error::Manifest(format!("artifact {name} missing {key}")))?
                        .iter()
                        .map(IoSpec::from_json)
                        .collect()
                };
                Ok(ArtifactMeta {
                    inputs: io("inputs")?,
                    outputs: io("outputs")?,
                    flops_per_call: a
                        .get("flops_per_call")
                        .and_then(Json::as_u64)
                        .unwrap_or(0),
                    name,
                    file,
                })
            })
            .collect::<Result<Vec<_>>>()?;
        Ok(Manifest { format, artifacts })
    }

    /// Look up an artifact by name.
    pub fn get(&self, name: &str) -> Option<&ArtifactMeta> {
        self.artifacts.iter().find(|a| a.name == name)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const DOC: &str = r#"{
        "format": "hlo-text/v1",
        "artifacts": [{
            "name": "vector_add",
            "file": "vector_add.hlo.txt",
            "inputs": [{"shape": [65536], "dtype": "f32"}, {"shape": [65536], "dtype": "f32"}],
            "outputs": [{"shape": [65536], "dtype": "f32"}],
            "flops_per_call": 65536
        }]
    }"#;

    #[test]
    fn parses_manifest() {
        let m = Manifest::parse(DOC).unwrap();
        assert_eq!(m.artifacts.len(), 1);
        let a = m.get("vector_add").unwrap();
        assert_eq!(a.inputs.len(), 2);
        assert_eq!(a.inputs[0].bytes(), 65536 * 4);
        assert_eq!(a.outputs[0].elements(), 65536);
        assert_eq!(a.flops_per_call, 65536);
    }

    #[test]
    fn rejects_wrong_format() {
        let doc = DOC.replace("hlo-text/v1", "hlo-proto/v0");
        assert!(Manifest::parse(&doc).is_err());
    }

    #[test]
    fn scalar_shape_has_one_element() {
        let spec = IoSpec { shape: vec![], dtype: DType::F32 };
        assert_eq!(spec.elements(), 1);
        assert_eq!(spec.bytes(), 4);
    }
}
