//! Kernel runtime: load AOT artifact metadata and execute kernels by
//! name.
//!
//! Two interchangeable backends (see [`ArtifactStore`]): the default
//! pure-Rust interpreter ([`simkern`], no external toolchain), and the
//! original XLA/PJRT path under `--features pjrt`.  The PJRT
//! interchange format is HLO **text** produced by
//! `python/compile/aot.py` — not a serialized `HloModuleProto`, because
//! jax ≥ 0.5 emits 64-bit instruction ids that xla_extension 0.5.1
//! rejects; the text parser reassigns ids (see DESIGN.md).
//!
//! All PJRT types wrap raw C pointers and are not `Send`; an
//! [`ArtifactStore`] therefore lives on the thread that created it (the
//! [`crate::device::ComputeEngine`] worker owns one).

mod arena;
mod manifest;
mod simkern;
mod store;

pub use arena::{ArenaLayout, ArenaPool, ARENA_ALIGN};
pub use manifest::{builtin_manifest_json, ArtifactMeta, DType, IoSpec, Manifest};
pub use store::{bytes, elastic_artifact, ArtifactStore};
pub(crate) use store::elastic_scale;
