//! Pooled host arenas for the native backend (DESIGN.md §Native
//! performance).
//!
//! The naive native path allocated and zeroed one `Vec<u8>` per plan
//! buffer per run; at service rates that is megabytes of `memset` per
//! submission before the first op executes.  This module replaces it
//! with a **reused arena**: one contiguous allocation per
//! [`ArenaPool`] slot, checked out at submit and returned at drain,
//! holding every logical buffer of the plan at a 64-byte-aligned
//! offset ([`ArenaLayout`]).
//!
//! Reuse breaks the simulated device's lazy-zero semantics — corpus
//! plans contain *zero-source* buffers that are read without ever
//! being written and rely on fresh storage reading back as zeros.  So
//! the layout carries the plan's **must-zero spans**: the exact byte
//! ranges some op reads that no earlier op wrote.  Checkout clears
//! only those spans; every other byte is overwritten before it is
//! read, so stale contents are unobservable.
//!
//! The span analysis scans ops in plan (topological-submission) order,
//! which is sound because the backend dependency contract orders every
//! conflicting access pair and `deps` point strictly backwards: if a
//! read and a write of the same bytes are both present, their partial
//! order matches their index order.  A read at index `i` therefore
//! observes exactly the writes at indices `< i` — anything else is
//! initial (zero) storage.  The Python mirror re-derives this analysis
//! and replays every corpus lowering over a deliberately dirty arena
//! (`tools/mirror/tuner_mirror.py --arena-check`).

use std::sync::Mutex;

use crate::plan::{PlanOpKind, PlanRegion, StreamPlan};

/// Buffer alignment inside the arena: one cache line, so adjacent
/// buffers never false-share and vector loads start aligned.
pub const ARENA_ALIGN: usize = 64;

/// Arenas kept per pool; runs beyond this allocate fresh and drop at
/// drain (a backend normally runs one plan at a time per lane, so the
/// pool stays at 1-2 slots).
const MAX_POOLED: usize = 4;

/// Where each logical buffer of one plan lives inside an arena, plus
/// the byte spans that must be zeroed before the run (see module docs).
#[derive(Debug, Clone)]
pub struct ArenaLayout {
    /// Arena byte offset of each `StreamPlan::bufs` entry.
    offsets: Vec<usize>,
    /// Total arena bytes (last offset + aligned size).
    total: usize,
    /// Absolute half-open `(start, end)` arena spans read by some op
    /// without a preceding write — cleared at checkout.
    zero_spans: Vec<(usize, usize)>,
}

impl ArenaLayout {
    /// Lay out `plan`'s buffers and compute its must-zero spans.
    pub fn of(plan: &StreamPlan) -> Self {
        let mut offsets = Vec::with_capacity(plan.bufs.len());
        let mut total = 0usize;
        for &b in &plan.bufs {
            offsets.push(total);
            total += b.div_ceil(ARENA_ALIGN) * ARENA_ALIGN;
        }

        // Per-buffer interval bookkeeping in op order: a read byte not
        // covered by an earlier write must come up zero.
        let mut written = vec![IntervalSet::default(); plan.bufs.len()];
        let mut zero = vec![IntervalSet::default(); plan.bufs.len()];
        let mut record_read = |written: &[IntervalSet], zero: &mut [IntervalSet], r: &PlanRegion| {
            for (s, e) in written[r.buf].uncovered(r.off, r.off + r.len) {
                zero[r.buf].insert(s, e);
            }
        };
        for op in &plan.ops {
            match &op.kind {
                PlanOpKind::H2d { dst, .. } => written[dst.buf].insert(dst.off, dst.off + dst.len),
                PlanOpKind::Kex { inputs, outputs, .. } => {
                    for r in inputs {
                        record_read(&written, &mut zero, r);
                    }
                    for r in outputs {
                        written[r.buf].insert(r.off, r.off + r.len);
                    }
                }
                PlanOpKind::D2h { src, .. } => record_read(&written, &mut zero, src),
            }
        }
        let mut zero_spans = Vec::new();
        for (b, set) in zero.iter().enumerate() {
            for &(s, e) in set.spans() {
                zero_spans.push((offsets[b] + s, offsets[b] + e));
            }
        }
        Self { offsets, total, zero_spans }
    }

    /// Arena byte offset of logical buffer `buf`.
    pub fn offset(&self, buf: usize) -> usize {
        self.offsets[buf]
    }

    /// Total arena bytes the layout needs.
    pub fn total(&self) -> usize {
        self.total
    }

    /// The must-zero spans (absolute arena coordinates), for tests.
    pub fn zero_spans(&self) -> &[(usize, usize)] {
        &self.zero_spans
    }

    /// Whether buffer-relative bytes `[lo, hi)` of `buf` are fully
    /// inside the must-zero spans — i.e. guaranteed to read back zero
    /// on a freshly checked-out (possibly reused) arena.  This is the
    /// span-introspection hook `plan::verify` discharges its
    /// arena-soundness obligation through.
    pub fn zero_covers(&self, buf: usize, lo: usize, hi: usize) -> bool {
        let (alo, ahi) = (self.offsets[buf] + lo, self.offsets[buf] + hi);
        let mut cur = alo;
        for &(s, e) in &self.zero_spans {
            if e <= cur {
                continue;
            }
            if s > cur {
                break; // gap at `cur`
            }
            cur = e;
            if cur >= ahi {
                return true;
            }
        }
        cur >= ahi
    }

    /// Replace the must-zero spans wholesale (absolute arena
    /// coordinates).  Test-injection hook for the verifier's
    /// negative controls: shrink a span and
    /// `plan::verify::verify_plan_with_layout` must report the
    /// uncovered read.  Never used on the execution path.
    pub fn with_zero_spans(mut self, spans: Vec<(usize, usize)>) -> Self {
        self.zero_spans = spans;
        self
    }
}

/// A pool of reusable arena storages.  `checkout` hands back a vector
/// of at least `layout.total()` bytes with the layout's must-zero
/// spans cleared and **everything else stale** (bytes from whatever
/// plan ran in the slot before); `checkin` returns it for the next
/// run.  Both ends are a short lock around a `Vec` push/pop — the pool
/// is never held across a run.
#[derive(Debug, Default)]
pub struct ArenaPool {
    slots: Mutex<Vec<Vec<u8>>>,
}

impl ArenaPool {
    pub fn new() -> Self {
        Self::default()
    }

    /// Storage ready for one run under `layout` (see type docs).
    pub fn checkout(&self, layout: &ArenaLayout) -> Vec<u8> {
        let mut storage = match self.slots.lock() {
            Ok(mut s) => s.pop().unwrap_or_default(),
            Err(e) => e.into_inner().pop().unwrap_or_default(),
        };
        if storage.len() < layout.total {
            // Growth zero-fills the new tail; reused bytes stay stale.
            storage.resize(layout.total, 0);
        }
        for &(s, e) in &layout.zero_spans {
            storage[s..e].fill(0);
        }
        storage
    }

    /// Return a storage for reuse (dropped if the pool is full).
    pub fn checkin(&self, storage: Vec<u8>) {
        let mut slots = match self.slots.lock() {
            Ok(s) => s,
            Err(e) => e.into_inner(),
        };
        if slots.len() < MAX_POOLED {
            slots.push(storage);
        }
    }

    /// Pooled storages (for tests).
    pub fn pooled(&self) -> usize {
        match self.slots.lock() {
            Ok(s) => s.len(),
            Err(e) => e.into_inner().len(),
        }
    }
}

/// Sorted, disjoint, half-open byte intervals.
#[derive(Debug, Clone, Default)]
struct IntervalSet(Vec<(usize, usize)>);

impl IntervalSet {
    /// Insert `[s, e)`, merging overlapping and touching intervals.
    fn insert(&mut self, s: usize, e: usize) {
        if s >= e {
            return;
        }
        let mut i = 0;
        while i < self.0.len() && self.0[i].1 < s {
            i += 1;
        }
        let mut j = i;
        let (mut ns, mut ne) = (s, e);
        while j < self.0.len() && self.0[j].0 <= e {
            ns = ns.min(self.0[j].0);
            ne = ne.max(self.0[j].1);
            j += 1;
        }
        self.0.splice(i..j, [(ns, ne)]);
    }

    /// The parts of `[s, e)` not covered by any interval.
    fn uncovered(&self, s: usize, e: usize) -> Vec<(usize, usize)> {
        let mut out = Vec::new();
        let mut cur = s;
        for &(a, b) in &self.0 {
            if b <= cur {
                continue;
            }
            if a >= e {
                break;
            }
            if a > cur {
                out.push((cur, a.min(e)));
            }
            cur = cur.max(b);
            if cur >= e {
                break;
            }
        }
        if cur < e {
            out.push((cur, e));
        }
        out
    }

    fn spans(&self) -> &[(usize, usize)] {
        &self.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::plan::{HostSlice, Slot};
    use std::sync::Arc;

    #[test]
    fn interval_set_merges_and_complements() {
        let mut s = IntervalSet::default();
        s.insert(10, 20);
        s.insert(30, 40);
        assert_eq!(s.spans(), &[(10, 20), (30, 40)]);
        s.insert(20, 30); // touching intervals merge
        assert_eq!(s.spans(), &[(10, 40)]);
        s.insert(5, 12);
        assert_eq!(s.spans(), &[(5, 40)]);
        assert_eq!(s.uncovered(0, 50), vec![(0, 5), (40, 50)]);
        assert_eq!(s.uncovered(10, 30), Vec::<(usize, usize)>::new());
        let empty = IntervalSet::default();
        assert_eq!(empty.uncovered(3, 7), vec![(3, 7)]);
    }

    #[test]
    fn layout_aligns_buffers_and_finds_zero_sources() {
        let mut p = StreamPlan::new("zero-src");
        let written = p.buf(100); // fully written before read
        let zsrc = p.buf(32); // never written: the corpus zero-source shape
        let out = p.output(132);
        let payload = Arc::new(vec![0xAAu8; 100]);
        p.h2d(Slot::Task(0), HostSlice::whole(payload), PlanRegion::whole(written, 100), vec![]);
        p.d2h(Slot::Task(0), PlanRegion::whole(written, 100), out, 0, vec![]);
        p.d2h(Slot::Task(0), PlanRegion::whole(zsrc, 32), out, 100, vec![]);
        let l = ArenaLayout::of(&p);
        assert_eq!(l.offset(0), 0);
        assert_eq!(l.offset(1) % ARENA_ALIGN, 0);
        assert_eq!(l.total() % ARENA_ALIGN, 0);
        // Only the never-written buffer needs zeroing, at its offset.
        assert_eq!(l.zero_spans(), &[(l.offset(1), l.offset(1) + 32)]);
    }

    #[test]
    fn read_before_write_counts_as_zero_source() {
        // A valid plan may read bytes and only write them later (the
        // read legitimately observes initial zeros); those bytes must
        // be in the must-zero set even though a write exists.
        let mut p = StreamPlan::new("rbw");
        let b = p.buf(64);
        let out = p.output(64);
        p.d2h(Slot::Task(0), PlanRegion::whole(b, 64), out, 0, vec![]);
        let payload = Arc::new(vec![1u8; 64]);
        p.h2d(Slot::Task(0), HostSlice::whole(payload), PlanRegion::whole(b, 64), vec![0]);
        let l = ArenaLayout::of(&p);
        assert_eq!(l.zero_spans(), &[(0, 64)]);
    }

    #[test]
    fn partial_writes_leave_only_the_gap_to_zero() {
        let mut p = StreamPlan::new("gap");
        let b = p.buf(96);
        let out = p.output(96);
        let payload = Arc::new(vec![7u8; 32]);
        p.h2d(
            Slot::Task(0),
            HostSlice::whole(payload),
            PlanRegion { buf: b, off: 0, len: 32 },
            vec![],
        );
        p.d2h(Slot::Task(0), PlanRegion::whole(b, 96), out, 0, vec![]);
        let l = ArenaLayout::of(&p);
        assert_eq!(l.zero_spans(), &[(32, 96)]);
    }

    #[test]
    fn pool_reuses_storage_and_clears_spans() {
        let mut p = StreamPlan::new("pool");
        let b = p.buf(64);
        let out = p.output(64);
        p.d2h(Slot::Task(0), PlanRegion::whole(b, 64), out, 0, vec![]);
        let layout = ArenaLayout::of(&p);

        let pool = ArenaPool::new();
        let mut storage = pool.checkout(&layout);
        storage.fill(0xAB); // simulate a prior plan's leftovers
        pool.checkin(storage);
        assert_eq!(pool.pooled(), 1);
        let storage = pool.checkout(&layout);
        assert_eq!(pool.pooled(), 0, "checkout drains the slot");
        // The never-written read span came back zeroed despite reuse.
        assert!(storage[..64].iter().all(|&x| x == 0));
    }
}
