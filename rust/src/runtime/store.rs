//! Compiled-artifact store: every manifest entry executable by name
//! with raw byte buffers.
//!
//! Two backends share one call surface:
//!
//! - **sim** (default) — the in-crate pure-Rust interpreter
//!   ([`super::simkern`]), semantically matched to the JAX reference
//!   kernels.  No external toolchain, nothing to compile at startup.
//! - **pjrt** (`--features pjrt`) — one PJRT CPU client per store with
//!   every artifact compiled from its HLO text at load time (the
//!   original backend; requires the `xla` crate and `make artifacts`).
//!
//! Signature validation (input arity and byte sizes against the
//! manifest) is backend-independent, so a sim-validated program runs
//! unchanged on PJRT — except for [`elastic_artifact`]s on the sim
//! backend, whose interpreter is a pure per-element map over its input
//! length: there the manifest shape is the *default* chunk size, and
//! any whole number of elements executes.  That relaxation is what
//! lets `GenericWorkload::with_chunks` re-derive a workload at a
//! different task count and still run (the granularity knob for the
//! declaratively-specified fig9 drivers); PJRT executables are
//! compiled for the manifest shape and stay strict.

use std::path::Path;

use crate::{Error, Result};

use super::manifest::{ArtifactMeta, Manifest};
use super::simkern;

/// Artifacts whose sim-backend implementation is a pure per-element map
/// (or length-driven reduction) over the streamed input rather than a
/// fixed-shape program: the manifest shape records the *default* chunk
/// size and any whole number of elements executes.  Re-chunking one of
/// these (`GenericWorkload::with_chunks`) keeps the math per element
/// identical, so assembled outputs stay bitwise equal across task
/// counts — the property the joint tuner validates.  Kernels with
/// per-chunk semantics (histogram bins, per-chunk scans, blockwise
/// transforms) are deliberately absent: their *meaning* changes with
/// the chunk size.
pub fn elastic_artifact(name: &str) -> bool {
    matches!(name, "vector_add" | "black_scholes" | "nn_dist") || name.starts_with("burner_")
}

/// Which inputs of an elastic artifact scale with the chunk; the rest
/// are fixed payloads (broadcast constants like nn's search target)
/// that must stay exactly manifest-sized.
fn elastic_input_scales(name: &str, idx: usize) -> bool {
    match name {
        "nn_dist" => idx == 0, // records scale; the (2,) target is fixed
        _ => true,             // vector_add / black_scholes / burner_*: all scale
    }
}

/// Validate elastic input lengths for `meta` and return the common
/// scale ρ as a rational `(scaled_len, manifest_len)` — `(1, 1)` when
/// everything is manifest-sized.  Scaling inputs must be whole element
/// counts sharing one ρ (an exact-size scaling input votes ρ = 1 — it
/// is **not** exempt, or a pairwise kernel fed `[1×, 2×]` would
/// silently zip to the shorter input); fixed inputs must match the
/// manifest exactly.  Shared by [`ArtifactStore::execute_bytes`] and
/// `StreamPlan::validate` so direct kernel calls and plan validation
/// accept exactly the same calls.
pub(crate) fn elastic_scale(
    name: &str,
    meta: &ArtifactMeta,
    lens: &[usize],
) -> std::result::Result<(usize, usize), String> {
    let mut rho: Option<(usize, usize)> = None;
    for (idx, (spec, &len)) in meta.inputs.iter().zip(lens).enumerate() {
        if !elastic_input_scales(name, idx) {
            if len != spec.bytes() {
                return Err(format!(
                    "fixed input {idx}: {len} bytes != manifest {}",
                    spec.bytes()
                ));
            }
            continue;
        }
        if len == 0 || len % spec.dtype.size() != 0 {
            return Err(format!(
                "input {idx}: {len} bytes is not a positive multiple of the {}-byte element",
                spec.dtype.size()
            ));
        }
        match rho {
            None => rho = Some((len, spec.bytes())),
            Some((a, b)) => {
                if len * b != a * spec.bytes() {
                    return Err(format!(
                        "inputs scale inconsistently ({len}/{} vs {a}/{b})",
                        spec.bytes()
                    ));
                }
            }
        }
    }
    Ok(rho.unwrap_or((1, 1)))
}

/// Owns the kernel backend and the manifest.  With the PJRT backend the
/// store is `!Send` (PJRT handles wrap raw C pointers) — keep it on the
/// thread that created it; the sim backend imposes no such constraint
/// but the engines treat both identically.
pub struct ArtifactStore {
    manifest: Manifest,
    backend: Backend,
}

enum Backend {
    Sim,
    #[cfg(feature = "pjrt")]
    Pjrt(pjrt::PjrtBackend),
}

impl ArtifactStore {
    /// Load the manifest and ready every artifact for execution.  The
    /// PJRT backend compiles each HLO text once here; the sim backend
    /// is dispatch-only.
    pub fn load(dir: &Path) -> Result<Self> {
        let manifest = Manifest::load(dir)?;
        Self::with_manifest(dir, manifest)
    }

    /// Load only the named artifacts (faster PJRT startup for focused
    /// runs; validates the names either way).
    pub fn load_subset(dir: &Path, names: &[&str]) -> Result<Self> {
        let mut manifest = Manifest::load(dir)?;
        manifest.artifacts.retain(|a| names.contains(&a.name.as_str()));
        Self::with_manifest(dir, manifest)
    }

    #[cfg(not(feature = "pjrt"))]
    fn with_manifest(_dir: &Path, manifest: Manifest) -> Result<Self> {
        Ok(Self { manifest, backend: Backend::Sim })
    }

    #[cfg(feature = "pjrt")]
    fn with_manifest(dir: &Path, manifest: Manifest) -> Result<Self> {
        // Fall back to the sim interpreter when the HLO artifacts are
        // not materialized on disk (manifest came from the builtin).
        // A pjrt build asking for missing artifacts is almost always a
        // forgotten `make artifacts` — say so rather than silently
        // reporting interpreter numbers as PJRT ones.
        let have_artifacts = manifest.artifacts.iter().all(|a| dir.join(&a.file).exists());
        let backend = if have_artifacts {
            Backend::Pjrt(pjrt::PjrtBackend::compile(dir, &manifest)?)
        } else {
            eprintln!(
                "hetstream: HLO artifacts missing under {} — falling back to the \
                 sim interpreter (run `make artifacts` for the PJRT backend)",
                dir.display()
            );
            Backend::Sim
        };
        Ok(Self { manifest, backend })
    }

    /// Metadata for an artifact.
    pub fn meta(&self, name: &str) -> Result<&ArtifactMeta> {
        self.manifest
            .get(name)
            .ok_or_else(|| Error::Manifest(format!("unknown artifact `{name}`")))
    }

    /// All loaded artifact names.
    pub fn names(&self) -> Vec<&str> {
        self.manifest.artifacts.iter().map(|a| a.name.as_str()).collect()
    }

    /// Backend platform string (for diagnostics).
    pub fn platform(&self) -> String {
        match &self.backend {
            Backend::Sim => "sim-cpu".to_string(),
            #[cfg(feature = "pjrt")]
            Backend::Pjrt(b) => b.platform(),
        }
    }

    /// Execute artifact `name` with raw little-endian byte payloads, one
    /// per input, shaped per the manifest.  Returns one byte payload per
    /// output.  Payload lengths are validated against the signature.
    pub fn execute_bytes(&self, name: &str, inputs: &[&[u8]]) -> Result<Vec<Vec<u8>>> {
        let meta = self.meta(name)?;
        if inputs.len() != meta.inputs.len() {
            return Err(Error::Signature {
                artifact: name.into(),
                detail: format!("got {} inputs, want {}", inputs.len(), meta.inputs.len()),
            });
        }
        // Elastic artifacts accept any whole element count on the sim
        // backend (see module docs); everything else — and every PJRT
        // execution — must match the manifest byte-for-byte.
        let strict = match &self.backend {
            Backend::Sim => !elastic_artifact(name),
            #[cfg(feature = "pjrt")]
            Backend::Pjrt(_) => true,
        };
        if strict {
            for (spec, bytes) in meta.inputs.iter().zip(inputs) {
                if bytes.len() != spec.bytes() {
                    return Err(Error::Signature {
                        artifact: name.into(),
                        detail: format!("input bytes {} != expected {}", bytes.len(), spec.bytes()),
                    });
                }
            }
        } else {
            // One shared rule with `StreamPlan::validate` (see
            // `elastic_scale`), so direct `kex_with` callers keep the
            // protection the strict check used to give them.
            let lens: Vec<usize> = inputs.iter().map(|b| b.len()).collect();
            if let Err(detail) = elastic_scale(name, meta, &lens) {
                return Err(Error::Signature { artifact: name.into(), detail });
            }
        }
        let outs = match &self.backend {
            Backend::Sim => simkern::execute(meta, inputs)?,
            #[cfg(feature = "pjrt")]
            Backend::Pjrt(b) => b.execute(meta, inputs)?,
        };
        if outs.len() != meta.outputs.len() {
            return Err(Error::Signature {
                artifact: name.into(),
                detail: format!("got {} outputs, want {}", outs.len(), meta.outputs.len()),
            });
        }
        Ok(outs)
    }
}

#[cfg(feature = "pjrt")]
mod pjrt {
    //! The original XLA/PJRT execution path (HLO-text artifacts through
    //! the PJRT CPU client), unchanged semantics.

    use std::collections::HashMap;
    use std::path::Path;

    use crate::runtime::manifest::{ArtifactMeta, Manifest};
    use crate::Result;

    pub struct PjrtBackend {
        #[allow(dead_code)]
        client: xla::PjRtClient,
        executables: HashMap<String, xla::PjRtLoadedExecutable>,
        /// §Perf: per-artifact input literals, created once and refilled
        /// with `copy_raw_from` on every call (saves an allocation +
        /// shape setup per input per call; see EXPERIMENTS.md §Perf).
        input_cache: std::cell::RefCell<HashMap<String, Vec<xla::Literal>>>,
    }

    impl PjrtBackend {
        pub fn compile(dir: &Path, manifest: &Manifest) -> Result<Self> {
            let client = xla::PjRtClient::cpu()?;
            let mut executables = HashMap::new();
            for art in &manifest.artifacts {
                let proto = xla::HloModuleProto::from_text_file(dir.join(&art.file))?;
                let comp = xla::XlaComputation::from_proto(&proto);
                let exe = client.compile(&comp)?;
                executables.insert(art.name.clone(), exe);
            }
            Ok(Self { client, executables, input_cache: Default::default() })
        }

        pub fn platform(&self) -> String {
            self.client.platform_name()
        }

        pub fn execute(&self, meta: &ArtifactMeta, inputs: &[&[u8]]) -> Result<Vec<Vec<u8>>> {
            use crate::runtime::DType;
            let mut cache = self.input_cache.borrow_mut();
            let literals = cache.entry(meta.name.clone()).or_insert_with(|| {
                meta.inputs
                    .iter()
                    .map(|spec| {
                        let ty = match spec.dtype {
                            DType::F32 => xla::PrimitiveType::F32,
                            DType::I32 => xla::PrimitiveType::S32,
                        };
                        xla::Literal::create_from_shape(ty, &spec.shape)
                    })
                    .collect()
            });
            for ((spec, bytes), lit) in meta.inputs.iter().zip(inputs).zip(literals.iter_mut()) {
                // Refill the cached literal in place (§Perf).
                match spec.dtype {
                    DType::F32 => {
                        // SAFETY: `execute_bytes` validated `bytes.len()
                        // == spec.bytes()` (strict mode on PJRT), so the
                        // reinterpreted slice covers exactly
                        // `spec.elements()` f32 values inside the live
                        // borrow; alignment is not required for reads
                        // through `copy_raw_from`'s memcpy, and `u8` →
                        // `f32` has no validity-breaking bit patterns.
                        let src: &[f32] = unsafe {
                            std::slice::from_raw_parts(
                                bytes.as_ptr() as *const f32,
                                spec.elements(),
                            )
                        };
                        lit.copy_raw_from(src)?;
                    }
                    DType::I32 => {
                        // SAFETY: as above — length validated against the
                        // manifest, every bit pattern is a valid i32.
                        let src: &[i32] = unsafe {
                            std::slice::from_raw_parts(
                                bytes.as_ptr() as *const i32,
                                spec.elements(),
                            )
                        };
                        lit.copy_raw_from(src)?;
                    }
                }
            }
            let exe = self
                .executables
                .get(&meta.name)
                .ok_or_else(|| {
                    crate::Error::Manifest(format!("artifact `{}` not compiled", meta.name))
                })?;
            let result = exe.execute::<xla::Literal>(literals)?;
            let lit = result[0][0].to_literal_sync()?;
            // aot.py lowers with return_tuple=True: always a tuple at top.
            let parts = lit.to_tuple()?;
            let mut outs = Vec::with_capacity(parts.len());
            for (spec, part) in meta.outputs.iter().zip(parts) {
                // §Perf: copy the literal straight into the output byte
                // buffer (one copy) instead of to_vec + recopy.
                let mut bytes = vec![0u8; spec.bytes()];
                match spec.dtype {
                    DType::F32 => {
                        // SAFETY: `bytes` was just allocated with exactly
                        // `spec.bytes()` = `spec.elements() * 4` bytes and
                        // is exclusively borrowed here; writing f32 values
                        // through the view leaves only initialized bytes.
                        let dst: &mut [f32] = unsafe {
                            std::slice::from_raw_parts_mut(
                                bytes.as_mut_ptr() as *mut f32,
                                spec.elements(),
                            )
                        };
                        part.copy_raw_to(dst)?;
                    }
                    DType::I32 => {
                        // SAFETY: as above, for i32 elements.
                        let dst: &mut [i32] = unsafe {
                            std::slice::from_raw_parts_mut(
                                bytes.as_mut_ptr() as *mut i32,
                                spec.elements(),
                            )
                        };
                        part.copy_raw_to(dst)?;
                    }
                }
                outs.push(bytes);
            }
            Ok(outs)
        }
    }
}

/// Helpers to view typed slices as byte slices and back — used throughout
/// the workload drivers.
pub mod bytes {
    // §Perf: bulk memcpy conversions.  Kernel backends and this host are
    // both native-endian, so per-element to/from_le_bytes loops (the
    // original implementation) only cost time; a compile-time check
    // keeps the little-endian assumption explicit.
    #[cfg(not(target_endian = "little"))]
    compile_error!("hetstream assumes a little-endian host (matches HLO text artifacts)");

    /// f32 slice -> byte vec (single memcpy).
    pub fn from_f32(v: &[f32]) -> Vec<u8> {
        let mut out = vec![0u8; v.len() * 4];
        // SAFETY: `out` was allocated with exactly `v.len() * 4` bytes,
        // the source is a live borrow of the same byte count, and the
        // freshly allocated destination cannot overlap it; any f32 bits
        // are valid u8 bytes.
        unsafe {
            std::ptr::copy_nonoverlapping(v.as_ptr() as *const u8, out.as_mut_ptr(), out.len());
        }
        out
    }

    /// i32 slice -> byte vec (single memcpy).
    pub fn from_i32(v: &[i32]) -> Vec<u8> {
        let mut out = vec![0u8; v.len() * 4];
        // SAFETY: as in `from_f32` — exact-size fresh allocation, no
        // overlap, i32 bits are valid bytes.
        unsafe {
            std::ptr::copy_nonoverlapping(v.as_ptr() as *const u8, out.as_mut_ptr(), out.len());
        }
        out
    }

    /// byte slice -> f32 vec (single memcpy).
    pub fn to_f32(b: &[u8]) -> Vec<f32> {
        let n = b.len() / 4;
        let mut out = vec![0.0f32; n];
        // SAFETY: `out` holds `n` f32s = `n * 4` bytes ≤ `b.len()`; the
        // fresh allocation cannot overlap the borrowed source, byte
        // copies need no alignment, and every bit pattern is a valid
        // f32 (trailing non-multiple bytes are deliberately dropped).
        unsafe {
            std::ptr::copy_nonoverlapping(b.as_ptr(), out.as_mut_ptr() as *mut u8, n * 4);
        }
        out
    }

    /// byte slice -> i32 vec (single memcpy).
    pub fn to_i32(b: &[u8]) -> Vec<i32> {
        let n = b.len() / 4;
        let mut out = vec![0i32; n];
        // SAFETY: as in `to_f32`, for i32 elements.
        unsafe {
            std::ptr::copy_nonoverlapping(b.as_ptr(), out.as_mut_ptr() as *mut u8, n * 4);
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sim_store(names: &[&str]) -> ArtifactStore {
        // A directory with no manifest.json -> builtin manifest + sim.
        ArtifactStore::load_subset(Path::new("/nonexistent-artifacts"), names).unwrap()
    }

    #[test]
    fn sim_backend_loads_without_artifacts_dir() {
        let s = sim_store(&["vector_add"]);
        assert_eq!(s.platform(), "sim-cpu");
        assert_eq!(s.names(), vec!["vector_add"]);
    }

    #[test]
    fn sim_vector_add_numerics() {
        let s = sim_store(&["vector_add"]);
        let a = vec![1.5f32; 65536];
        let b = vec![-0.25f32; 65536];
        let out = s
            .execute_bytes("vector_add", &[&bytes::from_f32(&a), &bytes::from_f32(&b)])
            .unwrap();
        let c = bytes::to_f32(&out[0]);
        assert!(c.iter().all(|&v| v == 1.25));
    }

    #[test]
    fn elastic_artifacts_accept_rechunked_windows() {
        let store = sim_store(&["vector_add"]);
        // Half the manifest chunk: a with_chunks(16) window of the
        // plan_integration rechunk workload.  Per-element map — the sim
        // backend executes it and returns a matching-length output.
        let half = 65536 / 2 * 4;
        let a = vec![0u8; half];
        let b = vec![0u8; half];
        let outs = store.execute_bytes("vector_add", &[&a, &b]).unwrap();
        assert_eq!(outs[0].len(), half);
        // Non-element-multiple payloads still refuse.
        let ragged = vec![0u8; 6];
        assert!(store.execute_bytes("vector_add", &[&ragged, &ragged]).is_err());
        // …and so do inconsistently scaled inputs (a pairwise kernel
        // would silently zip to the shorter one) — including the case
        // where one input happens to sit exactly at the manifest size.
        assert!(store.execute_bytes("vector_add", &[&a, &b[..half / 2]]).is_err());
        let exact = vec![0u8; 65536 * 4];
        let double = vec![0u8; 2 * 65536 * 4];
        assert!(store.execute_bytes("vector_add", &[&exact, &double]).is_err());
        // nn_dist: records scale, the target must stay exactly (2,).
        let store = sim_store(&["nn_dist"]);
        let recs = vec![0u8; 64];
        let target = vec![0u8; 8];
        assert!(store.execute_bytes("nn_dist", &[&recs, &target]).is_ok());
        let wrong_target = vec![0u8; 4];
        assert!(store.execute_bytes("nn_dist", &[&recs, &wrong_target]).is_err());
        assert!(elastic_artifact("burner_8") && !elastic_artifact("histogram"));
    }

    #[test]
    fn signature_still_enforced() {
        // Arity stays strict for everyone — including elastic artifacts.
        let s = sim_store(&["vector_add", "transpose"]);
        let short = vec![0u8; 16];
        let err = s.execute_bytes("vector_add", &[&short]).unwrap_err();
        assert!(err.to_string().contains("signature"), "{err}");
        // Fixed-shape artifacts keep exact byte-size validation.
        let err = s.execute_bytes("transpose", &[&short]).unwrap_err();
        assert!(err.to_string().contains("signature"), "{err}");
    }
}
