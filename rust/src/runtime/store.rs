//! Compiled-artifact store: one PJRT CPU client + every manifest entry
//! compiled once at startup, executed by name with raw byte buffers.

use std::collections::HashMap;
use std::path::Path;

use crate::{Error, Result};

use super::manifest::{ArtifactMeta, Manifest};

/// Owns the PJRT client and the compiled executables.  `!Send` — keep it
/// on the thread that created it.
pub struct ArtifactStore {
    client: xla::PjRtClient,
    manifest: Manifest,
    executables: HashMap<String, xla::PjRtLoadedExecutable>,
    /// §Perf: per-artifact input literals, created once and refilled
    /// with `copy_raw_from` on every call (saves an allocation + shape
    /// setup per input per call; see EXPERIMENTS.md §Perf).
    input_cache: std::cell::RefCell<HashMap<String, Vec<xla::Literal>>>,
}

impl ArtifactStore {
    /// Load the manifest and compile every artifact on the CPU PJRT
    /// client.  Compilation happens once; execution is pure dispatch.
    pub fn load(dir: &Path) -> Result<Self> {
        let manifest = Manifest::load(dir)?;
        let client = xla::PjRtClient::cpu()?;
        let mut executables = HashMap::new();
        for art in &manifest.artifacts {
            let proto = xla::HloModuleProto::from_text_file(dir.join(&art.file))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = client.compile(&comp)?;
            executables.insert(art.name.clone(), exe);
        }
        Ok(Self { client, manifest, executables, input_cache: Default::default() })
    }

    /// Load only the named artifacts (faster startup for focused runs).
    pub fn load_subset(dir: &Path, names: &[&str]) -> Result<Self> {
        let mut manifest = Manifest::load(dir)?;
        manifest.artifacts.retain(|a| names.contains(&a.name.as_str()));
        let client = xla::PjRtClient::cpu()?;
        let mut executables = HashMap::new();
        for art in &manifest.artifacts {
            let proto = xla::HloModuleProto::from_text_file(dir.join(&art.file))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = client.compile(&comp)?;
            executables.insert(art.name.clone(), exe);
        }
        Ok(Self { client, manifest, executables, input_cache: Default::default() })
    }

    /// Metadata for an artifact.
    pub fn meta(&self, name: &str) -> Result<&ArtifactMeta> {
        self.manifest
            .get(name)
            .ok_or_else(|| Error::Manifest(format!("unknown artifact `{name}`")))
    }

    /// All loaded artifact names.
    pub fn names(&self) -> Vec<&str> {
        self.manifest.artifacts.iter().map(|a| a.name.as_str()).collect()
    }

    /// PJRT platform string (for diagnostics).
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Execute artifact `name` with raw little-endian byte payloads, one
    /// per input, shaped per the manifest.  Returns one byte payload per
    /// output.  Payload lengths are validated against the signature.
    pub fn execute_bytes(&self, name: &str, inputs: &[&[u8]]) -> Result<Vec<Vec<u8>>> {
        let meta = self.meta(name)?.clone();
        if inputs.len() != meta.inputs.len() {
            return Err(Error::Signature {
                artifact: name.into(),
                detail: format!("got {} inputs, want {}", inputs.len(), meta.inputs.len()),
            });
        }
        let mut cache = self.input_cache.borrow_mut();
        let literals = cache.entry(name.to_string()).or_insert_with(|| {
            meta.inputs
                .iter()
                .map(|spec| {
                    let ty = match spec.dtype {
                        super::DType::F32 => xla::PrimitiveType::F32,
                        super::DType::I32 => xla::PrimitiveType::S32,
                    };
                    xla::Literal::create_from_shape(ty, &spec.shape)
                })
                .collect()
        });
        for ((spec, bytes), lit) in meta.inputs.iter().zip(inputs).zip(literals.iter_mut()) {
            if bytes.len() != spec.bytes() {
                return Err(Error::Signature {
                    artifact: name.into(),
                    detail: format!("input bytes {} != expected {}", bytes.len(), spec.bytes()),
                });
            }
            // Refill the cached literal in place (§Perf).
            match spec.dtype {
                super::DType::F32 => {
                    let src: &[f32] = unsafe {
                        std::slice::from_raw_parts(bytes.as_ptr() as *const f32, spec.elements())
                    };
                    lit.copy_raw_from(src)?;
                }
                super::DType::I32 => {
                    let src: &[i32] = unsafe {
                        std::slice::from_raw_parts(bytes.as_ptr() as *const i32, spec.elements())
                    };
                    lit.copy_raw_from(src)?;
                }
            }
        }
        let exe = self
            .executables
            .get(name)
            .ok_or_else(|| Error::Manifest(format!("artifact `{name}` not compiled")))?;
        let result = exe.execute::<xla::Literal>(&literals)?;
        let lit = result[0][0].to_literal_sync()?;
        // aot.py lowers with return_tuple=True: always a tuple at top level.
        let parts = lit.to_tuple()?;
        if parts.len() != meta.outputs.len() {
            return Err(Error::Signature {
                artifact: name.into(),
                detail: format!("got {} outputs, want {}", parts.len(), meta.outputs.len()),
            });
        }
        let mut outs = Vec::with_capacity(parts.len());
        for (spec, part) in meta.outputs.iter().zip(parts) {
            // §Perf: copy the literal straight into the output byte
            // buffer (one copy) instead of to_vec + recopy (two copies
            // plus an allocation) — see EXPERIMENTS.md §Perf.
            let mut bytes = vec![0u8; spec.bytes()];
            match spec.dtype {
                super::DType::F32 => {
                    let dst: &mut [f32] = unsafe {
                        std::slice::from_raw_parts_mut(
                            bytes.as_mut_ptr() as *mut f32,
                            spec.elements(),
                        )
                    };
                    part.copy_raw_to(dst)?;
                }
                super::DType::I32 => {
                    let dst: &mut [i32] = unsafe {
                        std::slice::from_raw_parts_mut(
                            bytes.as_mut_ptr() as *mut i32,
                            spec.elements(),
                        )
                    };
                    part.copy_raw_to(dst)?;
                }
            }
            outs.push(bytes);
        }
        Ok(outs)
    }
}

/// Helpers to view typed slices as byte slices and back — used throughout
/// the workload drivers.
pub mod bytes {
    // §Perf: bulk memcpy conversions.  PJRT literals and this host are
    // both native-endian, so per-element to/from_le_bytes loops (the
    // original implementation) only cost time; a compile-time check
    // keeps the little-endian assumption explicit.
    #[cfg(not(target_endian = "little"))]
    compile_error!("hetstream assumes a little-endian host (matches HLO text artifacts)");

    /// f32 slice -> byte vec (single memcpy).
    pub fn from_f32(v: &[f32]) -> Vec<u8> {
        let mut out = vec![0u8; v.len() * 4];
        unsafe {
            std::ptr::copy_nonoverlapping(v.as_ptr() as *const u8, out.as_mut_ptr(), out.len());
        }
        out
    }

    /// i32 slice -> byte vec (single memcpy).
    pub fn from_i32(v: &[i32]) -> Vec<u8> {
        let mut out = vec![0u8; v.len() * 4];
        unsafe {
            std::ptr::copy_nonoverlapping(v.as_ptr() as *const u8, out.as_mut_ptr(), out.len());
        }
        out
    }

    /// byte slice -> f32 vec (single memcpy).
    pub fn to_f32(b: &[u8]) -> Vec<f32> {
        let n = b.len() / 4;
        let mut out = vec![0.0f32; n];
        unsafe {
            std::ptr::copy_nonoverlapping(b.as_ptr(), out.as_mut_ptr() as *mut u8, n * 4);
        }
        out
    }

    /// byte slice -> i32 vec (single memcpy).
    pub fn to_i32(b: &[u8]) -> Vec<i32> {
        let n = b.len() / 4;
        let mut out = vec![0i32; n];
        unsafe {
            std::ptr::copy_nonoverlapping(b.as_ptr(), out.as_mut_ptr() as *mut u8, n * 4);
        }
        out
    }
}
