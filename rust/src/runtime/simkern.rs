//! Pure-Rust kernel interpreter — the default `ArtifactStore` backend.
//!
//! One function per AOT artifact, semantically identical to the JAX
//! reference implementations in `python/compile/kernels/ref.py` (f64
//! accumulation where the reference uses float64, f32 element types at
//! the interface).  This keeps the whole Rust stack runnable — and the
//! virtual-clock simulation exact — on machines without the XLA/PJRT
//! toolchain; enabling `--features pjrt` swaps in the real compiled
//! artifacts without touching any caller.
//!
//! Timing is *not* modeled here: kernels run at host speed and the
//! engines charge the modeled KEX duration through the `SimClock`
//! (virtual) or `pace_to` (wall-clock).

use crate::runtime::bytes;
use crate::{Error, Result};

use super::manifest::ArtifactMeta;

/// Execute artifact `meta.name` on raw little-endian input payloads.
/// Payload arity/sizes are validated by the caller (`ArtifactStore`).
pub fn execute(meta: &ArtifactMeta, inputs: &[&[u8]]) -> Result<Vec<Vec<u8>>> {
    let name = meta.name.as_str();
    if let Some(iters) = name.strip_prefix("burner_") {
        let iters: u32 = iters
            .parse()
            .map_err(|_| Error::Manifest(format!("bad burner variant `{name}`")))?;
        return Ok(vec![bytes::from_f32(&burner(&bytes::to_f32(inputs[0]), iters))]);
    }
    match name {
        "vector_add" => {
            let (a, b) = (bytes::to_f32(inputs[0]), bytes::to_f32(inputs[1]));
            Ok(vec![bytes::from_f32(&vector_add(&a, &b))])
        }
        "nn_dist" => {
            let recs = bytes::to_f32(inputs[0]);
            let t = bytes::to_f32(inputs[1]);
            let d: Vec<f32> = recs
                .chunks_exact(2)
                .map(|r| ((r[0] - t[0]).powi(2) + (r[1] - t[1]).powi(2)).sqrt())
                .collect();
            Ok(vec![bytes::from_f32(&d)])
        }
        "transpose" => {
            let x = bytes::to_f32(inputs[0]);
            let (r, c) = dims2(meta, 0)?;
            let mut out = vec![0.0f32; r * c];
            for i in 0..r {
                for j in 0..c {
                    out[j * r + i] = x[i * c + j];
                }
            }
            Ok(vec![bytes::from_f32(&out)])
        }
        "matmul" => {
            let a = bytes::to_f32(inputs[0]);
            let b = bytes::to_f32(inputs[1]);
            let (m, k) = dims2(meta, 0)?;
            let (_, n) = dims2(meta, 1)?;
            let mut out = vec![0.0f32; m * n];
            for i in 0..m {
                for j in 0..n {
                    let mut acc = 0.0f64;
                    for p in 0..k {
                        acc += a[i * k + p] as f64 * b[p * n + j] as f64;
                    }
                    out[i * n + j] = acc as f32;
                }
            }
            Ok(vec![bytes::from_f32(&out)])
        }
        "prefix_sum" => {
            let x = bytes::to_f32(inputs[0]);
            let mut acc = 0.0f64;
            let scan: Vec<f32> = x
                .iter()
                .map(|&v| {
                    acc += v as f64;
                    acc as f32
                })
                .collect();
            let total = vec![*scan.last().unwrap_or(&0.0)];
            Ok(vec![bytes::from_f32(&scan), bytes::from_f32(&total)])
        }
        "histogram" => {
            let x = bytes::to_i32(inputs[0]);
            let bins = meta.outputs[0].elements();
            let mut h = vec![0i32; bins];
            for &v in &x {
                let b = (v.max(0) as usize).min(bins - 1);
                h[b] += 1;
            }
            Ok(vec![bytes::from_i32(&h)])
        }
        "black_scholes" => {
            let s = bytes::to_f32(inputs[0]);
            let k = bytes::to_f32(inputs[1]);
            let t = bytes::to_f32(inputs[2]);
            let (call, put) = black_scholes(&s, &k, &t);
            Ok(vec![bytes::from_f32(&call), bytes::from_f32(&put)])
        }
        "dct8x8" => {
            let x = bytes::to_f32(inputs[0]);
            let basis = bytes::to_f32(inputs[1]);
            let (rows, cols) = dims2(meta, 0)?;
            Ok(vec![bytes::from_f32(&dct8x8(&x, &basis, rows, cols))])
        }
        "dot_product" => {
            let (a, b) = (bytes::to_f32(inputs[0]), bytes::to_f32(inputs[1]));
            Ok(vec![bytes::from_f32(&[dot_product(&a, &b)])])
        }
        "hotspot_step" => {
            let temp = bytes::to_f32(inputs[0]);
            let power = bytes::to_f32(inputs[1]);
            let (n, _) = dims2(meta, 0)?;
            Ok(vec![bytes::from_f32(&hotspot_step(&temp, &power, n))])
        }
        "fwt" => {
            let x = bytes::to_f32(inputs[0]);
            Ok(vec![bytes::from_f32(&fwt(&x))])
        }
        "conv_sep" => {
            let img = bytes::to_f32(inputs[0]);
            let krow = bytes::to_f32(inputs[1]);
            let kcol = bytes::to_f32(inputs[2]);
            let (rows, cols) = dims2_of(&meta.outputs[0])?;
            Ok(vec![bytes::from_f32(&conv_sep(&img, rows, cols, &krow, &kcol))])
        }
        "stencil2d" => {
            let x = bytes::to_f32(inputs[0]);
            let (rows, cols) = dims2_of(&meta.outputs[0])?;
            Ok(vec![bytes::from_f32(&stencil2d(&x, rows, cols))])
        }
        "lavamd_box" => {
            let x = bytes::to_f32(inputs[0]);
            let n = meta.outputs[0].elements();
            Ok(vec![bytes::from_f32(&lavamd(&x, n))])
        }
        "cfft2d" => {
            let tile = bytes::to_f32(inputs[0]);
            let filt = bytes::to_f32(inputs[1]);
            let (t, _) = dims2(meta, 0)?;
            Ok(vec![bytes::from_f32(&cfft2d(&tile, &filt, t)?)])
        }
        "nw_tile" => {
            let north = bytes::to_i32(inputs[0]);
            let west = bytes::to_i32(inputs[1]);
            let corner = bytes::to_i32(inputs[2]);
            let sub = bytes::to_i32(inputs[3]);
            let (tile, south, east) = nw_tile(&north, &west, corner[0], &sub);
            Ok(vec![bytes::from_i32(&tile), bytes::from_i32(&south), bytes::from_i32(&east)])
        }
        "reduction_v1" => {
            let x = bytes::to_f32(inputs[0]);
            let acc: f64 = x.iter().map(|&v| v as f64).sum();
            Ok(vec![bytes::from_f32(&[acc as f32])])
        }
        "reduction_v2" => {
            let x = bytes::to_f32(inputs[0]);
            let blocks = meta.outputs[0].elements();
            let per = x.len() / blocks.max(1);
            let sums: Vec<f32> = (0..blocks)
                .map(|b| x[b * per..(b + 1) * per].iter().map(|&v| v as f64).sum::<f64>() as f32)
                .collect();
            Ok(vec![bytes::from_f32(&sums)])
        }
        other => Err(Error::Manifest(format!("no sim kernel for artifact `{other}`"))),
    }
}

fn dims2(meta: &ArtifactMeta, input: usize) -> Result<(usize, usize)> {
    dims2_of(&meta.inputs[input])
}

fn dims2_of(spec: &super::manifest::IoSpec) -> Result<(usize, usize)> {
    if spec.shape.len() != 2 {
        return Err(Error::Manifest(format!("expected rank-2 shape, got {:?}", spec.shape)));
    }
    Ok((spec.shape[0], spec.shape[1]))
}

/// Lane width of the chunked hot-kernel loops: 8 f32 = one AVX2
/// register, two SSE registers — fixed-size chunks let LLVM drop the
/// bounds checks and emit straight vector code.
const LANES: usize = 8;

/// Elementwise `a + b` over `min(len)` elements, chunked for
/// autovectorization.  Bitwise-identical to the scalar
/// `zip(...).map(|(x, y)| x + y)` form: f32 addition is per-element,
/// so chunking changes no operation or order (see tests).
fn vector_add(a: &[f32], b: &[f32]) -> Vec<f32> {
    let n = a.len().min(b.len());
    let (a, b) = (&a[..n], &b[..n]);
    let mut c = vec![0.0f32; n];
    let mut it = c.chunks_exact_mut(LANES).zip(a.chunks_exact(LANES).zip(b.chunks_exact(LANES)));
    for (cc, (ca, cb)) in &mut it {
        for i in 0..LANES {
            cc[i] = ca[i] + cb[i];
        }
    }
    let tail = n - n % LANES;
    for i in tail..n {
        c[i] = a[i] + b[i];
    }
    c
}

/// Dot product with sequential f64 accumulation.  The widening
/// multiplies vectorize per chunk; the adds into `acc` stay strictly
/// left-to-right, so the f64 sum — and the rounded f32 result — are
/// bitwise-identical to the scalar fold (f64 addition is not
/// associative; reordering would change bits).
fn dot_product(a: &[f32], b: &[f32]) -> f32 {
    let n = a.len().min(b.len());
    let (a, b) = (&a[..n], &b[..n]);
    let mut acc = 0.0f64;
    let mut prod = [0.0f64; LANES];
    for (ca, cb) in a.chunks_exact(LANES).zip(b.chunks_exact(LANES)) {
        for i in 0..LANES {
            prod[i] = ca[i] as f64 * cb[i] as f64;
        }
        for &p in &prod {
            acc += p;
        }
    }
    let tail = n - n % LANES;
    for i in tail..n {
        acc += a[i] as f64 * b[i] as f64;
    }
    acc as f32
}

/// `iters` FMA sweeps over the block (the calibrated synthetic
/// kernel).  Loop-interchanged: each chunk of 8 elements runs all
/// `iters` steps while resident in registers (one memory pass instead
/// of `iters`), which is exact because every element's update sequence
/// is independent of the others — same ops per element, same order.
fn burner(x: &[f32], iters: u32) -> Vec<f32> {
    const C: f32 = 1.000001f32;
    const D: f32 = 1e-7f32;
    let mut v = x.to_vec();
    let mut it = v.chunks_exact_mut(LANES);
    for chunk in &mut it {
        let mut lane = [0.0f32; LANES];
        lane.copy_from_slice(chunk);
        for _ in 0..iters {
            for e in &mut lane {
                *e = *e * C + D;
            }
        }
        chunk.copy_from_slice(&lane);
    }
    for e in it.into_remainder() {
        for _ in 0..iters {
            *e = *e * C + D;
        }
    }
    v
}

/// Iterative Walsh–Hadamard transform, f64 accumulation (ref.py `fwt`).
fn fwt(x: &[f32]) -> Vec<f32> {
    let n = x.len();
    let mut v: Vec<f64> = x.iter().map(|&e| e as f64).collect();
    let mut h = 1;
    while h < n {
        for i in (0..n).step_by(h * 2) {
            for j in i..i + h {
                let (a, b) = (v[j], v[j + h]);
                v[j] = a + b;
                v[j + h] = a - b;
            }
        }
        h *= 2;
    }
    v.into_iter().map(|e| e as f32).collect()
}

/// Blockwise 8x8 DCT via the broadcast basis: `out = C @ B @ C^T`.
fn dct8x8(x: &[f32], basis: &[f32], rows: usize, cols: usize) -> Vec<f32> {
    let c = |i: usize, j: usize| basis[i * 8 + j] as f64;
    let mut out = vec![0.0f32; rows * cols];
    for bi in 0..rows / 8 {
        for bj in 0..cols / 8 {
            let mut tmp = [[0.0f64; 8]; 8];
            for i in 0..8 {
                for j in 0..8 {
                    let mut acc = 0.0;
                    for p in 0..8 {
                        acc += c(i, p) * x[(bi * 8 + p) * cols + bj * 8 + j] as f64;
                    }
                    tmp[i][j] = acc;
                }
            }
            for i in 0..8 {
                for j in 0..8 {
                    let mut acc = 0.0;
                    for p in 0..8 {
                        acc += tmp[i][p] * c(j, p);
                    }
                    out[(bi * 8 + i) * cols + bj * 8 + j] = acc as f32;
                }
            }
        }
    }
    out
}

/// Black–Scholes call/put prices (r = 0.02, v = 0.30), deliberately
/// *not* delegated to `workloads::oracle` — the drivers validate
/// against the oracle, so the kernel must be an independent
/// implementation.  The normal CDF here is the Zelen–Severo polynomial
/// (A&S 26.2.17, |err| < 7.5e-8), a different construction from the
/// oracle's erf-based path.
fn black_scholes(s: &[f32], k: &[f32], t: &[f32]) -> (Vec<f32>, Vec<f32>) {
    const R: f64 = 0.02;
    const V: f64 = 0.30;
    fn cnd(x: f64) -> f64 {
        let ax = x.abs();
        let t = 1.0 / (1.0 + 0.2316419 * ax);
        let phi = (-0.5 * ax * ax).exp() / (2.0 * std::f64::consts::PI).sqrt();
        let poly = t
            * (0.319381530
                + t * (-0.356563782 + t * (1.781477937 + t * (-1.821255978 + t * 1.330274429))));
        let upper = 1.0 - phi * poly;
        if x >= 0.0 {
            upper
        } else {
            1.0 - upper
        }
    }
    // Equal-length slices + pre-sized outputs: the element loop body
    // carries no bounds checks or capacity growth (the transcendental
    // calls don't vectorize, but everything around them streams).
    // Per-element math is unchanged from the scalar form — identical
    // operations in identical order, so results are bitwise-equal.
    let n = s.len();
    let (k, t) = (&k[..n], &t[..n]);
    let mut call = vec![0.0f32; n];
    let mut put = vec![0.0f32; n];
    for i in 0..n {
        let (s, k, t) = (s[i] as f64, k[i] as f64, t[i] as f64);
        let sqrt_t = t.sqrt();
        let d1 = ((s / k).ln() + (R + 0.5 * V * V) * t) / (V * sqrt_t);
        let d2 = d1 - V * sqrt_t;
        let e = (-R * t).exp();
        call[i] = (s * cnd(d1) - k * e * cnd(d2)) as f32;
        put[i] = (k * e * cnd(-d2) - s * cnd(-d1)) as f32;
    }
    (call, put)
}

/// One hotspot diffusion step (k = 0.1, boundary preserved).
fn hotspot_step(temp: &[f32], power: &[f32], n: usize) -> Vec<f32> {
    const K: f64 = 0.1;
    let mut out = temp.to_vec();
    for r in 1..n - 1 {
        for c in 1..n - 1 {
            let t = temp[r * n + c] as f64;
            let lap = temp[(r - 1) * n + c] as f64
                + temp[(r + 1) * n + c] as f64
                + temp[r * n + c - 1] as f64
                + temp[r * n + c + 1] as f64
                - 4.0 * t;
            out[r * n + c] = (t + K * (power[r * n + c] as f64 + lap)) as f32;
        }
    }
    out
}

/// Separable convolution over a halo-padded band: vertical pass inside
/// the halo, horizontal pass zero-padded (ref.py `conv_sep`).
fn conv_sep(padded: &[f32], rows: usize, cols: usize, krow: &[f32], kcol: &[f32]) -> Vec<f32> {
    let h = (krow.len() - 1) / 2;
    let mut mid = vec![0.0f64; rows * cols];
    for k in 0..2 * h + 1 {
        for r in 0..rows {
            for c in 0..cols {
                mid[r * cols + c] += padded[(r + k) * cols + c] as f64 * kcol[k] as f64;
            }
        }
    }
    let mut out = vec![0.0f32; rows * cols];
    for r in 0..rows {
        for c in 0..cols {
            let mut acc = 0.0f64;
            for k in 0..2 * h + 1 {
                let cc = c as isize + k as isize - h as isize;
                if cc >= 0 && (cc as usize) < cols {
                    acc += mid[r * cols + cc as usize] * krow[k] as f64;
                }
            }
            out[r * cols + c] = acc as f32;
        }
    }
    out
}

/// 5-point Jacobi step over a `(rows+2) x cols` padded field.
fn stencil2d(padded: &[f32], rows: usize, cols: usize) -> Vec<f32> {
    const C0: f64 = 0.5;
    const C1: f64 = 0.125;
    let mut out = vec![0.0f32; rows * cols];
    for r in 0..rows {
        for c in 0..cols {
            let center = padded[(r + 1) * cols + c] as f64;
            let north = padded[r * cols + c] as f64;
            let south = padded[(r + 2) * cols + c] as f64;
            let west = if c > 0 { padded[(r + 1) * cols + c - 1] as f64 } else { 0.0 };
            let east = if c + 1 < cols { padded[(r + 1) * cols + c + 1] as f64 } else { 0.0 };
            out[r * cols + c] = (C0 * center + C1 * (north + south + west + east)) as f32;
        }
    }
    out
}

/// lavaMD window potential over a halo-padded particle line.
fn lavamd(padded: &[f32], n: usize) -> Vec<f32> {
    let h = (padded.len() - n) / 2;
    (0..n)
        .map(|i| {
            let c = padded[h + i] as f64;
            let mut acc = 0.0f64;
            for j in i..i + 2 * h + 1 {
                let d2 = (c - padded[j] as f64).powi(2);
                acc += 1.0 / (1.0 + d2);
            }
            (acc - 1.0) as f32
        })
        .collect()
}

/// One NW DP tile from its north/west/corner edges (penalty 10).
fn nw_tile(
    north: &[i32],
    west: &[i32],
    corner: i32,
    sub: &[i32],
) -> (Vec<i32>, Vec<i32>, Vec<i32>) {
    const PENALTY: i64 = 10;
    let t = north.len();
    let w = t + 1;
    let mut e = vec![0i64; w * w];
    e[0] = corner as i64;
    for j in 0..t {
        e[j + 1] = north[j] as i64;
    }
    for i in 0..t {
        e[(i + 1) * w] = west[i] as i64;
    }
    for i in 1..=t {
        for j in 1..=t {
            let diag = e[(i - 1) * w + j - 1] + sub[(i - 1) * t + j - 1] as i64;
            let up = e[(i - 1) * w + j] - PENALTY;
            let left = e[i * w + j - 1] - PENALTY;
            e[i * w + j] = diag.max(up).max(left);
        }
    }
    let mut tile = vec![0i32; t * t];
    for i in 0..t {
        for j in 0..t {
            tile[i * t + j] = e[(i + 1) * w + j + 1] as i32;
        }
    }
    let south = tile[(t - 1) * t..].to_vec();
    let east: Vec<i32> = (0..t).map(|i| tile[i * t + t - 1]).collect();
    (tile, south, east)
}

/// Circular 2D convolution of `tile` with `filt` via FFT (both `t x t`).
fn cfft2d(tile: &[f32], filt: &[f32], t: usize) -> Result<Vec<f32>> {
    if !t.is_power_of_two() {
        return Err(Error::Manifest(format!("cfft2d tile side {t} must be a power of two")));
    }
    let mut a = Complex2d::from_f32(tile, t);
    let mut b = Complex2d::from_f32(filt, t);
    a.fft2(false);
    b.fft2(false);
    for i in 0..t * t {
        let (ar, ai) = (a.re[i], a.im[i]);
        let (br, bi) = (b.re[i], b.im[i]);
        a.re[i] = ar * br - ai * bi;
        a.im[i] = ar * bi + ai * br;
    }
    a.fft2(true);
    Ok(a.re.iter().map(|&v| v as f32).collect())
}

/// Square complex grid with in-place radix-2 FFT over rows and columns.
struct Complex2d {
    t: usize,
    re: Vec<f64>,
    im: Vec<f64>,
}

impl Complex2d {
    fn from_f32(x: &[f32], t: usize) -> Self {
        Self { t, re: x.iter().map(|&v| v as f64).collect(), im: vec![0.0; t * t] }
    }

    fn fft2(&mut self, invert: bool) {
        let t = self.t;
        let mut row_re = vec![0.0; t];
        let mut row_im = vec![0.0; t];
        // Rows.
        for r in 0..t {
            row_re.copy_from_slice(&self.re[r * t..(r + 1) * t]);
            row_im.copy_from_slice(&self.im[r * t..(r + 1) * t]);
            fft1d(&mut row_re, &mut row_im, invert);
            self.re[r * t..(r + 1) * t].copy_from_slice(&row_re);
            self.im[r * t..(r + 1) * t].copy_from_slice(&row_im);
        }
        // Columns.
        for c in 0..t {
            for r in 0..t {
                row_re[r] = self.re[r * t + c];
                row_im[r] = self.im[r * t + c];
            }
            fft1d(&mut row_re, &mut row_im, invert);
            for r in 0..t {
                self.re[r * t + c] = row_re[r];
                self.im[r * t + c] = row_im[r];
            }
        }
    }
}

/// Iterative radix-2 Cooley–Tukey FFT (`invert` divides by n).
fn fft1d(re: &mut [f64], im: &mut [f64], invert: bool) {
    let n = re.len();
    let mut j = 0usize;
    for i in 1..n {
        let mut bit = n >> 1;
        while j & bit != 0 {
            j ^= bit;
            bit >>= 1;
        }
        j |= bit;
        if i < j {
            re.swap(i, j);
            im.swap(i, j);
        }
    }
    let mut len = 2;
    while len <= n {
        let ang = 2.0 * std::f64::consts::PI / len as f64 * if invert { 1.0 } else { -1.0 };
        let (wr, wi) = (ang.cos(), ang.sin());
        for i in (0..n).step_by(len) {
            let (mut cr, mut ci) = (1.0f64, 0.0f64);
            for k in 0..len / 2 {
                let (ur, ui) = (re[i + k], im[i + k]);
                let (xr, xi) = (re[i + k + len / 2], im[i + k + len / 2]);
                let (vr, vi) = (xr * cr - xi * ci, xr * ci + xi * cr);
                re[i + k] = ur + vr;
                im[i + k] = ui + vi;
                re[i + k + len / 2] = ur - vr;
                im[i + k + len / 2] = ui - vi;
                let t = cr * wr - ci * wi;
                ci = cr * wi + ci * wr;
                cr = t;
            }
        }
        len <<= 1;
    }
    if invert {
        for v in re.iter_mut() {
            *v /= n as f64;
        }
        for v in im.iter_mut() {
            *v /= n as f64;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fft_roundtrip_recovers_signal() {
        let n = 16;
        let orig: Vec<f64> = (0..n).map(|i| (i as f64 * 0.37).sin()).collect();
        let mut re = orig.clone();
        let mut im = vec![0.0; n];
        fft1d(&mut re, &mut im, false);
        fft1d(&mut re, &mut im, true);
        for (a, b) in re.iter().zip(&orig) {
            assert!((a - b).abs() < 1e-12, "{a} vs {b}");
        }
        assert!(im.iter().all(|v| v.abs() < 1e-12));
    }

    #[test]
    fn cfft2d_delta_filter_is_circular_shift() {
        let t = 8;
        let tile: Vec<f32> = (0..t * t).map(|i| (i as f32 * 0.13).cos()).collect();
        let mut filt = vec![0.0f32; t * t];
        filt[1 * t + 3] = 1.0; // delta at (1, 3)
        let out = cfft2d(&tile, &filt, t).unwrap();
        for i in 0..t {
            for j in 0..t {
                let want = tile[((i + t - 1) % t) * t + (j + t - 3) % t];
                assert!((out[i * t + j] - want).abs() < 1e-5);
            }
        }
    }

    #[test]
    fn nw_tile_matches_whole_matrix_dp() {
        // A single tile with Rodinia boundaries equals the full oracle.
        let t = 4;
        let sub: Vec<i32> = (0..t * t).map(|i| (i as i32 % 7) - 3).collect();
        let north: Vec<i32> = (0..t as i32).map(|j| -10 * (j + 1)).collect();
        let west: Vec<i32> = (0..t as i32).map(|i| -10 * (i + 1)).collect();
        let (tile, south, east) = nw_tile(&north, &west, 0, &sub);
        let want = crate::workloads::oracle::nw_full(&sub, t, 10);
        assert_eq!(tile, want);
        assert_eq!(south, &want[(t - 1) * t..]);
        let want_east: Vec<i32> = (0..t).map(|i| want[i * t + t - 1]).collect();
        assert_eq!(east, want_east);
    }

    #[test]
    fn black_scholes_matches_the_independent_oracle() {
        // Different CND constructions (Zelen–Severo here, A&S erf in the
        // oracle) must agree to well under the drivers' tolerance.
        let s = [5.0f32, 12.5, 30.0, 20.0];
        let k = [1.0f32, 50.0, 100.0, 20.0];
        let t = [0.25f32, 2.0, 10.0, 1.0];
        let (call, put) = black_scholes(&s, &k, &t);
        let (wcall, wput) = crate::workloads::oracle::black_scholes(&s, &k, &t);
        for i in 0..s.len() {
            assert!((call[i] - wcall[i]).abs() < 1e-3, "call {i}: {} vs {}", call[i], wcall[i]);
            assert!((put[i] - wput[i]).abs() < 1e-3, "put {i}: {} vs {}", put[i], wput[i]);
        }
    }

    #[test]
    fn burner_applies_fma_sweeps() {
        let out = burner(&[1.0, -0.5], 2);
        let step = |v: f32| v * 1.000001 + 1e-7;
        assert_eq!(out, vec![step(step(1.0)), step(step(-0.5))]);
    }

    // --- exactness of the chunked hot kernels ------------------------
    //
    // The vectorized forms must be *bitwise* equal to the scalar
    // references (the sim-vs-native oracle and the golden traces both
    // depend on exact bytes), so every comparison below is on f32 bit
    // patterns, over lengths that exercise full chunks and tails.

    /// Deterministic pseudo-random f32s (LCG), mixed signs/magnitudes.
    fn lcg_f32(n: usize, mut seed: u64) -> Vec<f32> {
        (0..n)
            .map(|_| {
                seed = seed.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
                ((seed >> 33) as i32 % 2001 - 1000) as f32 * 0.037 + 0.5
            })
            .collect()
    }

    fn bits(v: &[f32]) -> Vec<u32> {
        v.iter().map(|x| x.to_bits()).collect()
    }

    #[test]
    fn vector_add_is_bitwise_equal_to_the_scalar_form() {
        for n in [0usize, 1, 7, 8, 9, 64, 1003] {
            let a = lcg_f32(n, 1);
            let b = lcg_f32(n, 2);
            let scalar: Vec<f32> = a.iter().zip(&b).map(|(x, y)| x + y).collect();
            assert_eq!(bits(&vector_add(&a, &b)), bits(&scalar), "n = {n}");
        }
    }

    #[test]
    fn dot_product_preserves_the_exact_accumulation_order() {
        // Long arrays with mixed magnitudes: any reassociation of the
        // f64 sum would flip low bits of the rounded f32.
        for n in [0usize, 5, 8, 17, 4096, 4099] {
            let a = lcg_f32(n, 3);
            let b = lcg_f32(n, 4);
            let scalar: f64 = a.iter().zip(&b).map(|(x, y)| *x as f64 * *y as f64).sum();
            assert_eq!(
                dot_product(&a, &b).to_bits(),
                (scalar as f32).to_bits(),
                "n = {n}"
            );
        }
    }

    #[test]
    fn burner_loop_interchange_is_bitwise_exact() {
        for (n, iters) in [(1usize, 3u32), (8, 10), (23, 7), (256, 1), (130, 0)] {
            let x = lcg_f32(n, 5);
            let mut scalar = x.clone();
            for _ in 0..iters {
                for e in &mut scalar {
                    *e = *e * 1.000001f32 + 1e-7f32;
                }
            }
            assert_eq!(bits(&burner(&x, iters)), bits(&scalar), "n = {n}, iters = {iters}");
        }
    }

    #[test]
    fn black_scholes_restructured_loop_is_bitwise_exact() {
        let n = 257;
        let s: Vec<f32> = (0..n).map(|i| 1.0 + (i as f32) * 0.37).collect();
        let k: Vec<f32> = (0..n).map(|i| 5.0 + (i % 90) as f32).collect();
        let t: Vec<f32> = (0..n).map(|i| 0.05 + (i as f32) * 0.01).collect();
        // Scalar reference: the pre-rewrite push-based loop.
        let (mut call, mut put) = (Vec::new(), Vec::new());
        const R: f64 = 0.02;
        const V: f64 = 0.30;
        fn cnd(x: f64) -> f64 {
            let ax = x.abs();
            let t = 1.0 / (1.0 + 0.2316419 * ax);
            let phi = (-0.5 * ax * ax).exp() / (2.0 * std::f64::consts::PI).sqrt();
            let poly = t
                * (0.319381530
                    + t * (-0.356563782
                        + t * (1.781477937 + t * (-1.821255978 + t * 1.330274429))));
            let upper = 1.0 - phi * poly;
            if x >= 0.0 {
                upper
            } else {
                1.0 - upper
            }
        }
        for i in 0..n {
            let (s, k, t) = (s[i] as f64, k[i] as f64, t[i] as f64);
            let sqrt_t = t.sqrt();
            let d1 = ((s / k).ln() + (R + 0.5 * V * V) * t) / (V * sqrt_t);
            let d2 = d1 - V * sqrt_t;
            let e = (-R * t).exp();
            call.push((s * cnd(d1) - k * e * cnd(d2)) as f32);
            put.push((k * e * cnd(-d2) - s * cnd(-d1)) as f32);
        }
        let (vcall, vput) = black_scholes(&s, &k, &t);
        assert_eq!(bits(&vcall), bits(&call));
        assert_eq!(bits(&vput), bits(&put));
    }
}
