//! NVIDIA/AMD `FastWalshTransform` — the paper's False Dependent
//! exemplar (Fig. 7): block transforms with negligible boundary cost
//! (254 of 1 M elements), hence the ~39% streamed gain in Fig. 9.
//!
//! The streamed port here follows the paper's partitioning: the signal
//! splits into independent blocks, each transformed in VMEM; the
//! "boundary" elements are the intra-block butterfly partners that ride
//! along with each block, so the per-task transfer is exactly one block.

use std::sync::Arc;

use crate::hstreams::Context;
use crate::runtime::bytes;
use crate::Result;

use super::{gen_f32, oracle, Benchmark, GenericWorkload, Mode, RunStats, Windows};

pub const CHUNK: usize = 4096;

pub struct Fwt {
    chunks: usize,
}

impl Fwt {
    pub fn new(scale: usize) -> Self {
        Self { chunks: 32 * scale.max(1) }
    }
}

impl Benchmark for Fwt {
    fn name(&self) -> &'static str {
        "FastWalshTransform"
    }

    fn artifacts(&self) -> Vec<&'static str> {
        vec!["fwt"]
    }

    fn run(&self, ctx: &Context, mode: Mode) -> Result<RunStats> {
        let total = self.chunks * CHUNK;
        let x = gen_f32(total, 61);

        let wl = GenericWorkload {
            name: "FastWalshTransform",
            artifact: "fwt",
            streamed_inputs: vec![Windows::disjoint(Arc::new(bytes::from_f32(&x)), self.chunks)],
            shared_inputs: vec![],
            output_chunk_bytes: vec![CHUNK * 4],
            // Butterfly stages walk device memory log2(N) times — device
            // time well above the raw FLOP count (paper: gain ≈ 39%).
            flops_per_chunk: Some(433_000),
        };
        let (wall, outputs, h2d) = wl.execute(ctx, mode)?;

        let got = bytes::to_f32(&outputs[0]);
        let mut ok = true;
        for c in 0..self.chunks {
            let mut want = x[c * CHUNK..(c + 1) * CHUNK].to_vec();
            oracle::fwt_block(&mut want);
            let blk = &got[c * CHUNK..(c + 1) * CHUNK];
            if !blk.iter().zip(&want).all(|(a, b)| (a - b).abs() <= 1e-2 + 1e-4 * b.abs()) {
                ok = false;
                break;
            }
        }

        Ok(RunStats {
            name: "FastWalshTransform".into(),
            mode,
            wall,
            h2d_bytes: h2d,
            d2h_bytes: (total * 4) as u64,
            tasks: self.chunks,
            validated: ok,
        })
    }
}
