//! The streamed benchmark drivers (paper §5 / Fig. 9).
//!
//! Every driver *lowers* to a [`crate::plan::StreamPlan`] — the unified
//! task-DAG IR — and executes through the backend-agnostic plan API
//! ([`crate::plan::Backend`]; the drivers run on the engine-backed
//! [`crate::plan::SimBackend`]):
//!
//! - [`Mode::Baseline`] lowers to the classic non-streamed port: one
//!   bulk H2D of each input, the kernel grid over device windows, one
//!   bulk D2H.  No redundant halo bytes, no per-task DMA latency — the
//!   strongest fair baseline.
//! - [`Mode::Streamed`] lowers to the paper's multi-stream port: the
//!   input partitions into tasks ([`crate::partition`]); each task's
//!   H2D / KEX / D2H chain carries a round-robin lane, so the executor
//!   overlaps transfers of task *i+1* with the kernel of task *i* on
//!   `n` streams.
//!
//! Both plans produce real outputs validated against host oracles
//! ([`oracle`]); `Streamed` must equal `Baseline` bit-for-bit for
//! integer kernels and to float tolerance otherwise (and, since both
//! run the same per-chunk kernels on the same bytes, the plan executor
//! in fact reproduces baseline outputs bit-for-bit for every
//! [`GenericWorkload`] — `tests/plan_integration.rs` asserts it).
//!
//! Most benchmarks instantiate [`GenericWorkload`] — per-chunk input
//! *windows* (which may overlap: that is exactly the false-dependent
//! redundant-boundary transfer of Fig. 7) plus shared broadcast inputs
//! that lower to `Slot::Broadcast` ops.  Needleman–Wunsch lowers its
//! wavefront (diagonal lanes, cross-tile RAW deps) in [`nw`].
//!
//! Task granularity is a first-class knob (DESIGN.md §Tuning):
//! [`GenericWorkload::with_chunks`] re-derives the same workload at a
//! different task count via each [`Windows`] recipe, hotspot chunks
//! its uploads ([`hotspot::Hotspot::lower_at`]), and NW's grid side is
//! its wavefront granularity ([`nw::NeedlemanWunsch::with_grid`]).

pub mod oracle;

pub mod blackscholes;
pub mod cfft;
pub mod convsep;
pub mod dct;
pub mod dotproduct;
pub mod fwt;
pub mod hotspot;
pub mod histogram;
pub mod lavamd;
pub mod matmul;
pub mod nn;
pub mod nw;
pub mod reduction;
pub mod scan;
pub mod stencil;
pub mod transpose;
pub mod vecadd;

pub use blackscholes::BlackScholes;
pub use cfft::ConvFft2d;
pub use convsep::ConvSep;
pub use dct::Dct8x8;
pub use dotproduct::DotProduct;
pub use fwt::Fwt;
pub use hotspot::Hotspot;
pub use histogram::Histogram;
pub use lavamd::LavaMd;
pub use matmul::MatMul;
pub use nn::Nn;
pub use nw::NeedlemanWunsch;
pub use reduction::{ReductionV1, ReductionV2};
pub use scan::PrefixSum;
pub use stencil::Stencil;
pub use transpose::Transpose;
pub use vecadd::VectorAdd;

use std::sync::Arc;
use std::time::Duration;

use crate::hstreams::Context;
use crate::plan::{Backend, HostSlice, PlanRegion, RunConfig, SimBackend, Slot, StreamPlan};
use crate::Result;

/// Execution mode of a driver.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Mode {
    /// Bulk-transfer single-offload port (no streams).
    Baseline,
    /// Multi-stream port with this many streams (1 = serialized pipeline).
    Streamed(usize),
}

/// Outcome of one run.
#[derive(Debug, Clone)]
pub struct RunStats {
    pub name: String,
    pub mode: Mode,
    pub wall: Duration,
    /// Host→device bytes actually transferred (includes halo redundancy
    /// in streamed mode — the lavaMD analysis reads this).
    pub h2d_bytes: u64,
    pub d2h_bytes: u64,
    pub tasks: usize,
    pub validated: bool,
}

/// A streamed benchmark.
pub trait Benchmark: Sync {
    fn name(&self) -> &'static str;
    /// Artifacts to compile (context subset loading).
    fn artifacts(&self) -> Vec<&'static str>;
    /// Run in the given mode and validate the outputs.
    fn run(&self, ctx: &Context, mode: Mode) -> Result<RunStats>;
    /// The declarative workload behind this driver, when granularity
    /// re-chunking preserves bitwise outputs — i.e. the kernel is a
    /// per-element map over its windows
    /// ([`crate::runtime::elastic_artifact`]).  `repro autotune <NAME>`
    /// uses this to tune the joint (streams × granularity) grid via
    /// [`GenericWorkload::with_chunks`]; drivers whose kernels have
    /// per-chunk semantics (histogram bins, per-chunk scans, fixed-tile
    /// wavefronts) return `None` and tune stream count only.
    fn tunable(&self) -> Option<GenericWorkload> {
        None
    }
}

/// Second-tier drivers beyond the paper's 13: extra Table-1 apps with
/// real kernels, plus the Iterative non-streamable control.
pub fn extended_benchmarks(scale: usize) -> Vec<Box<dyn Benchmark>> {
    vec![
        Box::new(Dct8x8::new(scale)),
        Box::new(DotProduct::new(scale)),
        Box::new(Hotspot::new(scale)),
        Box::new(ReductionV1::new(scale)),
        Box::new(ReductionV2::new(scale)),
    ]
}

/// The 13 streamed benchmarks of Fig. 9, in the paper's order of
/// discussion, plus their scale knob.
pub fn fig9_benchmarks(scale: usize) -> Vec<Box<dyn Benchmark>> {
    vec![
        Box::new(Nn::new(scale)),
        Box::new(Fwt::new(scale)),
        Box::new(ConvFft2d::new(scale)),
        Box::new(NeedlemanWunsch::new(scale)),
        Box::new(LavaMd::new(scale)),
        Box::new(ConvSep::new(scale)),
        Box::new(Transpose::new(scale)),
        Box::new(PrefixSum::new(scale)),
        Box::new(Histogram::new(scale)),
        Box::new(MatMul::new(scale)),
        Box::new(VectorAdd::new(scale)),
        Box::new(BlackScholes::new(scale)),
        Box::new(Stencil::new(scale)),
    ]
}

/// Per-chunk input windows over one shared host array.  Windows may
/// overlap (halo / redundant boundary transfer).
pub struct Windows {
    pub host: Arc<Vec<u8>>,
    /// (byte offset, byte length) per chunk.
    pub windows: Vec<(usize, usize)>,
    /// How the windows were derived — kept so the granularity knob can
    /// re-partition the same host array at a different chunk count.
    recipe: WindowRecipe,
}

/// The partitioning rule behind a [`Windows`] (see [`Windows::rechunk`]).
#[derive(Debug, Clone, Copy)]
enum WindowRecipe {
    Disjoint,
    Halo { halo_bytes: usize },
}

impl Windows {
    /// Disjoint equal windows (independent partitioning).
    pub fn disjoint(host: Arc<Vec<u8>>, chunks: usize) -> Self {
        let ranges = crate::partition::chunk_ranges(host.len(), chunks);
        Self {
            host,
            windows: ranges.into_iter().map(|r| (r.start, r.len)).collect(),
            recipe: WindowRecipe::Disjoint,
        }
    }

    /// Overlapping halo windows over a pre-padded host array:
    /// chunk `i` owns `owned` bytes and ships `owned + 2*halo_bytes`.
    pub fn halo(host: Arc<Vec<u8>>, chunks: usize, halo_bytes: usize) -> Self {
        let owned_total = host.len() - 2 * halo_bytes;
        let hcs = crate::partition::halo_chunks(owned_total, chunks, halo_bytes);
        Self {
            host,
            windows: hcs.into_iter().map(|h| (h.xfer_start, h.xfer_len)).collect(),
            recipe: WindowRecipe::Halo { halo_bytes },
        }
    }

    /// Re-partition the same host array into `chunks` windows — the
    /// task-granularity knob.  `None` when the owned range doesn't
    /// split into equal 4-byte-lane-aligned chunks (uneven windows
    /// would shift kernel lanes and break bitwise re-validation).
    pub fn rechunk(&self, chunks: usize) -> Option<Self> {
        let chunks = chunks.max(1);
        let owned = match self.recipe {
            WindowRecipe::Disjoint => self.host.len(),
            WindowRecipe::Halo { halo_bytes } => self.host.len() - 2 * halo_bytes,
        };
        if owned % (chunks * 4) != 0 {
            return None;
        }
        Some(match self.recipe {
            WindowRecipe::Disjoint => Self::disjoint(self.host.clone(), chunks),
            WindowRecipe::Halo { halo_bytes } => {
                Self::halo(self.host.clone(), chunks, halo_bytes)
            }
        })
    }
}

/// A declaratively-specified streamed benchmark: per-chunk windows over
/// N streamed inputs, M broadcast inputs, K per-chunk outputs.  Both
/// execution modes are thin lowerings to the [`StreamPlan`] IR.
///
/// Artifact signature convention: streamed inputs first, then shared
/// inputs — all AOT kernels in this repo follow it.
pub struct GenericWorkload {
    pub name: &'static str,
    pub artifact: &'static str,
    pub streamed_inputs: Vec<Windows>,
    /// Broadcast payloads, shared by every task (uploaded once; the
    /// `Arc` is handed straight to the DMA engine — never deep-cloned).
    pub shared_inputs: Vec<Arc<Vec<u8>>>,
    /// Per-chunk byte length of each output.
    pub output_chunk_bytes: Vec<usize>,
    /// KEX pacing override (models device-side memory-bound kernels
    /// whose FLOP count under-represents their device time).
    pub flops_per_chunk: Option<u64>,
}

impl GenericWorkload {
    pub fn chunks(&self) -> usize {
        self.streamed_inputs[0].windows.len()
    }

    /// Lower to the task-DAG IR for the given mode.
    pub fn lower(&self, mode: Mode) -> StreamPlan {
        match mode {
            Mode::Baseline => self.lower_baseline(),
            Mode::Streamed(_) => self.lower_streamed(),
        }
    }

    /// Re-derive the same workload at a different task count — the
    /// [`crate::plan::Granularity`] knob for declaratively-specified
    /// benchmarks.  Input windows re-partition via their recipes and
    /// per-chunk output sizes rescale so the assembled totals are
    /// unchanged.  `None` when any window set or output doesn't split
    /// evenly at lane alignment.
    ///
    /// Bitwise output equality across chunk counts additionally
    /// requires the kernel to be a per-element map over its windows
    /// (`vector_add`, `black_scholes`, …); kernels with per-chunk
    /// semantics (histogram bins, per-chunk scans) re-lower fine but
    /// mean something different per granularity — don't tune those
    /// against a fixed reference.
    pub fn with_chunks(&self, chunks: usize) -> Option<GenericWorkload> {
        let chunks = chunks.max(1);
        let streamed_inputs: Vec<Windows> =
            self.streamed_inputs.iter().map(|w| w.rechunk(chunks)).collect::<Option<_>>()?;
        let old_chunks = self.chunks();
        let output_chunk_bytes: Vec<usize> = self
            .output_chunk_bytes
            .iter()
            .map(|&b| {
                let total = b * old_chunks;
                (total % (chunks * 4) == 0).then(|| total / chunks)
            })
            .collect::<Option<_>>()?;
        Some(GenericWorkload {
            name: self.name,
            artifact: self.artifact,
            streamed_inputs,
            shared_inputs: self.shared_inputs.clone(),
            output_chunk_bytes,
            flops_per_chunk: self
                .flops_per_chunk
                .map(|f| (f * old_chunks as u64) / chunks as u64),
        })
    }

    /// Execute through the plan executor; returns (wall, per-output
    /// concatenated host bytes, streamed h2d bytes).
    pub fn execute(&self, ctx: &Context, mode: Mode) -> Result<(Duration, Vec<Vec<u8>>, u64)> {
        let n = match mode {
            Mode::Baseline => 1,
            Mode::Streamed(n) => n.max(1),
        };
        let run = SimBackend::new(ctx).run(&self.lower(mode), RunConfig::streams(n))?;
        Ok((run.wall, run.outputs, run.h2d_bytes))
    }

    /// Shared inputs lower to broadcast H2Ds into dedicated buffers;
    /// returns their device regions.
    fn lower_shared(&self, p: &mut StreamPlan) -> Vec<PlanRegion> {
        self.shared_inputs
            .iter()
            .map(|payload| {
                let region = PlanRegion::whole(p.buf(payload.len()), payload.len());
                p.h2d(Slot::Broadcast, HostSlice::whole(payload.clone()), region, vec![]);
                region
            })
            .collect()
    }

    /// Bulk port: whole-array H2D, chunk kernels over device windows,
    /// bulk D2H.
    fn lower_baseline(&self) -> StreamPlan {
        let chunks = self.chunks();
        let mut p = StreamPlan::new(self.name);
        let shared = self.lower_shared(&mut p);

        // One big device buffer per streamed input, uploaded whole.
        let in_bufs: Vec<usize> = self
            .streamed_inputs
            .iter()
            .map(|w| {
                let b = p.buf(w.host.len());
                p.h2d(
                    Slot::Task(0),
                    HostSlice::whole(w.host.clone()),
                    PlanRegion::whole(b, w.host.len()),
                    vec![],
                );
                b
            })
            .collect();
        // One big device buffer per output (chunks back-to-back).
        let out_bufs: Vec<usize> =
            self.output_chunk_bytes.iter().map(|&b| p.buf(b * chunks)).collect();
        let outs: Vec<usize> =
            self.output_chunk_bytes.iter().map(|&b| p.output(b * chunks)).collect();

        for c in 0..chunks {
            let mut ins: Vec<PlanRegion> = self
                .streamed_inputs
                .iter()
                .zip(&in_bufs)
                .map(|(w, &buf)| {
                    let (off, len) = w.windows[c];
                    PlanRegion { buf, off, len }
                })
                .collect();
            ins.extend(shared.iter().copied());
            let kouts: Vec<PlanRegion> = self
                .output_chunk_bytes
                .iter()
                .zip(&out_bufs)
                .map(|(&b, &buf)| PlanRegion { buf, off: c * b, len: b })
                .collect();
            p.kex(Slot::Task(0), self.artifact, ins, kouts, self.flops_per_chunk, 1, vec![]);
        }
        for ((&b, &buf), &out) in self.output_chunk_bytes.iter().zip(&out_bufs).zip(&outs) {
            p.d2h(Slot::Task(0), PlanRegion::whole(buf, b * chunks), out, 0, vec![]);
        }
        p
    }

    /// Multi-stream port: per-task windows (redundant halo bytes ride
    /// along), tasks carrying round-robin lanes.
    fn lower_streamed(&self) -> StreamPlan {
        let chunks = self.chunks();
        let mut p = StreamPlan::new(self.name);
        let shared = self.lower_shared(&mut p);
        let outs: Vec<usize> =
            self.output_chunk_bytes.iter().map(|&b| p.output(b * chunks)).collect();

        for c in 0..chunks {
            let slot = Slot::Task(c);
            let task_in: Vec<PlanRegion> = self
                .streamed_inputs
                .iter()
                .map(|w| {
                    let (off, len) = w.windows[c];
                    let region = PlanRegion::whole(p.buf(len), len);
                    p.h2d(slot, HostSlice { data: w.host.clone(), off, len }, region, vec![]);
                    region
                })
                .collect();
            let mut ins = task_in;
            ins.extend(shared.iter().copied());
            let kouts: Vec<PlanRegion> = self
                .output_chunk_bytes
                .iter()
                .map(|&b| PlanRegion::whole(p.buf(b), b))
                .collect();
            p.kex(slot, self.artifact, ins, kouts.clone(), self.flops_per_chunk, 1, vec![]);
            for ((region, &out), &b) in kouts.iter().zip(&outs).zip(&self.output_chunk_bytes) {
                p.d2h(slot, *region, out, c * b, vec![]);
            }
        }
        p
    }
}

/// Deterministic pseudo-random f32s in [-1, 1) (xorshift; seeded).
pub fn gen_f32(n: usize, seed: u64) -> Vec<f32> {
    let mut state = seed.wrapping_mul(0x9E3779B97F4A7C15) | 1;
    (0..n)
        .map(|_| {
            state ^= state << 13;
            state ^= state >> 7;
            state ^= state << 17;
            ((state >> 40) as f32 / (1u64 << 24) as f32) * 2.0 - 1.0
        })
        .collect()
}

/// Deterministic pseudo-random i32s in [0, bound).
pub fn gen_i32(n: usize, bound: i32, seed: u64) -> Vec<i32> {
    let mut state = seed.wrapping_mul(0x9E3779B97F4A7C15) | 1;
    (0..n)
        .map(|_| {
            state ^= state << 13;
            state ^= state >> 7;
            state ^= state << 17;
            ((state >> 33) as i32).rem_euclid(bound)
        })
        .collect()
}

/// Max |a-b| over two f32 byte buffers.
pub fn max_abs_diff(a: &[u8], b: &[u8]) -> f32 {
    let av = crate::runtime::bytes::to_f32(a);
    let bv = crate::runtime::bytes::to_f32(b);
    av.iter().zip(&bv).map(|(x, y)| (x - y).abs()).fold(0.0, f32::max)
}
