//! The streamed benchmark drivers (paper §5 / Fig. 9).
//!
//! Every driver runs in two modes:
//!
//! - [`Mode::Baseline`] — the classic non-streamed port: one bulk H2D of
//!   each input, the kernel grid, one bulk D2H.  No redundant halo
//!   bytes, no per-task DMA latency — the strongest fair baseline.
//! - [`Mode::Streamed`] — the paper's multi-stream port: the input is
//!   partitioned into tasks ([`crate::partition`]); each task's H2D /
//!   KEX / D2H ride one of `n` streams, so transfers of task *i+1*
//!   overlap the kernel of task *i*.
//!
//! Both modes produce real outputs validated against host oracles
//! ([`oracle`]); `Streamed` must equal `Baseline` bit-for-bit for
//! integer kernels and to float tolerance otherwise.
//!
//! Most benchmarks instantiate [`GenericWorkload`] — per-chunk input
//! *windows* (which may overlap: that is exactly the false-dependent
//! redundant-boundary transfer of Fig. 7) plus shared broadcast inputs.
//! Needleman–Wunsch has its own wavefront driver ([`nw`]).

pub mod oracle;

pub mod blackscholes;
pub mod cfft;
pub mod convsep;
pub mod dct;
pub mod dotproduct;
pub mod fwt;
pub mod hotspot;
pub mod histogram;
pub mod lavamd;
pub mod matmul;
pub mod nn;
pub mod nw;
pub mod reduction;
pub mod scan;
pub mod stencil;
pub mod transpose;
pub mod vecadd;

pub use blackscholes::BlackScholes;
pub use cfft::ConvFft2d;
pub use convsep::ConvSep;
pub use dct::Dct8x8;
pub use dotproduct::DotProduct;
pub use fwt::Fwt;
pub use hotspot::Hotspot;
pub use histogram::Histogram;
pub use lavamd::LavaMd;
pub use matmul::MatMul;
pub use nn::Nn;
pub use nw::NeedlemanWunsch;
pub use reduction::{ReductionV1, ReductionV2};
pub use scan::PrefixSum;
pub use stencil::Stencil;
pub use transpose::Transpose;
pub use vecadd::VectorAdd;

use std::sync::Arc;
use std::time::Duration;

use crate::device::{DevRegion, HostSrc};
use crate::hstreams::Context;
use crate::Result;

/// Execution mode of a driver.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Mode {
    /// Bulk-transfer single-offload port (no streams).
    Baseline,
    /// Multi-stream port with this many streams (1 = serialized pipeline).
    Streamed(usize),
}

/// Outcome of one run.
#[derive(Debug, Clone)]
pub struct RunStats {
    pub name: String,
    pub mode: Mode,
    pub wall: Duration,
    /// Host→device bytes actually transferred (includes halo redundancy
    /// in streamed mode — the lavaMD analysis reads this).
    pub h2d_bytes: u64,
    pub d2h_bytes: u64,
    pub tasks: usize,
    pub validated: bool,
}

/// A streamed benchmark.
pub trait Benchmark: Sync {
    fn name(&self) -> &'static str;
    /// Artifacts to compile (context subset loading).
    fn artifacts(&self) -> Vec<&'static str>;
    /// Run in the given mode and validate the outputs.
    fn run(&self, ctx: &Context, mode: Mode) -> Result<RunStats>;
}

/// Second-tier drivers beyond the paper's 13: extra Table-1 apps with
/// real kernels, plus the Iterative non-streamable control.
pub fn extended_benchmarks(scale: usize) -> Vec<Box<dyn Benchmark>> {
    vec![
        Box::new(Dct8x8::new(scale)),
        Box::new(DotProduct::new(scale)),
        Box::new(Hotspot::new(scale)),
        Box::new(ReductionV1::new(scale)),
        Box::new(ReductionV2::new(scale)),
    ]
}

/// The 13 streamed benchmarks of Fig. 9, in the paper's order of
/// discussion, plus their scale knob.
pub fn fig9_benchmarks(scale: usize) -> Vec<Box<dyn Benchmark>> {
    vec![
        Box::new(Nn::new(scale)),
        Box::new(Fwt::new(scale)),
        Box::new(ConvFft2d::new(scale)),
        Box::new(NeedlemanWunsch::new(scale)),
        Box::new(LavaMd::new(scale)),
        Box::new(ConvSep::new(scale)),
        Box::new(Transpose::new(scale)),
        Box::new(PrefixSum::new(scale)),
        Box::new(Histogram::new(scale)),
        Box::new(MatMul::new(scale)),
        Box::new(VectorAdd::new(scale)),
        Box::new(BlackScholes::new(scale)),
        Box::new(Stencil::new(scale)),
    ]
}

/// Per-chunk input windows over one shared host array.  Windows may
/// overlap (halo / redundant boundary transfer).
pub struct Windows {
    pub host: Arc<Vec<u8>>,
    /// (byte offset, byte length) per chunk.
    pub windows: Vec<(usize, usize)>,
}

impl Windows {
    /// Disjoint equal windows (independent partitioning).
    pub fn disjoint(host: Arc<Vec<u8>>, chunks: usize) -> Self {
        let ranges = crate::partition::chunk_ranges(host.len(), chunks);
        Self { host, windows: ranges.into_iter().map(|r| (r.start, r.len)).collect() }
    }

    /// Overlapping halo windows over a pre-padded host array:
    /// chunk `i` owns `owned` bytes and ships `owned + 2*halo_bytes`.
    pub fn halo(host: Arc<Vec<u8>>, chunks: usize, halo_bytes: usize) -> Self {
        let owned_total = host.len() - 2 * halo_bytes;
        let hcs = crate::partition::halo_chunks(owned_total, chunks, halo_bytes);
        Self {
            host,
            windows: hcs.into_iter().map(|h| (h.xfer_start, h.xfer_len)).collect(),
        }
    }
}

/// A declaratively-specified streamed benchmark: per-chunk windows over
/// N streamed inputs, M broadcast inputs, K per-chunk outputs.
///
/// Artifact signature convention: streamed inputs first, then shared
/// inputs — all AOT kernels in this repo follow it.
pub struct GenericWorkload {
    pub name: &'static str,
    pub artifact: &'static str,
    pub streamed_inputs: Vec<Windows>,
    pub shared_inputs: Vec<Vec<u8>>,
    /// Per-chunk byte length of each output.
    pub output_chunk_bytes: Vec<usize>,
    /// KEX pacing override (models device-side memory-bound kernels
    /// whose FLOP count under-represents their device time).
    pub flops_per_chunk: Option<u64>,
}

impl GenericWorkload {
    pub fn chunks(&self) -> usize {
        self.streamed_inputs[0].windows.len()
    }

    /// Execute; returns (wall, per-output concatenated host bytes,
    /// streamed h2d bytes).
    pub fn execute(&self, ctx: &Context, mode: Mode) -> Result<(Duration, Vec<Vec<u8>>, u64)> {
        match mode {
            Mode::Baseline => self.execute_baseline(ctx),
            Mode::Streamed(n) => self.execute_streamed(ctx, n.max(1)),
        }
    }

    fn alloc_shared(&self, ctx: &Context) -> Result<Vec<DevRegion>> {
        self.shared_inputs
            .iter()
            .map(|payload| {
                Ok(DevRegion::whole(ctx.alloc(payload.len())?, payload.len()))
            })
            .collect()
    }

    /// Bulk port: whole-array H2D, chunk kernels over device windows,
    /// bulk D2H.
    fn execute_baseline(&self, ctx: &Context) -> Result<(Duration, Vec<Vec<u8>>, u64)> {
        let chunks = self.chunks();
        let shared_regions = self.alloc_shared(ctx)?;

        // One big device buffer per streamed input.
        let in_bufs: Vec<DevRegion> = self
            .streamed_inputs
            .iter()
            .map(|w| Ok(DevRegion::whole(ctx.alloc(w.host.len())?, w.host.len())))
            .collect::<Result<_>>()?;
        // One big device buffer per output (chunks back-to-back).
        let out_bufs: Vec<DevRegion> = self
            .output_chunk_bytes
            .iter()
            .map(|&b| Ok(DevRegion::whole(ctx.alloc(b * chunks)?, b * chunks)))
            .collect::<Result<_>>()?;
        let dsts: Vec<crate::device::HostDst> =
            self.output_chunk_bytes.iter().map(|&b| crate::hstreams::host_dst(b * chunks)).collect();

        let mut s = ctx.stream();
        let mut h2d_bytes = 0u64;
        for (payload, region) in self.shared_inputs.iter().zip(&shared_regions) {
            s.h2d(HostSrc::whole(Arc::new(payload.clone())), *region);
            h2d_bytes += region.len as u64;
        }
        for (w, region) in self.streamed_inputs.iter().zip(&in_bufs) {
            s.h2d(HostSrc::whole(w.host.clone()), *region);
            h2d_bytes += region.len as u64;
        }
        for c in 0..chunks {
            let mut ins: Vec<DevRegion> = self
                .streamed_inputs
                .iter()
                .zip(&in_bufs)
                .map(|(w, buf)| {
                    let (off, len) = w.windows[c];
                    DevRegion { buf: buf.buf, off, len }
                })
                .collect();
            ins.extend(shared_regions.iter().copied());
            let outs: Vec<DevRegion> = self
                .output_chunk_bytes
                .iter()
                .zip(&out_bufs)
                .map(|(&b, buf)| DevRegion { buf: buf.buf, off: c * b, len: b })
                .collect();
            s.kex_with(self.artifact, ins, outs, self.flops_per_chunk, 1);
        }
        for (region, dst) in out_bufs.iter().zip(&dsts) {
            s.d2h(*region, dst.clone());
        }
        s.sync();
        // Timeline makespan of the offload: virtual (deterministic) under
        // TimeMode::Virtual, measured wall span under Wallclock.
        let wall = crate::hstreams::makespan(s.events());

        let outputs: Vec<Vec<u8>> = dsts.iter().map(|d| d.data.lock().unwrap().clone()).collect();
        for r in in_bufs.iter().chain(&out_bufs).chain(&shared_regions) {
            ctx.free(r.buf)?;
        }
        Ok((wall, outputs, h2d_bytes))
    }

    /// Multi-stream port: per-task windows (redundant halo bytes ride
    /// along), tasks round-robined over `n` streams.
    fn execute_streamed(&self, ctx: &Context, n: usize) -> Result<(Duration, Vec<Vec<u8>>, u64)> {
        let chunks = self.chunks();
        let shared_regions = self.alloc_shared(ctx)?;

        // Per-task device buffers.
        let mut task_in: Vec<Vec<DevRegion>> = Vec::with_capacity(chunks);
        let mut task_out: Vec<Vec<DevRegion>> = Vec::with_capacity(chunks);
        for c in 0..chunks {
            let ins = self
                .streamed_inputs
                .iter()
                .map(|w| {
                    let (_, len) = w.windows[c];
                    Ok(DevRegion::whole(ctx.alloc(len)?, len))
                })
                .collect::<Result<Vec<_>>>()?;
            let outs = self
                .output_chunk_bytes
                .iter()
                .map(|&b| Ok(DevRegion::whole(ctx.alloc(b)?, b)))
                .collect::<Result<Vec<_>>>()?;
            task_in.push(ins);
            task_out.push(outs);
        }
        let dsts: Vec<crate::device::HostDst> =
            self.output_chunk_bytes.iter().map(|&b| crate::hstreams::host_dst(b * chunks)).collect();

        let mut streams: Vec<_> = (0..n).map(|_| ctx.stream()).collect();
        let mut h2d_bytes = 0u64;

        // Broadcast inputs ride stream 0; every other stream's first op
        // waits on them (hStreams would use an event here too).
        let mut shared_events = Vec::new();
        for (payload, region) in self.shared_inputs.iter().zip(&shared_regions) {
            let e = streams[0].h2d(HostSrc::whole(Arc::new(payload.clone())), *region);
            h2d_bytes += region.len as u64;
            shared_events.push(e);
        }
        for (s, stream) in streams.iter_mut().enumerate().skip(1) {
            if s > 0 {
                for e in &shared_events {
                    stream.wait_event(e.clone());
                }
            }
        }

        for c in 0..chunks {
            let s = &mut streams[c % n];
            for (w, region) in self.streamed_inputs.iter().zip(&task_in[c]) {
                let (off, len) = w.windows[c];
                s.h2d(HostSrc { data: w.host.clone(), off, len }, *region);
                h2d_bytes += len as u64;
            }
            let mut ins = task_in[c].clone();
            ins.extend(shared_regions.iter().copied());
            s.kex_with(self.artifact, ins, task_out[c].clone(), self.flops_per_chunk, 1);
            for ((region, dst), &b) in
                task_out[c].iter().zip(&dsts).zip(&self.output_chunk_bytes)
            {
                s.d2h(*region, crate::device::HostDst { data: dst.data.clone(), off: c * b });
            }
        }
        for s in &streams {
            s.sync();
        }
        let wall = crate::hstreams::makespan(streams.iter().flat_map(|s| s.events()));

        let outputs: Vec<Vec<u8>> = dsts.iter().map(|d| d.data.lock().unwrap().clone()).collect();
        for regions in task_in.iter().chain(&task_out) {
            for r in regions {
                ctx.free(r.buf)?;
            }
        }
        for r in &shared_regions {
            ctx.free(r.buf)?;
        }
        Ok((wall, outputs, h2d_bytes))
    }
}

/// Deterministic pseudo-random f32s in [-1, 1) (xorshift; seeded).
pub fn gen_f32(n: usize, seed: u64) -> Vec<f32> {
    let mut state = seed.wrapping_mul(0x9E3779B97F4A7C15) | 1;
    (0..n)
        .map(|_| {
            state ^= state << 13;
            state ^= state >> 7;
            state ^= state << 17;
            ((state >> 40) as f32 / (1u64 << 24) as f32) * 2.0 - 1.0
        })
        .collect()
}

/// Deterministic pseudo-random i32s in [0, bound).
pub fn gen_i32(n: usize, bound: i32, seed: u64) -> Vec<i32> {
    let mut state = seed.wrapping_mul(0x9E3779B97F4A7C15) | 1;
    (0..n)
        .map(|_| {
            state ^= state << 13;
            state ^= state >> 7;
            state ^= state << 17;
            ((state >> 33) as i32).rem_euclid(bound)
        })
        .collect()
}

/// Max |a-b| over two f32 byte buffers.
pub fn max_abs_diff(a: &[u8], b: &[u8]) -> f32 {
    let av = crate::runtime::bytes::to_f32(a);
    let bv = crate::runtime::bytes::to_f32(b);
    av.iter().zip(&bv).map(|(x, y)| (x - y).abs()).fold(0.0, f32::max)
}
