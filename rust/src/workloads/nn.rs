//! Rodinia `nn` — the paper's Embarrassingly Independent exemplar
//! (Fig. 6) and its biggest streaming win (~85%, Fig. 9).

use std::sync::Arc;

use crate::hstreams::Context;
use crate::runtime::bytes;
use crate::Result;

use super::{gen_f32, oracle, Benchmark, GenericWorkload, Mode, RunStats, Windows};

/// Records per chunk — must match the `nn_dist` AOT artifact.
pub const CHUNK: usize = 16384;

pub struct Nn {
    chunks: usize,
}

impl Nn {
    pub fn new(scale: usize) -> Self {
        Self { chunks: 8 * scale.max(1) }
    }

    /// The declarative workload (shared by `run` and the joint tuner).
    fn workload(&self) -> (GenericWorkload, Vec<f32>, [f32; 2]) {
        let total = self.chunks * CHUNK;
        let records = gen_f32(total * 2, 0xA11CE);
        let target = [0.25f32, -0.5f32];
        let wl = GenericWorkload {
            name: "nn",
            artifact: "nn_dist",
            streamed_inputs: vec![Windows::disjoint(
                Arc::new(bytes::from_f32(&records)),
                self.chunks,
            )],
            shared_inputs: vec![Arc::new(bytes::from_f32(&target))],
            output_chunk_bytes: vec![CHUNK * 4],
            // Paper Fig. 4: KEX ≈ 33% for nn on MIC — the distance kernel's
            // device time is memory-bound, not FLOP-bound.
            flops_per_chunk: Some(650_000),
        };
        (wl, records, target)
    }
}

impl Benchmark for Nn {
    fn name(&self) -> &'static str {
        "nn"
    }

    fn artifacts(&self) -> Vec<&'static str> {
        vec!["nn_dist"]
    }

    fn tunable(&self) -> Option<GenericWorkload> {
        // Per-record distance map (the broadcast target rides along
        // unchanged): re-chunking keeps outputs bitwise identical.
        Some(self.workload().0)
    }

    fn run(&self, ctx: &Context, mode: Mode) -> Result<RunStats> {
        let (wl, records, target) = self.workload();
        let (wall, outputs, h2d) = wl.execute(ctx, mode)?;

        let got = bytes::to_f32(&outputs[0]);
        let want = oracle::nn_dist(&records, target);
        let ok = got.len() == want.len()
            && got.iter().zip(&want).all(|(a, b)| (a - b).abs() <= 1e-4 * (1.0 + b.abs()));

        // Host-side k-NN selection over the streamed distances — the part
        // Rodinia keeps on the CPU.
        let mut idx: Vec<usize> = (0..got.len()).collect();
        idx.sort_by(|&a, &b| got[a].partial_cmp(&got[b]).unwrap());
        let _nearest8 = &idx[..8.min(idx.len())];

        Ok(RunStats {
            name: "nn".into(),
            mode,
            wall,
            h2d_bytes: h2d,
            d2h_bytes: (self.chunks * CHUNK * 4) as u64,
            tasks: self.chunks,
            validated: ok,
        })
    }
}
