//! Parboil `stencil` — False Dependent with the smallest possible halo
//! (one row per side): the favourable end of the Fig. 7 spectrum.

use std::sync::Arc;

use crate::hstreams::Context;
use crate::runtime::bytes;
use crate::Result;

use super::{gen_f32, oracle, Benchmark, GenericWorkload, Mode, RunStats, Windows};

/// Band geometry — must match the `stencil2d` AOT artifact.
pub const ROWS: usize = 128;
pub const COLS: usize = 512;

pub struct Stencil {
    chunks: usize,
}

impl Stencil {
    pub fn new(scale: usize) -> Self {
        Self { chunks: 8 * scale.max(1) }
    }
}

impl Benchmark for Stencil {
    fn name(&self) -> &'static str {
        "stencil"
    }

    fn artifacts(&self) -> Vec<&'static str> {
        vec!["stencil2d"]
    }

    fn run(&self, ctx: &Context, mode: Mode) -> Result<RunStats> {
        let rows = self.chunks * ROWS;
        let field = gen_f32(rows * COLS, 81);
        let mut padded = vec![0.0f32; (rows + 2) * COLS];
        padded[COLS..(rows + 1) * COLS].copy_from_slice(&field);

        let wl = GenericWorkload {
            name: "stencil",
            artifact: "stencil2d",
            streamed_inputs: vec![Windows::halo(
                Arc::new(bytes::from_f32(&padded)),
                self.chunks,
                COLS * 4,
            )],
            shared_inputs: vec![],
            output_chunk_bytes: vec![ROWS * COLS * 4],
            // Memory-bound 5-point sweep: device time per band.
            flops_per_chunk: Some(7_100_000),
        };
        let (wall, outputs, h2d) = wl.execute(ctx, mode)?;

        let got = bytes::to_f32(&outputs[0]);
        let want = oracle::stencil2d(&padded, rows, COLS);
        let ok = got
            .iter()
            .zip(&want)
            .all(|(a, b)| (a - b).abs() <= 1e-4 + 1e-4 * b.abs());

        Ok(RunStats {
            name: "stencil".into(),
            mode,
            wall,
            h2d_bytes: h2d,
            d2h_bytes: (rows * COLS * 4) as u64,
            tasks: self.chunks,
            validated: ok,
        })
    }
}
