//! Rodinia `lavaMD` — the paper's **negative case** (§5): the halo
//! (2·111 elements) is comparable to the task itself (~250 elements), so
//! the streamed port nearly doubles the transferred bytes and per-task
//! DMA latency swamps the overlap — multiple streams do *not* beat the
//! bulk offload.

use std::sync::Arc;

use crate::hstreams::Context;
use crate::partition::halo_overhead_ratio;
use crate::runtime::bytes;
use crate::Result;

use super::{gen_f32, oracle, Benchmark, GenericWorkload, Mode, RunStats, Windows};

/// Task geometry — must match the `lavamd_box` AOT artifact.
pub const CHUNK: usize = 256;
pub const HALO: usize = 111;

pub struct LavaMd {
    chunks: usize,
}

impl LavaMd {
    pub fn new(scale: usize) -> Self {
        Self { chunks: 64 * scale.max(1) }
    }

    /// The paper's §5 analysis: redundant boundary vs task size.
    pub fn halo_ratio() -> f64 {
        halo_overhead_ratio(CHUNK, HALO)
    }
}

impl Benchmark for LavaMd {
    fn name(&self) -> &'static str {
        "lavaMD"
    }

    fn artifacts(&self) -> Vec<&'static str> {
        vec!["lavamd_box"]
    }

    fn run(&self, ctx: &Context, mode: Mode) -> Result<RunStats> {
        let n = self.chunks * CHUNK;
        let particles = gen_f32(n, 91);
        let mut padded = vec![0.0f32; n + 2 * HALO];
        padded[HALO..HALO + n].copy_from_slice(&particles);

        let wl = GenericWorkload {
            name: "lavaMD",
            artifact: "lavamd_box",
            streamed_inputs: vec![Windows::halo(
                Arc::new(bytes::from_f32(&padded)),
                self.chunks,
                HALO * 4,
            )],
            shared_inputs: vec![],
            output_chunk_bytes: vec![CHUNK * 4],
            // Per-box kernel time ~ halo-inflated transfer time: the
            // §5 balance (H2D 0.3476s ≈ KEX 0.3380s) that makes
            // streaming unprofitable.
            flops_per_chunk: Some(150_000),
        };
        let (wall, outputs, h2d) = wl.execute(ctx, mode)?;

        let got = bytes::to_f32(&outputs[0]);
        let want = oracle::lavamd(&padded, n, HALO);
        let ok = got
            .iter()
            .zip(&want)
            .all(|(a, b)| (a - b).abs() <= 1e-3 + 1e-3 * b.abs());

        Ok(RunStats {
            name: "lavaMD".into(),
            mode,
            wall,
            h2d_bytes: h2d,
            d2h_bytes: (n * 4) as u64,
            tasks: self.chunks,
            validated: ok,
        })
    }
}
