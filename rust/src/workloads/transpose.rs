//! NVIDIA `Transpose` — independent row bands; the paper's moderate-R
//! case (R ≈ 14–20%, gain 8–14% depending on dataset).

use std::sync::Arc;

use crate::hstreams::Context;
use crate::runtime::bytes;
use crate::Result;

use super::{gen_f32, oracle, Benchmark, GenericWorkload, Mode, RunStats, Windows};

/// Band geometry — must match the `transpose` AOT artifact.
pub const ROWS: usize = 128;
pub const COLS: usize = 1024;

/// Device-side transpose is memory-bound; this effective FLOP count
/// models its device time (≈ 60 "flop-equivalents"/element at the MIC
/// profile's GFLOP/s — see DESIGN.md §2).
const FLOPS_PER_CHUNK: u64 = (84 * ROWS * COLS) as u64;

pub struct Transpose {
    chunks: usize,
}

impl Transpose {
    pub fn new(scale: usize) -> Self {
        Self { chunks: 8 * scale.max(1) }
    }
}

impl Benchmark for Transpose {
    fn name(&self) -> &'static str {
        "Transpose"
    }

    fn artifacts(&self) -> Vec<&'static str> {
        vec!["transpose"]
    }

    fn run(&self, ctx: &Context, mode: Mode) -> Result<RunStats> {
        let total = self.chunks * ROWS * COLS;
        let x = gen_f32(total, 7);

        let wl = GenericWorkload {
            name: "Transpose",
            artifact: "transpose",
            streamed_inputs: vec![Windows::disjoint(Arc::new(bytes::from_f32(&x)), self.chunks)],
            shared_inputs: vec![],
            output_chunk_bytes: vec![ROWS * COLS * 4],
            flops_per_chunk: Some(FLOPS_PER_CHUNK),
        };
        let (wall, outputs, h2d) = wl.execute(ctx, mode)?;

        // Output is a sequence of transposed [COLS, ROWS] strips; strip i
        // holds columns of band i.  Validate each strip.
        let got = bytes::to_f32(&outputs[0]);
        let mut ok = true;
        for c in 0..self.chunks {
            let band = &x[c * ROWS * COLS..(c + 1) * ROWS * COLS];
            let want = oracle::transpose(band, ROWS, COLS);
            let strip = &got[c * ROWS * COLS..(c + 1) * ROWS * COLS];
            if strip != want.as_slice() {
                ok = false;
                break;
            }
        }

        Ok(RunStats {
            name: "Transpose".into(),
            mode,
            wall,
            h2d_bytes: h2d,
            d2h_bytes: (total * 4) as u64,
            tasks: self.chunks,
            validated: ok,
        })
    }
}
