//! NVIDIA SDK `DCT8x8` — blockwise 2D DCT over independent row bands
//! (JPEG-style).  A second-tier streamable benchmark beyond the paper's
//! 13, exercising the MXU-batched basis-matmul kernel.

use std::sync::Arc;

use crate::hstreams::Context;
use crate::runtime::bytes;
use crate::Result;

use super::{gen_f32, oracle, Benchmark, GenericWorkload, Mode, RunStats, Windows};

/// Band geometry — must match the `dct8x8` AOT artifact.
pub const ROWS: usize = 64;
pub const COLS: usize = 512;

pub struct Dct8x8 {
    chunks: usize,
}

impl Dct8x8 {
    pub fn new(scale: usize) -> Self {
        Self { chunks: 8 * scale.max(1) }
    }
}

impl Benchmark for Dct8x8 {
    fn name(&self) -> &'static str {
        "DCT8x8"
    }

    fn artifacts(&self) -> Vec<&'static str> {
        vec!["dct8x8"]
    }

    fn run(&self, ctx: &Context, mode: Mode) -> Result<RunStats> {
        let total = self.chunks * ROWS * COLS;
        let x = gen_f32(total, 201);

        // Orthonormal DCT basis, broadcast once (artifact input 2).
        let mut basis = vec![0.0f32; 64];
        for k in 0..8 {
            for n in 0..8 {
                let v = (std::f64::consts::PI * (2 * n + 1) as f64 * k as f64 / 16.0).cos();
                basis[k * 8 + n] =
                    (0.5 * if k == 0 { v / std::f64::consts::SQRT_2 } else { v }) as f32;
            }
        }
        let wl = GenericWorkload {
            name: "DCT8x8",
            artifact: "dct8x8",
            streamed_inputs: vec![Windows::disjoint(Arc::new(bytes::from_f32(&x)), self.chunks)],
            shared_inputs: vec![Arc::new(bytes::from_f32(&basis))],
            output_chunk_bytes: vec![ROWS * COLS * 4],
            // Two basis matmuls per block on the device.
            flops_per_chunk: Some(2_100_000),
        };
        let (wall, outputs, h2d) = wl.execute(ctx, mode)?;

        let got = bytes::to_f32(&outputs[0]);
        let mut ok = true;
        for c in 0..self.chunks {
            let band = &x[c * ROWS * COLS..(c + 1) * ROWS * COLS];
            let want = oracle::dct8x8(band, ROWS, COLS);
            let out = &got[c * ROWS * COLS..(c + 1) * ROWS * COLS];
            if !out.iter().zip(&want).all(|(a, b)| (a - b).abs() <= 1e-3 + 1e-3 * b.abs()) {
                ok = false;
                break;
            }
        }

        Ok(RunStats {
            name: "DCT8x8".into(),
            mode,
            wall,
            h2d_bytes: h2d,
            d2h_bytes: (total * 4) as u64,
            tasks: self.chunks,
            validated: ok,
        })
    }
}
