//! NVIDIA `ConvolutionSeparable` — False Dependent with a *small* halo
//! (8 rows per side of a 128-row band): redundant boundary transfer is
//! ~12% of the task, so streaming pays (paper: R ≈ 19%, gain ≈ 45%).

use std::sync::Arc;

use crate::hstreams::Context;
use crate::runtime::bytes;
use crate::Result;

use super::{gen_f32, oracle, Benchmark, GenericWorkload, Mode, RunStats, Windows};

/// Band geometry — must match the `conv_sep` AOT artifact.
pub const ROWS: usize = 128;
pub const COLS: usize = 256;
pub const HALO: usize = 8;

pub struct ConvSep {
    chunks: usize,
}

impl ConvSep {
    pub fn new(scale: usize) -> Self {
        Self { chunks: 8 * scale.max(1) }
    }
}

impl Benchmark for ConvSep {
    fn name(&self) -> &'static str {
        "ConvolutionSeparable"
    }

    fn artifacts(&self) -> Vec<&'static str> {
        vec!["conv_sep"]
    }

    fn run(&self, ctx: &Context, mode: Mode) -> Result<RunStats> {
        let rows = self.chunks * ROWS;
        // Zero-padded image: HALO rows above and below.
        let img = gen_f32(rows * COLS, 71);
        let mut padded = vec![0.0f32; (rows + 2 * HALO) * COLS];
        padded[HALO * COLS..(HALO + rows) * COLS].copy_from_slice(&img);
        let krow = gen_f32(2 * HALO + 1, 72);
        let kcol = gen_f32(2 * HALO + 1, 73);

        let wl = GenericWorkload {
            name: "ConvolutionSeparable",
            artifact: "conv_sep",
            streamed_inputs: vec![Windows::halo(
                Arc::new(bytes::from_f32(&padded)),
                self.chunks,
                HALO * COLS * 4,
            )],
            shared_inputs: vec![Arc::new(bytes::from_f32(&krow)), Arc::new(bytes::from_f32(&kcol))],
            output_chunk_bytes: vec![ROWS * COLS * 4],
            // Device time of both passes on the simulated MIC (paper §5:
            // R ≈ 19%, gain ≈ 45%).
            flops_per_chunk: Some(4_000_000),
        };
        let (wall, outputs, h2d) = wl.execute(ctx, mode)?;

        let got = bytes::to_f32(&outputs[0]);
        let want = oracle::conv_sep(&padded, rows, COLS, &krow, &kcol);
        let ok = got
            .iter()
            .zip(&want)
            .all(|(a, b)| (a - b).abs() <= 1e-3 + 1e-3 * b.abs());

        Ok(RunStats {
            name: "ConvolutionSeparable".into(),
            mode,
            wall,
            h2d_bytes: h2d,
            d2h_bytes: (rows * COLS * 4) as u64,
            tasks: self.chunks,
            validated: ok,
        })
    }
}
