//! NVIDIA `MatrixMul` / Parboil `sgemm` — independent row bands with a
//! broadcast B; compute-bound, so R is small and the streaming gain sits
//! at the paper's 8% lower end.

use std::sync::Arc;

use crate::hstreams::Context;
use crate::runtime::bytes;
use crate::Result;

use super::{gen_f32, oracle, Benchmark, GenericWorkload, Mode, RunStats, Windows};

/// Band geometry — must match the `matmul` AOT artifact.
pub const M: usize = 128;
pub const K: usize = 256;
pub const N: usize = 256;

pub struct MatMul {
    chunks: usize,
}

impl MatMul {
    pub fn new(scale: usize) -> Self {
        Self { chunks: 8 * scale.max(1) }
    }
}

impl Benchmark for MatMul {
    fn name(&self) -> &'static str {
        "MatrixMul"
    }

    fn artifacts(&self) -> Vec<&'static str> {
        vec!["matmul"]
    }

    fn run(&self, ctx: &Context, mode: Mode) -> Result<RunStats> {
        let a = gen_f32(self.chunks * M * K, 21);
        let b = gen_f32(K * N, 22);

        let wl = GenericWorkload {
            name: "MatrixMul",
            artifact: "matmul",
            streamed_inputs: vec![Windows::disjoint(Arc::new(bytes::from_f32(&a)), self.chunks)],
            shared_inputs: vec![Arc::new(bytes::from_f32(&b))],
            output_chunk_bytes: vec![M * N * 4],
            // Effective device GEMM time per band (the paper's 8% regime:
            // compute-bound, small R).
            flops_per_chunk: Some(8_000_000),
        };
        let (wall, outputs, h2d) = wl.execute(ctx, mode)?;

        let got = bytes::to_f32(&outputs[0]);
        let want = oracle::matmul(&a, &b, self.chunks * M, K, N);
        let ok = got.len() == want.len()
            && got
                .iter()
                .zip(&want)
                .all(|(x, y)| (x - y).abs() <= 1e-3 * (1.0 + y.abs()));

        Ok(RunStats {
            name: "MatrixMul".into(),
            mode,
            wall,
            h2d_bytes: h2d,
            d2h_bytes: (self.chunks * M * N * 4) as u64,
            tasks: self.chunks,
            validated: ok,
        })
    }
}
