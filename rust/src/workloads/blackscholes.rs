//! NVIDIA `BlackScholes` — pointwise option pricing; three streamed
//! input arrays, two streamed outputs.

use std::sync::Arc;

use crate::hstreams::Context;
use crate::runtime::bytes;
use crate::Result;

use super::{gen_f32, oracle, Benchmark, GenericWorkload, Mode, RunStats, Windows};

pub const CHUNK: usize = 16384;

pub struct BlackScholes {
    chunks: usize,
}

impl BlackScholes {
    pub fn new(scale: usize) -> Self {
        Self { chunks: 8 * scale.max(1) }
    }
}

fn uniform(n: usize, lo: f32, hi: f32, seed: u64) -> Vec<f32> {
    gen_f32(n, seed).into_iter().map(|v| lo + (v * 0.5 + 0.5) * (hi - lo)).collect()
}

impl BlackScholes {
    /// The declarative workload (shared by `run` and the joint tuner).
    fn workload(&self) -> (GenericWorkload, Vec<f32>, Vec<f32>, Vec<f32>) {
        let total = self.chunks * CHUNK;
        let s = uniform(total, 5.0, 30.0, 51);
        let k = uniform(total, 1.0, 100.0, 52);
        let t = uniform(total, 0.25, 10.0, 53);
        let wl = GenericWorkload {
            name: "BlackScholes",
            artifact: "black_scholes",
            streamed_inputs: vec![
                Windows::disjoint(Arc::new(bytes::from_f32(&s)), self.chunks),
                Windows::disjoint(Arc::new(bytes::from_f32(&k)), self.chunks),
                Windows::disjoint(Arc::new(bytes::from_f32(&t)), self.chunks),
            ],
            shared_inputs: vec![],
            output_chunk_bytes: vec![CHUNK * 4, CHUNK * 4],
            // Transcendental-heavy pricing: ~250 device ops per option.
            flops_per_chunk: Some(4_000_000),
        };
        (wl, s, k, t)
    }
}

impl Benchmark for BlackScholes {
    fn name(&self) -> &'static str {
        "BlackScholes"
    }

    fn artifacts(&self) -> Vec<&'static str> {
        vec!["black_scholes"]
    }

    fn tunable(&self) -> Option<GenericWorkload> {
        // Per-option pricing is a pure element map over all three
        // streamed arrays: any chunking assembles the same bytes.
        Some(self.workload().0)
    }

    fn run(&self, ctx: &Context, mode: Mode) -> Result<RunStats> {
        let total = self.chunks * CHUNK;
        let (wl, s, k, t) = self.workload();
        let (wall, outputs, h2d) = wl.execute(ctx, mode)?;

        let call = bytes::to_f32(&outputs[0]);
        let put = bytes::to_f32(&outputs[1]);
        let (wcall, wput) = oracle::black_scholes(&s, &k, &t);
        let close = |a: &[f32], b: &[f32]| {
            a.iter().zip(b).all(|(x, y)| (x - y).abs() <= 5e-3 + 2e-3 * y.abs())
        };
        let ok = close(&call, &wcall) && close(&put, &wput);

        Ok(RunStats {
            name: "BlackScholes".into(),
            mode,
            wall,
            h2d_bytes: h2d,
            d2h_bytes: (2 * total * 4) as u64,
            tasks: self.chunks,
            validated: ok,
        })
    }
}
