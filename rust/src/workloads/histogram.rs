//! NVIDIA `Histogram` — independent per-chunk counts merged on the
//! host (the paper's `hg`); D2H is 1 KiB per task, so R_H2D dominates.

use std::sync::Arc;

use crate::hstreams::Context;
use crate::runtime::bytes;
use crate::Result;

use super::{gen_i32, oracle, Benchmark, GenericWorkload, Mode, RunStats, Windows};

pub const CHUNK: usize = 16384;
pub const BINS: usize = 256;

pub struct Histogram {
    chunks: usize,
}

impl Histogram {
    pub fn new(scale: usize) -> Self {
        Self { chunks: 16 * scale.max(1) }
    }
}

impl Benchmark for Histogram {
    fn name(&self) -> &'static str {
        "Histogram"
    }

    fn artifacts(&self) -> Vec<&'static str> {
        vec!["histogram"]
    }

    fn run(&self, ctx: &Context, mode: Mode) -> Result<RunStats> {
        let total = self.chunks * CHUNK;
        let x = gen_i32(total, BINS as i32, 41);

        let wl = GenericWorkload {
            name: "Histogram",
            artifact: "histogram",
            streamed_inputs: vec![Windows::disjoint(Arc::new(bytes::from_i32(&x)), self.chunks)],
            shared_inputs: vec![],
            output_chunk_bytes: vec![BINS * 4],
            // Privatized-histogram merge passes on the device.
            flops_per_chunk: Some(6_500_000),
        };
        let (wall, outputs, h2d) = wl.execute(ctx, mode)?;

        // Host merge of the per-chunk histograms.
        let parts = bytes::to_i32(&outputs[0]);
        let mut merged = vec![0i32; BINS];
        for c in 0..self.chunks {
            for b in 0..BINS {
                merged[b] += parts[c * BINS + b];
            }
        }

        let ok = merged == oracle::histogram(&x);

        Ok(RunStats {
            name: "Histogram".into(),
            mode,
            wall,
            h2d_bytes: h2d,
            d2h_bytes: (self.chunks * BINS * 4) as u64,
            tasks: self.chunks,
            validated: ok,
        })
    }
}
