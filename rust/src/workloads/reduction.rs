//! NVIDIA `Reduction` v1/v2 — the Fig. 3 code-variant study: v1 reduces
//! fully on the device (scalar D2H), v2 ships per-block partials back
//! for a host final pass (256x the D2H traffic).

use std::sync::Arc;

use crate::hstreams::Context;
use crate::runtime::bytes;
use crate::Result;

use super::{gen_f32, Benchmark, GenericWorkload, Mode, RunStats, Windows};

pub const CHUNK: usize = 65536;
pub const BLOCKS: usize = 256;

fn run_variant(
    name: &'static str,
    artifact: &'static str,
    out_bytes: usize,
    chunks: usize,
    ctx: &Context,
    mode: Mode,
) -> Result<RunStats> {
    let total = chunks * CHUNK;
    let x = gen_f32(total, 111);

    let wl = GenericWorkload {
        name,
        artifact,
        streamed_inputs: vec![Windows::disjoint(Arc::new(bytes::from_f32(&x)), chunks)],
        shared_inputs: vec![],
        output_chunk_bytes: vec![out_bytes],
        flops_per_chunk: None,
    };
    let (wall, outputs, h2d) = wl.execute(ctx, mode)?;

    // Host final pass: sum whatever came back (1 or 256 partials/chunk).
    let partials = bytes::to_f32(&outputs[0]);
    let got: f64 = partials.iter().map(|&v| v as f64).sum();

    let want: f64 = x.iter().map(|&v| v as f64).sum();
    let ok = (got - want).abs() <= 1e-2 + 1e-4 * want.abs();

    Ok(RunStats {
        name: name.into(),
        mode,
        wall,
        h2d_bytes: h2d,
        d2h_bytes: (chunks * out_bytes) as u64,
        tasks: chunks,
        validated: ok,
    })
}

/// Variant 1: whole reduction on the accelerator.
pub struct ReductionV1 {
    chunks: usize,
}

impl ReductionV1 {
    pub fn new(scale: usize) -> Self {
        Self { chunks: 8 * scale.max(1) }
    }
}

impl Benchmark for ReductionV1 {
    fn name(&self) -> &'static str {
        "Reduction"
    }

    fn artifacts(&self) -> Vec<&'static str> {
        vec!["reduction_v1"]
    }

    fn run(&self, ctx: &Context, mode: Mode) -> Result<RunStats> {
        run_variant("Reduction", "reduction_v1", 4, self.chunks, ctx, mode)
    }
}

/// Variant 2: partial sums return to the host.
pub struct ReductionV2 {
    chunks: usize,
}

impl ReductionV2 {
    pub fn new(scale: usize) -> Self {
        Self { chunks: 8 * scale.max(1) }
    }
}

impl Benchmark for ReductionV2 {
    fn name(&self) -> &'static str {
        "Reduction-2"
    }

    fn artifacts(&self) -> Vec<&'static str> {
        vec!["reduction_v2"]
    }

    fn run(&self, ctx: &Context, mode: Mode) -> Result<RunStats> {
        run_variant("Reduction-2", "reduction_v2", BLOCKS * 4, self.chunks, ctx, mode)
    }
}
