//! Host-side Rust oracles for validating driver outputs.
//!
//! Independent reimplementations (no XLA, no chunking) of each
//! benchmark's math, so a partitioning bug and a kernel bug can't
//! cancel.

/// Euclidean distances of (lat, lng) records to a target.
pub fn nn_dist(records: &[f32], target: [f32; 2]) -> Vec<f32> {
    records
        .chunks_exact(2)
        .map(|r| ((r[0] - target[0]).powi(2) + (r[1] - target[1]).powi(2)).sqrt())
        .collect()
}

/// c = a + b.
pub fn vector_add(a: &[f32], b: &[f32]) -> Vec<f32> {
    a.iter().zip(b).map(|(x, y)| x + y).collect()
}

/// In-place iterative Walsh–Hadamard transform of a power-of-two block.
pub fn fwt_block(x: &mut [f32]) {
    let n = x.len();
    let mut h = 1;
    while h < n {
        for i in (0..n).step_by(h * 2) {
            for j in i..i + h {
                let (a, b) = (x[j], x[j + h]);
                x[j] = a + b;
                x[j + h] = a - b;
            }
        }
        h *= 2;
    }
}

/// Transpose an r x c row-major matrix.
pub fn transpose(x: &[f32], r: usize, c: usize) -> Vec<f32> {
    let mut out = vec![0.0f32; r * c];
    for i in 0..r {
        for j in 0..c {
            out[j * r + i] = x[i * c + j];
        }
    }
    out
}

/// Naive matmul: (m x k) @ (k x n), f64 accumulation.
pub fn matmul(a: &[f32], b: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
    let mut out = vec![0.0f32; m * n];
    for i in 0..m {
        for j in 0..n {
            let mut acc = 0.0f64;
            for p in 0..k {
                acc += a[i * k + p] as f64 * b[p * n + j] as f64;
            }
            out[i * n + j] = acc as f32;
        }
    }
    out
}

/// Inclusive prefix sum (f64 accumulation).
pub fn prefix_sum(x: &[f32]) -> Vec<f32> {
    let mut acc = 0.0f64;
    x.iter()
        .map(|&v| {
            acc += v as f64;
            acc as f32
        })
        .collect()
}

/// 256-bin histogram.
pub fn histogram(x: &[i32]) -> Vec<i32> {
    let mut bins = vec![0i32; 256];
    for &v in x {
        bins[v as usize] += 1;
    }
    bins
}

/// Abramowitz–Stegun 7.1.26 erf approximation (|err| < 1.5e-7).
pub fn erf(x: f64) -> f64 {
    let sign = if x < 0.0 { -1.0 } else { 1.0 };
    let x = x.abs();
    let t = 1.0 / (1.0 + 0.3275911 * x);
    let y = 1.0
        - (((((1.061405429 * t - 1.453152027) * t) + 1.421413741) * t - 0.284496736) * t
            + 0.254829592)
            * t
            * (-x * x).exp();
    sign * y
}

fn cnd(d: f64) -> f64 {
    0.5 * (1.0 + erf(d / std::f64::consts::SQRT_2))
}

/// Black–Scholes call/put prices (SDK constants r=0.02, v=0.30).
pub fn black_scholes(s: &[f32], k: &[f32], t: &[f32]) -> (Vec<f32>, Vec<f32>) {
    const R: f64 = 0.02;
    const V: f64 = 0.30;
    let mut call = Vec::with_capacity(s.len());
    let mut put = Vec::with_capacity(s.len());
    for i in 0..s.len() {
        let (s, k, t) = (s[i] as f64, k[i] as f64, t[i] as f64);
        let sqrt_t = t.sqrt();
        let d1 = ((s / k).ln() + (R + 0.5 * V * V) * t) / (V * sqrt_t);
        let d2 = d1 - V * sqrt_t;
        let e = (-R * t).exp();
        call.push((s * cnd(d1) - k * e * cnd(d2)) as f32);
        put.push((k * e * cnd(-d2) - s * cnd(-d1)) as f32);
    }
    (call, put)
}

/// 5-point Jacobi step over a (rows+2) x cols padded field.
pub fn stencil2d(padded: &[f32], rows: usize, cols: usize) -> Vec<f32> {
    const C0: f32 = 0.5;
    const C1: f32 = 0.125;
    let mut out = vec![0.0f32; rows * cols];
    for r in 0..rows {
        for c in 0..cols {
            let center = padded[(r + 1) * cols + c];
            let north = padded[r * cols + c];
            let south = padded[(r + 2) * cols + c];
            let west = if c > 0 { padded[(r + 1) * cols + c - 1] } else { 0.0 };
            let east = if c + 1 < cols { padded[(r + 1) * cols + c + 1] } else { 0.0 };
            out[r * cols + c] = C0 * center + C1 * (north + south + west + east);
        }
    }
    out
}

/// Separable convolution over a halo-padded band (matches
/// `kernels/convsep.py`: zero row-padding inside the row pass).
pub fn conv_sep(padded: &[f32], rows: usize, cols: usize, krow: &[f32], kcol: &[f32]) -> Vec<f32> {
    let h = (krow.len() - 1) / 2;
    let mut mid = vec![0.0f64; rows * cols];
    for k in 0..2 * h + 1 {
        for r in 0..rows {
            for c in 0..cols {
                mid[r * cols + c] += padded[(r + k) * cols + c] as f64 * kcol[k] as f64;
            }
        }
    }
    let mut out = vec![0.0f32; rows * cols];
    for r in 0..rows {
        for c in 0..cols {
            let mut acc = 0.0f64;
            for k in 0..2 * h + 1 {
                let cc = c as isize + k as isize - h as isize;
                if cc >= 0 && (cc as usize) < cols {
                    acc += mid[r * cols + cc as usize] * krow[k] as f64;
                }
            }
            out[r * cols + c] = acc as f32;
        }
    }
    out
}

/// lavaMD window potential over a halo-padded particle line.
pub fn lavamd(padded: &[f32], n: usize, h: usize) -> Vec<f32> {
    (0..n)
        .map(|i| {
            let c = padded[h + i] as f64;
            let mut acc = 0.0f64;
            for j in i..i + 2 * h + 1 {
                let d2 = (c - padded[j] as f64).powi(2);
                acc += 1.0 / (1.0 + d2);
            }
            (acc - 1.0) as f32
        })
        .collect()
}

/// Full Needleman–Wunsch score matrix with Rodinia boundary conditions
/// (first row/col = -penalty * 1-based index).
pub fn nw_full(sub: &[i32], size: usize, penalty: i32) -> Vec<i32> {
    let mut e = vec![0i64; (size + 1) * (size + 1)];
    for j in 0..=size {
        e[j] = -(penalty as i64) * j as i64;
    }
    for i in 0..=size {
        e[i * (size + 1)] = -(penalty as i64) * i as i64;
    }
    for i in 1..=size {
        for j in 1..=size {
            let diag = e[(i - 1) * (size + 1) + j - 1] + sub[(i - 1) * size + j - 1] as i64;
            let up = e[(i - 1) * (size + 1) + j] - penalty as i64;
            let left = e[i * (size + 1) + j - 1] - penalty as i64;
            e[i * (size + 1) + j] = diag.max(up).max(left);
        }
    }
    let mut out = vec![0i32; size * size];
    for i in 0..size {
        for j in 0..size {
            out[i * size + j] = e[(i + 1) * (size + 1) + j + 1] as i32;
        }
    }
    out
}

/// Circular 2D convolution (naive O(T^4) — test-sized tiles only).
pub fn cfft2d_circular(tile: &[f32], filt: &[f32], t: usize) -> Vec<f32> {
    let mut out = vec![0.0f32; t * t];
    for oi in 0..t {
        for oj in 0..t {
            let mut acc = 0.0f64;
            for ki in 0..t {
                for kj in 0..t {
                    let si = (oi + t - ki) % t;
                    let sj = (oj + t - kj) % t;
                    acc += tile[si * t + sj] as f64 * filt[ki * t + kj] as f64;
                }
            }
            out[oi * t + oj] = acc as f32;
        }
    }
    out
}

/// Blockwise 8x8 orthonormal DCT-II (f64 accumulation).
pub fn dct8x8(x: &[f32], rows: usize, cols: usize) -> Vec<f32> {
    // Basis C[k][n] = s(k)/2 * cos(pi (2n+1) k / 16).
    let mut c = [[0.0f64; 8]; 8];
    for k in 0..8 {
        for n in 0..8 {
            let v = (std::f64::consts::PI * (2 * n + 1) as f64 * k as f64 / 16.0).cos();
            c[k][n] = 0.5 * if k == 0 { v / std::f64::consts::SQRT_2 } else { v };
        }
    }
    let mut out = vec![0.0f32; rows * cols];
    for bi in 0..rows / 8 {
        for bj in 0..cols / 8 {
            // tmp = C @ B
            let mut tmp = [[0.0f64; 8]; 8];
            for i in 0..8 {
                for j in 0..8 {
                    let mut acc = 0.0;
                    for p in 0..8 {
                        acc += c[i][p] * x[(bi * 8 + p) * cols + bj * 8 + j] as f64;
                    }
                    tmp[i][j] = acc;
                }
            }
            // out = tmp @ C^T
            for i in 0..8 {
                for j in 0..8 {
                    let mut acc = 0.0;
                    for p in 0..8 {
                        acc += tmp[i][p] * c[j][p];
                    }
                    out[(bi * 8 + i) * cols + bj * 8 + j] = acc as f32;
                }
            }
        }
    }
    out
}

/// One hotspot diffusion step over an n x n grid (boundary preserved).
pub fn hotspot_step(temp: &[f32], power: &[f32], n: usize) -> Vec<f32> {
    const K: f64 = 0.1;
    let mut out = temp.to_vec();
    for r in 1..n - 1 {
        for c in 1..n - 1 {
            let t = temp[r * n + c] as f64;
            let lap = temp[(r - 1) * n + c] as f64
                + temp[(r + 1) * n + c] as f64
                + temp[r * n + c - 1] as f64
                + temp[r * n + c + 1] as f64
                - 4.0 * t;
            out[r * n + c] = (t + K * (power[r * n + c] as f64 + lap)) as f32;
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn erf_accuracy() {
        // Known values: erf(1) = 0.8427007929.
        assert!((erf(1.0) - 0.8427007929).abs() < 1e-6);
        assert!((erf(-1.0) + 0.8427007929).abs() < 1e-6);
        assert!(erf(0.0).abs() < 1e-7);
    }

    #[test]
    fn fwt_involution() {
        let orig: Vec<f32> = (0..16).map(|i| i as f32).collect();
        let mut x = orig.clone();
        fwt_block(&mut x);
        fwt_block(&mut x);
        for (a, b) in x.iter().zip(&orig) {
            assert!((a - b * 16.0).abs() < 1e-3);
        }
    }

    #[test]
    fn transpose_involution() {
        let x: Vec<f32> = (0..12).map(|i| i as f32).collect();
        let t = transpose(&x, 3, 4);
        let back = transpose(&t, 4, 3);
        assert_eq!(back, x);
    }

    #[test]
    fn nw_full_zero_sub_huge_penalty() {
        // Diagonal walk only: diag of output accumulates sub scores (0).
        let size = 4;
        let out = nw_full(&vec![0; 16], size, 10);
        for i in 0..size {
            assert_eq!(out[i * size + i], 0);
        }
    }

    #[test]
    fn dct8x8_constant_block_is_dc_only() {
        let x = vec![3.0f32; 64];
        let out = dct8x8(&x, 8, 8);
        assert!((out[0] - 24.0).abs() < 1e-3, "DC = 8*3 = {}", out[0]);
        let rest: f32 = out[1..].iter().map(|v| v.abs()).sum();
        assert!(rest < 1e-3, "energy outside DC: {rest}");
    }

    #[test]
    fn hotspot_uniform_zero_power_is_fixed_point() {
        let t = vec![5.0f32; 256];
        let p = vec![0.0f32; 256];
        assert_eq!(hotspot_step(&t, &p, 16), t);
    }

    #[test]
    fn histogram_conserves() {
        let x = vec![3, 3, 255, 0];
        let h = histogram(&x);
        assert_eq!(h[3], 2);
        assert_eq!(h[255], 1);
        assert_eq!(h.iter().sum::<i32>(), 4);
    }
}
