//! NVIDIA SDK `DotProduct` — per-chunk partial products with a 4-byte
//! D2H per task: the extreme H2D-dominated streamable code (R → 1
//! territory, the paper's "is the offload even worth it" regime).

use std::sync::Arc;

use crate::hstreams::Context;
use crate::runtime::bytes;
use crate::Result;

use super::{gen_f32, Benchmark, GenericWorkload, Mode, RunStats, Windows};

pub const CHUNK: usize = 65536;

pub struct DotProduct {
    chunks: usize,
}

impl DotProduct {
    pub fn new(scale: usize) -> Self {
        Self { chunks: 8 * scale.max(1) }
    }
}

impl Benchmark for DotProduct {
    fn name(&self) -> &'static str {
        "DotProduct"
    }

    fn artifacts(&self) -> Vec<&'static str> {
        vec!["dot_product"]
    }

    fn run(&self, ctx: &Context, mode: Mode) -> Result<RunStats> {
        let total = self.chunks * CHUNK;
        let a = gen_f32(total, 211);
        let b = gen_f32(total, 212);

        let wl = GenericWorkload {
            name: "DotProduct",
            artifact: "dot_product",
            streamed_inputs: vec![
                Windows::disjoint(Arc::new(bytes::from_f32(&a)), self.chunks),
                Windows::disjoint(Arc::new(bytes::from_f32(&b)), self.chunks),
            ],
            shared_inputs: vec![],
            output_chunk_bytes: vec![4],
            flops_per_chunk: Some(1_000_000),
        };
        let (wall, outputs, h2d) = wl.execute(ctx, mode)?;

        // Host final reduce over the partials.
        let partials = bytes::to_f32(&outputs[0]);
        let got: f64 = partials.iter().map(|&v| v as f64).sum();

        let want: f64 = a.iter().zip(&b).map(|(x, y)| *x as f64 * *y as f64).sum();
        let ok = (got - want).abs() <= 0.5 + 1e-3 * want.abs();

        Ok(RunStats {
            name: "DotProduct".into(),
            mode,
            wall,
            h2d_bytes: h2d,
            d2h_bytes: (self.chunks * 4) as u64,
            tasks: self.chunks,
            validated: ok,
        })
    }
}
