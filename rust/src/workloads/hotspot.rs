//! Rodinia `hotspot` — the **Iterative non-streamable control** (Table 2).
//!
//! The thermal grid uploads once, then the step kernel re-runs on
//! device-resident data via ping-pong buffers; each step consumes the
//! previous step's output, so there is no independent task for a second
//! stream to overlap beyond the initial upload.  The paper (§4.1):
//! "such cases can be streamed by overlapping the data transfer and the
//! first iteration … the overlapping brings no performance benefit for
//! a large number of iterations."  The lowering encodes exactly that:
//! the two uploads carry different lanes (everything the category
//! permits — on one stream they serialize, on two they overlap), and
//! the kernel chain is a pure dependency chain on lane 0, so the gain
//! collapses toward zero as steps grow.

use std::sync::Arc;

use crate::hstreams::Context;
use crate::plan::{
    Backend, Granularity, HostSlice, PlanRegion, RunConfig, SimBackend, Slot, StreamPlan,
};
use crate::runtime::bytes;
use crate::Result;

use super::{gen_f32, oracle, Benchmark, Mode, RunStats};

/// Grid side — must match the `hotspot_step` AOT artifact.
pub const N: usize = 128;

pub struct Hotspot {
    /// Diffusion steps (the paper's Iterative knob).
    steps: usize,
}

impl Hotspot {
    pub fn new(scale: usize) -> Self {
        Self { steps: 16 * scale.max(1) }
    }

    pub fn steps(&self) -> usize {
        self.steps
    }

    /// Lower the ping-pong chain to the task-DAG IR (historical shape:
    /// each upload is one op).
    pub fn lower(&self, temp0: &[f32], power: &[f32]) -> StreamPlan {
        self.lower_at(temp0, power, Granularity::new(1))
    }

    /// Lower with the upload-granularity knob: each of the two input
    /// arrays splits into `gran` chunked H2D ops on alternating lanes
    /// (temperature on even, power on odd), so finer chunks interleave
    /// the two uploads across two streams — all the concurrency the
    /// Iterative category permits ("overlapping the data transfer and
    /// the first iteration").  The kernel chain itself stays a pure
    /// RAW chain whatever the knob, and the assembled output is
    /// bitwise identical at every granularity: the same bytes land in
    /// the same buffer regions, only *when* they travel changes.
    pub fn lower_at(&self, temp0: &[f32], power: &[f32], gran: Granularity) -> StreamPlan {
        let bytes_n = N * N * 4;
        let chunks = gran.get().min(bytes_n / 4).max(1);
        let mut p = StreamPlan::new("hotspot");
        let out = p.output(bytes_n);
        let ta = p.buf(bytes_n);
        let tb = p.buf(bytes_n);
        let pw = p.buf(bytes_n);

        let upload = |p: &mut StreamPlan, data: &[f32], buf: usize, lane0: usize| {
            let payload = Arc::new(bytes::from_f32(data));
            crate::partition::chunk_ranges(bytes_n, chunks)
                .into_iter()
                .enumerate()
                .map(|(j, r)| {
                    p.h2d(
                        Slot::Task(lane0 + 2 * j),
                        HostSlice { data: payload.clone(), off: r.start, len: r.len },
                        PlanRegion { buf, off: r.start, len: r.len },
                        vec![],
                    )
                })
                .collect::<Vec<usize>>()
        };
        let mut uploads = upload(&mut p, temp0, ta, 0);
        uploads.extend(upload(&mut p, power, pw, 1));

        // Ping-pong chain: step k reads step k-1's output — a pure
        // RAW chain on lane 0, serialized regardless of stream count.
        // The first step waits on every upload chunk.
        let (mut src, mut dst) = (ta, tb);
        for step in 0..self.steps {
            let deps = if step == 0 { uploads.clone() } else { Vec::new() };
            p.kex(
                Slot::Task(0),
                "hotspot_step",
                vec![PlanRegion::whole(src, bytes_n), PlanRegion::whole(pw, bytes_n)],
                vec![PlanRegion::whole(dst, bytes_n)],
                None,
                1,
                deps,
            );
            std::mem::swap(&mut src, &mut dst);
        }
        p.d2h(Slot::Task(0), PlanRegion::whole(src, bytes_n), out, 0, vec![]);
        p
    }
}

impl Benchmark for Hotspot {
    fn name(&self) -> &'static str {
        "hotspot"
    }

    fn artifacts(&self) -> Vec<&'static str> {
        vec!["hotspot_step"]
    }

    fn run(&self, ctx: &Context, mode: Mode) -> Result<RunStats> {
        let temp0 = gen_f32(N * N, 221);
        let power = gen_f32(N * N, 222);
        let n_streams = match mode {
            Mode::Baseline => 1,
            Mode::Streamed(n) => n.max(1).min(2),
        };

        let plan = self.lower(&temp0, &power);
        let run = SimBackend::new(ctx).run(&plan, RunConfig::streams(n_streams))?;

        // Validate against the host oracle iterated the same number of
        // steps (f32 kernel vs f64 oracle: tolerance grows mildly).
        let got = bytes::to_f32(&run.outputs[0]);
        let mut want = temp0.clone();
        for _ in 0..self.steps {
            want = oracle::hotspot_step(&want, &power, N);
        }
        let ok = got
            .iter()
            .zip(&want)
            .all(|(a, b)| (a - b).abs() <= 1e-2 + 1e-3 * b.abs());

        Ok(RunStats {
            name: "hotspot".into(),
            mode,
            wall: run.wall,
            h2d_bytes: run.h2d_bytes,
            d2h_bytes: run.d2h_bytes,
            tasks: run.tasks,
            validated: ok,
        })
    }
}
