//! Rodinia `hotspot` — the **Iterative non-streamable control** (Table 2).
//!
//! The thermal grid uploads once, then the step kernel re-runs on
//! device-resident data via ping-pong buffers; each step consumes the
//! previous step's output, so there is no independent task for a second
//! stream to overlap beyond the initial upload.  The paper (§4.1):
//! "such cases can be streamed by overlapping the data transfer and the
//! first iteration … the overlapping brings no performance benefit for
//! a large number of iterations."  This driver measures exactly that:
//! `Streamed` splits the two uploads across streams (everything the
//! category permits) and the gain collapses toward zero as steps grow.

use std::sync::Arc;

use crate::device::DevRegion;
use crate::hstreams::Context;
use crate::runtime::bytes;
use crate::Result;

use super::{gen_f32, oracle, Benchmark, Mode, RunStats};

/// Grid side — must match the `hotspot_step` AOT artifact.
pub const N: usize = 128;

pub struct Hotspot {
    /// Diffusion steps (the paper's Iterative knob).
    steps: usize,
}

impl Hotspot {
    pub fn new(scale: usize) -> Self {
        Self { steps: 16 * scale.max(1) }
    }

    pub fn steps(&self) -> usize {
        self.steps
    }
}

impl Benchmark for Hotspot {
    fn name(&self) -> &'static str {
        "hotspot"
    }

    fn artifacts(&self) -> Vec<&'static str> {
        vec!["hotspot_step"]
    }

    fn run(&self, ctx: &Context, mode: Mode) -> Result<RunStats> {
        let bytes_n = N * N * 4;
        let temp0 = gen_f32(N * N, 221);
        let power = gen_f32(N * N, 222);

        let ta = DevRegion::whole(ctx.alloc(bytes_n)?, bytes_n);
        let tb = DevRegion::whole(ctx.alloc(bytes_n)?, bytes_n);
        let pw = DevRegion::whole(ctx.alloc(bytes_n)?, bytes_n);
        let dst = crate::hstreams::host_dst(bytes_n);

        let n_streams = match mode {
            Mode::Baseline => 1,
            Mode::Streamed(n) => n.max(1),
        };

        let mut streams: Vec<_> = (0..n_streams.max(2).min(2)).map(|_| ctx.stream()).collect();

        // All the overlap this category permits: the two uploads ride
        // different streams when streamed.
        let e_t = streams[0].h2d(
            crate::device::HostSrc::whole(Arc::new(bytes::from_f32(&temp0))),
            ta,
        );
        let up_stream = if n_streams > 1 && streams.len() > 1 { 1 } else { 0 };
        let e_p = streams[up_stream].h2d(
            crate::device::HostSrc::whole(Arc::new(bytes::from_f32(&power))),
            pw,
        );
        // Ping-pong chain: step k reads step k-1's output — a pure
        // dependency chain, serialized regardless of stream count.
        streams[0].wait_event(e_t.clone());
        streams[0].wait_event(e_p.clone());
        let (mut src, mut dst_buf) = (ta, tb);
        for _ in 0..self.steps {
            streams[0].kex("hotspot_step", vec![src, pw], vec![dst_buf]);
            std::mem::swap(&mut src, &mut dst_buf);
        }
        streams[0].d2h(src, dst.clone());
        for s in &streams {
            s.sync();
        }
        let wall = crate::hstreams::makespan(streams.iter().flat_map(|s| s.events()));

        // Validate against the host oracle iterated the same number of
        // steps (f32 kernel vs f64 oracle: tolerance grows mildly).
        let got = bytes::to_f32(&dst.data.lock().unwrap());
        let mut want = temp0.clone();
        for _ in 0..self.steps {
            want = oracle::hotspot_step(&want, &power, N);
        }
        let ok = got
            .iter()
            .zip(&want)
            .all(|(a, b)| (a - b).abs() <= 1e-2 + 1e-3 * b.abs());

        for r in [ta, tb, pw] {
            ctx.free(r.buf)?;
        }

        Ok(RunStats {
            name: "hotspot".into(),
            mode,
            wall,
            h2d_bytes: 2 * bytes_n as u64,
            d2h_bytes: bytes_n as u64,
            tasks: self.steps,
            validated: ok,
        })
    }
}
