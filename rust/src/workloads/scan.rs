//! AMD `PrefixSum` / `ScanLargeArrays` — independent chunk scans with a
//! host-side carry pass (the paper's `ps`).

use std::sync::Arc;

use crate::hstreams::Context;
use crate::runtime::bytes;
use crate::Result;

use super::{gen_f32, oracle, Benchmark, GenericWorkload, Mode, RunStats, Windows};

pub const CHUNK: usize = 16384;

pub struct PrefixSum {
    chunks: usize,
}

impl PrefixSum {
    pub fn new(scale: usize) -> Self {
        Self { chunks: 16 * scale.max(1) }
    }
}

impl Benchmark for PrefixSum {
    fn name(&self) -> &'static str {
        "PrefixSum"
    }

    fn artifacts(&self) -> Vec<&'static str> {
        vec!["prefix_sum"]
    }

    fn run(&self, ctx: &Context, mode: Mode) -> Result<RunStats> {
        let total = self.chunks * CHUNK;
        let x = gen_f32(total, 31);

        let wl = GenericWorkload {
            name: "PrefixSum",
            artifact: "prefix_sum",
            streamed_inputs: vec![Windows::disjoint(Arc::new(bytes::from_f32(&x)), self.chunks)],
            shared_inputs: vec![],
            // Output 0: per-chunk scans; output 1: per-chunk totals.
            output_chunk_bytes: vec![CHUNK * 4, 4],
            // Multi-pass device scan time per chunk.
            flops_per_chunk: Some(1_500_000),
        };
        let (wall, outputs, h2d) = wl.execute(ctx, mode)?;

        // Host carry propagation (the scan's tiny middle pass; host time
        // is off the modeled timeline).
        let mut scans = bytes::to_f32(&outputs[0]);
        let totals = bytes::to_f32(&outputs[1]);
        let mut carry = 0.0f32;
        for c in 0..self.chunks {
            if carry != 0.0 {
                for v in &mut scans[c * CHUNK..(c + 1) * CHUNK] {
                    *v += carry;
                }
            }
            carry += totals[c];
        }

        let want = oracle::prefix_sum(&x);
        // Scan accumulates rounding; scale tolerance with prefix length.
        let ok = scans
            .iter()
            .zip(&want)
            .all(|(a, b)| (a - b).abs() <= 2e-2 + 1e-3 * b.abs());

        Ok(RunStats {
            name: "PrefixSum".into(),
            mode,
            wall,
            h2d_bytes: h2d,
            d2h_bytes: (total * 4 + self.chunks * 4) as u64,
            tasks: self.chunks,
            validated: ok,
        })
    }
}
