//! Rodinia `nw` (Needleman–Wunsch) — the paper's True Dependent
//! exemplar (Fig. 8): tiles execute diagonal-by-diagonal; tiles on one
//! diagonal ride different streams concurrently, and each tile's kernel
//! waits (cross-stream events) on its north / west / northwest
//! neighbours.  Edges move device-to-device: each tile kernel emits its
//! south row and east column as separate contiguous outputs that the
//! dependent tiles read in place.

use std::sync::Arc;

use crate::device::{DevRegion, HostSrc};
use crate::hstreams::Context;
use crate::partition::diagonals;
use crate::runtime::bytes;
use crate::Result;

use super::{gen_i32, oracle, Benchmark, Mode, RunStats};

/// Tile side — must match the `nw_tile` AOT artifact.
pub const TILE: usize = 32;
/// Rodinia's gap penalty (baked into the kernel).
pub const PENALTY: i32 = 10;

pub struct NeedlemanWunsch {
    /// Tile-grid side: the score matrix is (grid*TILE)^2.
    grid: usize,
}

impl NeedlemanWunsch {
    pub fn new(scale: usize) -> Self {
        Self { grid: 8 * scale.max(1) }
    }

    pub fn matrix_size(&self) -> usize {
        self.grid * TILE
    }
}

impl Benchmark for NeedlemanWunsch {
    fn name(&self) -> &'static str {
        "nw"
    }

    fn artifacts(&self) -> Vec<&'static str> {
        vec!["nw_tile"]
    }

    fn run(&self, ctx: &Context, mode: Mode) -> Result<RunStats> {
        let g = self.grid;
        let size = g * TILE;
        let tile_bytes = TILE * TILE * 4;
        let edge_bytes = TILE * 4;
        let n_streams = match mode {
            Mode::Baseline => 1,
            Mode::Streamed(n) => n.max(1),
        };

        // Substitution scores for the whole matrix (Rodinia fills these
        // from the two sequences' reference table).
        let sub = gen_i32(size * size, 15, 0xBEEF);
        let sub_i32: Vec<i32> = sub.iter().map(|&v| v - 5).collect(); // scores in [-5, 10)

        // Per-tile substitution payloads (row-major within the tile).
        let mut tile_sub: Vec<Vec<i32>> = Vec::with_capacity(g * g);
        for bi in 0..g {
            for bj in 0..g {
                let mut t = Vec::with_capacity(TILE * TILE);
                for r in 0..TILE {
                    let row0 = (bi * TILE + r) * size + bj * TILE;
                    t.extend_from_slice(&sub_i32[row0..row0 + TILE]);
                }
                tile_sub.push(t);
            }
        }

        // Boundary vectors: score row/col 0 are -penalty * (1-based idx).
        let north_boundary: Vec<i32> = (0..size as i32).map(|j| -PENALTY * (j + 1)).collect();
        let west_boundary: Vec<i32> = (0..size as i32).map(|i| -PENALTY * (i + 1)).collect();
        let corner_zero: Vec<i32> = vec![0];

        // Device allocations: boundaries + per tile (sub, out, south, east).
        let nb = DevRegion::whole(ctx.alloc(size * 4)?, size * 4);
        let wb = DevRegion::whole(ctx.alloc(size * 4)?, size * 4);
        let cz = DevRegion::whole(ctx.alloc(4)?, 4);
        let mut sub_bufs = Vec::with_capacity(g * g);
        let mut out_bufs = Vec::with_capacity(g * g);
        let mut south_bufs = Vec::with_capacity(g * g);
        let mut east_bufs = Vec::with_capacity(g * g);
        for _ in 0..g * g {
            sub_bufs.push(DevRegion::whole(ctx.alloc(tile_bytes)?, tile_bytes));
            out_bufs.push(DevRegion::whole(ctx.alloc(tile_bytes)?, tile_bytes));
            south_bufs.push(DevRegion::whole(ctx.alloc(edge_bytes)?, edge_bytes));
            east_bufs.push(DevRegion::whole(ctx.alloc(edge_bytes)?, edge_bytes));
        }
        let dst = crate::hstreams::host_dst(g * g * tile_bytes);

        let mut streams: Vec<_> = (0..n_streams).map(|_| ctx.stream()).collect();

        // Prologue: boundaries ride stream 0; other streams wait on them.
        let mut boundary_events = Vec::new();
        boundary_events.push(
            streams[0].h2d(HostSrc::whole(Arc::new(bytes::from_i32(&north_boundary))), nb),
        );
        boundary_events
            .push(streams[0].h2d(HostSrc::whole(Arc::new(bytes::from_i32(&west_boundary))), wb));
        boundary_events
            .push(streams[0].h2d(HostSrc::whole(Arc::new(bytes::from_i32(&corner_zero))), cz));
        for s in streams.iter_mut().skip(1) {
            for e in &boundary_events {
                s.wait_event(e.clone());
            }
        }

        // Wavefront: diagonals in order; tiles within a diagonal
        // round-robin across streams ("the number of streams changes on
        // different diagonals").
        let mut kex_events: Vec<Option<crate::hstreams::Event>> = vec![None; g * g];
        let mut h2d_bytes = (2 * size * 4 + 4) as u64;
        for diag in diagonals(g, g) {
            for (slot, tc) in diag.tiles.iter().enumerate() {
                let (bi, bj) = (tc.bi, tc.bj);
                let t = bi * g + bj;
                let s = &mut streams[slot % n_streams];

                // Upload this tile's substitution scores.
                s.h2d(
                    HostSrc::whole(Arc::new(bytes::from_i32(&tile_sub[t]))),
                    sub_bufs[t],
                );
                h2d_bytes += tile_bytes as u64;

                // Edge inputs: neighbours' contiguous outputs or boundary
                // slices; cross-stream deps on the producing kernels.
                let north = if bi == 0 {
                    DevRegion { buf: nb.buf, off: bj * TILE * 4, len: edge_bytes }
                } else {
                    let up = (bi - 1) * g + bj;
                    if let Some(e) = &kex_events[up] {
                        s.wait_event(e.clone());
                    }
                    south_bufs[up]
                };
                let west = if bj == 0 {
                    DevRegion { buf: wb.buf, off: bi * TILE * 4, len: edge_bytes }
                } else {
                    let left = bi * g + bj - 1;
                    if let Some(e) = &kex_events[left] {
                        s.wait_event(e.clone());
                    }
                    east_bufs[left]
                };
                let corner = match (bi, bj) {
                    (0, 0) => cz,
                    (0, j) => DevRegion { buf: nb.buf, off: (j * TILE - 1) * 4, len: 4 },
                    (i, 0) => DevRegion { buf: wb.buf, off: (i * TILE - 1) * 4, len: 4 },
                    (i, j) => {
                        let diag_nb = (i - 1) * g + j - 1;
                        if let Some(e) = &kex_events[diag_nb] {
                            s.wait_event(e.clone());
                        }
                        DevRegion {
                            buf: south_bufs[diag_nb].buf,
                            off: (TILE - 1) * 4,
                            len: 4,
                        }
                    }
                };

                // Device time per tile (anti-diagonal sweeps are
                // latency-bound on the MIC, well above the raw FLOPs).
                let e = s.kex_with(
                    "nw_tile",
                    vec![north, west, corner, sub_bufs[t]],
                    vec![out_bufs[t], south_bufs[t], east_bufs[t]],
                    Some(450_000),
                    1,
                );
                kex_events[t] = Some(e);

                s.d2h(
                    out_bufs[t],
                    crate::device::HostDst { data: dst.data.clone(), off: t * tile_bytes },
                );
            }
        }
        for s in &streams {
            s.sync();
        }
        let wall = crate::hstreams::makespan(streams.iter().flat_map(|s| s.events()));

        // Reassemble and validate against the full-matrix DP oracle.
        let flat = bytes::to_i32(&dst.data.lock().unwrap());
        let want = oracle::nw_full(&sub_i32, size, PENALTY);
        let mut ok = true;
        'outer: for bi in 0..g {
            for bj in 0..g {
                let t = bi * g + bj;
                for r in 0..TILE {
                    for c in 0..TILE {
                        let got = flat[t * TILE * TILE + r * TILE + c];
                        let exp = want[(bi * TILE + r) * size + bj * TILE + c];
                        if got != exp {
                            ok = false;
                            break 'outer;
                        }
                    }
                }
            }
        }

        for r in sub_bufs
            .iter()
            .chain(&out_bufs)
            .chain(&south_bufs)
            .chain(&east_bufs)
            .chain([&nb, &wb, &cz])
        {
            ctx.free(r.buf)?;
        }

        Ok(RunStats {
            name: "nw".into(),
            mode,
            wall,
            h2d_bytes,
            d2h_bytes: (g * g * tile_bytes) as u64,
            tasks: g * g,
            validated: ok,
        })
    }
}
