//! Rodinia `nw` (Needleman–Wunsch) — the paper's True Dependent
//! exemplar (Fig. 8), lowered to the [`StreamPlan`] IR: tiles execute
//! diagonal-by-diagonal ([`crate::partition::wavefront`]); tiles on one
//! diagonal carry their slot-within-diagonal as the plan lane, so the
//! executor spreads them across streams, and each tile's kernel carries
//! explicit RAW deps on its north / west / northwest neighbours (the
//! executor turns them into cross-stream events).  Edges move
//! device-to-device: each tile kernel emits its south row and east
//! column as separate contiguous outputs that the dependent tiles read
//! in place.

use std::sync::Arc;

use crate::hstreams::Context;
use crate::plan::{
    wire_wavefront, Backend, HostSlice, PlanRegion, RunConfig, SimBackend, Slot, StreamPlan,
};
use crate::runtime::bytes;
use crate::Result;

use super::{gen_i32, oracle, Benchmark, Mode, RunStats};

/// Tile side — must match the `nw_tile` AOT artifact.
pub const TILE: usize = 32;
/// Rodinia's gap penalty (baked into the kernel).
pub const PENALTY: i32 = 10;
/// Device time per tile (anti-diagonal sweeps are latency-bound on the
/// MIC, well above the raw FLOPs).
const TILE_FLOPS: u64 = 450_000;

pub struct NeedlemanWunsch {
    /// Tile-grid side: the score matrix is (grid*TILE)^2.
    grid: usize,
}

impl NeedlemanWunsch {
    pub fn new(scale: usize) -> Self {
        Self { grid: 8 * scale.max(1) }
    }

    /// Exact tile-grid side — the wavefront's [`crate::plan::Granularity`]
    /// knob (property tests and the joint tuner exercise small grids).
    /// Note the tile side is fixed by the `nw_tile` artifact, so the
    /// grid side also sets the matrix size: unlike the corpus
    /// lowerings, two grids are two *problems*, and each must validate
    /// against its own single-stream reference rather than one shared
    /// bulk run.
    pub fn with_grid(grid: usize) -> Self {
        Self { grid: grid.max(1) }
    }

    /// The grid side this instance lowers at.
    pub fn grid(&self) -> usize {
        self.grid
    }

    pub fn matrix_size(&self) -> usize {
        self.grid * TILE
    }

    /// The substitution scores the run is defined over (deterministic).
    fn sub_scores(&self) -> Vec<i32> {
        let size = self.matrix_size();
        gen_i32(size * size, 15, 0xBEEF).iter().map(|&v| v - 5).collect() // scores in [-5, 10)
    }

    /// Lower the wavefront to the task-DAG IR.  One plan serves every
    /// stream count: `Baseline` is the same DAG on one stream.
    pub fn lower(&self) -> StreamPlan {
        self.lower_with(&self.sub_scores())
    }

    /// Lowering over caller-provided substitution scores (lets `run`
    /// share one `sub_scores()` computation with the oracle).
    fn lower_with(&self, sub_i32: &[i32]) -> StreamPlan {
        let g = self.grid;
        let size = g * TILE;
        let tile_bytes = TILE * TILE * 4;
        let edge_bytes = TILE * 4;

        // Per-tile substitution payloads (row-major within the tile).
        let mut tile_sub: Vec<Arc<Vec<u8>>> = Vec::with_capacity(g * g);
        for bi in 0..g {
            for bj in 0..g {
                let mut t = Vec::with_capacity(TILE * TILE);
                for r in 0..TILE {
                    let row0 = (bi * TILE + r) * size + bj * TILE;
                    t.extend_from_slice(&sub_i32[row0..row0 + TILE]);
                }
                tile_sub.push(Arc::new(bytes::from_i32(&t)));
            }
        }

        // Boundary vectors: score row/col 0 are -penalty * (1-based idx).
        let north_boundary: Vec<i32> = (0..size as i32).map(|j| -PENALTY * (j + 1)).collect();
        let west_boundary: Vec<i32> = (0..size as i32).map(|i| -PENALTY * (i + 1)).collect();

        let mut p = StreamPlan::new("nw");
        let out = p.output(g * g * tile_bytes);

        // Boundaries are broadcast inputs: stream 0, fan-out waits.
        let nb = p.buf(size * 4);
        let wb = p.buf(size * 4);
        let cz = p.buf(4);
        p.h2d(
            Slot::Broadcast,
            HostSlice::whole(Arc::new(bytes::from_i32(&north_boundary))),
            PlanRegion::whole(nb, size * 4),
            vec![],
        );
        p.h2d(
            Slot::Broadcast,
            HostSlice::whole(Arc::new(bytes::from_i32(&west_boundary))),
            PlanRegion::whole(wb, size * 4),
            vec![],
        );
        p.h2d(
            Slot::Broadcast,
            HostSlice::whole(Arc::new(bytes::from_i32(&[0i32]))),
            PlanRegion::whole(cz, 4),
            vec![],
        );

        // Per-tile device buffers (sub, out, south edge, east edge).
        let sub_bufs: Vec<usize> = (0..g * g).map(|_| p.buf(tile_bytes)).collect();
        let out_bufs: Vec<usize> = (0..g * g).map(|_| p.buf(tile_bytes)).collect();
        let south_bufs: Vec<usize> = (0..g * g).map(|_| p.buf(edge_bytes)).collect();
        let east_bufs: Vec<usize> = (0..g * g).map(|_| p.buf(edge_bytes)).collect();

        // Wavefront: `wire_wavefront` walks the diagonals, assigns each
        // tile its slot-within-diagonal lane and the RAW deps on its
        // north/west/northwest kernels.
        wire_wavefront(g, |tc, lane, deps| {
            let (bi, bj) = (tc.bi, tc.bj);
            let t = bi * g + bj;

            p.h2d(
                lane,
                HostSlice::whole(tile_sub[t].clone()),
                PlanRegion::whole(sub_bufs[t], tile_bytes),
                vec![],
            );

            // Edge inputs: neighbours' contiguous outputs (their
            // producing kernels are already in `deps`) or boundary
            // slices.
            let north = if bi == 0 {
                PlanRegion { buf: nb, off: bj * TILE * 4, len: edge_bytes }
            } else {
                PlanRegion::whole(south_bufs[(bi - 1) * g + bj], edge_bytes)
            };
            let west = if bj == 0 {
                PlanRegion { buf: wb, off: bi * TILE * 4, len: edge_bytes }
            } else {
                PlanRegion::whole(east_bufs[bi * g + bj - 1], edge_bytes)
            };
            let corner = match (bi, bj) {
                (0, 0) => PlanRegion::whole(cz, 4),
                (0, j) => PlanRegion { buf: nb, off: (j * TILE - 1) * 4, len: 4 },
                (i, 0) => PlanRegion { buf: wb, off: (i * TILE - 1) * 4, len: 4 },
                (i, j) => PlanRegion {
                    buf: south_bufs[(i - 1) * g + j - 1],
                    off: (TILE - 1) * 4,
                    len: 4,
                },
            };

            let kex = p.kex(
                lane,
                "nw_tile",
                vec![north, west, corner, PlanRegion::whole(sub_bufs[t], tile_bytes)],
                vec![
                    PlanRegion::whole(out_bufs[t], tile_bytes),
                    PlanRegion::whole(south_bufs[t], edge_bytes),
                    PlanRegion::whole(east_bufs[t], edge_bytes),
                ],
                Some(TILE_FLOPS),
                1,
                deps,
            );

            let out_region = PlanRegion::whole(out_bufs[t], tile_bytes);
            p.d2h(lane, out_region, out, t * tile_bytes, vec![]);
            kex
        });
        p
    }
}

impl Benchmark for NeedlemanWunsch {
    fn name(&self) -> &'static str {
        "nw"
    }

    fn artifacts(&self) -> Vec<&'static str> {
        vec!["nw_tile"]
    }

    fn run(&self, ctx: &Context, mode: Mode) -> Result<RunStats> {
        let g = self.grid;
        let size = g * TILE;
        let tile_bytes = TILE * TILE * 4;
        let n_streams = match mode {
            Mode::Baseline => 1,
            Mode::Streamed(n) => n.max(1),
        };

        let sub_i32 = self.sub_scores();
        let plan = self.lower_with(&sub_i32);
        let run = SimBackend::new(ctx).run(&plan, RunConfig::streams(n_streams))?;

        // Reassemble and validate against the full-matrix DP oracle.
        let flat = bytes::to_i32(&run.outputs[0]);
        let want = oracle::nw_full(&sub_i32, size, PENALTY);
        let mut ok = true;
        'outer: for bi in 0..g {
            for bj in 0..g {
                let t = bi * g + bj;
                for r in 0..TILE {
                    for c in 0..TILE {
                        let got = flat[t * TILE * TILE + r * TILE + c];
                        let exp = want[(bi * TILE + r) * size + bj * TILE + c];
                        if got != exp {
                            ok = false;
                            break 'outer;
                        }
                    }
                }
            }
        }

        Ok(RunStats {
            name: "nw".into(),
            mode,
            wall: run.wall,
            h2d_bytes: run.h2d_bytes,
            d2h_bytes: run.d2h_bytes,
            tasks: run.tasks,
            validated: ok,
        })
    }
}
