//! NVIDIA `VectorAdd` — minimal independent streamed code; R is very
//! high (transfer-dominated), the paper's "is offload even worth it"
//! regime.

use std::sync::Arc;

use crate::hstreams::Context;
use crate::runtime::bytes;
use crate::Result;

use super::{gen_f32, oracle, Benchmark, GenericWorkload, Mode, RunStats, Windows};

pub const CHUNK: usize = 65536;

pub struct VectorAdd {
    chunks: usize,
}

impl VectorAdd {
    pub fn new(scale: usize) -> Self {
        Self { chunks: 8 * scale.max(1) }
    }

    /// The declarative workload (shared by `run` and the joint tuner).
    fn workload(&self) -> (GenericWorkload, Vec<f32>, Vec<f32>) {
        let total = self.chunks * CHUNK;
        let a = gen_f32(total, 1);
        let b = gen_f32(total, 2);
        let wl = GenericWorkload {
            name: "VectorAdd",
            artifact: "vector_add",
            streamed_inputs: vec![
                Windows::disjoint(Arc::new(bytes::from_f32(&a)), self.chunks),
                Windows::disjoint(Arc::new(bytes::from_f32(&b)), self.chunks),
            ],
            shared_inputs: vec![],
            output_chunk_bytes: vec![CHUNK * 4],
            flops_per_chunk: None,
        };
        (wl, a, b)
    }
}

impl Benchmark for VectorAdd {
    fn name(&self) -> &'static str {
        "VectorAdd"
    }

    fn artifacts(&self) -> Vec<&'static str> {
        vec!["vector_add"]
    }

    fn tunable(&self) -> Option<GenericWorkload> {
        // Per-element map: re-chunking keeps outputs bitwise identical.
        Some(self.workload().0)
    }

    fn run(&self, ctx: &Context, mode: Mode) -> Result<RunStats> {
        let total = self.chunks * CHUNK;
        let (wl, a, b) = self.workload();
        let (wall, outputs, h2d) = wl.execute(ctx, mode)?;

        let got = bytes::to_f32(&outputs[0]);
        let want = oracle::vector_add(&a, &b);
        let ok = got == want; // addition is exact in f32

        Ok(RunStats {
            name: "VectorAdd".into(),
            mode,
            wall,
            h2d_bytes: h2d,
            d2h_bytes: (total * 4) as u64,
            tasks: self.chunks,
            validated: ok,
        })
    }
}
