//! NVIDIA `ConvolutionFFT2D` (the paper's `cFFT`) — tiled spectral
//! convolution.  The FFTs run inside the AOT artifact (XLA-native FFT
//! op, lowered at L2); the spectral pointwise multiply is the L1 Pallas
//! kernel.  Gain in the paper: ~38%.

use std::sync::Arc;

use crate::hstreams::Context;
use crate::runtime::bytes;
use crate::Result;

use super::{gen_f32, Benchmark, GenericWorkload, Mode, RunStats, Windows};

/// Tile side — must match the `cfft2d` AOT artifact.
pub const TILE: usize = 128;

pub struct ConvFft2d {
    chunks: usize,
}

impl ConvFft2d {
    pub fn new(scale: usize) -> Self {
        Self { chunks: 12 * scale.max(1) }
    }
}

impl Benchmark for ConvFft2d {
    fn name(&self) -> &'static str {
        "ConvolutionFFT2D"
    }

    fn artifacts(&self) -> Vec<&'static str> {
        vec!["cfft2d"]
    }

    fn run(&self, ctx: &Context, mode: Mode) -> Result<RunStats> {
        let elems = TILE * TILE;
        let tiles = gen_f32(self.chunks * elems, 101);
        // Validation filter: a shifted delta at (1, 3) — the circular
        // convolution then equals a circular shift of the tile, which is
        // checked exactly (random-filter numerics are covered by the
        // python kernel tests against the FFT oracle).
        let mut filt = vec![0.0f32; elems];
        filt[1 * TILE + 3] = 1.0;

        let wl = GenericWorkload {
            name: "ConvolutionFFT2D",
            artifact: "cfft2d",
            streamed_inputs: vec![Windows::disjoint(
                Arc::new(bytes::from_f32(&tiles)),
                self.chunks,
            )],
            shared_inputs: vec![Arc::new(bytes::from_f32(&filt))],
            output_chunk_bytes: vec![elems * 4],
            // FFT -> pointwise -> IFFT device time per tile.
            flops_per_chunk: Some(2_000_000),
        };
        let (wall, outputs, h2d) = wl.execute(ctx, mode)?;

        let got = bytes::to_f32(&outputs[0]);
        let mut ok = true;
        'outer: for c in 0..self.chunks {
            let tile = &tiles[c * elems..(c + 1) * elems];
            let out = &got[c * elems..(c + 1) * elems];
            for i in 0..TILE {
                for j in 0..TILE {
                    // out[i][j] = tile[(i-1) mod T][(j-3) mod T]
                    let want = tile[((i + TILE - 1) % TILE) * TILE + (j + TILE - 3) % TILE];
                    if (out[i * TILE + j] - want).abs() > 1e-3 + 1e-3 * want.abs() {
                        ok = false;
                        break 'outer;
                    }
                }
            }
        }

        Ok(RunStats {
            name: "ConvolutionFFT2D".into(),
            mode,
            wall,
            h2d_bytes: h2d,
            d2h_bytes: (self.chunks * elems * 4) as u64,
            tasks: self.chunks,
            validated: ok,
        })
    }
}
