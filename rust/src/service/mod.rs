//! `StreamService` — the async multi-tenant front-end over the
//! [`crate::plan`] execution API (DESIGN.md §Service).
//!
//! The paper's generic flow ends at "run the streamed workload"; a
//! serving system starts there: many callers, each with a workload,
//! none of them holding an engine.  The service owns a small fleet of
//! **engine lanes** — each lane a [`Context`] (its own modeled device
//! under its own virtual clock) driven by a worker thread through the
//! [`SimBackend`] — and multiplexes submissions onto them:
//!
//! - **Fair admission** ([`Admission`]): one FIFO queue per tenant,
//!   served round-robin, so a tenant that floods the service cannot
//!   starve the others — each admission turn takes at most one job
//!   from each tenant in arrival order of the tenants.
//! - **Plan cache**: corpus submissions lower once per
//!   `(suite, app, config, granularity)` and every lane shares the
//!   `Arc`'d plan — lowering synthesizes multi-MiB payloads, so repeat
//!   submissions skip real work.  Keys use the *effective* granularity
//!   (the category-clamped value the lowering actually uses), so
//!   aliased knob values share one entry.
//! - **Pluggable tuning** ([`TunePolicy`]): the service, not the
//!   caller, picks `(streams, granularity)` per submission — analytic
//!   seed by default, the learned k-NN behind `--learned`.
//!
//! Submissions are asynchronous: [`StreamService::submit`] returns a
//! [`Ticket`] immediately; [`Ticket::wait`] yields the
//! [`SubmissionReport`] with byte-exact outputs and per-run stats.
//! Because every lane quiesces its timeline between runs, a
//! submission's *modeled* makespan is identical whether it ran alone,
//! serially, or interleaved with other tenants — the concurrency
//! changes wall-clock throughput, never the simulated physics
//! (`tests/service_integration.rs` asserts both properties).

mod policy;

pub use policy::{AnalyticPolicy, LearnedPolicy, PolicyChoice, TunePolicy};

use std::collections::{HashMap, VecDeque};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;

use crate::corpus::BenchConfig;
use crate::device::{DeviceProfile, TimeMode};
use crate::hstreams::{Context, ContextBuilder};
use crate::metrics::median_duration;
use crate::plan::{
    lower_corpus_streamed_at, Backend, Granularity, RunConfig, SimBackend, StreamPlan,
    CORPUS_BURNER,
};
use crate::{Error, Result};

/// Service-wide configuration.
#[derive(Clone)]
pub struct ServiceConfig {
    /// Engine lanes (one modeled device + worker thread each).
    pub lanes: usize,
    /// Measurement repetitions per submission (median modeled time;
    /// 1 is exact under the virtual clock).
    pub runs: usize,
    /// Device profile every lane models (dilated automatically, same
    /// rule as [`ContextBuilder::profile`]).
    pub profile: DeviceProfile,
    /// How lane engines account time (virtual by default).
    pub time_mode: TimeMode,
    /// Artifact subset each lane compiles (`None` = full manifest).
    pub artifacts: Option<Vec<String>>,
}

impl Default for ServiceConfig {
    fn default() -> Self {
        Self {
            lanes: 4,
            runs: 1,
            profile: DeviceProfile::mic31sp(),
            time_mode: TimeMode::from_env_default(),
            artifacts: Some(vec![CORPUS_BURNER.into()]),
        }
    }
}

/// One unit of work a tenant submits.
pub enum Request {
    /// A Table-1 descriptor: the service consults its [`TunePolicy`]
    /// for `(streams, granularity)` and caches the lowered plan.
    Corpus(BenchConfig),
    /// A pre-lowered plan at an explicit stream count (no policy, no
    /// cache) — the escape hatch for non-corpus workloads.
    Plan { plan: Arc<StreamPlan>, streams: usize },
}

/// What a submission resolved to.
#[derive(Debug, Clone)]
pub struct SubmissionReport {
    pub tenant: String,
    /// Plan name (`app/config` for corpus submissions).
    pub name: String,
    /// Table-2 category label (corpus submissions only).
    pub category: Option<&'static str>,
    /// Streams the plan was mapped onto.
    pub streams: usize,
    /// Effective granularity (corpus submissions only).
    pub gran: Option<usize>,
    /// Whether the (streams, gran) choice came from a learned model.
    pub learned: bool,
    /// Which engine lane ran it.
    pub lane: usize,
    /// Whether the lowered plan came from the service's plan cache.
    pub cache_hit: bool,
    /// Median modeled makespan, ms.
    pub modeled_ms: f64,
    /// Byte-exact assembled host outputs.
    pub outputs: Vec<Vec<u8>>,
    pub error: Option<String>,
}

impl SubmissionReport {
    pub fn ok(&self) -> bool {
        self.error.is_none()
    }
}

/// Handle to one in-flight submission.
pub struct Ticket {
    rx: Receiver<SubmissionReport>,
}

impl Ticket {
    /// Block until the submission resolves.
    pub fn wait(self) -> Result<SubmissionReport> {
        match self.rx.recv() {
            Ok(report) => Ok(report),
            Err(_) => Err(Error::Stream("service dropped the submission".into())),
        }
    }
}

/// Fair round-robin admission: one FIFO per tenant, tenants served in
/// first-appearance order, the cursor advancing one tenant per pop —
/// a flooding tenant contributes at most one job per admission turn.
pub(crate) struct Admission<T> {
    queues: Vec<(String, VecDeque<T>)>,
    cursor: usize,
    len: usize,
}

impl<T> Admission<T> {
    pub(crate) fn new() -> Self {
        Self { queues: Vec::new(), cursor: 0, len: 0 }
    }

    pub(crate) fn len(&self) -> usize {
        self.len
    }

    pub(crate) fn push(&mut self, tenant: &str, item: T) {
        self.len += 1;
        match self.queues.iter_mut().find(|(t, _)| t == tenant) {
            Some((_, q)) => q.push_back(item),
            None => self.queues.push((tenant.to_string(), VecDeque::from([item]))),
        }
    }

    pub(crate) fn pop(&mut self) -> Option<T> {
        if self.len == 0 {
            return None;
        }
        let n = self.queues.len();
        for k in 0..n {
            let idx = (self.cursor + k) % n;
            if let Some(item) = self.queues[idx].1.pop_front() {
                self.cursor = (idx + 1) % n;
                self.len -= 1;
                return Some(item);
            }
        }
        None
    }
}

struct Job {
    tenant: String,
    req: Request,
    tx: Sender<SubmissionReport>,
}

struct QueueState {
    admission: Admission<Job>,
    closed: bool,
}

type CacheKey = (&'static str, &'static str, String, usize);

/// Single-flight cache slot: slot creation is atomic under the cache
/// lock and the plan is lowered through `OnceLock::get_or_init`
/// (outside that lock) — racing submissions for the same key block
/// until it lands, so one key is lowered exactly once however many
/// lanes race on it, and hit/miss counts are deterministic (the slot
/// creator is the one miss).
type CacheSlot = Arc<std::sync::OnceLock<Arc<StreamPlan>>>;

/// Key of the memoized policy decision: one per descriptor (the
/// granularity is the *output* of the decision, so it is absent here).
type ChoiceKey = (&'static str, &'static str, String);

struct Shared {
    queue: Mutex<QueueState>,
    cv: Condvar,
    cache: Mutex<HashMap<CacheKey, CacheSlot>>,
    /// `TunePolicy::choose` memoized per descriptor: both shipped
    /// policies lower the descriptor to extract features/seeds, which
    /// synthesizes the full multi-MiB payload — without this, every
    /// plan-cache *hit* would still pay a full lowering on the policy
    /// path.  Sound because a policy decision is a pure function of
    /// (descriptor, lane profile) and all lanes share one profile.
    choices: Mutex<HashMap<ChoiceKey, PolicyChoice>>,
    cache_hits: AtomicU64,
    cache_misses: AtomicU64,
    policy: Arc<dyn TunePolicy>,
    runs: usize,
}

/// Per-lane lifetime totals.
#[derive(Debug, Clone, Default)]
pub struct LaneStats {
    pub jobs: usize,
    pub errors: usize,
    /// Sum of modeled makespans this lane executed, ms.
    pub modeled_ms: f64,
}

/// Lifetime totals of a drained service.
#[derive(Debug, Clone, Default)]
pub struct ServiceStats {
    pub lanes: Vec<LaneStats>,
    pub cache_hits: u64,
    pub cache_misses: u64,
}

impl ServiceStats {
    pub fn jobs(&self) -> usize {
        self.lanes.iter().map(|l| l.jobs).sum()
    }

    pub fn errors(&self) -> usize {
        self.lanes.iter().map(|l| l.errors).sum()
    }

    pub fn modeled_ms(&self) -> f64 {
        self.lanes.iter().map(|l| l.modeled_ms).sum()
    }
}

/// The multi-tenant execution front-end (module docs).
pub struct StreamService {
    shared: Arc<Shared>,
    lanes: Vec<JoinHandle<LaneStats>>,
}

impl StreamService {
    /// Spawn the lane workers and start accepting submissions.
    pub fn start(cfg: ServiceConfig, policy: Arc<dyn TunePolicy>) -> Result<Self> {
        let shared = Arc::new(Shared {
            queue: Mutex::new(QueueState { admission: Admission::new(), closed: false }),
            cv: Condvar::new(),
            cache: Mutex::new(HashMap::new()),
            choices: Mutex::new(HashMap::new()),
            cache_hits: AtomicU64::new(0),
            cache_misses: AtomicU64::new(0),
            policy,
            runs: cfg.runs.max(1),
        });
        let mut lanes = Vec::with_capacity(cfg.lanes.max(1));
        for lane in 0..cfg.lanes.max(1) {
            let shared = shared.clone();
            let cfg = cfg.clone();
            let handle = std::thread::Builder::new()
                .name(format!("hetstream-lane-{lane}"))
                .spawn(move || lane_loop(lane, &shared, &cfg))
                .map_err(|e| Error::Stream(format!("spawn service lane {lane}: {e}")))?;
            lanes.push(handle);
        }
        Ok(Self { shared, lanes })
    }

    /// Enqueue a submission for `tenant`; returns immediately.
    pub fn submit(&self, tenant: &str, req: Request) -> Ticket {
        let (tx, rx) = channel();
        {
            let mut q = self.shared.queue.lock().unwrap();
            q.admission.push(tenant, Job { tenant: tenant.to_string(), req, tx });
        }
        self.shared.cv.notify_all();
        Ticket { rx }
    }

    /// Jobs admitted but not yet claimed by a lane.
    pub fn pending(&self) -> usize {
        self.shared.queue.lock().unwrap().admission.len()
    }

    /// Drain the queue, stop the lanes, and return lifetime stats.
    pub fn shutdown(mut self) -> ServiceStats {
        self.close();
        let handles = std::mem::take(&mut self.lanes);
        let lanes: Vec<LaneStats> =
            handles.into_iter().map(|h| h.join().unwrap_or_default()).collect();
        ServiceStats {
            lanes,
            cache_hits: self.shared.cache_hits.load(Ordering::Relaxed),
            cache_misses: self.shared.cache_misses.load(Ordering::Relaxed),
        }
    }

    fn close(&self) {
        if let Ok(mut q) = self.shared.queue.lock() {
            q.closed = true;
        }
        self.shared.cv.notify_all();
    }
}

impl Drop for StreamService {
    /// A dropped (not shut down) service must still release its lane
    /// threads: mark the queue closed and wake everyone, *without*
    /// joining — the lanes finish their current job, drain what's
    /// queued, and exit on their own.  Without this, an early-return
    /// path in a caller would park every lane on the condvar forever.
    fn drop(&mut self) {
        self.close();
    }
}

fn lane_loop(lane: usize, shared: &Shared, cfg: &ServiceConfig) -> LaneStats {
    let mut stats = LaneStats::default();
    // The lane's modeled device.  If it cannot be built, the lane
    // still drains jobs — with error reports — so no ticket ever
    // hangs on a dead lane.
    let mut b = ContextBuilder::new().profile(cfg.profile.clone()).time_mode(cfg.time_mode);
    if let Some(names) = &cfg.artifacts {
        b = b.only_artifacts(names.clone());
    }
    let ctx = b.build();
    // Artifacts this lane compiled.  A plan launching anything else
    // must be refused up front: the engine's kex worker panics on an
    // uncompiled artifact and its event never completes, which would
    // hang the lane (and the ticket, and shutdown) forever.
    let allowed: Option<std::collections::HashSet<&str>> =
        cfg.artifacts.as_ref().map(|v| v.iter().map(|s| s.as_str()).collect());
    loop {
        let job = {
            let mut q = shared.queue.lock().unwrap();
            loop {
                if let Some(job) = q.admission.pop() {
                    break job;
                }
                if q.closed {
                    return stats;
                }
                q = shared.cv.wait(q).unwrap();
            }
        };
        let report = match &ctx {
            Ok(ctx) => run_job(lane, shared, ctx, &job, allowed.as_ref()),
            Err(e) => error_report(lane, &job, format!("lane context failed to build: {e}")),
        };
        stats.jobs += 1;
        if report.error.is_some() {
            stats.errors += 1;
        } else {
            stats.modeled_ms += report.modeled_ms;
        }
        // A dropped ticket is fine — the work still counts.
        let _ = job.tx.send(report);
    }
}

fn error_report(lane: usize, job: &Job, error: String) -> SubmissionReport {
    let name = match &job.req {
        Request::Corpus(c) => format!("{}/{}", c.app, c.config),
        Request::Plan { plan, .. } => plan.name.clone(),
    };
    SubmissionReport {
        tenant: job.tenant.clone(),
        name,
        category: None,
        streams: 0,
        gran: None,
        learned: false,
        lane,
        cache_hit: false,
        modeled_ms: f64::NAN,
        outputs: Vec::new(),
        error: Some(error),
    }
}

fn run_job(
    lane: usize,
    shared: &Shared,
    ctx: &Context,
    job: &Job,
    allowed: Option<&std::collections::HashSet<&str>>,
) -> SubmissionReport {
    // Resolve the submission to (plan, streams) — policy + cache for
    // descriptors, pass-through for pre-lowered plans.
    let (plan, streams, mut report) = match &job.req {
        Request::Corpus(c) => {
            // Memoized policy decision (see `Shared::choices`): a
            // benign race may compute it twice, but the decision is
            // deterministic so both writers insert the same value.
            let ckey: ChoiceKey = (c.suite.label(), c.app, c.config.clone());
            let cached_choice = shared.choices.lock().unwrap().get(&ckey).copied();
            let choice = match cached_choice {
                Some(choice) => choice,
                None => {
                    let choice = shared.policy.choose(c, ctx.profile());
                    shared.choices.lock().unwrap().insert(ckey, choice);
                    choice
                }
            };
            let key: CacheKey = (c.suite.label(), c.app, c.config.clone(), choice.gran);
            // Slot creation is atomic under the cache lock, so exactly
            // one submission per key is the creator (= the cache miss);
            // everyone else is a hit, even if they arrive while the
            // creator is still lowering — they block in `get_or_init`
            // below rather than duplicating the multi-MiB lowering.
            let (slot, cache_hit) = {
                let mut cache = shared.cache.lock().unwrap();
                match cache.get(&key) {
                    Some(slot) => (slot.clone(), true),
                    None => {
                        let slot: CacheSlot = Arc::new(std::sync::OnceLock::new());
                        cache.insert(key, slot.clone());
                        (slot, false)
                    }
                }
            };
            if cache_hit {
                shared.cache_hits.fetch_add(1, Ordering::Relaxed);
            } else {
                shared.cache_misses.fetch_add(1, Ordering::Relaxed);
            }
            let plan = slot
                .get_or_init(|| {
                    Arc::new(lower_corpus_streamed_at(
                        c,
                        CORPUS_BURNER,
                        Granularity::new(choice.gran),
                    ))
                })
                .clone();
            let report = SubmissionReport {
                tenant: job.tenant.clone(),
                name: plan.name.clone(),
                category: Some(c.category().label()),
                streams: choice.streams,
                gran: Some(choice.gran),
                learned: choice.learned,
                lane,
                cache_hit,
                modeled_ms: f64::NAN,
                outputs: Vec::new(),
                error: None,
            };
            (plan, choice.streams, report)
        }
        Request::Plan { plan, streams } => {
            let report = SubmissionReport {
                tenant: job.tenant.clone(),
                name: plan.name.clone(),
                category: None,
                streams: (*streams).max(1),
                gran: None,
                learned: false,
                lane,
                cache_hit: false,
                modeled_ms: f64::NAN,
                outputs: Vec::new(),
                error: None,
            };
            (plan.clone(), (*streams).max(1), report)
        }
    };

    // Refuse plans that launch artifacts this lane never compiled —
    // see `lane_loop`: running one would hang the lane, not error.
    if let Some(allowed) = allowed {
        if let Some(missing) =
            plan.artifacts().into_iter().find(|a| !allowed.contains(a.as_str()))
        {
            report.error = Some(format!(
                "plan launches artifact `{missing}` but the service lanes only compiled {:?}",
                allowed
            ));
            return report;
        }
    }

    let backend = SimBackend::new(ctx);
    let mut samples = Vec::with_capacity(shared.runs);
    for rep in 0..shared.runs {
        match backend.run(&plan, RunConfig::streams(streams)) {
            Ok(run) => {
                samples.push(run.wall);
                if rep == 0 {
                    report.outputs = run.outputs;
                }
            }
            Err(e) => {
                report.error = Some(e.to_string());
                return report;
            }
        }
    }
    report.modeled_ms = median_duration(&mut samples).as_secs_f64() * 1e3;
    report
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn admission_serves_tenants_round_robin() {
        let mut a: Admission<u32> = Admission::new();
        // Tenant A floods; B and C trickle.
        for i in 0..4 {
            a.push("a", i);
        }
        a.push("b", 10);
        a.push("c", 20);
        a.push("c", 21);
        assert_eq!(a.len(), 7);
        let order: Vec<u32> = std::iter::from_fn(|| a.pop()).collect();
        // One job per tenant per turn, tenants in first-appearance
        // order; A's backlog drains only once the others are empty.
        assert_eq!(order, vec![0, 10, 20, 1, 21, 2, 3]);
        assert_eq!(a.len(), 0);
        assert!(a.pop().is_none());
    }

    #[test]
    fn admission_cursor_survives_empty_tenants() {
        let mut a: Admission<u32> = Admission::new();
        a.push("a", 0);
        a.push("b", 1);
        assert_eq!(a.pop(), Some(0));
        // "a" is now empty but still registered; the cursor must skip
        // it without losing "b".
        a.push("a", 2);
        assert_eq!(a.pop(), Some(1));
        assert_eq!(a.pop(), Some(2));
        assert_eq!(a.pop(), None);
    }
}
