//! `StreamService` — the async multi-tenant front-end over the
//! [`crate::plan`] execution API (DESIGN.md §Service).
//!
//! The paper's generic flow ends at "run the streamed workload"; a
//! serving system starts there: many callers, each with a workload,
//! none of them holding an engine.  The service owns a small fleet of
//! **engine lanes** — each lane a [`Context`] (its own modeled device
//! under its own virtual clock) driven by a worker thread through the
//! [`SimBackend`] — and multiplexes submissions onto them:
//!
//! - **Fair admission** ([`Admission`]): one FIFO queue per tenant,
//!   served round-robin, so a tenant that floods the service cannot
//!   starve the others — each admission turn takes at most one job
//!   from each tenant in arrival order of the tenants.
//! - **Cost-based admission control** ([`AdmissionConfig`]): when
//!   enabled, every submission is charged its *modeled* cost
//!   ([`PolicyChoice::est_ms`], the planner's pipelined-makespan
//!   estimate) against a per-tenant token bucket denominated in
//!   modeled-ms.  An over-budget tenant's submission — or one whose
//!   estimate already exceeds its deadline — is rejected at submit
//!   with a clean [`Error::Admission`], never queued, never hung;
//!   sheds are counted per tenant in [`ServiceStats`].
//! - **Poison tolerance**: every internal lock recovers from a
//!   poisoned state ([`relock`]) — the guarded structures (queues,
//!   caches, buckets) keep their invariants across an unwinding
//!   holder, so one panicking client or lane thread cannot wedge
//!   every other tenant behind a `PoisonError`.
//! - **Plan cache**: corpus submissions lower once per
//!   `(suite, app, config, granularity)` and every lane shares the
//!   `Arc`'d plan — lowering synthesizes multi-MiB payloads, so repeat
//!   submissions skip real work.  Keys use the *effective* granularity
//!   (the category-clamped value the lowering actually uses), so
//!   aliased knob values share one entry.
//! - **Pluggable tuning** ([`TunePolicy`]): the service, not the
//!   caller, picks `(streams, granularity)` per submission — analytic
//!   seed by default, the learned k-NN behind `--learned`.
//!
//! Submissions are asynchronous: [`StreamService::submit`] returns a
//! [`Ticket`] immediately; [`Ticket::wait`] yields the
//! [`SubmissionReport`] with byte-exact outputs and per-run stats.
//! Because every lane quiesces its timeline between runs, a
//! submission's *modeled* makespan is identical whether it ran alone,
//! serially, or interleaved with other tenants — the concurrency
//! changes wall-clock throughput, never the simulated physics
//! (`tests/service_integration.rs` asserts both properties).

mod adaptive;
mod policy;

pub use adaptive::{
    AdaptiveConfig, AdaptiveController, AdaptiveStats, AdaptiveTick, Decision, WakeupMode,
};
pub use policy::{AnalyticPolicy, LearnedPolicy, PolicyChoice, TunePolicy};

use std::collections::{HashMap, VecDeque};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Condvar, Mutex, MutexGuard};
use std::thread::JoinHandle;
use std::time::Instant;

use crate::corpus::BenchConfig;
use crate::device::{DeviceProfile, TimeMode};
use crate::hstreams::{Context, ContextBuilder};
use crate::metrics::median_duration;
use crate::plan::{
    lower_corpus_streamed_at, Backend, Granularity, NativeBackend, RunConfig, SimBackend,
    StreamPlan, CORPUS_BURNER,
};
use crate::spec::{SpecCompiler, WorkloadSpec};
use crate::{Error, Result};

/// Which execution backend the service's lanes run jobs on.
///
/// `Sim` lanes report **modeled** makespans (simulated physics under
/// the virtual clock, deterministic); `Native` lanes run the same
/// plans on host thread pools, so their per-job times are **real
/// wall-clock execution** — machine-dependent, and multiplied by the
/// native path's arena reuse + locality scheduling (DESIGN.md §Native
/// performance).  Outputs are bitwise-identical either way.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum ExecBackend {
    /// Modeled device per lane under the discrete-event clock (default).
    #[default]
    Sim,
    /// Host thread-pool execution ([`NativeBackend`], one arena pool
    /// per lane, reused across that lane's jobs).
    Native,
}

impl ExecBackend {
    /// CLI label (`"sim"` / `"native"`).
    pub fn label(self) -> &'static str {
        match self {
            ExecBackend::Sim => "sim",
            ExecBackend::Native => "native",
        }
    }

    /// Parse a `--backend` argument.
    pub fn parse(s: &str) -> Result<Self> {
        match s {
            "sim" => Ok(ExecBackend::Sim),
            "native" => Ok(ExecBackend::Native),
            other => Err(Error::Config(format!("unknown backend `{other}` (sim|native)"))),
        }
    }
}

/// Lock a mutex, recovering from poison instead of propagating it.
///
/// Every structure the service guards — the admission queues, the plan
/// cache, the policy memo, the token buckets — keeps its invariants
/// across an unwinding holder: `HashMap`/`VecDeque` mutations are
/// panic-safe at the container level, and the values are plain data
/// (no half-initialized states to observe).  Poison here only records
/// *that* some thread panicked while holding the lock; honoring it
/// would convert one crashed client into a `PoisonError` panic in
/// every other tenant's `submit`/`pending` and a permanently parked
/// lane fleet (`close()` silently failing meant `shutdown()` joined
/// forever).  Recovering the guard is therefore the correct handling
/// everywhere in this module — no state here warrants the
/// alternative, an `Error::Service` refusal.
fn relock<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|e| e.into_inner())
}

/// Cost-based admission control: a per-tenant token bucket denominated
/// in **modeled milliseconds** (the planner's [`PolicyChoice::est_ms`]
/// estimate), refilled in wall time.  A tenant may hold at most
/// `burst_ms` of budget and earns `refill_ms_per_sec` of modeled work
/// per wall-clock second; a submission whose estimate exceeds the
/// tenant's current balance is shed with [`Error::Admission`].
#[derive(Debug, Clone, Copy)]
pub struct AdmissionConfig {
    /// Modeled-ms of budget a tenant earns per wall-clock second.
    pub refill_ms_per_sec: f64,
    /// Bucket capacity: the largest modeled-ms burst a tenant can
    /// spend at once.  A request estimated above this is *never*
    /// admissible and is rejected as over-budget outright.
    pub burst_ms: f64,
}

impl Default for AdmissionConfig {
    /// One modeled device-second of work per wall second per tenant,
    /// with a two-second burst — "a tenant may keep one device busy".
    fn default() -> Self {
        Self { refill_ms_per_sec: 1_000.0, burst_ms: 2_000.0 }
    }
}

/// The token bucket behind [`AdmissionConfig`].  Time is passed in by
/// the caller (`now`) so refill behavior is unit-testable without
/// sleeping.
#[derive(Debug, Clone, Copy)]
struct TokenBucket {
    tokens_ms: f64,
    last: Instant,
}

impl TokenBucket {
    fn new(cfg: &AdmissionConfig, now: Instant) -> Self {
        // Born full: a fresh tenant can spend its burst immediately.
        Self { tokens_ms: cfg.burst_ms, last: now }
    }

    /// Refill for the wall time since the last touch, then charge
    /// `cost_ms` if the balance covers it.  Returns whether the charge
    /// was taken.
    fn try_charge(&mut self, cfg: &AdmissionConfig, now: Instant, cost_ms: f64) -> bool {
        let elapsed = now.saturating_duration_since(self.last).as_secs_f64();
        self.tokens_ms = (self.tokens_ms + elapsed * cfg.refill_ms_per_sec).min(cfg.burst_ms);
        self.last = now;
        if self.tokens_ms >= cost_ms {
            self.tokens_ms -= cost_ms;
            true
        } else {
            false
        }
    }
}

/// Per-tenant admission state: the bucket plus lifetime shed count.
#[derive(Debug, Clone, Copy)]
struct TenantGate {
    bucket: TokenBucket,
    shed: u64,
}

/// Service-wide configuration.
#[derive(Clone)]
pub struct ServiceConfig {
    /// Engine lanes (one modeled device + worker thread each).
    pub lanes: usize,
    /// Measurement repetitions per submission (median modeled time;
    /// 1 is exact under the virtual clock).
    pub runs: usize,
    /// Device profile every lane models (dilated automatically, same
    /// rule as [`ContextBuilder::profile`]).
    pub profile: DeviceProfile,
    /// How lane engines account time (virtual by default).
    pub time_mode: TimeMode,
    /// Artifact subset each lane compiles (`None` = full manifest).
    pub artifacts: Option<Vec<String>>,
    /// Cost-based admission control (`None` = admit everything, the
    /// pre-load-harness behavior).
    pub admission: Option<AdmissionConfig>,
    /// What lanes execute jobs on: the modeled device (default) or
    /// the native host thread pool (real wall-clock execution).
    pub backend: ExecBackend,
    /// Adaptive runtime (`--adaptive`): windowed feedback controller
    /// driving request batching, lane elasticity (`lanes` becomes the
    /// initial fleet, [`AdaptiveConfig::max_lanes`] the cap), and
    /// wakeup-mode switching.  `None` = the fixed-lane behavior.
    pub adaptive: Option<AdaptiveConfig>,
}

impl Default for ServiceConfig {
    fn default() -> Self {
        Self {
            lanes: 4,
            runs: 1,
            profile: DeviceProfile::mic31sp(),
            time_mode: TimeMode::from_env_default(),
            artifacts: Some(vec![CORPUS_BURNER.into()]),
            admission: None,
            backend: ExecBackend::default(),
            adaptive: None,
        }
    }
}

/// One unit of work a tenant submits.
pub enum Request {
    /// A Table-1 descriptor: the service consults its [`TunePolicy`]
    /// for `(streams, granularity)` and caches the lowered plan.
    Corpus(BenchConfig),
    /// A pre-lowered plan at an explicit stream count (no policy, no
    /// cache) — the escape hatch for non-corpus workloads.
    Plan { plan: Arc<StreamPlan>, streams: usize },
    /// A declarative [`WorkloadSpec`]: validated at submit (malformed
    /// specs are a clean [`Error::Spec`], never queued), compiled
    /// through [`SpecCompiler`] on first use, cached by
    /// `(content hash, effective granularity)`, and tuned per
    /// submission through [`TunePolicy::choose_plan`] — the same
    /// cache/policy/admission ride the corpus path gets.
    Spec(Arc<WorkloadSpec>),
}

/// What a submission resolved to.
#[derive(Debug, Clone)]
pub struct SubmissionReport {
    pub tenant: String,
    /// Plan name (`app/config` for corpus submissions).
    pub name: String,
    /// Table-2 category label (corpus submissions only).
    pub category: Option<&'static str>,
    /// Streams the plan was mapped onto.
    pub streams: usize,
    /// Effective granularity (corpus submissions only).
    pub gran: Option<usize>,
    /// Whether the (streams, gran) choice came from a learned model.
    pub learned: bool,
    /// Which engine lane ran it.
    pub lane: usize,
    /// Which backend executed it (`"sim"` / `"native"`).
    pub backend: &'static str,
    /// Whether the lowered plan came from the service's plan cache.
    pub cache_hit: bool,
    /// Median per-run makespan, ms: the **modeled** makespan on sim
    /// lanes, the **real wall-clock** execution time on native lanes.
    pub modeled_ms: f64,
    /// Wall time the job waited in the admission queue before a lane
    /// claimed it, ms.
    pub queue_wait_ms: f64,
    /// Wall time from `submit` to completion (queue wait + execution),
    /// ms — the load harness's end-to-end latency.
    pub e2e_ms: f64,
    /// Tickets served by the backend run that produced this report:
    /// 1 normally, ≥ 2 when the adaptive runtime coalesced this
    /// submission with queued same-key peers (outputs are still this
    /// ticket's own byte-exact bytes — DESIGN.md §Adaptive).
    pub batch: usize,
    /// Byte-exact assembled host outputs.
    pub outputs: Vec<Vec<u8>>,
    pub error: Option<String>,
}

impl SubmissionReport {
    pub fn ok(&self) -> bool {
        self.error.is_none()
    }
}

/// Handle to one in-flight submission.
pub struct Ticket {
    rx: Receiver<SubmissionReport>,
}

impl Ticket {
    /// Block until the submission resolves.
    pub fn wait(self) -> Result<SubmissionReport> {
        match self.rx.recv() {
            Ok(report) => Ok(report),
            Err(_) => Err(Error::Service("service dropped the submission".into())),
        }
    }
}

/// Fair round-robin admission: one FIFO per tenant, tenants served in
/// first-appearance order, the cursor advancing one tenant per pop —
/// a flooding tenant contributes at most one job per admission turn.
pub(crate) struct Admission<T> {
    queues: Vec<(String, VecDeque<T>)>,
    cursor: usize,
    len: usize,
}

impl<T> Admission<T> {
    pub(crate) fn new() -> Self {
        Self { queues: Vec::new(), cursor: 0, len: 0 }
    }

    pub(crate) fn len(&self) -> usize {
        self.len
    }

    pub(crate) fn push(&mut self, tenant: &str, item: T) {
        self.len += 1;
        match self.queues.iter_mut().find(|(t, _)| t == tenant) {
            Some((_, q)) => q.push_back(item),
            None => self.queues.push((tenant.to_string(), VecDeque::from([item]))),
        }
    }

    pub(crate) fn pop(&mut self) -> Option<T> {
        if self.len == 0 {
            return None;
        }
        let n = self.queues.len();
        for k in 0..n {
            let idx = (self.cursor + k) % n;
            if let Some(item) = self.queues[idx].1.pop_front() {
                self.cursor = (idx + 1) % n;
                self.len -= 1;
                return Some(item);
            }
        }
        None
    }

    /// Remove up to `limit` queued items matching `pred`, across every
    /// tenant, preserving each tenant's FIFO order for the rest — the
    /// batching claim (a coalesced run may serve many tenants, and
    /// fairness is preserved because the *primary* job still came off
    /// the round-robin cursor; peers it absorbs would have run the
    /// identical plan anyway).
    pub(crate) fn drain_matching<F: Fn(&T) -> bool>(&mut self, pred: F, limit: usize) -> Vec<T> {
        let mut out = Vec::new();
        if limit == 0 {
            return out;
        }
        for (_, q) in self.queues.iter_mut() {
            // Full rotation: every item is popped and either claimed
            // or pushed back, so survivors keep their relative order.
            for _ in 0..q.len() {
                let item = q.pop_front().expect("rotating a counted queue");
                if out.len() < limit && pred(&item) {
                    out.push(item);
                } else {
                    q.push_back(item);
                }
            }
        }
        self.len -= out.len();
        out
    }
}

struct Job {
    tenant: String,
    req: Request,
    tx: Sender<SubmissionReport>,
    /// When `submit` enqueued this job (queue-wait accounting).
    enqueued: Instant,
    /// Batching identity (adaptive runtime only; `None` otherwise, or
    /// for pre-lowered [`Request::Plan`] submissions which never
    /// coalesce — their plans have no cache identity).
    key: Option<BatchKey>,
}

/// What "the same work" means for request coalescing: two submissions
/// with equal keys lower to the identical plan and run at the
/// identical `(streams, granularity)`, so one backend run serves all
/// of them byte-exactly.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
enum BatchKey {
    /// Corpus descriptor at its policy-chosen effective granularity —
    /// the plan-cache key, minus the suite/app `&'static str`s being
    /// folded with the config string.
    Corpus(&'static str, &'static str, String, usize),
    /// Spec content hash at its effective granularity (two specs with
    /// equal content batch even under different names — same rule as
    /// the spec plan cache).
    Spec(u64, usize),
}

struct QueueState {
    admission: Admission<Job>,
    closed: bool,
    /// Queued jobs per batch key (adaptive runtime only): lets the
    /// admission estimate amortize a submission's cost over the run
    /// it will share, without scanning the queues.
    pending_keys: HashMap<BatchKey, usize>,
}

type CacheKey = (&'static str, &'static str, String, usize);

/// Single-flight cache slot: slot creation is atomic under the cache
/// lock and the plan is lowered through `OnceLock::get_or_init`
/// (outside that lock) — racing submissions for the same key block
/// until it lands, so one key is lowered exactly once however many
/// lanes race on it, and hit/miss counts are deterministic (the slot
/// creator is the one miss).
type CacheSlot = Arc<std::sync::OnceLock<Arc<StreamPlan>>>;

/// Key of the memoized policy decision: one per descriptor (the
/// granularity is the *output* of the decision, so it is absent here).
type ChoiceKey = (&'static str, &'static str, String);

/// Spec-plan cache key: the spec's content hash (not its name — two
/// specs with equal content share cached plans, a renamed buffer does
/// not alias) plus the effective granularity the plan was compiled at.
type SpecCacheKey = (u64, usize);

struct Shared {
    queue: Mutex<QueueState>,
    cv: Condvar,
    cache: Mutex<HashMap<CacheKey, CacheSlot>>,
    /// Spec submissions' plan cache (same single-flight discipline as
    /// `cache`, keyed by content hash — see [`SpecCacheKey`]).
    spec_cache: Mutex<HashMap<SpecCacheKey, CacheSlot>>,
    /// `TunePolicy::choose_plan` memoized per spec content hash (the
    /// decision compiles the spec's bulk plan, which materializes the
    /// payload — same rationale as `choices`).
    spec_choices: Mutex<HashMap<u64, PolicyChoice>>,
    /// `TunePolicy::choose` memoized per descriptor: both shipped
    /// policies lower the descriptor to extract features/seeds, which
    /// synthesizes the full multi-MiB payload — without this, every
    /// plan-cache *hit* would still pay a full lowering on the policy
    /// path.  Sound because a policy decision is a pure function of
    /// (descriptor, lane profile) and all lanes share one profile.
    choices: Mutex<HashMap<ChoiceKey, PolicyChoice>>,
    cache_hits: AtomicU64,
    cache_misses: AtomicU64,
    policy: Arc<dyn TunePolicy>,
    runs: usize,
    /// The (builder-dilated) profile every lane models — policy and
    /// cost decisions on the *submit* path must see exactly what the
    /// lanes' contexts see, or the memoized choices would diverge.
    profile: DeviceProfile,
    /// Cost-based admission (`None` = admit everything).
    admission: Option<AdmissionConfig>,
    /// Per-tenant token buckets + shed counts (admission control).
    gates: Mutex<HashMap<String, TenantGate>>,
    /// Plans that carried the static hazard proof through the service
    /// path (debug builds only — release builds skip the verifier and
    /// leave this at 0; see DESIGN.md §Verification).
    verified: AtomicU64,
    /// The adaptive runtime (`None` = fixed lanes, no batching).
    adaptive: Option<AdaptiveRt>,
}

/// Shared-side state of the adaptive runtime: the controller behind a
/// mutex plus its latest decision mirrored into atomics, so the hot
/// paths (lane claim loop, admission estimate) read plain loads and
/// only the observation points pay the controller lock.
struct AdaptiveRt {
    cfg: AdaptiveConfig,
    ctl: Mutex<AdaptiveController>,
    /// Service start: controller timestamps are ms since this instant.
    epoch: Instant,
    // Latest decision (written under `ctl`, read lock-free).
    batching: AtomicBool,
    target_lanes: AtomicUsize,
    wakeup_spin: AtomicBool,
    /// Lanes currently running their loop (grow/retire bookkeeping).
    live_lanes: AtomicUsize,
    grows: AtomicU64,
    retires: AtomicU64,
    peak_lanes: AtomicUsize,
}

impl AdaptiveRt {
    fn now_ms(&self) -> u64 {
        self.epoch.elapsed().as_millis() as u64
    }

    fn store_decision(&self, d: &Decision) {
        self.batching.store(d.batching, Ordering::Relaxed);
        self.target_lanes.store(d.target_lanes, Ordering::Relaxed);
        self.wakeup_spin.store(d.wakeup == WakeupMode::Spin, Ordering::Relaxed);
    }
}

impl Shared {
    /// The memoized policy decision for a descriptor (see `choices`):
    /// consulted by both the submit path (admission cost) and the lane
    /// path (streams/granularity), so the multi-MiB lowering behind a
    /// decision is paid once per descriptor, not once per use site.  A
    /// benign race may compute it twice; the decision is deterministic
    /// so both writers insert the same value.
    fn choice_for(&self, c: &BenchConfig) -> PolicyChoice {
        let ckey: ChoiceKey = (c.suite.label(), c.app, c.config.clone());
        if let Some(choice) = relock(&self.choices).get(&ckey).copied() {
            return choice;
        }
        let choice = self.policy.choose(c, &self.profile);
        relock(&self.choices).insert(ckey, choice);
        choice
    }

    /// The memoized policy decision for a spec submission: the policy
    /// sees the spec's *bulk* plan (same byte/FLOP profile at any
    /// knob), and the returned granularity is clamped through the
    /// compiler's unified clamp so the cache key below is the knob the
    /// lowering actually uses.  Requires a validated spec (submit
    /// rejects malformed ones before they can reach here).
    fn choice_for_spec(&self, spec: &WorkloadSpec) -> PolicyChoice {
        let key = spec.content_hash();
        if let Some(choice) = relock(&self.spec_choices).get(&key).copied() {
            return choice;
        }
        let compiler = SpecCompiler::new(spec);
        let mut choice =
            self.policy.choose_plan(&compiler.bulk(), spec.category, &self.profile);
        choice.gran = compiler.effective_granularity(Granularity::new(choice.gran)).get();
        relock(&self.spec_choices).insert(key, choice);
        choice
    }

    /// The batching identity of a request, when it has one.  Rides the
    /// memoized policy decision (the granularity in the key is the
    /// *effective* one the lowering will use), so repeat submissions
    /// cost a map lookup.
    fn batch_key(&self, req: &Request) -> Option<BatchKey> {
        match req {
            Request::Corpus(c) => {
                let choice = self.choice_for(c);
                Some(BatchKey::Corpus(c.suite.label(), c.app, c.config.clone(), choice.gran))
            }
            Request::Plan { .. } => None,
            Request::Spec(spec) => {
                let choice = self.choice_for_spec(spec);
                Some(BatchKey::Spec(spec.content_hash(), choice.gran))
            }
        }
    }
}

/// Per-lane lifetime totals.
#[derive(Debug, Clone, Default)]
pub struct LaneStats {
    pub jobs: usize,
    pub errors: usize,
    /// Sum of modeled makespans this lane executed, ms.
    pub modeled_ms: f64,
}

/// Lifetime totals of a drained service.
#[derive(Debug, Clone, Default)]
pub struct ServiceStats {
    pub lanes: Vec<LaneStats>,
    pub cache_hits: u64,
    pub cache_misses: u64,
    /// Admission sheds per tenant (over-budget + deadline-infeasible),
    /// sorted by tenant name; empty when admission control is off.
    pub shed: Vec<(String, u64)>,
    /// Plans that passed the static hazard verifier on the service
    /// path (debug builds; 0 in release, where the gate compiles out).
    pub verified: u64,
    /// Adaptive-runtime lifetime counters (`None` when `--adaptive`
    /// was off).
    pub adaptive: Option<AdaptiveStats>,
    /// Per-second controller tick log (empty when adaptive was off) —
    /// `repro bench` merges it into the v3 tick series.
    pub adaptive_ticks: Vec<AdaptiveTick>,
}

impl ServiceStats {
    pub fn jobs(&self) -> usize {
        self.lanes.iter().map(|l| l.jobs).sum()
    }

    pub fn errors(&self) -> usize {
        self.lanes.iter().map(|l| l.errors).sum()
    }

    pub fn modeled_ms(&self) -> f64 {
        self.lanes.iter().map(|l| l.modeled_ms).sum()
    }

    /// Modeled time to drain the whole set: the busiest lane's total.
    /// Under the virtual clock this — not wall time — is the physics
    /// headline: `modeled_ms() / modeled_drain_ms()` is the modeled
    /// speedup of L lanes over one device running the set serially.
    pub fn modeled_drain_ms(&self) -> f64 {
        self.lanes.iter().map(|l| l.modeled_ms).fold(0.0, f64::max)
    }

    pub fn shed_total(&self) -> u64 {
        self.shed.iter().map(|(_, n)| n).sum()
    }
}

/// The multi-tenant execution front-end (module docs).
pub struct StreamService {
    shared: Arc<Shared>,
    /// Lane thread handles, mutexed because the adaptive runtime
    /// spawns new lanes from the submit path (retired lanes leave
    /// their finished handle here; `shutdown` joins everything).
    lanes: Mutex<Vec<JoinHandle<LaneStats>>>,
    /// Service config kept for elastic lane spawns.
    cfg: ServiceConfig,
    /// Monotone lane-id allocator (retired ids are never reused, so
    /// reports always attribute to a unique lane).
    next_lane: AtomicUsize,
}

impl StreamService {
    /// Spawn the lane workers and start accepting submissions.
    pub fn start(cfg: ServiceConfig, policy: Arc<dyn TunePolicy>) -> Result<Self> {
        let initial = cfg.lanes.max(1);
        let adaptive = cfg.adaptive.map(|a| {
            let a = a.normalized();
            AdaptiveRt {
                cfg: a,
                ctl: Mutex::new(AdaptiveController::new(a, initial)),
                epoch: Instant::now(),
                batching: AtomicBool::new(false),
                target_lanes: AtomicUsize::new(initial.clamp(a.min_lanes, a.max_lanes)),
                wakeup_spin: AtomicBool::new(false),
                live_lanes: AtomicUsize::new(initial),
                grows: AtomicU64::new(0),
                retires: AtomicU64::new(0),
                peak_lanes: AtomicUsize::new(initial),
            }
        });
        let shared = Arc::new(Shared {
            queue: Mutex::new(QueueState {
                admission: Admission::new(),
                closed: false,
                pending_keys: HashMap::new(),
            }),
            cv: Condvar::new(),
            cache: Mutex::new(HashMap::new()),
            spec_cache: Mutex::new(HashMap::new()),
            spec_choices: Mutex::new(HashMap::new()),
            choices: Mutex::new(HashMap::new()),
            cache_hits: AtomicU64::new(0),
            cache_misses: AtomicU64::new(0),
            policy,
            runs: cfg.runs.max(1),
            // Same dilation rule as ContextBuilder::profile, so submit-
            // path decisions equal what lanes would have computed.
            profile: cfg.profile.simulation(),
            admission: cfg.admission,
            gates: Mutex::new(HashMap::new()),
            verified: AtomicU64::new(0),
            adaptive,
        });
        let service = Self {
            shared,
            lanes: Mutex::new(Vec::with_capacity(initial)),
            cfg: cfg.clone(),
            next_lane: AtomicUsize::new(0),
        };
        {
            let mut handles = relock(&service.lanes);
            for _ in 0..initial {
                let handle = service.spawn_lane()?;
                handles.push(handle);
            }
        }
        Ok(service)
    }

    /// Spawn one lane thread with the next lane id.
    fn spawn_lane(&self) -> Result<JoinHandle<LaneStats>> {
        let lane = self.next_lane.fetch_add(1, Ordering::Relaxed);
        let shared = self.shared.clone();
        let cfg = self.cfg.clone();
        std::thread::Builder::new()
            .name(format!("hetstream-lane-{lane}"))
            .spawn(move || lane_loop(lane, &shared, &cfg))
            .map_err(|e| Error::Service(format!("spawn service lane {lane}: {e}")))
    }

    /// Grow the live fleet toward the controller's lane target (no-op
    /// without the adaptive runtime; shrinking is lane-side — surplus
    /// lanes quiesce and retire themselves between jobs).  A failed
    /// spawn stops growing but never fails the submission that
    /// triggered it: the existing lanes still serve the queue.
    fn grow_to(&self, target: usize) {
        let Some(rt) = &self.shared.adaptive else { return };
        let target = target.min(rt.cfg.max_lanes);
        if rt.live_lanes.load(Ordering::Relaxed) >= target {
            return;
        }
        let mut handles = relock(&self.lanes);
        // `live_lanes` only grows under this lock, so the check-then-
        // spawn below cannot overshoot the cap (lane-side retirement
        // may undershoot concurrently; the next submit re-grows).
        while rt.live_lanes.load(Ordering::Relaxed) < target {
            match self.spawn_lane() {
                Ok(handle) => {
                    handles.push(handle);
                    let live = rt.live_lanes.fetch_add(1, Ordering::Relaxed) + 1;
                    rt.grows.fetch_add(1, Ordering::Relaxed);
                    rt.peak_lanes.fetch_max(live, Ordering::Relaxed);
                }
                Err(_) => break,
            }
        }
    }

    /// Enqueue a submission for `tenant`; returns immediately.
    ///
    /// With admission control enabled ([`ServiceConfig::admission`])
    /// the submission is first charged its modeled cost against the
    /// tenant's token bucket; an over-budget submission is rejected
    /// here with [`Error::Admission`] — it never enters the queue, so
    /// shedding is O(1) however deep the backlog.  Without admission
    /// control this never fails.
    pub fn submit(&self, tenant: &str, req: Request) -> Result<Ticket> {
        self.submit_with_deadline(tenant, req, None)
    }

    /// [`Self::submit`] with a modeled-ms deadline: a request whose
    /// *estimated* cost already exceeds `deadline_ms` is rejected at
    /// submit as deadline-infeasible (running it could only miss), on
    /// top of the token-bucket budget check.
    pub fn submit_with_deadline(
        &self,
        tenant: &str,
        req: Request,
        deadline_ms: Option<f64>,
    ) -> Result<Ticket> {
        // Malformed specs are refused here with a clean `Error::Spec`
        // — never queued, never compiled, never a hang.  Everything
        // past this point (policy, cache, lanes) may assume validity.
        if let Request::Spec(spec) = &req {
            spec.validate()?;
        }
        if self.shared.admission.is_some() || deadline_ms.is_some() {
            let est_ms = self.estimate_cost_ms(&req);
            if let Some(deadline) = deadline_ms {
                if !est_ms.is_finite() || est_ms > deadline {
                    self.record_shed(tenant);
                    return Err(Error::Admission {
                        tenant: tenant.to_string(),
                        reason: format!(
                            "deadline-infeasible: modeled cost {est_ms:.2} ms exceeds the \
                             {deadline:.2} ms deadline"
                        ),
                    });
                }
            }
            if let Some(cfg) = &self.shared.admission {
                let now = Instant::now();
                let mut gates = relock(&self.shared.gates);
                let gate = gates
                    .entry(tenant.to_string())
                    .or_insert_with(|| TenantGate { bucket: TokenBucket::new(cfg, now), shed: 0 });
                if !gate.bucket.try_charge(cfg, now, est_ms) {
                    gate.shed += 1;
                    let balance = gate.bucket.tokens_ms;
                    drop(gates);
                    self.observe_shed_adaptive();
                    return Err(Error::Admission {
                        tenant: tenant.to_string(),
                        reason: format!(
                            "over budget: modeled cost {est_ms:.2} ms exceeds the tenant's \
                             {balance:.2} ms balance (refill {:.0} ms/s, burst {:.0} ms)",
                            cfg.refill_ms_per_sec, cfg.burst_ms
                        ),
                    });
                }
            }
        }
        let (tx, rx) = channel();
        // Batching identity only matters under the adaptive runtime;
        // computed before taking the queue lock (it may pay a memoized
        // policy lowering on first sight of a descriptor).
        let key = match &self.shared.adaptive {
            Some(_) => self.shared.batch_key(&req),
            None => None,
        };
        let depth;
        {
            let mut q = relock(&self.shared.queue);
            let job = Job {
                tenant: tenant.to_string(),
                req,
                tx,
                enqueued: Instant::now(),
                key: key.clone(),
            };
            q.admission.push(tenant, job);
            if let Some(key) = key {
                *q.pending_keys.entry(key).or_insert(0) += 1;
            }
            depth = q.admission.len();
        }
        self.shared.cv.notify_all();
        if let Some(rt) = &self.shared.adaptive {
            let now = rt.now_ms();
            let decision = {
                let mut ctl = relock(&rt.ctl);
                ctl.observe_submit(now, depth);
                ctl.decide(now, rt.live_lanes.load(Ordering::Relaxed))
            };
            rt.store_decision(&decision);
            self.grow_to(decision.target_lanes);
        }
        Ok(Ticket { rx })
    }

    /// The modeled-ms admission charge for a request: the memoized
    /// policy decision's estimate for descriptors (the same decision
    /// the lane will reuse), [`predict_plan_cost_ms`] at the requested
    /// stream count for pre-lowered plans (already lowered, so the
    /// stage-time walk is cheap).
    ///
    /// While the adaptive runtime is batching, a submission that will
    /// share its backend run with `k−1` queued same-key peers is
    /// charged the amortized [`PolicyChoice::amortized_ms`] — the
    /// coalesced run costs one execution however many tickets it
    /// serves, so a flood of identical requests stops being billed as
    /// `k` executions.
    fn estimate_cost_ms(&self, req: &Request) -> f64 {
        let choice = match req {
            Request::Corpus(c) => self.shared.choice_for(c),
            Request::Plan { plan, streams } => PolicyChoice {
                streams: (*streams).max(1),
                gran: 1,
                learned: false,
                est_ms: crate::analysis::predict_plan_cost_ms(
                    plan,
                    &self.shared.profile,
                    *streams,
                ),
            },
            Request::Spec(spec) => self.shared.choice_for_spec(spec),
        };
        if let Some(rt) = &self.shared.adaptive {
            if rt.batching.load(Ordering::Relaxed) {
                if let Some(key) = self.shared.batch_key(req) {
                    let pending = relock(&self.shared.queue)
                        .pending_keys
                        .get(&key)
                        .copied()
                        .unwrap_or(0);
                    let coalesced = (pending + 1).min(rt.cfg.max_batch);
                    return choice.amortized_ms(coalesced);
                }
            }
        }
        choice.est_ms
    }

    /// Count a shed for `tenant` (deadline rejections shed even when
    /// token-bucket admission is off, so the bucket config falls back
    /// to the default — the bucket itself is only consulted when
    /// [`ServiceConfig::admission`] is set).
    fn record_shed(&self, tenant: &str) {
        let cfg = self.shared.admission.unwrap_or_default();
        let now = Instant::now();
        relock(&self.shared.gates)
            .entry(tenant.to_string())
            .or_insert_with(|| TenantGate { bucket: TokenBucket::new(&cfg, now), shed: 0 })
            .shed += 1;
        self.observe_shed_adaptive();
    }

    /// Feed a shed into the adaptive controller: rejected traffic is
    /// still offered load (a flood we shed should still trip batching
    /// and lane growth for the admitted remainder).
    fn observe_shed_adaptive(&self) {
        if let Some(rt) = &self.shared.adaptive {
            let now = rt.now_ms();
            let decision = {
                let mut ctl = relock(&rt.ctl);
                ctl.observe_shed(now);
                ctl.decide(now, rt.live_lanes.load(Ordering::Relaxed))
            };
            rt.store_decision(&decision);
            self.grow_to(decision.target_lanes);
        }
    }

    /// Lifetime admission sheds for one tenant (0 if never seen).
    pub fn shed_count(&self, tenant: &str) -> u64 {
        relock(&self.shared.gates).get(tenant).map(|g| g.shed).unwrap_or(0)
    }

    /// Jobs admitted but not yet claimed by a lane.
    pub fn pending(&self) -> usize {
        relock(&self.shared.queue).admission.len()
    }

    /// Drain the queue, stop the lanes, and return lifetime stats.
    pub fn shutdown(self) -> ServiceStats {
        self.close();
        // Retired lanes' threads have already returned; their handles
        // still sit in the vec, so every LaneStats — including those
        // of lanes that quiesced mid-run — is collected here.  No
        // increment is lost to retirement.
        let handles = std::mem::take(&mut *relock(&self.lanes));
        let lanes: Vec<LaneStats> =
            handles.into_iter().map(|h| h.join().unwrap_or_default()).collect();
        let mut shed: Vec<(String, u64)> = relock(&self.shared.gates)
            .iter()
            .map(|(t, g)| (t.clone(), g.shed))
            .collect();
        shed.sort();
        let (adaptive, adaptive_ticks) = match &self.shared.adaptive {
            Some(rt) => {
                let mut ctl = relock(&rt.ctl);
                ctl.finalize(rt.now_ms());
                let mut stats = ctl.stats();
                stats.lane_grows = rt.grows.load(Ordering::Relaxed);
                stats.lane_retires = rt.retires.load(Ordering::Relaxed);
                stats.peak_lanes = rt.peak_lanes.load(Ordering::Relaxed) as u64;
                (Some(stats), ctl.take_ticks())
            }
            None => (None, Vec::new()),
        };
        ServiceStats {
            lanes,
            cache_hits: self.shared.cache_hits.load(Ordering::Relaxed),
            cache_misses: self.shared.cache_misses.load(Ordering::Relaxed),
            shed,
            verified: self.shared.verified.load(Ordering::Relaxed),
            adaptive,
            adaptive_ticks,
        }
    }

    /// Close the queue and wake every lane.  Recovers a poisoned queue
    /// lock: skipping the close there (the old `if let Ok` behavior)
    /// meant one panicked holder made every lane park forever and
    /// `shutdown()` join forever.
    fn close(&self) {
        relock(&self.shared.queue).closed = true;
        self.shared.cv.notify_all();
    }
}

impl Drop for StreamService {
    /// A dropped (not shut down) service must still release its lane
    /// threads: mark the queue closed and wake everyone, *without*
    /// joining — the lanes finish their current job, drain what's
    /// queued, and exit on their own.  Without this, an early-return
    /// path in a caller would park every lane on the condvar forever.
    fn drop(&mut self) {
        self.close();
    }
}

/// What one lane executes jobs on: a modeled device, or a native host
/// pool whose arena is reused across every job the lane runs.
enum LaneExec {
    Sim(Context),
    Native(NativeBackend),
}

fn lane_loop(lane: usize, shared: &Shared, cfg: &ServiceConfig) -> LaneStats {
    let mut stats = LaneStats::default();
    // The lane's executor.  If it cannot be built, the lane still
    // drains jobs — with error reports — so no ticket ever hangs on a
    // dead lane.  Native lanes skip the modeled device entirely (no
    // engine threads, no artifact compile) and keep one arena-pooled
    // NativeBackend for their lifetime.
    let exec: Result<LaneExec> = match cfg.backend {
        ExecBackend::Native => Ok(LaneExec::Native(NativeBackend::new())),
        ExecBackend::Sim => {
            let mut b =
                ContextBuilder::new().profile(cfg.profile.clone()).time_mode(cfg.time_mode);
            if let Some(names) = &cfg.artifacts {
                b = b.only_artifacts(names.clone());
            }
            b.build().map(LaneExec::Sim)
        }
    };
    // Artifacts this lane compiled.  A plan launching anything else
    // must be refused up front on *sim* lanes: the engine's kex worker
    // panics on an uncompiled artifact and its event never completes,
    // which would hang the lane (and the ticket, and shutdown)
    // forever.  Native lanes load artifacts per plan and fail with a
    // clean signature error instead, so they need no gate.
    let allowed: Option<std::collections::HashSet<&str>> = match cfg.backend {
        ExecBackend::Sim => cfg.artifacts.as_ref().map(|v| v.iter().map(|s| s.as_str()).collect()),
        ExecBackend::Native => None,
    };
    loop {
        let jobs: Vec<Job> = {
            let mut q = relock(&shared.queue);
            // Fresh spin budget per claim: a lane in spin mode makes
            // this many polling passes before it falls back to the
            // condvar, so silence never burns a core indefinitely.
            let mut spin_left: u32 =
                shared.adaptive.as_ref().map(|rt| rt.cfg.spin_rounds).unwrap_or(0);
            loop {
                if let Some(job) = q.admission.pop() {
                    break claim_batch(&mut q, job, shared);
                }
                if q.closed {
                    return stats;
                }
                if let Some(rt) = &shared.adaptive {
                    // Surplus lane (target shrank below the live
                    // fleet): quiesce — the queue is empty here — and
                    // retire.  The CAS makes exactly (live − target)
                    // lanes take this exit however many race on it.
                    let floor = rt.target_lanes.load(Ordering::Relaxed).max(rt.cfg.min_lanes);
                    let mut live = rt.live_lanes.load(Ordering::Relaxed);
                    while live > floor {
                        match rt.live_lanes.compare_exchange(
                            live,
                            live - 1,
                            Ordering::Relaxed,
                            Ordering::Relaxed,
                        ) {
                            Ok(_) => {
                                rt.retires.fetch_add(1, Ordering::Relaxed);
                                return stats;
                            }
                            Err(cur) => live = cur,
                        }
                    }
                    // Spin-poll wakeup: release the lock, burn a short
                    // bounded busy-wait, and re-check — claim latency
                    // under dense traffic without a notify round-trip.
                    if spin_left > 0 && rt.wakeup_spin.load(Ordering::Relaxed) {
                        spin_left -= 1;
                        drop(q);
                        for _ in 0..64 {
                            std::hint::spin_loop();
                        }
                        std::thread::yield_now();
                        q = relock(&shared.queue);
                        continue;
                    }
                }
                // A poisoned wait still hands back the guard — recover
                // it like every other lock here.
                q = shared.cv.wait(q).unwrap_or_else(|e| e.into_inner());
            }
        };
        // One backend run serves every claimed ticket: run the primary
        // job once, then fan the report out with per-ticket identity
        // and timing.  `modeled_ms` stays the per-ticket modeled cost
        // (what an unbatched run of that submission would report), so
        // modeled-time accounting is batching-invariant; only the
        // wall-clock side (queue waits, e2e) shows the coalescing win.
        let claimed = Instant::now();
        let coalesced = jobs.len();
        let base = match &exec {
            Ok(exec) => run_job(lane, shared, exec, &jobs[0], allowed.as_ref()),
            Err(e) => error_report(
                lane,
                cfg.backend.label(),
                &jobs[0],
                format!("lane executor failed to build: {e}"),
            ),
        };
        for job in &jobs {
            let mut report = base.clone();
            report.tenant = job.tenant.clone();
            report.batch = coalesced;
            report.queue_wait_ms =
                claimed.saturating_duration_since(job.enqueued).as_secs_f64() * 1e3;
            report.e2e_ms = job.enqueued.elapsed().as_secs_f64() * 1e3;
            stats.jobs += 1;
            if report.error.is_some() {
                stats.errors += 1;
            } else {
                stats.modeled_ms += report.modeled_ms;
            }
            // A dropped ticket is fine — the work still counts.
            let _ = job.tx.send(report);
        }
        if let Some(rt) = &shared.adaptive {
            let depth = relock(&shared.queue).admission.len();
            let now = rt.now_ms();
            let decision = {
                let mut ctl = relock(&rt.ctl);
                ctl.observe_complete(now, coalesced, depth);
                ctl.decide(now, rt.live_lanes.load(Ordering::Relaxed))
            };
            rt.store_decision(&decision);
        }
    }
}

/// Claim the batch a popped job anchors: while the controller has
/// batching on, absorb up to `max_batch − 1` queued same-key peers —
/// they lower to the identical plan at the identical knobs, so one
/// backend run serves all of them byte-exactly.  Always settles the
/// claimed jobs' `pending_keys` bookkeeping.
fn claim_batch(q: &mut QueueState, job: Job, shared: &Shared) -> Vec<Job> {
    let mut jobs = vec![job];
    if let Some(rt) = &shared.adaptive {
        if rt.batching.load(Ordering::Relaxed) {
            if let Some(key) = jobs[0].key.clone() {
                let extras = q.admission.drain_matching(
                    |j: &Job| j.key.as_ref() == Some(&key),
                    rt.cfg.max_batch.saturating_sub(1),
                );
                jobs.extend(extras);
            }
        }
    }
    for job in &jobs {
        if let Some(key) = &job.key {
            let drop_key = q
                .pending_keys
                .get_mut(key)
                .map(|n| {
                    *n = n.saturating_sub(1);
                    *n == 0
                })
                .unwrap_or(false);
            if drop_key {
                q.pending_keys.remove(key);
            }
        }
    }
    jobs
}

fn error_report(lane: usize, backend: &'static str, job: &Job, error: String) -> SubmissionReport {
    let name = match &job.req {
        Request::Corpus(c) => format!("{}/{}", c.app, c.config),
        Request::Plan { plan, .. } => plan.name.clone(),
        Request::Spec(spec) => spec.name.clone(),
    };
    SubmissionReport {
        tenant: job.tenant.clone(),
        name,
        category: None,
        streams: 0,
        gran: None,
        learned: false,
        lane,
        backend,
        cache_hit: false,
        modeled_ms: f64::NAN,
        queue_wait_ms: f64::NAN,
        e2e_ms: f64::NAN,
        batch: 1,
        outputs: Vec::new(),
        error: Some(error),
    }
}

fn run_job(
    lane: usize,
    shared: &Shared,
    exec: &LaneExec,
    job: &Job,
    allowed: Option<&std::collections::HashSet<&str>>,
) -> SubmissionReport {
    let backend_label = match exec {
        LaneExec::Sim(_) => "sim",
        LaneExec::Native(_) => "native",
    };
    // Resolve the submission to (plan, streams) — policy + cache for
    // descriptors, pass-through for pre-lowered plans.
    let (plan, streams, mut report) = match &job.req {
        Request::Corpus(c) => {
            // Memoized policy decision — the same entry the submit
            // path's admission charge consulted (`Shared::choice_for`).
            let choice = shared.choice_for(c);
            let key: CacheKey = (c.suite.label(), c.app, c.config.clone(), choice.gran);
            // Slot creation is atomic under the cache lock, so exactly
            // one submission per key is the creator (= the cache miss);
            // everyone else is a hit, even if they arrive while the
            // creator is still lowering — they block in `get_or_init`
            // below rather than duplicating the multi-MiB lowering.
            let (slot, cache_hit) = {
                let mut cache = relock(&shared.cache);
                match cache.get(&key) {
                    Some(slot) => (slot.clone(), true),
                    None => {
                        let slot: CacheSlot = Arc::new(std::sync::OnceLock::new());
                        cache.insert(key, slot.clone());
                        (slot, false)
                    }
                }
            };
            if cache_hit {
                shared.cache_hits.fetch_add(1, Ordering::Relaxed);
            } else {
                shared.cache_misses.fetch_add(1, Ordering::Relaxed);
            }
            let plan = slot
                .get_or_init(|| {
                    Arc::new(lower_corpus_streamed_at(
                        c,
                        CORPUS_BURNER,
                        Granularity::new(choice.gran),
                    ))
                })
                .clone();
            let report = SubmissionReport {
                tenant: job.tenant.clone(),
                name: plan.name.clone(),
                category: Some(c.category().label()),
                streams: choice.streams,
                gran: Some(choice.gran),
                learned: choice.learned,
                lane,
                backend: backend_label,
                cache_hit,
                modeled_ms: f64::NAN,
                queue_wait_ms: f64::NAN,
                e2e_ms: f64::NAN,
                batch: 1,
                outputs: Vec::new(),
                error: None,
            };
            (plan, choice.streams, report)
        }
        Request::Plan { plan, streams } => {
            let report = SubmissionReport {
                tenant: job.tenant.clone(),
                name: plan.name.clone(),
                category: None,
                streams: (*streams).max(1),
                gran: None,
                learned: false,
                lane,
                backend: backend_label,
                cache_hit: false,
                modeled_ms: f64::NAN,
                queue_wait_ms: f64::NAN,
                e2e_ms: f64::NAN,
                batch: 1,
                outputs: Vec::new(),
                error: None,
            };
            (plan.clone(), (*streams).max(1), report)
        }
        Request::Spec(spec) => {
            // Mirrors the corpus arm: memoized policy decision, then a
            // single-flight cache slot keyed by content hash at the
            // effective granularity (see `SpecCacheKey`).
            let choice = shared.choice_for_spec(spec);
            let key: SpecCacheKey = (spec.content_hash(), choice.gran);
            let (slot, cache_hit) = {
                let mut cache = relock(&shared.spec_cache);
                match cache.get(&key) {
                    Some(slot) => (slot.clone(), true),
                    None => {
                        let slot: CacheSlot = Arc::new(std::sync::OnceLock::new());
                        cache.insert(key, slot.clone());
                        (slot, false)
                    }
                }
            };
            if cache_hit {
                shared.cache_hits.fetch_add(1, Ordering::Relaxed);
            } else {
                shared.cache_misses.fetch_add(1, Ordering::Relaxed);
            }
            let plan = slot
                .get_or_init(|| {
                    Arc::new(
                        SpecCompiler::new(spec).streamed_at(Granularity::new(choice.gran)),
                    )
                })
                .clone();
            let report = SubmissionReport {
                tenant: job.tenant.clone(),
                name: plan.name.clone(),
                category: Some(spec.category.label()),
                streams: choice.streams,
                gran: Some(choice.gran),
                learned: choice.learned,
                lane,
                backend: backend_label,
                cache_hit,
                modeled_ms: f64::NAN,
                queue_wait_ms: f64::NAN,
                e2e_ms: f64::NAN,
                batch: 1,
                outputs: Vec::new(),
                error: None,
            };
            (plan, choice.streams, report)
        }
    };

    // Refuse plans that launch artifacts this lane never compiled —
    // see `lane_loop`: running one would hang the lane, not error.
    if let Some(allowed) = allowed {
        if let Some(missing) =
            plan.artifacts().into_iter().find(|a| !allowed.contains(a.as_str()))
        {
            report.error = Some(format!(
                "plan launches artifact `{missing}` but the service lanes only compiled {:?}",
                allowed
            ));
            return report;
        }
    }

    // Debug builds discharge the static hazard proof on every plan the
    // service admits — validate first (so malformed plans keep their
    // validation error text, same order as the backend gates), then the
    // byte-interval race/lifetime verifier (DESIGN.md §Verification).
    // The backends repeat the check at submit; doing it here too makes
    // the refusal attributable to the service path (clean report, no
    // lane churn) and feeds the `verified` stat.  Pure analysis: the
    // modeled makespan never sees it.
    if cfg!(debug_assertions) {
        if let Err(e) = plan.validate().and_then(|()| crate::plan::ensure_sound(&plan)) {
            report.error = Some(e.to_string());
            return report;
        }
        shared.verified.fetch_add(1, Ordering::Relaxed);
    }

    let mut samples = Vec::with_capacity(shared.runs);
    for rep in 0..shared.runs {
        // On sim lanes `run.wall` is the modeled makespan (virtual
        // clock); on native lanes it is real host execution time.
        let result = match exec {
            LaneExec::Sim(ctx) => SimBackend::new(ctx).run(&plan, RunConfig::streams(streams)),
            LaneExec::Native(nb) => nb.run(&plan, RunConfig::streams(streams)),
        };
        match result {
            Ok(run) => {
                samples.push(run.wall);
                if rep == 0 {
                    report.outputs = run.outputs;
                }
            }
            Err(e) => {
                report.error = Some(e.to_string());
                return report;
            }
        }
    }
    report.modeled_ms = median_duration(&mut samples).as_secs_f64() * 1e3;
    report
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn admission_serves_tenants_round_robin() {
        let mut a: Admission<u32> = Admission::new();
        // Tenant A floods; B and C trickle.
        for i in 0..4 {
            a.push("a", i);
        }
        a.push("b", 10);
        a.push("c", 20);
        a.push("c", 21);
        assert_eq!(a.len(), 7);
        let order: Vec<u32> = std::iter::from_fn(|| a.pop()).collect();
        // One job per tenant per turn, tenants in first-appearance
        // order; A's backlog drains only once the others are empty.
        assert_eq!(order, vec![0, 10, 20, 1, 21, 2, 3]);
        assert_eq!(a.len(), 0);
        assert!(a.pop().is_none());
    }

    #[test]
    fn drain_matching_claims_across_tenants_and_preserves_order() {
        let mut a: Admission<u32> = Admission::new();
        a.push("a", 1);
        a.push("a", 2);
        a.push("a", 3);
        a.push("b", 12);
        a.push("b", 5);
        // Claim even values, capped at 2: one from each tenant, odd
        // values untouched and still in FIFO order.
        let evens = a.drain_matching(|v| v % 2 == 0, 2);
        assert_eq!(evens, vec![2, 12]);
        assert_eq!(a.len(), 3);
        let rest: Vec<u32> = std::iter::from_fn(|| a.pop()).collect();
        assert_eq!(rest, vec![1, 5, 3], "round-robin over the survivors, order intact");
        // Limit 0 claims nothing.
        let mut b: Admission<u32> = Admission::new();
        b.push("a", 4);
        assert!(b.drain_matching(|_| true, 0).is_empty());
        assert_eq!(b.len(), 1);
    }

    #[test]
    fn adaptive_service_batches_same_key_floods_bitwise_exactly() {
        // An adaptive service under a same-descriptor flood must
        // coalesce submissions (batch > 1 on some report) and still
        // hand every ticket the bytes an unbatched run produces.
        let c = corpus_config();
        let plain = admission_service(None);
        let reference = plain
            .submit("ref", Request::Corpus(c.clone()))
            .expect("admit")
            .wait()
            .expect("report");
        plain.shutdown();
        assert_eq!(reference.batch, 1);

        let adaptive = StreamService::start(
            ServiceConfig {
                lanes: 1,
                adaptive: Some(AdaptiveConfig {
                    batch_on_rps: 0.0, // batching on from the first decide
                    batch_off_rps: 0.0,
                    dwell_ms: 0,
                    max_batch: 8,
                    ..AdaptiveConfig::default()
                }),
                ..ServiceConfig::default()
            },
            Arc::new(AnalyticPolicy),
        )
        .expect("adaptive service starts");
        let tickets: Vec<Ticket> = (0..24)
            .map(|i| {
                adaptive
                    .submit(&format!("t{}", i % 3), Request::Corpus(c.clone()))
                    .expect("admit")
            })
            .collect();
        let mut max_batch_seen = 0;
        for t in tickets {
            let r = t.wait().expect("report");
            assert!(r.ok(), "{:?}", r.error);
            assert_eq!(r.outputs, reference.outputs, "batched ticket must stay byte-exact");
            assert_eq!(r.modeled_ms, reference.modeled_ms, "modeled accounting is invariant");
            max_batch_seen = max_batch_seen.max(r.batch);
        }
        let stats = adaptive.shutdown();
        assert_eq!(stats.jobs(), 24, "every ticket counts as a job");
        let a = stats.adaptive.expect("adaptive stats present");
        assert!(a.batches > 0, "the flood must coalesce at least once");
        assert!(max_batch_seen >= 2 && max_batch_seen <= 8, "batch size respects the cap");
        assert_eq!(
            stats.adaptive_ticks.first().map(|t| t.t_s),
            Some(0),
            "tick log starts at t=0"
        );
    }

    #[test]
    fn admission_cursor_survives_empty_tenants() {
        let mut a: Admission<u32> = Admission::new();
        a.push("a", 0);
        a.push("b", 1);
        assert_eq!(a.pop(), Some(0));
        // "a" is now empty but still registered; the cursor must skip
        // it without losing "b".
        a.push("a", 2);
        assert_eq!(a.pop(), Some(1));
        assert_eq!(a.pop(), Some(2));
        assert_eq!(a.pop(), None);
    }

    #[test]
    fn token_bucket_sheds_floods_and_refills_idle_tenants() {
        let cfg = AdmissionConfig { refill_ms_per_sec: 100.0, burst_ms: 200.0 };
        let t0 = Instant::now();
        let mut b = TokenBucket::new(&cfg, t0);
        // A flooding tenant drains its burst, then is shed: 200 ms of
        // budget covers exactly four 50 ms requests at the same instant.
        for i in 0..4 {
            assert!(b.try_charge(&cfg, t0, 50.0), "charge {i} fits the burst");
        }
        assert!(!b.try_charge(&cfg, t0, 50.0), "the fifth charge must be shed");
        // An idle second refills 100 ms — two more requests, not three.
        let t1 = t0 + std::time::Duration::from_secs(1);
        assert!(b.try_charge(&cfg, t1, 50.0));
        assert!(b.try_charge(&cfg, t1, 50.0));
        assert!(!b.try_charge(&cfg, t1, 50.0));
        // Refill caps at the burst: ten idle seconds don't bank 1000 ms.
        let t2 = t1 + std::time::Duration::from_secs(10);
        assert!(b.try_charge(&cfg, t2, 200.0), "balance is capped at burst_ms");
        assert!(!b.try_charge(&cfg, t2, 1.0));
        // A request larger than the burst is never admissible.
        let mut fresh = TokenBucket::new(&cfg, t2);
        assert!(!fresh.try_charge(&cfg, t2, 201.0), "over-burst request is over-budget forever");
    }

    fn corpus_config() -> BenchConfig {
        crate::corpus::all_configs().into_iter().next().expect("corpus")
    }

    fn admission_service(admission: Option<AdmissionConfig>) -> StreamService {
        StreamService::start(
            ServiceConfig { lanes: 1, admission, ..ServiceConfig::default() },
            Arc::new(AnalyticPolicy),
        )
        .expect("service starts")
    }

    #[test]
    fn flooding_tenant_is_shed_while_idle_tenant_is_admitted() {
        // Size the burst in units of the descriptor's own modeled cost
        // so the test is profile-independent: ~3 requests fit, then
        // the flooder is shed with Error::Admission while a tenant
        // that has not spent its budget is still admitted.
        let c = corpus_config();
        let est = AnalyticPolicy.choose(&c, &DeviceProfile::mic31sp().simulation()).est_ms;
        assert!(est.is_finite() && est > 0.0);
        let service = admission_service(Some(AdmissionConfig {
            refill_ms_per_sec: est * 1e-3, // effectively no refill within the test
            burst_ms: est * 3.5,
        }));
        let mut admitted = Vec::new();
        let mut shed = 0u64;
        for _ in 0..20 {
            match service.submit("flood", Request::Corpus(c.clone())) {
                Ok(t) => admitted.push(t),
                Err(Error::Admission { tenant, .. }) => {
                    assert_eq!(tenant, "flood");
                    shed += 1;
                }
                Err(e) => panic!("unexpected error: {e}"),
            }
        }
        assert!(!admitted.is_empty(), "the burst must admit something");
        assert!(shed > 0, "a 20-deep flood must overrun a ~3-request burst");
        assert_eq!(service.shed_count("flood"), shed);
        // The well-behaved tenant's own bucket is untouched.
        let ticket =
            service.submit("idle", Request::Corpus(c)).expect("idle tenant admitted");
        assert!(ticket.wait().expect("report").ok());
        assert_eq!(service.shed_count("idle"), 0);
        for t in admitted {
            assert!(t.wait().expect("report").ok());
        }
        let stats = service.shutdown();
        assert_eq!(stats.shed, vec![("flood".to_string(), shed)]);
        assert_eq!(stats.shed_total(), shed);
    }

    #[test]
    fn deadline_infeasible_request_is_rejected_at_submit() {
        // Deadline checks apply even without token-bucket admission.
        let service = admission_service(None);
        let c = corpus_config();
        let est = AnalyticPolicy.choose(&c, &service.shared.profile).est_ms;
        assert!(est.is_finite() && est > 0.0);
        let err = service
            .submit_with_deadline("t", Request::Corpus(c.clone()), Some(est / 2.0))
            .expect_err("a deadline below the modeled cost is infeasible");
        assert!(
            matches!(&err, Error::Admission { tenant, reason }
                if tenant == "t" && reason.contains("deadline-infeasible")),
            "{err}"
        );
        assert_eq!(service.shed_count("t"), 1);
        // A feasible deadline admits normally.
        let report = service
            .submit_with_deadline("t", Request::Corpus(c), Some(est * 2.0))
            .expect("feasible deadline admits")
            .wait()
            .expect("report");
        assert!(report.ok());
        service.shutdown();
    }

    #[test]
    fn native_lanes_serve_with_bitwise_sim_parity() {
        // The same corpus submission through a sim-lane service and a
        // native-lane service must assemble identical bytes; only the
        // meaning of the reported time changes (modeled vs real wall).
        let c = corpus_config();
        let sim = admission_service(None);
        let sref =
            sim.submit("t", Request::Corpus(c.clone())).expect("sim admit").wait().expect("sim");
        sim.shutdown();
        assert_eq!(sref.backend, "sim");

        let native = StreamService::start(
            ServiceConfig { lanes: 1, backend: ExecBackend::Native, ..ServiceConfig::default() },
            Arc::new(AnalyticPolicy),
        )
        .expect("native service starts");
        let nref = native
            .submit("t", Request::Corpus(c))
            .expect("native admit")
            .wait()
            .expect("native");
        let stats = native.shutdown();
        assert!(nref.ok(), "{:?}", nref.error);
        assert_eq!(nref.backend, "native");
        assert_eq!(sref.outputs, nref.outputs, "sim and native lanes diverge");
        assert_eq!(stats.jobs(), 1);
    }

    fn demo_spec() -> WorkloadSpec {
        use crate::spec::{BufferInit, BufferSpec, HaloSpec, SpecMode, StageSpec};
        WorkloadSpec {
            name: "spec-demo".into(),
            category: crate::analysis::Category::Independent,
            mode: SpecMode::Windows,
            granularity: 4,
            repeats: 1,
            output_bytes: 65536,
            block_bytes: crate::spec::KEX_BLOCK_BYTES,
            steps: 0,
            penalty: 0,
            halo: HaloSpec::ZERO,
            buffers: vec![BufferSpec {
                name: "a".into(),
                bytes: 65536,
                init: BufferInit::F32Rand { seed: 9 },
            }],
            stages: vec![StageSpec {
                kernel: CORPUS_BURNER.into(),
                inputs: vec!["a".into()],
                flops: Some(1_000_000),
            }],
        }
    }

    #[test]
    fn spec_submissions_ride_the_cache_and_policy() {
        // Spec requests get the full corpus treatment: policy-chosen
        // (streams, gran), content-hash plan cache, clean refusal of
        // malformed specs at submit.
        let service = admission_service(None);
        let spec = Arc::new(demo_spec());
        let r1 = service
            .submit("t", Request::Spec(spec.clone()))
            .expect("valid spec admits")
            .wait()
            .expect("report");
        assert!(r1.ok(), "{:?}", r1.error);
        assert!(!r1.cache_hit, "first submission lowers");
        assert_eq!(r1.name, "spec-demo");
        assert_eq!(r1.category, Some("Independent"));
        assert!(r1.streams >= 1 && r1.gran.unwrap_or(0) >= 1);
        let r2 = service
            .submit("t", Request::Spec(spec.clone()))
            .expect("resubmit admits")
            .wait()
            .expect("report");
        assert!(r2.cache_hit, "same content hash must hit the cache");
        assert_eq!(r1.outputs, r2.outputs, "cached plan replays byte-exactly");

        let mut bad = demo_spec();
        bad.stages[0].kernel = "no_such_kernel".into();
        match service.submit("t", Request::Spec(Arc::new(bad))) {
            Err(Error::Spec(m)) => assert!(m.contains("unknown kernel"), "{m}"),
            other => panic!("malformed spec must be Error::Spec, got {:?}", other.is_ok()),
        }

        let stats = service.shutdown();
        assert_eq!(stats.jobs(), 2, "the malformed spec never reached a lane");
        assert_eq!((stats.cache_hits, stats.cache_misses), (1, 1));
    }

    #[test]
    fn exec_backend_parses_cli_labels() {
        assert_eq!(ExecBackend::parse("sim").unwrap(), ExecBackend::Sim);
        assert_eq!(ExecBackend::parse("native").unwrap(), ExecBackend::Native);
        assert!(ExecBackend::parse("cuda").is_err());
        assert_eq!(ExecBackend::default().label(), "sim");
    }

    #[test]
    fn service_survives_poisoned_locks() {
        // Poison every recoverable lock by panicking while holding it,
        // then prove the service still admits, serves, and shuts down —
        // the regression for the seven lock().unwrap() sites that used
        // to cascade one panicked thread into a wedged service.
        let service = admission_service(Some(AdmissionConfig::default()));
        let shared = service.shared.clone();
        std::thread::spawn(move || {
            let _q = shared.queue.lock().unwrap();
            let _c = shared.cache.lock().unwrap();
            let _ch = shared.choices.lock().unwrap();
            let _g = shared.gates.lock().unwrap();
            panic!("poison all service locks");
        })
        .join()
        .expect_err("the poisoning thread must panic");
        assert!(service.shared.queue.is_poisoned(), "queue lock must actually be poisoned");
        let report = service
            .submit("tenant", Request::Corpus(corpus_config()))
            .expect("poisoned service still admits")
            .wait()
            .expect("poisoned service still serves");
        assert!(report.ok(), "{:?}", report.error);
        assert_eq!(service.pending(), 0);
        let stats = service.shutdown();
        assert_eq!(stats.jobs(), 1);
        assert_eq!(stats.errors(), 0);
    }
}
