//! `StreamService` — the async multi-tenant front-end over the
//! [`crate::plan`] execution API (DESIGN.md §Service).
//!
//! The paper's generic flow ends at "run the streamed workload"; a
//! serving system starts there: many callers, each with a workload,
//! none of them holding an engine.  The service owns a small fleet of
//! **engine lanes** — each lane a [`Context`] (its own modeled device
//! under its own virtual clock) driven by a worker thread through the
//! [`SimBackend`] — and multiplexes submissions onto them:
//!
//! - **Fair admission** ([`Admission`]): one FIFO queue per tenant,
//!   served round-robin, so a tenant that floods the service cannot
//!   starve the others — each admission turn takes at most one job
//!   from each tenant in arrival order of the tenants.
//! - **Cost-based admission control** ([`AdmissionConfig`]): when
//!   enabled, every submission is charged its *modeled* cost
//!   ([`PolicyChoice::est_ms`], the planner's pipelined-makespan
//!   estimate) against a per-tenant token bucket denominated in
//!   modeled-ms.  An over-budget tenant's submission — or one whose
//!   estimate already exceeds its deadline — is rejected at submit
//!   with a clean [`Error::Admission`], never queued, never hung;
//!   sheds are counted per tenant in [`ServiceStats`].
//! - **Poison tolerance**: every internal lock recovers from a
//!   poisoned state ([`relock`]) — the guarded structures (queues,
//!   caches, buckets) keep their invariants across an unwinding
//!   holder, so one panicking client or lane thread cannot wedge
//!   every other tenant behind a `PoisonError`.
//! - **Plan cache**: corpus submissions lower once per
//!   `(suite, app, config, granularity)` and every lane shares the
//!   `Arc`'d plan — lowering synthesizes multi-MiB payloads, so repeat
//!   submissions skip real work.  Keys use the *effective* granularity
//!   (the category-clamped value the lowering actually uses), so
//!   aliased knob values share one entry.
//! - **Pluggable tuning** ([`TunePolicy`]): the service, not the
//!   caller, picks `(streams, granularity)` per submission — analytic
//!   seed by default, the learned k-NN behind `--learned`.
//!
//! Submissions are asynchronous: [`StreamService::submit`] returns a
//! [`Ticket`] immediately; [`Ticket::wait`] yields the
//! [`SubmissionReport`] with byte-exact outputs and per-run stats.
//! Because every lane quiesces its timeline between runs, a
//! submission's *modeled* makespan is identical whether it ran alone,
//! serially, or interleaved with other tenants — the concurrency
//! changes wall-clock throughput, never the simulated physics
//! (`tests/service_integration.rs` asserts both properties).

mod policy;

pub use policy::{AnalyticPolicy, LearnedPolicy, PolicyChoice, TunePolicy};

use std::collections::{HashMap, VecDeque};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Condvar, Mutex, MutexGuard};
use std::thread::JoinHandle;
use std::time::Instant;

use crate::corpus::BenchConfig;
use crate::device::{DeviceProfile, TimeMode};
use crate::hstreams::{Context, ContextBuilder};
use crate::metrics::median_duration;
use crate::plan::{
    lower_corpus_streamed_at, Backend, Granularity, NativeBackend, RunConfig, SimBackend,
    StreamPlan, CORPUS_BURNER,
};
use crate::spec::{SpecCompiler, WorkloadSpec};
use crate::{Error, Result};

/// Which execution backend the service's lanes run jobs on.
///
/// `Sim` lanes report **modeled** makespans (simulated physics under
/// the virtual clock, deterministic); `Native` lanes run the same
/// plans on host thread pools, so their per-job times are **real
/// wall-clock execution** — machine-dependent, and multiplied by the
/// native path's arena reuse + locality scheduling (DESIGN.md §Native
/// performance).  Outputs are bitwise-identical either way.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum ExecBackend {
    /// Modeled device per lane under the discrete-event clock (default).
    #[default]
    Sim,
    /// Host thread-pool execution ([`NativeBackend`], one arena pool
    /// per lane, reused across that lane's jobs).
    Native,
}

impl ExecBackend {
    /// CLI label (`"sim"` / `"native"`).
    pub fn label(self) -> &'static str {
        match self {
            ExecBackend::Sim => "sim",
            ExecBackend::Native => "native",
        }
    }

    /// Parse a `--backend` argument.
    pub fn parse(s: &str) -> Result<Self> {
        match s {
            "sim" => Ok(ExecBackend::Sim),
            "native" => Ok(ExecBackend::Native),
            other => Err(Error::Config(format!("unknown backend `{other}` (sim|native)"))),
        }
    }
}

/// Lock a mutex, recovering from poison instead of propagating it.
///
/// Every structure the service guards — the admission queues, the plan
/// cache, the policy memo, the token buckets — keeps its invariants
/// across an unwinding holder: `HashMap`/`VecDeque` mutations are
/// panic-safe at the container level, and the values are plain data
/// (no half-initialized states to observe).  Poison here only records
/// *that* some thread panicked while holding the lock; honoring it
/// would convert one crashed client into a `PoisonError` panic in
/// every other tenant's `submit`/`pending` and a permanently parked
/// lane fleet (`close()` silently failing meant `shutdown()` joined
/// forever).  Recovering the guard is therefore the correct handling
/// everywhere in this module — no state here warrants the
/// alternative, an `Error::Service` refusal.
fn relock<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|e| e.into_inner())
}

/// Cost-based admission control: a per-tenant token bucket denominated
/// in **modeled milliseconds** (the planner's [`PolicyChoice::est_ms`]
/// estimate), refilled in wall time.  A tenant may hold at most
/// `burst_ms` of budget and earns `refill_ms_per_sec` of modeled work
/// per wall-clock second; a submission whose estimate exceeds the
/// tenant's current balance is shed with [`Error::Admission`].
#[derive(Debug, Clone, Copy)]
pub struct AdmissionConfig {
    /// Modeled-ms of budget a tenant earns per wall-clock second.
    pub refill_ms_per_sec: f64,
    /// Bucket capacity: the largest modeled-ms burst a tenant can
    /// spend at once.  A request estimated above this is *never*
    /// admissible and is rejected as over-budget outright.
    pub burst_ms: f64,
}

impl Default for AdmissionConfig {
    /// One modeled device-second of work per wall second per tenant,
    /// with a two-second burst — "a tenant may keep one device busy".
    fn default() -> Self {
        Self { refill_ms_per_sec: 1_000.0, burst_ms: 2_000.0 }
    }
}

/// The token bucket behind [`AdmissionConfig`].  Time is passed in by
/// the caller (`now`) so refill behavior is unit-testable without
/// sleeping.
#[derive(Debug, Clone, Copy)]
struct TokenBucket {
    tokens_ms: f64,
    last: Instant,
}

impl TokenBucket {
    fn new(cfg: &AdmissionConfig, now: Instant) -> Self {
        // Born full: a fresh tenant can spend its burst immediately.
        Self { tokens_ms: cfg.burst_ms, last: now }
    }

    /// Refill for the wall time since the last touch, then charge
    /// `cost_ms` if the balance covers it.  Returns whether the charge
    /// was taken.
    fn try_charge(&mut self, cfg: &AdmissionConfig, now: Instant, cost_ms: f64) -> bool {
        let elapsed = now.saturating_duration_since(self.last).as_secs_f64();
        self.tokens_ms = (self.tokens_ms + elapsed * cfg.refill_ms_per_sec).min(cfg.burst_ms);
        self.last = now;
        if self.tokens_ms >= cost_ms {
            self.tokens_ms -= cost_ms;
            true
        } else {
            false
        }
    }
}

/// Per-tenant admission state: the bucket plus lifetime shed count.
#[derive(Debug, Clone, Copy)]
struct TenantGate {
    bucket: TokenBucket,
    shed: u64,
}

/// Service-wide configuration.
#[derive(Clone)]
pub struct ServiceConfig {
    /// Engine lanes (one modeled device + worker thread each).
    pub lanes: usize,
    /// Measurement repetitions per submission (median modeled time;
    /// 1 is exact under the virtual clock).
    pub runs: usize,
    /// Device profile every lane models (dilated automatically, same
    /// rule as [`ContextBuilder::profile`]).
    pub profile: DeviceProfile,
    /// How lane engines account time (virtual by default).
    pub time_mode: TimeMode,
    /// Artifact subset each lane compiles (`None` = full manifest).
    pub artifacts: Option<Vec<String>>,
    /// Cost-based admission control (`None` = admit everything, the
    /// pre-load-harness behavior).
    pub admission: Option<AdmissionConfig>,
    /// What lanes execute jobs on: the modeled device (default) or
    /// the native host thread pool (real wall-clock execution).
    pub backend: ExecBackend,
}

impl Default for ServiceConfig {
    fn default() -> Self {
        Self {
            lanes: 4,
            runs: 1,
            profile: DeviceProfile::mic31sp(),
            time_mode: TimeMode::from_env_default(),
            artifacts: Some(vec![CORPUS_BURNER.into()]),
            admission: None,
            backend: ExecBackend::default(),
        }
    }
}

/// One unit of work a tenant submits.
pub enum Request {
    /// A Table-1 descriptor: the service consults its [`TunePolicy`]
    /// for `(streams, granularity)` and caches the lowered plan.
    Corpus(BenchConfig),
    /// A pre-lowered plan at an explicit stream count (no policy, no
    /// cache) — the escape hatch for non-corpus workloads.
    Plan { plan: Arc<StreamPlan>, streams: usize },
    /// A declarative [`WorkloadSpec`]: validated at submit (malformed
    /// specs are a clean [`Error::Spec`], never queued), compiled
    /// through [`SpecCompiler`] on first use, cached by
    /// `(content hash, effective granularity)`, and tuned per
    /// submission through [`TunePolicy::choose_plan`] — the same
    /// cache/policy/admission ride the corpus path gets.
    Spec(Arc<WorkloadSpec>),
}

/// What a submission resolved to.
#[derive(Debug, Clone)]
pub struct SubmissionReport {
    pub tenant: String,
    /// Plan name (`app/config` for corpus submissions).
    pub name: String,
    /// Table-2 category label (corpus submissions only).
    pub category: Option<&'static str>,
    /// Streams the plan was mapped onto.
    pub streams: usize,
    /// Effective granularity (corpus submissions only).
    pub gran: Option<usize>,
    /// Whether the (streams, gran) choice came from a learned model.
    pub learned: bool,
    /// Which engine lane ran it.
    pub lane: usize,
    /// Which backend executed it (`"sim"` / `"native"`).
    pub backend: &'static str,
    /// Whether the lowered plan came from the service's plan cache.
    pub cache_hit: bool,
    /// Median per-run makespan, ms: the **modeled** makespan on sim
    /// lanes, the **real wall-clock** execution time on native lanes.
    pub modeled_ms: f64,
    /// Wall time the job waited in the admission queue before a lane
    /// claimed it, ms.
    pub queue_wait_ms: f64,
    /// Wall time from `submit` to completion (queue wait + execution),
    /// ms — the load harness's end-to-end latency.
    pub e2e_ms: f64,
    /// Byte-exact assembled host outputs.
    pub outputs: Vec<Vec<u8>>,
    pub error: Option<String>,
}

impl SubmissionReport {
    pub fn ok(&self) -> bool {
        self.error.is_none()
    }
}

/// Handle to one in-flight submission.
pub struct Ticket {
    rx: Receiver<SubmissionReport>,
}

impl Ticket {
    /// Block until the submission resolves.
    pub fn wait(self) -> Result<SubmissionReport> {
        match self.rx.recv() {
            Ok(report) => Ok(report),
            Err(_) => Err(Error::Service("service dropped the submission".into())),
        }
    }
}

/// Fair round-robin admission: one FIFO per tenant, tenants served in
/// first-appearance order, the cursor advancing one tenant per pop —
/// a flooding tenant contributes at most one job per admission turn.
pub(crate) struct Admission<T> {
    queues: Vec<(String, VecDeque<T>)>,
    cursor: usize,
    len: usize,
}

impl<T> Admission<T> {
    pub(crate) fn new() -> Self {
        Self { queues: Vec::new(), cursor: 0, len: 0 }
    }

    pub(crate) fn len(&self) -> usize {
        self.len
    }

    pub(crate) fn push(&mut self, tenant: &str, item: T) {
        self.len += 1;
        match self.queues.iter_mut().find(|(t, _)| t == tenant) {
            Some((_, q)) => q.push_back(item),
            None => self.queues.push((tenant.to_string(), VecDeque::from([item]))),
        }
    }

    pub(crate) fn pop(&mut self) -> Option<T> {
        if self.len == 0 {
            return None;
        }
        let n = self.queues.len();
        for k in 0..n {
            let idx = (self.cursor + k) % n;
            if let Some(item) = self.queues[idx].1.pop_front() {
                self.cursor = (idx + 1) % n;
                self.len -= 1;
                return Some(item);
            }
        }
        None
    }
}

struct Job {
    tenant: String,
    req: Request,
    tx: Sender<SubmissionReport>,
    /// When `submit` enqueued this job (queue-wait accounting).
    enqueued: Instant,
}

struct QueueState {
    admission: Admission<Job>,
    closed: bool,
}

type CacheKey = (&'static str, &'static str, String, usize);

/// Single-flight cache slot: slot creation is atomic under the cache
/// lock and the plan is lowered through `OnceLock::get_or_init`
/// (outside that lock) — racing submissions for the same key block
/// until it lands, so one key is lowered exactly once however many
/// lanes race on it, and hit/miss counts are deterministic (the slot
/// creator is the one miss).
type CacheSlot = Arc<std::sync::OnceLock<Arc<StreamPlan>>>;

/// Key of the memoized policy decision: one per descriptor (the
/// granularity is the *output* of the decision, so it is absent here).
type ChoiceKey = (&'static str, &'static str, String);

/// Spec-plan cache key: the spec's content hash (not its name — two
/// specs with equal content share cached plans, a renamed buffer does
/// not alias) plus the effective granularity the plan was compiled at.
type SpecCacheKey = (u64, usize);

struct Shared {
    queue: Mutex<QueueState>,
    cv: Condvar,
    cache: Mutex<HashMap<CacheKey, CacheSlot>>,
    /// Spec submissions' plan cache (same single-flight discipline as
    /// `cache`, keyed by content hash — see [`SpecCacheKey`]).
    spec_cache: Mutex<HashMap<SpecCacheKey, CacheSlot>>,
    /// `TunePolicy::choose_plan` memoized per spec content hash (the
    /// decision compiles the spec's bulk plan, which materializes the
    /// payload — same rationale as `choices`).
    spec_choices: Mutex<HashMap<u64, PolicyChoice>>,
    /// `TunePolicy::choose` memoized per descriptor: both shipped
    /// policies lower the descriptor to extract features/seeds, which
    /// synthesizes the full multi-MiB payload — without this, every
    /// plan-cache *hit* would still pay a full lowering on the policy
    /// path.  Sound because a policy decision is a pure function of
    /// (descriptor, lane profile) and all lanes share one profile.
    choices: Mutex<HashMap<ChoiceKey, PolicyChoice>>,
    cache_hits: AtomicU64,
    cache_misses: AtomicU64,
    policy: Arc<dyn TunePolicy>,
    runs: usize,
    /// The (builder-dilated) profile every lane models — policy and
    /// cost decisions on the *submit* path must see exactly what the
    /// lanes' contexts see, or the memoized choices would diverge.
    profile: DeviceProfile,
    /// Cost-based admission (`None` = admit everything).
    admission: Option<AdmissionConfig>,
    /// Per-tenant token buckets + shed counts (admission control).
    gates: Mutex<HashMap<String, TenantGate>>,
    /// Plans that carried the static hazard proof through the service
    /// path (debug builds only — release builds skip the verifier and
    /// leave this at 0; see DESIGN.md §Verification).
    verified: AtomicU64,
}

impl Shared {
    /// The memoized policy decision for a descriptor (see `choices`):
    /// consulted by both the submit path (admission cost) and the lane
    /// path (streams/granularity), so the multi-MiB lowering behind a
    /// decision is paid once per descriptor, not once per use site.  A
    /// benign race may compute it twice; the decision is deterministic
    /// so both writers insert the same value.
    fn choice_for(&self, c: &BenchConfig) -> PolicyChoice {
        let ckey: ChoiceKey = (c.suite.label(), c.app, c.config.clone());
        if let Some(choice) = relock(&self.choices).get(&ckey).copied() {
            return choice;
        }
        let choice = self.policy.choose(c, &self.profile);
        relock(&self.choices).insert(ckey, choice);
        choice
    }

    /// The memoized policy decision for a spec submission: the policy
    /// sees the spec's *bulk* plan (same byte/FLOP profile at any
    /// knob), and the returned granularity is clamped through the
    /// compiler's unified clamp so the cache key below is the knob the
    /// lowering actually uses.  Requires a validated spec (submit
    /// rejects malformed ones before they can reach here).
    fn choice_for_spec(&self, spec: &WorkloadSpec) -> PolicyChoice {
        let key = spec.content_hash();
        if let Some(choice) = relock(&self.spec_choices).get(&key).copied() {
            return choice;
        }
        let compiler = SpecCompiler::new(spec);
        let mut choice =
            self.policy.choose_plan(&compiler.bulk(), spec.category, &self.profile);
        choice.gran = compiler.effective_granularity(Granularity::new(choice.gran)).get();
        relock(&self.spec_choices).insert(key, choice);
        choice
    }
}

/// Per-lane lifetime totals.
#[derive(Debug, Clone, Default)]
pub struct LaneStats {
    pub jobs: usize,
    pub errors: usize,
    /// Sum of modeled makespans this lane executed, ms.
    pub modeled_ms: f64,
}

/// Lifetime totals of a drained service.
#[derive(Debug, Clone, Default)]
pub struct ServiceStats {
    pub lanes: Vec<LaneStats>,
    pub cache_hits: u64,
    pub cache_misses: u64,
    /// Admission sheds per tenant (over-budget + deadline-infeasible),
    /// sorted by tenant name; empty when admission control is off.
    pub shed: Vec<(String, u64)>,
    /// Plans that passed the static hazard verifier on the service
    /// path (debug builds; 0 in release, where the gate compiles out).
    pub verified: u64,
}

impl ServiceStats {
    pub fn jobs(&self) -> usize {
        self.lanes.iter().map(|l| l.jobs).sum()
    }

    pub fn errors(&self) -> usize {
        self.lanes.iter().map(|l| l.errors).sum()
    }

    pub fn modeled_ms(&self) -> f64 {
        self.lanes.iter().map(|l| l.modeled_ms).sum()
    }

    /// Modeled time to drain the whole set: the busiest lane's total.
    /// Under the virtual clock this — not wall time — is the physics
    /// headline: `modeled_ms() / modeled_drain_ms()` is the modeled
    /// speedup of L lanes over one device running the set serially.
    pub fn modeled_drain_ms(&self) -> f64 {
        self.lanes.iter().map(|l| l.modeled_ms).fold(0.0, f64::max)
    }

    pub fn shed_total(&self) -> u64 {
        self.shed.iter().map(|(_, n)| n).sum()
    }
}

/// The multi-tenant execution front-end (module docs).
pub struct StreamService {
    shared: Arc<Shared>,
    lanes: Vec<JoinHandle<LaneStats>>,
}

impl StreamService {
    /// Spawn the lane workers and start accepting submissions.
    pub fn start(cfg: ServiceConfig, policy: Arc<dyn TunePolicy>) -> Result<Self> {
        let shared = Arc::new(Shared {
            queue: Mutex::new(QueueState { admission: Admission::new(), closed: false }),
            cv: Condvar::new(),
            cache: Mutex::new(HashMap::new()),
            spec_cache: Mutex::new(HashMap::new()),
            spec_choices: Mutex::new(HashMap::new()),
            choices: Mutex::new(HashMap::new()),
            cache_hits: AtomicU64::new(0),
            cache_misses: AtomicU64::new(0),
            policy,
            runs: cfg.runs.max(1),
            // Same dilation rule as ContextBuilder::profile, so submit-
            // path decisions equal what lanes would have computed.
            profile: cfg.profile.simulation(),
            admission: cfg.admission,
            gates: Mutex::new(HashMap::new()),
            verified: AtomicU64::new(0),
        });
        let mut lanes = Vec::with_capacity(cfg.lanes.max(1));
        for lane in 0..cfg.lanes.max(1) {
            let shared = shared.clone();
            let cfg = cfg.clone();
            let handle = std::thread::Builder::new()
                .name(format!("hetstream-lane-{lane}"))
                .spawn(move || lane_loop(lane, &shared, &cfg))
                .map_err(|e| Error::Service(format!("spawn service lane {lane}: {e}")))?;
            lanes.push(handle);
        }
        Ok(Self { shared, lanes })
    }

    /// Enqueue a submission for `tenant`; returns immediately.
    ///
    /// With admission control enabled ([`ServiceConfig::admission`])
    /// the submission is first charged its modeled cost against the
    /// tenant's token bucket; an over-budget submission is rejected
    /// here with [`Error::Admission`] — it never enters the queue, so
    /// shedding is O(1) however deep the backlog.  Without admission
    /// control this never fails.
    pub fn submit(&self, tenant: &str, req: Request) -> Result<Ticket> {
        self.submit_with_deadline(tenant, req, None)
    }

    /// [`Self::submit`] with a modeled-ms deadline: a request whose
    /// *estimated* cost already exceeds `deadline_ms` is rejected at
    /// submit as deadline-infeasible (running it could only miss), on
    /// top of the token-bucket budget check.
    pub fn submit_with_deadline(
        &self,
        tenant: &str,
        req: Request,
        deadline_ms: Option<f64>,
    ) -> Result<Ticket> {
        // Malformed specs are refused here with a clean `Error::Spec`
        // — never queued, never compiled, never a hang.  Everything
        // past this point (policy, cache, lanes) may assume validity.
        if let Request::Spec(spec) = &req {
            spec.validate()?;
        }
        if self.shared.admission.is_some() || deadline_ms.is_some() {
            let est_ms = self.estimate_cost_ms(&req);
            if let Some(deadline) = deadline_ms {
                if !est_ms.is_finite() || est_ms > deadline {
                    self.record_shed(tenant);
                    return Err(Error::Admission {
                        tenant: tenant.to_string(),
                        reason: format!(
                            "deadline-infeasible: modeled cost {est_ms:.2} ms exceeds the \
                             {deadline:.2} ms deadline"
                        ),
                    });
                }
            }
            if let Some(cfg) = &self.shared.admission {
                let now = Instant::now();
                let mut gates = relock(&self.shared.gates);
                let gate = gates
                    .entry(tenant.to_string())
                    .or_insert_with(|| TenantGate { bucket: TokenBucket::new(cfg, now), shed: 0 });
                if !gate.bucket.try_charge(cfg, now, est_ms) {
                    gate.shed += 1;
                    let balance = gate.bucket.tokens_ms;
                    return Err(Error::Admission {
                        tenant: tenant.to_string(),
                        reason: format!(
                            "over budget: modeled cost {est_ms:.2} ms exceeds the tenant's \
                             {balance:.2} ms balance (refill {:.0} ms/s, burst {:.0} ms)",
                            cfg.refill_ms_per_sec, cfg.burst_ms
                        ),
                    });
                }
            }
        }
        let (tx, rx) = channel();
        {
            let mut q = relock(&self.shared.queue);
            let job =
                Job { tenant: tenant.to_string(), req, tx, enqueued: Instant::now() };
            q.admission.push(tenant, job);
        }
        self.shared.cv.notify_all();
        Ok(Ticket { rx })
    }

    /// The modeled-ms admission charge for a request: the memoized
    /// policy decision's estimate for descriptors (the same decision
    /// the lane will reuse), [`predict_plan_cost_ms`] at the requested
    /// stream count for pre-lowered plans (already lowered, so the
    /// stage-time walk is cheap).
    fn estimate_cost_ms(&self, req: &Request) -> f64 {
        match req {
            Request::Corpus(c) => self.shared.choice_for(c).est_ms,
            Request::Plan { plan, streams } => {
                crate::analysis::predict_plan_cost_ms(plan, &self.shared.profile, *streams)
            }
            Request::Spec(spec) => self.shared.choice_for_spec(spec).est_ms,
        }
    }

    /// Count a shed for `tenant` (deadline rejections shed even when
    /// token-bucket admission is off, so the bucket config falls back
    /// to the default — the bucket itself is only consulted when
    /// [`ServiceConfig::admission`] is set).
    fn record_shed(&self, tenant: &str) {
        let cfg = self.shared.admission.unwrap_or_default();
        let now = Instant::now();
        relock(&self.shared.gates)
            .entry(tenant.to_string())
            .or_insert_with(|| TenantGate { bucket: TokenBucket::new(&cfg, now), shed: 0 })
            .shed += 1;
    }

    /// Lifetime admission sheds for one tenant (0 if never seen).
    pub fn shed_count(&self, tenant: &str) -> u64 {
        relock(&self.shared.gates).get(tenant).map(|g| g.shed).unwrap_or(0)
    }

    /// Jobs admitted but not yet claimed by a lane.
    pub fn pending(&self) -> usize {
        relock(&self.shared.queue).admission.len()
    }

    /// Drain the queue, stop the lanes, and return lifetime stats.
    pub fn shutdown(mut self) -> ServiceStats {
        self.close();
        let handles = std::mem::take(&mut self.lanes);
        let lanes: Vec<LaneStats> =
            handles.into_iter().map(|h| h.join().unwrap_or_default()).collect();
        let mut shed: Vec<(String, u64)> = relock(&self.shared.gates)
            .iter()
            .map(|(t, g)| (t.clone(), g.shed))
            .collect();
        shed.sort();
        ServiceStats {
            lanes,
            cache_hits: self.shared.cache_hits.load(Ordering::Relaxed),
            cache_misses: self.shared.cache_misses.load(Ordering::Relaxed),
            shed,
            verified: self.shared.verified.load(Ordering::Relaxed),
        }
    }

    /// Close the queue and wake every lane.  Recovers a poisoned queue
    /// lock: skipping the close there (the old `if let Ok` behavior)
    /// meant one panicked holder made every lane park forever and
    /// `shutdown()` join forever.
    fn close(&self) {
        relock(&self.shared.queue).closed = true;
        self.shared.cv.notify_all();
    }
}

impl Drop for StreamService {
    /// A dropped (not shut down) service must still release its lane
    /// threads: mark the queue closed and wake everyone, *without*
    /// joining — the lanes finish their current job, drain what's
    /// queued, and exit on their own.  Without this, an early-return
    /// path in a caller would park every lane on the condvar forever.
    fn drop(&mut self) {
        self.close();
    }
}

/// What one lane executes jobs on: a modeled device, or a native host
/// pool whose arena is reused across every job the lane runs.
enum LaneExec {
    Sim(Context),
    Native(NativeBackend),
}

fn lane_loop(lane: usize, shared: &Shared, cfg: &ServiceConfig) -> LaneStats {
    let mut stats = LaneStats::default();
    // The lane's executor.  If it cannot be built, the lane still
    // drains jobs — with error reports — so no ticket ever hangs on a
    // dead lane.  Native lanes skip the modeled device entirely (no
    // engine threads, no artifact compile) and keep one arena-pooled
    // NativeBackend for their lifetime.
    let exec: Result<LaneExec> = match cfg.backend {
        ExecBackend::Native => Ok(LaneExec::Native(NativeBackend::new())),
        ExecBackend::Sim => {
            let mut b =
                ContextBuilder::new().profile(cfg.profile.clone()).time_mode(cfg.time_mode);
            if let Some(names) = &cfg.artifacts {
                b = b.only_artifacts(names.clone());
            }
            b.build().map(LaneExec::Sim)
        }
    };
    // Artifacts this lane compiled.  A plan launching anything else
    // must be refused up front on *sim* lanes: the engine's kex worker
    // panics on an uncompiled artifact and its event never completes,
    // which would hang the lane (and the ticket, and shutdown)
    // forever.  Native lanes load artifacts per plan and fail with a
    // clean signature error instead, so they need no gate.
    let allowed: Option<std::collections::HashSet<&str>> = match cfg.backend {
        ExecBackend::Sim => cfg.artifacts.as_ref().map(|v| v.iter().map(|s| s.as_str()).collect()),
        ExecBackend::Native => None,
    };
    loop {
        let job = {
            let mut q = relock(&shared.queue);
            loop {
                if let Some(job) = q.admission.pop() {
                    break job;
                }
                if q.closed {
                    return stats;
                }
                // A poisoned wait still hands back the guard — recover
                // it like every other lock here.
                q = shared.cv.wait(q).unwrap_or_else(|e| e.into_inner());
            }
        };
        let queue_wait_ms = job.enqueued.elapsed().as_secs_f64() * 1e3;
        let mut report = match &exec {
            Ok(exec) => run_job(lane, shared, exec, &job, allowed.as_ref()),
            Err(e) => error_report(
                lane,
                cfg.backend.label(),
                &job,
                format!("lane executor failed to build: {e}"),
            ),
        };
        report.queue_wait_ms = queue_wait_ms;
        report.e2e_ms = job.enqueued.elapsed().as_secs_f64() * 1e3;
        stats.jobs += 1;
        if report.error.is_some() {
            stats.errors += 1;
        } else {
            stats.modeled_ms += report.modeled_ms;
        }
        // A dropped ticket is fine — the work still counts.
        let _ = job.tx.send(report);
    }
}

fn error_report(lane: usize, backend: &'static str, job: &Job, error: String) -> SubmissionReport {
    let name = match &job.req {
        Request::Corpus(c) => format!("{}/{}", c.app, c.config),
        Request::Plan { plan, .. } => plan.name.clone(),
        Request::Spec(spec) => spec.name.clone(),
    };
    SubmissionReport {
        tenant: job.tenant.clone(),
        name,
        category: None,
        streams: 0,
        gran: None,
        learned: false,
        lane,
        backend,
        cache_hit: false,
        modeled_ms: f64::NAN,
        queue_wait_ms: f64::NAN,
        e2e_ms: f64::NAN,
        outputs: Vec::new(),
        error: Some(error),
    }
}

fn run_job(
    lane: usize,
    shared: &Shared,
    exec: &LaneExec,
    job: &Job,
    allowed: Option<&std::collections::HashSet<&str>>,
) -> SubmissionReport {
    let backend_label = match exec {
        LaneExec::Sim(_) => "sim",
        LaneExec::Native(_) => "native",
    };
    // Resolve the submission to (plan, streams) — policy + cache for
    // descriptors, pass-through for pre-lowered plans.
    let (plan, streams, mut report) = match &job.req {
        Request::Corpus(c) => {
            // Memoized policy decision — the same entry the submit
            // path's admission charge consulted (`Shared::choice_for`).
            let choice = shared.choice_for(c);
            let key: CacheKey = (c.suite.label(), c.app, c.config.clone(), choice.gran);
            // Slot creation is atomic under the cache lock, so exactly
            // one submission per key is the creator (= the cache miss);
            // everyone else is a hit, even if they arrive while the
            // creator is still lowering — they block in `get_or_init`
            // below rather than duplicating the multi-MiB lowering.
            let (slot, cache_hit) = {
                let mut cache = relock(&shared.cache);
                match cache.get(&key) {
                    Some(slot) => (slot.clone(), true),
                    None => {
                        let slot: CacheSlot = Arc::new(std::sync::OnceLock::new());
                        cache.insert(key, slot.clone());
                        (slot, false)
                    }
                }
            };
            if cache_hit {
                shared.cache_hits.fetch_add(1, Ordering::Relaxed);
            } else {
                shared.cache_misses.fetch_add(1, Ordering::Relaxed);
            }
            let plan = slot
                .get_or_init(|| {
                    Arc::new(lower_corpus_streamed_at(
                        c,
                        CORPUS_BURNER,
                        Granularity::new(choice.gran),
                    ))
                })
                .clone();
            let report = SubmissionReport {
                tenant: job.tenant.clone(),
                name: plan.name.clone(),
                category: Some(c.category().label()),
                streams: choice.streams,
                gran: Some(choice.gran),
                learned: choice.learned,
                lane,
                backend: backend_label,
                cache_hit,
                modeled_ms: f64::NAN,
                queue_wait_ms: f64::NAN,
                e2e_ms: f64::NAN,
                outputs: Vec::new(),
                error: None,
            };
            (plan, choice.streams, report)
        }
        Request::Plan { plan, streams } => {
            let report = SubmissionReport {
                tenant: job.tenant.clone(),
                name: plan.name.clone(),
                category: None,
                streams: (*streams).max(1),
                gran: None,
                learned: false,
                lane,
                backend: backend_label,
                cache_hit: false,
                modeled_ms: f64::NAN,
                queue_wait_ms: f64::NAN,
                e2e_ms: f64::NAN,
                outputs: Vec::new(),
                error: None,
            };
            (plan.clone(), (*streams).max(1), report)
        }
        Request::Spec(spec) => {
            // Mirrors the corpus arm: memoized policy decision, then a
            // single-flight cache slot keyed by content hash at the
            // effective granularity (see `SpecCacheKey`).
            let choice = shared.choice_for_spec(spec);
            let key: SpecCacheKey = (spec.content_hash(), choice.gran);
            let (slot, cache_hit) = {
                let mut cache = relock(&shared.spec_cache);
                match cache.get(&key) {
                    Some(slot) => (slot.clone(), true),
                    None => {
                        let slot: CacheSlot = Arc::new(std::sync::OnceLock::new());
                        cache.insert(key, slot.clone());
                        (slot, false)
                    }
                }
            };
            if cache_hit {
                shared.cache_hits.fetch_add(1, Ordering::Relaxed);
            } else {
                shared.cache_misses.fetch_add(1, Ordering::Relaxed);
            }
            let plan = slot
                .get_or_init(|| {
                    Arc::new(
                        SpecCompiler::new(spec).streamed_at(Granularity::new(choice.gran)),
                    )
                })
                .clone();
            let report = SubmissionReport {
                tenant: job.tenant.clone(),
                name: plan.name.clone(),
                category: Some(spec.category.label()),
                streams: choice.streams,
                gran: Some(choice.gran),
                learned: choice.learned,
                lane,
                backend: backend_label,
                cache_hit,
                modeled_ms: f64::NAN,
                queue_wait_ms: f64::NAN,
                e2e_ms: f64::NAN,
                outputs: Vec::new(),
                error: None,
            };
            (plan, choice.streams, report)
        }
    };

    // Refuse plans that launch artifacts this lane never compiled —
    // see `lane_loop`: running one would hang the lane, not error.
    if let Some(allowed) = allowed {
        if let Some(missing) =
            plan.artifacts().into_iter().find(|a| !allowed.contains(a.as_str()))
        {
            report.error = Some(format!(
                "plan launches artifact `{missing}` but the service lanes only compiled {:?}",
                allowed
            ));
            return report;
        }
    }

    // Debug builds discharge the static hazard proof on every plan the
    // service admits — validate first (so malformed plans keep their
    // validation error text, same order as the backend gates), then the
    // byte-interval race/lifetime verifier (DESIGN.md §Verification).
    // The backends repeat the check at submit; doing it here too makes
    // the refusal attributable to the service path (clean report, no
    // lane churn) and feeds the `verified` stat.  Pure analysis: the
    // modeled makespan never sees it.
    if cfg!(debug_assertions) {
        if let Err(e) = plan.validate().and_then(|()| crate::plan::ensure_sound(&plan)) {
            report.error = Some(e.to_string());
            return report;
        }
        shared.verified.fetch_add(1, Ordering::Relaxed);
    }

    let mut samples = Vec::with_capacity(shared.runs);
    for rep in 0..shared.runs {
        // On sim lanes `run.wall` is the modeled makespan (virtual
        // clock); on native lanes it is real host execution time.
        let result = match exec {
            LaneExec::Sim(ctx) => SimBackend::new(ctx).run(&plan, RunConfig::streams(streams)),
            LaneExec::Native(nb) => nb.run(&plan, RunConfig::streams(streams)),
        };
        match result {
            Ok(run) => {
                samples.push(run.wall);
                if rep == 0 {
                    report.outputs = run.outputs;
                }
            }
            Err(e) => {
                report.error = Some(e.to_string());
                return report;
            }
        }
    }
    report.modeled_ms = median_duration(&mut samples).as_secs_f64() * 1e3;
    report
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn admission_serves_tenants_round_robin() {
        let mut a: Admission<u32> = Admission::new();
        // Tenant A floods; B and C trickle.
        for i in 0..4 {
            a.push("a", i);
        }
        a.push("b", 10);
        a.push("c", 20);
        a.push("c", 21);
        assert_eq!(a.len(), 7);
        let order: Vec<u32> = std::iter::from_fn(|| a.pop()).collect();
        // One job per tenant per turn, tenants in first-appearance
        // order; A's backlog drains only once the others are empty.
        assert_eq!(order, vec![0, 10, 20, 1, 21, 2, 3]);
        assert_eq!(a.len(), 0);
        assert!(a.pop().is_none());
    }

    #[test]
    fn admission_cursor_survives_empty_tenants() {
        let mut a: Admission<u32> = Admission::new();
        a.push("a", 0);
        a.push("b", 1);
        assert_eq!(a.pop(), Some(0));
        // "a" is now empty but still registered; the cursor must skip
        // it without losing "b".
        a.push("a", 2);
        assert_eq!(a.pop(), Some(1));
        assert_eq!(a.pop(), Some(2));
        assert_eq!(a.pop(), None);
    }

    #[test]
    fn token_bucket_sheds_floods_and_refills_idle_tenants() {
        let cfg = AdmissionConfig { refill_ms_per_sec: 100.0, burst_ms: 200.0 };
        let t0 = Instant::now();
        let mut b = TokenBucket::new(&cfg, t0);
        // A flooding tenant drains its burst, then is shed: 200 ms of
        // budget covers exactly four 50 ms requests at the same instant.
        for i in 0..4 {
            assert!(b.try_charge(&cfg, t0, 50.0), "charge {i} fits the burst");
        }
        assert!(!b.try_charge(&cfg, t0, 50.0), "the fifth charge must be shed");
        // An idle second refills 100 ms — two more requests, not three.
        let t1 = t0 + std::time::Duration::from_secs(1);
        assert!(b.try_charge(&cfg, t1, 50.0));
        assert!(b.try_charge(&cfg, t1, 50.0));
        assert!(!b.try_charge(&cfg, t1, 50.0));
        // Refill caps at the burst: ten idle seconds don't bank 1000 ms.
        let t2 = t1 + std::time::Duration::from_secs(10);
        assert!(b.try_charge(&cfg, t2, 200.0), "balance is capped at burst_ms");
        assert!(!b.try_charge(&cfg, t2, 1.0));
        // A request larger than the burst is never admissible.
        let mut fresh = TokenBucket::new(&cfg, t2);
        assert!(!fresh.try_charge(&cfg, t2, 201.0), "over-burst request is over-budget forever");
    }

    fn corpus_config() -> BenchConfig {
        crate::corpus::all_configs().into_iter().next().expect("corpus")
    }

    fn admission_service(admission: Option<AdmissionConfig>) -> StreamService {
        StreamService::start(
            ServiceConfig { lanes: 1, admission, ..ServiceConfig::default() },
            Arc::new(AnalyticPolicy),
        )
        .expect("service starts")
    }

    #[test]
    fn flooding_tenant_is_shed_while_idle_tenant_is_admitted() {
        // Size the burst in units of the descriptor's own modeled cost
        // so the test is profile-independent: ~3 requests fit, then
        // the flooder is shed with Error::Admission while a tenant
        // that has not spent its budget is still admitted.
        let c = corpus_config();
        let est = AnalyticPolicy.choose(&c, &DeviceProfile::mic31sp().simulation()).est_ms;
        assert!(est.is_finite() && est > 0.0);
        let service = admission_service(Some(AdmissionConfig {
            refill_ms_per_sec: est * 1e-3, // effectively no refill within the test
            burst_ms: est * 3.5,
        }));
        let mut admitted = Vec::new();
        let mut shed = 0u64;
        for _ in 0..20 {
            match service.submit("flood", Request::Corpus(c.clone())) {
                Ok(t) => admitted.push(t),
                Err(Error::Admission { tenant, .. }) => {
                    assert_eq!(tenant, "flood");
                    shed += 1;
                }
                Err(e) => panic!("unexpected error: {e}"),
            }
        }
        assert!(!admitted.is_empty(), "the burst must admit something");
        assert!(shed > 0, "a 20-deep flood must overrun a ~3-request burst");
        assert_eq!(service.shed_count("flood"), shed);
        // The well-behaved tenant's own bucket is untouched.
        let ticket =
            service.submit("idle", Request::Corpus(c)).expect("idle tenant admitted");
        assert!(ticket.wait().expect("report").ok());
        assert_eq!(service.shed_count("idle"), 0);
        for t in admitted {
            assert!(t.wait().expect("report").ok());
        }
        let stats = service.shutdown();
        assert_eq!(stats.shed, vec![("flood".to_string(), shed)]);
        assert_eq!(stats.shed_total(), shed);
    }

    #[test]
    fn deadline_infeasible_request_is_rejected_at_submit() {
        // Deadline checks apply even without token-bucket admission.
        let service = admission_service(None);
        let c = corpus_config();
        let est = AnalyticPolicy.choose(&c, &service.shared.profile).est_ms;
        assert!(est.is_finite() && est > 0.0);
        let err = service
            .submit_with_deadline("t", Request::Corpus(c.clone()), Some(est / 2.0))
            .expect_err("a deadline below the modeled cost is infeasible");
        assert!(
            matches!(&err, Error::Admission { tenant, reason }
                if tenant == "t" && reason.contains("deadline-infeasible")),
            "{err}"
        );
        assert_eq!(service.shed_count("t"), 1);
        // A feasible deadline admits normally.
        let report = service
            .submit_with_deadline("t", Request::Corpus(c), Some(est * 2.0))
            .expect("feasible deadline admits")
            .wait()
            .expect("report");
        assert!(report.ok());
        service.shutdown();
    }

    #[test]
    fn native_lanes_serve_with_bitwise_sim_parity() {
        // The same corpus submission through a sim-lane service and a
        // native-lane service must assemble identical bytes; only the
        // meaning of the reported time changes (modeled vs real wall).
        let c = corpus_config();
        let sim = admission_service(None);
        let sref =
            sim.submit("t", Request::Corpus(c.clone())).expect("sim admit").wait().expect("sim");
        sim.shutdown();
        assert_eq!(sref.backend, "sim");

        let native = StreamService::start(
            ServiceConfig { lanes: 1, backend: ExecBackend::Native, ..ServiceConfig::default() },
            Arc::new(AnalyticPolicy),
        )
        .expect("native service starts");
        let nref = native
            .submit("t", Request::Corpus(c))
            .expect("native admit")
            .wait()
            .expect("native");
        let stats = native.shutdown();
        assert!(nref.ok(), "{:?}", nref.error);
        assert_eq!(nref.backend, "native");
        assert_eq!(sref.outputs, nref.outputs, "sim and native lanes diverge");
        assert_eq!(stats.jobs(), 1);
    }

    fn demo_spec() -> WorkloadSpec {
        use crate::spec::{BufferInit, BufferSpec, HaloSpec, SpecMode, StageSpec};
        WorkloadSpec {
            name: "spec-demo".into(),
            category: crate::analysis::Category::Independent,
            mode: SpecMode::Windows,
            granularity: 4,
            repeats: 1,
            output_bytes: 65536,
            block_bytes: crate::spec::KEX_BLOCK_BYTES,
            steps: 0,
            penalty: 0,
            halo: HaloSpec::ZERO,
            buffers: vec![BufferSpec {
                name: "a".into(),
                bytes: 65536,
                init: BufferInit::F32Rand { seed: 9 },
            }],
            stages: vec![StageSpec {
                kernel: CORPUS_BURNER.into(),
                inputs: vec!["a".into()],
                flops: Some(1_000_000),
            }],
        }
    }

    #[test]
    fn spec_submissions_ride_the_cache_and_policy() {
        // Spec requests get the full corpus treatment: policy-chosen
        // (streams, gran), content-hash plan cache, clean refusal of
        // malformed specs at submit.
        let service = admission_service(None);
        let spec = Arc::new(demo_spec());
        let r1 = service
            .submit("t", Request::Spec(spec.clone()))
            .expect("valid spec admits")
            .wait()
            .expect("report");
        assert!(r1.ok(), "{:?}", r1.error);
        assert!(!r1.cache_hit, "first submission lowers");
        assert_eq!(r1.name, "spec-demo");
        assert_eq!(r1.category, Some("Independent"));
        assert!(r1.streams >= 1 && r1.gran.unwrap_or(0) >= 1);
        let r2 = service
            .submit("t", Request::Spec(spec.clone()))
            .expect("resubmit admits")
            .wait()
            .expect("report");
        assert!(r2.cache_hit, "same content hash must hit the cache");
        assert_eq!(r1.outputs, r2.outputs, "cached plan replays byte-exactly");

        let mut bad = demo_spec();
        bad.stages[0].kernel = "no_such_kernel".into();
        match service.submit("t", Request::Spec(Arc::new(bad))) {
            Err(Error::Spec(m)) => assert!(m.contains("unknown kernel"), "{m}"),
            other => panic!("malformed spec must be Error::Spec, got {:?}", other.is_ok()),
        }

        let stats = service.shutdown();
        assert_eq!(stats.jobs(), 2, "the malformed spec never reached a lane");
        assert_eq!((stats.cache_hits, stats.cache_misses), (1, 1));
    }

    #[test]
    fn exec_backend_parses_cli_labels() {
        assert_eq!(ExecBackend::parse("sim").unwrap(), ExecBackend::Sim);
        assert_eq!(ExecBackend::parse("native").unwrap(), ExecBackend::Native);
        assert!(ExecBackend::parse("cuda").is_err());
        assert_eq!(ExecBackend::default().label(), "sim");
    }

    #[test]
    fn service_survives_poisoned_locks() {
        // Poison every recoverable lock by panicking while holding it,
        // then prove the service still admits, serves, and shuts down —
        // the regression for the seven lock().unwrap() sites that used
        // to cascade one panicked thread into a wedged service.
        let service = admission_service(Some(AdmissionConfig::default()));
        let shared = service.shared.clone();
        std::thread::spawn(move || {
            let _q = shared.queue.lock().unwrap();
            let _c = shared.cache.lock().unwrap();
            let _ch = shared.choices.lock().unwrap();
            let _g = shared.gates.lock().unwrap();
            panic!("poison all service locks");
        })
        .join()
        .expect_err("the poisoning thread must panic");
        assert!(service.shared.queue.is_poisoned(), "queue lock must actually be poisoned");
        let report = service
            .submit("tenant", Request::Corpus(corpus_config()))
            .expect("poisoned service still admits")
            .wait()
            .expect("poisoned service still serves");
        assert!(report.ok(), "{:?}", report.error);
        assert_eq!(service.pending(), 0);
        let stats = service.shutdown();
        assert_eq!(stats.jobs(), 1);
        assert_eq!(stats.errors(), 0);
    }
}
