//! `AdaptiveController` — the windowed feedback loop behind
//! `--adaptive` (DESIGN.md §Adaptive).
//!
//! The controller is **pure**: it never reads a clock, never touches a
//! thread, never holds a lock of its own.  The service feeds it
//! millisecond timestamps (`now_ms`, measured from the service's start
//! epoch) on every submit / shed / completion, and asks it for a
//! [`Decision`] — the three actuator settings — whenever something
//! changed.  That inversion keeps the whole state machine
//! deterministic and unit-testable with synthetic tick series (no
//! sleeps, no threads; see the tests at the bottom).
//!
//! Telemetry is a sliding window (default 1 s) of submit and shed
//! timestamps plus recent inter-arrival gaps.  Three actuators hang
//! off it, each guarded by **hysteresis** (distinct on/off thresholds)
//! and a **minimum dwell time** (a switch pins the actuator for
//! `dwell_ms` before it may flip back), so a single spike or a
//! threshold-straddling load never flaps a mode:
//!
//! 1. **Request batching** — on when the offered load (submits + sheds
//!    per second) crosses `batch_on_rps`, off again only below
//!    `batch_off_rps`.  While on, lanes coalesce queued same-key
//!    submissions into one backend run (the fan-out lives in
//!    `service/mod.rs`; exactness argument in DESIGN.md).
//! 2. **Lane elasticity** — the lane *target* steps up one lane when
//!    the queue is deep (`depth > grow_depth × target`) **and** the
//!    window shows sustained arrivals (more submits in the window than
//!    lanes to absorb them — a lone spike can stack depth but not
//!    sustained arrivals, so it never grows the fleet), down one lane
//!    when the queue is drained and arrivals are sparse.  The service
//!    spawns toward the target on submit and lets surplus lanes retire
//!    themselves between jobs (quiesce-then-exit, never mid-job).
//! 3. **Wakeup mode** — idle lanes park on the condvar by default;
//!    when the mean inter-arrival gap in the window drops under
//!    `spin_on_gap_ms`, lanes switch to a bounded spin-poll (claim
//!    latency under heavy traffic), and back to parking once gaps
//!    stretch past `park_on_gap_ms`.
//!
//! The controller also keeps a per-second tick log ([`AdaptiveTick`]):
//! mode, lane target, and batch count for each elapsed second, which
//! `repro bench` merges into the `hetstream-bench-v3` tick series.

use std::collections::VecDeque;

/// Tuning knobs for the adaptive runtime.  All thresholds are paired
/// (hysteresis) and every actuator shares the one `dwell_ms` guard.
#[derive(Debug, Clone, Copy)]
pub struct AdaptiveConfig {
    /// Sliding telemetry window, ms.
    pub window_ms: u64,
    /// Minimum time between two switches of the same actuator, ms.
    pub dwell_ms: u64,
    /// Batching turns ON when offered load (submits + sheds per
    /// second over the window) exceeds this.
    pub batch_on_rps: f64,
    /// Batching turns OFF when offered load falls below this (must be
    /// `< batch_on_rps` for hysteresis to bite).
    pub batch_off_rps: f64,
    /// Most tickets one coalesced backend run may serve.
    pub max_batch: usize,
    /// Lane-target floor (elasticity never drains below this).
    pub min_lanes: usize,
    /// Lane-target cap (`--max-lanes`).
    pub max_lanes: usize,
    /// Grow one lane when queue depth exceeds `grow_depth × target`.
    pub grow_depth: usize,
    /// Shrink one lane when queue depth is at or below this.
    pub shrink_depth: usize,
    /// Spin-poll when the mean inter-arrival gap drops below this, ms.
    pub spin_on_gap_ms: f64,
    /// Park again when the mean gap stretches past this, ms.
    pub park_on_gap_ms: f64,
    /// Spin-poll budget: claim attempts a lane makes before it falls
    /// back to the condvar (bounds idle CPU burn if traffic stops
    /// mid-dwell).
    pub spin_rounds: u32,
}

impl Default for AdaptiveConfig {
    fn default() -> Self {
        Self {
            window_ms: 1_000,
            dwell_ms: 250,
            batch_on_rps: 100.0,
            batch_off_rps: 25.0,
            max_batch: 16,
            min_lanes: 1,
            max_lanes: 8,
            grow_depth: 4,
            shrink_depth: 1,
            spin_on_gap_ms: 2.0,
            park_on_gap_ms: 20.0,
            spin_rounds: 64,
        }
    }
}

impl AdaptiveConfig {
    /// Clamp the knobs into a consistent state (hysteresis pairs
    /// ordered, floors ≤ caps, nonzero window) so a hostile CLI can't
    /// configure a controller that flaps by construction.
    pub fn normalized(mut self) -> Self {
        self.window_ms = self.window_ms.max(1);
        self.max_batch = self.max_batch.max(1);
        self.min_lanes = self.min_lanes.max(1);
        self.max_lanes = self.max_lanes.max(self.min_lanes);
        self.grow_depth = self.grow_depth.max(1);
        self.batch_off_rps = self.batch_off_rps.min(self.batch_on_rps);
        self.park_on_gap_ms = self.park_on_gap_ms.max(self.spin_on_gap_ms);
        self
    }
}

/// How idle lanes wait for work.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum WakeupMode {
    /// Park on the condvar (zero idle CPU; wake latency = notify).
    #[default]
    Park,
    /// Bounded spin-poll before parking (claim latency under load).
    Spin,
}

impl WakeupMode {
    /// Label used in bench ticks and stats (`"park"` / `"spin"`).
    pub fn label(self) -> &'static str {
        match self {
            WakeupMode::Park => "park",
            WakeupMode::Spin => "spin",
        }
    }
}

/// The three actuator settings the controller currently wants.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Decision {
    /// Coalesce queued same-key submissions into one run.
    pub batching: bool,
    /// Lane target the service should spawn/drain toward.
    pub target_lanes: usize,
    /// How idle lanes should wait.
    pub wakeup: WakeupMode,
}

/// One second of the controller's life, for the bench tick series.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct AdaptiveTick {
    /// Whole seconds since the service epoch.
    pub t_s: u64,
    /// Wakeup mode in force at the end of the second.
    pub mode: WakeupMode,
    /// Lane target at the end of the second.
    pub lanes: usize,
    /// Coalesced (multi-ticket) runs completed during the second.
    pub batches: u64,
}

/// Lifetime counters of one adaptive run.
#[derive(Debug, Clone, Default)]
pub struct AdaptiveStats {
    /// Coalesced backend runs (each served ≥ 2 tickets).
    pub batches: u64,
    /// Tickets served by those coalesced runs.
    pub batched_jobs: u64,
    /// Times the batching actuator toggled (either direction).
    pub batch_toggles: u64,
    /// Times the wakeup mode flipped (either direction).
    pub wakeup_switches: u64,
    /// Lanes the service actually spawned beyond its initial fleet.
    pub lane_grows: u64,
    /// Lanes that quiesced and retired.
    pub lane_retires: u64,
    /// Largest live-lane count the service reached.
    pub peak_lanes: u64,
    /// Mode distribution: ms spent with lanes parking / spinning.
    pub park_ms: u64,
    pub spin_ms: u64,
}

/// The sliding-window hysteresis state machine (module docs).
#[derive(Debug)]
pub struct AdaptiveController {
    cfg: AdaptiveConfig,
    /// Submit timestamps inside the window, ms.
    submits: VecDeque<u64>,
    /// Shed timestamps inside the window, ms (offered load counts
    /// rejected traffic too — a flood we shed is still pressure).
    sheds: VecDeque<u64>,
    /// Recent (timestamp, gap_ms) inter-arrival samples.
    gaps: VecDeque<(u64, f64)>,
    last_submit_ms: Option<u64>,
    /// Queue depth as of the last observation.
    queue_depth: usize,
    batching: bool,
    target_lanes: usize,
    wakeup: WakeupMode,
    last_batch_switch_ms: u64,
    last_lane_switch_ms: u64,
    last_wakeup_switch_ms: u64,
    /// When the current wakeup mode was entered (mode distribution).
    mode_since_ms: u64,
    /// Tick accumulator: current second + its batch count.
    cur_t: u64,
    cur_batches: u64,
    ticks: Vec<AdaptiveTick>,
    stats: AdaptiveStats,
}

impl AdaptiveController {
    pub fn new(cfg: AdaptiveConfig, initial_lanes: usize) -> Self {
        let cfg = cfg.normalized();
        let target_lanes = initial_lanes.clamp(cfg.min_lanes, cfg.max_lanes);
        Self {
            cfg,
            submits: VecDeque::new(),
            sheds: VecDeque::new(),
            gaps: VecDeque::new(),
            last_submit_ms: None,
            queue_depth: 0,
            batching: false,
            target_lanes,
            wakeup: WakeupMode::Park,
            last_batch_switch_ms: 0,
            last_lane_switch_ms: 0,
            last_wakeup_switch_ms: 0,
            mode_since_ms: 0,
            cur_t: 0,
            cur_batches: 0,
            ticks: Vec::new(),
            stats: AdaptiveStats::default(),
        }
    }

    pub fn config(&self) -> &AdaptiveConfig {
        &self.cfg
    }

    /// Record an admitted submission and the queue depth just after it
    /// was enqueued.
    pub fn observe_submit(&mut self, now_ms: u64, queue_depth: usize) {
        self.roll_ticks(now_ms);
        if let Some(prev) = self.last_submit_ms {
            let gap = now_ms.saturating_sub(prev) as f64;
            self.gaps.push_back((now_ms, gap));
        }
        self.last_submit_ms = Some(now_ms);
        self.submits.push_back(now_ms);
        self.queue_depth = queue_depth;
        self.prune(now_ms);
    }

    /// Record an admission shed (offered load, not served load).
    pub fn observe_shed(&mut self, now_ms: u64) {
        self.roll_ticks(now_ms);
        self.sheds.push_back(now_ms);
        self.prune(now_ms);
    }

    /// Record a finished backend run that served `coalesced` tickets.
    pub fn observe_complete(&mut self, now_ms: u64, coalesced: usize, queue_depth: usize) {
        self.roll_ticks(now_ms);
        self.queue_depth = queue_depth;
        if coalesced > 1 {
            self.stats.batches += 1;
            self.stats.batched_jobs += coalesced as u64;
            self.cur_batches += 1;
        }
        self.prune(now_ms);
    }

    /// Offered load over the window, requests/second.
    fn offered_rps(&self) -> f64 {
        let n = (self.submits.len() + self.sheds.len()) as f64;
        n * 1_000.0 / self.cfg.window_ms as f64
    }

    /// Mean inter-arrival gap over the window, ms (`None` until two
    /// arrivals have landed inside it).
    fn mean_gap_ms(&self) -> Option<f64> {
        if self.gaps.len() < 2 {
            return None;
        }
        let sum: f64 = self.gaps.iter().map(|(_, g)| g).sum();
        Some(sum / self.gaps.len() as f64)
    }

    /// Run the hysteresis state machine and return the actuator
    /// settings in force.  `live_lanes` is the service's current lane
    /// count (the target steps relative to it so the ladder can't
    /// outrun what actually exists).
    pub fn decide(&mut self, now_ms: u64, live_lanes: usize) -> Decision {
        self.roll_ticks(now_ms);
        self.prune(now_ms);
        let rps = self.offered_rps();
        let dwell = self.cfg.dwell_ms;

        // Actuator 1: batching (offered-load hysteresis).
        if now_ms.saturating_sub(self.last_batch_switch_ms) >= dwell {
            let next = if self.batching {
                rps >= self.cfg.batch_off_rps
            } else {
                rps > self.cfg.batch_on_rps
            };
            if next != self.batching {
                self.batching = next;
                self.last_batch_switch_ms = now_ms;
                self.stats.batch_toggles += 1;
            }
        }

        // Actuator 2: lane target.  Growth needs *sustained* pressure:
        // a deep queue AND more window arrivals than lanes to absorb
        // them — a single spike satisfies the first but never the
        // second, so it cannot grow the fleet.
        if now_ms.saturating_sub(self.last_lane_switch_ms) >= dwell {
            let target = self.target_lanes.clamp(self.cfg.min_lanes, self.cfg.max_lanes);
            let deep = self.queue_depth > self.cfg.grow_depth.saturating_mul(target);
            let sustained = self.submits.len() > target;
            let drained =
                self.queue_depth <= self.cfg.shrink_depth && self.submits.len() < target;
            if deep && sustained && target < self.cfg.max_lanes {
                self.target_lanes = (live_lanes.max(target) + 1).min(self.cfg.max_lanes);
                self.last_lane_switch_ms = now_ms;
            } else if drained && target > self.cfg.min_lanes {
                self.target_lanes = target - 1;
                self.last_lane_switch_ms = now_ms;
            } else {
                self.target_lanes = target;
            }
        }

        // Actuator 3: wakeup mode (inter-arrival-gap hysteresis).
        if now_ms.saturating_sub(self.last_wakeup_switch_ms) >= dwell {
            let next = match (self.wakeup, self.mean_gap_ms()) {
                (WakeupMode::Park, Some(gap)) if gap < self.cfg.spin_on_gap_ms => {
                    WakeupMode::Spin
                }
                (WakeupMode::Spin, Some(gap)) if gap > self.cfg.park_on_gap_ms => {
                    WakeupMode::Park
                }
                // No gap data (traffic stopped): spin lanes fall back
                // to parking — never burn CPU on silence.
                (WakeupMode::Spin, None) => WakeupMode::Park,
                (mode, _) => mode,
            };
            if next != self.wakeup {
                self.credit_mode_time(now_ms);
                self.wakeup = next;
                self.last_wakeup_switch_ms = now_ms;
                self.stats.wakeup_switches += 1;
            }
        }

        Decision { batching: self.batching, target_lanes: self.target_lanes, wakeup: self.wakeup }
    }

    /// Close out the run: credit the final mode interval and flush the
    /// partial tick.  Idempotent enough for shutdown paths (a second
    /// call at the same `now_ms` adds nothing).
    pub fn finalize(&mut self, now_ms: u64) {
        self.roll_ticks(now_ms);
        self.credit_mode_time(now_ms);
        if self.cur_batches > 0 || self.ticks.is_empty() {
            self.push_tick();
        }
    }

    pub fn stats(&self) -> AdaptiveStats {
        self.stats.clone()
    }

    /// Drain the per-second tick log (bench merges it by `t_s`).
    pub fn take_ticks(&mut self) -> Vec<AdaptiveTick> {
        std::mem::take(&mut self.ticks)
    }

    fn credit_mode_time(&mut self, now_ms: u64) {
        let span = now_ms.saturating_sub(self.mode_since_ms);
        match self.wakeup {
            WakeupMode::Park => self.stats.park_ms += span,
            WakeupMode::Spin => self.stats.spin_ms += span,
        }
        self.mode_since_ms = now_ms;
    }

    /// Emit one tick per elapsed whole second (the log stays
    /// contiguous from t=0 even across quiet seconds).
    fn roll_ticks(&mut self, now_ms: u64) {
        let now_s = now_ms / 1_000;
        while self.cur_t < now_s {
            self.push_tick();
        }
    }

    fn push_tick(&mut self) {
        self.ticks.push(AdaptiveTick {
            t_s: self.cur_t,
            mode: self.wakeup,
            lanes: self.target_lanes,
            batches: self.cur_batches,
        });
        self.cur_t += 1;
        self.cur_batches = 0;
    }

    fn prune(&mut self, now_ms: u64) {
        let cut = now_ms.saturating_sub(self.cfg.window_ms);
        while self.submits.front().is_some_and(|&t| t < cut) {
            self.submits.pop_front();
        }
        while self.sheds.front().is_some_and(|&t| t < cut) {
            self.sheds.pop_front();
        }
        while self.gaps.front().is_some_and(|&(t, _)| t < cut) {
            self.gaps.pop_front();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> AdaptiveConfig {
        AdaptiveConfig {
            window_ms: 1_000,
            dwell_ms: 250,
            batch_on_rps: 100.0,
            batch_off_rps: 25.0,
            max_batch: 16,
            min_lanes: 1,
            max_lanes: 4,
            grow_depth: 2,
            shrink_depth: 1,
            spin_on_gap_ms: 2.0,
            park_on_gap_ms: 20.0,
            spin_rounds: 64,
        }
    }

    /// Feed `n` submissions spaced `gap_ms` apart starting at `t0`,
    /// holding queue depth constant; returns the last timestamp.
    fn feed(ctl: &mut AdaptiveController, t0: u64, n: usize, gap_ms: u64, depth: usize) -> u64 {
        let mut t = t0;
        for i in 0..n {
            t = t0 + i as u64 * gap_ms;
            ctl.observe_submit(t, depth);
        }
        t
    }

    #[test]
    fn batching_switches_with_hysteresis() {
        let mut ctl = AdaptiveController::new(cfg(), 1);
        // 150 submits in one window = 150 rps > batch_on (100).
        let t = feed(&mut ctl, 0, 150, 5, 3);
        let d = ctl.decide(t, 1);
        assert!(d.batching, "150 rps must switch batching on");
        // Load drops to ~50 rps: inside the hysteresis band — stays on.
        let mut ctl2 = AdaptiveController::new(cfg(), 1);
        let t = feed(&mut ctl2, 0, 150, 5, 3);
        ctl2.decide(t, 1);
        let t2 = feed(&mut ctl2, t + 1_000, 50, 20, 1); // fresh window, 50 in 1 s
        assert!(ctl2.decide(t2, 1).batching, "50 rps is inside the band: no flap");
        // Load collapses below batch_off (25): switches off.
        let t3 = feed(&mut ctl2, t2 + 2_000, 5, 200, 0);
        assert!(!ctl2.decide(t3, 1).batching, "5 rps must switch batching off");
        assert_eq!(ctl2.stats().batch_toggles, 2);
    }

    #[test]
    fn dwell_blocks_rapid_flapping() {
        let mut c = cfg();
        c.dwell_ms = 500;
        let mut ctl = AdaptiveController::new(c, 1);
        let t = feed(&mut ctl, 0, 150, 5, 3);
        assert!(ctl.decide(t, 1).batching);
        let on_at = t;
        // Traffic stops dead; within the dwell the actuator is pinned
        // even though the window has fully drained past it.
        let quiet = on_at + 499;
        ctl.observe_complete(quiet, 1, 0);
        assert!(ctl.decide(quiet, 1).batching, "dwell must pin batching on");
        // One ms past the dwell it may flip.
        assert!(!ctl.decide(on_at + 501, 1).batching, "past dwell the drop registers");
    }

    #[test]
    fn single_spike_never_flips_anything() {
        let mut ctl = AdaptiveController::new(cfg(), 1);
        // One submission with an absurd queue depth: no rate (1 rps),
        // no sustained arrivals — nothing may move.
        ctl.observe_submit(300, 10_000);
        let d = ctl.decide(300, 1);
        assert!(!d.batching, "one submit is 1 rps, not a flood");
        assert_eq!(d.target_lanes, 1, "depth without sustained arrivals must not grow lanes");
        assert_eq!(d.wakeup, WakeupMode::Park, "one gap sample must not start spinning");
        assert_eq!(ctl.stats().batch_toggles + ctl.stats().wakeup_switches, 0);
    }

    #[test]
    fn lane_target_grows_under_sustained_pressure_and_shrinks_when_drained() {
        let mut ctl = AdaptiveController::new(cfg(), 1);
        // Sustained arrivals + deep queue: grow one step per dwell.
        let t = feed(&mut ctl, 0, 50, 10, 50);
        assert_eq!(ctl.decide(t, 1).target_lanes, 2, "first grow step");
        // Within the dwell the ladder is pinned.
        assert_eq!(ctl.decide(t + 100, 2).target_lanes, 2);
        // Next dwell, still deep: another step, relative to live lanes.
        let t2 = feed(&mut ctl, t + 300, 50, 10, 50);
        assert_eq!(ctl.decide(t2, 2).target_lanes, 3, "second grow step");
        // Cap binds.
        let mut t3 = t2;
        for _ in 0..6 {
            t3 = feed(&mut ctl, t3 + 300, 50, 10, 80);
            ctl.decide(t3, 4);
        }
        assert_eq!(ctl.decide(t3, 4).target_lanes, 4, "max_lanes caps the ladder");
        // Queue drains + traffic stops: shrink one step per dwell back
        // to the floor, never below.
        let mut t4 = t3;
        for expect in [3, 2, 1, 1] {
            t4 += 1_500;
            ctl.observe_complete(t4, 1, 0);
            assert_eq!(ctl.decide(t4, 4).target_lanes, expect);
        }
        assert_eq!(ctl.decide(t4 + 1_500, 1).target_lanes, 1, "floor binds");
    }

    #[test]
    fn wakeup_follows_interarrival_gaps() {
        let mut ctl = AdaptiveController::new(cfg(), 1);
        // 1 ms gaps < spin_on (2 ms): spin.
        let t = feed(&mut ctl, 0, 400, 1, 2);
        assert_eq!(ctl.decide(t, 1).wakeup, WakeupMode::Spin);
        // 10 ms gaps: inside the band (2..20) — stays spinning.
        let t2 = feed(&mut ctl, t + 1_100, 110, 10, 1);
        assert_eq!(ctl.decide(t2, 1).wakeup, WakeupMode::Spin, "band holds the mode");
        // 50 ms gaps > park_on (20 ms): park again.
        let t3 = feed(&mut ctl, t2 + 1_100, 25, 50, 0);
        assert_eq!(ctl.decide(t3, 1).wakeup, WakeupMode::Park);
        assert_eq!(ctl.stats().wakeup_switches, 2);
        let s = ctl.stats();
        assert!(s.park_ms > 0 && s.spin_ms > 0, "mode distribution is credited");
    }

    #[test]
    fn spinning_controller_parks_when_traffic_stops() {
        let mut ctl = AdaptiveController::new(cfg(), 1);
        let t = feed(&mut ctl, 0, 400, 1, 2);
        assert_eq!(ctl.decide(t, 1).wakeup, WakeupMode::Spin);
        // Silence long enough to drain the window: no gap data → park.
        let quiet = t + 5_000;
        ctl.observe_complete(quiet, 1, 0);
        assert_eq!(ctl.decide(quiet, 1).wakeup, WakeupMode::Park, "silence must not spin");
    }

    #[test]
    fn tick_log_is_contiguous_and_counts_batches() {
        let mut ctl = AdaptiveController::new(cfg(), 2);
        ctl.observe_submit(100, 1);
        ctl.observe_complete(200, 4, 0); // one coalesced run of 4
        ctl.observe_complete(300, 1, 0); // unbatched: not a batch
        // Quiet seconds 1..3, then another batch in second 3.
        ctl.observe_complete(3_400, 2, 0);
        ctl.finalize(3_500);
        let ticks = ctl.take_ticks();
        let t_s: Vec<u64> = ticks.iter().map(|t| t.t_s).collect();
        assert_eq!(t_s, vec![0, 1, 2, 3], "contiguous from t=0 across quiet seconds");
        let batches: Vec<u64> = ticks.iter().map(|t| t.batches).collect();
        assert_eq!(batches, vec![1, 0, 0, 1]);
        assert!(ticks.iter().all(|t| t.lanes == 2 && t.mode == WakeupMode::Park));
        let s = ctl.stats();
        assert_eq!((s.batches, s.batched_jobs), (2, 6));
    }

    #[test]
    fn window_prunes_old_samples() {
        let mut ctl = AdaptiveController::new(cfg(), 1);
        feed(&mut ctl, 0, 200, 1, 2);
        // Two seconds later the window is empty: offered load is 0.
        ctl.observe_complete(2_500, 1, 0);
        assert_eq!(ctl.submits.len(), 0, "stale submits pruned");
        assert_eq!(ctl.gaps.len(), 0, "stale gaps pruned");
        assert!(ctl.offered_rps() == 0.0);
    }

    #[test]
    fn normalized_config_orders_hysteresis_pairs() {
        let c = AdaptiveConfig {
            batch_on_rps: 10.0,
            batch_off_rps: 50.0,
            spin_on_gap_ms: 30.0,
            park_on_gap_ms: 5.0,
            min_lanes: 6,
            max_lanes: 2,
            max_batch: 0,
            grow_depth: 0,
            ..AdaptiveConfig::default()
        }
        .normalized();
        assert!(c.batch_off_rps <= c.batch_on_rps);
        assert!(c.park_on_gap_ms >= c.spin_on_gap_ms);
        assert!(c.min_lanes <= c.max_lanes && c.min_lanes >= 1);
        assert!(c.max_batch >= 1 && c.grow_depth >= 1);
    }
}
