//! Tuning policies: how the service picks `(streams, granularity)`
//! for a submission (DESIGN.md §Service).
//!
//! The paper's §6 vision — and arXiv:2003.04294's argument for keeping
//! tuning *behind* the programming surface — is that callers submit
//! workloads, not knob values.  A [`TunePolicy`] is that seam: the
//! analytic closed-form seed ([`AnalyticPolicy`]) and the k-NN learned
//! tuner from the `repro learn` stack ([`LearnedPolicy`]) both plug in
//! behind the same one-call interface, and the service consults
//! whichever it was started with once per descriptor submission.

use crate::analysis::{
    analytic_corpus_choice, corpus_features, predict_plan_cost_ms, predict_plan_point,
    Category, KnnTuner, PlanFeatures,
};
use crate::corpus::BenchConfig;
use crate::device::DeviceProfile;
use crate::plan::{
    effective_corpus_granularity, lower_corpus_bulk, Granularity, StreamPlan, CORPUS_BURNER,
};

/// One policy decision for a descriptor submission.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct PolicyChoice {
    pub streams: usize,
    /// Effective granularity in the descriptor's knob units (already
    /// clamped through [`effective_corpus_granularity`]).
    pub gran: usize,
    /// Whether the choice came from a learned model (vs analytic).
    pub learned: bool,
    /// Modeled cost of one run at this choice, ms
    /// ([`predict_plan_cost_ms`] over the bulk plan) — the admission
    /// layer's token-bucket charge.  An estimate the planner computes
    /// before any execution, never a measurement.
    pub est_ms: f64,
}

impl PolicyChoice {
    /// Batch-aware admission charge: when the adaptive runtime will
    /// coalesce this submission with `coalesced − 1` queued same-key
    /// peers, the backend pays `est_ms` *once* for all of them, so
    /// each ticket's fair share is the estimate split evenly.  With
    /// `coalesced ≤ 1` (or batching off) this is just `est_ms`.
    pub fn amortized_ms(&self, coalesced: usize) -> f64 {
        self.est_ms / coalesced.max(1) as f64
    }
}

/// Picks `(streams, granularity)` for a corpus descriptor on a given
/// device profile.  Implementations must be cheap relative to a run —
/// the service calls this on the submission path, once per descriptor
/// (the plan cache then memoizes the lowering itself).
pub trait TunePolicy: Send + Sync {
    /// Short policy identifier (`"analytic"`, `"learned"`, ...).
    fn name(&self) -> &'static str;

    /// Choose the execution point for `c` on `profile`.
    fn choose(&self, c: &BenchConfig, profile: &DeviceProfile) -> PolicyChoice;

    /// Choose the execution point for an arbitrary lowered plan (the
    /// [`Request::Spec`](crate::service::Request) path: spec
    /// submissions have no descriptor to look up, but their *bulk*
    /// plan carries the same byte/FLOP profile the analytic model
    /// reads).  The returned granularity is in the workload's knob
    /// units and still gets clamped through the spec compiler by the
    /// caller.  Default: the analytic closed form
    /// ([`predict_plan_point`] + [`predict_plan_cost_ms`]).
    fn choose_plan(
        &self,
        plan: &StreamPlan,
        category: Category,
        profile: &DeviceProfile,
    ) -> PolicyChoice {
        let (streams, seed_tasks) = predict_plan_point(plan, profile);
        // Same knob mapping as `analytic_corpus_choice`: wavefront
        // categories spend the task budget as a grid side.
        let gran = match category {
            Category::TrueDependent => (seed_tasks as f64).sqrt().ceil() as usize,
            _ => seed_tasks,
        }
        .max(1);
        PolicyChoice {
            streams,
            gran,
            learned: false,
            est_ms: predict_plan_cost_ms(plan, profile, streams),
        }
    }
}

/// The closed-form §6 seed: stream count from the stage balance,
/// granularity from `m* = √(overlappable / c_task)`, mapped into the
/// category's knob units ([`analytic_corpus_choice`]).
#[derive(Debug, Clone, Copy, Default)]
pub struct AnalyticPolicy;

impl TunePolicy for AnalyticPolicy {
    fn name(&self) -> &'static str {
        "analytic"
    }

    fn choose(&self, c: &BenchConfig, profile: &DeviceProfile) -> PolicyChoice {
        let (streams, gran, est_ms) = analytic_corpus_choice(c, profile);
        PolicyChoice { streams, gran, learned: false, est_ms }
    }
}

/// The learned tuner as a policy: same-category distance-weighted k-NN
/// over [`crate::analysis::PlanFeatures`] (the `repro learn` model),
/// falling back to the analytic seed when the model has no
/// same-category training rows.  Predicted granularities are clamped
/// through [`effective_corpus_granularity`] so the choice is always a
/// knob value the lowering will actually use.
pub struct LearnedPolicy {
    knn: KnnTuner,
}

impl LearnedPolicy {
    pub fn new(knn: KnnTuner) -> Self {
        Self { knn }
    }
}

impl TunePolicy for LearnedPolicy {
    fn name(&self) -> &'static str {
        "learned"
    }

    fn choose(&self, c: &BenchConfig, profile: &DeviceProfile) -> PolicyChoice {
        match self.knn.predict(&corpus_features(c, profile)) {
            Some((streams, gran)) => PolicyChoice {
                streams,
                gran: effective_corpus_granularity(c, Granularity::new(gran)).get(),
                learned: true,
                // The cost model stays analytic either way — the k-NN
                // predicts knobs, not makespans — evaluated at the
                // *learned* stream count so admission charges what
                // this choice will actually pipeline to.
                est_ms: predict_plan_cost_ms(
                    &lower_corpus_bulk(c, CORPUS_BURNER),
                    profile,
                    streams,
                ),
            },
            None => AnalyticPolicy.choose(c, profile),
        }
    }

    fn choose_plan(
        &self,
        plan: &StreamPlan,
        category: Category,
        profile: &DeviceProfile,
    ) -> PolicyChoice {
        match self.knn.predict(&PlanFeatures::of(plan, profile, category)) {
            Some((streams, gran)) => PolicyChoice {
                streams,
                gran,
                learned: true,
                est_ms: predict_plan_cost_ms(plan, profile, streams),
            },
            None => AnalyticPolicy.choose_plan(plan, category, profile),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analysis::{analytic_corpus_seed, Dataset};

    fn sim_profile() -> DeviceProfile {
        DeviceProfile::mic31sp().simulation()
    }

    #[test]
    fn analytic_policy_matches_the_shared_seed() {
        let profile = sim_profile();
        for c in crate::corpus::all_configs().into_iter().step_by(37) {
            let choice = AnalyticPolicy.choose(&c, &profile);
            assert_eq!((choice.streams, choice.gran), analytic_corpus_seed(&c, &profile));
            assert!(!choice.learned);
            assert!(choice.streams >= 1 && choice.gran >= 1);
            assert!(
                choice.est_ms.is_finite() && choice.est_ms > 0.0,
                "{}/{}: admission cost must be a positive modeled-ms estimate, got {}",
                c.app,
                c.config,
                choice.est_ms
            );
        }
    }

    #[test]
    fn amortized_cost_splits_the_estimate_over_the_batch() {
        let choice = PolicyChoice { streams: 4, gran: 8, learned: false, est_ms: 120.0 };
        assert_eq!(choice.amortized_ms(0), 120.0, "degenerate batch charges full price");
        assert_eq!(choice.amortized_ms(1), 120.0);
        assert_eq!(choice.amortized_ms(4), 30.0);
        assert!(choice.amortized_ms(16) < choice.amortized_ms(2));
    }

    #[test]
    fn learned_policy_falls_back_without_neighbors() {
        // An empty dataset has no same-category rows for anything: the
        // learned policy must hand every choice to the analytic seed.
        let profile = sim_profile();
        let policy = LearnedPolicy::new(KnnTuner::fit(Dataset::default(), 5));
        let c = &crate::corpus::all_configs()[0];
        let choice = policy.choose(c, &profile);
        assert!(!choice.learned, "empty model must report the analytic fallback");
        assert_eq!((choice.streams, choice.gran), analytic_corpus_seed(c, &profile));
    }

    #[test]
    fn plan_level_choice_is_the_same_closed_form_the_corpus_path_uses() {
        // `choose_plan` over a descriptor's bulk plan must agree with
        // `choose` over the descriptor itself — the spec path and the
        // corpus path share one analytic model.
        let profile = sim_profile();
        for c in crate::corpus::all_configs().into_iter().step_by(61) {
            let spec = crate::spec::WorkloadSpec::from_corpus(&c, CORPUS_BURNER);
            let bulk = crate::spec::SpecCompiler::new(&spec).bulk();
            let via_plan = AnalyticPolicy.choose_plan(&bulk, c.category(), &profile);
            let via_corpus = AnalyticPolicy.choose(&c, &profile);
            assert_eq!(via_plan.streams, via_corpus.streams, "{}/{}", c.app, c.config);
            assert_eq!(via_plan.est_ms, via_corpus.est_ms, "{}/{}", c.app, c.config);
            assert!(!via_plan.learned);
            // An empty learned model falls back to the same point.
            let learned = LearnedPolicy::new(KnnTuner::fit(Dataset::default(), 5));
            let fb = learned.choose_plan(&bulk, c.category(), &profile);
            assert!(!fb.learned);
            assert_eq!((fb.streams, fb.gran), (via_plan.streams, via_plan.gran));
        }
    }
}
