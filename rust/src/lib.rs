//! # hetstream — multi-stream heterogeneous offload runtime
//!
//! A full reproduction of *Streaming Applications on Heterogeneous
//! Platforms* (Li, Fang, Tang, Chen, Yang — 2016) as a three-layer
//! Rust + JAX + Pallas stack.
//!
//! The paper studies when and how to use **multiple streams** (hStreams /
//! CUDA-streams style pipelines) to overlap host↔device data transfers
//! with kernel execution on a CPU + coprocessor platform.  This crate
//! provides:
//!
//! - [`device`] — a simulated heterogeneous platform: a device-memory
//!   arena with the paper's lazy-allocation semantics, a DMA
//!   [`device::TransferEngine`] and a [`device::ComputeEngine`]
//!   executing the kernels (pure-Rust interpreter by default, PJRT
//!   under `--features pjrt`), all timed by the [`device::SimClock`]
//!   discrete-event virtual clock (`TimeMode::Virtual`, the default:
//!   deterministic, sleep-free instant replay; `TimeMode::Wallclock`
//!   paces ops to their modeled durations in real time).
//! - [`hstreams`] — the multi-stream programming model: [`hstreams::Context`],
//!   in-order [`hstreams::Stream`]s, cross-stream [`hstreams::Event`]s.
//! - [`partition`] — the paper's three streaming transformations:
//!   independent chunking (Fig. 6), redundant boundary/halo transfer
//!   (Fig. 7), and wavefront diagonal scheduling (Fig. 8).
//! - [`analysis`] — the stage-by-stage analyzer that measures the data
//!   transfer ratio *R* (11-run medians), the CDF builder behind Fig. 1,
//!   the streaming-necessity decision rule, and the Table-2 categorizer.
//! - [`plan`] — the unified `StreamPlan` IR: every workload lowers to
//!   a task DAG of typed H2D/KEX/D2H ops with byte/FLOP annotations,
//!   executed through the backend-agnostic [`plan::Backend`] API — the
//!   engine-backed [`plan::SimBackend`] maps any plan onto `n` modeled
//!   streams, the [`plan::NativeBackend`] runs the same DAG on a host
//!   thread pool, and both assemble bitwise-identical outputs.
//!   Lowerings take a [`plan::Granularity`] knob and re-derive at any
//!   task count with bitwise-identical outputs, which the joint
//!   (streams × granularity) tuner ([`analysis::autotune_plan`],
//!   `repro tune --corpus`) exploits.
//! - [`service`] — the async multi-tenant front-end: a
//!   [`service::StreamService`] accepts concurrent plan submissions,
//!   multiplexes them onto shared engine lanes with fair per-tenant
//!   admission, caches lowered plans, and picks (streams, granularity)
//!   per submission through a pluggable [`service::TunePolicy`].
//! - [`spec`] — the declarative workload front end: a JSON
//!   [`spec::WorkloadSpec`] (buffers, kernel stages, dependence
//!   category, halo/iteration/wavefront parameters) compiled to a
//!   `StreamPlan` by [`spec::SpecCompiler`] — the one lowering path
//!   shared by the corpus descriptors, the `repro run-spec` CLI and
//!   the service's `Request::Spec`.
//! - [`corpus`] — all 56 benchmarks × 223 input configurations of
//!   Table 1 as workload descriptors.
//! - [`workloads`] — the 13 streamed benchmark drivers of Fig. 9 plus
//!   the Reduction v1/v2 code variants of Fig. 3.
//! - [`runtime`] — the PJRT artifact loader (HLO-text interchange).
//!
//! Python (JAX + Pallas) runs only at build time (`make artifacts`); the
//! produced `artifacts/*.hlo.txt` are loaded and executed from Rust — no
//! Python on the measurement path.

// Unsafe hygiene (DESIGN.md §Verification): every unsafe operation
// must sit in its own explicit `unsafe` block with a `// SAFETY:`
// comment discharging its proof obligation.
#![deny(unsafe_op_in_unsafe_fn)]
#![warn(clippy::undocumented_unsafe_blocks)]

pub mod analysis;
pub mod config;
pub mod corpus;
pub mod device;
pub mod error;
pub mod experiments;
pub mod hstreams;
pub mod metrics;
pub mod partition;
pub mod plan;
pub mod runtime;
pub mod service;
pub mod spec;
pub mod util;
pub mod workloads;

pub use error::{Error, Result};

/// Default location of the AOT artifacts relative to the repo root.
pub const DEFAULT_ARTIFACTS_DIR: &str = "artifacts";

/// Resolve the artifacts directory: `$HETSTREAM_ARTIFACTS`, else walk up
/// from the current directory looking for `artifacts/manifest.json`.
pub fn artifacts_dir() -> std::path::PathBuf {
    if let Ok(dir) = std::env::var("HETSTREAM_ARTIFACTS") {
        return dir.into();
    }
    let mut cur = std::env::current_dir().unwrap_or_else(|_| ".".into());
    loop {
        let cand = cur.join(DEFAULT_ARTIFACTS_DIR);
        if cand.join("manifest.json").exists() {
            return cand;
        }
        if !cur.pop() {
            return DEFAULT_ARTIFACTS_DIR.into();
        }
    }
}
