//! Lowering the Table-1 corpus descriptors to [`StreamPlan`]s.
//!
//! Every (app, input) descriptor becomes a task DAG driven by the
//! calibrated synthetic `burner` kernel under the descriptor's
//! byte/FLOP profile, shaped by its Table-2 category and a
//! [`Granularity`] knob (task count / tile-grid side — see
//! [`lower_corpus_streamed_at`]):
//!
//! - **Independent** — `gran` disjoint windows, one `H2d → Kex → D2h`
//!   chain per task, round-robin lanes (Fig. 6).
//! - **False dependent** — the same, with every window extended by the
//!   descriptor's halo/chunk ratio on both sides: the redundant
//!   boundary bytes of Fig. 7 ride along with each task.
//! - **True dependent** — a `gran`² tile grid scheduled
//!   diagonal-by-diagonal ([`crate::partition::diagonals`]); each tile
//!   kernel carries explicit RAW deps on its north/west/northwest
//!   neighbours (Fig. 8).
//! - **Sync / Iterative** — a single task (one upload, `repeats`
//!   kernel launches on resident data, one download): nothing for a
//!   second stream to overlap, exactly the paper's non-streamable
//!   verdict.  Granularity is ignored.
//!
//! Since the [`crate::spec`] refactor the functions here are thin
//! `CorpusDescriptor → WorkloadSpec` conversions
//! ([`crate::spec::WorkloadSpec::from_corpus`]) over the one
//! spec-driven compiler ([`crate::spec::SpecCompiler`]), which owns
//! granularity clipping, 4-byte alignment and zero-source padding for
//! *every* lowering in the repo.  The construction — and therefore
//! every emitted op — is unchanged: the Python mirror cross-checks all
//! 224 (app, gran) corpus plans against its own independent lowering
//! per commit.
//!
//! **Granularity invariance.**  Re-lowering one descriptor at any
//! granularity assembles bitwise-identical host outputs (the joint
//! tuner's validation oracle).  The construction that guarantees it:
//! the *input* payload partitions at 4-byte-aligned boundaries (so
//! every task's burner f32 lanes line up with the bulk lowering's
//! lanes), each task's output window is the same byte range as its
//! input window clipped to the output size (downloaded at the
//! window-relative offset), and output bytes past the kernel's fixed
//! block — bytes the bulk lowering leaves zero — are downloaded from
//! a never-written device buffer instead of the kernel output.  See
//! DESIGN.md §Tuning.
//!
//! Scaling matches the stage-measurement path bit-for-bit: bytes and
//! FLOPs divide by the engine [`crate::device::DILATION`], iterations
//! clamp to 20 and per-iteration FLOPs to 3·10⁸ to keep full-corpus
//! sweeps tractable (the linear terms cancel in R — see
//! `experiments::fig1::offload_spec`).

use crate::analysis::Category;
use crate::corpus::BenchConfig;
use crate::partition::{diagonals, TileCoord};
use crate::spec::{SpecCompiler, WorkloadSpec};

use super::{Granularity, Slot, StreamPlan};

/// Walk a `g`×`g` wavefront grid in diagonal order and wire each tile's
/// RAW deps: `emit` is called once per tile with its coordinate, its
/// lane (`Slot::Task(slot within the anti-diagonal)` — "the number of
/// streams changes on different diagonals"), and the kex op ids of its
/// north / west / northwest producers, and must return the tile's own
/// kex op id.  Shared by every wavefront lowering (NW, the
/// true-dependent corpus shape and spec tiles mode) so dep wiring and
/// placement cannot diverge.  Returns the kex op ids in row-major tile
/// order.
pub fn wire_wavefront(
    g: usize,
    mut emit: impl FnMut(TileCoord, Slot, Vec<usize>) -> usize,
) -> Vec<usize> {
    let mut kex_ids: Vec<Option<usize>> = vec![None; g * g];
    for diag in diagonals(g, g) {
        for (slot, tc) in diag.tiles.iter().enumerate() {
            let mut deps = Vec::new();
            if tc.bi > 0 {
                deps.push(kex_ids[(tc.bi - 1) * g + tc.bj].expect("north lowered earlier"));
            }
            if tc.bj > 0 {
                deps.push(kex_ids[tc.bi * g + tc.bj - 1].expect("west lowered earlier"));
            }
            if tc.bi > 0 && tc.bj > 0 {
                deps.push(kex_ids[(tc.bi - 1) * g + tc.bj - 1].expect("nw lowered earlier"));
            }
            kex_ids[tc.bi * g + tc.bj] = Some(emit(*tc, Slot::Task(slot), deps));
        }
    }
    kex_ids.into_iter().map(|k| k.expect("every tile visited")).collect()
}

/// Burner variant the corpus plans launch (8 FMA sweeps: cheap on the
/// host interpreter; KEX pacing comes from the FLOP override anyway).
pub const CORPUS_BURNER: &str = "burner_8";

/// Historical fixed task count for independent / false-dependent
/// corpus lowerings — the default [`Granularity`] and the joint
/// tuner's fixed-granularity baseline.
pub const CORPUS_TASKS: usize = 8;

/// Historical fixed tile-grid side for true-dependent (wavefront)
/// corpus lowerings — the default [`Granularity`] for that category.
pub const WAVEFRONT_GRID: usize = 4;

/// The seed repo's fixed pre-tuner settings, per category: the
/// granularity [`lower_corpus_streamed`] uses and the baseline the
/// joint tuner reports improvements against.
pub fn default_corpus_granularity(cat: Category) -> Granularity {
    match cat {
        Category::Independent | Category::FalseDependent => Granularity::new(CORPUS_TASKS),
        Category::TrueDependent => Granularity::new(WAVEFRONT_GRID),
        Category::Sync | Category::Iterative => Granularity::new(1),
    }
}

/// The granularity ladder the hazard verifier (and the Python mirror's
/// `native_check`) sweeps per app: a serial lowering, the category
/// default, an odd off-default value, and an oversized one.  56
/// representative apps × these 4 = the 224-plan verification corpus;
/// duplicates after [`effective_corpus_granularity`] clamping are kept
/// so the two sides count identically.
pub fn mirror_check_granularities(cat: Category) -> [Granularity; 4] {
    [
        Granularity::new(1),
        default_corpus_granularity(cat),
        Granularity::new(7),
        Granularity::new(16),
    ]
}

/// The knob value [`lower_corpus_streamed_at`] will actually lower
/// `c` at: requested granularity clamped per category.  Delegates to
/// the *one* clamp on [`SpecCompiler::effective_granularity`] — the
/// clamp and the lowering share an implementation and cannot
/// disagree.  Tuners should map their candidate ladders through this
/// and dedupe, or aliased grid points get measured twice under
/// different labels.
pub fn effective_corpus_granularity(c: &BenchConfig, gran: Granularity) -> Granularity {
    let spec = WorkloadSpec::from_corpus(c, CORPUS_BURNER);
    SpecCompiler::new(&spec).effective_granularity(gran)
}

/// Bulk (non-streamed) lowering: one upload, `repeats` kernel
/// launches, one download — the offload the paper's §3.3 protocol
/// measures stage-by-stage, and the reference every streamed corpus
/// run (at every granularity) is validated against bitwise.
pub fn lower_corpus_bulk(c: &BenchConfig, artifact: &str) -> StreamPlan {
    let spec = WorkloadSpec::from_corpus(c, artifact);
    SpecCompiler::new(&spec).bulk()
}

/// Streamed lowering at the category's historical fixed granularity
/// ([`default_corpus_granularity`]) — the pre-tuner behavior.
pub fn lower_corpus_streamed(c: &BenchConfig, artifact: &str) -> StreamPlan {
    lower_corpus_streamed_at(c, artifact, default_corpus_granularity(c.category()))
}

/// Streamed lowering at an explicit granularity: the category-shaped
/// task DAG described in the module docs, re-derivable at any knob
/// value with bitwise-identical assembled outputs (the joint tuner's
/// oracle).  Executing the result on 1 stream is the serialized
/// pipeline; `repro sweep`/`repro tune` map the same plan onto more
/// streams and validate bit-for-bit.
pub fn lower_corpus_streamed_at(
    c: &BenchConfig,
    artifact: &str,
    gran: Granularity,
) -> StreamPlan {
    let spec = WorkloadSpec::from_corpus(c, artifact);
    SpecCompiler::new(&spec).streamed_at(gran)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::corpus::all_configs;
    use crate::plan::PlanOpKind;

    #[test]
    fn every_descriptor_lowers_to_a_valid_plan() {
        for c in all_configs() {
            let bulk = lower_corpus_bulk(&c, CORPUS_BURNER);
            bulk.validate().unwrap_or_else(|e| panic!("{}/{} bulk: {e}", c.app, c.config));
            let strm = lower_corpus_streamed(&c, CORPUS_BURNER);
            strm.validate().unwrap_or_else(|e| panic!("{}/{} streamed: {e}", c.app, c.config));
            assert!(strm.tasks() >= 1);
            assert!(strm.h2d_bytes() >= bulk.h2d_bytes(), "{}: halo can only add", c.app);
            assert_eq!(strm.d2h_bytes(), bulk.d2h_bytes(), "{}", c.app);
        }
    }

    #[test]
    fn every_granularity_keeps_the_descriptor_byte_profile() {
        // Re-lowering at any knob value moves *when* bytes travel, not
        // how many: D2H totals are exactly the descriptor's, H2D totals
        // are the descriptor's plus (for false dependent) halo bytes.
        for c in all_configs().into_iter().step_by(17) {
            let bulk = lower_corpus_bulk(&c, CORPUS_BURNER);
            for g in [1usize, 2, 3, 8, 16, 64] {
                let strm = lower_corpus_streamed_at(&c, CORPUS_BURNER, Granularity::new(g));
                strm.validate()
                    .unwrap_or_else(|e| panic!("{}/{} gran {g}: {e}", c.app, c.config));
                assert_eq!(strm.d2h_bytes(), bulk.d2h_bytes(), "{} gran {g}", c.app);
                assert!(strm.h2d_bytes() >= bulk.h2d_bytes(), "{} gran {g}", c.app);
                if c.category() == crate::analysis::Category::Independent {
                    assert_eq!(strm.h2d_bytes(), bulk.h2d_bytes(), "{} gran {g}", c.app);
                }
            }
        }
    }

    #[test]
    fn category_shapes_the_task_dag() {
        let find = |app: &str| {
            all_configs().into_iter().find(|c| c.app == app).expect("app in corpus")
        };
        // Iterative/sync collapse to one task.
        assert_eq!(lower_corpus_streamed(&find("hotspot"), CORPUS_BURNER).tasks(), 1);
        assert_eq!(lower_corpus_streamed(&find("backprop"), CORPUS_BURNER).tasks(), 1);
        // Independent fans out, and the knob re-shapes it.
        let nn = lower_corpus_streamed(&find("nn"), CORPUS_BURNER);
        assert_eq!(nn.tasks(), CORPUS_TASKS);
        let kex_dep_free = nn
            .ops
            .iter()
            .filter(|op| matches!(op.kind, PlanOpKind::Kex { .. }))
            .all(|op| op.deps.is_empty());
        assert!(kex_dep_free, "independent kernels have no cross-task RAW edges");
        let nn16 =
            lower_corpus_streamed_at(&find("nn"), CORPUS_BURNER, Granularity::new(16));
        assert_eq!(nn16.tasks(), 16);
        // False dependent ships more than the bulk payload.
        let lavamd = find("lavaMD");
        let strm = lower_corpus_streamed(&lavamd, CORPUS_BURNER);
        let bulk = lower_corpus_bulk(&lavamd, CORPUS_BURNER);
        assert!(strm.h2d_bytes() > bulk.h2d_bytes(), "halo redundancy must show up");
        // True dependent carries wavefront deps; the knob is the grid side.
        let wf = lower_corpus_streamed(&find("nw"), CORPUS_BURNER);
        assert_eq!(wf.tasks(), WAVEFRONT_GRID * WAVEFRONT_GRID);
        let wf2 = lower_corpus_streamed_at(&find("nw"), CORPUS_BURNER, Granularity::new(2));
        assert_eq!(wf2.tasks(), 4);
        let dep_edges: usize = wf
            .ops
            .iter()
            .filter(|op| matches!(op.kind, PlanOpKind::Kex { .. }))
            .map(|op| op.deps.len())
            .sum();
        assert!(dep_edges > 0, "wavefront must have RAW edges");
    }

    #[test]
    fn effective_granularity_matches_category_clamps() {
        let find = |app: &str| {
            all_configs().into_iter().find(|c| c.app == app).expect("app in corpus")
        };
        let eff = |c: &crate::corpus::BenchConfig, g: usize| {
            effective_corpus_granularity(c, Granularity::new(g)).get()
        };
        // Sync/iterative ignore the knob entirely.
        assert_eq!(eff(&find("backprop"), 16), 1);
        assert_eq!(eff(&find("hotspot"), 7), 1);
        // Wavefront grid sides clamp to [1, 8].
        assert_eq!(eff(&find("nw"), 16), 8);
        assert_eq!(eff(&find("nw"), 3), 3);
        // Partitioned shapes keep at least one input lane per task,
        // and the streamed lowering's task count agrees.
        let nn = find("nn");
        assert_eq!(eff(&nn, 16), 16);
        assert_eq!(
            lower_corpus_streamed_at(&nn, CORPUS_BURNER, Granularity::new(16)).tasks(),
            eff(&nn, 16)
        );
        // Tasks partition the *input*: a scalar-output reduction still
        // streams its uploads (Fig. 6) — the knob must not collapse on
        // tiny outputs.
        let red = find("Reduction");
        let strm = lower_corpus_streamed(&red, CORPUS_BURNER);
        assert_eq!(strm.tasks(), CORPUS_TASKS, "4-byte-output app keeps its task fan-out");
        let h2d_ops = strm
            .ops
            .iter()
            .filter(|op| matches!(op.kind, PlanOpKind::H2d { .. }))
            .count();
        assert_eq!(h2d_ops, CORPUS_TASKS, "every task ships an input share");
    }

    #[test]
    fn unified_clamp_agrees_with_the_historical_formula_for_all_224_rows() {
        // Satellite of the spec refactor: `effective_corpus_granularity`
        // now delegates to `SpecCompiler::effective_granularity`.  Over
        // the exact verification population (56 representative apps ×
        // the 4-point mirror ladder = 224 rows, plus a few off-ladder
        // knobs) the delegated clamp must agree with the historical
        // per-category formula, restated inline here.
        let dil = crate::device::DILATION;
        for c in crate::experiments::sweep::representative_configs(false) {
            let h2d = ((c.h2d_bytes as f64 / dil) as usize).max(4);
            let ladder = mirror_check_granularities(c.category());
            let extra = [Granularity::new(2), Granularity::new(3), Granularity::new(64)];
            for g in ladder.iter().chain(extra.iter()) {
                let historical = match c.category() {
                    Category::Sync | Category::Iterative => 1,
                    Category::Independent | Category::FalseDependent => {
                        g.get().min(h2d.max(4) / 4).max(1)
                    }
                    Category::TrueDependent => g.get().clamp(1, 8),
                };
                assert_eq!(
                    effective_corpus_granularity(&c, *g).get(),
                    historical,
                    "{}/{} gran {}",
                    c.app,
                    c.config,
                    g.get()
                );
            }
        }
    }

    #[test]
    fn bulk_matches_stage_measurement_scaling() {
        // The bulk plan's offload spec must reproduce the historical
        // fig1 spec numbers: dilation-scaled bytes, capped iterations.
        let c = all_configs().into_iter().find(|c| c.app == "leukocyte").unwrap();
        let spec = lower_corpus_bulk(&c, "burner_64").offload_spec();
        let dil = crate::device::DILATION;
        assert_eq!(spec.h2d, vec![((c.h2d_bytes as f64 / dil) as usize).max(4)]);
        assert_eq!(spec.d2h, vec![((c.d2h_bytes as f64 / dil) as usize).max(4)]);
        assert_eq!(spec.kex.len(), 1);
        assert_eq!(spec.kex[0].repeats, c.kex_iterations.clamp(1, 20));
    }
}
