//! Lowering the Table-1 corpus descriptors to [`StreamPlan`]s.
//!
//! Every (app, input) descriptor becomes a task DAG driven by the
//! calibrated synthetic `burner` kernel under the descriptor's
//! byte/FLOP profile, shaped by its Table-2 category and a
//! [`Granularity`] knob (task count / tile-grid side — see
//! [`lower_corpus_streamed_at`]):
//!
//! - **Independent** — `gran` disjoint windows, one `H2d → Kex → D2h`
//!   chain per task, round-robin lanes (Fig. 6).
//! - **False dependent** — the same, with every window extended by the
//!   descriptor's halo/chunk ratio on both sides: the redundant
//!   boundary bytes of Fig. 7 ride along with each task.
//! - **True dependent** — a `gran`² tile grid scheduled
//!   diagonal-by-diagonal ([`crate::partition::diagonals`]); each tile
//!   kernel carries explicit RAW deps on its north/west/northwest
//!   neighbours (Fig. 8).
//! - **Sync / Iterative** — a single task (one upload, `repeats`
//!   kernel launches on resident data, one download): nothing for a
//!   second stream to overlap, exactly the paper's non-streamable
//!   verdict.  Granularity is ignored.
//!
//! **Granularity invariance.**  Re-lowering one descriptor at any
//! granularity assembles bitwise-identical host outputs (the joint
//! tuner's validation oracle).  The construction that guarantees it:
//! the *input* payload partitions at 4-byte-aligned boundaries (so
//! every task's burner f32 lanes line up with the bulk lowering's
//! lanes), each task's output window is the same byte range as its
//! input window clipped to the output size (downloaded at the
//! window-relative offset), and output bytes past the kernel's fixed
//! block — bytes the bulk lowering leaves zero — are downloaded from
//! a never-written device buffer instead of the kernel output.  See
//! DESIGN.md §Tuning.
//!
//! Scaling matches the stage-measurement path bit-for-bit: bytes and
//! FLOPs divide by the engine [`crate::device::DILATION`], iterations
//! clamp to 20 and per-iteration FLOPs to 3·10⁸ to keep full-corpus
//! sweeps tractable (the linear terms cancel in R — see
//! `experiments::fig1::offload_spec`).

use std::sync::Arc;

use crate::analysis::{Category, TaskDep};
use crate::corpus::BenchConfig;
use crate::partition::{diagonals, TileCoord};

use super::{Granularity, HostSlice, PlanRegion, Slot, StreamPlan};

/// Walk a `g`×`g` wavefront grid in diagonal order and wire each tile's
/// RAW deps: `emit` is called once per tile with its coordinate, its
/// lane (`Slot::Task(slot within the anti-diagonal)` — "the number of
/// streams changes on different diagonals"), and the kex op ids of its
/// north / west / northwest producers, and must return the tile's own
/// kex op id.  Shared by every wavefront lowering (NW and the
/// true-dependent corpus shape) so dep wiring and placement cannot
/// diverge.  Returns the kex op ids in row-major tile order.
pub fn wire_wavefront(
    g: usize,
    mut emit: impl FnMut(TileCoord, Slot, Vec<usize>) -> usize,
) -> Vec<usize> {
    let mut kex_ids: Vec<Option<usize>> = vec![None; g * g];
    for diag in diagonals(g, g) {
        for (slot, tc) in diag.tiles.iter().enumerate() {
            let mut deps = Vec::new();
            if tc.bi > 0 {
                deps.push(kex_ids[(tc.bi - 1) * g + tc.bj].expect("north lowered earlier"));
            }
            if tc.bj > 0 {
                deps.push(kex_ids[tc.bi * g + tc.bj - 1].expect("west lowered earlier"));
            }
            if tc.bi > 0 && tc.bj > 0 {
                deps.push(kex_ids[(tc.bi - 1) * g + tc.bj - 1].expect("nw lowered earlier"));
            }
            kex_ids[tc.bi * g + tc.bj] = Some(emit(*tc, Slot::Task(slot), deps));
        }
    }
    kex_ids.into_iter().map(|k| k.expect("every tile visited")).collect()
}

/// Burner variant the corpus plans launch (8 FMA sweeps: cheap on the
/// host interpreter; KEX pacing comes from the FLOP override anyway).
pub const CORPUS_BURNER: &str = "burner_8";

/// Historical fixed task count for independent / false-dependent
/// corpus lowerings — the default [`Granularity`] and the joint
/// tuner's fixed-granularity baseline.
pub const CORPUS_TASKS: usize = 8;

/// Historical fixed tile-grid side for true-dependent (wavefront)
/// corpus lowerings — the default [`Granularity`] for that category.
pub const WAVEFRONT_GRID: usize = 4;

/// The burner artifacts' fixed block: 65536 f32 in, 65536 f32 out.
const KEX_BYTES: usize = 65536 * 4;

/// The seed repo's fixed pre-tuner settings, per category: the
/// granularity [`lower_corpus_streamed`] uses and the baseline the
/// joint tuner reports improvements against.
pub fn default_corpus_granularity(cat: Category) -> Granularity {
    match cat {
        Category::Independent | Category::FalseDependent => Granularity::new(CORPUS_TASKS),
        Category::TrueDependent => Granularity::new(WAVEFRONT_GRID),
        Category::Sync | Category::Iterative => Granularity::new(1),
    }
}

/// The granularity ladder the hazard verifier (and the Python mirror's
/// `native_check`) sweeps per app: a serial lowering, the category
/// default, an odd off-default value, and an oversized one.  56
/// representative apps × these 4 = the 224-plan verification corpus;
/// duplicates after [`effective_corpus_granularity`] clamping are kept
/// so the two sides count identically.
pub fn mirror_check_granularities(cat: Category) -> [Granularity; 4] {
    [
        Granularity::new(1),
        default_corpus_granularity(cat),
        Granularity::new(7),
        Granularity::new(16),
    ]
}

/// The knob value [`lower_corpus_streamed_at`] will actually lower
/// `c` at: requested granularity clamped per category (at least one
/// output lane per task for the partitioned shapes, tile-grid side in
/// [1, 8] for wavefronts, always 1 where the knob is ignored).  Tuners
/// should map their candidate ladders through this and dedupe, or
/// aliased grid points get measured twice under different labels.
pub fn effective_corpus_granularity(c: &BenchConfig, gran: Granularity) -> Granularity {
    let s = scaled(c);
    match c.category() {
        Category::Sync | Category::Iterative => Granularity::new(1),
        Category::Independent | Category::FalseDependent => {
            // At least one input lane per task (tasks partition the
            // payload — a 4-byte-output reduction still streams its
            // uploads, Fig. 6).
            Granularity::new(gran.get().min(s.h2d.max(4) / 4).max(1))
        }
        Category::TrueDependent => Granularity::new(gran.get().clamp(1, 8)),
    }
}

/// Descriptor profile after engine scaling (see module docs).
struct Scaled {
    h2d: usize,
    d2h: usize,
    flops_per_iter: u64,
    repeats: u32,
}

fn scaled(c: &BenchConfig) -> Scaled {
    let dil = crate::device::DILATION;
    Scaled {
        h2d: ((c.h2d_bytes as f64 / dil) as usize).max(4),
        d2h: ((c.d2h_bytes as f64 / dil) as usize).max(4),
        flops_per_iter: ((c.flops_per_iteration() as f64 / dil) as u64).min(300_000_000),
        repeats: c.kex_iterations.clamp(1, 20),
    }
}

/// Deterministic synthetic payload (seeded per app so different
/// descriptors ship different data; generator shared with the
/// property-testing RNG rather than re-implemented).
fn synth_payload(len: usize, seed: u64) -> Arc<Vec<u8>> {
    let mut rng = crate::util::prop::Rng::new(seed);
    let mut v = Vec::with_capacity(len + 8);
    while v.len() < len {
        v.extend_from_slice(&rng.next_u64().to_le_bytes());
    }
    v.truncate(len);
    Arc::new(v)
}

fn seed_of(c: &BenchConfig) -> u64 {
    c.app
        .bytes()
        .chain(c.config.bytes())
        .fold(0xCBF29CE484222325u64, |h, b| (h ^ b as u64).wrapping_mul(0x100000001B3))
}

/// Bulk (non-streamed) lowering: one upload, `repeats` kernel
/// launches, one download — the offload the paper's §3.3 protocol
/// measures stage-by-stage, and the reference every streamed corpus
/// run (at every granularity) is validated against bitwise.
pub fn lower_corpus_bulk(c: &BenchConfig, artifact: &str) -> StreamPlan {
    let s = scaled(c);
    let mut p = StreamPlan::new(format!("{}/{}", c.app, c.config));
    let out = p.output(s.d2h);
    let payload = synth_payload(s.h2d, seed_of(c));
    let in_buf = p.buf(s.h2d.max(KEX_BYTES));
    let out_buf = p.buf(s.d2h.max(KEX_BYTES));
    p.h2d(
        Slot::Task(0),
        HostSlice::whole(payload),
        PlanRegion { buf: in_buf, off: 0, len: s.h2d },
        vec![],
    );
    let kex = p.kex(
        Slot::Task(0),
        artifact,
        vec![PlanRegion::whole(in_buf, KEX_BYTES)],
        vec![PlanRegion::whole(out_buf, KEX_BYTES)],
        Some(s.flops_per_iter),
        s.repeats,
        vec![],
    );
    p.d2h(Slot::Task(0), PlanRegion { buf: out_buf, off: 0, len: s.d2h }, out, 0, vec![kex]);
    p
}

/// Streamed lowering at the category's historical fixed granularity
/// ([`default_corpus_granularity`]) — the pre-tuner behavior.
pub fn lower_corpus_streamed(c: &BenchConfig, artifact: &str) -> StreamPlan {
    lower_corpus_streamed_at(c, artifact, default_corpus_granularity(c.category()))
}

/// Streamed lowering at an explicit granularity: the category-shaped
/// task DAG described in the module docs, re-derivable at any knob
/// value with bitwise-identical assembled outputs (the joint tuner's
/// oracle).  Executing the result on 1 stream is the serialized
/// pipeline; `repro sweep`/`repro tune` map the same plan onto more
/// streams and validate bit-for-bit.
pub fn lower_corpus_streamed_at(
    c: &BenchConfig,
    artifact: &str,
    gran: Granularity,
) -> StreamPlan {
    let s = scaled(c);
    let eff = effective_corpus_granularity(c, gran).get();
    match c.category() {
        Category::Sync | Category::Iterative => lower_corpus_bulk(c, artifact),
        Category::Independent | Category::FalseDependent => {
            // Halo ratio per window side (false dependent only): the
            // redundant boundary bytes of Fig. 7, from the descriptor's
            // recorded halo/chunk element ratio.
            let inflate = match c.facts.task_dep {
                TaskDep::Rar { halo, chunk } => 2.0 * halo as f64 / chunk.max(1) as f64,
                _ => 0.0,
            };
            lower_tasks(c, artifact, &s, eff, inflate, None)
        }
        Category::TrueDependent => lower_tasks(c, artifact, &s, eff * eff, 0.0, Some(eff)),
    }
}

/// Round up to the next f32-lane boundary.
fn lane_up(n: usize) -> usize {
    (n + 3) & !3
}

/// The shared task construction (module docs, "Granularity
/// invariance"): partition the payload at aligned boundaries, derive
/// each task's output window from its input window clipped to the
/// output size, and split any download reaching past the kernel block
/// between the kernel output and a never-written zero buffer.
/// `wavefront = Some(g)`
/// wires `g`² tiles diagonal-by-diagonal with RAW deps; `None` emits
/// independent round-robin chains in task order.
fn lower_tasks(
    c: &BenchConfig,
    artifact: &str,
    s: &Scaled,
    m: usize,
    inflate: f64,
    wavefront: Option<usize>,
) -> StreamPlan {
    let (h, d) = (s.h2d, s.d2h);
    let payload = synth_payload(h, seed_of(c));
    let mut p = StreamPlan::new(format!("{}/{}", c.app, c.config));
    let out = p.output(d);

    // Input boundaries: 4-byte-aligned partition of the payload — the
    // Fig. 6 overlap structure (every task ships a share of the input
    // whatever the output size).  Alignment keeps every task's burner
    // f32 lanes in phase with the bulk lowering's lanes.
    let ix: Vec<usize> = (0..=m).map(|t| if t == m { h } else { (t * h / m) & !3 }).collect();
    // Output boundaries follow the input partition, clipped to the
    // output size; the tail of a larger output (d > h) rides with the
    // last task.  A task's output window is always inside its own
    // input window's byte positions, so its kernel computed exactly
    // those lanes.
    let ob: Vec<usize> = (0..=m).map(|t| if t == m { d } else { ix[t].min(d) }).collect();

    // Zero source for output bytes past the kernel block (bytes the
    // bulk lowering leaves untouched): one never-written buffer.
    let zmax = (0..m)
        .map(|t| ob[t + 1].saturating_sub(ob[t].max(KEX_BYTES)))
        .max()
        .unwrap_or(0);
    let zeros = if zmax > 0 { Some(p.buf(zmax)) } else { None };

    let flops = s.flops_per_iter / m as u64;
    let emit_task = |p: &mut StreamPlan, t: usize, slot: Slot, deps: Vec<usize>| -> usize {
        let (olo, ohi) = (ob[t], ob[t + 1]);
        let (ilo, ihi) = (ix[t], ix[t + 1]);
        // Symmetric halo extension, lane-aligned, clipped to the
        // payload (so the window still slices the bulk payload).
        let halo = if inflate > 0.0 && ihi > ilo {
            lane_up((((ihi - ilo) as f64 * inflate / 2.0) as usize).max(1))
        } else {
            0
        };
        let xlo = ilo - halo.min(ilo);
        let xhi = (ihi + halo).min(h);
        let xfer = xhi - xlo;

        let in_buf = p.buf(xfer.max(KEX_BYTES));
        let out_buf = p.buf(KEX_BYTES);
        if xfer > 0 {
            p.h2d(
                slot,
                HostSlice { data: payload.clone(), off: xlo, len: xfer },
                PlanRegion { buf: in_buf, off: 0, len: xfer },
                vec![],
            );
        }
        let kex = p.kex(
            slot,
            artifact,
            vec![PlanRegion::whole(in_buf, KEX_BYTES)],
            vec![PlanRegion::whole(out_buf, KEX_BYTES)],
            Some(flops),
            s.repeats,
            deps,
        );
        // Computed part: output positions below the kernel block, read
        // at the window-relative offset.  A non-empty output window
        // implies a non-empty input window starting at `olo` (so there
        // `delta` is just the halo shift, and `olo ≥ xlo` holds —
        // outside this branch `olo - xlo` could underflow: an
        // empty-output task has olo clamped to `d` below its `xlo`).
        let chi = ohi.min(KEX_BYTES);
        if chi > olo {
            let delta = olo - xlo;
            p.d2h(
                slot,
                PlanRegion { buf: out_buf, off: delta, len: chi - olo },
                out,
                olo,
                vec![kex],
            );
        }
        // Zero part: positions the bulk lowering leaves untouched.
        let zlo = olo.max(KEX_BYTES);
        if ohi > zlo {
            p.d2h(
                slot,
                PlanRegion { buf: zeros.expect("zero buffer declared"), off: 0, len: ohi - zlo },
                out,
                zlo,
                vec![],
            );
        }
        kex
    };

    match wavefront {
        Some(g) => {
            wire_wavefront(g, |tc, lane, deps| {
                emit_task(&mut p, tc.bi * g + tc.bj, lane, deps)
            });
        }
        None => {
            for t in 0..m {
                emit_task(&mut p, t, Slot::Task(t), vec![]);
            }
        }
    }
    p
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::corpus::all_configs;
    use crate::plan::PlanOpKind;

    #[test]
    fn every_descriptor_lowers_to_a_valid_plan() {
        for c in all_configs() {
            let bulk = lower_corpus_bulk(&c, CORPUS_BURNER);
            bulk.validate().unwrap_or_else(|e| panic!("{}/{} bulk: {e}", c.app, c.config));
            let strm = lower_corpus_streamed(&c, CORPUS_BURNER);
            strm.validate().unwrap_or_else(|e| panic!("{}/{} streamed: {e}", c.app, c.config));
            assert!(strm.tasks() >= 1);
            assert!(strm.h2d_bytes() >= bulk.h2d_bytes(), "{}: halo can only add", c.app);
            assert_eq!(strm.d2h_bytes(), bulk.d2h_bytes(), "{}", c.app);
        }
    }

    #[test]
    fn every_granularity_keeps_the_descriptor_byte_profile() {
        // Re-lowering at any knob value moves *when* bytes travel, not
        // how many: D2H totals are exactly the descriptor's, H2D totals
        // are the descriptor's plus (for false dependent) halo bytes.
        for c in all_configs().into_iter().step_by(17) {
            let bulk = lower_corpus_bulk(&c, CORPUS_BURNER);
            for g in [1usize, 2, 3, 8, 16, 64] {
                let strm = lower_corpus_streamed_at(&c, CORPUS_BURNER, Granularity::new(g));
                strm.validate()
                    .unwrap_or_else(|e| panic!("{}/{} gran {g}: {e}", c.app, c.config));
                assert_eq!(strm.d2h_bytes(), bulk.d2h_bytes(), "{} gran {g}", c.app);
                assert!(strm.h2d_bytes() >= bulk.h2d_bytes(), "{} gran {g}", c.app);
                if c.category() == crate::analysis::Category::Independent {
                    assert_eq!(strm.h2d_bytes(), bulk.h2d_bytes(), "{} gran {g}", c.app);
                }
            }
        }
    }

    #[test]
    fn category_shapes_the_task_dag() {
        let find = |app: &str| {
            all_configs().into_iter().find(|c| c.app == app).expect("app in corpus")
        };
        // Iterative/sync collapse to one task.
        assert_eq!(lower_corpus_streamed(&find("hotspot"), CORPUS_BURNER).tasks(), 1);
        assert_eq!(lower_corpus_streamed(&find("backprop"), CORPUS_BURNER).tasks(), 1);
        // Independent fans out, and the knob re-shapes it.
        let nn = lower_corpus_streamed(&find("nn"), CORPUS_BURNER);
        assert_eq!(nn.tasks(), CORPUS_TASKS);
        let kex_dep_free = nn
            .ops
            .iter()
            .filter(|op| matches!(op.kind, PlanOpKind::Kex { .. }))
            .all(|op| op.deps.is_empty());
        assert!(kex_dep_free, "independent kernels have no cross-task RAW edges");
        let nn16 =
            lower_corpus_streamed_at(&find("nn"), CORPUS_BURNER, Granularity::new(16));
        assert_eq!(nn16.tasks(), 16);
        // False dependent ships more than the bulk payload.
        let lavamd = find("lavaMD");
        let strm = lower_corpus_streamed(&lavamd, CORPUS_BURNER);
        let bulk = lower_corpus_bulk(&lavamd, CORPUS_BURNER);
        assert!(strm.h2d_bytes() > bulk.h2d_bytes(), "halo redundancy must show up");
        // True dependent carries wavefront deps; the knob is the grid side.
        let wf = lower_corpus_streamed(&find("nw"), CORPUS_BURNER);
        assert_eq!(wf.tasks(), WAVEFRONT_GRID * WAVEFRONT_GRID);
        let wf2 = lower_corpus_streamed_at(&find("nw"), CORPUS_BURNER, Granularity::new(2));
        assert_eq!(wf2.tasks(), 4);
        let dep_edges: usize = wf
            .ops
            .iter()
            .filter(|op| matches!(op.kind, PlanOpKind::Kex { .. }))
            .map(|op| op.deps.len())
            .sum();
        assert!(dep_edges > 0, "wavefront must have RAW edges");
    }

    #[test]
    fn effective_granularity_matches_category_clamps() {
        let find = |app: &str| {
            all_configs().into_iter().find(|c| c.app == app).expect("app in corpus")
        };
        let eff = |c: &crate::corpus::BenchConfig, g: usize| {
            effective_corpus_granularity(c, Granularity::new(g)).get()
        };
        // Sync/iterative ignore the knob entirely.
        assert_eq!(eff(&find("backprop"), 16), 1);
        assert_eq!(eff(&find("hotspot"), 7), 1);
        // Wavefront grid sides clamp to [1, 8].
        assert_eq!(eff(&find("nw"), 16), 8);
        assert_eq!(eff(&find("nw"), 3), 3);
        // Partitioned shapes keep at least one input lane per task,
        // and the streamed lowering's task count agrees.
        let nn = find("nn");
        assert_eq!(eff(&nn, 16), 16);
        assert_eq!(
            lower_corpus_streamed_at(&nn, CORPUS_BURNER, Granularity::new(16)).tasks(),
            eff(&nn, 16)
        );
        // Tasks partition the *input*: a scalar-output reduction still
        // streams its uploads (Fig. 6) — the knob must not collapse on
        // tiny outputs.
        let red = find("Reduction");
        let strm = lower_corpus_streamed(&red, CORPUS_BURNER);
        assert_eq!(strm.tasks(), CORPUS_TASKS, "4-byte-output app keeps its task fan-out");
        let h2d_ops = strm
            .ops
            .iter()
            .filter(|op| matches!(op.kind, PlanOpKind::H2d { .. }))
            .count();
        assert_eq!(h2d_ops, CORPUS_TASKS, "every task ships an input share");
    }

    #[test]
    fn bulk_matches_stage_measurement_scaling() {
        // The bulk plan's offload spec must reproduce the historical
        // fig1 spec numbers: dilation-scaled bytes, capped iterations.
        let c = all_configs().into_iter().find(|c| c.app == "leukocyte").unwrap();
        let spec = lower_corpus_bulk(&c, "burner_64").offload_spec();
        let dil = crate::device::DILATION;
        assert_eq!(spec.h2d, vec![((c.h2d_bytes as f64 / dil) as usize).max(4)]);
        assert_eq!(spec.d2h, vec![((c.d2h_bytes as f64 / dil) as usize).max(4)]);
        assert_eq!(spec.kex.len(), 1);
        assert_eq!(spec.kex[0].repeats, c.kex_iterations.clamp(1, 20));
    }
}
