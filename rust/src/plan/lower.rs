//! Lowering the Table-1 corpus descriptors to [`StreamPlan`]s.
//!
//! Every (app, input) descriptor becomes a task DAG driven by the
//! calibrated synthetic `burner` kernel under the descriptor's
//! byte/FLOP profile, shaped by its Table-2 category:
//!
//! - **Independent** — `CORPUS_TASKS` disjoint windows
//!   ([`crate::partition::chunk_ranges`]), one `H2d → Kex → D2h` chain
//!   per task, round-robin lanes (Fig. 6).
//! - **False dependent** — the same, with every window inflated by the
//!   descriptor's halo/chunk ratio: the redundant boundary bytes of
//!   Fig. 7 ride along with each task.
//! - **True dependent** — a `WAVEFRONT_GRID`² tile grid scheduled
//!   diagonal-by-diagonal ([`crate::partition::diagonals`]); each tile
//!   kernel carries explicit RAW deps on its north/west/northwest
//!   neighbours (Fig. 8).
//! - **Sync / Iterative** — a single task (one upload, `repeats`
//!   kernel launches on resident data, one download): nothing for a
//!   second stream to overlap, exactly the paper's non-streamable
//!   verdict.
//!
//! Scaling matches the stage-measurement path bit-for-bit: bytes and
//! FLOPs divide by the engine [`crate::device::DILATION`], iterations
//! clamp to 20 and per-iteration FLOPs to 3·10⁸ to keep full-corpus
//! sweeps tractable (the linear terms cancel in R — see
//! `experiments::fig1::offload_spec`).

use std::sync::Arc;

use crate::analysis::{Category, TaskDep};
use crate::corpus::BenchConfig;
use crate::partition::{chunk_ranges, diagonals, TileCoord};

use super::{HostSlice, PlanRegion, Slot, StreamPlan};

/// Walk a `g`×`g` wavefront grid in diagonal order and wire each tile's
/// RAW deps: `emit` is called once per tile with its coordinate, its
/// lane (`Slot::Task(slot within the anti-diagonal)` — "the number of
/// streams changes on different diagonals"), and the kex op ids of its
/// north / west / northwest producers, and must return the tile's own
/// kex op id.  Shared by every wavefront lowering (NW and the
/// true-dependent corpus shape) so dep wiring and placement cannot
/// diverge.  Returns the kex op ids in row-major tile order.
pub fn wire_wavefront(
    g: usize,
    mut emit: impl FnMut(TileCoord, Slot, Vec<usize>) -> usize,
) -> Vec<usize> {
    let mut kex_ids: Vec<Option<usize>> = vec![None; g * g];
    for diag in diagonals(g, g) {
        for (slot, tc) in diag.tiles.iter().enumerate() {
            let mut deps = Vec::new();
            if tc.bi > 0 {
                deps.push(kex_ids[(tc.bi - 1) * g + tc.bj].expect("north lowered earlier"));
            }
            if tc.bj > 0 {
                deps.push(kex_ids[tc.bi * g + tc.bj - 1].expect("west lowered earlier"));
            }
            if tc.bi > 0 && tc.bj > 0 {
                deps.push(kex_ids[(tc.bi - 1) * g + tc.bj - 1].expect("nw lowered earlier"));
            }
            kex_ids[tc.bi * g + tc.bj] = Some(emit(*tc, Slot::Task(slot), deps));
        }
    }
    kex_ids.into_iter().map(|k| k.expect("every tile visited")).collect()
}

/// Burner variant the corpus plans launch (8 FMA sweeps: cheap on the
/// host interpreter; KEX pacing comes from the FLOP override anyway).
pub const CORPUS_BURNER: &str = "burner_8";

/// Task count for independent / false-dependent corpus lowerings.
pub const CORPUS_TASKS: usize = 8;

/// Tile-grid side for true-dependent (wavefront) corpus lowerings.
const WAVEFRONT_GRID: usize = 4;

/// The burner artifacts' fixed block: 65536 f32 in, 65536 f32 out.
const KEX_BYTES: usize = 65536 * 4;

/// Descriptor profile after engine scaling (see module docs).
struct Scaled {
    h2d: usize,
    d2h: usize,
    flops_per_iter: u64,
    repeats: u32,
}

fn scaled(c: &BenchConfig) -> Scaled {
    let dil = crate::device::DILATION;
    Scaled {
        h2d: ((c.h2d_bytes as f64 / dil) as usize).max(4),
        d2h: ((c.d2h_bytes as f64 / dil) as usize).max(4),
        flops_per_iter: ((c.flops_per_iteration() as f64 / dil) as u64).min(300_000_000),
        repeats: c.kex_iterations.clamp(1, 20),
    }
}

/// Deterministic synthetic payload (seeded per app so different
/// descriptors ship different data; generator shared with the
/// property-testing RNG rather than re-implemented).
fn synth_payload(len: usize, seed: u64) -> Arc<Vec<u8>> {
    let mut rng = crate::util::prop::Rng::new(seed);
    let mut v = Vec::with_capacity(len + 8);
    while v.len() < len {
        v.extend_from_slice(&rng.next_u64().to_le_bytes());
    }
    v.truncate(len);
    Arc::new(v)
}

fn seed_of(c: &BenchConfig) -> u64 {
    c.app
        .bytes()
        .chain(c.config.bytes())
        .fold(0xCBF29CE484222325u64, |h, b| (h ^ b as u64).wrapping_mul(0x100000001B3))
}

/// One task's chain: H2D its window, burn a fixed block of its input
/// buffer, D2H its output window.  Buffers are padded to the burner
/// block so the kernel signature always matches; windows shorter than
/// the block read deterministic zero padding.
#[allow(clippy::too_many_arguments)]
fn task_chain(
    p: &mut StreamPlan,
    slot: Slot,
    payload: &Arc<Vec<u8>>,
    src_off: usize,
    xfer_len: usize,
    out_len: usize,
    out_idx: usize,
    out_off: usize,
    artifact: &str,
    flops: u64,
    repeats: u32,
    deps: Vec<usize>,
) -> usize {
    let in_buf = p.buf(xfer_len.max(KEX_BYTES));
    let out_buf = p.buf(out_len.max(KEX_BYTES));
    if xfer_len > 0 {
        p.h2d(
            slot,
            HostSlice { data: payload.clone(), off: src_off, len: xfer_len },
            PlanRegion { buf: in_buf, off: 0, len: xfer_len },
            vec![],
        );
    }
    let kex = p.kex(
        slot,
        artifact,
        vec![PlanRegion::whole(in_buf, KEX_BYTES)],
        vec![PlanRegion::whole(out_buf, KEX_BYTES)],
        Some(flops),
        repeats,
        deps,
    );
    if out_len > 0 {
        p.d2h(slot, PlanRegion { buf: out_buf, off: 0, len: out_len }, out_idx, out_off, vec![]);
    }
    kex
}

/// Bulk (non-streamed) lowering: one upload, `repeats` kernel
/// launches, one download — the offload the paper's §3.3 protocol
/// measures stage-by-stage, and the baseline every streamed corpus run
/// is compared against analytically.
pub fn lower_corpus_bulk(c: &BenchConfig, artifact: &str) -> StreamPlan {
    let s = scaled(c);
    let mut p = StreamPlan::new(format!("{}/{}", c.app, c.config));
    let out = p.output(s.d2h);
    let payload = synth_payload(s.h2d, seed_of(c));
    task_chain(
        &mut p,
        Slot::Task(0),
        &payload,
        0,
        s.h2d,
        s.d2h,
        out,
        0,
        artifact,
        s.flops_per_iter,
        s.repeats,
        vec![],
    );
    p
}

/// Streamed lowering: the category-shaped task DAG described in the
/// module docs.  Executing the result on 1 stream is the serialized
/// pipeline; the `repro sweep --corpus` ladder maps the same plan onto
/// more streams and validates outputs bit-for-bit against it.
pub fn lower_corpus_streamed(c: &BenchConfig, artifact: &str) -> StreamPlan {
    let s = scaled(c);
    let cat = c.category();
    match cat {
        Category::Sync | Category::Iterative => lower_corpus_bulk(c, artifact),
        Category::Independent | Category::FalseDependent => {
            // Halo inflation per window (false dependent only): the
            // redundant boundary bytes of Fig. 7, from the descriptor's
            // recorded halo/chunk element ratio.
            let inflate = match c.facts.task_dep {
                TaskDep::Rar { halo, chunk } => 2.0 * halo as f64 / chunk.max(1) as f64,
                _ => 0.0,
            };
            let k = CORPUS_TASKS.min(s.h2d / 4).max(1);
            let owned = chunk_ranges(s.h2d, k);
            let outs = chunk_ranges(s.d2h, k);
            let xfer: Vec<usize> =
                owned.iter().map(|r| r.len + (r.len as f64 * inflate) as usize).collect();
            let payload = synth_payload(xfer.iter().sum(), seed_of(c));
            let mut p = StreamPlan::new(format!("{}/{}", c.app, c.config));
            let out = p.output(s.d2h);
            let mut src_off = 0;
            for t in 0..k {
                task_chain(
                    &mut p,
                    Slot::Task(t),
                    &payload,
                    src_off,
                    xfer[t],
                    outs[t].len,
                    out,
                    outs[t].start,
                    artifact,
                    s.flops_per_iter / k as u64,
                    s.repeats,
                    vec![],
                );
                src_off += xfer[t];
            }
            p
        }
        Category::TrueDependent => {
            let g = WAVEFRONT_GRID;
            let tiles = g * g;
            let windows = chunk_ranges(s.h2d, tiles);
            let outs = chunk_ranges(s.d2h, tiles);
            let payload = synth_payload(s.h2d, seed_of(c));
            let mut p = StreamPlan::new(format!("{}/{}", c.app, c.config));
            let out = p.output(s.d2h);
            wire_wavefront(g, |tc, lane, deps| {
                let t = tc.bi * g + tc.bj;
                task_chain(
                    &mut p,
                    lane,
                    &payload,
                    windows[t].start,
                    windows[t].len,
                    outs[t].len,
                    out,
                    outs[t].start,
                    artifact,
                    s.flops_per_iter / tiles as u64,
                    s.repeats,
                    deps,
                )
            });
            p
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::corpus::all_configs;
    use crate::plan::PlanOpKind;

    #[test]
    fn every_descriptor_lowers_to_a_valid_plan() {
        for c in all_configs() {
            let bulk = lower_corpus_bulk(&c, CORPUS_BURNER);
            bulk.validate().unwrap_or_else(|e| panic!("{}/{} bulk: {e}", c.app, c.config));
            let strm = lower_corpus_streamed(&c, CORPUS_BURNER);
            strm.validate().unwrap_or_else(|e| panic!("{}/{} streamed: {e}", c.app, c.config));
            assert!(strm.tasks() >= 1);
            assert!(strm.h2d_bytes() >= bulk.h2d_bytes(), "{}: halo can only add", c.app);
            assert_eq!(strm.d2h_bytes(), bulk.d2h_bytes(), "{}", c.app);
        }
    }

    #[test]
    fn category_shapes_the_task_dag() {
        let find = |app: &str| {
            all_configs().into_iter().find(|c| c.app == app).expect("app in corpus")
        };
        // Iterative/sync collapse to one task.
        assert_eq!(lower_corpus_streamed(&find("hotspot"), CORPUS_BURNER).tasks(), 1);
        assert_eq!(lower_corpus_streamed(&find("backprop"), CORPUS_BURNER).tasks(), 1);
        // Independent fans out.
        let nn = lower_corpus_streamed(&find("nn"), CORPUS_BURNER);
        assert_eq!(nn.tasks(), CORPUS_TASKS);
        assert!(nn.ops.iter().all(|op| op.deps.is_empty()), "independent has no RAW edges");
        // False dependent ships more than the bulk payload.
        let lavamd = find("lavaMD");
        let strm = lower_corpus_streamed(&lavamd, CORPUS_BURNER);
        let bulk = lower_corpus_bulk(&lavamd, CORPUS_BURNER);
        assert!(strm.h2d_bytes() > bulk.h2d_bytes(), "halo redundancy must show up");
        // True dependent carries wavefront deps.
        let wf = lower_corpus_streamed(&find("nw"), CORPUS_BURNER);
        assert_eq!(wf.tasks(), WAVEFRONT_GRID * WAVEFRONT_GRID);
        let dep_edges: usize = wf
            .ops
            .iter()
            .filter(|op| matches!(op.kind, PlanOpKind::Kex { .. }))
            .map(|op| op.deps.len())
            .sum();
        assert!(dep_edges > 0, "wavefront must have RAW edges");
    }

    #[test]
    fn bulk_matches_stage_measurement_scaling() {
        // The bulk plan's offload spec must reproduce the historical
        // fig1 spec numbers: dilation-scaled bytes, capped iterations.
        let c = all_configs().into_iter().find(|c| c.app == "leukocyte").unwrap();
        let spec = lower_corpus_bulk(&c, "burner_64").offload_spec();
        let dil = crate::device::DILATION;
        assert_eq!(spec.h2d, vec![((c.h2d_bytes as f64 / dil) as usize).max(4)]);
        assert_eq!(spec.d2h, vec![((c.d2h_bytes as f64 / dil) as usize).max(4)]);
        assert_eq!(spec.kex.len(), 1);
        assert_eq!(spec.kex[0].repeats, c.kex_iterations.clamp(1, 20));
    }
}
