//! `StreamPlan` — the unified streaming IR (the paper's "generic flow",
//! §4, as a data structure).
//!
//! Every workload in this repo — the [`crate::workloads::GenericWorkload`]
//! family, the Needleman–Wunsch wavefront, and all 223 descriptor-backed
//! corpus configurations — *lowers* (via the transformations in
//! [`crate::partition`]) into one representation: a DAG of typed ops
//!
//! ```text
//! op   := H2d(host slice -> device region)
//!       | Kex(artifact, inputs, outputs, flops, repeats)
//!       | D2h(device region -> host output @ offset)
//! slot := Broadcast          -- shared prologue (kernels, boundaries)
//!       | Task(lane)         -- one pipeline task; lane is abstract
//! dep  := op index           -- explicit cross-task RAW edge
//! ```
//!
//! with byte/FLOP annotations on every op.  Plans execute through the
//! backend-agnostic API ([`Backend`], DESIGN.md §Backend): the
//! [`SimBackend`] maps any plan onto `n` modeled hstreams —
//! `Task(lane)` ops run on stream `lane % n` (round-robin for
//! independent/halo lowerings, diagonal slot for wavefronts),
//! `Broadcast` ops ride stream 0 with every other stream's first op
//! waiting on them, and explicit `deps` become cross-stream events —
//! while the [`NativeBackend`] runs the same DAG on a host thread pool
//! at wall-clock time.  A backend owns buffer lifetimes, host-output
//! assembly, and byte accounting; ops are submitted in plan order, so
//! a plan must list its ops in a topological order of the DAG (all
//! lowerings here do — the FIFO engine queues require it).  Both
//! backends assemble bitwise-identical outputs for any valid plan.
//!
//! Because the IR carries the task-DAG shape and per-stage byte/FLOP
//! totals, everything downstream reasons about workloads uniformly:
//! [`StreamPlan::stage_times`] feeds the §3.4 decision rule and the §6
//! stream-count predictor, [`StreamPlan::offload_spec`] bridges to the
//! stage-by-stage measurement protocol, and `repro sweep --corpus`
//! replays the whole Table-1 corpus through the one executor under the
//! virtual clock.

mod backend;
mod exec;
mod lower;
pub mod verify;

// The engine-mapping scheduler (`exec::Executor`) is an implementation
// detail of `SimBackend` now: every caller — in-crate drivers, tuners,
// experiments, external tests — goes through the `Backend` trait.
pub use backend::{native_deps, Backend, NativeBackend, RunConfig, RunHandle, SimBackend};
pub use exec::{outputs_match, PlanRun};
pub use lower::{
    default_corpus_granularity, effective_corpus_granularity, lower_corpus_bulk,
    lower_corpus_streamed, lower_corpus_streamed_at, mirror_check_granularities, wire_wavefront,
    CORPUS_BURNER, CORPUS_TASKS, WAVEFRONT_GRID,
};
pub use verify::{ensure_sound, verify_plan, Hazard, HazardKind, VerifyReport};

use std::sync::Arc;

use crate::analysis::StageTimes;
use crate::device::DeviceProfile;
use crate::{Error, Result};

/// Task-granularity knob of a lowering (paper §6: "proper task and/or
/// resource granularity").  One integer whose meaning is fixed per
/// workload category (DESIGN.md §Tuning):
///
/// - **Independent / false dependent** — the number of pipeline tasks
///   the transfer space is partitioned into.
/// - **True dependent (wavefront)** — the tile-grid side (`g` ⇒ `g²`
///   tasks scheduled diagonal-by-diagonal).
/// - **Sync / iterative** — upload-chunking only (the kernel chain is
///   a single RAW chain whatever the knob); corpus lowerings ignore it.
///
/// Every lowering that takes a `Granularity` must produce bitwise the
/// same assembled host outputs at every value — granularity moves
/// *when* bytes travel, never *which* bytes the result holds — so the
/// joint (streams × granularity) tuner can validate each grid point
/// against one bulk reference.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct Granularity(usize);

impl Granularity {
    /// Clamped to ≥ 1 (a zero-task plan is meaningless).
    pub const fn new(n: usize) -> Self {
        Self(if n == 0 { 1 } else { n })
    }

    pub const fn get(self) -> usize {
        self.0
    }
}

/// A borrowed window of immutable host bytes (H2D source).
#[derive(Debug, Clone)]
pub struct HostSlice {
    pub data: Arc<Vec<u8>>,
    pub off: usize,
    pub len: usize,
}

impl HostSlice {
    /// The whole payload.
    pub fn whole(data: Arc<Vec<u8>>) -> Self {
        let len = data.len();
        Self { data, off: 0, len }
    }
}

/// A byte range inside one of the plan's logical device buffers.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct PlanRegion {
    /// Index into [`StreamPlan::bufs`].
    pub buf: usize,
    pub off: usize,
    pub len: usize,
}

impl PlanRegion {
    pub fn whole(buf: usize, len: usize) -> Self {
        Self { buf, off: 0, len }
    }
}

/// Where an op runs when the plan is mapped onto `n` streams.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Slot {
    /// Shared prologue: stream 0; every other stream's first op waits
    /// on it (broadcast fan-out).  Must precede all `Task` ops.
    Broadcast,
    /// One pipeline task; the executor maps `lane % n`.  Independent
    /// and halo lowerings use the task index as lane; wavefronts use
    /// the slot within the diagonal ("the number of streams changes on
    /// different diagonals").
    Task(usize),
}

/// The typed payload of one plan op.
#[derive(Debug, Clone)]
pub enum PlanOpKind {
    /// Host→device copy of `src` into `dst` (lengths must match).
    H2d { src: HostSlice, dst: PlanRegion },
    /// Kernel launch.  `flops` overrides the artifact's manifest
    /// estimate for KEX pacing; `repeats` models iterative kernels.
    Kex {
        artifact: String,
        inputs: Vec<PlanRegion>,
        outputs: Vec<PlanRegion>,
        flops: Option<u64>,
        repeats: u32,
    },
    /// Device→host copy of `src` into host output `output` at `off`.
    D2h { src: PlanRegion, output: usize, off: usize },
}

/// One node of the task DAG.
#[derive(Debug, Clone)]
pub struct PlanOp {
    pub kind: PlanOpKind,
    pub slot: Slot,
    /// Indices of earlier ops this op must wait for (explicit RAW
    /// edges; same-stream program order is implicit).
    pub deps: Vec<usize>,
}

/// A lowered workload: logical device buffers, host outputs, and the
/// op DAG in topological submission order.
#[derive(Debug, Clone, Default)]
pub struct StreamPlan {
    pub name: String,
    /// Byte size of each logical device buffer.
    pub bufs: Vec<usize>,
    /// Byte size of each host output the D2H ops assemble.
    pub outputs: Vec<usize>,
    pub ops: Vec<PlanOp>,
}

impl StreamPlan {
    pub fn new(name: impl Into<String>) -> Self {
        Self { name: name.into(), ..Default::default() }
    }

    /// Declare a logical device buffer; returns its index.
    pub fn buf(&mut self, bytes: usize) -> usize {
        self.bufs.push(bytes);
        self.bufs.len() - 1
    }

    /// Declare a host output; returns its index.
    pub fn output(&mut self, bytes: usize) -> usize {
        self.outputs.push(bytes);
        self.outputs.len() - 1
    }

    fn push(&mut self, kind: PlanOpKind, slot: Slot, deps: Vec<usize>) -> usize {
        self.ops.push(PlanOp { kind, slot, deps });
        self.ops.len() - 1
    }

    /// Append an H2D op; returns its op index.
    pub fn h2d(&mut self, slot: Slot, src: HostSlice, dst: PlanRegion, deps: Vec<usize>) -> usize {
        self.push(PlanOpKind::H2d { src, dst }, slot, deps)
    }

    /// Append a KEX op; returns its op index.
    #[allow(clippy::too_many_arguments)]
    pub fn kex(
        &mut self,
        slot: Slot,
        artifact: impl Into<String>,
        inputs: Vec<PlanRegion>,
        outputs: Vec<PlanRegion>,
        flops: Option<u64>,
        repeats: u32,
        deps: Vec<usize>,
    ) -> usize {
        self.push(
            PlanOpKind::Kex { artifact: artifact.into(), inputs, outputs, flops, repeats },
            slot,
            deps,
        )
    }

    /// Append a D2H op; returns its op index.
    pub fn d2h(
        &mut self,
        slot: Slot,
        src: PlanRegion,
        output: usize,
        off: usize,
        deps: Vec<usize>,
    ) -> usize {
        self.push(PlanOpKind::D2h { src, output, off }, slot, deps)
    }

    // --- annotations ----------------------------------------------------

    /// Total host→device bytes the plan transfers (incl. broadcast and
    /// redundant halo bytes).
    pub fn h2d_bytes(&self) -> u64 {
        self.ops
            .iter()
            .map(|op| match &op.kind {
                PlanOpKind::H2d { dst, .. } => dst.len as u64,
                _ => 0,
            })
            .sum()
    }

    /// Total device→host bytes.
    pub fn d2h_bytes(&self) -> u64 {
        self.ops
            .iter()
            .map(|op| match &op.kind {
                PlanOpKind::D2h { src, .. } => src.len as u64,
                _ => 0,
            })
            .sum()
    }

    /// Total kernel FLOPs (overrides × repeats; ops without an override
    /// contribute zero — their cost comes from the artifact manifest).
    pub fn kex_flops(&self) -> u64 {
        self.ops
            .iter()
            .map(|op| match &op.kind {
                PlanOpKind::Kex { flops, repeats, .. } => {
                    flops.unwrap_or(0) * (*repeats).max(1) as u64
                }
                _ => 0,
            })
            .sum()
    }

    /// Number of pipeline tasks: KEX ops carried by `Task` slots.
    pub fn tasks(&self) -> usize {
        self.ops
            .iter()
            .filter(|op| {
                matches!(op.kind, PlanOpKind::Kex { .. }) && matches!(op.slot, Slot::Task(_))
            })
            .count()
    }

    /// Host→device bytes carried by `Broadcast` ops (shared prologue
    /// payloads every stream waits on — one of the learned-tuner
    /// features: a high broadcast fraction caps what streaming buys).
    pub fn broadcast_h2d_bytes(&self) -> u64 {
        self.ops
            .iter()
            .map(|op| match (&op.kind, op.slot) {
                (PlanOpKind::H2d { dst, .. }, Slot::Broadcast) => dst.len as u64,
                _ => 0,
            })
            .sum()
    }

    /// Critical-path length of the kernel DAG in kernels: the longest
    /// chain of KEX ops linked by explicit `deps` (transfers relay the
    /// chain).  1 for independent fan-outs, `2g−1` for a `g`×`g`
    /// wavefront, the task count for a serial chain.
    pub fn dag_depth(&self) -> usize {
        let mut depth = vec![0usize; self.ops.len()];
        let mut max = 0;
        for (i, op) in self.ops.iter().enumerate() {
            // Inherit the deepest producer through any op kind, but only
            // kernels add a level — depth counts *kernels on the chain*.
            let inherited = op.deps.iter().map(|&d| depth[d]).max().unwrap_or(0);
            depth[i] = inherited + usize::from(matches!(op.kind, PlanOpKind::Kex { .. }));
            max = max.max(depth[i]);
        }
        max
    }

    /// Peak parallelism of the kernel DAG: the most kernels sharing one
    /// depth level (tasks for fan-outs, the longest anti-diagonal for
    /// wavefronts, 1 for chains).
    pub fn dag_width(&self) -> usize {
        let mut depth = vec![0usize; self.ops.len()];
        let mut counts = std::collections::BTreeMap::new();
        for (i, op) in self.ops.iter().enumerate() {
            let inherited = op.deps.iter().map(|&d| depth[d]).max().unwrap_or(0);
            let is_kex = matches!(op.kind, PlanOpKind::Kex { .. });
            depth[i] = inherited + usize::from(is_kex);
            if is_kex {
                *counts.entry(depth[i]).or_insert(0usize) += 1;
            }
        }
        counts.values().copied().max().unwrap_or(1).max(1)
    }

    /// Unique artifact names the plan launches (context subset loading).
    pub fn artifacts(&self) -> Vec<String> {
        let mut names: Vec<String> = Vec::new();
        for op in &self.ops {
            if let PlanOpKind::Kex { artifact, .. } = &op.kind {
                if !names.iter().any(|n| n == artifact) {
                    names.push(artifact.clone());
                }
            }
        }
        names
    }

    // --- structural validation -----------------------------------------

    /// Check the IR invariants the executor relies on: deps point
    /// backwards (topological order), regions sit inside their declared
    /// buffers, H2D lengths match, D2H windows sit inside their
    /// outputs, broadcast ops precede all task ops, and every KEX op's
    /// input regions satisfy its artifact's manifest signature (exact
    /// bytes for fixed-shape artifacts, whole elements for
    /// [`crate::runtime::elastic_artifact`]s).  The signature check
    /// matters for the tuning paths: a mis-sized kernel call that only
    /// failed *inside* a worker thread would never complete its event
    /// and would hang the submitting run — validating up front turns
    /// that into a clean [`Error::Plan`] before anything is submitted.
    pub fn validate(&self) -> Result<()> {
        let err = |m: String| Err(Error::Plan(format!("{}: {m}", self.name)));
        let region_ok = |r: &PlanRegion| {
            r.buf < self.bufs.len() && r.off + r.len <= self.bufs[r.buf]
        };
        let mut seen_task = false;
        for (i, op) in self.ops.iter().enumerate() {
            for &d in &op.deps {
                if d >= i {
                    return err(format!("op {i} depends on later op {d}"));
                }
            }
            match op.slot {
                Slot::Task(_) => seen_task = true,
                Slot::Broadcast if seen_task => {
                    return err(format!("broadcast op {i} after a task op"));
                }
                Slot::Broadcast => {}
            }
            match &op.kind {
                PlanOpKind::H2d { src, dst } => {
                    if src.len != dst.len {
                        return err(format!("op {i}: h2d src {} != dst {}", src.len, dst.len));
                    }
                    if src.off + src.len > src.data.len() {
                        return err(format!("op {i}: h2d src window out of payload"));
                    }
                    if !region_ok(dst) {
                        return err(format!("op {i}: h2d region {dst:?} out of buffer"));
                    }
                }
                PlanOpKind::Kex { artifact, inputs, outputs, .. } => {
                    for r in inputs.iter().chain(outputs) {
                        if !region_ok(r) {
                            return err(format!("op {i}: kex region {r:?} out of buffer"));
                        }
                    }
                    if let Some(meta) = manifest_meta(artifact) {
                        if inputs.len() != meta.inputs.len() {
                            return err(format!(
                                "op {i}: kex `{artifact}` takes {} inputs, plan passes {}",
                                meta.inputs.len(),
                                inputs.len()
                            ));
                        }
                        if crate::runtime::elastic_artifact(artifact) {
                            // One shared input rule with `execute_bytes`
                            // (`runtime::elastic_scale`: whole elements,
                            // one common ratio ρ across scaling inputs,
                            // fixed inputs exact), plus ρ-scaled output
                            // regions — a per-element map produces outputs
                            // in proportion to its inputs, so anything
                            // else would panic a kex worker on the output
                            // write and hang the submitting run.
                            let lens: Vec<usize> = inputs.iter().map(|r| r.len).collect();
                            let (a, b) = match crate::runtime::elastic_scale(
                                artifact, meta, &lens,
                            ) {
                                Ok(rho) => rho,
                                Err(detail) => {
                                    return err(format!("op {i}: kex `{artifact}` {detail}"));
                                }
                            };
                            for (r, spec) in outputs.iter().zip(&meta.outputs) {
                                if r.len * b != spec.bytes() * a {
                                    return err(format!(
                                        "op {i}: kex `{artifact}` output region of {} bytes \
                                         is not the manifest size ({}) scaled by {a}/{b}",
                                        r.len,
                                        spec.bytes()
                                    ));
                                }
                            }
                        } else {
                            for (r, spec) in inputs.iter().zip(&meta.inputs) {
                                if r.len != spec.bytes() {
                                    return err(format!(
                                        "op {i}: kex `{artifact}` input region of {} bytes \
                                         violates the manifest signature ({} bytes)",
                                        r.len,
                                        spec.bytes()
                                    ));
                                }
                            }
                        }
                    }
                }
                PlanOpKind::D2h { src, output, off } => {
                    if !region_ok(src) {
                        return err(format!("op {i}: d2h region {src:?} out of buffer"));
                    }
                    if *output >= self.outputs.len() || off + src.len > self.outputs[*output] {
                        return err(format!("op {i}: d2h window out of output {output}"));
                    }
                }
            }
        }
        Ok(())
    }

    // --- analysis bridges -----------------------------------------------

    /// Analytic stage times of the *bulk* (single-stream, strictly
    /// staged) execution of this plan on `profile` — the closed-form
    /// view the decision rule (§3.4) and stream-count predictor (§6)
    /// consume.  H2D includes the lazy-allocation cost of each buffer's
    /// first touch, and kernels without a FLOP override fall back to
    /// the artifact manifest's per-call estimate — exactly as the
    /// engines charge both.
    pub fn stage_times(&self, profile: &DeviceProfile) -> StageTimes {
        let mut h2d = std::time::Duration::ZERO;
        let mut kex = std::time::Duration::ZERO;
        let mut d2h = std::time::Duration::ZERO;
        let mut touched = vec![false; self.bufs.len()];
        for op in &self.ops {
            match &op.kind {
                PlanOpKind::H2d { dst, .. } => {
                    h2d += profile.transfer_time(dst.len, true);
                    if !touched[dst.buf] {
                        touched[dst.buf] = true;
                        h2d += profile.alloc_time(dst.len);
                    }
                }
                PlanOpKind::Kex { artifact, flops, repeats, .. } => {
                    let per_call = flops.unwrap_or_else(|| manifest_flops(artifact));
                    kex += profile.kex_time(per_call * (*repeats).max(1) as u64);
                }
                PlanOpKind::D2h { src, .. } => {
                    d2h += profile.transfer_time(src.len, false);
                }
            }
        }
        StageTimes { h2d, kex, d2h }
    }

    /// The §3.3 stage-by-stage measurement spec of this plan: every H2D
    /// payload, every kernel call, every D2H payload, strictly staged.
    pub fn offload_spec(&self) -> crate::analysis::OffloadSpec {
        let mut h2d = Vec::new();
        let mut kex = Vec::new();
        let mut d2h = Vec::new();
        for op in &self.ops {
            match &op.kind {
                PlanOpKind::H2d { dst, .. } => h2d.push(dst.len),
                PlanOpKind::Kex { artifact, flops, repeats, .. } => {
                    kex.push(crate::analysis::KexCall {
                        artifact: artifact.clone(),
                        // measure_stages passes this as an explicit KEX
                        // pacing override, so a missing plan-level
                        // override must become the manifest estimate
                        // here, not zero.
                        flops: flops.unwrap_or_else(|| manifest_flops(artifact)),
                        repeats: *repeats,
                    })
                }
                PlanOpKind::D2h { src, .. } => d2h.push(src.len),
            }
        }
        crate::analysis::OffloadSpec { name: self.name.clone(), h2d, kex, d2h }
    }
}

/// Manifest entry for `artifact` (`None` if unknown).  Loaded once
/// (builtin manifest when no artifacts dir) and shared by the FLOP
/// fallback and the signature validation in [`StreamPlan::validate`].
pub(crate) fn manifest_meta(artifact: &str) -> Option<&'static crate::runtime::ArtifactMeta> {
    use std::sync::OnceLock;
    static MANIFEST: OnceLock<Option<crate::runtime::Manifest>> = OnceLock::new();
    MANIFEST
        .get_or_init(|| crate::runtime::Manifest::load(&crate::artifacts_dir()).ok())
        .as_ref()
        .and_then(|m| m.artifacts.iter().find(|a| a.name == artifact))
}

/// Manifest per-call FLOP estimate for `artifact` (0 if unknown) — the
/// same fallback the compute engine applies when a kernel job carries
/// no override.
fn manifest_flops(artifact: &str) -> u64 {
    manifest_meta(artifact).map(|a| a.flops_per_call).unwrap_or(0)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn payload(n: usize) -> Arc<Vec<u8>> {
        Arc::new(vec![7u8; n])
    }

    #[test]
    fn builder_tracks_annotations() {
        let mut p = StreamPlan::new("t");
        let b = p.buf(64);
        let o = p.output(32);
        p.h2d(Slot::Task(0), HostSlice::whole(payload(64)), PlanRegion::whole(b, 64), vec![]);
        let k = p.kex(
            Slot::Task(0),
            "burner_8",
            vec![PlanRegion::whole(b, 64)],
            vec![PlanRegion::whole(b, 64)],
            Some(1000),
            2,
            vec![],
        );
        p.d2h(Slot::Task(0), PlanRegion { buf: b, off: 0, len: 32 }, o, 0, vec![k]);
        assert_eq!(p.h2d_bytes(), 64);
        assert_eq!(p.d2h_bytes(), 32);
        assert_eq!(p.kex_flops(), 2000);
        assert_eq!(p.tasks(), 1);
        assert_eq!(p.artifacts(), vec!["burner_8".to_string()]);
        p.validate().expect("well-formed plan");
    }

    #[test]
    fn validate_rejects_forward_deps() {
        let mut p = StreamPlan::new("bad");
        let b = p.buf(16);
        p.h2d(Slot::Task(0), HostSlice::whole(payload(16)), PlanRegion::whole(b, 16), vec![3]);
        assert!(p.validate().is_err());
    }

    #[test]
    fn validate_rejects_out_of_buffer_regions() {
        let mut p = StreamPlan::new("bad");
        let b = p.buf(16);
        p.h2d(Slot::Task(0), HostSlice::whole(payload(32)), PlanRegion::whole(b, 32), vec![]);
        assert!(p.validate().is_err());
    }

    #[test]
    fn validate_rejects_late_broadcast() {
        let mut p = StreamPlan::new("bad");
        let b = p.buf(16);
        let src = HostSlice::whole(payload(16));
        p.h2d(Slot::Task(0), src.clone(), PlanRegion::whole(b, 16), vec![]);
        p.h2d(Slot::Broadcast, src, PlanRegion::whole(b, 16), vec![]);
        assert!(p.validate().is_err());
    }

    #[test]
    fn dag_shape_and_broadcast_accessors() {
        let mut p = StreamPlan::new("shape");
        let shared = p.buf(32);
        let src = HostSlice::whole(payload(32));
        p.h2d(Slot::Broadcast, src, PlanRegion::whole(shared, 32), vec![]);
        let b = p.buf(64);
        let k0 = p.kex(
            Slot::Task(0),
            "burner_8",
            vec![PlanRegion::whole(b, 64)],
            vec![PlanRegion::whole(b, 64)],
            Some(1),
            1,
            vec![],
        );
        p.kex(
            Slot::Task(1),
            "burner_8",
            vec![PlanRegion::whole(b, 64)],
            vec![PlanRegion::whole(b, 64)],
            Some(1),
            1,
            vec![],
        );
        // A third kernel chained on the first: depth 2, peak width 2.
        p.kex(
            Slot::Task(2),
            "burner_8",
            vec![PlanRegion::whole(b, 64)],
            vec![PlanRegion::whole(b, 64)],
            Some(1),
            1,
            vec![k0],
        );
        assert_eq!(p.dag_depth(), 2);
        assert_eq!(p.dag_width(), 2);
        assert_eq!(p.broadcast_h2d_bytes(), 32);
        p.validate().expect("well-formed plan");
    }

    #[test]
    fn validate_rejects_mis_signed_kex() {
        // Elastic artifacts demand whole elements…
        let mut p = StreamPlan::new("ragged");
        let b = p.buf(16);
        p.kex(
            Slot::Task(0),
            "burner_8",
            vec![PlanRegion { buf: b, off: 0, len: 6 }],
            vec![PlanRegion::whole(b, 16)],
            Some(1),
            1,
            vec![],
        );
        assert!(p.validate().is_err(), "6 bytes is not a whole f32 count");
        // …and fixed-shape artifacts demand the exact manifest bytes —
        // caught here instead of hanging a worker thread mid-run.
        let mut p = StreamPlan::new("short");
        let b = p.buf(16);
        p.kex(
            Slot::Task(0),
            "transpose",
            vec![PlanRegion::whole(b, 16)],
            vec![PlanRegion::whole(b, 16)],
            Some(1),
            1,
            vec![],
        );
        assert!(p.validate().is_err(), "fixed-shape artifact with wrong byte size");
        // Elastic inputs must scale by one common ratio, and outputs
        // must follow it — a per-element kernel fed 8+4 bytes would
        // produce a 4-byte output and panic the worker's output write.
        let mut p = StreamPlan::new("skewed");
        let b = p.buf(16);
        p.kex(
            Slot::Task(0),
            "vector_add",
            vec![PlanRegion { buf: b, off: 0, len: 8 }, PlanRegion { buf: b, off: 0, len: 4 }],
            vec![PlanRegion { buf: b, off: 0, len: 8 }],
            Some(1),
            1,
            vec![],
        );
        assert!(p.validate().is_err(), "inconsistently scaled elastic inputs");
        let mut p = StreamPlan::new("bad-out");
        let b = p.buf(16);
        p.kex(
            Slot::Task(0),
            "vector_add",
            vec![PlanRegion { buf: b, off: 0, len: 8 }, PlanRegion { buf: b, off: 0, len: 8 }],
            vec![PlanRegion { buf: b, off: 0, len: 4 }],
            Some(1),
            1,
            vec![],
        );
        assert!(p.validate().is_err(), "elastic output not scaled with the inputs");
    }

    #[test]
    fn offload_spec_mirrors_ops() {
        let mut p = StreamPlan::new("spec");
        let b = p.buf(128);
        let o = p.output(64);
        p.h2d(Slot::Task(0), HostSlice::whole(payload(128)), PlanRegion::whole(b, 128), vec![]);
        p.kex(
            Slot::Task(0),
            "burner_64",
            vec![PlanRegion::whole(b, 128)],
            vec![PlanRegion::whole(b, 128)],
            Some(5),
            3,
            vec![],
        );
        p.d2h(Slot::Task(0), PlanRegion { buf: b, off: 0, len: 64 }, o, 0, vec![]);
        let spec = p.offload_spec();
        assert_eq!(spec.h2d, vec![128]);
        assert_eq!(spec.d2h, vec![64]);
        assert_eq!(spec.kex.len(), 1);
        assert_eq!(spec.kex[0].artifact, "burner_64");
        assert_eq!(spec.kex[0].flops, 5);
        assert_eq!(spec.kex[0].repeats, 3);
    }
}
