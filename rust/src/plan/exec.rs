//! The engine-mapping scheduler: map any [`StreamPlan`] onto `n`
//! hstreams.  Since the backend-agnostic API landed this is the
//! *internals* of [`super::SimBackend`] — external callers submit
//! through the [`super::Backend`] trait; in-crate tuning loops may
//! still drive the executor directly.
//!
//! Placement policy (DESIGN.md §Plan):
//!
//! - `Slot::Broadcast` ops ride stream 0; every *other* stream's first
//!   op waits on their completion events (broadcast fan-out, exactly
//!   the hStreams idiom the hand-rolled drivers used).
//! - `Slot::Task(lane)` ops ride stream `lane % n`.  Independent and
//!   halo lowerings pass the task index as lane (round-robin);
//!   wavefront lowerings pass the slot within the anti-diagonal, so
//!   concurrency per diagonal follows the paper's Fig. 8.
//! - Explicit `deps` become `wait_event`s on the producing op's event —
//!   cross-stream RAW edges; same-stream deps are timing-neutral under
//!   the FIFO engine queues.
//!
//! Ops are submitted in plan order (a topological order by
//! construction), the executor owns every device buffer's lifetime and
//! assembles host outputs from the D2H ops, and all byte accounting
//! comes from the op annotations.

use std::time::Duration;

use crate::device::{DevRegion, HostDst, HostSrc};
use crate::hstreams::{Context, Event};
use crate::Result;

use super::{PlanOpKind, Slot, StreamPlan};

/// Outcome of one plan execution.
#[derive(Debug, Clone)]
pub struct PlanRun {
    /// Timeline makespan across all streams (virtual under
    /// `TimeMode::Virtual`, measured under `Wallclock`).
    pub wall: Duration,
    /// The assembled host outputs, one per [`StreamPlan::outputs`] entry.
    pub outputs: Vec<Vec<u8>>,
    /// Host→device bytes actually transferred (incl. halo redundancy).
    pub h2d_bytes: u64,
    pub d2h_bytes: u64,
    /// Pipeline tasks executed (`Task`-slot kernels).
    pub tasks: usize,
}

/// Executes plans on a [`Context`].
pub struct Executor<'c> {
    ctx: &'c Context,
}

impl<'c> Executor<'c> {
    pub fn new(ctx: &'c Context) -> Self {
        Self { ctx }
    }

    /// Run `plan` on `streams` streams (clamped to ≥ 1) and return the
    /// makespan, assembled outputs and byte counts.
    pub fn run(&self, plan: &StreamPlan, streams: usize) -> Result<PlanRun> {
        plan.validate()?;
        let n = streams.max(1);
        let ctx = self.ctx;
        // Measurement isolation: every executor caller syncs its streams
        // before returning, so the engines are drained here and each
        // run's timeline starts from aligned lanes.  Without this, grid
        // points in a tuning search inherit the previous point's
        // per-lane stagger and measured times depend on visit order.
        ctx.quiesce_timeline();

        // Allocate every plan buffer up front; on a mid-way failure
        // (arena exhaustion) release what was taken — callers like the
        // corpus sweep treat executor errors as per-plan outcomes and
        // keep using the same context, so a failed plan must not leak.
        let mut bufs: Vec<DevRegion> = Vec::with_capacity(plan.bufs.len());
        for &b in &plan.bufs {
            match ctx.alloc(b) {
                Ok(id) => bufs.push(DevRegion::whole(id, b)),
                Err(e) => {
                    for r in &bufs {
                        let _ = ctx.free(r.buf);
                    }
                    return Err(e);
                }
            }
        }
        let region = |r: &super::PlanRegion| DevRegion {
            buf: bufs[r.buf].buf,
            off: r.off,
            len: r.len,
        };
        let dsts: Vec<HostDst> =
            plan.outputs.iter().map(|&b| crate::hstreams::host_dst(b)).collect();

        let mut ss: Vec<_> = (0..n).map(|_| ctx.stream()).collect();
        let mut events: Vec<Event> = Vec::with_capacity(plan.ops.len());
        let mut broadcast_events: Vec<Event> = Vec::new();
        let mut started = vec![false; n];
        let mut h2d_bytes = 0u64;
        let mut d2h_bytes = 0u64;

        for op in &plan.ops {
            let si = match op.slot {
                Slot::Broadcast => 0,
                Slot::Task(lane) => lane % n,
            };
            let s = &mut ss[si];
            // Broadcast fan-out: a non-zero stream's first op waits on
            // every broadcast op (stream 0 is ordered after them by its
            // own FIFO program order).  This wait set is only complete
            // because `validate()` (above) rejects any broadcast op
            // that appears after a task op: a late broadcast would be
            // missing from `broadcast_events` for streams that already
            // started, silently dropping the RAW edge.
            if !started[si] {
                started[si] = true;
                if si != 0 {
                    for e in &broadcast_events {
                        s.wait_event(e.clone());
                    }
                }
            }
            for &d in &op.deps {
                s.wait_event(events[d].clone());
            }
            let e = match &op.kind {
                PlanOpKind::H2d { src, dst } => {
                    h2d_bytes += dst.len as u64;
                    s.h2d(
                        HostSrc { data: src.data.clone(), off: src.off, len: src.len },
                        region(dst),
                    )
                }
                PlanOpKind::Kex { artifact, inputs, outputs, flops, repeats } => s.kex_with(
                    artifact.clone(),
                    inputs.iter().map(&region).collect(),
                    outputs.iter().map(&region).collect(),
                    *flops,
                    *repeats,
                ),
                PlanOpKind::D2h { src, output, off } => {
                    d2h_bytes += src.len as u64;
                    s.d2h(region(src), HostDst { data: dsts[*output].data.clone(), off: *off })
                }
            };
            if matches!(op.slot, Slot::Broadcast) {
                broadcast_events.push(e.clone());
            }
            events.push(e);
        }

        for s in &ss {
            s.sync();
        }
        let wall = crate::hstreams::makespan(ss.iter().flat_map(|s| s.events()));

        let outputs: Vec<Vec<u8>> = dsts.iter().map(|d| d.data.lock().unwrap().clone()).collect();
        // Free everything even if one free fails (can only happen on a
        // foreign double-free); report the first error afterwards.
        let mut free_err = None;
        for r in &bufs {
            if let Err(e) = ctx.free(r.buf) {
                free_err.get_or_insert(e);
            }
        }
        if let Some(e) = free_err {
            return Err(e);
        }
        Ok(PlanRun { wall, outputs, h2d_bytes, d2h_bytes, tasks: plan.tasks() })
    }
}

/// Bit-for-bit output equality between two runs — the executor-level
/// oracle: a streamed mapping must reproduce the single-stream (or
/// bulk-lowered) outputs exactly, whatever the dtype, because every
/// task executes the same kernels on the same bytes.
pub fn outputs_match(a: &PlanRun, b: &PlanRun) -> bool {
    a.outputs == b.outputs
}
