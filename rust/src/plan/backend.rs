//! The backend-agnostic execution API: every way of *running* a
//! [`StreamPlan`] sits behind one [`Backend`] trait (DESIGN.md
//! §Backend).
//!
//! ```text
//! Backend::submit(&plan, RunConfig) -> RunHandle -> wait() -> PlanRun
//! ```
//!
//! Two implementations ship in-crate:
//!
//! - [`SimBackend`] — the virtual-clock engine path (the refactored
//!   historical `Executor`): plans map onto the modeled device's
//!   hstreams, every op's interval comes from the discrete-event
//!   clock, and engine lanes are quiesced between drained runs so
//!   makespans are independent of submission order.
//! - [`NativeBackend`] — the same task DAG executed on a **host
//!   thread pool** at wall-clock time through the `simkern`
//!   interpreter: no modeled device, no pacing, just the real
//!   dependency-driven execution of the plan's ops over host byte
//!   buffers.  Its `PlanRun::wall` is the host's real elapsed time.
//!
//! Both backends assemble **bitwise-identical** host outputs for any
//! valid plan: outputs are a pure function of (plan, payload bytes),
//! never of the clock — `tests/service_integration.rs` asserts it over
//! a category-spanning corpus sample, and the executor-level oracle
//! [`super::outputs_match`] makes the comparison one call.
//!
//! **Dependency contract.**  A plan's implicit ordering guarantees are
//! exactly what the engine executor provides at unbounded stream
//! count: ops sharing a `Slot::Task(lane)` value execute in program
//! order, `Slot::Broadcast` ops execute in program order before every
//! task lane's first op, and everything else must be ordered by
//! explicit `deps`.  The native backend materializes precisely that
//! partial order ([`native_deps`]) and runs any topological order of
//! it concurrently, which is sound because a plan whose conflicting
//! accesses are unordered under this contract would already be
//! nondeterministic on the engine path at some stream count.
//!
//! **Native throughput** (DESIGN.md §Native performance).  The native
//! pool is built for wall-clock speed, not just correctness:
//!
//! - *Arena reuse* — device buffers live in one pooled
//!   [`ArenaPool`] storage per run instead of per-plan zeroed
//!   vectors; checkout clears only the plan's must-zero spans
//!   ([`ArenaLayout`]).
//! - *Lock-light readiness* — op completion decrements successor
//!   indegrees with atomics (`AcqRel`, the release-sequence idiom);
//!   the only lock is a short one around the ready queue, and buffer
//!   regions are accessed without any per-buffer lock because the
//!   dependency contract orders every conflicting access pair and the
//!   scheduler's atomics carry the happens-before edges.
//! - *Locality-aware ordering* — the ready queue is a min-heap on
//!   `(lane, program index)` and a worker that makes its own lane's
//!   next op ready runs it immediately (chain-following), so one
//!   worker drains a task's H2D→KEX→D2H back-to-back while the heap
//!   keeps wavefront diagonal slots adjacent.

use std::cmp::Reverse;
use std::collections::{BinaryHeap, HashMap};
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, MutexGuard};
use std::time::Instant;

use crate::hstreams::Context;
use crate::runtime::{ArenaLayout, ArenaPool};
use crate::{Error, Result};

use super::exec::{Executor, PlanRun};
use super::{PlanOpKind, Slot, StreamPlan};

/// Per-submission knobs of one plan execution.
#[derive(Debug, Clone)]
pub struct RunConfig {
    /// Streams (engine lanes / native pool width) to map the plan onto;
    /// clamped to ≥ 1 by every backend.
    pub streams: usize,
}

impl RunConfig {
    /// Run on `n` streams.
    pub fn streams(n: usize) -> Self {
        Self { streams: n.max(1) }
    }
}

impl Default for RunConfig {
    fn default() -> Self {
        Self { streams: 1 }
    }
}

/// An accepted submission.  [`RunHandle::wait`] yields the byte-exact
/// assembled outputs and per-run stats ([`PlanRun`]).  Synchronous
/// backends resolve the handle at submission; asynchronous ones (the
/// native pool) resolve it when the DAG drains — kernel-level errors
/// surface at `wait`, structural (validation) errors at `submit`.
pub struct RunHandle {
    backend: &'static str,
    streams: usize,
    state: HandleState,
}

enum HandleState {
    Ready(Result<PlanRun>),
    Pending(std::thread::JoinHandle<Result<PlanRun>>),
}

impl RunHandle {
    fn ready(backend: &'static str, streams: usize, run: Result<PlanRun>) -> Self {
        Self { backend, streams, state: HandleState::Ready(run) }
    }

    /// Which backend accepted the submission.
    pub fn backend(&self) -> &'static str {
        self.backend
    }

    /// The stream count the plan was mapped onto.
    pub fn streams(&self) -> usize {
        self.streams
    }

    /// Whether `wait` would return without blocking.
    pub fn is_ready(&self) -> bool {
        match &self.state {
            HandleState::Ready(_) => true,
            HandleState::Pending(h) => h.is_finished(),
        }
    }

    /// Block until the run completes and return its outcome.
    pub fn wait(self) -> Result<PlanRun> {
        match self.state {
            HandleState::Ready(r) => r,
            HandleState::Pending(h) => h
                .join()
                .unwrap_or_else(|_| Err(Error::Stream("native backend run panicked".into()))),
        }
    }
}

/// A place a [`StreamPlan`] can run.  Implementations own *how* —
/// which engines, which clock, which physical device — while callers
/// own only the IR and a [`RunConfig`]; this is the seam every later
/// backend (real accelerator, PJRT device) plugs into.
pub trait Backend {
    /// Short backend identifier (`"sim"`, `"native"`, ...).
    fn name(&self) -> &'static str;

    /// Validate and submit `plan`; the handle yields outputs + stats.
    fn submit(&self, plan: &StreamPlan, cfg: RunConfig) -> Result<RunHandle>;

    /// Submit and wait — the common synchronous call shape.
    fn run(&self, plan: &StreamPlan, cfg: RunConfig) -> Result<PlanRun> {
        self.submit(plan, cfg)?.wait()
    }
}

/// The virtual-clock engine backend: plans execute on a borrowed
/// [`Context`]'s modeled device (DMA lanes + kernel queues under the
/// discrete-event clock).  Runs are synchronous — the handle is
/// resolved at submission — and the context's timeline is quiesced
/// between drained runs, so each run's makespan is independent of what
/// ran before it (measurement isolation; DESIGN.md §Time).
pub struct SimBackend<'c> {
    ctx: &'c Context,
}

impl<'c> SimBackend<'c> {
    pub fn new(ctx: &'c Context) -> Self {
        Self { ctx }
    }

    /// The context this backend maps plans onto.
    pub fn ctx(&self) -> &Context {
        self.ctx
    }
}

impl Backend for SimBackend<'_> {
    fn name(&self) -> &'static str {
        "sim"
    }

    fn submit(&self, plan: &StreamPlan, cfg: RunConfig) -> Result<RunHandle> {
        // Debug builds statically verify every submission (validate
        // first, so malformed plans keep their validation error; the
        // verifier then proves hazard freedom under the dependency
        // contract).  Pure analysis — the virtual clock never sees it,
        // so modeled makespans are unchanged.
        if cfg!(debug_assertions) {
            plan.validate()?;
            super::verify::ensure_sound(plan)?;
        }
        let streams = cfg.streams.max(1);
        Ok(RunHandle::ready("sim", streams, Executor::new(self.ctx).run(plan, streams)))
    }
}

/// The host thread-pool backend: the same task DAG, executed over host
/// byte buffers through the `simkern` interpreter at wall-clock time.
/// `RunConfig::streams` is the pool width; each worker thread owns its
/// own `ArtifactStore` (the PJRT feature's handles are `!Send`, same
/// per-thread idiom as the compute engine).  Device buffers live in a
/// pooled arena reused across runs ([`ArenaPool`]); the lazy-zero
/// semantics corpus plans rely on for never-written zero-source
/// buffers are preserved by clearing exactly the plan's must-zero
/// spans at checkout ([`ArenaLayout`]).
pub struct NativeBackend {
    artifacts_dir: PathBuf,
    arenas: Arc<ArenaPool>,
}

impl NativeBackend {
    /// A backend over the default artifacts directory (builtin manifest
    /// fallback when none is materialized on disk).
    pub fn new() -> Self {
        Self { artifacts_dir: crate::artifacts_dir(), arenas: Arc::new(ArenaPool::new()) }
    }

    /// Override where `manifest.json` / HLO artifacts live.
    pub fn artifacts_dir(mut self, dir: impl Into<PathBuf>) -> Self {
        self.artifacts_dir = dir.into();
        self
    }
}

impl Default for NativeBackend {
    fn default() -> Self {
        Self::new()
    }
}

impl Backend for NativeBackend {
    fn name(&self) -> &'static str {
        "native"
    }

    fn submit(&self, plan: &StreamPlan, cfg: RunConfig) -> Result<RunHandle> {
        plan.validate()?;
        // Debug builds additionally discharge the static soundness
        // proof the lock-free pool relies on (see `SharedBytes`).
        if cfg!(debug_assertions) {
            super::verify::ensure_sound(plan)?;
        }
        let workers = cfg.streams.max(1);
        let plan = plan.clone();
        let dir = self.artifacts_dir.clone();
        let arenas = Arc::clone(&self.arenas);
        let coordinator = std::thread::Builder::new()
            .name("hetstream-native".into())
            .spawn(move || run_native(&plan, &dir, workers, &arenas))
            .map_err(|e| Error::Stream(format!("spawn native coordinator: {e}")))?;
        Ok(RunHandle {
            backend: "native",
            streams: workers,
            state: HandleState::Pending(coordinator),
        })
    }
}

/// The full dependency list of every op under the backend contract
/// (module docs): explicit `deps`, plus program order within each
/// `Slot::Task(lane)` chain and within the broadcast prologue, plus a
/// barrier from every broadcast op to each task lane's first op.
/// Sorted and deduped per op (an explicit dep may coincide with the
/// implicit chain edge).
///
/// Public (re-exported as `plan::native_deps`) because this list *is*
/// the partial order the static verifier ([`super::verify`]) proves
/// hazard freedom against — the contract definition and its proof
/// obligation must be the same function.
pub fn native_deps(plan: &StreamPlan) -> Vec<Vec<usize>> {
    let mut deps: Vec<Vec<usize>> = Vec::with_capacity(plan.ops.len());
    // Key: None = the broadcast chain, Some(lane) = one task lane.
    let mut last: HashMap<Option<usize>, usize> = HashMap::new();
    let mut broadcasts: Vec<usize> = Vec::new();
    for (i, op) in plan.ops.iter().enumerate() {
        let key = match op.slot {
            Slot::Broadcast => None,
            Slot::Task(lane) => Some(lane),
        };
        let mut d = op.deps.clone();
        match last.get(&key) {
            Some(&prev) => d.push(prev),
            None if key.is_some() => d.extend(broadcasts.iter().copied()),
            None => {}
        }
        if key.is_none() {
            broadcasts.push(i);
        }
        last.insert(key, i);
        d.sort_unstable();
        d.dedup();
        deps.push(d);
    }
    deps
}

/// Ready-queue priority of op `i`: broadcasts first, then task lanes
/// in ascending order, program order within a lane.  Popping the
/// minimum makes a worker drain the lowest runnable chain front-to-
/// back (H2D→KEX→D2H cache-warm) and keeps the slots of a wavefront
/// diagonal — consecutive lanes at consecutive indices — adjacent.
fn order_key(slot: Slot, i: usize) -> u64 {
    let lane = match slot {
        Slot::Broadcast => 0u64,
        Slot::Task(l) => l as u64 + 1,
    };
    (lane << 32) | i as u64
}

/// Lock a mutex, tolerating poison: scheduler state stays usable even
/// if some thread panicked while holding it.
fn relock<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|e| e.into_inner())
}

/// Shared scheduler of one native run.  Readiness is tracked with
/// atomics — completing an op takes **no lock** unless it makes
/// off-lane successors ready (then one short push under the queue
/// mutex).  `done` flips under the queue mutex before the condvar
/// broadcast, so parked workers cannot miss the wakeup.
struct Scheduler {
    indeg: Vec<AtomicUsize>,
    /// Ops not yet retired; 0 = drained.
    remaining: AtomicUsize,
    /// Min-heap of [`order_key`]s of ready ops.
    queue: Mutex<BinaryHeap<Reverse<u64>>>,
    cv: Condvar,
    /// Drained or failed — workers exit when set.
    done: AtomicBool,
    error: Mutex<Option<Error>>,
}

impl Scheduler {
    fn new(deps: &[Vec<usize>], plan: &StreamPlan) -> Self {
        let indeg: Vec<AtomicUsize> = deps.iter().map(|d| AtomicUsize::new(d.len())).collect();
        let mut queue = BinaryHeap::new();
        for (i, d) in deps.iter().enumerate() {
            if d.is_empty() {
                queue.push(Reverse(order_key(plan.ops[i].slot, i)));
            }
        }
        Self {
            indeg,
            remaining: AtomicUsize::new(plan.ops.len()),
            queue: Mutex::new(queue),
            cv: Condvar::new(),
            // An empty plan is born drained.
            done: AtomicBool::new(plan.ops.is_empty()),
            error: Mutex::new(None),
        }
    }

    /// Next ready op in (lane, program order), or `None` when the run
    /// is drained or failed.  Parks on the condvar while empty.
    fn next(&self) -> Option<usize> {
        let mut q = relock(&self.queue);
        loop {
            if self.done.load(Ordering::Acquire) {
                return None;
            }
            if let Some(Reverse(key)) = q.pop() {
                return Some((key & 0xFFFF_FFFF) as usize);
            }
            q = self.cv.wait(q).unwrap_or_else(|e| e.into_inner());
        }
    }

    /// Publish newly-ready ops to the shared queue.
    fn push(&self, keys: &[u64]) {
        if keys.is_empty() {
            return;
        }
        let mut q = relock(&self.queue);
        for &k in keys {
            q.push(Reverse(k));
        }
        drop(q);
        if keys.len() == 1 {
            self.cv.notify_one();
        } else {
            self.cv.notify_all();
        }
    }

    /// End the run (drained or failed) and wake every parked worker.
    /// Holding the queue mutex across the flag flip closes the
    /// check-then-park race in [`Scheduler::next`].
    fn finish(&self) {
        let _q = relock(&self.queue);
        self.done.store(true, Ordering::Release);
        self.cv.notify_all();
    }

    /// Record the first error and end the run.
    fn fail(&self, e: Error) {
        relock(&self.error).get_or_insert(e);
        self.finish();
    }
}

/// Ends the run if a worker unwinds mid-op: without this, a panic
/// inside an op (a slice shape `validate` doesn't cover) would leave
/// `remaining > 0` with no error and no notification — sibling workers
/// would park on the condvar forever and `RunHandle::wait` would hang
/// instead of reporting the panic.  The panicking worker's own unwind
/// happens *outside* the scheduler locks, so recording the error here
/// cannot deadlock.
struct PanicGuard<'a> {
    sched: &'a Scheduler,
    armed: bool,
}

impl Drop for PanicGuard<'_> {
    fn drop(&mut self) {
        if self.armed {
            self.sched.fail(Error::Stream("native backend worker panicked".into()));
        }
    }
}

/// A raw shared view of one byte allocation (the run's arena or one
/// host output), accessed concurrently by the pool **without locks**.
///
/// Safety argument: the dependency contract orders every pair of ops
/// whose regions conflict (PR-5's offline mirror proves it over every
/// corpus lowering), and the scheduler's `AcqRel` indegree decrements
/// plus the queue mutex carry happens-before along every dependency
/// edge — so no two ops ever touch overlapping bytes concurrently,
/// and every read observes all writes ordered before it.  Each access
/// is bounds-asserted against the allocation.
struct SharedBytes {
    ptr: *mut u8,
    len: usize,
}

// SAFETY: `ptr` points into an allocation owned by the coordinating
// `run_native` frame, which outlives every worker thread of the run
// (workers are joined before the allocation drops), so sending the
// view across threads cannot dangle.
unsafe impl Send for SharedBytes {}
// SAFETY: concurrent `&self` access is race-free by the type-level
// argument above — the statically verified dependency contract keeps
// conflicting byte ranges on ordered ops, and the scheduler's
// `AcqRel` indegree decrements + queue mutex carry happens-before
// along every dependency edge.
unsafe impl Sync for SharedBytes {}

impl SharedBytes {
    /// View over `v`'s heap allocation (stable while `v` is neither
    /// resized nor dropped — the run holds it for its whole scope).
    fn of(v: &mut [u8]) -> Self {
        Self { ptr: v.as_mut_ptr(), len: v.len() }
    }

    /// Borrow `len` bytes at `off` (see type-level safety argument).
    fn slice(&self, off: usize, len: usize) -> &[u8] {
        assert!(off + len <= self.len, "native read out of bounds");
        // SAFETY: the assert keeps `[off, off+len)` inside the live
        // allocation, and the dependency contract (statically checked
        // by `plan::verify` in debug builds) guarantees no op writes
        // these bytes concurrently with this borrow.
        unsafe { std::slice::from_raw_parts(self.ptr.add(off), len) }
    }

    /// Copy `src` into the view at `off`.
    fn write(&self, off: usize, src: &[u8]) {
        assert!(off + src.len() <= self.len, "native write out of bounds");
        // SAFETY: the assert keeps the destination inside the live
        // allocation; `src` is a fresh worker-local buffer (or host
        // payload), so the ranges cannot overlap, and the dependency
        // contract orders every conflicting access to these bytes.
        unsafe { std::ptr::copy_nonoverlapping(src.as_ptr(), self.ptr.add(off), src.len()) }
    }
}

/// Execute `plan`'s DAG on `workers` host threads over a pooled arena
/// and assemble the outputs — dependency-driven, locality-ordered (see
/// module docs for the scheduling and memory policy).
fn run_native(
    plan: &StreamPlan,
    dir: &std::path::Path,
    workers: usize,
    arenas: &ArenaPool,
) -> Result<PlanRun> {
    let t0 = Instant::now();
    let deps = native_deps(plan);
    let mut children: Vec<Vec<usize>> = vec![Vec::new(); plan.ops.len()];
    for (i, d) in deps.iter().enumerate() {
        for &p in d {
            children[p].push(i);
        }
    }
    let sched = Scheduler::new(&deps, plan);

    let layout = ArenaLayout::of(plan);
    let mut storage = arenas.checkout(&layout);
    let arena = SharedBytes::of(&mut storage[..layout.total()]);
    let mut out_storage: Vec<Vec<u8>> = plan.outputs.iter().map(|&b| vec![0u8; b]).collect();
    let outputs: Vec<SharedBytes> = out_storage.iter_mut().map(|v| SharedBytes::of(v)).collect();
    let h2d_bytes = AtomicU64::new(0);
    let d2h_bytes = AtomicU64::new(0);

    // Load only what the plan launches (fast startup; unknown names
    // fail inside execute_bytes with a clean signature error).
    let artifact_names = plan.artifacts();
    // Never park more workers than the plan has ops.
    let workers = workers.max(1).min(plan.ops.len().max(1));

    std::thread::scope(|scope| {
        for w in 0..workers {
            let (sched, layout, arena) = (&sched, &layout, &arena);
            let (outputs, children) = (&outputs, &children);
            let (h2d_bytes, d2h_bytes) = (&h2d_bytes, &d2h_bytes);
            let (plan, names) = (&*plan, &artifact_names);
            std::thread::Builder::new()
                .name(format!("hetstream-native-{w}"))
                .spawn_scoped(scope, move || {
                    // Per-worker store, like the compute engine's workers.
                    let refs: Vec<&str> = names.iter().map(|s| s.as_str()).collect();
                    let store = crate::runtime::ArtifactStore::load_subset(dir, &refs);
                    // Chain-following: the same-lane successor this
                    // worker made ready, run next without re-queueing.
                    let mut next: Option<usize> = None;
                    loop {
                        let i = match next.take() {
                            Some(i) => i,
                            None => match sched.next() {
                                Some(i) => i,
                                None => return,
                            },
                        };
                        if sched.done.load(Ordering::Acquire) {
                            return; // another worker failed mid-chain
                        }
                        let mut guard = PanicGuard { sched, armed: true };
                        let result = store
                            .as_ref()
                            .map_err(|e| Error::Stream(e.to_string()))
                            .and_then(|store| {
                                exec_native_op(
                                    plan, i, store, layout, arena, outputs, h2d_bytes, d2h_bytes,
                                )
                            });
                        guard.armed = false;
                        drop(guard);
                        if let Err(e) = result {
                            sched.fail(e);
                            return;
                        }
                        // Retire: release successors with atomics; the
                        // last decrement of each indegree sees every
                        // predecessor's writes (release sequence).
                        let lane = plan.ops[i].slot;
                        let mut spill: Vec<u64> = Vec::new();
                        for &c in children[i].iter() {
                            if sched.indeg[c].fetch_sub(1, Ordering::AcqRel) == 1 {
                                let same_lane = plan.ops[c].slot == lane;
                                if next.is_none() && same_lane {
                                    next = Some(c);
                                } else {
                                    spill.push(order_key(plan.ops[c].slot, c));
                                }
                            }
                        }
                        sched.push(&spill);
                        if sched.remaining.fetch_sub(1, Ordering::AcqRel) == 1 {
                            sched.finish();
                            return;
                        }
                    }
                })
                .expect("spawn native worker");
        }
    });

    arenas.checkin(storage);
    if let Some(e) = relock(&sched.error).take() {
        return Err(e);
    }
    Ok(PlanRun {
        wall: t0.elapsed(),
        outputs: out_storage,
        h2d_bytes: h2d_bytes.into_inner(),
        d2h_bytes: d2h_bytes.into_inner(),
        tasks: plan.tasks(),
    })
}

/// Execute one op of a native run directly over the shared arena —
/// kernel inputs are borrowed from the arena (no staging copy), and
/// every write lands in place.
#[allow(clippy::too_many_arguments)]
fn exec_native_op(
    plan: &StreamPlan,
    i: usize,
    store: &crate::runtime::ArtifactStore,
    layout: &ArenaLayout,
    arena: &SharedBytes,
    outputs: &[SharedBytes],
    h2d_bytes: &AtomicU64,
    d2h_bytes: &AtomicU64,
) -> Result<()> {
    match &plan.ops[i].kind {
        PlanOpKind::H2d { src, dst } => {
            let at = layout.offset(dst.buf) + dst.off;
            arena.write(at, &src.data[src.off..src.off + src.len]);
            h2d_bytes.fetch_add(dst.len as u64, Ordering::Relaxed);
        }
        PlanOpKind::Kex { artifact, inputs, outputs: kouts, repeats, .. } => {
            let results = {
                let input_refs: Vec<&[u8]> = inputs
                    .iter()
                    .map(|r| arena.slice(layout.offset(r.buf) + r.off, r.len))
                    .collect();
                let mut results = Vec::new();
                for _ in 0..(*repeats).max(1) {
                    results = store.execute_bytes(artifact, &input_refs)?;
                }
                results
            };
            for (region, bytes) in kouts.iter().zip(&results) {
                if bytes.len() != region.len {
                    return Err(Error::Plan(format!(
                        "{}: op {i} kex `{artifact}` produced {} bytes for a {}-byte region",
                        plan.name,
                        bytes.len(),
                        region.len
                    )));
                }
                arena.write(layout.offset(region.buf) + region.off, bytes);
            }
        }
        PlanOpKind::D2h { src, output, off } => {
            let bytes = arena.slice(layout.offset(src.buf) + src.off, src.len);
            outputs[*output].write(*off, bytes);
            d2h_bytes.fetch_add(src.len as u64, Ordering::Relaxed);
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::plan::{HostSlice, PlanRegion};
    use std::sync::Arc;

    fn vecadd_plan(chunks: usize) -> StreamPlan {
        // `chunks` independent vector_add tasks over a shared payload,
        // one task lane each — small enough for unit tests, shaped like
        // the real lowerings (per-lane chains, no explicit H2d→Kex dep).
        let n = 65536 * 4;
        let a = Arc::new(vec![0x3Fu8; n * chunks]);
        let b = Arc::new(vec![0x40u8; n * chunks]);
        let mut p = StreamPlan::new("vecadd-backend");
        let out = p.output(n * chunks);
        for c in 0..chunks {
            let ab = p.buf(n);
            let bb = p.buf(n);
            let ob = p.buf(n);
            let slot = Slot::Task(c);
            p.h2d(
                slot,
                HostSlice { data: a.clone(), off: c * n, len: n },
                PlanRegion::whole(ab, n),
                vec![],
            );
            p.h2d(
                slot,
                HostSlice { data: b.clone(), off: c * n, len: n },
                PlanRegion::whole(bb, n),
                vec![],
            );
            let k = p.kex(
                slot,
                "vector_add",
                vec![PlanRegion::whole(ab, n), PlanRegion::whole(bb, n)],
                vec![PlanRegion::whole(ob, n)],
                Some(1),
                1,
                vec![],
            );
            p.d2h(slot, PlanRegion::whole(ob, n), out, c * n, vec![k]);
        }
        p
    }

    /// A plan whose second output half streams from a never-written
    /// zero-source buffer — the corpus shape arena reuse must not
    /// corrupt with a prior run's bytes.
    fn zero_tail_plan(n: usize) -> StreamPlan {
        let payload = Arc::new(vec![0x5Au8; n]);
        let mut p = StreamPlan::new("zero-tail");
        let out = p.output(2 * n);
        let data = p.buf(n);
        let zsrc = p.buf(n); // never written
        p.h2d(Slot::Task(0), HostSlice::whole(payload), PlanRegion::whole(data, n), vec![]);
        p.d2h(Slot::Task(0), PlanRegion::whole(data, n), out, 0, vec![]);
        p.d2h(Slot::Task(1), PlanRegion::whole(zsrc, n), out, n, vec![]);
        p
    }

    #[test]
    fn native_deps_chain_lanes_and_barrier_broadcasts() {
        let src = Arc::new(vec![0u8; 16]);
        let mut p = StreamPlan::new("deps");
        let b = p.buf(16);
        let r = PlanRegion::whole(b, 16);
        let s = HostSlice::whole(src);
        p.h2d(Slot::Broadcast, s.clone(), r, vec![]); // 0
        p.h2d(Slot::Broadcast, s.clone(), r, vec![]); // 1: after 0
        p.h2d(Slot::Task(0), s.clone(), r, vec![]); // 2: after broadcasts
        p.h2d(Slot::Task(1), s.clone(), r, vec![]); // 3: after broadcasts
        p.h2d(Slot::Task(0), s.clone(), r, vec![2]); // 4: chain dep dedupes
        let d = native_deps(&p);
        assert_eq!(d[0], Vec::<usize>::new());
        assert_eq!(d[1], vec![0], "broadcast prologue is a chain");
        assert_eq!(d[2], vec![0, 1], "first op of a task lane waits on all broadcasts");
        assert_eq!(d[3], vec![0, 1]);
        assert_eq!(d[4], vec![2], "explicit dep coinciding with the chain edge dedupes");
    }

    #[test]
    fn order_key_groups_lanes_after_broadcasts() {
        let mut keys = vec![
            order_key(Slot::Task(1), 5),
            order_key(Slot::Broadcast, 2),
            order_key(Slot::Task(0), 7),
            order_key(Slot::Task(0), 3),
            order_key(Slot::Broadcast, 0),
        ];
        keys.sort_unstable();
        let want = vec![
            order_key(Slot::Broadcast, 0),
            order_key(Slot::Broadcast, 2),
            order_key(Slot::Task(0), 3),
            order_key(Slot::Task(0), 7),
            order_key(Slot::Task(1), 5),
        ];
        assert_eq!(keys, want, "broadcasts first, then lanes in program order");
    }

    #[test]
    fn miri_sized_native_roundtrip() {
        // Small enough for `cargo miri test` (CI's unsafe-hygiene job):
        // exercises SharedBytes raw-pointer access, the atomic
        // readiness protocol, arena checkout and output assembly on a
        // 2-lane plan of 16-float buffers.
        let n = 64;
        let a = Arc::new(crate::runtime::bytes::from_f32(&[1.5f32; 16]));
        let b = Arc::new(crate::runtime::bytes::from_f32(&[2.25f32; 16]));
        let mut p = StreamPlan::new("miri-roundtrip");
        let out = p.output(2 * n);
        for lane in 0..2 {
            let ab = p.buf(n);
            let bb = p.buf(n);
            let ob = p.buf(n);
            let slot = Slot::Task(lane);
            p.h2d(slot, HostSlice::whole(a.clone()), PlanRegion::whole(ab, n), vec![]);
            p.h2d(slot, HostSlice::whole(b.clone()), PlanRegion::whole(bb, n), vec![]);
            p.kex(
                slot,
                "vector_add",
                vec![PlanRegion::whole(ab, n), PlanRegion::whole(bb, n)],
                vec![PlanRegion::whole(ob, n)],
                Some(1),
                1,
                vec![],
            );
            p.d2h(slot, PlanRegion::whole(ob, n), out, lane * n, vec![]);
        }
        let run = NativeBackend::new().run(&p, RunConfig::streams(2)).expect("native run");
        let got = crate::runtime::bytes::to_f32(&run.outputs[0]);
        assert_eq!(got, vec![3.75f32; 32]);
    }

    #[test]
    fn native_backend_matches_sim_backend_bitwise() {
        let plan = vecadd_plan(3);
        let ctx = crate::hstreams::ContextBuilder::new()
            .profile(crate::device::DeviceProfile::instant())
            .only_artifacts(vec!["vector_add"])
            .build()
            .expect("context");
        let sim = SimBackend::new(&ctx).run(&plan, RunConfig::streams(2)).expect("sim run");
        let native = NativeBackend::new();
        for streams in [1usize, 4] {
            let handle = native.submit(&plan, RunConfig::streams(streams)).expect("submit");
            assert_eq!(handle.backend(), "native");
            let run = handle.wait().expect("native run");
            assert_eq!(sim.outputs, run.outputs, "outputs diverge at pool width {streams}");
            assert_eq!(sim.h2d_bytes, run.h2d_bytes);
            assert_eq!(sim.d2h_bytes, run.d2h_bytes);
            assert_eq!(sim.tasks, run.tasks);
        }
    }

    #[test]
    fn reused_arena_never_leaks_into_zero_source_buffers() {
        // Regression for arena pooling: a plan that fills buffers with
        // nonzero bytes, then (on the same backend, hence the same
        // pooled storage) a plan with a never-written zero-source
        // buffer; the second run must match a fresh backend bitwise.
        let dirty = vecadd_plan(2);
        let zplan = zero_tail_plan(4096);
        let fresh = NativeBackend::new().run(&zplan, RunConfig::streams(2)).expect("fresh");
        assert!(fresh.outputs[0][4096..].iter().all(|&b| b == 0), "tail streams from zeros");

        let reused = NativeBackend::new();
        for width in [1usize, 2] {
            reused.run(&dirty, RunConfig::streams(width)).expect("dirty run");
            assert!(reused.arenas.pooled() > 0, "arena returned to the pool");
            let again = reused.run(&zplan, RunConfig::streams(width)).expect("reused run");
            assert_eq!(fresh.outputs, again.outputs, "stale bytes leaked at width {width}");
        }
    }

    #[test]
    fn native_backend_rejects_invalid_plans_at_submit() {
        let mut p = StreamPlan::new("bad");
        let b = p.buf(16);
        p.h2d(
            Slot::Task(0),
            HostSlice::whole(Arc::new(vec![0u8; 32])),
            PlanRegion::whole(b, 32),
            vec![],
        );
        assert!(NativeBackend::new().submit(&p, RunConfig::default()).is_err());
    }

    #[test]
    fn run_config_clamps_streams() {
        assert_eq!(RunConfig::streams(0).streams, 1);
        assert_eq!(RunConfig::default().streams, 1);
        assert_eq!(RunConfig::streams(6).streams, 6);
    }
}
