//! The backend-agnostic execution API: every way of *running* a
//! [`StreamPlan`] sits behind one [`Backend`] trait (DESIGN.md
//! §Backend).
//!
//! ```text
//! Backend::submit(&plan, RunConfig) -> RunHandle -> wait() -> PlanRun
//! ```
//!
//! Two implementations ship in-crate:
//!
//! - [`SimBackend`] — the virtual-clock engine path (the refactored
//!   historical `Executor`): plans map onto the modeled device's
//!   hstreams, every op's interval comes from the discrete-event
//!   clock, and engine lanes are quiesced between drained runs so
//!   makespans are independent of submission order.
//! - [`NativeBackend`] — the same task DAG executed on a **host
//!   thread pool** at wall-clock time through the `simkern`
//!   interpreter: no modeled device, no pacing, just the real
//!   dependency-driven execution of the plan's ops over host byte
//!   buffers.  Its `PlanRun::wall` is the host's real elapsed time.
//!
//! Both backends assemble **bitwise-identical** host outputs for any
//! valid plan: outputs are a pure function of (plan, payload bytes),
//! never of the clock — `tests/service_integration.rs` asserts it over
//! a category-spanning corpus sample, and the executor-level oracle
//! [`super::outputs_match`] makes the comparison one call.
//!
//! **Dependency contract.**  A plan's implicit ordering guarantees are
//! exactly what the engine executor provides at unbounded stream
//! count: ops sharing a `Slot::Task(lane)` value execute in program
//! order, `Slot::Broadcast` ops execute in program order before every
//! task lane's first op, and everything else must be ordered by
//! explicit `deps`.  The native backend materializes precisely that
//! partial order ([`native_deps`]) and runs any topological order of
//! it concurrently, which is sound because a plan whose conflicting
//! accesses are unordered under this contract would already be
//! nondeterministic on the engine path at some stream count.

use std::collections::HashMap;
use std::path::PathBuf;
use std::sync::{Condvar, Mutex};
use std::time::Instant;

use crate::hstreams::Context;
use crate::{Error, Result};

use super::exec::{Executor, PlanRun};
use super::{PlanOpKind, PlanRegion, Slot, StreamPlan};

/// Per-submission knobs of one plan execution.
#[derive(Debug, Clone)]
pub struct RunConfig {
    /// Streams (engine lanes / native pool width) to map the plan onto;
    /// clamped to ≥ 1 by every backend.
    pub streams: usize,
}

impl RunConfig {
    /// Run on `n` streams.
    pub fn streams(n: usize) -> Self {
        Self { streams: n.max(1) }
    }
}

impl Default for RunConfig {
    fn default() -> Self {
        Self { streams: 1 }
    }
}

/// An accepted submission.  [`RunHandle::wait`] yields the byte-exact
/// assembled outputs and per-run stats ([`PlanRun`]).  Synchronous
/// backends resolve the handle at submission; asynchronous ones (the
/// native pool) resolve it when the DAG drains — kernel-level errors
/// surface at `wait`, structural (validation) errors at `submit`.
pub struct RunHandle {
    backend: &'static str,
    streams: usize,
    state: HandleState,
}

enum HandleState {
    Ready(Result<PlanRun>),
    Pending(std::thread::JoinHandle<Result<PlanRun>>),
}

impl RunHandle {
    fn ready(backend: &'static str, streams: usize, run: Result<PlanRun>) -> Self {
        Self { backend, streams, state: HandleState::Ready(run) }
    }

    /// Which backend accepted the submission.
    pub fn backend(&self) -> &'static str {
        self.backend
    }

    /// The stream count the plan was mapped onto.
    pub fn streams(&self) -> usize {
        self.streams
    }

    /// Whether `wait` would return without blocking.
    pub fn is_ready(&self) -> bool {
        match &self.state {
            HandleState::Ready(_) => true,
            HandleState::Pending(h) => h.is_finished(),
        }
    }

    /// Block until the run completes and return its outcome.
    pub fn wait(self) -> Result<PlanRun> {
        match self.state {
            HandleState::Ready(r) => r,
            HandleState::Pending(h) => h
                .join()
                .unwrap_or_else(|_| Err(Error::Stream("native backend run panicked".into()))),
        }
    }
}

/// A place a [`StreamPlan`] can run.  Implementations own *how* —
/// which engines, which clock, which physical device — while callers
/// own only the IR and a [`RunConfig`]; this is the seam every later
/// backend (real accelerator, PJRT device) plugs into.
pub trait Backend {
    /// Short backend identifier (`"sim"`, `"native"`, ...).
    fn name(&self) -> &'static str;

    /// Validate and submit `plan`; the handle yields outputs + stats.
    fn submit(&self, plan: &StreamPlan, cfg: RunConfig) -> Result<RunHandle>;

    /// Submit and wait — the common synchronous call shape.
    fn run(&self, plan: &StreamPlan, cfg: RunConfig) -> Result<PlanRun> {
        self.submit(plan, cfg)?.wait()
    }
}

/// The virtual-clock engine backend: plans execute on a borrowed
/// [`Context`]'s modeled device (DMA lanes + kernel queues under the
/// discrete-event clock).  Runs are synchronous — the handle is
/// resolved at submission — and the context's timeline is quiesced
/// between drained runs, so each run's makespan is independent of what
/// ran before it (measurement isolation; DESIGN.md §Time).
pub struct SimBackend<'c> {
    ctx: &'c Context,
}

impl<'c> SimBackend<'c> {
    pub fn new(ctx: &'c Context) -> Self {
        Self { ctx }
    }

    /// The context this backend maps plans onto.
    pub fn ctx(&self) -> &Context {
        self.ctx
    }
}

impl Backend for SimBackend<'_> {
    fn name(&self) -> &'static str {
        "sim"
    }

    fn submit(&self, plan: &StreamPlan, cfg: RunConfig) -> Result<RunHandle> {
        let streams = cfg.streams.max(1);
        Ok(RunHandle::ready("sim", streams, Executor::new(self.ctx).run(plan, streams)))
    }
}

/// The host thread-pool backend: the same task DAG, executed over host
/// byte buffers through the `simkern` interpreter at wall-clock time.
/// `RunConfig::streams` is the pool width; each worker thread owns its
/// own `ArtifactStore` (the PJRT feature's handles are `!Send`, same
/// per-thread idiom as the compute engine).  Device buffers are
/// zero-initialized host vectors — the same lazy-zero semantics the
/// simulated arena provides, which corpus plans rely on for their
/// never-written zero-source buffers.
pub struct NativeBackend {
    artifacts_dir: PathBuf,
}

impl NativeBackend {
    /// A backend over the default artifacts directory (builtin manifest
    /// fallback when none is materialized on disk).
    pub fn new() -> Self {
        Self { artifacts_dir: crate::artifacts_dir() }
    }

    /// Override where `manifest.json` / HLO artifacts live.
    pub fn artifacts_dir(mut self, dir: impl Into<PathBuf>) -> Self {
        self.artifacts_dir = dir.into();
        self
    }
}

impl Default for NativeBackend {
    fn default() -> Self {
        Self::new()
    }
}

impl Backend for NativeBackend {
    fn name(&self) -> &'static str {
        "native"
    }

    fn submit(&self, plan: &StreamPlan, cfg: RunConfig) -> Result<RunHandle> {
        plan.validate()?;
        let workers = cfg.streams.max(1);
        let plan = plan.clone();
        let dir = self.artifacts_dir.clone();
        let coordinator = std::thread::Builder::new()
            .name("hetstream-native".into())
            .spawn(move || run_native(&plan, &dir, workers))
            .map_err(|e| Error::Stream(format!("spawn native coordinator: {e}")))?;
        Ok(RunHandle {
            backend: "native",
            streams: workers,
            state: HandleState::Pending(coordinator),
        })
    }
}

/// The full dependency list of every op under the backend contract
/// (module docs): explicit `deps`, plus program order within each
/// `Slot::Task(lane)` chain and within the broadcast prologue, plus a
/// barrier from every broadcast op to each task lane's first op.
/// Sorted and deduped per op (an explicit dep may coincide with the
/// implicit chain edge).
fn native_deps(plan: &StreamPlan) -> Vec<Vec<usize>> {
    let mut deps: Vec<Vec<usize>> = Vec::with_capacity(plan.ops.len());
    // Key: None = the broadcast chain, Some(lane) = one task lane.
    let mut last: HashMap<Option<usize>, usize> = HashMap::new();
    let mut broadcasts: Vec<usize> = Vec::new();
    for (i, op) in plan.ops.iter().enumerate() {
        let key = match op.slot {
            Slot::Broadcast => None,
            Slot::Task(lane) => Some(lane),
        };
        let mut d = op.deps.clone();
        match last.get(&key) {
            Some(&prev) => d.push(prev),
            None if key.is_some() => d.extend(broadcasts.iter().copied()),
            None => {}
        }
        if key.is_none() {
            broadcasts.push(i);
        }
        last.insert(key, i);
        d.sort_unstable();
        d.dedup();
        deps.push(d);
    }
    deps
}

/// Shared scheduler state of one native run (behind the pool's mutex).
struct NativeState {
    indeg: Vec<usize>,
    ready: Vec<usize>,
    /// Ops not yet retired; 0 = drained.
    remaining: usize,
    error: Option<Error>,
}

/// Wakes the pool if a worker unwinds mid-op: without this, a panic
/// inside an op (poisoned buffer mutex, a slice shape `validate`
/// doesn't cover) would leave `remaining > 0` with no error and no
/// notification — sibling workers would park on the condvar forever
/// and `RunHandle::wait` would hang instead of reporting the panic.
/// The panicking worker's own unwind happens *outside* the state
/// mutex, so recording the error here cannot deadlock or poison it.
struct PanicGuard<'a> {
    state: &'a Mutex<NativeState>,
    cv: &'a Condvar,
    armed: bool,
}

impl Drop for PanicGuard<'_> {
    fn drop(&mut self) {
        if self.armed {
            if let Ok(mut s) = self.state.lock() {
                s.error.get_or_insert(Error::Stream("native backend worker panicked".into()));
            }
            self.cv.notify_all();
        }
    }
}

/// Execute `plan`'s DAG on `workers` host threads and assemble the
/// outputs — dependency-driven, order-free: any ready op may run on
/// any worker, which is sound under the backend dependency contract.
fn run_native(plan: &StreamPlan, dir: &std::path::Path, workers: usize) -> Result<PlanRun> {
    let t0 = Instant::now();
    let deps = native_deps(plan);
    let mut children: Vec<Vec<usize>> = vec![Vec::new(); plan.ops.len()];
    let mut indeg = vec![0usize; plan.ops.len()];
    for (i, d) in deps.iter().enumerate() {
        indeg[i] = d.len();
        for &p in d {
            children[p].push(i);
        }
    }
    let ready: Vec<usize> = (0..plan.ops.len()).filter(|&i| indeg[i] == 0).collect();
    let state = Mutex::new(NativeState { indeg, ready, remaining: plan.ops.len(), error: None });
    let cv = Condvar::new();

    let bufs: Vec<Mutex<Vec<u8>>> = plan.bufs.iter().map(|&b| Mutex::new(vec![0u8; b])).collect();
    let outputs: Vec<Mutex<Vec<u8>>> =
        plan.outputs.iter().map(|&b| Mutex::new(vec![0u8; b])).collect();
    let h2d_bytes = std::sync::atomic::AtomicU64::new(0);
    let d2h_bytes = std::sync::atomic::AtomicU64::new(0);

    // Load only what the plan launches (fast startup; unknown names
    // fail inside execute_bytes with a clean signature error).
    let artifact_names = plan.artifacts();

    std::thread::scope(|scope| {
        for w in 0..workers.max(1) {
            let (state, cv) = (&state, &cv);
            let (bufs, outputs) = (&bufs, &outputs);
            let (h2d_bytes, d2h_bytes) = (&h2d_bytes, &d2h_bytes);
            let (plan, children, names) = (&*plan, &children, &artifact_names);
            std::thread::Builder::new()
                .name(format!("hetstream-native-{w}"))
                .spawn_scoped(scope, move || {
                    // Per-worker store, like the compute engine's workers.
                    let refs: Vec<&str> = names.iter().map(|s| s.as_str()).collect();
                    let store = crate::runtime::ArtifactStore::load_subset(dir, &refs);
                    loop {
                        let i = {
                            let mut s = state.lock().unwrap();
                            loop {
                                if s.error.is_some() || s.remaining == 0 {
                                    return;
                                }
                                if let Some(i) = s.ready.pop() {
                                    break i;
                                }
                                s = cv.wait(s).unwrap();
                            }
                        };
                        let mut guard = PanicGuard { state, cv, armed: true };
                        let result = store
                            .as_ref()
                            .map_err(|e| Error::Stream(e.to_string()))
                            .and_then(|store| {
                                exec_native_op(plan, i, store, bufs, outputs, h2d_bytes, d2h_bytes)
                            });
                        guard.armed = false;
                        drop(guard);
                        let mut s = state.lock().unwrap();
                        match result {
                            Err(e) => {
                                s.error.get_or_insert(e);
                                cv.notify_all();
                                return;
                            }
                            Ok(()) => {
                                s.remaining -= 1;
                                for &c in &children[i] {
                                    s.indeg[c] -= 1;
                                    if s.indeg[c] == 0 {
                                        s.ready.push(c);
                                    }
                                }
                                cv.notify_all();
                            }
                        }
                    }
                })
                .expect("spawn native worker");
        }
    });

    let mut s = state.into_inner().unwrap();
    if let Some(e) = s.error.take() {
        return Err(e);
    }
    Ok(PlanRun {
        wall: t0.elapsed(),
        outputs: outputs.into_iter().map(|m| m.into_inner().unwrap()).collect(),
        h2d_bytes: h2d_bytes.into_inner(),
        d2h_bytes: d2h_bytes.into_inner(),
        tasks: plan.tasks(),
    })
}

/// Execute one op of a native run.
fn exec_native_op(
    plan: &StreamPlan,
    i: usize,
    store: &crate::runtime::ArtifactStore,
    bufs: &[Mutex<Vec<u8>>],
    outputs: &[Mutex<Vec<u8>>],
    h2d_bytes: &std::sync::atomic::AtomicU64,
    d2h_bytes: &std::sync::atomic::AtomicU64,
) -> Result<()> {
    use std::sync::atomic::Ordering::Relaxed;
    let read_region = |r: &PlanRegion| -> Vec<u8> {
        bufs[r.buf].lock().unwrap()[r.off..r.off + r.len].to_vec()
    };
    match &plan.ops[i].kind {
        PlanOpKind::H2d { src, dst } => {
            let mut b = bufs[dst.buf].lock().unwrap();
            b[dst.off..dst.off + dst.len].copy_from_slice(&src.data[src.off..src.off + src.len]);
            h2d_bytes.fetch_add(dst.len as u64, Relaxed);
        }
        PlanOpKind::Kex { artifact, inputs, outputs: kouts, repeats, .. } => {
            // One buffered copy in, execute, one copy out — the same
            // host-side shadow of device memory traffic the engine
            // workers perform.
            let input_bytes: Vec<Vec<u8>> = inputs.iter().map(read_region).collect();
            let input_refs: Vec<&[u8]> = input_bytes.iter().map(|b| b.as_slice()).collect();
            let mut results = Vec::new();
            for _ in 0..(*repeats).max(1) {
                results = store.execute_bytes(artifact, &input_refs)?;
            }
            for (region, bytes) in kouts.iter().zip(&results) {
                if bytes.len() != region.len {
                    return Err(Error::Plan(format!(
                        "{}: op {i} kex `{artifact}` produced {} bytes for a {}-byte region",
                        plan.name,
                        bytes.len(),
                        region.len
                    )));
                }
                let mut b = bufs[region.buf].lock().unwrap();
                b[region.off..region.off + region.len].copy_from_slice(bytes);
            }
        }
        PlanOpKind::D2h { src, output, off } => {
            let bytes = read_region(src);
            let mut o = outputs[*output].lock().unwrap();
            o[*off..*off + src.len].copy_from_slice(&bytes);
            d2h_bytes.fetch_add(src.len as u64, Relaxed);
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::plan::HostSlice;
    use std::sync::Arc;

    fn vecadd_plan(chunks: usize) -> StreamPlan {
        // `chunks` independent vector_add tasks over a shared payload,
        // one task lane each — small enough for unit tests, shaped like
        // the real lowerings (per-lane chains, no explicit H2d→Kex dep).
        let n = 65536 * 4;
        let a = Arc::new(vec![0x3Fu8; n * chunks]);
        let b = Arc::new(vec![0x40u8; n * chunks]);
        let mut p = StreamPlan::new("vecadd-backend");
        let out = p.output(n * chunks);
        for c in 0..chunks {
            let ab = p.buf(n);
            let bb = p.buf(n);
            let ob = p.buf(n);
            let slot = Slot::Task(c);
            p.h2d(
                slot,
                HostSlice { data: a.clone(), off: c * n, len: n },
                PlanRegion::whole(ab, n),
                vec![],
            );
            p.h2d(
                slot,
                HostSlice { data: b.clone(), off: c * n, len: n },
                PlanRegion::whole(bb, n),
                vec![],
            );
            let k = p.kex(
                slot,
                "vector_add",
                vec![PlanRegion::whole(ab, n), PlanRegion::whole(bb, n)],
                vec![PlanRegion::whole(ob, n)],
                Some(1),
                1,
                vec![],
            );
            p.d2h(slot, PlanRegion::whole(ob, n), out, c * n, vec![k]);
        }
        p
    }

    #[test]
    fn native_deps_chain_lanes_and_barrier_broadcasts() {
        let src = Arc::new(vec![0u8; 16]);
        let mut p = StreamPlan::new("deps");
        let b = p.buf(16);
        let r = PlanRegion::whole(b, 16);
        let s = HostSlice::whole(src);
        p.h2d(Slot::Broadcast, s.clone(), r, vec![]); // 0
        p.h2d(Slot::Broadcast, s.clone(), r, vec![]); // 1: after 0
        p.h2d(Slot::Task(0), s.clone(), r, vec![]); // 2: after broadcasts
        p.h2d(Slot::Task(1), s.clone(), r, vec![]); // 3: after broadcasts
        p.h2d(Slot::Task(0), s.clone(), r, vec![2]); // 4: chain dep dedupes
        let d = native_deps(&p);
        assert_eq!(d[0], Vec::<usize>::new());
        assert_eq!(d[1], vec![0], "broadcast prologue is a chain");
        assert_eq!(d[2], vec![0, 1], "first op of a task lane waits on all broadcasts");
        assert_eq!(d[3], vec![0, 1]);
        assert_eq!(d[4], vec![2], "explicit dep coinciding with the chain edge dedupes");
    }

    #[test]
    fn native_backend_matches_sim_backend_bitwise() {
        let plan = vecadd_plan(3);
        let ctx = crate::hstreams::ContextBuilder::new()
            .profile(crate::device::DeviceProfile::instant())
            .only_artifacts(vec!["vector_add"])
            .build()
            .expect("context");
        let sim = SimBackend::new(&ctx).run(&plan, RunConfig::streams(2)).expect("sim run");
        let native = NativeBackend::new();
        for streams in [1usize, 4] {
            let handle = native.submit(&plan, RunConfig::streams(streams)).expect("submit");
            assert_eq!(handle.backend(), "native");
            let run = handle.wait().expect("native run");
            assert_eq!(sim.outputs, run.outputs, "outputs diverge at pool width {streams}");
            assert_eq!(sim.h2d_bytes, run.h2d_bytes);
            assert_eq!(sim.d2h_bytes, run.d2h_bytes);
            assert_eq!(sim.tasks, run.tasks);
        }
    }

    #[test]
    fn native_backend_rejects_invalid_plans_at_submit() {
        let mut p = StreamPlan::new("bad");
        let b = p.buf(16);
        p.h2d(
            Slot::Task(0),
            HostSlice::whole(Arc::new(vec![0u8; 32])),
            PlanRegion::whole(b, 32),
            vec![],
        );
        assert!(NativeBackend::new().submit(&p, RunConfig::default()).is_err());
    }

    #[test]
    fn run_config_clamps_streams() {
        assert_eq!(RunConfig::streams(0).streams, 1);
        assert_eq!(RunConfig::default().streams, 1);
        assert_eq!(RunConfig::streams(6).streams, 6);
    }
}
