//! `plan::verify` — static hazard analysis over a [`StreamPlan`]
//! (DESIGN.md §Verification).
//!
//! The paper's premise is that overlapping transfers and compute across
//! streams must never change results.  The executors enforce that
//! *dynamically* (the bitwise streamed-vs-reference oracle); this
//! module proves it *statically*, per plan, without executing anything:
//!
//! 1. **Race freedom.**  Every op's byte-interval accesses are derived
//!    from its declared regions (H2d writes its destination, Kex reads
//!    its inputs and writes its outputs, D2h reads its source and
//!    writes its host-output window).  Any two accesses that overlap,
//!    touch the same buffer or output, and include a write must be
//!    ordered by the backend dependency contract ([`native_deps`]:
//!    explicit deps + per-lane program order + the broadcast barrier) —
//!    RAW/WAW/WAR hazard freedom over the partial order, so *any* pool
//!    schedule or stream mapping assembles the same bytes.
//! 2. **Output tiling.**  D2h windows tile each host output exactly
//!    once — no gap, no double-write.  (Ordered gaps/double-writes are
//!    still deterministic — outputs are zero-initialized and the
//!    partial order fixes the winner — so these are strictness
//!    hazards, reported but not fatal at submit; every in-repo
//!    lowering is tiled exactly and `repro verify` enforces it.)
//! 3. **Graph sanity.**  Dep edges in range and strictly backwards
//!    (acyclicity by topological construction), broadcast prologue
//!    closed before the first `Task` op.
//! 4. **Arena soundness.**  [`ArenaLayout`] must-zero spans cover every
//!    byte an op reads that no *ancestor* (under the partial order)
//!    wrote — the condition under which pooled-arena reuse
//!    (`runtime::arena`) cannot leak a previous plan's bytes into this
//!    plan's reads.
//! 5. **Lifetimes.**  Every access lands inside its declared buffer or
//!    output (backends allocate per plan and free at drain, so
//!    in-bounds ⇒ no use-after-release), and broadcast ops precede all
//!    consumers (with the contract's barrier, every task op is then
//!    ordered after the whole prologue).
//!
//! The verifier reports a structured [`Hazard`] list — op pair, space,
//! byte interval, missing edge — never a bare boolean.  It runs three
//! ways: `repro verify [--corpus]` over the corpus lowerings (the
//! offline proof, cross-checked against the Python mirror's
//! `native_output_path_check` in CI), as a `debug_assertions` gate
//! inside both [`super::Backend`] `submit` paths, and on the service
//! path for every admitted lowering.  Kex regions are taken as
//! declared; `StreamPlan::validate` separately proves they conform to
//! the manifest signature (arity + elastic scaling), which is why the
//! submit gates run `validate` first.

use std::collections::HashMap;
use std::fmt;

use super::{PlanOp, PlanOpKind, Slot, StreamPlan};
use crate::runtime::ArenaLayout;
use crate::{Error, Result};

/// Address space of one byte-interval access.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Space {
    /// A logical device buffer (index into `StreamPlan::bufs`).
    Dev(usize),
    /// A host output (index into `StreamPlan::outputs`).
    Out(usize),
}

impl fmt::Display for Space {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Space::Dev(b) => write!(f, "dev buf {b}"),
            Space::Out(o) => write!(f, "host output {o}"),
        }
    }
}

/// One byte-interval access record: op `op` touches `space` bytes
/// `[lo, hi)`, reading or writing.
#[derive(Debug, Clone, Copy)]
pub struct Access {
    pub op: usize,
    pub space: Space,
    pub lo: usize,
    pub hi: usize,
    pub write: bool,
}

/// What kind of proof obligation a [`Hazard`] violates.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum HazardKind {
    /// A dep edge pointing at itself or forward — the topological
    /// contract is broken and the dependency closure is undefined.
    InvalidDep,
    /// A `Slot::Broadcast` op after the first `Task` op: the barrier
    /// no longer covers its consumers.
    LateBroadcast,
    /// An access outside its declared buffer or output.
    OutOfRange,
    /// Two overlapping accesses, at least one a write, with no
    /// dependency path between their ops — the schedule decides the
    /// bytes.
    UnorderedRace,
    /// An op reads bytes that no ancestor wrote and that the arena
    /// layout does not guarantee zero — pooled-arena reuse could leak
    /// a previous plan's bytes into them.
    UncoveredRead,
    /// D2h windows leave part of a host output unwritten.
    OutputGap,
    /// Two D2h windows write the same host-output byte.
    OutputOverlap,
}

impl HazardKind {
    /// Stable lowercase label (JSON / mirror cross-check vocabulary).
    pub fn label(self) -> &'static str {
        match self {
            HazardKind::InvalidDep => "invalid-dep",
            HazardKind::LateBroadcast => "late-broadcast",
            HazardKind::OutOfRange => "out-of-range",
            HazardKind::UnorderedRace => "unordered-race",
            HazardKind::UncoveredRead => "uncovered-read",
            HazardKind::OutputGap => "output-gap",
            HazardKind::OutputOverlap => "output-overlap",
        }
    }

    /// Fatal hazards make the assembled bytes schedule- or
    /// reuse-dependent; the submit gate refuses them.  Tiling hazards
    /// (`OutputGap` / ordered `OutputOverlap`) are deterministic but
    /// non-canonical — reported, enforced by `repro verify`, admitted
    /// at submit (an *unordered* double-write is an `UnorderedRace`).
    pub fn fatal(self) -> bool {
        !matches!(self, HazardKind::OutputGap | HazardKind::OutputOverlap)
    }
}

impl fmt::Display for HazardKind {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.label())
    }
}

/// One verifier finding: the violated obligation, the op pair
/// involved, the byte interval in `space`, and — for races — the edge
/// whose absence makes the pair unordered.
#[derive(Debug, Clone)]
pub struct Hazard {
    pub kind: HazardKind,
    /// The ops involved (`None` where no op anchors the finding, e.g.
    /// a gap at the end of an output no window reaches).
    pub ops: (Option<usize>, Option<usize>),
    pub space: Option<Space>,
    /// Conflicting half-open byte interval within `space`.
    pub lo: usize,
    pub hi: usize,
    /// For [`HazardKind::UnorderedRace`]: the `(from, to)` dep edge
    /// (consistent with submission order) that would order the pair.
    pub missing_edge: Option<(usize, usize)>,
    pub detail: String,
}

impl fmt::Display for Hazard {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}: {}", self.kind, self.detail)?;
        if let Some(space) = self.space {
            write!(f, " [{} bytes {}..{})", space, self.lo, self.hi)?;
        }
        if let Some((a, b)) = self.missing_edge {
            write!(f, " (missing edge {a} -> {b})")?;
        }
        Ok(())
    }
}

/// The verifier's structured result for one plan.
#[derive(Debug, Clone)]
pub struct VerifyReport {
    /// `StreamPlan::name` of the analyzed plan.
    pub plan: String,
    /// Ops analyzed.
    pub ops: usize,
    /// Byte-interval access records derived.
    pub accesses: usize,
    /// Overlapping access pairs with a write that had to be proven
    /// ordered (the size of the discharged obligation, hazardous or
    /// not).
    pub conflicts: usize,
    /// Everything found, in discovery order (structure, races, tiling,
    /// coverage).
    pub hazards: Vec<Hazard>,
}

impl VerifyReport {
    /// No fatal hazard: any pool schedule and any pooled-arena reuse
    /// assembles the same bytes.  This is what the submit gate checks.
    pub fn is_sound(&self) -> bool {
        self.hazards.iter().all(|h| !h.kind.fatal())
    }

    /// No hazard at all, tiling included — what `repro verify`
    /// demands of every in-repo lowering.
    pub fn is_clean(&self) -> bool {
        self.hazards.is_empty()
    }

    /// Human-readable multi-line report.
    pub fn summary(&self) -> String {
        let mut s = format!(
            "plan `{}`: {} ops, {} accesses, {} conflicting pairs proven ordered",
            self.plan,
            self.ops,
            self.accesses,
            self.conflicts
        );
        if self.hazards.is_empty() {
            s.push_str(" — hazard-free");
        } else {
            s.push_str(&format!(" — {} hazard(s):", self.hazards.len()));
            for h in &self.hazards {
                s.push_str(&format!("\n  {h}"));
            }
        }
        s
    }

    /// One JSON object (util::json-parsable; the CI cross-check diffs
    /// these against the Python mirror's verdicts).
    pub fn to_json(&self) -> String {
        let hazards: Vec<String> = self
            .hazards
            .iter()
            .map(|h| {
                let op_json = |o: Option<usize>| {
                    o.map_or("null".to_string(), |i| i.to_string())
                };
                format!(
                    "{{\"kind\":\"{}\",\"ops\":[{},{}],\"space\":\"{}\",\"interval\":[{},{}],\"missing_edge\":{},\"detail\":\"{}\"}}",
                    h.kind.label(),
                    op_json(h.ops.0),
                    op_json(h.ops.1),
                    h.space.map_or_else(|| "-".to_string(), |s| s.to_string()),
                    h.lo,
                    h.hi,
                    h.missing_edge
                        .map_or("null".to_string(), |(a, b)| format!("[{a},{b}]")),
                    esc(&h.detail)
                )
            })
            .collect();
        format!(
            "{{\"plan\":\"{}\",\"ops\":{},\"accesses\":{},\"conflicts\":{},\"sound\":{},\"clean\":{},\"hazards\":[{}]}}",
            esc(&self.plan),
            self.ops,
            self.accesses,
            self.conflicts,
            self.is_sound(),
            self.is_clean(),
            hazards.join(",")
        )
    }
}

use crate::util::json::escape as esc;

/// The byte-interval access records of one op, from its declared
/// regions.  Kex regions are trusted as declared — `validate()`
/// separately proves they match the manifest signature (arity +
/// elastic scaling), so signature conformance and hazard freedom
/// compose into the full proof.
pub fn op_accesses(op: &PlanOp, i: usize) -> Vec<Access> {
    let mut acc = Vec::new();
    let mut push = |space: Space, lo: usize, hi: usize, write: bool| {
        acc.push(Access { op: i, space, lo, hi, write });
    };
    match &op.kind {
        PlanOpKind::H2d { dst, .. } => {
            push(Space::Dev(dst.buf), dst.off, dst.off + dst.len, true);
        }
        PlanOpKind::Kex { inputs, outputs, .. } => {
            for r in inputs {
                push(Space::Dev(r.buf), r.off, r.off + r.len, false);
            }
            for r in outputs {
                push(Space::Dev(r.buf), r.off, r.off + r.len, true);
            }
        }
        PlanOpKind::D2h { src, output, off } => {
            push(Space::Dev(src.buf), src.off, src.off + src.len, false);
            push(Space::Out(*output), *off, *off + src.len, true);
        }
    }
    acc
}

/// All access records of a plan, in op order.
pub fn access_records(plan: &StreamPlan) -> Vec<Access> {
    plan.ops.iter().enumerate().flat_map(|(i, op)| op_accesses(op, i)).collect()
}

/// Verify `plan` against a freshly derived [`ArenaLayout`] (the layout
/// the native backend will actually run it under).
pub fn verify_plan(plan: &StreamPlan) -> VerifyReport {
    verify_plan_with_layout(plan, &ArenaLayout::of(plan))
}

/// Refuse a plan with any fatal hazard (the `Backend::submit` /
/// service gate).  The error carries the first few hazards, op pairs
/// and byte intervals included.
pub fn ensure_sound(plan: &StreamPlan) -> Result<()> {
    let report = verify_plan(plan);
    if report.is_sound() {
        return Ok(());
    }
    let fatal: Vec<String> =
        report.hazards.iter().filter(|h| h.kind.fatal()).take(3).map(|h| h.to_string()).collect();
    let total = report.hazards.iter().filter(|h| h.kind.fatal()).count();
    Err(Error::Plan(format!(
        "hazard verifier refused plan `{}`: {} fatal hazard(s): {}{}",
        report.plan,
        total,
        fatal.join("; "),
        if total > fatal.len() { "; ..." } else { "" }
    )))
}

/// Short `op 12 (Kex vector_add)`-style label for hazard messages.
fn op_label(plan: &StreamPlan, i: usize) -> String {
    match &plan.ops[i].kind {
        PlanOpKind::H2d { .. } => format!("op {i} (H2d)"),
        PlanOpKind::Kex { artifact, .. } => format!("op {i} (Kex {artifact})"),
        PlanOpKind::D2h { .. } => format!("op {i} (D2h)"),
    }
}

/// Verify `plan` under a caller-supplied layout — the negative-control
/// hook: inject a corrupted layout (e.g. a shrunk must-zero span via
/// [`ArenaLayout::with_zero_spans`]) and the coverage check must
/// object.
pub fn verify_plan_with_layout(plan: &StreamPlan, layout: &ArenaLayout) -> VerifyReport {
    let n = plan.ops.len();
    let mut hazards: Vec<Hazard> = Vec::new();

    // (3) + (5a): dep edges strictly backwards (topological order ⇒
    // acyclic), broadcast prologue closed before the first task op.
    let mut seen_task = false;
    for (i, op) in plan.ops.iter().enumerate() {
        for &d in &op.deps {
            if d >= i {
                hazards.push(Hazard {
                    kind: HazardKind::InvalidDep,
                    ops: (Some(i), Some(d)),
                    space: None,
                    lo: 0,
                    hi: 0,
                    missing_edge: None,
                    detail: format!(
                        "{} depends on op {d}, which is not an earlier op",
                        op_label(plan, i)
                    ),
                });
            }
        }
        match op.slot {
            Slot::Task(_) => seen_task = true,
            Slot::Broadcast if seen_task => hazards.push(Hazard {
                kind: HazardKind::LateBroadcast,
                ops: (Some(i), None),
                space: None,
                lo: 0,
                hi: 0,
                missing_edge: None,
                detail: format!(
                    "{} is a broadcast after the first task op — the fan-out barrier no longer covers its consumers",
                    op_label(plan, i)
                ),
            }),
            Slot::Broadcast => {}
        }
    }

    // (5b): every access inside its declared buffer / output.
    let accesses = access_records(plan);
    for a in &accesses {
        let declared = match a.space {
            Space::Dev(b) => plan.bufs.get(b).copied(),
            Space::Out(o) => plan.outputs.get(o).copied(),
        };
        match declared {
            Some(size) if a.hi <= size => {}
            Some(size) => hazards.push(Hazard {
                kind: HazardKind::OutOfRange,
                ops: (Some(a.op), None),
                space: Some(a.space),
                lo: a.lo,
                hi: a.hi,
                missing_edge: None,
                detail: format!(
                    "{} accesses bytes past the declared {size}-byte size",
                    op_label(plan, a.op)
                ),
            }),
            None => hazards.push(Hazard {
                kind: HazardKind::OutOfRange,
                ops: (Some(a.op), None),
                space: Some(a.space),
                lo: a.lo,
                hi: a.hi,
                missing_edge: None,
                detail: format!("{} targets an undeclared buffer/output", op_label(plan, a.op)),
            }),
        }
    }

    // The interval analyses below assume a well-formed DAG and
    // in-range records; report structural damage alone if present.
    if hazards.iter().any(|h| matches!(h.kind, HazardKind::InvalidDep | HazardKind::OutOfRange)) {
        return VerifyReport {
            plan: plan.name.clone(),
            ops: n,
            accesses: accesses.len(),
            conflicts: 0,
            hazards,
        };
    }

    // Ancestor closure over the backend dependency contract, as
    // multi-word bitsets (row i = every op with a dependency path to
    // op i).  Wavefront corpus plans exceed 192 ops, so a single u64
    // (the mirror's Python int) does not suffice here.
    let deps = super::backend::native_deps(plan);
    let words = n.div_ceil(64);
    let mut anc = vec![0u64; n * words];
    for i in 0..n {
        // Split so the predecessor rows (disjoint, earlier) stay
        // readable while row i is written.
        let (done, rest) = anc.split_at_mut(i * words);
        let row = &mut rest[..words];
        for &p in &deps[i] {
            let prow = &done[p * words..(p + 1) * words];
            for (r, &w) in row.iter_mut().zip(prow) {
                *r |= w;
            }
            row[p / 64] |= 1 << (p % 64);
        }
    }
    let reaches = |from: usize, to: usize| anc[to * words + from / 64] >> (from % 64) & 1 == 1;
    let ordered = |i: usize, j: usize| reaches(i, j) || reaches(j, i);

    // (1): every overlapping access pair with a write, ordered — the
    // mirror's `native_output_path_check` conflict loop, ported.
    let mut groups: HashMap<Space, Vec<&Access>> = HashMap::new();
    for a in &accesses {
        groups.entry(a.space).or_default().push(a);
    }
    let mut spaces: Vec<&Space> = groups.keys().collect();
    spaces.sort_unstable_by_key(|s| match s {
        Space::Dev(b) => (0, *b),
        Space::Out(o) => (1, *o),
    });
    let mut conflicts = 0usize;
    for space in spaces {
        let accs = &groups[space];
        for (k, a) in accs.iter().enumerate() {
            for b in &accs[k + 1..] {
                if a.op == b.op || (!a.write && !b.write) {
                    continue;
                }
                if a.lo < b.hi && b.lo < a.hi {
                    conflicts += 1;
                    if !ordered(a.op, b.op) {
                        let (from, to) = (a.op.min(b.op), a.op.max(b.op));
                        hazards.push(Hazard {
                            kind: HazardKind::UnorderedRace,
                            ops: (Some(a.op), Some(b.op)),
                            space: Some(*space),
                            lo: a.lo.max(b.lo),
                            hi: a.hi.min(b.hi),
                            missing_edge: Some((from, to)),
                            detail: format!(
                                "{} and {} overlap with a write and no dependency path orders them",
                                op_label(plan, a.op),
                                op_label(plan, b.op)
                            ),
                        });
                    }
                }
            }
        }
    }

    // (2): D2h windows tile each host output exactly once.
    for (o, &size) in plan.outputs.iter().enumerate() {
        let mut wins: Vec<(usize, usize, usize)> = accesses
            .iter()
            .filter(|a| a.write && a.space == Space::Out(o))
            .map(|a| (a.lo, a.hi, a.op))
            .collect();
        wins.sort_unstable();
        let mut pos = 0usize;
        let mut prev_op: Option<usize> = None;
        for &(lo, hi, op) in &wins {
            if lo > pos {
                hazards.push(Hazard {
                    kind: HazardKind::OutputGap,
                    ops: (prev_op, Some(op)),
                    space: Some(Space::Out(o)),
                    lo: pos,
                    hi: lo,
                    missing_edge: None,
                    detail: format!(
                        "no D2h window writes these bytes before {}",
                        op_label(plan, op)
                    ),
                });
            } else if lo < pos {
                hazards.push(Hazard {
                    kind: HazardKind::OutputOverlap,
                    ops: (prev_op, Some(op)),
                    space: Some(Space::Out(o)),
                    lo,
                    hi: hi.min(pos),
                    missing_edge: None,
                    detail: format!("{} re-writes already-tiled bytes", op_label(plan, op)),
                });
            }
            pos = pos.max(hi);
            prev_op = Some(op);
        }
        if pos < size {
            hazards.push(Hazard {
                kind: HazardKind::OutputGap,
                ops: (prev_op, None),
                space: Some(Space::Out(o)),
                lo: pos,
                hi: size,
                missing_edge: None,
                detail: format!("D2h windows cover only {pos} of {size} bytes"),
            });
        }
    }

    // (4): every byte an op reads is either written by an ancestor or
    // guaranteed zero by the arena layout.  Stronger than the layout's
    // own index-order scan: coverage is demanded under the *partial
    // order*, so it also certifies the layout itself (the
    // negative-control hook shrinks a span and this check objects).
    for r in accesses.iter().filter(|a| !a.write) {
        let Space::Dev(buf) = r.space else { continue };
        let mut written: Vec<(usize, usize)> = groups[&r.space]
            .iter()
            .filter(|w| w.write && w.op != r.op && reaches(w.op, r.op))
            .map(|w| (w.lo, w.hi))
            .collect();
        written.sort_unstable();
        let mut cur = r.lo;
        let mut check_zero = |lo: usize, hi: usize, hazards: &mut Vec<Hazard>| {
            if lo < hi && !layout.zero_covers(buf, lo, hi) {
                hazards.push(Hazard {
                    kind: HazardKind::UncoveredRead,
                    ops: (Some(r.op), None),
                    space: Some(r.space),
                    lo,
                    hi,
                    missing_edge: None,
                    detail: format!(
                        "{} reads bytes no ancestor wrote and the arena layout does not zero",
                        op_label(plan, r.op)
                    ),
                });
            }
        };
        for &(lo, hi) in &written {
            if lo > cur {
                check_zero(cur, lo.min(r.hi), &mut hazards);
            }
            cur = cur.max(hi);
            if cur >= r.hi {
                break;
            }
        }
        check_zero(cur, r.hi, &mut hazards);
    }

    VerifyReport { plan: plan.name.clone(), ops: n, accesses: accesses.len(), conflicts, hazards }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::plan::{HostSlice, PlanRegion};
    use std::sync::Arc;

    fn payload(n: usize) -> HostSlice {
        HostSlice::whole(Arc::new(vec![7u8; n]))
    }

    /// One H2d → Kex → D2h chain per lane, disjoint buffers, exact
    /// tiling — the canonical clean shape.
    fn clean_plan(lanes: usize) -> StreamPlan {
        let n = 64;
        let mut p = StreamPlan::new("clean");
        let out = p.output(n * lanes);
        for l in 0..lanes {
            let a = p.buf(n);
            let b = p.buf(n);
            let slot = Slot::Task(l);
            p.h2d(slot, payload(n), PlanRegion::whole(a, n), vec![]);
            let k = p.kex(
                slot,
                "vector_add",
                vec![PlanRegion::whole(a, n), PlanRegion::whole(a, n)],
                vec![PlanRegion::whole(b, n)],
                Some(1),
                1,
                vec![],
            );
            p.d2h(slot, PlanRegion::whole(b, n), out, l * n, vec![k]);
        }
        p
    }

    fn kinds(r: &VerifyReport) -> Vec<HazardKind> {
        r.hazards.iter().map(|h| h.kind).collect()
    }

    #[test]
    fn clean_plan_is_clean() {
        let r = verify_plan(&clean_plan(3));
        assert!(r.is_clean(), "{}", r.summary());
        assert!(r.conflicts > 0, "per-lane RAW/WAR pairs must be discharged, not skipped");
        assert_eq!(r.ops, 9);
    }

    #[test]
    fn unordered_cross_lane_write_is_a_race() {
        // Two lanes H2d into the same region with no ordering: WAW.
        let mut p = StreamPlan::new("waw");
        let b = p.buf(16);
        p.h2d(Slot::Task(0), payload(16), PlanRegion::whole(b, 16), vec![]);
        p.h2d(Slot::Task(1), payload(16), PlanRegion::whole(b, 16), vec![]);
        let r = verify_plan(&p);
        assert!(!r.is_sound());
        let h = &r.hazards[0];
        assert_eq!(h.kind, HazardKind::UnorderedRace);
        assert_eq!(h.ops, (Some(0), Some(1)));
        assert_eq!((h.lo, h.hi), (0, 16));
        assert_eq!(h.missing_edge, Some((0, 1)));
        // The same pair ordered by an explicit dep is race-free (a
        // WAW toward buffer reuse, not a hazard).
        let mut p = StreamPlan::new("waw-ordered");
        let b = p.buf(16);
        p.h2d(Slot::Task(0), payload(16), PlanRegion::whole(b, 16), vec![]);
        p.h2d(Slot::Task(1), payload(16), PlanRegion::whole(b, 16), vec![0]);
        assert!(verify_plan(&p).is_sound());
    }

    #[test]
    fn in_place_kex_is_not_a_self_race() {
        // An op reading and writing the same region races only with
        // *other* ops — mirrors the `i == j` skip in the Python check.
        let mut p = StreamPlan::new("in-place");
        let b = p.buf(64);
        p.h2d(Slot::Task(0), payload(64), PlanRegion::whole(b, 64), vec![]);
        p.kex(
            Slot::Task(0),
            "vector_add",
            vec![PlanRegion::whole(b, 64), PlanRegion::whole(b, 64)],
            vec![PlanRegion::whole(b, 64)],
            Some(1),
            1,
            vec![],
        );
        let r = verify_plan(&p);
        assert!(r.is_clean(), "{}", r.summary());
    }

    #[test]
    fn forward_dep_and_late_broadcast_are_structural_hazards() {
        let mut p = StreamPlan::new("bad-graph");
        let b = p.buf(16);
        p.h2d(Slot::Task(0), payload(16), PlanRegion::whole(b, 16), vec![1]);
        p.h2d(Slot::Broadcast, payload(16), PlanRegion::whole(b, 16), vec![]);
        let r = verify_plan(&p);
        assert!(kinds(&r).contains(&HazardKind::InvalidDep));
        assert!(kinds(&r).contains(&HazardKind::LateBroadcast));
        assert!(!r.is_sound());
    }

    #[test]
    fn out_of_range_access_is_reported() {
        let mut p = StreamPlan::new("oob");
        let b = p.buf(16);
        let out = p.output(8);
        p.d2h(Slot::Task(0), PlanRegion { buf: b, off: 8, len: 16 }, out, 0, vec![]);
        let r = verify_plan(&p);
        assert!(kinds(&r).contains(&HazardKind::OutOfRange));
        assert!(!r.is_sound());
    }

    #[test]
    fn gaps_and_double_writes_in_outputs_are_reported() {
        // Lane-chained D2hs (ordered, so no race): window 2 re-writes
        // window 1's bytes and the tail stays unwritten.
        let mut p = StreamPlan::new("tiling");
        let b = p.buf(64);
        let out = p.output(64);
        p.h2d(Slot::Task(0), payload(64), PlanRegion::whole(b, 64), vec![]);
        p.d2h(Slot::Task(0), PlanRegion { buf: b, off: 0, len: 16 }, out, 0, vec![]);
        p.d2h(Slot::Task(0), PlanRegion { buf: b, off: 0, len: 16 }, out, 8, vec![]);
        let r = verify_plan(&p);
        assert!(kinds(&r).contains(&HazardKind::OutputOverlap));
        assert!(kinds(&r).contains(&HazardKind::OutputGap));
        // Deterministic (ordered) — non-canonical but sound.
        assert!(r.is_sound() && !r.is_clean());
        let overlap = r.hazards.iter().find(|h| h.kind == HazardKind::OutputOverlap).unwrap();
        assert_eq!((overlap.lo, overlap.hi), (8, 16));
    }

    #[test]
    fn zero_source_reads_need_layout_coverage() {
        // A never-written read is fine under the true layout…
        let mut p = StreamPlan::new("zero-src");
        let z = p.buf(32);
        let out = p.output(32);
        p.d2h(Slot::Task(0), PlanRegion::whole(z, 32), out, 0, vec![]);
        let r = verify_plan(&p);
        assert!(r.is_clean(), "{}", r.summary());
        // …and an UncoveredRead under a layout whose span was shrunk
        // (the arena-reuse soundness condition, negative control).
        let layout = ArenaLayout::of(&p).with_zero_spans(vec![(0, 16)]);
        let r = verify_plan_with_layout(&p, &layout);
        let h = r.hazards.iter().find(|h| h.kind == HazardKind::UncoveredRead).expect("hazard");
        assert_eq!((h.lo, h.hi), (16, 32));
        assert!(!r.is_sound());
    }

    #[test]
    fn coverage_respects_the_partial_order_not_index_order() {
        // Lane 1 writes the bytes lane 0 reads, with an explicit edge:
        // the ancestor write covers the read, no zero span needed.
        let mut p = StreamPlan::new("cross-lane-cover");
        let b = p.buf(16);
        let out = p.output(16);
        p.h2d(Slot::Task(1), payload(16), PlanRegion::whole(b, 16), vec![]);
        p.d2h(Slot::Task(0), PlanRegion::whole(b, 16), out, 0, vec![0]);
        assert!(verify_plan(&p).is_clean());
    }

    #[test]
    fn ensure_sound_names_the_op_pair_and_interval() {
        let mut p = StreamPlan::new("named");
        let b = p.buf(16);
        p.h2d(Slot::Task(0), payload(16), PlanRegion::whole(b, 16), vec![]);
        p.h2d(Slot::Task(1), payload(16), PlanRegion::whole(b, 16), vec![]);
        let err = ensure_sound(&p).expect_err("race must refuse").to_string();
        assert!(err.contains("op 0"), "{err}");
        assert!(err.contains("op 1"), "{err}");
        assert!(err.contains("0..16"), "{err}");
        assert!(err.contains("missing edge 0 -> 1"), "{err}");
    }

    #[test]
    fn report_json_round_trips_through_util_json() {
        let mut p = StreamPlan::new("json");
        let b = p.buf(16);
        p.h2d(Slot::Task(0), payload(16), PlanRegion::whole(b, 16), vec![]);
        p.h2d(Slot::Task(1), payload(16), PlanRegion::whole(b, 16), vec![]);
        let v = crate::util::json::Json::parse(&verify_plan(&p).to_json()).expect("valid JSON");
        assert_eq!(v.get("sound").and_then(|b| b.as_bool()), Some(false));
        let hazards = v.get("hazards").and_then(|h| h.as_arr()).expect("array");
        assert_eq!(hazards.len(), 1);
    }
}
