//! Embarrassingly-independent partitioning (paper Fig. 6).
//!
//! The input is cut into contiguous chunks; chunk *i* is task *i*, and
//! tasks share no data, so any assignment of tasks to streams is legal.

/// One contiguous element range `[start, start + len)`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ChunkRange {
    pub index: usize,
    pub start: usize,
    pub len: usize,
}

/// Split `total` elements into `chunks` contiguous ranges.  Every range
/// gets `total / chunks` elements; the remainder spreads one element at
/// a time over the leading ranges (so lengths differ by at most one and
/// the union is exact — a proptest invariant).
pub fn chunk_ranges(total: usize, chunks: usize) -> Vec<ChunkRange> {
    assert!(chunks > 0, "need at least one chunk");
    let base = total / chunks;
    let rem = total % chunks;
    let mut out = Vec::with_capacity(chunks);
    let mut start = 0;
    for i in 0..chunks {
        let len = base + usize::from(i < rem);
        out.push(ChunkRange { index: i, start, len });
        start += len;
    }
    debug_assert_eq!(start, total);
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exact_cover() {
        let rs = chunk_ranges(100, 7);
        assert_eq!(rs.len(), 7);
        assert_eq!(rs.iter().map(|r| r.len).sum::<usize>(), 100);
        // contiguous, ordered, non-overlapping
        let mut pos = 0;
        for r in &rs {
            assert_eq!(r.start, pos);
            pos += r.len;
        }
    }

    #[test]
    fn lengths_differ_by_at_most_one() {
        let rs = chunk_ranges(103, 8);
        let min = rs.iter().map(|r| r.len).min().unwrap();
        let max = rs.iter().map(|r| r.len).max().unwrap();
        assert!(max - min <= 1);
    }

    #[test]
    fn more_chunks_than_elements() {
        let rs = chunk_ranges(3, 5);
        assert_eq!(rs.iter().map(|r| r.len).sum::<usize>(), 3);
        assert_eq!(rs.iter().filter(|r| r.len == 0).count(), 2);
    }
}
