//! True-dependent partitioning: wavefront / diagonal scheduling (Fig. 8).
//!
//! For a DP recurrence where tile (i, j) needs (i-1, j), (i, j-1) and
//! (i-1, j-1), tiles are numbered diagonal-by-diagonal from the top-left
//! corner; tiles on the same diagonal are mutually independent and run
//! concurrently in different streams, while diagonals execute in order
//! — "the number of streams changes on different diagonals".

/// Tile position in the block grid.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct TileCoord {
    pub bi: usize,
    pub bj: usize,
}

/// One anti-diagonal: tiles that may run concurrently.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Diagonal {
    pub index: usize,
    pub tiles: Vec<TileCoord>,
}

/// Enumerate the anti-diagonals of an `rows x cols` tile grid, top-left
/// to bottom-right.
pub fn diagonals(rows: usize, cols: usize) -> Vec<Diagonal> {
    let mut out = Vec::with_capacity(rows + cols - 1);
    for d in 0..rows + cols - 1 {
        let mut tiles = Vec::new();
        // bi ranges so that bj = d - bi stays inside the grid.
        let bi_lo = d.saturating_sub(cols - 1);
        let bi_hi = d.min(rows - 1);
        for bi in bi_lo..=bi_hi {
            tiles.push(TileCoord { bi, bj: d - bi });
        }
        out.push(Diagonal { index: d, tiles });
    }
    out
}

/// All tile coordinates in wavefront order (flattened diagonals) — a
/// topological order of the dependency DAG, which is what the FIFO
/// engine queues require.
pub fn tile_coords(rows: usize, cols: usize) -> Vec<TileCoord> {
    diagonals(rows, cols).into_iter().flat_map(|d| d.tiles).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::collections::HashSet;

    #[test]
    fn diagonal_counts_grow_then_shrink() {
        let ds = diagonals(3, 3);
        let sizes: Vec<usize> = ds.iter().map(|d| d.tiles.len()).collect();
        assert_eq!(sizes, vec![1, 2, 3, 2, 1], "paper Fig. 8: stream count varies per diagonal");
    }

    #[test]
    fn covers_every_tile_once() {
        let coords = tile_coords(4, 6);
        assert_eq!(coords.len(), 24);
        let set: HashSet<_> = coords.iter().cloned().collect();
        assert_eq!(set.len(), 24);
    }

    #[test]
    fn wavefront_order_is_topological() {
        // Every tile's predecessors appear earlier in the flat order.
        let coords = tile_coords(5, 4);
        let pos = |c: &TileCoord| coords.iter().position(|x| x == c).unwrap();
        for c in &coords {
            if c.bi > 0 {
                assert!(pos(&TileCoord { bi: c.bi - 1, bj: c.bj }) < pos(c));
            }
            if c.bj > 0 {
                assert!(pos(&TileCoord { bi: c.bi, bj: c.bj - 1 }) < pos(c));
            }
            if c.bi > 0 && c.bj > 0 {
                assert!(pos(&TileCoord { bi: c.bi - 1, bj: c.bj - 1 }) < pos(c));
            }
        }
    }

    #[test]
    fn rectangular_grids() {
        let ds = diagonals(2, 5);
        let sizes: Vec<usize> = ds.iter().map(|d| d.tiles.len()).collect();
        assert_eq!(sizes, vec![1, 2, 2, 2, 2, 1]);
        assert_eq!(sizes.iter().sum::<usize>(), 10);
    }
}
