//! False-dependent partitioning: redundant boundary transfer (Fig. 7).
//!
//! RAR-shared elements are *eliminated* by shipping each task its chunk
//! plus the `halo` boundary elements its stencil reads.  The transfer
//! window is clamped at the array ends (callers pre-pad when the kernel
//! expects a fixed halo, as the AOT chunk shapes do).

/// One halo task: it *owns* `[start, start+len)` of the output but
/// *transfers* `[xfer_start, xfer_start + xfer_len)` of the (pre-padded)
/// input.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct HaloChunk {
    pub index: usize,
    /// Owned output range (in unpadded coordinates).
    pub start: usize,
    pub len: usize,
    /// Transferred input range (in *padded* coordinates: the caller pads
    /// the input with `halo` elements on each side, so task `i`'s window
    /// is `start .. start + len + 2*halo`).
    pub xfer_start: usize,
    pub xfer_len: usize,
}

/// Cut `total` output elements into `chunks` halo tasks with radius
/// `halo`, against an input pre-padded by `halo` on each side.
pub fn halo_chunks(total: usize, chunks: usize, halo: usize) -> Vec<HaloChunk> {
    super::independent::chunk_ranges(total, chunks)
        .into_iter()
        .map(|r| HaloChunk {
            index: r.index,
            start: r.start,
            len: r.len,
            // Padded input coordinates: owned start maps to start+halo;
            // the window begins `halo` earlier, i.e. at `start`.
            xfer_start: r.start,
            xfer_len: r.len + 2 * halo,
        })
        .collect()
}

/// The paper's lavaMD analysis (§5): redundant boundary bytes per task
/// relative to owned bytes.  Streaming a false-dependent code pays off
/// when this ratio is small (FWT: 254/1048576 ≈ 0); it fails when the
/// boundary is comparable to the task (lavaMD: 222/250 ≈ 0.9).
pub fn halo_overhead_ratio(chunk_len: usize, halo: usize) -> f64 {
    if chunk_len == 0 {
        return f64::INFINITY;
    }
    (2 * halo) as f64 / chunk_len as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn windows_cover_owned_plus_halo() {
        let halo = 3;
        let cs = halo_chunks(100, 4, halo);
        assert_eq!(cs.len(), 4);
        for c in &cs {
            assert_eq!(c.xfer_len, c.len + 2 * halo);
            assert_eq!(c.xfer_start, c.start);
        }
        // Owned ranges tile the output exactly.
        assert_eq!(cs.iter().map(|c| c.len).sum::<usize>(), 100);
    }

    #[test]
    fn overhead_ratio_matches_paper_cases() {
        // FWT: boundary 254 elements, task 1048576 -> negligible.
        assert!(halo_overhead_ratio(1_048_576, 127) < 0.001);
        // lavaMD: boundary 222, task 250 -> ~0.9: streaming won't pay.
        let r = halo_overhead_ratio(250, 111);
        assert!(r > 0.8, "lavaMD halo ratio {r}");
    }
}
