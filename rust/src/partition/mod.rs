//! Task partitioning — the paper's three streaming transformations.
//!
//! Tasks are generated from input/output data partitioning; task
//! dependency therefore shows up as data dependency (§4.2):
//!
//! - [`independent`]: *Embarrassingly Independent* — disjoint chunks, no
//!   inter-task data (Fig. 6, nn).
//! - [`halo`]: *False Dependent* — RAR overlap eliminated by redundantly
//!   transferring boundary elements with each task (Fig. 7, FWT), plus
//!   the overhead accounting that predicts the lavaMD negative case.
//! - [`wavefront`]: *True Dependent* — RAW dependencies respected by
//!   diagonal ordering; tasks on one diagonal run concurrently in
//!   different streams (Fig. 8, NW).

pub mod halo;
pub mod independent;
pub mod wavefront;

pub use halo::{halo_chunks, halo_overhead_ratio, HaloChunk};
pub use independent::{chunk_ranges, ChunkRange};
pub use wavefront::{diagonals, tile_coords, Diagonal, TileCoord};
