//! Empirical CDF — the Fig. 1 statistical view of R over the corpus.

/// One CDF point: `fraction` of the sample is ≤ `value`.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct CdfPoint {
    pub value: f64,
    pub fraction: f64,
}

/// Build the empirical CDF of a sample (sorted ascending).
pub fn cdf_points(values: &[f64]) -> Vec<CdfPoint> {
    let mut sorted = values.to_vec();
    sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let n = sorted.len() as f64;
    sorted
        .iter()
        .enumerate()
        .map(|(i, &v)| CdfPoint { value: v, fraction: (i + 1) as f64 / n })
        .collect()
}

/// Fraction of the sample with value ≤ `x` — e.g. the paper's headline
/// "the CDF is over 50% when R_H2D = 0.1".
pub fn fraction_at_or_below(values: &[f64], x: f64) -> f64 {
    if values.is_empty() {
        return 0.0;
    }
    values.iter().filter(|&&v| v <= x).count() as f64 / values.len() as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cdf_monotone_and_normalized() {
        let pts = cdf_points(&[0.3, 0.1, 0.2, 0.4]);
        assert_eq!(pts.len(), 4);
        assert!((pts.last().unwrap().fraction - 1.0).abs() < 1e-12);
        for w in pts.windows(2) {
            assert!(w[0].value <= w[1].value);
            assert!(w[0].fraction <= w[1].fraction);
        }
    }

    #[test]
    fn fraction_below_threshold() {
        let vs = [0.05, 0.08, 0.15, 0.5, 0.9];
        assert_eq!(fraction_at_or_below(&vs, 0.1), 0.4);
        assert_eq!(fraction_at_or_below(&vs, 1.0), 1.0);
        assert_eq!(fraction_at_or_below(&vs, 0.0), 0.0);
    }
}
