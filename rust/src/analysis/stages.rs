//! Stage-by-stage measurement (§3.3): run H2D, KEX, D2H strictly
//! separated, 11 runs, median per stage, and compute R.
//!
//! Descriptor-backed corpus entries realize KEX with the calibrated
//! `burner` kernel under a FLOP override, so all 223 configurations flow
//! through the *same* engines, allocator and pacing as the real
//! benchmarks — R keeps its shape (DESIGN.md §2).

use std::sync::Arc;
use std::time::Duration;

use crate::device::DevRegion;
use crate::hstreams::Context;
use crate::metrics::median_duration;

/// One kernel execution in the KEX stage.
#[derive(Debug, Clone)]
pub struct KexCall {
    /// Artifact name (usually a burner variant for corpus entries).
    pub artifact: String,
    /// FLOP budget driving the pacing for this call.
    pub flops: u64,
    /// Back-to-back repetitions (iterative kernels).
    pub repeats: u32,
}

/// A stage-by-stage measurable offload: what moves in, what runs, what
/// moves out.  Derivable from any lowered workload via
/// [`crate::plan::StreamPlan::offload_spec`], so the measurement
/// protocol consumes the same IR the executor runs.
#[derive(Debug, Clone)]
pub struct OffloadSpec {
    pub name: String,
    /// Byte sizes of the host→device payloads.
    pub h2d: Vec<usize>,
    /// Kernel executions.
    pub kex: Vec<KexCall>,
    /// Byte sizes of the device→host payloads.
    pub d2h: Vec<usize>,
}

/// Median stage durations of an offload.
#[derive(Debug, Clone, Copy)]
pub struct StageTimes {
    pub h2d: Duration,
    pub kex: Duration,
    pub d2h: Duration,
}

impl StageTimes {
    pub fn total(&self) -> Duration {
        self.h2d + self.kex + self.d2h
    }

    /// The paper's R: fraction of H2D in the end-to-end time.
    pub fn r_h2d(&self) -> f64 {
        self.h2d.as_secs_f64() / self.total().as_secs_f64()
    }

    /// D2H fraction (the second Fig. 1 series).
    pub fn r_d2h(&self) -> f64 {
        self.d2h.as_secs_f64() / self.total().as_secs_f64()
    }

    /// KEX fraction (Fig. 4 quotes this for nn).
    pub fn r_kex(&self) -> f64 {
        self.kex.as_secs_f64() / self.total().as_secs_f64()
    }
}

/// Measure an offload stage-by-stage on `ctx`: `runs` repetitions,
/// median per stage (the paper's protocol, §3.3).
///
/// Device buffers for the raw payloads are re-allocated every run so the
/// lazy-allocation cost lands inside H2D each time, exactly like the
/// paper's measurement ("the allocation overhead is often counted into
/// H2D").  Kernel scratch buffers are staged once, untimed.
pub fn measure_stages(ctx: &Context, spec: &OffloadSpec, runs: usize) -> StageTimes {
    // Untimed: stage the kernel scratch (inputs must exist and match the
    // artifact signatures; shapes come from the manifest).
    let manifest = crate::runtime::Manifest::load(&crate::artifacts_dir()).expect("manifest");
    let mut scratch: Vec<(String, Vec<DevRegion>, Vec<DevRegion>)> = Vec::new();
    {
        let mut stream = ctx.stream();
        for call in &spec.kex {
            let meta = manifest.get(&call.artifact).expect("artifact in manifest");
            let mut ins = Vec::new();
            for io in &meta.inputs {
                let buf = ctx.alloc(io.bytes()).expect("scratch alloc");
                let region = DevRegion::whole(buf, io.bytes());
                // Touch with zeros so lazy-alloc cost stays out of KEX.
                let payload = Arc::new(vec![0u8; io.bytes()]);
                stream.h2d(crate::device::HostSrc::whole(payload), region);
                ins.push(region);
            }
            let outs = meta
                .outputs
                .iter()
                .map(|io| {
                    let buf = ctx.alloc(io.bytes()).expect("scratch alloc");
                    DevRegion::whole(buf, io.bytes())
                })
                .collect();
            scratch.push((call.artifact.clone(), ins, outs));
        }
        stream.sync();
    }

    let h2d_payloads: Vec<Arc<Vec<u8>>> =
        spec.h2d.iter().map(|&n| Arc::new(vec![0x5au8; n])).collect();

    let mut h2d_samples = Vec::with_capacity(runs);
    let mut kex_samples = Vec::with_capacity(runs);
    let mut d2h_samples = Vec::with_capacity(runs);

    for _ in 0..runs {
        // Fresh buffers each run: lazy allocation charges into H2D.
        let in_bufs: Vec<DevRegion> = spec
            .h2d
            .iter()
            .map(|&n| DevRegion::whole(ctx.alloc(n).expect("h2d alloc"), n))
            .collect();
        let out_bufs: Vec<DevRegion> = spec
            .d2h
            .iter()
            .map(|&n| DevRegion::whole(ctx.alloc(n).expect("d2h alloc"), n))
            .collect();

        // Each stage's duration is the timeline makespan of its ops —
        // exact under TimeMode::Virtual, measured under Wallclock.

        // --- H2D stage ---
        {
            let mut s = ctx.stream();
            for (payload, region) in h2d_payloads.iter().zip(&in_bufs) {
                s.h2d(crate::device::HostSrc::whole(payload.clone()), *region);
            }
            s.sync();
            h2d_samples.push(crate::hstreams::makespan(s.events()));
        }

        // --- KEX stage ---
        {
            let mut s = ctx.stream();
            for (call, (artifact, ins, outs)) in spec.kex.iter().zip(&scratch) {
                s.kex_with(
                    artifact.clone(),
                    ins.clone(),
                    outs.clone(),
                    Some(call.flops),
                    call.repeats,
                );
            }
            s.sync();
            kex_samples.push(crate::hstreams::makespan(s.events()));
        }

        // --- D2H stage ---
        {
            let mut s = ctx.stream();
            for region in &out_bufs {
                let dst = crate::hstreams::host_dst(region.len);
                s.d2h(*region, dst);
            }
            s.sync();
            d2h_samples.push(crate::hstreams::makespan(s.events()));
        }

        for r in in_bufs.iter().chain(&out_bufs) {
            ctx.free(r.buf).expect("free");
        }
    }

    // Free scratch.
    for (_, ins, outs) in &scratch {
        for r in ins.iter().chain(outs) {
            let _ = ctx.free(r.buf);
        }
    }

    StageTimes {
        h2d: median_duration(&mut h2d_samples),
        kex: median_duration(&mut kex_samples),
        d2h: median_duration(&mut d2h_samples),
    }
}
