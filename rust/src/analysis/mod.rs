//! The paper's analysis machinery: stage-by-stage *R* measurement
//! (§3.3–3.4), the CDF view (Fig. 1), the streaming-necessity decision
//! rule (§3.4/§6), and the Table-2 dependency categorizer (§4.1).

mod autotune;
mod categorize;
mod cdf;
mod decision;
mod learned;
mod stages;

pub use autotune::{
    analytic_corpus_choice, analytic_corpus_seed, autotune_plan, autotune_plan_pruned,
    autotune_streams, autotune_workload, gran_ladder, normalize_ladder, predict_plan_cost_ms,
    predict_plan_point, predict_streams, predict_streams_for_plan, snap_seed, AutotuneResult,
    PlanTuneResult, GRAN_CEILING,
};
pub(crate) use autotune::argmin;
pub use learned::{
    corpus_features, Dataset, KnnTuner, PlanFeatures, TrainRow, DEFAULT_K, FEATURE_NAMES,
};
pub use categorize::{categorize, Category, DependencyFacts, TaskDep};
pub use cdf::{cdf_points, fraction_at_or_below, CdfPoint};
pub use decision::{decide, decide_plan, Decision, HI_THRESHOLD, LO_THRESHOLD};
pub use stages::{measure_stages, KexCall, OffloadSpec, StageTimes};
