//! The streaming-necessity decision rule (§3.4, §6).
//!
//! "Applications are not worthwhile to be streamed when R is small"
//! (pipeline fill/drain + programming effort exceed the win) and "when
//! R is too large (e.g. 90%) it is equally not worthwhile" (offloading
//! itself is questionable, never mind streams).

/// Below this R, streaming overheads swamp the achievable overlap.
pub const LO_THRESHOLD: f64 = 0.10;
/// Above this R, using the accelerator at all is questionable.
pub const HI_THRESHOLD: f64 = 0.90;

/// Outcome of the necessity analysis for one (benchmark, config).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Decision {
    /// R < 0.10: transfers are a rounding error; keep single stream.
    NotWorthLowR,
    /// Streaming is expected to pay off.
    Worthwhile,
    /// R > 0.90: reconsider offloading before considering streams.
    NotWorthHighR,
}

/// Apply the paper's rule to a lowered plan on a given device: R comes
/// from the plan's own byte/FLOP annotations.
pub fn decide_plan(
    plan: &crate::plan::StreamPlan,
    profile: &crate::device::DeviceProfile,
) -> Decision {
    decide(plan.stage_times(profile).r_h2d())
}

/// Apply the paper's rule to a measured R.
pub fn decide(r: f64) -> Decision {
    if r < LO_THRESHOLD {
        Decision::NotWorthLowR
    } else if r > HI_THRESHOLD {
        Decision::NotWorthHighR
    } else {
        Decision::Worthwhile
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn thresholds() {
        assert_eq!(decide(0.05), Decision::NotWorthLowR);
        assert_eq!(decide(0.10), Decision::Worthwhile);
        assert_eq!(decide(0.5), Decision::Worthwhile);
        assert_eq!(decide(0.90), Decision::Worthwhile);
        assert_eq!(decide(0.95), Decision::NotWorthHighR);
    }
}
