//! Stream-count auto-tuning — the paper's §6 future work ("we will
//! further investigate how to get optimal performance by setting a
//! proper task and/or resource granularity … autotune these
//! parameters").
//!
//! Two strategies:
//!
//! - [`predict_streams`] — zero-cost analytic rule from the stage
//!   balance: with one DMA lane per direction and one kernel queue, the
//!   pipeline saturates once every lane is busy, so the useful stream
//!   count is ⌈serial / bottleneck⌉ (+1 fill margin), clamped to [2, 8].
//! - [`autotune_streams`] — empirical: measure a candidate ladder and
//!   return the argmin (the paper's "leveraging machine learning" is a
//!   measured search here — exact, since the space is tiny).

use crate::hstreams::Context;
use crate::workloads::{Benchmark, Mode};
use crate::Result;

use super::stages::StageTimes;

/// Analytic stream-count suggestion straight from a lowered plan: the
/// IR's byte/FLOP annotations give the stage balance without running
/// anything (the per-plan features the ML-tuning line needs).
pub fn predict_streams_for_plan(
    plan: &crate::plan::StreamPlan,
    profile: &crate::device::DeviceProfile,
) -> usize {
    predict_streams(&plan.stage_times(profile))
}

/// Analytic stream-count suggestion from a stage-by-stage measurement.
pub fn predict_streams(st: &StageTimes) -> usize {
    let total = st.total().as_secs_f64();
    let bottleneck = st.h2d.as_secs_f64().max(st.kex.as_secs_f64()).max(st.d2h.as_secs_f64());
    if bottleneck <= 0.0 {
        return 2;
    }
    let depth = (total / bottleneck).ceil() as usize + 1;
    depth.clamp(2, 8)
}

/// Result of an empirical sweep.
#[derive(Debug, Clone)]
pub struct AutotuneResult {
    pub best_streams: usize,
    pub best_ms: f64,
    /// (streams, median ms) for every candidate tried.
    pub ladder: Vec<(usize, f64)>,
}

/// Measure `bench` at each candidate stream count (median of `runs`)
/// and return the fastest.
pub fn autotune_streams(
    ctx: &Context,
    bench: &dyn Benchmark,
    candidates: &[usize],
    runs: usize,
) -> Result<AutotuneResult> {
    // Warmup (absorb PJRT first-execution cost).
    bench.run(ctx, Mode::Streamed(candidates[0]))?;
    let mut ladder = Vec::with_capacity(candidates.len());
    for &n in candidates {
        let mut samples = Vec::with_capacity(runs);
        for _ in 0..runs {
            let r = bench.run(ctx, Mode::Streamed(n))?;
            if !r.validated {
                return Err(crate::Error::Stream(format!(
                    "{} failed validation at {n} streams",
                    bench.name()
                )));
            }
            samples.push(r.wall);
        }
        let med = crate::metrics::median_duration(&mut samples).as_secs_f64() * 1e3;
        ladder.push((n, med));
    }
    let (best_streams, best_ms) =
        ladder.iter().copied().min_by(|a, b| a.1.partial_cmp(&b.1).unwrap()).unwrap();
    Ok(AutotuneResult { best_streams, best_ms, ladder })
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    fn st(h2d: u64, kex: u64, d2h: u64) -> StageTimes {
        StageTimes {
            h2d: Duration::from_millis(h2d),
            kex: Duration::from_millis(kex),
            d2h: Duration::from_millis(d2h),
        }
    }

    #[test]
    fn balanced_stages_want_deep_pipelines() {
        // Three equal stages: serial/bottleneck = 3 -> 4 streams.
        assert_eq!(predict_streams(&st(10, 10, 10)), 4);
    }

    #[test]
    fn kex_dominated_needs_few_streams() {
        // KEX is 90%: overlap headroom is small -> shallow pipeline.
        assert_eq!(predict_streams(&st(5, 90, 5)), 3);
    }

    #[test]
    fn prediction_clamped() {
        assert!(predict_streams(&st(1, 1000, 1)) >= 2);
        assert!(predict_streams(&st(1, 1, 1)) <= 8);
    }
}
