//! Joint (streams × task-granularity) plan auto-tuning — the paper's
//! §6 future work ("we will further investigate how to get optimal
//! performance by setting a proper task and/or resource granularity …
//! autotune these parameters").
//!
//! Three strategies, from free to exact:
//!
//! - [`predict_streams`] — zero-cost analytic rule from the stage
//!   balance: with one DMA lane per direction and one kernel queue, the
//!   pipeline saturates once every lane is busy, so the useful stream
//!   count is ⌈serial / bottleneck⌉ (+1 fill margin), clamped to [2, 8].
//! - [`predict_plan_point`] — the joint analytic seed over a lowered
//!   [`StreamPlan`]: stream count as above, task granularity from the
//!   fill/drain-vs-overhead balance `m* = √(overlappable / c_task)`
//!   where `overlappable` is the serial time outside the bottleneck
//!   stage and `c_task` the per-task fixed cost of the bottleneck lane
//!   (DMA latency for transfer-bound plans, launch overhead for
//!   compute-bound) — finer tasks shrink pipeline fill/drain by
//!   `overlappable/m` but add `m·c_task` of fixed overhead.
//! - [`autotune_streams`] / [`autotune_plan`] — empirical: measure a
//!   candidate ladder (or the full streams × granularity grid, each
//!   point re-lowered and validated bitwise against the bulk
//!   reference) under the virtual clock and return the argmin.  The
//!   paper's "leveraging machine learning" is a measured search here —
//!   exact, since the space is tiny and the clock is deterministic.
//!
//! Tuning paths are panic-safe: empty candidate ladders are
//! [`crate::Error::Stream`] errors (not index panics) and argmin
//! comparisons use `f64::total_cmp` (a NaN median cannot crash the
//! search).

use crate::hstreams::Context;
use crate::plan::{outputs_match, Executor, Granularity, StreamPlan};
use crate::workloads::{Benchmark, Mode};
use crate::{Error, Result};

use super::stages::StageTimes;

/// Analytic stream-count suggestion straight from a lowered plan: the
/// IR's byte/FLOP annotations give the stage balance without running
/// anything (the per-plan features the ML-tuning line needs).
pub fn predict_streams_for_plan(
    plan: &StreamPlan,
    profile: &crate::device::DeviceProfile,
) -> usize {
    predict_streams(&plan.stage_times(profile))
}

/// Analytic stream-count suggestion from a stage-by-stage measurement.
pub fn predict_streams(st: &StageTimes) -> usize {
    let total = st.total().as_secs_f64();
    let bottleneck = st.h2d.as_secs_f64().max(st.kex.as_secs_f64()).max(st.d2h.as_secs_f64());
    if bottleneck <= 0.0 {
        return 2;
    }
    let depth = (total / bottleneck).ceil() as usize + 1;
    depth.clamp(2, 8)
}

/// Joint analytic seed `(streams, granularity)` for a lowered plan —
/// the grid point the measured search grows around (module docs).
/// The granularity is a **pipeline task count**; callers tuning a
/// lowering whose knob is in other units (a wavefront's grid side)
/// must map it (e.g. `√tasks`) before building a candidate ladder —
/// `experiments::tune_corpus` does.
pub fn predict_plan_point(
    plan: &StreamPlan,
    profile: &crate::device::DeviceProfile,
) -> (usize, usize) {
    let st = plan.stage_times(profile);
    let streams = predict_streams(&st);
    let (h2d, kex, d2h) = (st.h2d.as_secs_f64(), st.kex.as_secs_f64(), st.d2h.as_secs_f64());
    let bottleneck = h2d.max(kex).max(d2h);
    // Per-task fixed cost of the bottleneck lane.
    let c_task = if bottleneck == kex { profile.launch_us } else { profile.latency_us } * 1e-6;
    let overlappable = (h2d + kex + d2h) - bottleneck;
    let gran = if c_task > 0.0 && overlappable > 0.0 {
        ((overlappable / c_task).sqrt().round() as usize).clamp(1, 64)
    } else {
        streams
    };
    // At least one task per stream, or the pipeline can't fill.
    (streams, gran.max(streams))
}

/// Result of an empirical stream-count sweep.
#[derive(Debug, Clone)]
pub struct AutotuneResult {
    pub best_streams: usize,
    pub best_ms: f64,
    /// (streams, median ms) for every candidate tried.
    pub ladder: Vec<(usize, f64)>,
}

/// Measure `bench` at each candidate stream count (median of `runs`)
/// and return the fastest.  Errors (never panics) on an empty ladder.
pub fn autotune_streams(
    ctx: &Context,
    bench: &dyn Benchmark,
    candidates: &[usize],
    runs: usize,
) -> Result<AutotuneResult> {
    if candidates.is_empty() {
        return Err(Error::Stream(format!(
            "autotune {}: empty stream-candidate ladder",
            bench.name()
        )));
    }
    let runs = runs.max(1);
    // Warmup (absorb PJRT first-execution cost).
    bench.run(ctx, Mode::Streamed(candidates[0]))?;
    let mut ladder = Vec::with_capacity(candidates.len());
    for &n in candidates {
        let mut samples = Vec::with_capacity(runs);
        for _ in 0..runs {
            let r = bench.run(ctx, Mode::Streamed(n))?;
            if !r.validated {
                return Err(Error::Stream(format!(
                    "{} failed validation at {n} streams",
                    bench.name()
                )));
            }
            samples.push(r.wall);
        }
        let med = crate::metrics::median_duration(&mut samples).as_secs_f64() * 1e3;
        ladder.push((n, med));
    }
    let (best_streams, best_ms) = argmin(ladder.iter().copied()).expect("non-empty ladder");
    Ok(AutotuneResult { best_streams, best_ms, ladder })
}

/// Result of a joint (streams × granularity) grid search.  Seeds are
/// the caller's concern ([`predict_plan_point`] + the lowering's knob
/// mapping — see `experiments::tune_corpus`), not duplicated here.
#[derive(Debug, Clone)]
pub struct PlanTuneResult {
    pub best_streams: usize,
    pub best_gran: usize,
    pub best_ms: f64,
    /// Bulk (non-streamed) reference makespan, ms.
    pub bulk_ms: f64,
    /// (streams, granularity, median ms) for every measured grid point
    /// (stream counts normalized to ≥ 1 and deduped, ascending).
    pub surface: Vec<(usize, usize, f64)>,
}

/// Measure the full (streams × granularity) grid of a re-lowerable
/// workload and return the argmin plus the whole surface.
///
/// `bulk` is the single-offload reference plan; every grid point is
/// re-lowered via `lower`, executed under the context's clock, and its
/// assembled outputs validated **bitwise** against the bulk run —
/// granularity must move when bytes travel, never what the result
/// holds.  A divergence or an empty ladder is an error, never a panic.
///
/// Candidates are measured exactly as given: if the lowering clamps
/// the knob (tile-grid sides, per-lane minimums), map the ladder
/// through the effective values and dedupe first — e.g. via
/// `plan::effective_corpus_granularity`, as `experiments::tune_corpus`
/// does — or aliased points are measured twice under two labels.
pub fn autotune_plan(
    ctx: &Context,
    bulk: &StreamPlan,
    lower: &dyn Fn(Granularity) -> StreamPlan,
    streams: &[usize],
    grans: &[usize],
    runs: usize,
) -> Result<PlanTuneResult> {
    if streams.is_empty() || grans.is_empty() {
        return Err(Error::Stream(format!(
            "autotune {}: empty (streams × granularity) candidate grid",
            bulk.name
        )));
    }
    let runs = runs.max(1);
    // Normalize stream counts to what the executor actually maps (≥ 1)
    // and dedupe, so the surface never labels a point with a stream
    // count that doesn't exist (e.g. --ladder 0,1 aliasing 1 twice).
    let streams: Vec<usize> = {
        let mut v: Vec<usize> = streams.iter().map(|&n| n.max(1)).collect();
        v.sort_unstable();
        v.dedup();
        v
    };
    let exec = Executor::new(ctx);
    // Bulk reference: same median-of-runs methodology as every grid
    // point (one wallclock outlier must not skew all the comparisons);
    // the first run's outputs serve as the bitwise oracle.
    let reference = exec.run(bulk, 1)?;
    let mut bulk_samples = vec![reference.wall];
    for _ in 1..runs {
        bulk_samples.push(exec.run(bulk, 1)?.wall);
    }
    let bulk_ms = crate::metrics::median_duration(&mut bulk_samples).as_secs_f64() * 1e3;

    let mut surface = Vec::with_capacity(streams.len() * grans.len());
    for &g in grans {
        let plan = lower(Granularity::new(g));
        plan.validate()?;
        for &n in &streams {
            let mut samples = Vec::with_capacity(runs);
            for i in 0..runs {
                let r = exec.run(&plan, n)?;
                // Outputs are a pure function of (plan, bytes), not of
                // the clock: one bitwise check per grid point suffices,
                // repetitions only re-sample the timing.
                if i == 0 && !outputs_match(&reference, &r) {
                    return Err(Error::Stream(format!(
                        "{}: outputs diverge from bulk at {n} streams × granularity {g}",
                        plan.name
                    )));
                }
                samples.push(r.wall);
            }
            let med = crate::metrics::median_duration(&mut samples).as_secs_f64() * 1e3;
            surface.push((n, g, med));
        }
    }
    let ((best_streams, best_gran), best_ms) =
        argmin(surface.iter().map(|&(n, g, ms)| ((n, g), ms))).expect("non-empty grid");
    Ok(PlanTuneResult { best_streams, best_gran, best_ms, bulk_ms, surface })
}

/// Granularity candidate ladder grown around an analytic seed: the
/// usual powers of two plus the seed's neighbourhood, sorted, deduped.
pub fn gran_ladder(seed: usize) -> Vec<usize> {
    let s = seed.clamp(1, 64);
    let mut v = vec![1, 2, 4, 8, 16, (s / 2).max(1), s, (s * 2).min(64)];
    v.sort_unstable();
    v.dedup();
    v
}

/// First-seen argmin under `f64::total_cmp` (NaN orders above every
/// real time, so a poisoned sample can never win or crash the search);
/// `None` on an empty iterator.  Shared by every tuning/sweep argmin
/// so tie-breaks agree across tables (first-seen = smallest candidate
/// when ladders are ascending).
pub(crate) fn argmin<K: Copy>(points: impl IntoIterator<Item = (K, f64)>) -> Option<(K, f64)> {
    let mut best: Option<(K, f64)> = None;
    for (k, v) in points {
        match &best {
            Some((_, b)) if v.total_cmp(b) != std::cmp::Ordering::Less => {}
            _ => best = Some((k, v)),
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    fn st(h2d: u64, kex: u64, d2h: u64) -> StageTimes {
        StageTimes {
            h2d: Duration::from_millis(h2d),
            kex: Duration::from_millis(kex),
            d2h: Duration::from_millis(d2h),
        }
    }

    #[test]
    fn balanced_stages_want_deep_pipelines() {
        // Three equal stages: serial/bottleneck = 3 -> 4 streams.
        assert_eq!(predict_streams(&st(10, 10, 10)), 4);
    }

    #[test]
    fn kex_dominated_needs_few_streams() {
        // KEX is 90%: overlap headroom is small -> shallow pipeline.
        assert_eq!(predict_streams(&st(5, 90, 5)), 3);
    }

    #[test]
    fn prediction_clamped() {
        assert!(predict_streams(&st(1, 1000, 1)) >= 2);
        assert!(predict_streams(&st(1, 1, 1)) <= 8);
    }

    #[test]
    fn argmin_is_nan_safe_and_first_seen() {
        let pts = [(1usize, f64::NAN), (2, 3.0), (3, 1.0), (4, 1.0)];
        let (k, v) = argmin(pts.iter().copied()).expect("non-empty");
        assert_eq!(k, 3, "ties keep the first-seen point");
        assert_eq!(v, 1.0);
        assert!(argmin(std::iter::empty::<((), f64)>()).is_none());
        // All-NaN still returns a point rather than panicking.
        assert_eq!(argmin([(7usize, f64::NAN)].into_iter()).map(|p| p.0), Some(7));
    }

    #[test]
    fn gran_ladder_contains_seed_and_defaults() {
        let l = gran_ladder(11);
        assert!(l.contains(&1) && l.contains(&8) && l.contains(&11) && l.contains(&22));
        assert!(l.windows(2).all(|w| w[0] < w[1]), "sorted, deduped: {l:?}");
        assert!(gran_ladder(0).contains(&1));
        assert!(gran_ladder(1000).iter().all(|&g| g <= 64));
    }
}
