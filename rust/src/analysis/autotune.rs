//! Joint (streams × task-granularity) plan auto-tuning — the paper's
//! §6 future work ("we will further investigate how to get optimal
//! performance by setting a proper task and/or resource granularity …
//! autotune these parameters").
//!
//! Three strategies, from free to exact:
//!
//! - [`predict_streams`] — zero-cost analytic rule from the stage
//!   balance: with one DMA lane per direction and one kernel queue, the
//!   pipeline saturates once every lane is busy, so the useful stream
//!   count is ⌈serial / bottleneck⌉ (+1 fill margin), clamped to [2, 8].
//! - [`predict_plan_point`] — the joint analytic seed over a lowered
//!   [`StreamPlan`]: stream count as above, task granularity from the
//!   fill/drain-vs-overhead balance `m* = √(overlappable / c_task)`
//!   where `overlappable` is the serial time outside the bottleneck
//!   stage and `c_task` the per-task fixed cost of the bottleneck lane
//!   (DMA latency for transfer-bound plans, launch overhead for
//!   compute-bound) — finer tasks shrink pipeline fill/drain by
//!   `overlappable/m` but add `m·c_task` of fixed overhead.
//! - [`autotune_streams`] / [`autotune_plan`] — empirical: measure a
//!   candidate ladder (or the full streams × granularity grid, each
//!   point re-lowered and validated bitwise against the bulk
//!   reference) under the virtual clock and return the argmin — exact,
//!   since the clock is deterministic.
//! - [`autotune_plan_pruned`] — the same measured search without
//!   exhausting the grid: hill-climb the surface outward from a seed
//!   (analytic, or the learned prediction from
//!   [`crate::analysis::KnnTuner`]), measuring only each step's
//!   (streams, granularity) index neighborhood.  On the 56-app corpus
//!   this visits about a third of the full grid and lands on the
//!   exhaustive argmin's time on 55/56 apps (the one miss is within
//!   0.1%) — the full grid stopped being "tiny" the moment granularity
//!   became a second axis, so the pruned walk is what `repro tune
//!   --corpus --learned` runs.  Every visited point is still validated
//!   bitwise against the bulk reference.
//!
//! Tuning paths are panic-safe: empty candidate ladders are
//! [`crate::Error::Stream`] errors (not index panics), argmin
//! comparisons use `f64::total_cmp` (a NaN median cannot crash the
//! search), and a degenerate zero-cost [`crate::device::DeviceProfile`]
//! cannot walk an `inf` through the analytic seed
//! ([`predict_plan_point`] pins `c_task <= 0` to the granularity
//! ceiling instead of dividing by it).

use crate::corpus::BenchConfig;
use crate::hstreams::Context;
use crate::plan::{
    effective_corpus_granularity, lower_corpus_bulk, outputs_match, Backend, Granularity,
    RunConfig, SimBackend, StreamPlan, CORPUS_BURNER,
};
use crate::workloads::{Benchmark, GenericWorkload, Mode};
use crate::{Error, Result};

use super::categorize::Category;
use super::stages::StageTimes;

/// Analytic stream-count suggestion straight from a lowered plan: the
/// IR's byte/FLOP annotations give the stage balance without running
/// anything (the per-plan features the ML-tuning line needs).
pub fn predict_streams_for_plan(
    plan: &StreamPlan,
    profile: &crate::device::DeviceProfile,
) -> usize {
    predict_streams(&plan.stage_times(profile))
}

/// Analytic stream-count suggestion from a stage-by-stage measurement.
pub fn predict_streams(st: &StageTimes) -> usize {
    let total = st.total().as_secs_f64();
    let bottleneck = st.h2d.as_secs_f64().max(st.kex.as_secs_f64()).max(st.d2h.as_secs_f64());
    if bottleneck <= 0.0 {
        return 2;
    }
    let depth = (total / bottleneck).ceil() as usize + 1;
    depth.clamp(2, 8)
}

/// Ceiling of the analytic granularity seed (tasks): matches the
/// [`gran_ladder`] clamp so a seed always sits on a buildable ladder.
pub const GRAN_CEILING: usize = 64;

/// Joint analytic seed `(streams, granularity)` for a lowered plan —
/// the grid point the measured search grows around (module docs).
/// The granularity is a **pipeline task count**; callers tuning a
/// lowering whose knob is in other units (a wavefront's grid side)
/// must map it (e.g. `√tasks`) before building a candidate ladder —
/// `experiments::tune_corpus` does.
pub fn predict_plan_point(
    plan: &StreamPlan,
    profile: &crate::device::DeviceProfile,
) -> (usize, usize) {
    let st = plan.stage_times(profile);
    let streams = predict_streams(&st);
    let (h2d, kex, d2h) = (st.h2d.as_secs_f64(), st.kex.as_secs_f64(), st.d2h.as_secs_f64());
    let bottleneck = h2d.max(kex).max(d2h);
    // Per-task fixed cost of the bottleneck lane.
    let c_task = if bottleneck == kex { profile.launch_us } else { profile.latency_us } * 1e-6;
    let overlappable = (h2d + kex + d2h) - bottleneck;
    let gran = if overlappable <= 0.0 {
        // Nothing to overlap: the pipeline only needs enough tasks to
        // fill its streams.
        streams
    } else if c_task <= 0.0 {
        // Degenerate profile (zero DMA latency / launch overhead):
        // finer tasks are free, so m* is the clamp ceiling — dividing
        // here would walk `inf` through `sqrt`/`round` and lean on the
        // saturating `as usize` cast instead of choosing a point.
        GRAN_CEILING
    } else {
        ((overlappable / c_task).sqrt().round() as usize).clamp(1, GRAN_CEILING)
    };
    // At least one task per stream, or the pipeline can't fill.
    (streams, gran.max(streams))
}

/// Modeled pipelined-makespan estimate of a plan at `streams`, ms:
/// the bottleneck stage runs end to end, the other stages hide behind
/// it except for one un-overlapped pipeline fill/drain share —
/// `bottleneck + (total − bottleneck) / streams`, the same stage-time
/// model [`predict_plan_point`] seeds from.  This is the *admission
/// currency* of the service layer (modeled-ms charged against
/// per-tenant token buckets): a planning-time estimate, deliberately
/// on the conservative side of the measured makespan, never a
/// measurement.
pub fn predict_plan_cost_ms(
    plan: &StreamPlan,
    profile: &crate::device::DeviceProfile,
    streams: usize,
) -> f64 {
    let st = plan.stage_times(profile);
    let (h2d, kex, d2h) = (st.h2d.as_secs_f64(), st.kex.as_secs_f64(), st.d2h.as_secs_f64());
    let total = h2d + kex + d2h;
    let bottleneck = h2d.max(kex).max(d2h);
    (bottleneck + (total - bottleneck) / streams.max(1) as f64) * 1e3
}

/// The analytic `(streams, granularity, modeled cost)` decision for a
/// corpus descriptor: one bulk lowering feeds both the seed
/// ([`predict_plan_point`], knob-mapped) and the cost estimate
/// ([`predict_plan_cost_ms`] at the chosen stream count) — callers
/// that need both (the service's admission path) pay the multi-MiB
/// payload synthesis once.
pub fn analytic_corpus_choice(
    c: &BenchConfig,
    profile: &crate::device::DeviceProfile,
) -> (usize, usize, f64) {
    let bulk = lower_corpus_bulk(c, CORPUS_BURNER);
    let (streams, seed_tasks) = predict_plan_point(&bulk, profile);
    let knob = match c.category() {
        Category::TrueDependent => (seed_tasks as f64).sqrt().ceil() as usize,
        _ => seed_tasks,
    };
    let gran = effective_corpus_granularity(c, Granularity::new(knob)).get();
    (streams, gran, predict_plan_cost_ms(&bulk, profile, streams))
}

/// The analytic `(streams, granularity)` seed for a corpus descriptor
/// in the units its lowering actually uses: [`predict_plan_point`]
/// over the bulk plan, the task count mapped into the category's knob
/// (a wavefront's knob is the tile-grid side, so `√tasks`), and the
/// result clamped through [`effective_corpus_granularity`].  One rule
/// shared by the corpus tuner's seeding and the service layer's
/// [`crate::service::AnalyticPolicy`], so "what would the analytic
/// model pick" is answered identically everywhere.
pub fn analytic_corpus_seed(
    c: &BenchConfig,
    profile: &crate::device::DeviceProfile,
) -> (usize, usize) {
    let (streams, gran, _) = analytic_corpus_choice(c, profile);
    (streams, gran)
}

/// Result of an empirical stream-count sweep.
#[derive(Debug, Clone)]
pub struct AutotuneResult {
    pub best_streams: usize,
    pub best_ms: f64,
    /// (streams, median ms) for every candidate tried.
    pub ladder: Vec<(usize, f64)>,
}

/// Measure `bench` at each candidate stream count (median of `runs`)
/// and return the fastest.  Errors (never panics) on an empty ladder.
pub fn autotune_streams(
    ctx: &Context,
    bench: &dyn Benchmark,
    candidates: &[usize],
    runs: usize,
) -> Result<AutotuneResult> {
    if candidates.is_empty() {
        return Err(Error::Stream(format!(
            "autotune {}: empty stream-candidate ladder",
            bench.name()
        )));
    }
    let runs = runs.max(1);
    // Warmup (absorb PJRT first-execution cost).
    bench.run(ctx, Mode::Streamed(candidates[0]))?;
    let mut ladder = Vec::with_capacity(candidates.len());
    for &n in candidates {
        let mut samples = Vec::with_capacity(runs);
        for _ in 0..runs {
            let r = bench.run(ctx, Mode::Streamed(n))?;
            if !r.validated {
                return Err(Error::Stream(format!(
                    "{} failed validation at {n} streams",
                    bench.name()
                )));
            }
            samples.push(r.wall);
        }
        let med = crate::metrics::median_duration(&mut samples).as_secs_f64() * 1e3;
        ladder.push((n, med));
    }
    let (best_streams, best_ms) = argmin(ladder.iter().copied()).expect("non-empty ladder");
    Ok(AutotuneResult { best_streams, best_ms, ladder })
}

/// Result of a joint (streams × granularity) grid search.  Seeds are
/// the caller's concern ([`predict_plan_point`] + the lowering's knob
/// mapping — see `experiments::tune_corpus`), not duplicated here.
#[derive(Debug, Clone)]
pub struct PlanTuneResult {
    pub best_streams: usize,
    pub best_gran: usize,
    pub best_ms: f64,
    /// Bulk (non-streamed) reference makespan, ms.
    pub bulk_ms: f64,
    /// (streams, granularity, median ms) for every measured grid point
    /// (stream counts normalized to ≥ 1 and deduped, ascending).
    pub surface: Vec<(usize, usize, f64)>,
}

/// Measure the full (streams × granularity) grid of a re-lowerable
/// workload and return the argmin plus the whole surface.
///
/// `bulk` is the single-offload reference plan; every grid point is
/// re-lowered via `lower`, executed under the context's clock, and its
/// assembled outputs validated **bitwise** against the bulk run —
/// granularity must move when bytes travel, never what the result
/// holds.  A divergence or an empty ladder is an error, never a panic.
///
/// Candidates are measured exactly as given: if the lowering clamps
/// the knob (tile-grid sides, per-lane minimums), map the ladder
/// through the effective values and dedupe first — e.g. via
/// `plan::effective_corpus_granularity`, as `experiments::tune_corpus`
/// does — or aliased points are measured twice under two labels.
pub fn autotune_plan(
    ctx: &Context,
    bulk: &StreamPlan,
    lower: &dyn Fn(Granularity) -> StreamPlan,
    streams: &[usize],
    grans: &[usize],
    runs: usize,
) -> Result<PlanTuneResult> {
    if streams.is_empty() || grans.is_empty() {
        return Err(Error::Stream(format!(
            "autotune {}: empty (streams × granularity) candidate grid",
            bulk.name
        )));
    }
    let runs = runs.max(1);
    // Normalize stream counts to what the executor actually maps (≥ 1)
    // and dedupe, so the surface never labels a point with a stream
    // count that doesn't exist (e.g. --ladder 0,1 aliasing 1 twice).
    let streams = normalize_ladder(streams);
    let exec = SimBackend::new(ctx);
    // Bulk reference: same median-of-runs methodology as every grid
    // point (one wallclock outlier must not skew all the comparisons);
    // the first run's outputs serve as the bitwise oracle.
    let reference = exec.run(bulk, RunConfig::streams(1))?;
    let mut bulk_samples = vec![reference.wall];
    for _ in 1..runs {
        bulk_samples.push(exec.run(bulk, RunConfig::streams(1))?.wall);
    }
    let bulk_ms = crate::metrics::median_duration(&mut bulk_samples).as_secs_f64() * 1e3;

    let mut surface = Vec::with_capacity(streams.len() * grans.len());
    for &g in grans {
        let plan = lower(Granularity::new(g));
        plan.validate()?;
        for &n in &streams {
            let mut samples = Vec::with_capacity(runs);
            for i in 0..runs {
                let r = exec.run(&plan, RunConfig::streams(n))?;
                // Outputs are a pure function of (plan, bytes), not of
                // the clock: one bitwise check per grid point suffices,
                // repetitions only re-sample the timing.
                if i == 0 && !outputs_match(&reference, &r) {
                    return Err(Error::Stream(format!(
                        "{}: outputs diverge from bulk at {n} streams × granularity {g}",
                        plan.name
                    )));
                }
                samples.push(r.wall);
            }
            let med = crate::metrics::median_duration(&mut samples).as_secs_f64() * 1e3;
            surface.push((n, g, med));
        }
    }
    let ((best_streams, best_gran), best_ms) =
        argmin(surface.iter().map(|&(n, g, ms)| ((n, g), ms))).expect("non-empty grid");
    Ok(PlanTuneResult { best_streams, best_gran, best_ms, bulk_ms, surface })
}

/// Measure the (streams × granularity) surface outward from `seed`
/// instead of exhausting it: snap the seed to the nearest grid point,
/// then hill-climb — measure the current point's 4-neighborhood in
/// *index* space (one step along either axis), move to the best point
/// measured so far, stop when the current point beats every measured
/// neighbor.  Candidates follow the same contract as [`autotune_plan`]
/// (effective knob values, deduped); the returned surface holds only
/// the visited points, so `surface.len()` against
/// `streams.len() * grans.len()` is the measured fraction.  Every
/// visited point is re-lowered and validated bitwise against the bulk
/// reference, exactly like the full search.
///
/// The walk is greedy: on a non-unimodal surface it can settle on a
/// local minimum.  Across the 56-app corpus it matches the exhaustive
/// argmin's time on 55 apps and is within 0.1% on the last — see
/// `tools/mirror/tuner_mirror.py` and `tests/learned_integration.rs`.
pub fn autotune_plan_pruned(
    ctx: &Context,
    bulk: &StreamPlan,
    lower: &dyn Fn(Granularity) -> StreamPlan,
    streams: &[usize],
    grans: &[usize],
    seed: (usize, usize),
    runs: usize,
) -> Result<PlanTuneResult> {
    if streams.is_empty() || grans.is_empty() {
        return Err(Error::Stream(format!(
            "autotune {}: empty (streams × granularity) candidate grid",
            bulk.name
        )));
    }
    let runs = runs.max(1);
    // Both axes normalized: the 4-neighborhood walks *index* space, so
    // it needs sorted, deduped, ≥ 1 candidates on each axis (the
    // streams rule matches `autotune_plan`; grans are normalized here
    // too because an unsorted axis would turn index neighbors into
    // arbitrary value jumps).
    let streams = normalize_ladder(streams);
    let grans = normalize_ladder(grans);
    let exec = SimBackend::new(ctx);
    let reference = exec.run(bulk, RunConfig::streams(1))?;
    let mut bulk_samples = vec![reference.wall];
    for _ in 1..runs {
        bulk_samples.push(exec.run(bulk, RunConfig::streams(1))?.wall);
    }
    let bulk_ms = crate::metrics::median_duration(&mut bulk_samples).as_secs_f64() * 1e3;

    // Snap the seed to grid indices (shared rule — see [`snap_seed`]).
    let (sn, gn) = snap_seed(&streams, &grans, seed);
    let mut si = streams.iter().position(|&n| n == sn).expect("snapped onto the stream axis");
    let mut gi = grans.iter().position(|&g| g == gn).expect("snapped onto the gran axis");

    // Measured points, keyed (streams, granularity).  The argmin's
    // first-seen tie-break over BTreeMap order resolves exact-time ties
    // to the lexicographically smallest (streams, gran) point — note
    // this is streams-major, while `autotune_plan`'s surface is
    // gran-major, so on an exact tie the two searches can report
    // different (equal-time) argmin coordinates.
    let mut cache: std::collections::BTreeMap<(usize, usize), f64> = Default::default();
    let mut plans: std::collections::BTreeMap<usize, StreamPlan> = Default::default();
    let mut measure = |i: usize, j: usize,
                       cache: &mut std::collections::BTreeMap<(usize, usize), f64>,
                       plans: &mut std::collections::BTreeMap<usize, StreamPlan>|
     -> Result<()> {
        let (n, g) = (streams[i], grans[j]);
        if cache.contains_key(&(n, g)) {
            return Ok(());
        }
        if let std::collections::btree_map::Entry::Vacant(slot) = plans.entry(g) {
            let plan = lower(Granularity::new(g));
            plan.validate()?;
            slot.insert(plan);
        }
        let plan = &plans[&g];
        let mut samples = Vec::with_capacity(runs);
        for rep in 0..runs {
            let r = exec.run(plan, RunConfig::streams(n))?;
            if rep == 0 && !outputs_match(&reference, &r) {
                return Err(Error::Stream(format!(
                    "{}: outputs diverge from bulk at {n} streams × granularity {g}",
                    plan.name
                )));
            }
            samples.push(r.wall);
        }
        let med = crate::metrics::median_duration(&mut samples).as_secs_f64() * 1e3;
        cache.insert((n, g), med);
        Ok(())
    };

    measure(si, gi, &mut cache, &mut plans)?;
    for _ in 0..streams.len() * grans.len() {
        for (di, dj) in [(1i64, 0i64), (-1, 0), (0, 1), (0, -1)] {
            let (i, j) = (si as i64 + di, gi as i64 + dj);
            if i >= 0 && (i as usize) < streams.len() && j >= 0 && (j as usize) < grans.len() {
                measure(i as usize, j as usize, &mut cache, &mut plans)?;
            }
        }
        let ((bn, bg), _) =
            argmin(cache.iter().map(|(&k, &v)| (k, v))).expect("at least the seed measured");
        let (bi, bj) = (
            streams.iter().position(|&n| n == bn).expect("argmin on the grid"),
            grans.iter().position(|&g| g == bg).expect("argmin on the grid"),
        );
        if (bi, bj) == (si, gi) {
            break;
        }
        (si, gi) = (bi, bj);
    }

    let surface: Vec<(usize, usize, f64)> =
        cache.iter().map(|(&(n, g), &ms)| (n, g, ms)).collect();
    let ((best_streams, best_gran), best_ms) =
        argmin(surface.iter().map(|&(n, g, ms)| ((n, g), ms))).expect("non-empty surface");
    Ok(PlanTuneResult { best_streams, best_gran, best_ms, bulk_ms, surface })
}

/// Joint (streams × chunk-count) autotune of a re-chunkable
/// [`GenericWorkload`] — the granularity-aware path behind
/// `repro autotune <NAME>` for drivers exposing
/// [`Benchmark::tunable`].  Chunk-count candidates grow around the
/// analytic seed and keep only counts the workload's windows actually
/// re-partition to ([`GenericWorkload::with_chunks`] refuses
/// non-dividing counts); the bulk (baseline) lowering is the bitwise
/// reference for every grid point, which is sound exactly because
/// `tunable()` is only implemented by per-element-map drivers.
pub fn autotune_workload(
    ctx: &Context,
    wl: &GenericWorkload,
    streams: &[usize],
    runs: usize,
) -> Result<PlanTuneResult> {
    let bulk = wl.lower(Mode::Baseline);
    let (_, seed_tasks) = predict_plan_point(&bulk, ctx.profile());
    let mut grans: Vec<usize> = gran_ladder(seed_tasks)
        .into_iter()
        .chain([wl.chunks()])
        .filter(|&g| wl.with_chunks(g).is_some())
        .collect();
    grans.sort_unstable();
    grans.dedup();
    autotune_plan(
        ctx,
        &bulk,
        &|g| {
            wl.with_chunks(g.get())
                .expect("candidates pre-filtered to dividing chunk counts")
                .lower(Mode::Streamed(1))
        },
        streams,
        &grans,
        runs,
    )
}

/// Normalize a candidate ladder to what the executor actually maps:
/// every entry ≥ 1, sorted ascending, deduped.  One rule shared by
/// both grid searches and `experiments::tune_one`'s grid accounting,
/// so the coverage denominator always counts exactly the points a
/// search could measure.
pub fn normalize_ladder(ladder: &[usize]) -> Vec<usize> {
    let mut v: Vec<usize> = ladder.iter().map(|&n| n.max(1)).collect();
    v.sort_unstable();
    v.dedup();
    v
}

/// Snap a `(streams, granularity)` seed onto candidate axes: nearest
/// stream count by absolute distance, nearest granularity by log-ratio
/// (the knob is multiplicative — 16 is "closer" to 8 than 1 is), ties
/// to the first (smallest) candidate.  One rule shared by the pruned
/// walk, the CV harness, and the integration tests, so "how good was
/// the seed" is always evaluated with the walk's own snapping.
///
/// # Panics
/// On an empty axis — callers validate their grids first.
pub fn snap_seed(streams: &[usize], grans: &[usize], seed: (usize, usize)) -> (usize, usize) {
    let (sseed, gseed) = seed;
    let sn = *streams
        .iter()
        .min_by_key(|&&n| n.abs_diff(sseed))
        .expect("non-empty stream axis");
    // +0.5 keeps the ratio finite for a zero seed or candidate.
    let log_dist = |g: usize, s: usize| ((g as f64 + 0.5) / (s as f64 + 0.5)).ln().abs();
    let gn = *grans
        .iter()
        .min_by(|&&a, &&b| log_dist(a, gseed).total_cmp(&log_dist(b, gseed)))
        .expect("non-empty gran axis");
    (sn, gn)
}

/// Granularity candidate ladder grown around an analytic seed: the
/// usual powers of two plus the seed's neighbourhood, sorted, deduped.
pub fn gran_ladder(seed: usize) -> Vec<usize> {
    let s = seed.clamp(1, GRAN_CEILING);
    let mut v = vec![1, 2, 4, 8, 16, (s / 2).max(1), s, (s * 2).min(GRAN_CEILING)];
    v.sort_unstable();
    v.dedup();
    v
}

/// First-seen argmin under `f64::total_cmp` (NaN orders above every
/// real time, so a poisoned sample can never win or crash the search);
/// `None` on an empty iterator.  Shared by every tuning/sweep argmin
/// so tie-breaks agree across tables (first-seen = smallest candidate
/// when ladders are ascending).
pub(crate) fn argmin<K: Copy>(points: impl IntoIterator<Item = (K, f64)>) -> Option<(K, f64)> {
    let mut best: Option<(K, f64)> = None;
    for (k, v) in points {
        match &best {
            Some((_, b)) if v.total_cmp(b) != std::cmp::Ordering::Less => {}
            _ => best = Some((k, v)),
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    fn st(h2d: u64, kex: u64, d2h: u64) -> StageTimes {
        StageTimes {
            h2d: Duration::from_millis(h2d),
            kex: Duration::from_millis(kex),
            d2h: Duration::from_millis(d2h),
        }
    }

    #[test]
    fn balanced_stages_want_deep_pipelines() {
        // Three equal stages: serial/bottleneck = 3 -> 4 streams.
        assert_eq!(predict_streams(&st(10, 10, 10)), 4);
    }

    #[test]
    fn kex_dominated_needs_few_streams() {
        // KEX is 90%: overlap headroom is small -> shallow pipeline.
        assert_eq!(predict_streams(&st(5, 90, 5)), 3);
    }

    #[test]
    fn prediction_clamped() {
        assert!(predict_streams(&st(1, 1000, 1)) >= 2);
        assert!(predict_streams(&st(1, 1, 1)) <= 8);
    }

    #[test]
    fn degenerate_profiles_cannot_poison_the_seed() {
        use crate::plan::{HostSlice, PlanRegion, Slot, StreamPlan};
        use std::sync::Arc;

        let mut p = StreamPlan::new("degenerate");
        let n = 1 << 20;
        let b = p.buf(n);
        let o = p.output(n);
        let payload = HostSlice::whole(Arc::new(vec![0u8; n]));
        p.h2d(Slot::Task(0), payload, PlanRegion::whole(b, n), vec![]);
        let k = p.kex(
            Slot::Task(0),
            "burner_8",
            vec![PlanRegion::whole(b, n)],
            vec![PlanRegion::whole(b, n)],
            Some(1_000_000),
            1,
            vec![],
        );
        p.d2h(Slot::Task(0), PlanRegion::whole(b, n), o, 0, vec![k]);

        // Zero per-transfer latency but finite bandwidth: the transfer
        // bottleneck has c_task = 0, so the balance says "finer is
        // free" — the seed must be the clamp ceiling, not an inf walked
        // through sqrt/round into a saturating cast (and not the old
        // fallback to the stream count).
        let mut zero_latency = crate::device::DeviceProfile::mic31sp().simulation();
        zero_latency.latency_us = 0.0;
        zero_latency.alloc_us_per_mb = 0.0;
        let (s, g) = predict_plan_point(&p, &zero_latency);
        assert!((2..=8).contains(&s));
        assert_eq!(g, GRAN_CEILING, "zero c_task pins the seed to the ceiling");

        // Fully instant profile: every stage is zero, nothing overlaps,
        // and the seed stays small and finite.
        let instant = crate::device::DeviceProfile::instant();
        let (s, g) = predict_plan_point(&p, &instant);
        assert_eq!(s, 2);
        assert_eq!(g, 2, "no overlap headroom -> one task per stream");
    }

    #[test]
    fn argmin_is_nan_safe_and_first_seen() {
        let pts = [(1usize, f64::NAN), (2, 3.0), (3, 1.0), (4, 1.0)];
        let (k, v) = argmin(pts.iter().copied()).expect("non-empty");
        assert_eq!(k, 3, "ties keep the first-seen point");
        assert_eq!(v, 1.0);
        assert!(argmin(std::iter::empty::<((), f64)>()).is_none());
        // All-NaN still returns a point rather than panicking.
        assert_eq!(argmin([(7usize, f64::NAN)].into_iter()).map(|p| p.0), Some(7));
    }

    #[test]
    fn normalize_ladder_sorts_dedupes_and_floors() {
        assert_eq!(normalize_ladder(&[0, 1, 8, 2, 2]), vec![1, 2, 8]);
        assert_eq!(normalize_ladder(&[4]), vec![4]);
    }

    #[test]
    fn snap_seed_uses_abs_streams_and_log_grans() {
        let streams = [1, 2, 4, 8];
        let grans = [1, 2, 4, 16];
        // Stream ties (3 is 1 away from both 2 and 4) keep the first
        // candidate; gran 8 sits between 4 and 16 and the smoothed log
        // distance puts it marginally nearer 4.
        assert_eq!(snap_seed(&streams, &grans, (3, 8)), (2, 4));
        assert_eq!(snap_seed(&streams, &grans, (9, 30)), (8, 16));
        assert_eq!(snap_seed(&streams, &grans, (0, 0)), (1, 1));
    }

    #[test]
    fn gran_ladder_contains_seed_and_defaults() {
        let l = gran_ladder(11);
        assert!(l.contains(&1) && l.contains(&8) && l.contains(&11) && l.contains(&22));
        assert!(l.windows(2).all(|w| w[0] < w[1]), "sorted, deduped: {l:?}");
        assert!(gran_ladder(0).contains(&1));
        assert!(gran_ladder(1000).iter().all(|&g| g <= 64));
    }
}
