//! The learned (streams × granularity) tuner — the arXiv:1802.02760
//! line of work (ML-predicted stream/granularity configs for streamed
//! coprocessor apps) over this repo's plan features, in the spirit of
//! arXiv:2003.04294's feature-based streaming-parallelism predictor.
//!
//! Three layers, all zero-dependency:
//!
//! - [`PlanFeatures`] — a normalized feature vector extracted from a
//!   lowered [`StreamPlan`] (category one-hots, task count, DAG
//!   depth/width, per-stage bytes and FLOPs, the h2d:kex:d2h balance,
//!   broadcast fraction).  Features come from the *default-granularity
//!   streamed* lowering: it is free to build (no measurement) and,
//!   unlike the bulk plan, exposes the DAG shape, halo inflation and
//!   broadcast structure the tuner needs to discriminate categories.
//! - [`Dataset`] — `(features → best_streams, best_gran)` training
//!   rows.  `repro tune --corpus --json` is the dataset generator; its
//!   output round-trips through [`crate::util::json`] back into rows
//!   ([`Dataset::from_tune_json`]), and in-process callers convert
//!   [`crate::experiments::TuneRow`]s directly.
//! - [`KnnTuner`] — distance-weighted k-nearest-neighbors over the
//!   normalized features, restricted to same-category neighbors.  The
//!   prediction is the weighted geometric mean of the neighbors' optima
//!   (both knobs are multiplicative).  An empty neighborhood returns
//!   `None`; callers fall back to the analytic
//!   [`super::predict_plan_point`] seed.
//!
//! Evaluation is leave-one-app-out cross-validation over the 56-app
//! corpus (`repro learn --cv`, `tests/learned_integration.rs`): train
//! on 55 apps, predict the held-out app's config, measure it, and
//! compare against the exhaustive-grid optimum.  The learned seed also
//! feeds [`super::autotune_plan_pruned`] (`repro tune --corpus
//! --learned`): hill-climb from the prediction instead of measuring
//! the whole grid.

use crate::corpus::BenchConfig;
use crate::device::DeviceProfile;
use crate::plan::{lower_corpus_streamed, StreamPlan, CORPUS_BURNER};
use crate::util::json::Json;
use crate::{Error, Result};

use super::Category;

/// Names of the feature dimensions, in vector order (the `repro learn`
/// feature table and DESIGN.md §Learning use these).
pub const FEATURE_NAMES: [&str; 14] = [
    "cat_independent",
    "cat_false_dep",
    "cat_true_dep",
    "cat_nonstream",
    "log_tasks",
    "depth_frac",
    "width_frac",
    "log_h2d_bytes",
    "log_d2h_bytes",
    "log_flops",
    "r_h2d",
    "r_kex",
    "r_d2h",
    "broadcast_frac",
];

/// Normalized feature vector of one lowered plan.
#[derive(Debug, Clone, PartialEq)]
pub struct PlanFeatures {
    pub category: Category,
    /// One value per [`FEATURE_NAMES`] entry, each roughly in [0, 1].
    pub values: Vec<f64>,
}

impl PlanFeatures {
    /// Extract features from a lowered plan.  `category` comes from the
    /// Table-2 categorizer (it shapes the lowering, so it is known
    /// wherever a plan is).
    pub fn of(plan: &StreamPlan, profile: &DeviceProfile, category: Category) -> Self {
        let st = plan.stage_times(profile);
        let (h2d, kex, d2h) = (st.h2d.as_secs_f64(), st.kex.as_secs_f64(), st.d2h.as_secs_f64());
        let total = (h2d + kex + d2h).max(f64::MIN_POSITIVE);
        let tasks = plan.tasks().max(1) as f64;
        let h2d_bytes = plan.h2d_bytes();
        let log10p1 = |v: u64| ((v + 1) as f64).log10();
        let onehot = |b: bool| if b { 1.0 } else { 0.0 };
        let values = vec![
            onehot(category == Category::Independent),
            onehot(category == Category::FalseDependent),
            onehot(category == Category::TrueDependent),
            onehot(!category.streamable()),
            (tasks + 1.0).log10() / 2.0,
            plan.dag_depth().max(1) as f64 / tasks,
            plan.dag_width() as f64 / tasks,
            log10p1(h2d_bytes) / 9.0,
            log10p1(plan.d2h_bytes()) / 9.0,
            log10p1(plan.kex_flops()) / 12.0,
            h2d / total,
            kex / total,
            d2h / total,
            plan.broadcast_h2d_bytes() as f64 / h2d_bytes.max(1) as f64,
        ];
        Self { category, values }
    }

    /// Euclidean distance in feature space.
    pub fn distance(&self, other: &PlanFeatures) -> f64 {
        self.values
            .iter()
            .zip(&other.values)
            .map(|(a, b)| (a - b) * (a - b))
            .sum::<f64>()
            .sqrt()
    }
}

/// Features of one corpus descriptor: its default-granularity streamed
/// lowering under `profile` (no measurement involved).
pub fn corpus_features(c: &BenchConfig, profile: &DeviceProfile) -> PlanFeatures {
    let plan = lower_corpus_streamed(c, CORPUS_BURNER);
    PlanFeatures::of(&plan, profile, c.category())
}

/// One training example: plan features labeled with the measured
/// joint optimum (in the lowering's knob units).
#[derive(Debug, Clone)]
pub struct TrainRow {
    pub suite: String,
    pub app: String,
    pub config: String,
    pub features: PlanFeatures,
    pub best_streams: usize,
    pub best_gran: usize,
}

/// The training set for the learned tuner.
#[derive(Debug, Clone, Default)]
pub struct Dataset {
    pub rows: Vec<TrainRow>,
}

impl Dataset {
    /// Round-trip `repro tune --corpus --json` output back into
    /// training rows: every validated row whose `(app, config)` matches
    /// a corpus descriptor contributes features (re-derived from the
    /// descriptor's lowering — the JSON holds labels, not features) and
    /// its measured best point.  Errored / mis-validated rows are
    /// skipped: their `best` fields are the struct defaults, not
    /// optima.
    pub fn from_tune_json(text: &str, profile: &DeviceProfile) -> Result<Dataset> {
        let doc = Json::parse(text).map_err(|e| Error::Config(format!("tune dataset: {e}")))?;
        let rows_json = doc
            .get("rows")
            .and_then(Json::as_arr)
            .ok_or_else(|| Error::Config("tune dataset: missing `rows` array".into()))?;
        let configs = crate::corpus::all_configs();
        let mut rows = Vec::new();
        for r in rows_json {
            if r.get("validated").and_then(Json::as_bool) != Some(true) {
                continue;
            }
            let (Some(suite), Some(app), Some(config), Some(best)) = (
                r.get("suite").and_then(Json::as_str),
                r.get("app").and_then(Json::as_str),
                r.get("config").and_then(Json::as_str),
                r.get("best"),
            ) else {
                continue;
            };
            let (Some(streams), Some(gran)) = (
                best.get("streams").and_then(Json::as_usize),
                best.get("gran").and_then(Json::as_usize),
            ) else {
                continue;
            };
            let Some(c) = configs
                .iter()
                .find(|c| c.app == app && c.config == config && c.suite.label() == suite)
            else {
                continue; // dataset from another corpus revision
            };
            rows.push(TrainRow {
                suite: suite.into(),
                app: app.into(),
                config: config.into(),
                features: corpus_features(c, profile),
                best_streams: streams.max(1),
                best_gran: gran.max(1),
            });
        }
        Ok(Dataset { rows })
    }

    /// Serialize as the minimal labeled subset of the tune-JSON schema
    /// (enough for [`Dataset::from_tune_json`] to round-trip).
    pub fn to_json(&self) -> String {
        use crate::util::json::escape;
        let mut s = String::from("{\"rows\":[");
        for (i, r) in self.rows.iter().enumerate() {
            if i > 0 {
                s.push(',');
            }
            s.push_str(&format!(
                "{{\"suite\":\"{}\",\"app\":\"{}\",\"config\":\"{}\",\"validated\":true,\
                 \"best\":{{\"streams\":{},\"gran\":{},\"ms\":null}}}}",
                escape(&r.suite),
                escape(&r.app),
                escape(&r.config),
                r.best_streams,
                r.best_gran,
            ));
        }
        s.push_str("]}");
        s
    }
}

/// Distance-weighted k-nearest-neighbors over plan features.
#[derive(Debug, Clone)]
pub struct KnnTuner {
    k: usize,
    rows: Vec<TrainRow>,
}

/// Default neighborhood size (5 balances the small per-category corpus
/// populations against vote stability; see DESIGN.md §Learning).
pub const DEFAULT_K: usize = 5;

impl KnnTuner {
    pub fn fit(dataset: Dataset, k: usize) -> Self {
        Self { k: k.max(1), rows: dataset.rows }
    }

    pub fn rows(&self) -> &[TrainRow] {
        &self.rows
    }

    /// Predict `(streams, granularity)` for a plan with `features`,
    /// or `None` when no same-category training row exists (the caller
    /// falls back to the analytic seed).  Granularity is in the same
    /// knob units as the training labels (`tune_corpus` labels are
    /// effective corpus-knob values).
    pub fn predict(&self, features: &PlanFeatures) -> Option<(usize, usize)> {
        let mut neigh: Vec<(f64, usize, usize)> = self
            .rows
            .iter()
            .filter(|r| r.features.category == features.category)
            .map(|r| (features.distance(&r.features), r.best_streams, r.best_gran))
            .collect();
        if neigh.is_empty() {
            return None;
        }
        neigh.sort_by(|a, b| a.0.total_cmp(&b.0));
        neigh.truncate(self.k);
        // Distance-weighted geometric mean: both knobs are
        // multiplicative (ladders are power-of-two-ish), so averaging
        // their logs is the natural vote.
        let mut wsum = 0.0;
        let mut ls = 0.0;
        let mut lg = 0.0;
        for (d, s, g) in &neigh {
            let w = 1.0 / (d + 1e-6);
            wsum += w;
            ls += w * (*s as f64).ln();
            lg += w * (*g as f64).ln();
        }
        // No upper clamp: the vote cannot exceed the training labels'
        // range, and callers snap onto their own candidate axes
        // (`snap_seed`) — a fixed cap would undercut ladders above it.
        let streams = ((ls / wsum).exp().round() as usize).max(1);
        let gran = ((lg / wsum).exp().round() as usize).max(1);
        Some((streams, gran))
    }

    /// Leave-one-app-out view: a tuner trained on every row whose app
    /// differs from `app` (all of an app's input configs are held out
    /// together — the CV protocol must not leak the app's own surface).
    pub fn without_app(&self, app: &str) -> KnnTuner {
        KnnTuner {
            k: self.k,
            rows: self.rows.iter().filter(|r| r.app != app).cloned().collect(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::corpus::configs_for;

    fn profile() -> DeviceProfile {
        DeviceProfile::mic31sp().simulation()
    }

    #[test]
    fn features_are_normalized_and_category_shaped() {
        let p = profile();
        let nn = corpus_features(&configs_for("nn")[0], &p);
        assert_eq!(nn.values.len(), FEATURE_NAMES.len());
        assert!(nn.values.iter().all(|v| v.is_finite() && *v >= 0.0 && *v <= 1.5), "{nn:?}");
        assert_eq!(nn.values[0], 1.0, "nn is independent");
        assert!((nn.values[10] + nn.values[11] + nn.values[12] - 1.0).abs() < 1e-9);
        // Independent fan-out: depth 1, full width.
        assert!(nn.values[5] < 0.2 && (nn.values[6] - 1.0).abs() < 1e-9);

        let wf = corpus_features(&configs_for("nw")[0], &p);
        assert_eq!(wf.values[2], 1.0, "nw is true-dependent");
        assert!(wf.values[5] > nn.values[5], "wavefront chains are deeper");

        let it = corpus_features(&configs_for("hotspot")[0], &p);
        assert_eq!(it.values[3], 1.0, "hotspot is non-streamable");
        assert_eq!(it.category, Category::Iterative);
    }

    #[test]
    fn knn_votes_within_category_and_falls_back_empty() {
        let p = profile();
        let mk = |app: &str, s: usize, g: usize| {
            let c = &configs_for(app)[0];
            TrainRow {
                suite: c.suite.label().into(),
                app: app.into(),
                config: c.config.clone(),
                features: corpus_features(c, &p),
                best_streams: s,
                best_gran: g,
            }
        };
        // Same-category (independent) neighbors dominate the vote.
        let ds = Dataset { rows: vec![mk("nn", 4, 8), mk("Transpose", 4, 8), mk("nw", 1, 1)] };
        let model = KnnTuner::fit(ds, DEFAULT_K);
        let (s, g) = model.predict(&corpus_features(&configs_for("VectorAdd")[0], &p)).unwrap();
        assert_eq!((s, g), (4, 8), "independent query ignores the wavefront row");
        // No true-dependent training row after holding nw out -> None.
        let held = model.without_app("nw");
        assert!(held.predict(&corpus_features(&configs_for("gaussian")[0], &p)).is_none());
    }

    #[test]
    fn dataset_roundtrips_through_tune_json() {
        let p = profile();
        let c = &configs_for("nn")[0];
        let ds = Dataset {
            rows: vec![TrainRow {
                suite: c.suite.label().into(),
                app: c.app.into(),
                config: c.config.clone(),
                features: corpus_features(c, &p),
                best_streams: 2,
                best_gran: 4,
            }],
        };
        let back = Dataset::from_tune_json(&ds.to_json(), &p).unwrap();
        assert_eq!(back.rows.len(), 1);
        let r = &back.rows[0];
        assert_eq!((r.app.as_str(), r.best_streams, r.best_gran), ("nn", 2, 4));
        assert_eq!(r.features, ds.rows[0].features, "features re-derive identically");
        // Unvalidated rows are skipped, not trained on.
        let doc = ds.to_json().replace("\"validated\":true", "\"validated\":false");
        assert!(Dataset::from_tune_json(&doc, &p).unwrap().rows.is_empty());
        // Garbage is an error, not a panic.
        assert!(Dataset::from_tune_json("{", &p).is_err());
    }
}
