//! Table-2 categorizer: map a benchmark's dependency facts to the
//! paper's five categories.
//!
//! The paper derives categories from H2D↔KEX dependency analysis
//! (Fig. 5).  Corpus descriptors record the *facts* (what data each task
//! needs, whether kernels iterate on resident data, whether the kernel
//! is sequential); this module holds the *rules* so the classification
//! is reproducible rather than hand-labeled.

/// Inter-task data dependency of the partitioned code (§4.2).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TaskDep {
    /// Tasks share no data (Fig. 6, nn).
    None,
    /// Read-after-read sharing: tasks read each other's boundary inputs;
    /// eliminated by redundant transfer (Fig. 7, FWT).  `halo` and
    /// `chunk` sizes drive the lavaMD overhead analysis.
    Rar { halo: usize, chunk: usize },
    /// Read-after-write: true dependency, respected by wavefront
    /// ordering (Fig. 8, NW).
    Raw,
}

/// Dependency facts recorded per benchmark in the corpus.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct DependencyFacts {
    /// The whole H2D payload is consumed by every task — the transfer
    /// must finish before any kernel starts (SYNC pattern).
    pub shared_input_all_tasks: bool,
    /// KEX iterates on device-resident data after one upload
    /// (Iterative pattern): overlapping helps only the first iteration.
    pub iterative_kex: bool,
    /// The kernel itself is sequential — no concurrent tasks exist
    /// (myocyte).
    pub sequential_kernel: bool,
    /// Inter-task data dependency after partitioning.
    pub task_dep: TaskDep,
}

impl DependencyFacts {
    pub fn independent() -> Self {
        Self {
            shared_input_all_tasks: false,
            iterative_kex: false,
            sequential_kernel: false,
            task_dep: TaskDep::None,
        }
    }

    pub fn rar(halo: usize, chunk: usize) -> Self {
        Self { task_dep: TaskDep::Rar { halo, chunk }, ..Self::independent() }
    }

    pub fn raw() -> Self {
        Self { task_dep: TaskDep::Raw, ..Self::independent() }
    }

    pub fn sync() -> Self {
        Self { shared_input_all_tasks: true, ..Self::independent() }
    }

    pub fn iterative() -> Self {
        Self { iterative_kex: true, ..Self::independent() }
    }
}

/// The paper's Table-2 categories.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Category {
    /// Non-streamable: shared H2D payload must land before any KEX.
    Sync,
    /// Non-streamable: kernels iterate on resident data (or are
    /// sequential) — pipelining the single upload buys nothing.
    Iterative,
    /// Streamable, no inter-task data.
    Independent,
    /// Streamable, RAR sharing removed by redundant boundary transfer.
    FalseDependent,
    /// Streamable, RAW dependency respected by wavefront ordering.
    TrueDependent,
}

impl Category {
    pub fn streamable(self) -> bool {
        matches!(self, Category::Independent | Category::FalseDependent | Category::TrueDependent)
    }

    pub fn label(self) -> &'static str {
        match self {
            Category::Sync => "SYNC",
            Category::Iterative => "Iterative",
            Category::Independent => "Independent",
            Category::FalseDependent => "False-dependent",
            Category::TrueDependent => "True-dependent",
        }
    }
}

/// The classification rule (§4.1): non-streamable patterns first, then
/// the three streamable categories by dependency kind.
pub fn categorize(f: &DependencyFacts) -> Category {
    if f.shared_input_all_tasks {
        return Category::Sync;
    }
    if f.iterative_kex || f.sequential_kernel {
        return Category::Iterative;
    }
    match f.task_dep {
        TaskDep::None => Category::Independent,
        TaskDep::Rar { .. } => Category::FalseDependent,
        TaskDep::Raw => Category::TrueDependent,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_exemplars() {
        // nn is the Embarrassingly Independent exemplar (Fig. 6).
        assert_eq!(categorize(&DependencyFacts::independent()), Category::Independent);
        // FWT is the False Dependent exemplar (Fig. 7).
        assert_eq!(categorize(&DependencyFacts::rar(127, 1 << 20)), Category::FalseDependent);
        // NW is the True Dependent exemplar (Fig. 8).
        assert_eq!(categorize(&DependencyFacts::raw()), Category::TrueDependent);
        // SYNC and Iterative are non-streamable.
        assert!(!categorize(&DependencyFacts::sync()).streamable());
        assert!(!categorize(&DependencyFacts::iterative()).streamable());
    }

    #[test]
    fn sync_wins_over_dependency_kind() {
        // A shared-input code is SYNC even if its tasks would otherwise
        // look independent.
        let f = DependencyFacts { shared_input_all_tasks: true, ..DependencyFacts::raw() };
        assert_eq!(categorize(&f), Category::Sync);
    }

    #[test]
    fn sequential_kernel_is_nonstreamable() {
        let f = DependencyFacts { sequential_kernel: true, ..DependencyFacts::independent() };
        assert_eq!(categorize(&f), Category::Iterative);
    }
}
