//! Device-memory arena: coprocessor RAM with lazy-allocation accounting.

use std::collections::HashMap;

use crate::{Error, Result};

/// Handle to one device buffer.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct BufId(pub u64);

/// A byte range inside a device buffer — the unit kernels read/write and
/// DMA jobs target.
#[derive(Debug, Clone, Copy)]
pub struct DevRegion {
    pub buf: BufId,
    pub off: usize,
    pub len: usize,
}

impl DevRegion {
    pub fn whole(buf: BufId, len: usize) -> Self {
        Self { buf, off: 0, len }
    }
}

struct Buffer {
    data: Vec<u8>,
    /// Lazy-allocation: the paper (§3.3) observes that buffer allocation
    /// happens on first H2D touch and is *counted into H2D time*.  The
    /// transfer engine charges `alloc_time` once, when this flips.
    touched: bool,
}

/// The coprocessor's memory.  Both engines access it behind a mutex;
/// copies happen under the lock (µs-scale), pacing sleeps outside it.
pub struct DeviceArena {
    buffers: HashMap<BufId, Buffer>,
    next: u64,
    capacity: usize,
    used: usize,
}

impl DeviceArena {
    /// Create an arena with `capacity` bytes of device memory
    /// (Xeon Phi 31SP carries 8 GiB; default callers pass less).
    pub fn new(capacity: usize) -> Self {
        Self { buffers: HashMap::new(), next: 0, capacity, used: 0 }
    }

    /// Reserve a device buffer of `len` bytes.  Reservation is free; the
    /// modeled allocation cost is charged lazily by the first H2D.
    pub fn alloc(&mut self, len: usize) -> Result<BufId> {
        if self.used + len > self.capacity {
            return Err(Error::Arena(format!(
                "out of device memory: want {len}, used {}/{}",
                self.used, self.capacity
            )));
        }
        let id = BufId(self.next);
        self.next += 1;
        self.used += len;
        self.buffers.insert(id, Buffer { data: vec![0u8; len], touched: false });
        Ok(id)
    }

    /// Release a buffer.
    pub fn free(&mut self, id: BufId) -> Result<()> {
        match self.buffers.remove(&id) {
            Some(b) => {
                self.used -= b.data.len();
                Ok(())
            }
            None => Err(Error::Arena(format!("free of unknown buffer {id:?}"))),
        }
    }

    /// Bytes currently reserved.
    pub fn used(&self) -> usize {
        self.used
    }

    /// Number of live buffers.
    pub fn live_buffers(&self) -> usize {
        self.buffers.len()
    }

    fn buffer_mut(&mut self, id: BufId) -> Result<&mut Buffer> {
        self.buffers.get_mut(&id).ok_or_else(|| Error::Arena(format!("unknown buffer {id:?}")))
    }

    fn buffer(&self, id: BufId) -> Result<&Buffer> {
        self.buffers.get(&id).ok_or_else(|| Error::Arena(format!("unknown buffer {id:?}")))
    }

    /// Copy host bytes into a device region.  Returns `true` if this was
    /// the buffer's first touch (caller charges the lazy-alloc cost).
    pub fn write(&mut self, region: DevRegion, src: &[u8]) -> Result<bool> {
        let buf = self.buffer_mut(region.buf)?;
        let end = region.off + region.len;
        if src.len() != region.len || end > buf.data.len() {
            return Err(Error::Arena(format!(
                "bad write: region {:?} src {} buf {}",
                region,
                src.len(),
                buf.data.len()
            )));
        }
        buf.data[region.off..end].copy_from_slice(src);
        let first = !buf.touched;
        buf.touched = true;
        Ok(first)
    }

    /// Copy a device region out to host bytes.
    pub fn read(&self, region: DevRegion) -> Result<Vec<u8>> {
        let buf = self.buffer(region.buf)?;
        let end = region.off + region.len;
        if end > buf.data.len() {
            return Err(Error::Arena(format!("bad read: region {region:?} buf {}", buf.data.len())));
        }
        Ok(buf.data[region.off..end].to_vec())
    }

    /// Whether a buffer has been touched by DMA yet (lazy-alloc state).
    pub fn touched(&self, id: BufId) -> Result<bool> {
        Ok(self.buffer(id)?.touched)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn alloc_write_read_roundtrip() {
        let mut a = DeviceArena::new(1 << 20);
        let id = a.alloc(16).unwrap();
        let first = a.write(DevRegion::whole(id, 16), &[7u8; 16]).unwrap();
        assert!(first);
        let second = a.write(DevRegion { buf: id, off: 4, len: 4 }, &[9u8; 4]).unwrap();
        assert!(!second, "alloc cost must be charged exactly once");
        let back = a.read(DevRegion::whole(id, 16)).unwrap();
        assert_eq!(&back[..4], &[7u8; 4]);
        assert_eq!(&back[4..8], &[9u8; 4]);
    }

    #[test]
    fn capacity_enforced() {
        let mut a = DeviceArena::new(10);
        assert!(a.alloc(8).is_ok());
        assert!(a.alloc(8).is_err());
    }

    #[test]
    fn free_returns_capacity() {
        let mut a = DeviceArena::new(10);
        let id = a.alloc(8).unwrap();
        a.free(id).unwrap();
        assert_eq!(a.used(), 0);
        assert!(a.alloc(8).is_ok());
    }

    #[test]
    fn oob_region_rejected() {
        let mut a = DeviceArena::new(64);
        let id = a.alloc(8).unwrap();
        assert!(a.write(DevRegion { buf: id, off: 4, len: 8 }, &[0; 8]).is_err());
        assert!(a.read(DevRegion { buf: id, off: 0, len: 9 }).is_err());
    }
}
