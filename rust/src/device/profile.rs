//! Device profiles: the modeled hardware parameters of the platform.

/// Modeled hardware parameters for one heterogeneous platform.
///
/// Bandwidths/latency pace the DMA engine; `gflops` paces kernel
/// execution (`max(real, modeled)`); `alloc_us_per_mb` models the lazy
/// buffer-allocation cost the paper folds into H2D (§3.3).
#[derive(Debug, Clone)]
pub struct DeviceProfile {
    pub name: String,
    /// Host→device bandwidth, GB/s.
    pub h2d_gbps: f64,
    /// Device→host bandwidth, GB/s.
    pub d2h_gbps: f64,
    /// Per-transfer DMA setup latency, microseconds.
    pub latency_us: f64,
    /// Lazy-allocation cost charged on first touch, µs per MiB.
    pub alloc_us_per_mb: f64,
    /// Effective device compute throughput, GFLOP/s (models the
    /// coprocessor; K80-like profiles set this ~16x higher than MIC).
    pub gflops: f64,
    /// Fixed kernel-launch overhead, microseconds.
    pub launch_us: f64,
    /// Whether H2D and D2H have independent DMA lanes (PCIe is duplex).
    pub duplex: bool,
}

/// Simulation time-dilation factor (see [`DeviceProfile::dilated`]).
///
/// The CPU-PJRT "coprocessor" has a real per-kernel-call floor of
/// 30 µs – 1.3 ms (literal marshalling + dispatch).  Engine pacing is
/// `max(real, modeled)`, so modeled stage times must sit *above* that
/// floor for the device model to govern.  Running the simulated platform
/// 16× slower than the paper's MIC does exactly that while leaving every
/// stage *ratio* (R, overlap fractions, speedups) unchanged — the
/// quantities the paper reports.  Wall-clock numbers in EXPERIMENTS.md
/// are therefore "simulator time" (16× paper time).
pub const DILATION: f64 = 16.0;

impl DeviceProfile {
    /// Xeon Phi 31SP over PCIe gen2 x16 — the paper's platform (§3.2).
    ///
    /// Bandwidths match measured MPSS/COI rates (~6 GB/s); `gflops` is
    /// the *effective single-stream kernel throughput* for the streamed
    /// chunk sizes (deliberately modest: offloaded kernels on one MIC
    /// partition never approach peak).
    pub fn mic31sp() -> Self {
        Self {
            name: "mic31sp".into(),
            h2d_gbps: 6.0,
            d2h_gbps: 6.5,
            latency_us: 15.0,
            alloc_us_per_mb: 70.0,
            gflops: 22.0,
            launch_us: 8.0,
            duplex: true,
        }
    }

    /// NVIDIA K80-like profile for the Fig. 4 platform-divergence study:
    /// PCIe gen3 x16 and ~16x the effective kernel throughput ("huge
    /// processing power ... reduces the KEX fraction significantly").
    pub fn k80() -> Self {
        Self {
            name: "k80".into(),
            h2d_gbps: 10.5,
            d2h_gbps: 11.0,
            latency_us: 10.0,
            alloc_us_per_mb: 40.0,
            gflops: 350.0,
            launch_us: 5.0,
            duplex: true,
        }
    }

    /// AMD Fiji-class profile (R9 Fury X era, PCIe gen3 x16): link
    /// rates close to the K80's but a slightly lower effective kernel
    /// throughput and higher per-transfer setup cost — a third point
    /// for the Fig. 4-style platform-divergence studies and the
    /// service/tuner `--profile` runs.
    pub fn fiji() -> Self {
        Self {
            name: "fiji".into(),
            h2d_gbps: 11.0,
            d2h_gbps: 11.5,
            latency_us: 12.0,
            alloc_us_per_mb: 45.0,
            gflops: 300.0,
            launch_us: 6.0,
            duplex: true,
        }
    }

    /// No pacing at all — ops take their real CPU time only.  For unit
    /// tests and functional validation.
    pub fn instant() -> Self {
        Self {
            name: "instant".into(),
            h2d_gbps: f64::INFINITY,
            d2h_gbps: f64::INFINITY,
            latency_us: 0.0,
            alloc_us_per_mb: 0.0,
            gflops: f64::INFINITY,
            launch_us: 0.0,
            duplex: true,
        }
    }

    /// A slow-link profile (PCIe gen1-ish) for bandwidth-sensitivity
    /// ablations.
    pub fn slow_link() -> Self {
        Self {
            name: "slow-link".into(),
            h2d_gbps: 2.0,
            d2h_gbps: 2.0,
            ..Self::mic31sp()
        }
    }

    /// Slow this profile down by `factor`: bandwidths and compute divide,
    /// latencies multiply.  Every stage *ratio* is preserved.
    pub fn dilated(&self, factor: f64) -> Self {
        Self {
            name: format!("{}-sim", self.name),
            h2d_gbps: self.h2d_gbps / factor,
            d2h_gbps: self.d2h_gbps / factor,
            latency_us: self.latency_us * factor,
            alloc_us_per_mb: self.alloc_us_per_mb * factor,
            gflops: self.gflops / factor,
            launch_us: self.launch_us * factor,
            duplex: self.duplex,
        }
    }

    /// The engine-ready (time-dilated) variant of this profile.
    pub fn simulation(&self) -> Self {
        if self.name.ends_with("-sim") || self.name == "instant" {
            return self.clone();
        }
        self.dilated(DILATION)
    }

    /// Look up a preset by name.
    pub fn preset(name: &str) -> Option<Self> {
        match name {
            "mic31sp" | "mic" => Some(Self::mic31sp()),
            "k80" | "gpu" => Some(Self::k80()),
            "fiji" | "amd" => Some(Self::fiji()),
            "instant" => Some(Self::instant()),
            "slow-link" | "slow" => Some(Self::slow_link()),
            _ => None,
        }
    }

    /// Modeled duration of a transfer of `bytes` in the given direction.
    pub fn transfer_time(&self, bytes: usize, h2d: bool) -> std::time::Duration {
        let bw = if h2d { self.h2d_gbps } else { self.d2h_gbps };
        let secs = self.latency_us * 1e-6 + bytes as f64 / (bw * 1e9);
        std::time::Duration::from_secs_f64(secs.max(0.0))
    }

    /// Modeled lazy-allocation cost for a buffer of `bytes`.
    pub fn alloc_time(&self, bytes: usize) -> std::time::Duration {
        let mb = bytes as f64 / (1024.0 * 1024.0);
        std::time::Duration::from_secs_f64((self.alloc_us_per_mb * mb * 1e-6).max(0.0))
    }

    /// Modeled kernel-execution duration for `flops` floating point ops.
    pub fn kex_time(&self, flops: u64) -> std::time::Duration {
        let secs = self.launch_us * 1e-6 + flops as f64 / (self.gflops * 1e9);
        std::time::Duration::from_secs_f64(secs.max(0.0))
    }
}
