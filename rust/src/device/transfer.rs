//! DMA transfer engine: dedicated thread(s) moving bytes host↔device.
//!
//! Timing is delegated to the context's [`SimClock`]: under
//! `TimeMode::Virtual` the lane computes each job's discrete-event
//! interval and never sleeps; under `TimeMode::Wallclock` the copy is
//! paced to the modeled PCIe link with `pace_to` (the original
//! behaviour).

use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Instant;

use std::sync::mpsc::{channel, Sender};
use std::sync::Mutex;

use crate::hstreams::{Event, Sample};

use super::arena::{DevRegion, DeviceArena};
use super::clock::{OpDesc, OpKind, SimClock, SimTime, TimeMode};
use super::pacing::pace_to;
use super::profile::DeviceProfile;

/// Transfer direction.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Direction {
    H2D,
    D2H,
}

/// Host-side source for an H2D: shared immutable bytes plus a range.
#[derive(Clone)]
pub struct HostSrc {
    pub data: Arc<Vec<u8>>,
    pub off: usize,
    pub len: usize,
}

impl HostSrc {
    pub fn whole(data: Arc<Vec<u8>>) -> Self {
        let len = data.len();
        Self { data, off: 0, len }
    }
}

/// Host-side destination for a D2H: shared mutable bytes plus an offset.
#[derive(Clone)]
pub struct HostDst {
    pub data: Arc<Mutex<Vec<u8>>>,
    pub off: usize,
}

/// One DMA job.
pub struct TransferJob {
    pub dir: Direction,
    /// Present for H2D.
    pub src: Option<HostSrc>,
    /// Present for D2H.
    pub dst: Option<HostDst>,
    pub dev: DevRegion,
    /// Events that must complete before the copy starts (stream order +
    /// explicit cross-stream waits).
    pub deps: Vec<Event>,
    pub done: Event,
    /// Context-wide submission sequence (trace ordering).
    pub seq: u64,
    /// Logical stream that enqueued the job (trace metadata).
    pub stream: u64,
}

enum Msg {
    Job(TransferJob),
    Quit,
}

/// The DMA engine.  With `duplex` profiles, H2D and D2H each get a lane
/// (PCIe has independent directions); otherwise one lane serves both.
pub struct TransferEngine {
    h2d_tx: Sender<Msg>,
    d2h_tx: Sender<Msg>,
    handles: Vec<JoinHandle<()>>,
}

impl TransferEngine {
    pub fn new(
        arena: Arc<Mutex<DeviceArena>>,
        profile: DeviceProfile,
        clock: Arc<SimClock>,
    ) -> Self {
        let (h2d_tx, h2d_rx) = channel::<Msg>();
        let mut handles = Vec::new();
        let d2h_tx;
        if profile.duplex {
            let (tx, d2h_rx) = channel::<Msg>();
            d2h_tx = tx;
            let (a1, p1, c1) = (arena.clone(), profile.clone(), clock.clone());
            handles.push(
                std::thread::Builder::new()
                    .name("hetstream-dma-h2d".into())
                    .spawn(move || lane_loop(h2d_rx, a1, p1, c1, 0))
                    .expect("spawn dma h2d"),
            );
            let (a2, p2) = (arena, profile);
            handles.push(
                std::thread::Builder::new()
                    .name("hetstream-dma-d2h".into())
                    .spawn(move || lane_loop(d2h_rx, a2, p2, clock, 1))
                    .expect("spawn dma d2h"),
            );
        } else {
            // Single half-duplex lane: both directions share the queue.
            d2h_tx = h2d_tx.clone();
            handles.push(
                std::thread::Builder::new()
                    .name("hetstream-dma".into())
                    .spawn(move || lane_loop(h2d_rx, arena, profile, clock, 0))
                    .expect("spawn dma"),
            );
        }
        Self { h2d_tx, d2h_tx, handles }
    }

    /// Enqueue a DMA job (FIFO per lane; the lane waits the job's deps).
    pub fn submit(&self, job: TransferJob) {
        let tx = match job.dir {
            Direction::H2D => &self.h2d_tx,
            Direction::D2H => &self.d2h_tx,
        };
        tx.send(Msg::Job(job)).expect("dma lane alive");
    }

    /// Stop the lanes and join the threads.
    pub fn shutdown(&mut self) {
        let _ = self.h2d_tx.send(Msg::Quit);
        let _ = self.d2h_tx.send(Msg::Quit);
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

impl Drop for TransferEngine {
    fn drop(&mut self) {
        self.shutdown();
    }
}

fn lane_loop(
    rx: std::sync::mpsc::Receiver<Msg>,
    arena: Arc<Mutex<DeviceArena>>,
    profile: DeviceProfile,
    clock: Arc<SimClock>,
    lane: usize,
) {
    let lane_name = match (profile.duplex, lane) {
        (true, 0) => "h2d",
        (true, _) => "d2h",
        // Half-duplex: one physical lane carries both directions.
        (false, _) => "dma",
    };
    while let Ok(Msg::Job(job)) = rx.recv() {
        // In-order lane semantics: the lane head blocks on its deps,
        // exactly like a hardware DMA queue waiting on an event.
        let mut deps_end = SimTime::ZERO;
        for dep in &job.deps {
            deps_end = deps_end.max(dep.wait().end);
        }
        let wall_start = Instant::now();
        let mut modeled = profile.transfer_time(job.dev.len, job.dir == Direction::H2D);
        match job.dir {
            Direction::H2D => {
                let src = job.src.as_ref().expect("h2d needs src");
                let bytes = &src.data[src.off..src.off + src.len];
                let first_touch = {
                    let mut a = arena.lock().unwrap();
                    a.write(job.dev, bytes).expect("h2d write")
                };
                if first_touch {
                    // Lazy allocation (paper §3.3): the allocation cost
                    // lands inside the first H2D that touches the buffer.
                    modeled += profile.alloc_time(job.dev.len);
                }
            }
            Direction::D2H => {
                let bytes = {
                    let a = arena.lock().unwrap();
                    a.read(job.dev).expect("d2h read")
                };
                let dst = job.dst.as_ref().expect("d2h needs dst");
                let mut out = dst.data.lock().unwrap();
                out[dst.off..dst.off + bytes.len()].copy_from_slice(&bytes);
            }
        }
        let desc = OpDesc {
            seq: job.seq,
            kind: match job.dir {
                Direction::H2D => OpKind::H2d,
                Direction::D2H => OpKind::D2h,
            },
            stream: job.stream,
            label: String::new(),
            bytes: job.dev.len as u64,
            flops: 0,
        };
        let sample = match clock.mode() {
            TimeMode::Virtual => {
                let (start, end) =
                    clock.schedule_transfer(lane, lane_name, deps_end, modeled, &desc);
                Sample { start, end }
            }
            TimeMode::Wallclock => {
                pace_to(wall_start, modeled);
                let start = clock.wall(wall_start);
                let end = clock.wall(Instant::now());
                clock.record_wall(lane_name, start, end, &desc);
                Sample { start, end }
            }
        };
        job.done.complete(sample);
    }
}
