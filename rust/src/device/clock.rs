//! `SimClock` — the discrete-event virtual clock behind the engines.
//!
//! Under [`TimeMode::Virtual`] (the default) no engine ever sleeps:
//! every op's virtual interval is *computed* instead of *waited out*,
//! from exactly the quantities the hardware model defines —
//!
//! ```text
//! start = max(resource available, latest dependency end)
//! end   = start + modeled duration
//! ```
//!
//! Resources are the same ones the thread structure models: one DMA
//! lane per direction (or a shared lane for half-duplex profiles) and
//! `workers` kernel queues.  Transfer lanes are single-threaded FIFOs,
//! so their availability is owned by the lane thread and the timeline
//! follows submission order by construction.  Kernel jobs may be
//! claimed by racing OS workers, so the clock *admits* them in
//! submission order (`kex_seq`) and assigns each to the earliest-free
//! modeled worker (ties to the lowest index) — a greedy list schedule
//! that is deterministic regardless of which OS thread runs the math.
//!
//! The result: a full multi-stream simulation is byte-reproducible
//! run-to-run and completes as fast as the host can do the memcpys and
//! kernel math — milliseconds of wall time for seconds of modeled time.
//!
//! Under [`TimeMode::Wallclock`] the engines keep the original
//! behaviour (`pace_to` spin/sleep to the modeled deadline) and the
//! clock merely translates `Instant`s into offsets from the context
//! epoch, so both modes publish the same [`SimTime`]-based samples.
//!
//! The clock can also record a trace of every retired op
//! ([`TraceEntry`]), sorted by submission sequence — the basis of the
//! golden-trace regression test and `repro`'s timeline dumps.

use std::sync::{Condvar, Mutex};
use std::time::{Duration, Instant};

/// How the engines account time.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TimeMode {
    /// Discrete-event virtual time: deterministic, instant replay.
    Virtual,
    /// Original behaviour: ops occupy their modeled duration in real
    /// time (`pace_to`), timestamps are wall-clock offsets.
    Wallclock,
}

impl TimeMode {
    /// Session default: `Virtual`, unless `HETSTREAM_TIME=wallclock`
    /// opts the paper-fidelity benches back into real pacing.
    pub fn from_env_default() -> Self {
        match std::env::var("HETSTREAM_TIME").as_deref() {
            Ok("wallclock") | Ok("wall") | Ok("real") => TimeMode::Wallclock,
            _ => TimeMode::Virtual,
        }
    }
}

/// A point on the simulation timeline: nanoseconds since the context
/// epoch.  Total-ordered, `Copy`, and mode-agnostic — wall-clock mode
/// publishes the same type, measured from the same epoch.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash, Default)]
pub struct SimTime(u64);

impl SimTime {
    pub const ZERO: SimTime = SimTime(0);

    pub fn from_nanos(ns: u64) -> Self {
        SimTime(ns)
    }

    pub fn as_nanos(self) -> u64 {
        self.0
    }

    /// Offset from the epoch as a `Duration`.
    pub fn as_duration(self) -> Duration {
        Duration::from_nanos(self.0)
    }

    /// `self - earlier`, saturating at zero.
    pub fn since(self, earlier: SimTime) -> Duration {
        Duration::from_nanos(self.0.saturating_sub(earlier.0))
    }
}

impl std::ops::Add<Duration> for SimTime {
    type Output = SimTime;

    fn add(self, d: Duration) -> SimTime {
        SimTime(self.0.saturating_add(d.as_nanos() as u64))
    }
}

impl std::ops::Sub<SimTime> for SimTime {
    type Output = Duration;

    fn sub(self, rhs: SimTime) -> Duration {
        self.since(rhs)
    }
}

/// What kind of op a trace entry describes.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum OpKind {
    H2d,
    D2h,
    Kex,
}

impl OpKind {
    pub fn label(self) -> &'static str {
        match self {
            OpKind::H2d => "h2d",
            OpKind::D2h => "d2h",
            OpKind::Kex => "kex",
        }
    }
}

/// One retired op on the virtual timeline.
#[derive(Debug, Clone)]
pub struct TraceEntry {
    /// Context-wide submission sequence (the deterministic sort key).
    pub seq: u64,
    pub kind: OpKind,
    /// Modeled resource: `"h2d"`, `"d2h"`, or `"kex<N>"`.
    pub lane: String,
    /// Logical stream that enqueued the op.
    pub stream: u64,
    /// Artifact name for KEX, empty for transfers.
    pub label: String,
    /// Payload bytes for transfers, 0 for KEX.
    pub bytes: u64,
    /// FLOP budget for KEX (repeats included), 0 for transfers.
    pub flops: u64,
    pub start: SimTime,
    pub end: SimTime,
}

impl TraceEntry {
    /// One canonical JSON object (stable field order, no whitespace
    /// variation) — the golden-trace format.
    pub fn to_json(&self) -> String {
        format!(
            "{{\"seq\":{},\"kind\":\"{}\",\"lane\":\"{}\",\"stream\":{},\"label\":\"{}\",\
             \"bytes\":{},\"flops\":{},\"start_ns\":{},\"end_ns\":{}}}",
            self.seq,
            self.kind.label(),
            crate::util::json::escape(&self.lane),
            self.stream,
            crate::util::json::escape(&self.label),
            self.bytes,
            self.flops,
            self.start.as_nanos(),
            self.end.as_nanos(),
        )
    }
}

/// Descriptor the engines hand the clock alongside each schedule call
/// (trace metadata; has no effect on the timeline itself).
#[derive(Debug, Clone)]
pub struct OpDesc {
    pub seq: u64,
    pub kind: OpKind,
    pub stream: u64,
    pub label: String,
    pub bytes: u64,
    pub flops: u64,
}

struct ClockInner {
    /// Transfer-lane availability: `[h2d-thread, d2h-thread]`.  A
    /// half-duplex profile routes both directions through lane 0.
    xfer_avail: [u64; 2],
    /// Modeled kernel-queue availability, one slot per worker.
    workers: Vec<u64>,
    /// Next kernel submission sequence allowed to schedule (admission
    /// gate making multi-worker timelines deterministic).
    next_kex_admit: u64,
    /// Sequences abandoned by a panicking worker — skipped by the
    /// admission gate so one dead kernel cannot wedge the engine.
    abandoned_kex: std::collections::BTreeSet<u64>,
    /// High-water mark of the timeline (virtual mode).
    horizon: u64,
    trace: Option<Vec<TraceEntry>>,
}

/// The context-wide time authority shared by both engines.
pub struct SimClock {
    mode: TimeMode,
    epoch: Instant,
    /// Immutable after construction; lets wall-clock retire paths skip
    /// the mutex entirely when tracing is off.
    trace_enabled: bool,
    inner: Mutex<ClockInner>,
    admit_cv: Condvar,
}

impl SimClock {
    /// A clock for `workers` modeled kernel queues.  `record_trace`
    /// keeps a [`TraceEntry`] per retired op.
    pub fn new(mode: TimeMode, workers: usize, record_trace: bool) -> Self {
        Self {
            mode,
            epoch: Instant::now(),
            trace_enabled: record_trace,
            inner: Mutex::new(ClockInner {
                xfer_avail: [0; 2],
                workers: vec![0; workers.max(1)],
                next_kex_admit: 0,
                abandoned_kex: std::collections::BTreeSet::new(),
                horizon: 0,
                trace: if record_trace { Some(Vec::new()) } else { None },
            }),
            admit_cv: Condvar::new(),
        }
    }

    pub fn mode(&self) -> TimeMode {
        self.mode
    }

    /// Translate a wall-clock instant into a timeline point (wall mode).
    pub fn wall(&self, t: Instant) -> SimTime {
        SimTime(t.saturating_duration_since(self.epoch).as_nanos() as u64)
    }

    /// Latest point any op has reached on the timeline.
    pub fn now(&self) -> SimTime {
        match self.mode {
            TimeMode::Virtual => SimTime(self.inner.lock().unwrap().horizon),
            TimeMode::Wallclock => self.wall(Instant::now()),
        }
    }

    /// Align every modeled lane to the timeline horizon (virtual mode;
    /// a no-op under wall clock).  Call only with the engines drained —
    /// i.e. after every submitted op has retired.  This is the
    /// measurement-isolation barrier between independent runs: without
    /// it, a run's makespan inherits whatever per-lane stagger the
    /// *previous* run left behind (its D2H tail keeps that lane busy
    /// past the point the H2D lane went idle), making measured times
    /// depend on the order runs happen to execute in — poison for a
    /// grid search that compares points against each other.
    pub fn quiesce(&self) {
        if self.mode != TimeMode::Virtual {
            return;
        }
        let mut inner = self.inner.lock().unwrap();
        let h = inner.horizon;
        inner.xfer_avail = [h; 2];
        for w in inner.workers.iter_mut() {
            *w = h;
        }
    }

    /// Virtual-mode transfer scheduling: FIFO lane `lane` (0 = the
    /// h2d-queue thread, 1 = the d2h-queue thread), earliest start after
    /// `deps_end`, occupying `dur`.
    pub fn schedule_transfer(
        &self,
        lane: usize,
        lane_name: &str,
        deps_end: SimTime,
        dur: Duration,
        desc: &OpDesc,
    ) -> (SimTime, SimTime) {
        debug_assert!(self.mode == TimeMode::Virtual);
        let mut inner = self.inner.lock().unwrap();
        let start = inner.xfer_avail[lane.min(1)].max(deps_end.0);
        let end = start.saturating_add(dur.as_nanos() as u64);
        inner.xfer_avail[lane.min(1)] = end;
        inner.horizon = inner.horizon.max(end);
        Self::push_trace(&mut inner, desc, lane_name.to_string(), start, end);
        (SimTime(start), SimTime(end))
    }

    /// Virtual-mode kernel scheduling.  Blocks until every kernel with a
    /// smaller `kex_seq` has been scheduled (submission-order admission),
    /// then assigns the job to the earliest-available modeled worker.
    pub fn schedule_kex(
        &self,
        kex_seq: u64,
        deps_end: SimTime,
        dur: Duration,
        desc: &OpDesc,
    ) -> (SimTime, SimTime) {
        debug_assert!(self.mode == TimeMode::Virtual);
        let mut inner = self.inner.lock().unwrap();
        while inner.next_kex_admit != kex_seq {
            inner = self.admit_cv.wait(inner).unwrap();
        }
        // Greedy list schedule: earliest-free worker, ties to index 0.
        let (w, _) = inner
            .workers
            .iter()
            .copied()
            .enumerate()
            .min_by_key(|&(i, avail)| (avail, i))
            .expect("at least one worker");
        let start = inner.workers[w].max(deps_end.0);
        let end = start.saturating_add(dur.as_nanos() as u64);
        inner.workers[w] = end;
        inner.horizon = inner.horizon.max(end);
        inner.next_kex_admit += 1;
        Self::drain_abandoned(&mut inner);
        Self::push_trace(&mut inner, desc, format!("kex{w}"), start, end);
        drop(inner);
        self.admit_cv.notify_all();
        (SimTime(start), SimTime(end))
    }

    /// Mark a kernel sequence as never-to-schedule (its worker is
    /// unwinding).  The admission gate skips it so later kernels — and
    /// engine shutdown — are not wedged behind a dead job.
    pub fn abandon_kex(&self, kex_seq: u64) {
        let mut inner = self.inner.lock().unwrap();
        inner.abandoned_kex.insert(kex_seq);
        Self::drain_abandoned(&mut inner);
        drop(inner);
        self.admit_cv.notify_all();
    }

    fn drain_abandoned(inner: &mut ClockInner) {
        while inner.abandoned_kex.remove(&inner.next_kex_admit) {
            inner.next_kex_admit += 1;
        }
    }

    /// Wall-clock mode: record an already-timed span (trace parity with
    /// virtual mode; the timeline state is not consulted).  A no-op
    /// without tracing — wall-clock retire paths must not contend on
    /// the clock mutex, that mode exists for timing fidelity.
    pub fn record_wall(&self, lane: &str, start: SimTime, end: SimTime, desc: &OpDesc) {
        if !self.trace_enabled {
            return;
        }
        let mut inner = self.inner.lock().unwrap();
        inner.horizon = inner.horizon.max(end.0);
        Self::push_trace(&mut inner, desc, lane.to_string(), start, end);
    }

    fn push_trace(
        inner: &mut ClockInner,
        desc: &OpDesc,
        lane: String,
        start: u64,
        end: u64,
    ) {
        if let Some(trace) = &mut inner.trace {
            trace.push(TraceEntry {
                seq: desc.seq,
                kind: desc.kind,
                lane,
                stream: desc.stream,
                label: desc.label.clone(),
                bytes: desc.bytes,
                flops: desc.flops,
                start: SimTime(start),
                end: SimTime(end),
            });
        }
    }

    /// The recorded trace, sorted by submission sequence (deterministic
    /// regardless of which OS thread retired which op).  Empty when
    /// trace recording is off.
    pub fn trace(&self) -> Vec<TraceEntry> {
        let inner = self.inner.lock().unwrap();
        let mut t = inner.trace.clone().unwrap_or_default();
        t.sort_by_key(|e| e.seq);
        t
    }

    /// Serialize the trace as canonical JSON (one event object per
    /// line) — the golden-trace interchange format.
    pub fn trace_json(&self) -> String {
        let entries = self.trace();
        let mut out = String::from("{\"version\":1,\"events\":[\n");
        for (i, e) in entries.iter().enumerate() {
            out.push_str(&e.to_json());
            if i + 1 < entries.len() {
                out.push(',');
            }
            out.push('\n');
        }
        out.push_str("]}\n");
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn desc(seq: u64) -> OpDesc {
        OpDesc { seq, kind: OpKind::H2d, stream: 0, label: String::new(), bytes: 0, flops: 0 }
    }

    #[test]
    fn lane_is_fifo_and_respects_deps() {
        let c = SimClock::new(TimeMode::Virtual, 1, false);
        let (s0, e0) =
            c.schedule_transfer(0, "h2d", SimTime::ZERO, Duration::from_nanos(100), &desc(0));
        assert_eq!(s0, SimTime::ZERO);
        assert_eq!(e0.as_nanos(), 100);
        // Lane busy until 100 even though deps are ready at 0.
        let (s1, e1) =
            c.schedule_transfer(0, "h2d", SimTime::ZERO, Duration::from_nanos(50), &desc(1));
        assert_eq!(s1.as_nanos(), 100);
        assert_eq!(e1.as_nanos(), 150);
        // A dependency beyond the lane availability delays the start.
        let later = SimTime::from_nanos(400);
        let (s2, _) = c.schedule_transfer(0, "h2d", later, Duration::from_nanos(10), &desc(2));
        assert_eq!(s2.as_nanos(), 400);
        // The other lane is independent.
        let (s3, _) =
            c.schedule_transfer(1, "d2h", SimTime::ZERO, Duration::from_nanos(10), &desc(3));
        assert_eq!(s3, SimTime::ZERO);
    }

    #[test]
    fn kex_picks_earliest_worker() {
        let c = SimClock::new(TimeMode::Virtual, 2, false);
        let (s0, e0) = c.schedule_kex(0, SimTime::ZERO, Duration::from_nanos(100), &desc(0));
        assert_eq!((s0.as_nanos(), e0.as_nanos()), (0, 100));
        // Second job lands on the idle worker 1.
        let (s1, _) = c.schedule_kex(1, SimTime::ZERO, Duration::from_nanos(100), &desc(1));
        assert_eq!(s1.as_nanos(), 0);
        // Third job waits for the earliest of the two.
        let (s2, _) = c.schedule_kex(2, SimTime::ZERO, Duration::from_nanos(10), &desc(2));
        assert_eq!(s2.as_nanos(), 100);
    }

    #[test]
    fn trace_sorted_by_submission_seq() {
        let c = SimClock::new(TimeMode::Virtual, 1, true);
        c.schedule_transfer(0, "h2d", SimTime::ZERO, Duration::from_nanos(5), &desc(2));
        c.schedule_transfer(1, "h2d", SimTime::ZERO, Duration::from_nanos(5), &desc(0));
        c.schedule_transfer(0, "h2d", SimTime::ZERO, Duration::from_nanos(5), &desc(1));
        let t = c.trace();
        let seqs: Vec<u64> = t.iter().map(|e| e.seq).collect();
        assert_eq!(seqs, vec![0, 1, 2]);
    }

    #[test]
    fn quiesce_aligns_lanes_to_horizon() {
        let c = SimClock::new(TimeMode::Virtual, 2, false);
        // Leave the lanes staggered: h2d busy to 100, d2h idle at 0.
        c.schedule_transfer(0, "h2d", SimTime::ZERO, Duration::from_nanos(100), &desc(0));
        c.schedule_kex(0, SimTime::ZERO, Duration::from_nanos(40), &desc(1));
        c.quiesce();
        // Every lane now starts at the horizon (100): the next op on any
        // lane begins there, not at its own stale availability.
        let (s, _) =
            c.schedule_transfer(1, "d2h", SimTime::ZERO, Duration::from_nanos(10), &desc(2));
        assert_eq!(s.as_nanos(), 100);
        let (sk, _) = c.schedule_kex(1, SimTime::ZERO, Duration::from_nanos(10), &desc(3));
        assert_eq!(sk.as_nanos(), 100, "both modeled workers re-aligned");
    }

    #[test]
    fn sim_time_arithmetic() {
        let a = SimTime::from_nanos(250);
        let b = a + Duration::from_nanos(50);
        assert_eq!(b.as_nanos(), 300);
        assert_eq!(b - a, Duration::from_nanos(50));
        assert_eq!(a - b, Duration::ZERO, "saturating");
        assert_eq!(b.as_duration(), Duration::from_nanos(300));
    }
}
