//! Compute engine: the "coprocessor" — worker thread(s) executing the
//! AOT-compiled XLA/Pallas artifacts through PJRT.
//!
//! Each worker owns its own [`ArtifactStore`] (PJRT handles are not
//! `Send`).  One worker models one coprocessor kernel queue; more
//! workers model hStreams-style core partitioning where small kernels
//! from different streams run concurrently (an ablation knob).

use std::path::PathBuf;
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Instant;

use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Mutex;

use crate::hstreams::{Event, Sample};
use crate::runtime::ArtifactStore;

use super::arena::{DevRegion, DeviceArena};
use super::pacing::pace_to;
use super::profile::DeviceProfile;

/// One kernel launch: read device inputs, execute `artifact`, write the
/// outputs back into device memory.
pub struct KernelJob {
    pub artifact: String,
    pub inputs: Vec<DevRegion>,
    pub outputs: Vec<DevRegion>,
    /// Overrides the manifest's per-call FLOP estimate for KEX pacing
    /// (descriptor-backed corpus entries set their own budget).
    pub flops: Option<u64>,
    /// Execute the artifact this many times (iterative kernels; KEX
    /// pacing covers `repeats * flops`).
    pub repeats: u32,
    pub deps: Vec<Event>,
    pub done: Event,
}

enum Msg {
    Job(KernelJob),
    Quit,
}

/// The device's kernel-execution resource.
pub struct ComputeEngine {
    tx: Sender<Msg>,
    handles: Vec<JoinHandle<()>>,
}

impl ComputeEngine {
    /// Spawn `workers` kernel queues over the artifacts in `dir`.
    /// `artifact_subset` limits compilation to the named kernels (much
    /// faster startup); `None` compiles everything in the manifest.
    pub fn new(
        arena: Arc<Mutex<DeviceArena>>,
        profile: DeviceProfile,
        dir: PathBuf,
        workers: usize,
        artifact_subset: Option<Vec<String>>,
    ) -> Self {
        let (tx, rx) = channel::<Msg>();
        let rx = Arc::new(Mutex::new(rx));
        let mut handles = Vec::new();
        for w in 0..workers.max(1) {
            let (a, p, d, s) = (arena.clone(), profile.clone(), dir.clone(), artifact_subset.clone());
            // std mpsc receivers are single-consumer; workers share one
            // behind a mutex and claim jobs first-come, first-served.
            let worker_rx = rx.clone();
            handles.push(
                std::thread::Builder::new()
                    .name(format!("hetstream-kex-{w}"))
                    .spawn(move || worker_loop(worker_rx, a, p, d, s))
                    .expect("spawn kex worker"),
            );
        }
        Self { tx, handles }
    }

    /// Enqueue a kernel launch (FIFO; a worker waits the job's deps).
    pub fn submit(&self, job: KernelJob) {
        self.tx.send(Msg::Job(job)).expect("kex queue alive");
    }

    /// Stop the workers and join.
    pub fn shutdown(&mut self) {
        for _ in 0..self.handles.len() {
            let _ = self.tx.send(Msg::Quit);
        }
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

impl Drop for ComputeEngine {
    fn drop(&mut self) {
        self.shutdown();
    }
}

fn worker_loop(
    rx: Arc<Mutex<Receiver<Msg>>>,
    arena: Arc<Mutex<DeviceArena>>,
    profile: DeviceProfile,
    dir: PathBuf,
    subset: Option<Vec<String>>,
) {
    // PJRT client + compiled executables live on this thread.
    let store = match &subset {
        Some(names) => {
            let refs: Vec<&str> = names.iter().map(|s| s.as_str()).collect();
            ArtifactStore::load_subset(&dir, &refs)
        }
        None => ArtifactStore::load(&dir),
    }
    .expect("load artifacts");

    loop {
        let msg = { rx.lock().unwrap().recv() };
        let job = match msg {
            Ok(Msg::Job(j)) => j,
            _ => return,
        };
        for dep in &job.deps {
            dep.wait();
        }
        let start = Instant::now();

        // Read inputs out of device memory (brief lock), execute, write
        // outputs back.  The copy is the host-side shadow of the device's
        // own memory traffic; KEX pacing dominates it.
        let input_bytes: Vec<Vec<u8>> = {
            let a = arena.lock().unwrap();
            job.inputs.iter().map(|r| a.read(*r).expect("kex input read")).collect()
        };
        let input_refs: Vec<&[u8]> = input_bytes.iter().map(|b| b.as_slice()).collect();

        let mut outputs = Vec::new();
        for _ in 0..job.repeats.max(1) {
            outputs = store.execute_bytes(&job.artifact, &input_refs).expect("kex execute");
        }
        {
            let mut a = arena.lock().unwrap();
            for (region, bytes) in job.outputs.iter().zip(&outputs) {
                a.write(*region, bytes).expect("kex output write");
            }
        }

        let flops = job.flops.unwrap_or_else(|| {
            store.meta(&job.artifact).map(|m| m.flops_per_call).unwrap_or(0)
        }) * job.repeats.max(1) as u64;
        pace_to(start, profile.kex_time(flops));
        job.done.complete(Sample { start, end: Instant::now() });
    }
}
