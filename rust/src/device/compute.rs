//! Compute engine: the "coprocessor" — worker thread(s) executing the
//! AOT-compiled kernels through the [`crate::runtime::ArtifactStore`]
//! (pure-Rust interpreter by default, PJRT under `--features pjrt`).
//!
//! One worker models one coprocessor kernel queue; more workers model
//! hStreams-style core partitioning where small kernels from different
//! streams run concurrently (an ablation knob).  Timing is delegated to
//! the context's [`SimClock`]: virtual mode computes each launch's
//! discrete-event interval (deterministic even with racing OS workers,
//! thanks to submission-order admission), wall-clock mode paces with
//! `max(real execution, modeled)` as before.

use std::path::PathBuf;
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Instant;

use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Mutex;

use crate::hstreams::{Event, Sample};
use crate::runtime::ArtifactStore;

use super::arena::{DevRegion, DeviceArena};
use super::clock::{OpDesc, OpKind, SimClock, SimTime, TimeMode};
use super::pacing::pace_to;
use super::profile::DeviceProfile;

/// One kernel launch: read device inputs, execute `artifact`, write the
/// outputs back into device memory.
pub struct KernelJob {
    pub artifact: String,
    pub inputs: Vec<DevRegion>,
    pub outputs: Vec<DevRegion>,
    /// Overrides the manifest's per-call FLOP estimate for KEX pacing
    /// (descriptor-backed corpus entries set their own budget).
    pub flops: Option<u64>,
    /// Execute the artifact this many times (iterative kernels; KEX
    /// pacing covers `repeats * flops`).
    pub repeats: u32,
    pub deps: Vec<Event>,
    pub done: Event,
    /// Context-wide submission sequence (trace ordering).
    pub seq: u64,
    /// Logical stream that enqueued the job (trace metadata).
    pub stream: u64,
}

struct SeqJob {
    job: KernelJob,
    /// Dense per-engine submission index — the clock's admission order.
    kex_seq: u64,
}

enum Msg {
    Job(SeqJob),
    Quit,
}

/// Submission side of the kernel queue.  Sequence assignment and send
/// live behind one lock so channel order always equals `kex_seq`
/// order — the clock's admission gate relies on claimed jobs arriving
/// in submission order, and keeping the counter inside the mutex makes
/// that invariant structural rather than conventional.
struct KexQueue {
    tx: Sender<Msg>,
    next_seq: u64,
}

/// The device's kernel-execution resource.
pub struct ComputeEngine {
    queue: Mutex<KexQueue>,
    handles: Vec<JoinHandle<()>>,
}

impl ComputeEngine {
    /// Spawn `workers` kernel queues over the artifacts in `dir`.
    /// `artifact_subset` limits compilation to the named kernels (much
    /// faster startup); `None` compiles everything in the manifest.
    pub fn new(
        arena: Arc<Mutex<DeviceArena>>,
        profile: DeviceProfile,
        dir: PathBuf,
        workers: usize,
        artifact_subset: Option<Vec<String>>,
        clock: Arc<SimClock>,
    ) -> Self {
        let (tx, rx) = channel::<Msg>();
        let rx = Arc::new(Mutex::new(rx));
        let mut handles = Vec::new();
        for w in 0..workers.max(1) {
            let (a, p, d, s) =
                (arena.clone(), profile.clone(), dir.clone(), artifact_subset.clone());
            let c = clock.clone();
            // std mpsc receivers are single-consumer; workers share one
            // behind a mutex and claim jobs first-come, first-served.
            let worker_rx = rx.clone();
            handles.push(
                std::thread::Builder::new()
                    .name(format!("hetstream-kex-{w}"))
                    .spawn(move || worker_loop(worker_rx, a, p, d, s, c, w))
                    .expect("spawn kex worker"),
            );
        }
        Self { queue: Mutex::new(KexQueue { tx, next_seq: 0 }), handles }
    }

    /// Enqueue a kernel launch (FIFO; a worker waits the job's deps).
    pub fn submit(&self, job: KernelJob) {
        let mut q = self.queue.lock().unwrap();
        let kex_seq = q.next_seq;
        q.next_seq += 1;
        q.tx.send(Msg::Job(SeqJob { job, kex_seq })).expect("kex queue alive");
    }

    /// Stop the workers and join.
    pub fn shutdown(&mut self) {
        {
            let q = self.queue.lock().unwrap();
            for _ in 0..self.handles.len() {
                let _ = q.tx.send(Msg::Quit);
            }
        }
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

impl Drop for ComputeEngine {
    fn drop(&mut self) {
        self.shutdown();
    }
}

/// Releases a claimed admission slot if the worker unwinds before
/// scheduling (a panicking kernel must not wedge the admission gate —
/// later kernels and engine shutdown would block forever).
struct AdmitGuard<'a> {
    clock: &'a SimClock,
    kex_seq: u64,
    armed: bool,
}

impl Drop for AdmitGuard<'_> {
    fn drop(&mut self) {
        if self.armed {
            self.clock.abandon_kex(self.kex_seq);
        }
    }
}

fn worker_loop(
    rx: Arc<Mutex<Receiver<Msg>>>,
    arena: Arc<Mutex<DeviceArena>>,
    profile: DeviceProfile,
    dir: PathBuf,
    subset: Option<Vec<String>>,
    clock: Arc<SimClock>,
    worker: usize,
) {
    // The kernel backend lives on this thread (PJRT handles are !Send;
    // the sim interpreter simply has no shared state).
    let store = match &subset {
        Some(names) => {
            let refs: Vec<&str> = names.iter().map(|s| s.as_str()).collect();
            ArtifactStore::load_subset(&dir, &refs)
        }
        None => ArtifactStore::load(&dir),
    }
    .expect("load artifacts");

    // Wallclock mode records the physical queue; computed once, not
    // per retire (the no-trace retire path must stay allocation-free).
    let wall_lane = format!("kex{worker}");

    loop {
        let msg = { rx.lock().unwrap().recv() };
        let SeqJob { job, kex_seq } = match msg {
            Ok(Msg::Job(j)) => j,
            _ => return,
        };
        let mut guard = AdmitGuard {
            clock: &clock,
            kex_seq,
            armed: clock.mode() == TimeMode::Virtual,
        };
        let mut deps_end = SimTime::ZERO;
        for dep in &job.deps {
            deps_end = deps_end.max(dep.wait().end);
        }
        let wall_start = Instant::now();

        // Read inputs out of device memory (brief lock), execute, write
        // outputs back.  The copy is the host-side shadow of the device's
        // own memory traffic; KEX pacing dominates it.
        let input_bytes: Vec<Vec<u8>> = {
            let a = arena.lock().unwrap();
            job.inputs.iter().map(|r| a.read(*r).expect("kex input read")).collect()
        };
        let input_refs: Vec<&[u8]> = input_bytes.iter().map(|b| b.as_slice()).collect();

        let mut outputs = Vec::new();
        for _ in 0..job.repeats.max(1) {
            outputs = store.execute_bytes(&job.artifact, &input_refs).expect("kex execute");
        }
        {
            let mut a = arena.lock().unwrap();
            for (region, bytes) in job.outputs.iter().zip(&outputs) {
                a.write(*region, bytes).expect("kex output write");
            }
        }

        let flops = job.flops.unwrap_or_else(|| {
            store.meta(&job.artifact).map(|m| m.flops_per_call).unwrap_or(0)
        }) * job.repeats.max(1) as u64;
        let modeled = profile.kex_time(flops);
        let desc = OpDesc {
            seq: job.seq,
            kind: OpKind::Kex,
            stream: job.stream,
            label: job.artifact.clone(),
            bytes: 0,
            flops,
        };
        let sample = match clock.mode() {
            TimeMode::Virtual => {
                let (start, end) = clock.schedule_kex(kex_seq, deps_end, modeled, &desc);
                guard.armed = false;
                Sample { start, end }
            }
            TimeMode::Wallclock => {
                pace_to(wall_start, modeled);
                let start = clock.wall(wall_start);
                let end = clock.wall(Instant::now());
                // In wall-clock mode the OS worker *is* the physical
                // queue — same `kex<N>` vocabulary as virtual mode.
                clock.record_wall(&wall_lane, start, end, &desc);
                Sample { start, end }
            }
        };
        drop(guard);
        job.done.complete(sample);
    }
}
