//! Precise pacing: make an operation occupy its modeled duration.

use std::time::{Duration, Instant};

/// Sleep/spin until `start + modeled` has elapsed.  Durations under
/// ~120 µs are spin-waited (OS sleep granularity would distort the DMA
/// model); longer waits sleep most of the interval and spin the tail.
pub fn pace_to(start: Instant, modeled: Duration) {
    let deadline = start + modeled;
    loop {
        let now = Instant::now();
        if now >= deadline {
            return;
        }
        let remaining = deadline - now;
        if remaining > Duration::from_micros(200) {
            std::thread::sleep(remaining - Duration::from_micros(120));
        } else {
            std::hint::spin_loop();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pace_reaches_deadline() {
        let t0 = Instant::now();
        pace_to(t0, Duration::from_micros(500));
        assert!(t0.elapsed() >= Duration::from_micros(500));
        // and not wildly over (sleep/spin hybrid should be tight)
        assert!(t0.elapsed() < Duration::from_millis(50));
    }

    #[test]
    fn pace_past_deadline_returns_immediately() {
        let t0 = Instant::now() - Duration::from_millis(5);
        let before = Instant::now();
        pace_to(t0, Duration::from_millis(1));
        assert!(before.elapsed() < Duration::from_millis(2));
    }
}
