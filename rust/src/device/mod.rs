//! Simulated heterogeneous platform: host + coprocessor over a modeled
//! PCIe link.
//!
//! The paper's testbed (dual Xeon + Xeon Phi 31SP over PCIe, MPSS/COI
//! DMA) is substituted per DESIGN.md §2 by three cooperating pieces:
//!
//! - [`DeviceArena`] — coprocessor memory with the *lazy allocation*
//!   semantics of §3.3 (allocation cost is charged into the first H2D
//!   that touches a buffer).
//! - [`TransferEngine`] — a dedicated DMA thread per direction that
//!   performs real memcpys **paced** to a modeled link
//!   (latency + bytes/bandwidth), so transfers occupy a real hardware
//!   resource distinct from compute.
//! - [`ComputeEngine`] — worker thread(s) owning a PJRT CPU client each,
//!   executing the AOT-compiled XLA/Pallas artifacts; kernel time is
//!   `max(real execution, flops / modeled_gflops)` so device compute
//!   capability is a [`DeviceProfile`] knob (Fig. 4's platform study).
//!
//! Because transfer and compute run on *different OS threads*, H2D of
//! one task genuinely overlaps KEX of another — multi-stream speedups
//! measured on this simulator are real wall-clock effects, not modeled
//! arithmetic.
//!
//! Time itself is owned by the [`SimClock`]: `TimeMode::Virtual` (the
//! default) replaces pacing with a deterministic discrete-event
//! timeline so full experiment sweeps replay instantly and
//! byte-identically; `TimeMode::Wallclock` keeps the original
//! paced-in-real-time behaviour (see DESIGN.md §Time).

mod arena;
mod clock;
mod compute;
mod pacing;
mod profile;
mod transfer;

pub use arena::{BufId, DevRegion, DeviceArena};
pub use clock::{OpDesc, OpKind, SimClock, SimTime, TimeMode, TraceEntry};
pub use compute::{ComputeEngine, KernelJob};
pub use pacing::pace_to;
pub use profile::{DeviceProfile, DILATION};
pub use transfer::{Direction, HostDst, HostSrc, TransferEngine, TransferJob};
