//! [`SpecCompiler`]: one spec-driven lowering path from
//! [`WorkloadSpec`] to [`StreamPlan`] — the only place in the repo
//! that builds category-shaped task DAGs.
//!
//! One composable builder per category/discipline:
//!
//! - **bulk / block fan-out / block wavefront** — the historical
//!   corpus construction (fixed kernel block, aligned payload
//!   partition, zero-source padding), moved here verbatim from
//!   `plan/lower.rs` so descriptor-derived plans stay op-for-op
//!   identical to what the Python mirror independently derives.
//! - **windows** — exact task windows: elastic kernels run whole
//!   windows, fixed-shape kernels tile inside them, stages chain per
//!   task with explicit RAW deps; false-dependent specs extend each
//!   window by (possibly asymmetric) halo ratios and download only the
//!   owned range.
//! - **pingpong** — chunked uploads on alternating lanes feeding a
//!   pure RAW kernel chain (hotspot's Iterative shape).
//! - **tiles** — the NW wavefront: broadcast boundary vectors,
//!   per-tile payloads, device-resident south/east edges, deps wired
//!   by [`wire_wavefront`].
//!
//! The compiler also owns the *unified* granularity clamp
//! ([`SpecCompiler::effective_granularity`]);
//! `plan::effective_corpus_granularity` delegates here, so the clamp
//! and the lowering can no longer disagree.
//!
//! **Granularity invariance** (the tuner's oracle) holds for every
//! mode: re-compiling one spec at any granularity assembles
//! bitwise-identical host outputs.  See `plan/lower.rs` module docs
//! for the block-mode construction and DESIGN.md §Spec for the rest.

use std::sync::Arc;

use crate::analysis::Category;
use crate::partition::chunk_ranges;
use crate::plan::{
    manifest_meta, wire_wavefront, Granularity, HostSlice, PlanRegion, Slot, StreamPlan,
};
use crate::runtime::{bytes, elastic_artifact};

use super::{materialize, SpecMode, WorkloadSpec};

/// Round up to the next f32-lane boundary.
fn lane_up(n: usize) -> usize {
    (n + 3) & !3
}

/// Halo bytes for one window side: `ratio × window_len`, lane-aligned,
/// at least one lane when the ratio is non-zero (the historical
/// symmetric arithmetic, applied per side).
fn halo_side(ratio: f64, len: usize) -> usize {
    if ratio > 0.0 && len > 0 {
        lane_up(((len as f64 * ratio) as usize).max(1))
    } else {
        0
    }
}

/// Compiles one validated [`WorkloadSpec`] into [`StreamPlan`]s.
pub struct SpecCompiler<'a> {
    spec: &'a WorkloadSpec,
}

impl<'a> SpecCompiler<'a> {
    pub fn new(spec: &'a WorkloadSpec) -> Self {
        Self { spec }
    }

    /// The one category/mode granularity clamp (shared by the corpus
    /// path, the tuners and the service — tuners should map candidate
    /// ladders through this and dedupe, or aliased grid points get
    /// measured twice under different labels):
    ///
    /// - block Sync/Iterative — the knob is ignored (single task);
    /// - partitioned shapes (block fan-out, windows, pingpong
    ///   uploads) — at least one f32 input lane per task;
    /// - block wavefront — tile-grid side in [1, 8];
    /// - tiles — pinned by buffer size ÷ kernel tile side.
    pub fn effective_granularity(&self, gran: Granularity) -> Granularity {
        let s = self.spec;
        let g = gran.get();
        let lanes = |bytes: usize| g.min(bytes.max(4) / 4).max(1);
        Granularity::new(match s.mode {
            SpecMode::Block => match s.category {
                Category::Sync | Category::Iterative => 1,
                Category::Independent | Category::FalseDependent => lanes(s.buffers[0].bytes),
                Category::TrueDependent => g.clamp(1, 8),
            },
            SpecMode::Windows | SpecMode::PingPong => lanes(s.buffers[0].bytes),
            SpecMode::Tiles => self.tile_grid(),
        })
    }

    /// The reference (non-streamed) lowering every streamed compile is
    /// validated against bitwise: one task / whole windows / whole
    /// uploads.  For tiles the wavefront *is* the reference (baseline
    /// = same DAG on one stream).
    pub fn bulk(&self) -> StreamPlan {
        match self.spec.mode {
            SpecMode::Block => self.block_bulk(),
            SpecMode::Windows => self.windows_at(1),
            SpecMode::PingPong => self.pingpong_at(1),
            SpecMode::Tiles => self.tiles(),
        }
    }

    /// Streamed lowering at the spec's default granularity.
    pub fn streamed(&self) -> StreamPlan {
        self.streamed_at(Granularity::new(self.spec.granularity))
    }

    /// Streamed lowering at an explicit granularity (clamped through
    /// [`Self::effective_granularity`]).
    pub fn streamed_at(&self, gran: Granularity) -> StreamPlan {
        let eff = self.effective_granularity(gran).get();
        match self.spec.mode {
            SpecMode::Block => match self.spec.category {
                Category::Sync | Category::Iterative => self.block_bulk(),
                Category::Independent | Category::FalseDependent => self.block_tasks(eff, None),
                Category::TrueDependent => self.block_tasks(eff * eff, Some(eff)),
            },
            SpecMode::Windows => self.windows_at(eff),
            SpecMode::PingPong => self.pingpong_at(eff),
            SpecMode::Tiles => self.tiles(),
        }
    }

    // ----- block mode (the historical corpus construction) -----

    /// Bulk block lowering: one upload, `repeats` kernel launches, one
    /// download — the offload the paper's §3.3 protocol measures
    /// stage-by-stage.
    fn block_bulk(&self) -> StreamPlan {
        let s = self.spec;
        let st = &s.stages[0];
        let b = s.block_bytes;
        let (h, d) = (s.buffers[0].bytes, s.output_bytes);
        let mut p = StreamPlan::new(s.name.clone());
        let out = p.output(d);
        let payload = materialize(&s.buffers[0]);
        let in_buf = p.buf(h.max(b));
        let out_buf = p.buf(d.max(b));
        p.h2d(
            Slot::Task(0),
            HostSlice::whole(payload),
            PlanRegion { buf: in_buf, off: 0, len: h },
            vec![],
        );
        let kex = p.kex(
            Slot::Task(0),
            &st.kernel,
            vec![PlanRegion::whole(in_buf, b)],
            vec![PlanRegion::whole(out_buf, b)],
            st.flops,
            s.repeats,
            vec![],
        );
        p.d2h(Slot::Task(0), PlanRegion { buf: out_buf, off: 0, len: d }, out, 0, vec![kex]);
        p
    }

    /// The shared block task construction ("granularity invariance" in
    /// the `plan/lower.rs` module docs): partition the payload at
    /// aligned boundaries, derive each task's output window from its
    /// input window clipped to the output size, and split any download
    /// reaching past the kernel block between the kernel output and a
    /// never-written zero buffer.  `wavefront = Some(g)` wires `g`²
    /// tiles diagonal-by-diagonal with RAW deps; `None` emits
    /// independent round-robin chains in task order.
    fn block_tasks(&self, m: usize, wavefront: Option<usize>) -> StreamPlan {
        let s = self.spec;
        let st = &s.stages[0];
        let kb = s.block_bytes;
        let (h, d) = (s.buffers[0].bytes, s.output_bytes);
        let payload = materialize(&s.buffers[0]);
        let mut p = StreamPlan::new(s.name.clone());
        let out = p.output(d);

        // Input boundaries: 4-byte-aligned partition of the payload —
        // the Fig. 6 overlap structure (every task ships a share of
        // the input whatever the output size).  Alignment keeps every
        // task's burner f32 lanes in phase with the bulk lowering's.
        let ix: Vec<usize> = (0..=m).map(|t| if t == m { h } else { (t * h / m) & !3 }).collect();
        // Output boundaries follow the input partition, clipped to the
        // output size; the tail of a larger output (d > h) rides with
        // the last task.  A task's output window is always inside its
        // own input window's byte positions, so its kernel computed
        // exactly those lanes.
        let ob: Vec<usize> = (0..=m).map(|t| if t == m { d } else { ix[t].min(d) }).collect();

        // Zero source for output bytes past the kernel block (bytes
        // the bulk lowering leaves untouched): one never-written
        // buffer.
        let zmax =
            (0..m).map(|t| ob[t + 1].saturating_sub(ob[t].max(kb))).max().unwrap_or(0);
        let zeros = if zmax > 0 { Some(p.buf(zmax)) } else { None };

        let flops = st.flops.map(|f| f / m as u64);
        let emit_task = |p: &mut StreamPlan, t: usize, slot: Slot, deps: Vec<usize>| -> usize {
            let (olo, ohi) = (ob[t], ob[t + 1]);
            let (ilo, ihi) = (ix[t], ix[t + 1]);
            // Halo extension per side (false dependent only),
            // lane-aligned, clipped to the payload (so the window
            // still slices the bulk payload).
            let (hlo, hhi) =
                (halo_side(s.halo.lo, ihi - ilo), halo_side(s.halo.hi, ihi - ilo));
            let xlo = ilo - hlo.min(ilo);
            let xhi = (ihi + hhi).min(h);
            let xfer = xhi - xlo;

            let in_buf = p.buf(xfer.max(kb));
            let out_buf = p.buf(kb);
            if xfer > 0 {
                p.h2d(
                    slot,
                    HostSlice { data: payload.clone(), off: xlo, len: xfer },
                    PlanRegion { buf: in_buf, off: 0, len: xfer },
                    vec![],
                );
            }
            let kex = p.kex(
                slot,
                &st.kernel,
                vec![PlanRegion::whole(in_buf, kb)],
                vec![PlanRegion::whole(out_buf, kb)],
                flops,
                s.repeats,
                deps,
            );
            // Computed part: output positions below the kernel block,
            // read at the window-relative offset.  A non-empty output
            // window implies a non-empty input window starting at
            // `olo` (so there `delta` is just the halo shift, and
            // `olo ≥ xlo` holds — outside this branch `olo - xlo`
            // could underflow: an empty-output task has olo clamped to
            // `d` below its `xlo`).
            let chi = ohi.min(kb);
            if chi > olo {
                let delta = olo - xlo;
                p.d2h(
                    slot,
                    PlanRegion { buf: out_buf, off: delta, len: chi - olo },
                    out,
                    olo,
                    vec![kex],
                );
            }
            // Zero part: positions the bulk lowering leaves untouched.
            let zlo = olo.max(kb);
            if ohi > zlo {
                p.d2h(
                    slot,
                    PlanRegion {
                        buf: zeros.expect("zero buffer declared"),
                        off: 0,
                        len: ohi - zlo,
                    },
                    out,
                    zlo,
                    vec![],
                );
            }
            kex
        };

        match wavefront {
            Some(g) => {
                wire_wavefront(g, |tc, lane, deps| {
                    emit_task(&mut p, tc.bi * g + tc.bj, lane, deps)
                });
            }
            None => {
                for t in 0..m {
                    emit_task(&mut p, t, Slot::Task(t), vec![]);
                }
            }
        }
        p
    }

    // ----- windows mode (exact-window pipelines) -----

    /// Largest fixed-shape tile among the pipeline stages (4 when all
    /// stages are elastic) — window boundaries snap to it so every
    /// fixed kernel sees whole tiles at every granularity.
    fn window_quantum(&self) -> usize {
        self.spec
            .stages
            .iter()
            .filter(|st| !elastic_artifact(&st.kernel))
            .filter_map(|st| manifest_meta(&st.kernel))
            .map(|m| m.inputs[0].bytes())
            .fold(4usize, usize::max)
    }

    /// Exact-window fan-out: `m` tasks partition the (equal-sized)
    /// streamed inputs at quantum-aligned boundaries; each task
    /// uploads its (halo-extended) windows, chains the stages on its
    /// own lane with explicit RAW deps, and downloads only the owned
    /// range.  Elastic stages run the whole window in one launch;
    /// fixed-shape stages tile it.  Because window boundaries never
    /// move data between lanes — every output byte is computed from
    /// exactly the same input lanes at any `m` — the assembled output
    /// is bitwise granularity-invariant.
    fn windows_at(&self, m: usize) -> StreamPlan {
        let s = self.spec;
        let h = s.buffers[0].bytes;
        let q = self.window_quantum();
        let payloads: Vec<Arc<Vec<u8>>> = s.stages[0]
            .inputs
            .iter()
            .map(|n| {
                materialize(
                    s.buffers.iter().find(|b| &b.name == n).expect("validated stage inputs"),
                )
            })
            .collect();

        let mut p = StreamPlan::new(s.name.clone());
        let out = p.output(h);
        let ix: Vec<usize> =
            (0..=m).map(|t| if t == m { h } else { (t * h / m) / q * q }).collect();

        for t in 0..m {
            let (ilo, ihi) = (ix[t], ix[t + 1]);
            if ihi == ilo {
                continue; // more tasks than quanta: this lane is empty
            }
            let len = ihi - ilo;
            let slot = Slot::Task(t);
            let (hlo, hhi) = (halo_side(s.halo.lo, len), halo_side(s.halo.hi, len));
            let xlo = ilo - hlo.min(ilo);
            let xhi = (ihi + hhi).min(h);
            let xfer = xhi - xlo;

            // Stage 0 inputs stream from the host.
            let in_bufs: Vec<usize> = payloads.iter().map(|_| p.buf(xfer)).collect();
            for (pl, &buf) in payloads.iter().zip(&in_bufs) {
                p.h2d(
                    slot,
                    HostSlice { data: pl.clone(), off: xlo, len: xfer },
                    PlanRegion { buf, off: 0, len: xfer },
                    vec![],
                );
            }

            let mut stage_in = in_bufs;
            let mut prev_kex: Vec<usize> = Vec::new();
            for st in &s.stages {
                // Pacing annotation proportional to the owned window.
                let flops = st.flops.map(|f| (f as u128 * len as u128 / h as u128) as u64);
                let out_buf = p.buf(xfer);
                if elastic_artifact(&st.kernel) {
                    let inputs =
                        stage_in.iter().map(|&b| PlanRegion::whole(b, xfer)).collect();
                    let id = p.kex(
                        slot,
                        &st.kernel,
                        inputs,
                        vec![PlanRegion::whole(out_buf, xfer)],
                        flops,
                        1,
                        prev_kex.clone(),
                    );
                    prev_kex = vec![id];
                } else {
                    let tile = manifest_meta(&st.kernel)
                        .expect("validated kernel")
                        .inputs[0]
                        .bytes();
                    let tiles = xfer / tile;
                    let per_tile = flops.map(|f| f / tiles.max(1) as u64);
                    let mut ids = Vec::with_capacity(tiles);
                    for j in 0..tiles {
                        ids.push(p.kex(
                            slot,
                            &st.kernel,
                            vec![PlanRegion { buf: stage_in[0], off: j * tile, len: tile }],
                            vec![PlanRegion { buf: out_buf, off: j * tile, len: tile }],
                            per_tile,
                            1,
                            prev_kex.clone(),
                        ));
                    }
                    prev_kex = ids;
                }
                stage_in = vec![out_buf];
            }

            // Download only the owned range (halo bytes were redundant
            // compute), at the window-relative offset.
            let delta = ilo - xlo;
            p.d2h(
                slot,
                PlanRegion { buf: stage_in[0], off: delta, len },
                out,
                ilo,
                prev_kex,
            );
        }
        p
    }

    // ----- pingpong mode (Iterative) -----

    /// Chunked uploads on alternating lanes (state on even, param on
    /// odd — all the concurrency the Iterative category permits), then
    /// a pure RAW ping-pong kernel chain on lane 0 and one download of
    /// the final state.  The chain is serialized whatever the stream
    /// count, exactly the paper's non-streamable verdict; the knob
    /// only re-chunks the uploads, so outputs are bitwise identical at
    /// every granularity.
    fn pingpong_at(&self, chunks: usize) -> StreamPlan {
        let s = self.spec;
        let st = &s.stages[0];
        let state =
            s.buffers.iter().find(|b| b.name == st.inputs[0]).expect("validated stage inputs");
        let param =
            s.buffers.iter().find(|b| b.name == st.inputs[1]).expect("validated stage inputs");
        let bytes_n = state.bytes;
        let mut p = StreamPlan::new(s.name.clone());
        let out = p.output(bytes_n);
        let ta = p.buf(bytes_n);
        let tb = p.buf(bytes_n);
        let pw = p.buf(bytes_n);

        let upload = |p: &mut StreamPlan, data: Arc<Vec<u8>>, buf: usize, lane0: usize| {
            chunk_ranges(bytes_n, chunks)
                .into_iter()
                .enumerate()
                .map(|(j, r)| {
                    p.h2d(
                        Slot::Task(lane0 + 2 * j),
                        HostSlice { data: data.clone(), off: r.start, len: r.len },
                        PlanRegion { buf, off: r.start, len: r.len },
                        vec![],
                    )
                })
                .collect::<Vec<usize>>()
        };
        let mut uploads = upload(&mut p, materialize(state), ta, 0);
        uploads.extend(upload(&mut p, materialize(param), pw, 1));

        // Ping-pong chain: step k reads step k-1's output — a pure
        // RAW chain on lane 0.  The first step waits on every chunk.
        let (mut src, mut dst) = (ta, tb);
        for step in 0..s.steps {
            let deps = if step == 0 { uploads.clone() } else { Vec::new() };
            p.kex(
                Slot::Task(0),
                &st.kernel,
                vec![PlanRegion::whole(src, bytes_n), PlanRegion::whole(pw, bytes_n)],
                vec![PlanRegion::whole(dst, bytes_n)],
                st.flops,
                1,
                deps,
            );
            std::mem::swap(&mut src, &mut dst);
        }
        p.d2h(Slot::Task(0), PlanRegion::whole(src, bytes_n), out, 0, vec![]);
        p
    }

    // ----- tiles mode (True Dependent wavefront) -----

    /// Grid side pinned by the spec: matrix side ÷ kernel tile side.
    fn tile_grid(&self) -> usize {
        let meta = manifest_meta(&self.spec.stages[0].kernel).expect("validated kernel");
        let side = meta.inputs[0].bytes() / 4;
        let size = ((self.spec.buffers[0].bytes / 4) as f64).sqrt() as usize;
        (size / side.max(1)).max(1)
    }

    /// The NW-shaped wavefront: boundary vectors broadcast once,
    /// per-tile payloads stream on the tile's slot-within-diagonal
    /// lane, each tile kernel reads its neighbours' device-resident
    /// south/east edges (RAW deps wired by [`wire_wavefront`]) and
    /// downloads its own block of the assembled matrix.
    fn tiles(&self) -> StreamPlan {
        let s = self.spec;
        let st = &s.stages[0];
        let meta = manifest_meta(&st.kernel).expect("validated kernel");
        let edge_bytes = meta.inputs[0].bytes();
        let tile = edge_bytes / 4;
        let tile_bytes = meta.inputs[3].bytes();
        let g = self.tile_grid();
        let size = g * tile;
        let penalty = s.penalty;
        let sub_i32 = bytes::to_i32(&materialize(&s.buffers[0]));

        // Per-tile substitution payloads (row-major within the tile).
        let mut tile_sub: Vec<Arc<Vec<u8>>> = Vec::with_capacity(g * g);
        for bi in 0..g {
            for bj in 0..g {
                let mut t = Vec::with_capacity(tile * tile);
                for r in 0..tile {
                    let row0 = (bi * tile + r) * size + bj * tile;
                    t.extend_from_slice(&sub_i32[row0..row0 + tile]);
                }
                tile_sub.push(Arc::new(bytes::from_i32(&t)));
            }
        }

        // Boundary vectors: score row/col 0 are -penalty * (1-based).
        let north_boundary: Vec<i32> = (0..size as i32).map(|j| -penalty * (j + 1)).collect();
        let west_boundary: Vec<i32> = (0..size as i32).map(|i| -penalty * (i + 1)).collect();

        let mut p = StreamPlan::new(s.name.clone());
        let out = p.output(g * g * tile_bytes);

        // Boundaries are broadcast inputs: stream 0, fan-out waits.
        let nb = p.buf(size * 4);
        let wb = p.buf(size * 4);
        let cz = p.buf(4);
        p.h2d(
            Slot::Broadcast,
            HostSlice::whole(Arc::new(bytes::from_i32(&north_boundary))),
            PlanRegion::whole(nb, size * 4),
            vec![],
        );
        p.h2d(
            Slot::Broadcast,
            HostSlice::whole(Arc::new(bytes::from_i32(&west_boundary))),
            PlanRegion::whole(wb, size * 4),
            vec![],
        );
        p.h2d(
            Slot::Broadcast,
            HostSlice::whole(Arc::new(bytes::from_i32(&[0i32]))),
            PlanRegion::whole(cz, 4),
            vec![],
        );

        // Per-tile device buffers (sub, out, south edge, east edge).
        let sub_bufs: Vec<usize> = (0..g * g).map(|_| p.buf(tile_bytes)).collect();
        let out_bufs: Vec<usize> = (0..g * g).map(|_| p.buf(tile_bytes)).collect();
        let south_bufs: Vec<usize> = (0..g * g).map(|_| p.buf(edge_bytes)).collect();
        let east_bufs: Vec<usize> = (0..g * g).map(|_| p.buf(edge_bytes)).collect();

        wire_wavefront(g, |tc, lane, deps| {
            let (bi, bj) = (tc.bi, tc.bj);
            let t = bi * g + bj;

            p.h2d(
                lane,
                HostSlice::whole(tile_sub[t].clone()),
                PlanRegion::whole(sub_bufs[t], tile_bytes),
                vec![],
            );

            // Edge inputs: neighbours' contiguous outputs (their
            // producing kernels are already in `deps`) or boundary
            // slices.
            let north = if bi == 0 {
                PlanRegion { buf: nb, off: bj * tile * 4, len: edge_bytes }
            } else {
                PlanRegion::whole(south_bufs[(bi - 1) * g + bj], edge_bytes)
            };
            let west = if bj == 0 {
                PlanRegion { buf: wb, off: bi * tile * 4, len: edge_bytes }
            } else {
                PlanRegion::whole(east_bufs[bi * g + bj - 1], edge_bytes)
            };
            let corner = match (bi, bj) {
                (0, 0) => PlanRegion::whole(cz, 4),
                (0, j) => PlanRegion { buf: nb, off: (j * tile - 1) * 4, len: 4 },
                (i, 0) => PlanRegion { buf: wb, off: (i * tile - 1) * 4, len: 4 },
                (i, j) => PlanRegion {
                    buf: south_bufs[(i - 1) * g + j - 1],
                    off: (tile - 1) * 4,
                    len: 4,
                },
            };

            let kex = p.kex(
                lane,
                &st.kernel,
                vec![north, west, corner, PlanRegion::whole(sub_bufs[t], tile_bytes)],
                vec![
                    PlanRegion::whole(out_bufs[t], tile_bytes),
                    PlanRegion::whole(south_bufs[t], edge_bytes),
                    PlanRegion::whole(east_bufs[t], edge_bytes),
                ],
                st.flops,
                1,
                deps,
            );

            let out_region = PlanRegion::whole(out_bufs[t], tile_bytes);
            p.d2h(lane, out_region, out, t * tile_bytes, vec![]);
            kex
        });
        p
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::spec::{BufferInit, BufferSpec, HaloSpec, StageSpec, KEX_BLOCK_BYTES};

    fn windows_spec(stages: Vec<StageSpec>, bytes: usize, halo: HaloSpec) -> WorkloadSpec {
        let category = if halo.is_zero() {
            Category::Independent
        } else {
            Category::FalseDependent
        };
        WorkloadSpec {
            name: "t".into(),
            category,
            mode: SpecMode::Windows,
            granularity: 4,
            repeats: 1,
            output_bytes: bytes,
            block_bytes: KEX_BLOCK_BYTES,
            steps: 0,
            penalty: 0,
            halo,
            buffers: vec![BufferSpec {
                name: "a".into(),
                bytes,
                init: BufferInit::F32Rand { seed: 7 },
            }],
            stages,
        }
    }

    #[test]
    fn window_boundaries_snap_to_fixed_stage_tiles() {
        // vector_add (elastic) -> fwt (fixed 16384-byte tiles): every
        // task window must hold whole fwt tiles at every granularity.
        let spec = windows_spec(
            vec![
                StageSpec { kernel: "burner_8".into(), inputs: vec!["a".into()], flops: None },
                StageSpec { kernel: "fwt".into(), inputs: vec![], flops: None },
            ],
            16384 * 8,
            HaloSpec::ZERO,
        );
        spec.validate().unwrap();
        let q = SpecCompiler::new(&spec).window_quantum();
        assert_eq!(q, 16384);
        for m in [1usize, 2, 3, 5, 8, 64] {
            let plan = SpecCompiler::new(&spec).windows_at(m);
            plan.validate().unwrap_or_else(|e| panic!("m={m}: {e}"));
            // Assembled output always covers the whole array.
            assert_eq!(plan.d2h_bytes(), 16384 * 8);
        }
    }

    #[test]
    fn asymmetric_halo_extends_uploads_but_not_downloads() {
        let spec = windows_spec(
            vec![StageSpec { kernel: "burner_64".into(), inputs: vec!["a".into()], flops: None }],
            65536,
            HaloSpec { lo: 0.25, hi: 0.0625 },
        );
        spec.validate().unwrap();
        let bulk = SpecCompiler::new(&spec).bulk();
        let strm = SpecCompiler::new(&spec).windows_at(8);
        strm.validate().unwrap();
        assert_eq!(strm.d2h_bytes(), bulk.d2h_bytes(), "downloads: owned ranges only");
        assert!(strm.h2d_bytes() > bulk.h2d_bytes(), "halo redundancy must show up");
    }

    #[test]
    fn pingpong_chain_length_and_upload_fanout() {
        use crate::plan::PlanOpKind;
        let spec = WorkloadSpec {
            name: "hs".into(),
            category: Category::Iterative,
            mode: SpecMode::PingPong,
            granularity: 4,
            repeats: 1,
            output_bytes: 128 * 128 * 4,
            block_bytes: KEX_BLOCK_BYTES,
            steps: 3,
            penalty: 0,
            halo: HaloSpec::ZERO,
            buffers: vec![
                BufferSpec {
                    name: "temp".into(),
                    bytes: 128 * 128 * 4,
                    init: BufferInit::F32Rand { seed: 221 },
                },
                BufferSpec {
                    name: "power".into(),
                    bytes: 128 * 128 * 4,
                    init: BufferInit::F32Rand { seed: 222 },
                },
            ],
            stages: vec![StageSpec {
                kernel: "hotspot_step".into(),
                inputs: vec!["temp".into(), "power".into()],
                flops: None,
            }],
        };
        spec.validate().unwrap();
        let plan = SpecCompiler::new(&spec).streamed_at(Granularity::new(4));
        plan.validate().unwrap();
        let kexes = plan.ops.iter().filter(|o| matches!(o.kind, PlanOpKind::Kex { .. })).count();
        let h2ds = plan.ops.iter().filter(|o| matches!(o.kind, PlanOpKind::H2d { .. })).count();
        assert_eq!(kexes, 3, "one launch per step");
        assert_eq!(h2ds, 8, "two arrays x four chunks");
    }

    #[test]
    fn tiles_grid_is_pinned_by_the_buffer() {
        let spec = WorkloadSpec {
            name: "nw".into(),
            category: Category::TrueDependent,
            mode: SpecMode::Tiles,
            granularity: 4,
            repeats: 1,
            output_bytes: (4 * 32) * (4 * 32) * 4,
            block_bytes: KEX_BLOCK_BYTES,
            steps: 0,
            penalty: 10,
            halo: HaloSpec::ZERO,
            buffers: vec![BufferSpec {
                name: "sub".into(),
                bytes: (4 * 32) * (4 * 32) * 4,
                init: BufferInit::I32Rand { seed: 0xBEEF, bound: 15, shift: 5 },
            }],
            stages: vec![StageSpec {
                kernel: "nw_tile".into(),
                inputs: vec!["sub".into()],
                flops: Some(450_000),
            }],
        };
        spec.validate().unwrap();
        let c = SpecCompiler::new(&spec);
        // The knob cannot move the grid: it is fixed by matrix/tile.
        assert_eq!(c.effective_granularity(Granularity::new(16)).get(), 4);
        assert_eq!(c.effective_granularity(Granularity::new(1)).get(), 4);
        let plan = c.streamed();
        plan.validate().unwrap();
        assert_eq!(plan.tasks(), 16);
    }
}
