//! Declarative workload specs — the single front end for every
//! streamed lowering in the repo.
//!
//! The paper's generic flow (§5) classifies a heterogeneous code by
//! its dependence pattern and derives the streamed program from that
//! classification mechanically.  A [`WorkloadSpec`] is exactly that
//! classification written down: buffers (sizes + deterministic init),
//! one kernel per stage drawn from the simkern artifact manifest, the
//! Table-2 category, and the per-category parameters (halo ratios,
//! iteration count, wavefront grid).  [`compile::SpecCompiler`] turns
//! a spec into a [`StreamPlan`] with one composable builder per
//! category; `plan::lower_corpus_{bulk,streamed_at}` are now thin
//! `CorpusDescriptor → WorkloadSpec` conversions over the same
//! compiler, so all 224 (app, gran) corpus plans provably flow through
//! this path.
//!
//! Specs round-trip through JSON (`util::json`, no external deps):
//! [`WorkloadSpec::from_json`] ∘ [`WorkloadSpec::to_json`] is the
//! identity, and [`WorkloadSpec::content_hash`] over the canonical
//! serialization keys the service's plan cache.  See DESIGN.md §Spec
//! and `specs/README.md` for the schema walkthrough.

pub mod compile;

pub use compile::SpecCompiler;

use std::sync::Arc;

use crate::analysis::{Category, TaskDep};
use crate::corpus::BenchConfig;
use crate::error::{Error, Result};
use crate::util::json::{escape, Json};

/// The burner artifacts' fixed block: 65536 f32 in, 65536 f32 out —
/// the `block_bytes` every corpus-derived spec carries.
pub const KEX_BLOCK_BYTES: usize = 65536 * 4;

/// Schema tag committed spec files must carry.
pub const SPEC_SCHEMA: &str = "hetstream-spec-v1";

/// How a buffer's deterministic payload is produced (specs describe
/// data, they never embed it).
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum BufferInit {
    /// Raw bytes from the property-testing RNG (`util::prop::Rng`) —
    /// what every corpus descriptor ships.
    Synth { seed: u64 },
    /// f32 lanes in [-1, 1) from `workloads::gen_f32`.
    F32Rand { seed: u64 },
    /// i32 lanes in `[-shift, bound - shift)` from
    /// `workloads::gen_i32` minus `shift` (NW substitution scores).
    I32Rand { seed: u64, bound: i32, shift: i32 },
    /// All zero.
    Zeros,
}

/// One named input buffer.
#[derive(Debug, Clone, PartialEq)]
pub struct BufferSpec {
    pub name: String,
    pub bytes: usize,
    pub init: BufferInit,
}

/// One kernel stage.  Stage 0 reads host buffers (by name); stages
/// past the first read the previous stage's device output (spelled
/// `"$prev"`, or omitted).
#[derive(Debug, Clone, PartialEq)]
pub struct StageSpec {
    /// Artifact name — must exist in the simkern manifest.
    pub kernel: String,
    pub inputs: Vec<String>,
    /// Total-FLOP annotation for the whole stage (pacing only; the
    /// compiler splits it across tasks).  `None` falls back to the
    /// manifest per-call estimate.
    pub flops: Option<u64>,
}

/// Per-side halo ratio for false-dependent windows: each task's input
/// window extends by `ratio × window_len` bytes on that side (Fig. 7's
/// redundant boundary transfer).  Asymmetric ratios are allowed.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct HaloSpec {
    pub lo: f64,
    pub hi: f64,
}

impl HaloSpec {
    pub const ZERO: HaloSpec = HaloSpec { lo: 0.0, hi: 0.0 };

    pub fn is_zero(&self) -> bool {
        self.lo == 0.0 && self.hi == 0.0
    }
}

/// Which region discipline the compiler uses within the category's
/// builder (the category fixes the DAG shape; the mode fixes how
/// kernel regions map onto windows).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SpecMode {
    /// Historical corpus discipline: one payload, kernel regions pinned
    /// to the artifact's fixed `block_bytes` block, zero-source padding
    /// for output bytes past the block.  What every descriptor-derived
    /// spec uses.
    Block,
    /// Exact windows: elastic kernels run whole task windows, fixed
    /// kernels run tile-by-tile inside them; stages chain per task.
    Windows,
    /// Iterative ping-pong: chunked uploads on alternating lanes, then
    /// a pure RAW kernel chain on resident data (hotspot's shape).
    PingPong,
    /// Wavefront tile grid with device-resident edges (NW's shape);
    /// the grid side is fixed by buffer size ÷ kernel tile.
    Tiles,
}

impl SpecMode {
    pub fn token(self) -> &'static str {
        match self {
            SpecMode::Block => "block",
            SpecMode::Windows => "windows",
            SpecMode::PingPong => "pingpong",
            SpecMode::Tiles => "tiles",
        }
    }
}

/// Category ↔ JSON token (the paper's Table-2 names, snake_cased).
pub fn category_token(cat: Category) -> &'static str {
    match cat {
        Category::Sync => "sync",
        Category::Iterative => "iterative",
        Category::Independent => "independent",
        Category::FalseDependent => "false_dependent",
        Category::TrueDependent => "true_dependent",
    }
}

fn category_from_token(s: &str) -> Option<Category> {
    Some(match s {
        "sync" => Category::Sync,
        "iterative" => Category::Iterative,
        "independent" => Category::Independent,
        "false_dependent" => Category::FalseDependent,
        "true_dependent" => Category::TrueDependent,
        _ => return None,
    })
}

fn mode_from_token(s: &str) -> Option<SpecMode> {
    Some(match s {
        "block" => SpecMode::Block,
        "windows" => SpecMode::Windows,
        "pingpong" => SpecMode::PingPong,
        "tiles" => SpecMode::Tiles,
        _ => return None,
    })
}

/// A declarative streamed workload: everything the compiler needs to
/// derive the bulk and streamed plans at any granularity.
#[derive(Debug, Clone, PartialEq)]
pub struct WorkloadSpec {
    pub name: String,
    /// The paper's dependence category — picks the builder.
    pub category: Category,
    /// Region discipline within the builder.
    pub mode: SpecMode,
    /// Default granularity (task count / tile-grid side); the
    /// compiler's unified clamp applies on top.
    pub granularity: usize,
    /// Kernel launches per task (block mode; windows/tiles/pingpong
    /// stages launch once per window/tile/step).
    pub repeats: u32,
    /// Assembled host output size.
    pub output_bytes: usize,
    /// Fixed kernel block for [`SpecMode::Block`].
    pub block_bytes: usize,
    /// Ping-pong chain length for [`SpecMode::PingPong`].
    pub steps: usize,
    /// Boundary gap penalty for [`SpecMode::Tiles`] (score row/col 0
    /// are `-penalty × (1-based index)`).
    pub penalty: i32,
    /// False-dependent halo ratios (zero elsewhere).
    pub halo: HaloSpec,
    pub buffers: Vec<BufferSpec>,
    pub stages: Vec<StageSpec>,
}

fn err(msg: impl Into<String>) -> Error {
    Error::Spec(msg.into())
}

fn get_usize(j: &Json, key: &str) -> Result<Option<usize>> {
    match j.get(key) {
        None | Some(Json::Null) => Ok(None),
        Some(v) => {
            let f = v.as_f64().ok_or_else(|| err(format!("`{key}` must be a number")))?;
            if f < 0.0 || f.fract() != 0.0 {
                return Err(err(format!("`{key}` must be a non-negative integer")));
            }
            Ok(Some(f as usize))
        }
    }
}

/// Seeds are u64; values past 2^53 are carried as decimal strings so
/// the f64-backed JSON layer cannot round them.
fn get_seed(j: &Json, key: &str) -> Result<u64> {
    match j.get(key) {
        Some(Json::Str(s)) => {
            s.parse::<u64>().map_err(|_| err(format!("`{key}` string must be a decimal u64")))
        }
        Some(v) => {
            let f = v.as_f64().ok_or_else(|| err(format!("`{key}` must be a number")))?;
            if f < 0.0 || f.fract() != 0.0 {
                return Err(err(format!("`{key}` must be a non-negative integer")));
            }
            Ok(f as u64)
        }
        None => Err(err(format!("buffer init missing `{key}`"))),
    }
}

fn seed_json(seed: u64) -> String {
    if seed <= (1u64 << 53) {
        format!("{seed}")
    } else {
        format!("\"{seed}\"")
    }
}

impl WorkloadSpec {
    /// Parse a spec document.  Every malformation is a clean
    /// [`Error::Spec`]; parsing never panics or hangs.
    pub fn from_json(text: &str) -> Result<WorkloadSpec> {
        let j = Json::parse(text).map_err(|e| err(format!("unparsable json: {e}")))?;
        if j.get("schema").and_then(Json::as_str) != Some(SPEC_SCHEMA) {
            return Err(err(format!("missing or wrong `schema` (want \"{SPEC_SCHEMA}\")")));
        }
        let name = j
            .get("name")
            .and_then(Json::as_str)
            .ok_or_else(|| err("missing `name`"))?
            .to_string();
        if name.is_empty() {
            return Err(err("`name` must be non-empty"));
        }
        let category = j
            .get("category")
            .and_then(Json::as_str)
            .and_then(category_from_token)
            .ok_or_else(|| {
                err("missing or unknown `category` \
                     (sync|iterative|independent|false_dependent|true_dependent)")
            })?;
        let mode = j
            .get("mode")
            .and_then(Json::as_str)
            .and_then(mode_from_token)
            .ok_or_else(|| err("missing or unknown `mode` (block|windows|pingpong|tiles)"))?;
        let granularity = get_usize(&j, "granularity")?.unwrap_or(1);
        let repeats = get_usize(&j, "repeats")?.unwrap_or(1) as u32;
        let output_bytes =
            get_usize(&j, "output_bytes")?.ok_or_else(|| err("missing `output_bytes`"))?;
        let block_bytes = get_usize(&j, "block_bytes")?.unwrap_or(KEX_BLOCK_BYTES);
        let steps = get_usize(&j, "steps")?.unwrap_or(0);
        let penalty = match j.get("penalty") {
            None | Some(Json::Null) => 0,
            Some(v) => {
                let f = v.as_f64().ok_or_else(|| err("`penalty` must be a number"))?;
                if f.fract() != 0.0 {
                    return Err(err("`penalty` must be an integer"));
                }
                f as i32
            }
        };
        let halo = match j.get("halo") {
            None | Some(Json::Null) => HaloSpec::ZERO,
            Some(h) => {
                let side = |key: &str| -> Result<f64> {
                    match h.get(key) {
                        None => Ok(0.0),
                        Some(v) => {
                            let f = v
                                .as_f64()
                                .ok_or_else(|| err(format!("halo `{key}` must be a number")))?;
                            if !f.is_finite() || f < 0.0 {
                                return Err(err(format!("halo `{key}` must be finite and >= 0")));
                            }
                            Ok(f)
                        }
                    }
                };
                HaloSpec { lo: side("lo")?, hi: side("hi")? }
            }
        };

        let buffers_j =
            j.get("buffers").and_then(Json::as_arr).ok_or_else(|| err("missing `buffers` array"))?;
        let mut buffers = Vec::with_capacity(buffers_j.len());
        for (i, b) in buffers_j.iter().enumerate() {
            let bname = b
                .get("name")
                .and_then(Json::as_str)
                .ok_or_else(|| err(format!("buffer {i} missing `name`")))?
                .to_string();
            let bytes = get_usize(b, "bytes")?
                .ok_or_else(|| err(format!("buffer `{bname}` missing `bytes`")))?;
            let init_j =
                b.get("init").ok_or_else(|| err(format!("buffer `{bname}` missing `init`")))?;
            let kind = init_j
                .get("kind")
                .and_then(Json::as_str)
                .ok_or_else(|| err(format!("buffer `{bname}` init missing `kind`")))?;
            let init = match kind {
                "synth" => BufferInit::Synth { seed: get_seed(init_j, "seed")? },
                "f32_rand" => BufferInit::F32Rand { seed: get_seed(init_j, "seed")? },
                "i32_rand" => {
                    let bound = get_usize(init_j, "bound")?
                        .ok_or_else(|| err(format!("buffer `{bname}` i32_rand missing `bound`")))?;
                    let shift = get_usize(init_j, "shift")?.unwrap_or(0);
                    BufferInit::I32Rand {
                        seed: get_seed(init_j, "seed")?,
                        bound: bound as i32,
                        shift: shift as i32,
                    }
                }
                "zeros" => BufferInit::Zeros,
                other => {
                    return Err(err(format!(
                        "buffer `{bname}` unknown init kind `{other}` \
                         (synth|f32_rand|i32_rand|zeros)"
                    )))
                }
            };
            buffers.push(BufferSpec { name: bname, bytes, init });
        }

        let stages_j =
            j.get("stages").and_then(Json::as_arr).ok_or_else(|| err("missing `stages` array"))?;
        let mut stages = Vec::with_capacity(stages_j.len());
        for (i, s) in stages_j.iter().enumerate() {
            let kernel = s
                .get("kernel")
                .and_then(Json::as_str)
                .ok_or_else(|| err(format!("stage {i} missing `kernel`")))?
                .to_string();
            let inputs = match s.get("inputs") {
                None | Some(Json::Null) => Vec::new(),
                Some(v) => v
                    .as_arr()
                    .ok_or_else(|| err(format!("stage {i} `inputs` must be an array")))?
                    .iter()
                    .map(|x| {
                        x.as_str()
                            .map(str::to_string)
                            .ok_or_else(|| err(format!("stage {i} inputs must be strings")))
                    })
                    .collect::<Result<Vec<_>>>()?,
            };
            let flops = get_usize(s, "flops")?.map(|f| f as u64);
            stages.push(StageSpec { kernel, inputs, flops });
        }

        Ok(WorkloadSpec {
            name,
            category,
            mode,
            granularity,
            repeats,
            output_bytes,
            block_bytes,
            steps,
            penalty,
            halo,
            buffers,
            stages,
        })
    }

    /// Canonical serialization: stable field order, one line per
    /// scalar.  `from_json(to_json(s)) == s` for every valid spec, and
    /// [`Self::content_hash`] is FNV-1a over exactly these bytes.
    pub fn to_json(&self) -> String {
        let mut o = String::new();
        o.push_str("{\n");
        o.push_str(&format!("  \"schema\": \"{SPEC_SCHEMA}\",\n"));
        o.push_str(&format!("  \"name\": \"{}\",\n", escape(&self.name)));
        o.push_str(&format!("  \"category\": \"{}\",\n", category_token(self.category)));
        o.push_str(&format!("  \"mode\": \"{}\",\n", self.mode.token()));
        o.push_str(&format!("  \"granularity\": {},\n", self.granularity));
        o.push_str(&format!("  \"repeats\": {},\n", self.repeats));
        o.push_str(&format!("  \"output_bytes\": {},\n", self.output_bytes));
        if self.mode == SpecMode::Block {
            o.push_str(&format!("  \"block_bytes\": {},\n", self.block_bytes));
        }
        if self.steps > 0 {
            o.push_str(&format!("  \"steps\": {},\n", self.steps));
        }
        if self.penalty != 0 {
            o.push_str(&format!("  \"penalty\": {},\n", self.penalty));
        }
        if !self.halo.is_zero() {
            let (lo, hi) = (self.halo.lo, self.halo.hi);
            o.push_str(&format!("  \"halo\": {{\"lo\": {lo}, \"hi\": {hi}}},\n"));
        }
        o.push_str("  \"buffers\": [\n");
        for (i, b) in self.buffers.iter().enumerate() {
            let init = match b.init {
                BufferInit::Synth { seed } => {
                    format!("{{\"kind\": \"synth\", \"seed\": {}}}", seed_json(seed))
                }
                BufferInit::F32Rand { seed } => {
                    format!("{{\"kind\": \"f32_rand\", \"seed\": {}}}", seed_json(seed))
                }
                BufferInit::I32Rand { seed, bound, shift } => format!(
                    "{{\"kind\": \"i32_rand\", \"seed\": {}, \
                     \"bound\": {bound}, \"shift\": {shift}}}",
                    seed_json(seed)
                ),
                BufferInit::Zeros => "{\"kind\": \"zeros\"}".to_string(),
            };
            o.push_str(&format!(
                "    {{\"name\": \"{}\", \"bytes\": {}, \"init\": {init}}}{}\n",
                escape(&b.name),
                b.bytes,
                if i + 1 < self.buffers.len() { "," } else { "" }
            ));
        }
        o.push_str("  ],\n");
        o.push_str("  \"stages\": [\n");
        for (i, s) in self.stages.iter().enumerate() {
            let inputs = s
                .inputs
                .iter()
                .map(|n| format!("\"{}\"", escape(n)))
                .collect::<Vec<_>>()
                .join(", ");
            let flops = match s.flops {
                Some(f) => format!(", \"flops\": {f}"),
                None => String::new(),
            };
            o.push_str(&format!(
                "    {{\"kernel\": \"{}\", \"inputs\": [{inputs}]{flops}}}{}\n",
                escape(&s.kernel),
                if i + 1 < self.stages.len() { "," } else { "" }
            ));
        }
        o.push_str("  ]\n");
        o.push_str("}\n");
        o
    }

    /// FNV-1a over the canonical serialization — the service's plan
    /// cache key (two specs with equal content share cached plans, a
    /// renamed buffer does not alias).
    pub fn content_hash(&self) -> u64 {
        self.to_json()
            .bytes()
            .fold(0xCBF29CE484222325u64, |h, b| (h ^ b as u64).wrapping_mul(0x100000001B3))
    }

    /// Structural validation against the artifact manifest and the
    /// per-mode rules.  Every violation is a clean [`Error::Spec`];
    /// a spec that validates compiles without panicking.
    pub fn validate(&self) -> Result<()> {
        if self.name.is_empty() {
            return Err(err("`name` must be non-empty"));
        }
        if self.buffers.is_empty() {
            return Err(err("at least one buffer required"));
        }
        if self.stages.is_empty() {
            return Err(err("at least one stage required"));
        }
        if self.granularity == 0 {
            return Err(err("`granularity` must be >= 1"));
        }
        if self.repeats == 0 {
            return Err(err("`repeats` must be >= 1"));
        }
        if self.output_bytes == 0 {
            return Err(err("`output_bytes` must be >= 1"));
        }
        let halo_ok = |r: f64| r.is_finite() && r >= 0.0;
        if !halo_ok(self.halo.lo) || !halo_ok(self.halo.hi) {
            return Err(err("halo ratios must be finite and >= 0"));
        }
        for (i, b) in self.buffers.iter().enumerate() {
            if b.bytes == 0 {
                return Err(err(format!("buffer `{}` must have bytes >= 1", b.name)));
            }
            if self.buffers[..i].iter().any(|o| o.name == b.name) {
                return Err(err(format!("duplicate buffer name `{}`", b.name)));
            }
        }
        // Every kernel must exist in the manifest; every stage-0 input
        // must name a declared buffer; later stages read `$prev`.
        for (si, s) in self.stages.iter().enumerate() {
            let meta = crate::plan::manifest_meta(&s.kernel)
                .ok_or_else(|| err(format!("unknown kernel `{}` (not in manifest)", s.kernel)))?;
            if si == 0 {
                if s.inputs.is_empty() {
                    return Err(err("stage 0 must name its input buffers"));
                }
                for n in &s.inputs {
                    if !self.buffers.iter().any(|b| &b.name == n) {
                        return Err(err(format!("stage 0 input `{n}` names no declared buffer")));
                    }
                }
            } else if !s.inputs.is_empty() && s.inputs != ["$prev"] {
                return Err(err(format!(
                    "stage {si} inputs must be omitted or [\"$prev\"] (stages chain)"
                )));
            }
            if meta.outputs.is_empty() {
                return Err(err(format!("kernel `{}` has no outputs", s.kernel)));
            }
        }
        match self.mode {
            SpecMode::Block => self.validate_block(),
            SpecMode::Windows => self.validate_windows(),
            SpecMode::PingPong => self.validate_pingpong(),
            SpecMode::Tiles => self.validate_tiles(),
        }
    }

    fn validate_block(&self) -> Result<()> {
        if self.buffers.len() != 1 || self.stages.len() != 1 {
            return Err(err("block mode takes exactly one buffer and one stage"));
        }
        if self.block_bytes < 4 || self.block_bytes % 4 != 0 {
            return Err(err("`block_bytes` must be a positive multiple of 4"));
        }
        if self.stages[0].inputs.len() != 1 {
            return Err(err("block mode stage reads exactly the one buffer"));
        }
        Ok(())
    }

    fn validate_windows(&self) -> Result<()> {
        use crate::runtime::elastic_artifact;
        if !matches!(self.category, Category::Independent | Category::FalseDependent) {
            return Err(err("windows mode requires an independent or false_dependent category"));
        }
        let s0 = &self.stages[0];
        let meta0 = crate::plan::manifest_meta(&s0.kernel).expect("checked above");
        if !elastic_artifact(&s0.kernel) {
            return Err(err(format!("windows stage 0 kernel `{}` must be elastic", s0.kernel)));
        }
        if s0.inputs.len() != meta0.inputs.len() {
            return Err(err(format!(
                "stage 0 names {} inputs but kernel `{}` takes {}",
                s0.inputs.len(),
                s0.kernel,
                meta0.inputs.len()
            )));
        }
        if meta0.outputs.len() != 1 || meta0.outputs[0].bytes() != meta0.inputs[0].bytes() {
            return Err(err(format!(
                "windows kernels must map bytes 1:1 (kernel `{}` does not)",
                s0.kernel
            )));
        }
        let h = self.buffers[0].bytes;
        for n in &s0.inputs {
            let b = self.buffers.iter().find(|b| &b.name == n).expect("checked above");
            if b.bytes != h {
                return Err(err(format!(
                    "size mismatch: windows-mode inputs must be equal-sized \
                     (`{}` is {} bytes, `{}` is {})",
                    self.buffers[0].name, h, b.name, b.bytes
                )));
            }
        }
        if h % 4 != 0 {
            return Err(err("windows-mode buffers must be whole f32 lanes (multiple of 4 bytes)"));
        }
        if self.output_bytes != h {
            return Err(err(format!(
                "size mismatch: windows mode assembles output_bytes == input bytes ({} != {h})",
                self.output_bytes
            )));
        }
        let mut quantum = 4usize;
        for (si, s) in self.stages.iter().enumerate().skip(1) {
            let meta = crate::plan::manifest_meta(&s.kernel).expect("checked above");
            if meta.inputs.len() != 1 || meta.outputs.len() != 1 {
                return Err(err(format!(
                    "pipeline stage {si} kernel `{}` must be 1-in 1-out",
                    s.kernel
                )));
            }
            if meta.outputs[0].bytes() != meta.inputs[0].bytes() {
                return Err(err(format!(
                    "pipeline stage {si} kernel `{}` must map bytes 1:1",
                    s.kernel
                )));
            }
            if !elastic_artifact(&s.kernel) {
                let tile = meta.inputs[0].bytes();
                if h % tile != 0 {
                    return Err(err(format!(
                        "size mismatch: fixed-shape stage {si} kernel `{}` \
                         tiles {tile} bytes, which must divide the {h} byte window",
                        s.kernel
                    )));
                }
                if quantum % tile != 0 && tile % quantum != 0 {
                    return Err(err("fixed-shape stage tiles must nest (share a common quantum)"));
                }
                quantum = quantum.max(tile);
            }
        }
        if !self.halo.is_zero() {
            if self.category != Category::FalseDependent {
                return Err(err("halo ratios require category false_dependent"));
            }
            if self.stages.len() != 1 {
                return Err(err("halo windows support a single elastic stage"));
            }
        }
        if self.category == Category::FalseDependent && self.halo.is_zero() {
            return Err(err("false_dependent windows need a non-zero halo"));
        }
        Ok(())
    }

    fn validate_pingpong(&self) -> Result<()> {
        if self.category != Category::Iterative {
            return Err(err("pingpong mode is the iterative category"));
        }
        if self.steps == 0 {
            return Err(err("pingpong mode needs `steps` >= 1"));
        }
        if self.buffers.len() != 2 || self.stages.len() != 1 {
            return Err(err("pingpong mode takes exactly two buffers (state, param) and one stage"));
        }
        let s0 = &self.stages[0];
        let meta = crate::plan::manifest_meta(&s0.kernel).expect("checked above");
        if s0.inputs.len() != 2 {
            return Err(err("pingpong stage reads [state, param]"));
        }
        let n = self.buffers[0].bytes;
        if self.buffers[1].bytes != n {
            return Err(err(format!(
                "size mismatch: state ({} bytes) and param ({} bytes) must be equal",
                self.buffers[0].bytes, self.buffers[1].bytes
            )));
        }
        if meta.inputs.len() != 2 || meta.outputs.len() != 1 {
            return Err(err(format!("pingpong kernel `{}` must be 2-in 1-out", s0.kernel)));
        }
        if meta.inputs[0].bytes() != n || meta.outputs[0].bytes() != n {
            return Err(err(format!(
                "size mismatch: kernel `{}` block is {} bytes, buffers are {} bytes",
                s0.kernel,
                meta.inputs[0].bytes(),
                n
            )));
        }
        if self.output_bytes != n {
            return Err(err("pingpong downloads the whole state: output_bytes must equal it"));
        }
        Ok(())
    }

    fn validate_tiles(&self) -> Result<()> {
        if self.category != Category::TrueDependent {
            return Err(err("tiles mode is the true_dependent category"));
        }
        if self.buffers.len() != 1 || self.stages.len() != 1 {
            return Err(err("tiles mode takes exactly one buffer (the score matrix) and one stage"));
        }
        let s0 = &self.stages[0];
        let meta = crate::plan::manifest_meta(&s0.kernel).expect("checked above");
        if meta.inputs.len() != 4 || meta.outputs.len() != 3 {
            return Err(err(format!(
                "tiles kernel `{}` must take [north, west, corner, tile] \
                 and emit [out, south, east]",
                s0.kernel
            )));
        }
        let edge = meta.inputs[0].bytes();
        let tile_bytes = meta.inputs[3].bytes();
        let side = edge / 4;
        if side * side * 4 != tile_bytes
            || meta.inputs[1].bytes() != edge
            || meta.inputs[2].bytes() != 4
            || meta.outputs[0].bytes() != tile_bytes
            || meta.outputs[1].bytes() != edge
            || meta.outputs[2].bytes() != edge
        {
            return Err(err(format!("kernel `{}` is not a wavefront tile kernel", s0.kernel)));
        }
        let bytes = self.buffers[0].bytes;
        let elems = bytes / 4;
        let size = (elems as f64).sqrt() as usize;
        if bytes % 4 != 0 || size * size != elems {
            return Err(err("tiles-mode buffer must be a square i32 matrix"));
        }
        if size % side != 0 {
            return Err(err(format!(
                "size mismatch: matrix side {size} must be a multiple \
                 of the kernel tile side {side}"
            )));
        }
        let grid = size / side;
        if self.granularity != grid {
            return Err(err(format!(
                "tiles-mode granularity is pinned by the buffer: expected {grid}, spec says {}",
                self.granularity
            )));
        }
        if self.output_bytes != bytes {
            return Err(err("tiles mode assembles the whole matrix: output_bytes must equal it"));
        }
        Ok(())
    }

    /// Descriptor → spec conversion: the one remaining job of the
    /// corpus path.  All 224 (app, gran) corpus plans flow through
    /// [`SpecCompiler`] via this conversion; the produced plans are
    /// op-for-op identical to the historical `plan/lower.rs` bodies
    /// (the Python mirror cross-checks this per commit).
    pub fn from_corpus(c: &BenchConfig, artifact: &str) -> WorkloadSpec {
        let dil = crate::device::DILATION;
        let h2d = ((c.h2d_bytes as f64 / dil) as usize).max(4);
        let d2h = ((c.d2h_bytes as f64 / dil) as usize).max(4);
        let flops_per_iter = ((c.flops_per_iteration() as f64 / dil) as u64).min(300_000_000);
        let repeats = c.kex_iterations.clamp(1, 20);
        // Halo ratio per window side (false dependent only): the
        // descriptor's halo/chunk element ratio, carried as the
        // historical `inflate` halved so the compiler's per-side
        // arithmetic reproduces the legacy bytes bit-for-bit.
        let inflate = match c.facts.task_dep {
            TaskDep::Rar { halo, chunk } => 2.0 * halo as f64 / chunk.max(1) as f64,
            _ => 0.0,
        };
        WorkloadSpec {
            name: format!("{}/{}", c.app, c.config),
            category: c.category(),
            mode: SpecMode::Block,
            granularity: crate::plan::default_corpus_granularity(c.category()).get(),
            repeats,
            output_bytes: d2h,
            block_bytes: KEX_BLOCK_BYTES,
            steps: 0,
            penalty: 0,
            halo: HaloSpec { lo: inflate / 2.0, hi: inflate / 2.0 },
            buffers: vec![BufferSpec {
                name: "input".into(),
                bytes: h2d,
                init: BufferInit::Synth { seed: corpus_seed(c) },
            }],
            stages: vec![StageSpec {
                kernel: artifact.to_string(),
                inputs: vec!["input".into()],
                flops: Some(flops_per_iter),
            }],
        }
    }
}

/// Deterministic per-descriptor payload seed (FNV-1a over app+config —
/// unchanged from the historical `plan/lower.rs` seeding, so every
/// corpus payload is bitwise what it always was).
pub fn corpus_seed(c: &BenchConfig) -> u64 {
    c.app
        .bytes()
        .chain(c.config.bytes())
        .fold(0xCBF29CE484222325u64, |h, b| (h ^ b as u64).wrapping_mul(0x100000001B3))
}

/// Feature extraction for the learned tuner: compile the spec at its
/// default granularity and featurize the plan — specs ride the k-NN
/// dataset exactly like corpus rows.
pub fn spec_features(
    spec: &WorkloadSpec,
    profile: &crate::device::DeviceProfile,
) -> crate::analysis::PlanFeatures {
    let plan = SpecCompiler::new(spec).streamed();
    crate::analysis::PlanFeatures::of(&plan, profile, spec.category)
}

/// Materialize a buffer's deterministic payload.
pub(crate) fn materialize(b: &BufferSpec) -> Arc<Vec<u8>> {
    use crate::runtime::bytes;
    match b.init {
        BufferInit::Synth { seed } => {
            let mut rng = crate::util::prop::Rng::new(seed);
            let mut v = Vec::with_capacity(b.bytes + 8);
            while v.len() < b.bytes {
                v.extend_from_slice(&rng.next_u64().to_le_bytes());
            }
            v.truncate(b.bytes);
            Arc::new(v)
        }
        BufferInit::F32Rand { seed } => {
            Arc::new(bytes::from_f32(&crate::workloads::gen_f32(b.bytes / 4, seed)))
        }
        BufferInit::I32Rand { seed, bound, shift } => {
            let v: Vec<i32> = crate::workloads::gen_i32(b.bytes / 4, bound, seed)
                .into_iter()
                .map(|x| x - shift)
                .collect();
            Arc::new(bytes::from_i32(&v))
        }
        BufferInit::Zeros => Arc::new(vec![0u8; b.bytes]),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::corpus::all_configs;

    fn minimal_windows_spec() -> WorkloadSpec {
        WorkloadSpec {
            name: "t".into(),
            category: Category::Independent,
            mode: SpecMode::Windows,
            granularity: 4,
            repeats: 1,
            output_bytes: 1024,
            block_bytes: KEX_BLOCK_BYTES,
            steps: 0,
            penalty: 0,
            halo: HaloSpec::ZERO,
            buffers: vec![
                BufferSpec { name: "a".into(), bytes: 1024, init: BufferInit::F32Rand { seed: 1 } },
                BufferSpec { name: "b".into(), bytes: 1024, init: BufferInit::F32Rand { seed: 2 } },
            ],
            stages: vec![StageSpec {
                kernel: "vector_add".into(),
                inputs: vec!["a".into(), "b".into()],
                flops: None,
            }],
        }
    }

    #[test]
    fn json_roundtrip_is_identity() {
        let spec = minimal_windows_spec();
        let text = spec.to_json();
        let back = WorkloadSpec::from_json(&text).expect("canonical json parses");
        assert_eq!(back, spec);
        // And the serialization is a fixed point (hash-stable).
        assert_eq!(back.to_json(), text);
    }

    #[test]
    fn big_seeds_survive_the_f64_json_layer() {
        let mut spec = minimal_windows_spec();
        let seed = 0xDEAD_BEEF_CAFE_F00Du64; // > 2^53
        spec.buffers[0].init = BufferInit::Synth { seed };
        let back = WorkloadSpec::from_json(&spec.to_json()).unwrap();
        assert_eq!(back.buffers[0].init, BufferInit::Synth { seed });
    }

    #[test]
    fn content_hash_tracks_content_not_identity() {
        let a = minimal_windows_spec();
        let mut b = minimal_windows_spec();
        assert_eq!(a.content_hash(), b.content_hash());
        b.granularity = 5;
        assert_ne!(a.content_hash(), b.content_hash());
    }

    #[test]
    fn every_corpus_descriptor_converts_to_a_valid_spec() {
        for c in all_configs() {
            let spec = WorkloadSpec::from_corpus(&c, crate::plan::CORPUS_BURNER);
            spec.validate()
                .unwrap_or_else(|e| panic!("{}/{}: {e}", c.app, c.config));
            // Round-trips too: descriptor-derived specs are exportable.
            let back = WorkloadSpec::from_json(&spec.to_json()).unwrap();
            assert_eq!(back, spec, "{}/{}", c.app, c.config);
        }
    }

    #[test]
    fn validate_rejects_unknown_kernel_and_missing_buffer() {
        let mut s = minimal_windows_spec();
        s.stages[0].kernel = "no_such_kernel".into();
        assert!(matches!(s.validate(), Err(Error::Spec(m)) if m.contains("unknown kernel")));
        let mut s = minimal_windows_spec();
        s.stages[0].inputs[1] = "ghost".into();
        let got = s.validate();
        assert!(matches!(got, Err(Error::Spec(m)) if m.contains("names no declared buffer")));
        let mut s = minimal_windows_spec();
        s.buffers[1].bytes = 512;
        assert!(matches!(s.validate(), Err(Error::Spec(m)) if m.contains("size mismatch")));
    }
}
