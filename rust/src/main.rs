//! `repro` — the hetstream launcher.
//!
//! One subcommand per paper experiment (fig1..fig9, table2, lavamd) plus
//! generic `stream` / `survey` commands.  Run `repro help` for usage.

use hetstream::config::RunConfig;
use hetstream::device::DeviceProfile;
use hetstream::experiments;
use hetstream::hstreams::{Context, ContextBuilder};
use hetstream::util::cli::Args;
use hetstream::workloads::{extended_benchmarks, fig9_benchmarks, Benchmark, Mode};

/// CLI-level result: any error renders via `Display` (no external
/// error-handling crate; the crate's own `hetstream::Error` converts
/// through the std blanket impl).
type Result<T> = std::result::Result<T, Box<dyn std::error::Error>>;

fn cli_err(msg: String) -> Box<dyn std::error::Error> {
    msg.into()
}

const USAGE: &str = "\
repro — hetstream launcher (reproduction of 'Streaming Applications on \
Heterogeneous Platforms', Li et al. 2016)

USAGE: repro <COMMAND> [OPTIONS]

COMMANDS:
  fig1        CDF of R_H2D / R_D2H over the 223-config corpus
                [--engine] [--subset N] [--csv PATH]
  fig2        R vs input dataset (lbm, FDTD3d)          [--engine]
  fig3        R vs code variant (Reduction v1/v2)        [--engine]
  fig4        R vs platform (nn on MIC vs K80 profiles)
  table2      Dependency categorization of all 56 benchmarks
  fig9        Single vs multi-stream, 13 streamed benchmarks
                [--streams N=4] [--scale S=2]
  lavamd      The §5 lavaMD negative case   [--streams N=4] [--scale S=2]
  rgain       R vs gain correlation (ConvSep/Transpose)
  stream NAME Run one streamed benchmark    [--streams N=4] [--scale S=2]
  autotune NAME  Tune a benchmark (paper §6 future work): measured
                 stream ladder, and for re-chunkable drivers (nn,
                 VectorAdd, BlackScholes) the joint (streams x
                 granularity) grid via GenericWorkload::with_chunks
  survey      Full corpus CSV (analytic R + category + decision)
  sweep       Run the corpus through the plan SimBackend across a
              stream ladder (virtual clock; exits non-zero on any
              validation failure); --native additionally cross-checks
              every app's outputs bitwise against the NativeBackend
              (host thread pool)
                --corpus [--ladder 1,2,4,8] [--all-configs] [--native]
                [--csv PATH]
  tune        Joint (streams x granularity) plan autotuner: re-lower
              every corpus app across the candidate grid, validate each
              point bitwise against the bulk lowering, report the
              argmin + seed (paper §6 future work)
                --corpus [--ladder 1,2,4,8] [--grans 1,2,4,8,16]
                [--all-configs] [--json] [--csv PATH]
                [--learned [--dataset PATH]]  hill-climb from the k-NN
                seed (fallback: analytic) instead of the full grid
  verify      Static hazard verifier over the corpus lowerings: prove
              byte-interval race freedom under the backend dependency
              contract, exact D2h output tiling, arena must-zero
              coverage, and graph/lifetime sanity — without executing
              anything; exits non-zero on any hazard
                [--corpus  all 224 (app x granularity) lowerings;
                 default: each app's default granularity (56)]
                [--spec FILE  verify one declarative workload spec
                 instead: bulk + a streamed granularity ladder, every
                 row demanded fully clean (tiling findings included)]
                [--json  structured verdicts for the CI cross-check
                 against tools/mirror/tuner_mirror.py --native-check]
  run-spec FILE  Compile and execute a declarative workload spec
              (specs/*.json, DESIGN.md §Spec): parse → validate →
              SpecCompiler lowering → static hazard check → run; a
              fatal hazard refuses execution (non-zero exit)
                [--streams N=4] [--gran G  override the spec default]
                [--backend sim|native] [--verify  bulk re-chunk oracle,
                 bitwise] [--json  hetstream-run-spec-v1 op-list dump]
                [--tune  seed + prune the joint (streams x granularity)
                 autotuner over the spec's lowering (virtual clock) and
                 run at the argmin; overrides --streams/--gran]
  learn       Learned (streams x granularity) tuner over plan features
              (arXiv:1802.02760-style): build the training set, or
              leave-one-app-out cross-validate the k-NN seed
                [--dataset PATH] [--cv] [--subset N] [--k K=5]
                [--ladder 1,2,4,8] [--grans 1,2,4,8,16] [--out PATH]
  serve       Async multi-tenant StreamService demo: N concurrent
              mixed-category corpus submissions onto shared engine
              lanes (fair per-tenant admission, plan cache, policy-
              picked (streams x granularity)), reported against serial
              execution of the same submission set
                --demo N [--lanes L=4] [--runs R=1]
                [--backend sim|native  native = real host execution]
                [--learned [--dataset PATH] [--k K=5]]
                [--adaptive  windowed feedback controller: same-key
                 request batching, lane elasticity, wakeup switching]
                [--max-lanes M=8] [--dwell MS=250]
                [--batch-on RPS=100] [--batch-off RPS=25]
                [--max-batch B=16]
  bench       Multi-tenant load harness over the StreamService: one
              worker per tenant paces mixed-category corpus submissions
              at --rate req/s for --secs s (closed-loop by default;
              --open-loop submits on schedule regardless of
              completions), with cost-based admission charging each
              request's modeled cost to a per-tenant token bucket;
              reports a per-second throughput + avg/p50/p99 latency
              series, per-tenant sheds, and the BENCH_*.json artifact
                [--tenants T=4] [--rate R=50] [--secs S=2] [--lanes L=4]
                [--backend sim|native  native = real host execution]
                [--open-loop] [--flood F  tenant 0 at F x rate]
                [--admit MS=1000  bucket refill in modeled-ms per wall
                 second (burst 2x); 0 = admit everything]
                [--json [PATH]  write the time series as JSON]
                [--learned [--dataset PATH] [--k K=5]]
                [--adaptive [--max-lanes M=8] [--dwell MS=250]
                 [--batch-on RPS=100] [--batch-off RPS=25]
                 [--max-batch B=16]  the adaptive runtime: per-tick
                 mode/lanes/batches land in the v3 JSON series]
  trace NAME  Dump one benchmark's virtual event timeline as JSON, or
              as a per-lane SVG Gantt chart with --svg
                [--streams N=4] [--scale S=2] [--svg] [--out PATH]
  quickstart  Smoke run: vector_add through the full stack

GLOBAL OPTIONS:
  --config PATH   JSON run config
  --profile NAME  device preset: mic | k80 | fiji | instant | slow-link
  --device NAME   alias of --profile
  --runs N        measurement repetitions (median; paper uses 11)
  --time MODE     virtual (default: deterministic, no sleeping) | wallclock
";

fn profile_from(args: &Args, cfg: &RunConfig) -> Result<DeviceProfile> {
    // `--profile` and `--device` are aliases; the former reads better
    // for service/tuner runs targeting a preset platform.
    if let Some(name) = args.get("profile").or_else(|| args.get("device")) {
        return DeviceProfile::preset(name)
            .ok_or_else(|| cli_err(format!("unknown device preset `{name}`")));
    }
    cfg.device_profile().map_err(|e| cli_err(e.to_string()))
}

fn time_mode_from(args: &Args) -> Result<hetstream::device::TimeMode> {
    match args.get("time") {
        None => Ok(hetstream::device::TimeMode::from_env_default()),
        Some("virtual") => Ok(hetstream::device::TimeMode::Virtual),
        Some("wallclock") | Some("wall") => Ok(hetstream::device::TimeMode::Wallclock),
        Some(other) => Err(cli_err(format!("unknown time mode `{other}`"))),
    }
}

/// Parse `--backend sim|native` (default sim) for service commands.
fn backend_from(args: &Args) -> Result<hetstream::service::ExecBackend> {
    match args.get("backend") {
        None => Ok(hetstream::service::ExecBackend::Sim),
        Some(s) => {
            hetstream::service::ExecBackend::parse(s).map_err(|e| cli_err(e.to_string()))
        }
    }
}

/// Parse a `--flag 1,2,4` integer-list option, with a default.
fn usize_list(args: &Args, flag: &str, default: &[usize]) -> Result<Vec<usize>> {
    match args.get(flag) {
        Some(spec) => spec
            .split(',')
            .map(|tok| tok.trim().parse::<usize>())
            .collect::<std::result::Result<_, _>>()
            .map_err(|_| cli_err(format!("bad --{flag} `{spec}`"))),
        None => Ok(default.to_vec()),
    }
}

/// The service tuning policy behind `serve`/`bench`: analytic by
/// default, the k-NN model with `--learned` (trained on a `--dataset`
/// dump when given).  `sim_profile` must be the dilated profile the
/// service lanes model, so features/predictions match lane physics.
fn policy_from(
    args: &Args,
    sim_profile: &DeviceProfile,
) -> Result<std::sync::Arc<dyn hetstream::service::TunePolicy>> {
    if args.flag("learned") {
        let ds = match args.get("dataset") {
            Some(path) => {
                let text = std::fs::read_to_string(path)?;
                hetstream::analysis::Dataset::from_tune_json(&text, sim_profile)
                    .map_err(|e| cli_err(e.to_string()))?
            }
            None => hetstream::analysis::Dataset::default(),
        };
        eprintln!("learned policy: {} training row(s)", ds.rows.len());
        Ok(std::sync::Arc::new(hetstream::service::LearnedPolicy::new(
            hetstream::analysis::KnnTuner::fit(
                ds,
                args.get_usize("k", hetstream::analysis::DEFAULT_K),
            ),
        )))
    } else {
        Ok(std::sync::Arc::new(hetstream::service::AnalyticPolicy))
    }
}

/// Parse the adaptive-runtime flags shared by `serve` and `bench`:
/// `--adaptive` switches the windowed feedback controller on (request
/// batching + lane elasticity + wakeup-mode switching); the threshold
/// knobs override [`hetstream::service::AdaptiveConfig`]'s defaults.
/// `lanes` (the `--lanes` starting fleet) seeds the elastic floor so
/// the controller never drains below what the caller asked for.
fn adaptive_from(
    args: &Args,
    lanes: usize,
) -> Result<Option<hetstream::service::AdaptiveConfig>> {
    if !args.flag("adaptive") {
        return Ok(None);
    }
    let d = hetstream::service::AdaptiveConfig::default();
    Ok(Some(
        hetstream::service::AdaptiveConfig {
            max_lanes: args.get_usize("max-lanes", d.max_lanes.max(lanes)),
            dwell_ms: args.get_usize("dwell", d.dwell_ms as usize) as u64,
            batch_on_rps: args.get_f64("batch-on", d.batch_on_rps),
            batch_off_rps: args.get_f64("batch-off", d.batch_off_rps),
            max_batch: args.get_usize("max-batch", d.max_batch),
            ..d
        }
        .normalized(),
    ))
}

/// One-line adaptive-runtime summary for `serve`/`bench` output.
fn adaptive_line(a: &hetstream::service::AdaptiveStats) -> String {
    format!(
        "adaptive: {} batch(es) covering {} job(s), {} batch toggle(s) | \
         lanes +{} / -{} (peak {}) | {} wakeup switch(es), \
         park {} ms / spin {} ms",
        a.batches,
        a.batched_jobs,
        a.batch_toggles,
        a.lane_grows,
        a.lane_retires,
        a.peak_lanes,
        a.wakeup_switches,
        a.park_ms,
        a.spin_ms,
    )
}

fn make_ctx_with(
    args: &Args,
    profile: DeviceProfile,
    artifacts: Option<Vec<String>>,
    record_trace: bool,
) -> Result<Context> {
    let mut b = ContextBuilder::new()
        .profile(profile)
        .time_mode(time_mode_from(args)?)
        .record_trace(record_trace);
    if let Some(names) = artifacts {
        b = b.only_artifacts(names);
    }
    b.build().map_err(|e| cli_err(e.to_string()))
}

fn main() -> Result<()> {
    let args = Args::from_env();
    let cfg = match args.get("config") {
        Some(path) => RunConfig::load(path).map_err(|e| cli_err(e.to_string()))?,
        None => RunConfig::default(),
    };
    let runs = args.get_usize("runs", cfg.measure.runs);
    let profile = profile_from(&args, &cfg)?;
    let streams = args.get_usize("streams", cfg.streaming.streams);
    let scale = args.get_usize("scale", 2);

    match args.cmd.as_deref() {
        Some("fig1") => {
            let (table, rows) = if args.flag("engine") {
                let ctx = make_ctx_with(&args, profile, Some(vec!["burner_64".into()]), false)?;
                let subset = args.get("subset").and_then(|s| s.parse().ok());
                experiments::fig1_engine(&ctx, runs, subset)
            } else {
                experiments::fig1_analytic(&profile)
            };
            println!("{}", table.markdown());
            println!("paper: CDF > 50% at R_H2D = 0.1; ~70% for D2H  (n = {})", rows.len());
            if let Some(path) = args.get("csv") {
                let mut t =
                    hetstream::metrics::Table::new("", &["app", "config", "r_h2d", "r_d2h"]);
                for r in &rows {
                    t.row(&[
                        r.app.to_string(),
                        r.config.clone(),
                        format!("{:.4}", r.r_h2d),
                        format!("{:.4}", r.r_d2h),
                    ]);
                }
                std::fs::write(path, t.csv())?;
                println!("wrote {path}");
            }
        }
        Some("fig2") => {
            let table = if args.flag("engine") {
                let ctx =
                    make_ctx_with(&args, profile.clone(), Some(vec!["burner_64".into()]), false)?;
                experiments::fig2(Some(&ctx), &profile, runs)
            } else {
                experiments::fig2(None, &profile, runs)
            };
            println!("{}", table.markdown());
        }
        Some("fig3") => {
            let table = if args.flag("engine") {
                let ctx =
                    make_ctx_with(&args, profile.clone(), Some(vec!["burner_64".into()]), false)?;
                experiments::fig3(Some(&ctx), &profile, runs)
            } else {
                experiments::fig3(None, &profile, runs)
            };
            println!("{}", table.markdown());
        }
        Some("fig4") => println!("{}", experiments::fig4().markdown()),
        Some("table2") => println!("{}", experiments::table2().markdown()),
        Some("fig9") => {
            let ctx = make_ctx_with(&args, profile, None, false)?;
            let (table, _) = experiments::fig9(&ctx, scale, streams, runs)
                .map_err(|e| cli_err(e.to_string()))?;
            println!("{}", table.markdown());
            println!(
                "paper: improvements of 8%..90%; nn ≈ 85%, fwt ≈ 39%, cFFT ≈ 38%, nw ≈ 52%; lavaMD negative"
            );
        }
        Some("lavamd") => {
            let ctx = make_ctx_with(&args, profile, Some(vec!["lavamd_box".into()]), false)?;
            let table = experiments::lavamd_negative(&ctx, scale, streams, runs)
                .map_err(|e| cli_err(e.to_string()))?;
            println!("{}", table.markdown());
        }
        Some("rgain") => {
            let artifacts = Some(vec!["conv_sep".into(), "transpose".into()]);
            let ctx = make_ctx_with(&args, profile, artifacts, false)?;
            let table = experiments::rgain(&ctx, scale, streams, runs)
                .map_err(|e| cli_err(e.to_string()))?;
            println!("{}", table.markdown());
        }
        Some("stream") => {
            let name = args
                .positional
                .first()
                .ok_or_else(|| cli_err("usage: repro stream <NAME> [--streams N]".into()))?;
            let mut benches = fig9_benchmarks(scale);
            benches.extend(extended_benchmarks(scale));
            let b = benches
                .iter()
                .find(|b| b.name().eq_ignore_ascii_case(name))
                .ok_or_else(|| cli_err(format!("unknown benchmark `{name}`")))?;
            let ctx = make_ctx_with(
                &args,
                profile,
                Some(b.artifacts().iter().map(|s| s.to_string()).collect()),
                false,
            )?;
            let base = b.run(&ctx, Mode::Baseline).map_err(|e| cli_err(e.to_string()))?;
            let strm = b.run(&ctx, Mode::Streamed(streams)).map_err(|e| cli_err(e.to_string()))?;
            println!(
                "{name}: baseline {:.2} ms | {streams} streams {:.2} ms | improvement {:+.1}% | validated {}",
                base.wall.as_secs_f64() * 1e3,
                strm.wall.as_secs_f64() * 1e3,
                (base.wall.as_secs_f64() / strm.wall.as_secs_f64() - 1.0) * 100.0,
                base.validated && strm.validated,
            );
        }
        Some("autotune") => {
            let name = args
                .positional
                .first()
                .ok_or_else(|| cli_err("usage: repro autotune <NAME> [--scale S]".into()))?;
            let mut benches = fig9_benchmarks(scale);
            benches.extend(extended_benchmarks(scale));
            let b = benches
                .iter()
                .find(|b| b.name().eq_ignore_ascii_case(name))
                .ok_or_else(|| cli_err(format!("unknown benchmark `{name}`")))?;
            let ctx = make_ctx_with(
                &args,
                profile,
                Some(b.artifacts().iter().map(|s| s.to_string()).collect()),
                false,
            )?;
            match b.tunable() {
                // Re-chunkable driver: tune the joint (streams ×
                // granularity) grid, every point validated bitwise
                // against the bulk lowering.
                Some(wl) => {
                    let result = hetstream::analysis::autotune_workload(
                        &ctx,
                        &wl,
                        &[1, 2, 4, 8],
                        runs.min(5),
                    )
                    .map_err(|e| cli_err(e.to_string()))?;
                    for (n, g, ms) in &result.surface {
                        println!("  {n:2} streams x {g:3} chunks: {ms:8.2} ms");
                    }
                    println!(
                        "best: {} streams x {} chunks ({:.2} ms) | bulk {:.2} ms",
                        result.best_streams, result.best_gran, result.best_ms, result.bulk_ms
                    );
                }
                // Chunk-semantic kernels tune stream count only.
                None => {
                    let result = hetstream::analysis::autotune_streams(
                        &ctx,
                        b.as_ref(),
                        &[1, 2, 4, 8],
                        runs.min(5),
                    )
                    .map_err(|e| cli_err(e.to_string()))?;
                    for (n, ms) in &result.ladder {
                        println!("  {n:2} streams: {ms:8.2} ms");
                    }
                    println!(
                        "best: {} streams ({:.2} ms) — granularity knob n/a for this driver",
                        result.best_streams, result.best_ms
                    );
                }
            }
        }
        Some("survey") => {
            let mut t = hetstream::metrics::Table::new(
                "",
                &["suite", "app", "config", "category", "r_h2d", "r_d2h", "decision"],
            );
            for c in hetstream::corpus::all_configs() {
                let st = experiments::analytic_stage_times(&c, &profile);
                let d = hetstream::analysis::decide(st.r_h2d());
                t.row(&[
                    c.suite.label().to_string(),
                    c.app.to_string(),
                    c.config.clone(),
                    c.category().label().to_string(),
                    format!("{:.4}", st.r_h2d()),
                    format!("{:.4}", st.r_d2h()),
                    format!("{d:?}"),
                ]);
            }
            print!("{}", t.csv());
        }
        Some("sweep") => {
            if !args.flag("corpus") {
                return Err(cli_err("usage: repro sweep --corpus [--ladder 1,2,4,8]".into()));
            }
            let ladder = usize_list(&args, "ladder", &[1, 2, 4, 8])?;
            let ctx = make_ctx_with(
                &args,
                profile,
                Some(vec![hetstream::plan::CORPUS_BURNER.into()]),
                false,
            )?;
            let (table, rows, failures) = hetstream::experiments::sweep_corpus_with(
                &ctx,
                &ladder,
                args.flag("all-configs"),
                args.flag("native"),
            )
            .map_err(|e| cli_err(e.to_string()))?;
            println!("{}", table.markdown());
            if let Some(path) = args.get("csv") {
                std::fs::write(path, table.csv())?;
                println!("wrote {path}");
            }
            println!(
                "swept {} corpus rows through the plan executor (ladder {:?})",
                rows.len(),
                ladder
            );
            if failures > 0 {
                return Err(cli_err(format!("{failures} corpus row(s) failed validation")));
            }
        }
        Some("tune") => {
            if !args.flag("corpus") {
                return Err(cli_err(
                    "usage: repro tune --corpus [--ladder 1,2,4,8] [--grans 1,2,4,8,16]".into(),
                ));
            }
            let ladder = usize_list(&args, "ladder", &[1, 2, 4, 8])?;
            let grans = usize_list(&args, "grans", &[1, 2, 4, 8, 16])?;
            let ctx = make_ctx_with(
                &args,
                profile,
                Some(vec![hetstream::plan::CORPUS_BURNER.into()]),
                false,
            )?;
            // Under the virtual clock every repetition is bit-identical,
            // so medians carry no information — one run per grid point.
            let runs = match ctx.time_mode() {
                hetstream::device::TimeMode::Virtual => 1,
                hetstream::device::TimeMode::Wallclock => runs,
            };
            // --learned: hill-climb from the k-NN seed (trained on a
            // --dataset dump when given) instead of measuring the full
            // grid; analytic seed where the model has no neighbors.
            let model = if args.flag("learned") {
                let ds = match args.get("dataset") {
                    Some(path) => {
                        let text = std::fs::read_to_string(path)?;
                        hetstream::analysis::Dataset::from_tune_json(&text, ctx.profile())
                            .map_err(|e| cli_err(e.to_string()))?
                    }
                    None => hetstream::analysis::Dataset::default(),
                };
                eprintln!("learned tuner: {} training row(s)", ds.rows.len());
                Some(hetstream::analysis::KnnTuner::fit(
                    ds,
                    args.get_usize("k", hetstream::analysis::DEFAULT_K),
                ))
            } else {
                None
            };
            let strategy = match &model {
                Some(m) => hetstream::experiments::TuneStrategy::Pruned { model: Some(m) },
                None => hetstream::experiments::TuneStrategy::Exhaustive,
            };
            let (table, rows, failures) = hetstream::experiments::tune_corpus_with(
                &ctx,
                &ladder,
                &grans,
                args.flag("all-configs"),
                runs,
                strategy,
            )
            .map_err(|e| cli_err(e.to_string()))?;
            let json = args.flag("json");
            if json {
                println!("{}", hetstream::experiments::tune_rows_json(&rows));
            } else {
                println!("{}", table.markdown());
            }
            if let Some(path) = args.get("csv") {
                std::fs::write(path, table.csv())?;
                // Keep --json stdout machine-parseable.
                if json {
                    eprintln!("wrote {path}");
                } else {
                    println!("wrote {path}");
                }
            }
            let beats_fixed = rows.iter().filter(|r| r.validated && r.best_ms < r.fixed_ms).count();
            let (visited, grid) = rows
                .iter()
                .fold((0usize, 0usize), |(v, g), r| (v + r.surface.len(), g + r.grid));
            let summary = format!(
                "tuned {} corpus rows over streams {:?} x granularity {:?}; \
                 {beats_fixed} app(s) beat their fixed-granularity streamed makespan; \
                 measured {visited}/{grid} grid points ({:.0}%)",
                rows.len(),
                ladder,
                grans,
                100.0 * visited as f64 / grid.max(1) as f64,
            );
            if json {
                eprintln!("{summary}");
            } else {
                println!("{summary}");
            }
            if failures > 0 {
                return Err(cli_err(format!("{failures} corpus row(s) failed tuning")));
            }
        }
        Some("verify") => {
            // Pure static analysis: no Context, no artifacts, nothing
            // executes — lower every corpus plan (or one user spec
            // with --spec FILE) and prove it hazard-free (DESIGN.md
            // §Verification, §Spec).
            let (table, rows, failed) = match args.get("spec") {
                Some(path) => {
                    let text = std::fs::read_to_string(path)?;
                    let spec = hetstream::spec::WorkloadSpec::from_json(&text)
                        .map_err(|e| cli_err(e.to_string()))?;
                    spec.validate().map_err(|e| cli_err(e.to_string()))?;
                    experiments::verify_spec(&spec)
                }
                None => experiments::verify_corpus(args.flag("corpus")),
            };
            if args.flag("json") {
                println!("{}", experiments::verify_rows_json(&rows));
                eprintln!("verified {} lowering(s), {failed} failed", rows.len());
            } else {
                println!("{}", table.markdown());
                println!("verified {} lowering(s), {failed} failed", rows.len());
                for r in rows.iter().filter(|r| !r.ok) {
                    if let Some(e) = &r.valid_error {
                        println!("  {}/{} gran {}: validate: {e}", r.app, r.config, r.gran);
                    }
                    for h in &r.report.hazards {
                        println!("  {}/{} gran {}: {h}", r.app, r.config, r.gran);
                    }
                }
            }
            if failed > 0 {
                return Err(cli_err(format!("{failed} lowering(s) have hazards")));
            }
        }
        Some("run-spec") => {
            let path = args.positional.first().ok_or_else(|| {
                cli_err(
                    "usage: repro run-spec <FILE> [--streams N] [--gran G] \
                     [--backend sim|native] [--verify] [--json]"
                        .into(),
                )
            })?;
            let text = std::fs::read_to_string(path)?;
            let spec = hetstream::spec::WorkloadSpec::from_json(&text)
                .map_err(|e| cli_err(e.to_string()))?;
            let gran = match args.get("gran") {
                Some(g) => Some(
                    g.parse::<usize>().map_err(|_| cli_err(format!("bad --gran `{g}`")))?,
                ),
                None => None,
            };
            // The sim engines load artifacts up front: register exactly
            // the kernels the spec's stages name (the tuner needs the
            // same set even when the run itself is native).
            let mut artifacts: Vec<String> =
                spec.stages.iter().map(|s| s.kernel.clone()).collect();
            artifacts.sort();
            artifacts.dedup();
            let mut opts =
                experiments::RunSpecOpts { streams, gran, verify: args.flag("verify") };
            // --tune: route the spec through the seeded pruned joint
            // autotuner (virtual clock) first and run at its argmin —
            // explicit --streams/--gran are overridden by the winner.
            let tuned = if args.flag("tune") {
                let tctx =
                    make_ctx_with(&args, profile.clone(), Some(artifacts.clone()), false)?;
                let t = experiments::tune_spec(&tctx, &spec, runs)
                    .map_err(|e| cli_err(e.to_string()))?;
                opts.streams = t.streams;
                opts.gran = Some(t.gran);
                Some(t)
            } else {
                None
            };
            let mut outcome = match backend_from(&args)? {
                hetstream::service::ExecBackend::Sim => {
                    let ctx = make_ctx_with(&args, profile, Some(artifacts), false)?;
                    experiments::run_spec(
                        &spec,
                        &hetstream::plan::SimBackend::new(&ctx),
                        &opts,
                    )
                }
                hetstream::service::ExecBackend::Native => {
                    experiments::run_spec(&spec, &hetstream::plan::NativeBackend::new(), &opts)
                }
            }
            .map_err(|e| cli_err(e.to_string()))?;
            outcome.tuned = tuned;
            let summary = format!(
                "run-spec {}: {} backend | gran {} x {} stream(s) | {} op(s) / {} task(s) | \
                 wall {:.2} ms | {} hazard(s){}{}",
                spec.name,
                outcome.backend,
                outcome.gran,
                outcome.streams,
                outcome.plan.ops.len(),
                outcome.plan.tasks(),
                outcome.wall_ms,
                outcome.report.hazards.len(),
                match outcome.bulk_match {
                    Some(true) => " | bulk oracle: match",
                    Some(false) => " | bulk oracle: MISMATCH",
                    None => "",
                },
                match &outcome.tuned {
                    Some(t) => format!(
                        " | tuned ({}, {}) best {:.2} ms vs bulk {:.2} ms over {} point(s)",
                        t.streams, t.gran, t.best_ms, t.bulk_ms, t.points
                    ),
                    None => String::new(),
                },
            );
            if args.flag("json") {
                println!("{}", experiments::run_spec_json(&spec, &outcome));
                eprintln!("{summary}");
            } else {
                println!("{summary}");
            }
            if outcome.bulk_match == Some(false) {
                return Err(cli_err(format!(
                    "spec `{}`: streamed outputs diverge from the bulk oracle",
                    spec.name
                )));
            }
        }
        Some("learn") => {
            let ladder = usize_list(&args, "ladder", &[1, 2, 4, 8])?;
            let grans = usize_list(&args, "grans", &[1, 2, 4, 8, 16])?;
            let subset = args.get_usize("subset", 0);
            let k = args.get_usize("k", hetstream::analysis::DEFAULT_K);
            let ctx = make_ctx_with(
                &args,
                profile,
                Some(vec![hetstream::plan::CORPUS_BURNER.into()]),
                false,
            )?;
            let dataset_text = match args.get("dataset") {
                Some(path) => Some(std::fs::read_to_string(path)?),
                None => None,
            };
            if args.flag("cv") {
                // Leave-one-app-out CV: external labels when --dataset
                // was given, in-process exhaustive tuning otherwise.
                let external = match &dataset_text {
                    Some(text) => Some(
                        hetstream::analysis::Dataset::from_tune_json(text, ctx.profile())
                            .map_err(|e| cli_err(e.to_string()))?,
                    ),
                    None => None,
                };
                let (table, stats) = hetstream::experiments::learn_cv(
                    &ctx,
                    &ladder,
                    &grans,
                    subset,
                    k,
                    external.as_ref(),
                )
                .map_err(|e| cli_err(e.to_string()))?;
                println!("{}", table.markdown());
                println!(
                    "learned seed within 10% of the exhaustive optimum on {}/{} app(s) \
                     ({:.0}%); {} prediction(s) from k-NN, {} analytic fallback(s)",
                    stats.within_10pct,
                    stats.apps,
                    100.0 * stats.within_fraction(),
                    stats.learned,
                    stats.apps - stats.learned,
                );
                // CI gate: any app failing to tune — or none tuning at
                // all — is a non-zero exit, same as the sweep smokes.
                if stats.failures > 0 {
                    return Err(cli_err(format!(
                        "{} corpus app(s) failed to tune during CV",
                        stats.failures
                    )));
                }
                if stats.apps == 0 {
                    return Err(cli_err("no corpus app tuned successfully".into()));
                }
            } else {
                let ds = hetstream::experiments::learn_dataset(
                    &ctx,
                    &ladder,
                    &grans,
                    subset,
                    dataset_text.as_deref(),
                )
                .map_err(|e| cli_err(e.to_string()))?;
                println!("{}", hetstream::experiments::dataset_table(&ds).markdown());
                if let Some(path) = args.get("out") {
                    std::fs::write(path, ds.to_json())?;
                    println!("wrote {} training row(s) to {path}", ds.rows.len());
                }
            }
        }
        Some("serve") => {
            let n = args.get_usize("demo", 0);
            if n == 0 {
                return Err(cli_err(
                    "usage: repro serve --demo N [--lanes L] [--runs R] \
                     [--backend sim|native] [--learned [--dataset PATH]]"
                        .into(),
                ));
            }
            let lanes = args.get_usize("lanes", 4);
            // Default 1 repetition (exact under the virtual clock), not
            // the paper's 11 — this is a serving demo, not a benchmark.
            let runs = args.get_usize("runs", 1);
            let time_mode = time_mode_from(&args)?;
            let backend = backend_from(&args)?;
            // Policy features/predictions must see the same (dilated)
            // profile the service lanes model.
            let policy = policy_from(&args, &profile.simulation())?;
            let adaptive = adaptive_from(&args, lanes)?;
            let (table, s) = experiments::serve_demo(
                &profile, time_mode, backend, n, lanes, runs, policy, adaptive,
            )
            .map_err(|e| cli_err(e.to_string()))?;
            println!("{}", table.markdown());
            // Under the virtual clock the headline is the *modeled*
            // speedup (simulated physics); wall time there measures the
            // host CPU cost of simulating — scheduling noise, reported
            // but labeled as such.  On the native backend every number
            // is real host execution, so wall is the headline.
            let native = s.backend == hetstream::service::ExecBackend::Native;
            let (headline_label, wall_note) = if native {
                ("wall", " (real native execution)")
            } else {
                match s.time_mode {
                    hetstream::device::TimeMode::Virtual => {
                        ("modeled", " (host simulation cost under the virtual clock)")
                    }
                    hetstream::device::TimeMode::Wallclock => ("wall", ""),
                }
            };
            println!(
                "service: {} submissions on {} lanes ({} backend) | \
                 {:.2}x {headline_label} speedup | \
                 {} total {:.2} ms, fleet drain {:.2} ms | \
                 plan cache {} hit(s) / {} miss(es)",
                s.submissions,
                s.lanes,
                s.backend.label(),
                s.headline_speedup(),
                if native { "exec" } else { "modeled" },
                s.modeled_total_ms,
                s.modeled_drain_ms,
                s.cache_hits,
                s.cache_misses,
            );
            println!(
                "wall{wall_note}: service {:.1} ms vs serial {:.1} ms = {:.2}x",
                s.service_wall.as_secs_f64() * 1e3,
                s.serial_wall.as_secs_f64() * 1e3,
                s.wall_speedup,
            );
            if let Some(a) = &s.adaptive {
                println!("{}", adaptive_line(a));
            }
            if s.errors > 0 || !s.validated {
                return Err(cli_err(format!(
                    "{} submission error(s); outputs bitwise-identical to serial: {}",
                    s.errors, s.validated
                )));
            }
        }
        Some("bench") => {
            let rate = args.get_f64("rate", 50.0);
            let secs = args.get_f64("secs", 2.0);
            // --admit MS: token-bucket refill in modeled-ms per wall
            // second (burst = 2x refill); 0 disables admission control.
            let refill = args.get_f64("admit", 1_000.0);
            let admission = (refill > 0.0).then(|| hetstream::service::AdmissionConfig {
                refill_ms_per_sec: refill,
                burst_ms: refill * 2.0,
            });
            // --flood F: tenant 0 misbehaves at F x the base rate.
            let flood = match args.get("flood") {
                Some(v) => {
                    let f: f64 =
                        v.parse().map_err(|_| cli_err(format!("bad --flood `{v}`")))?;
                    Some((0usize, f))
                }
                None => None,
            };
            let policy = policy_from(&args, &profile.simulation())?;
            let lanes = args.get_usize("lanes", 4);
            let opts = experiments::BenchOpts {
                tenants: args.get_usize("tenants", 4),
                rate,
                secs,
                open_loop: args.flag("open-loop"),
                lanes,
                flood,
                admission,
                profile: profile.clone(),
                time_mode: time_mode_from(&args)?,
                backend: backend_from(&args)?,
                adaptive: adaptive_from(&args, lanes)?,
            };
            let report =
                experiments::run_bench(&opts, policy).map_err(|e| cli_err(e.to_string()))?;
            println!("{}", experiments::bench_table(&report).markdown());
            println!(
                "bench: {} completed, {} shed, {} error(s) in {:.2} s | {:.1} req/s | \
                 latency avg {:.2} / p50 {:.2} / p99 {:.2} ms | queue avg {:.2} ms | \
                 modeled total {:.2} ms | plan cache {} hit(s) / {} miss(es)",
                report.completed,
                report.rejected,
                report.errors,
                report.duration_s,
                report.throughput_rps,
                report.lat_avg_ms,
                report.lat_p50_ms,
                report.lat_p99_ms,
                report.queue_avg_ms,
                report.modeled_total_ms,
                report.cache_hits,
                report.cache_misses,
            );
            if report.adaptive {
                println!(
                    "adaptive: {} batch(es) covering {} job(s) | lanes +{} / -{} \
                     (peak {} of max {}) | {} wakeup switch(es)",
                    report.batches,
                    report.batched_jobs,
                    report.lane_grows,
                    report.lane_retires,
                    report.peak_lanes,
                    report.max_lanes,
                    report.wakeup_switches,
                );
            }
            for t in &report.per_tenant {
                println!(
                    "  {}: {} completed, {} shed, {} error(s), p99 {:.2} ms",
                    t.tenant, t.completed, t.shed, t.errors, t.p99_ms
                );
            }
            // --json [PATH]: the versioned BENCH_*.json artifact
            // (bare --json picks the timestamped default name).
            if let Some(v) = args.get("json") {
                let path = if v == "true" {
                    hetstream::metrics::default_bench_path()
                } else {
                    v.to_string()
                };
                std::fs::write(&path, hetstream::metrics::bench_json(&report))?;
                println!("wrote {path}");
            }
            if report.completed == 0 {
                return Err(cli_err("bench completed zero submissions".into()));
            }
        }
        Some("trace") => {
            let name = args
                .positional
                .first()
                .ok_or_else(|| cli_err("usage: repro trace <NAME> [--out PATH]".into()))?;
            let mut benches = fig9_benchmarks(scale);
            benches.extend(extended_benchmarks(scale));
            let b = benches
                .iter()
                .find(|b| b.name().eq_ignore_ascii_case(name))
                .ok_or_else(|| cli_err(format!("unknown benchmark `{name}`")))?;
            let ctx = make_ctx_with(
                &args,
                profile,
                Some(b.artifacts().iter().map(|s| s.to_string()).collect()),
                true,
            )?;
            let r = b.run(&ctx, Mode::Streamed(streams)).map_err(|e| cli_err(e.to_string()))?;
            // --svg renders the per-lane Gantt chart instead of the
            // JSON event list (tools/trace_viz.py does the same for a
            // JSON file after the fact).
            let payload = if args.flag("svg") {
                hetstream::metrics::trace_svg(&ctx.trace())
            } else {
                ctx.trace_json()
            };
            match args.get("out") {
                Some(path) => {
                    std::fs::write(path, &payload)?;
                    println!(
                        "wrote {} events ({} bytes) to {path} — makespan {:.3} ms, validated {}",
                        ctx.trace().len(),
                        payload.len(),
                        r.wall.as_secs_f64() * 1e3,
                        r.validated,
                    );
                }
                None => print!("{payload}"),
            }
        }
        Some("quickstart") => {
            let ctx = make_ctx_with(&args, profile, Some(vec!["vector_add".into()]), false)?;
            let b = hetstream::workloads::VectorAdd::new(1);
            let base = b.run(&ctx, Mode::Baseline).map_err(|e| cli_err(e.to_string()))?;
            let strm = b.run(&ctx, Mode::Streamed(4)).map_err(|e| cli_err(e.to_string()))?;
            println!(
                "quickstart OK — baseline {:.2} ms, 4 streams {:.2} ms, validated {}",
                base.wall.as_secs_f64() * 1e3,
                strm.wall.as_secs_f64() * 1e3,
                base.validated && strm.validated
            );
        }
        _ => {
            print!("{USAGE}");
        }
    }
    Ok(())
}
