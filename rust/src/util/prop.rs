//! Seeded property-testing loop (offline replacement for proptest):
//! deterministic xorshift cases with failure-seed reporting.

/// Deterministic 64-bit xorshift* generator.
#[derive(Debug, Clone)]
pub struct Rng(u64);

impl Rng {
    pub fn new(seed: u64) -> Self {
        Rng(seed.wrapping_mul(0x9E3779B97F4A7C15) | 1)
    }

    pub fn next_u64(&mut self) -> u64 {
        self.0 ^= self.0 << 13;
        self.0 ^= self.0 >> 7;
        self.0 ^= self.0 << 17;
        self.0.wrapping_mul(0x2545F4914F6CDD1D)
    }

    /// Uniform in [0, bound).
    pub fn below(&mut self, bound: u64) -> u64 {
        self.next_u64() % bound.max(1)
    }

    /// Uniform usize in [lo, hi].
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        lo + self.below((hi - lo + 1) as u64) as usize
    }

    /// Uniform f64 in [0, 1).
    pub fn unit_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64
    }
}

/// Run `cases` seeded property cases; panics with the failing seed so
/// the case can be replayed.
pub fn check<F: Fn(&mut Rng)>(cases: usize, f: F) {
    for case in 0..cases {
        let seed = 0xC0FFEE ^ (case as u64).wrapping_mul(0x9E37);
        let mut rng = Rng::new(seed);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| f(&mut rng)));
        if let Err(e) = result {
            let msg = e
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| e.downcast_ref::<&str>().map(|s| s.to_string()))
                .unwrap_or_else(|| "panic".into());
            panic!("property failed on case {case} (seed {seed:#x}): {msg}");
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rng_is_deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn range_within_bounds() {
        let mut r = Rng::new(7);
        for _ in 0..1000 {
            let v = r.range(3, 9);
            assert!((3..=9).contains(&v));
        }
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn failing_property_reports_seed() {
        check(10, |rng| {
            assert!(rng.below(10) < 5, "too big");
        });
    }
}
