//! Tiny CLI argument helper (offline replacement for clap): positional
//! subcommand + `--flag`, `--key value` options.

use std::collections::BTreeMap;

/// Parsed command line.
#[derive(Debug, Clone, Default)]
pub struct Args {
    /// First non-flag token (the subcommand).
    pub cmd: Option<String>,
    /// Remaining positional tokens.
    pub positional: Vec<String>,
    /// `--key value` options and bare `--flag`s (value = "true").
    pub opts: BTreeMap<String, String>,
}

impl Args {
    /// Parse from an iterator of tokens (usually `std::env::args().skip(1)`).
    pub fn parse<I: IntoIterator<Item = String>>(it: I) -> Args {
        let tokens: Vec<String> = it.into_iter().collect();
        let mut out = Args::default();
        let mut i = 0;
        while i < tokens.len() {
            let t = &tokens[i];
            if let Some(key) = t.strip_prefix("--") {
                // `--key=value` or `--key value` or bare flag.
                if let Some((k, v)) = key.split_once('=') {
                    out.opts.insert(k.to_string(), v.to_string());
                } else if i + 1 < tokens.len() && !tokens[i + 1].starts_with("--") {
                    out.opts.insert(key.to_string(), tokens[i + 1].clone());
                    i += 1;
                } else {
                    out.opts.insert(key.to_string(), "true".into());
                }
            } else if out.cmd.is_none() {
                out.cmd = Some(t.clone());
            } else {
                out.positional.push(t.clone());
            }
            i += 1;
        }
        out
    }

    /// From the process arguments.
    pub fn from_env() -> Args {
        Self::parse(std::env::args().skip(1))
    }

    pub fn flag(&self, name: &str) -> bool {
        self.opts.get(name).map(|v| v != "false").unwrap_or(false)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.opts.get(name).map(|s| s.as_str())
    }

    pub fn get_usize(&self, name: &str, default: usize) -> usize {
        self.get(name).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn get_f64(&self, name: &str, default: f64) -> f64 {
        self.get(name).and_then(|v| v.parse().ok()).unwrap_or(default)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from))
    }

    #[test]
    fn subcommand_and_options() {
        let a = parse("fig9 --streams 8 --scale=2 --engine");
        assert_eq!(a.cmd.as_deref(), Some("fig9"));
        assert_eq!(a.get_usize("streams", 4), 8);
        assert_eq!(a.get_usize("scale", 1), 2);
        assert!(a.flag("engine"));
        assert!(!a.flag("missing"));
    }

    #[test]
    fn positional_after_command() {
        let a = parse("stream nn --streams 2");
        assert_eq!(a.cmd.as_deref(), Some("stream"));
        assert_eq!(a.positional, vec!["nn".to_string()]);
        assert_eq!(a.get_usize("streams", 4), 2);
    }

    #[test]
    fn defaults_apply() {
        let a = parse("fig1");
        assert_eq!(a.get_usize("runs", 11), 11);
        assert_eq!(a.get("csv"), None);
    }
}
